"""Layer-2 tests: model shapes, training dynamics, and path equivalence."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model as M
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")

SMALL = dict(din=8, hidden=16, classes=4, batch=32, fanouts=(4, 3))


def _sample(cfg, seed=0, full_mask=False):
    key = jax.random.PRNGKey(seed)
    sizes = cfg.level_sizes()
    ks = jax.random.split(key, len(sizes) + cfg.layers)
    xs = [jax.random.normal(ks[i], (n, cfg.din)) for i, n in enumerate(sizes)]
    masks = []
    for i in range(cfg.layers):
        if full_mask:
            masks.append(jnp.ones((sizes[i + 1],)))
        else:
            masks.append(
                (jax.random.uniform(ks[len(sizes) + i], (sizes[i + 1],)) < 0.8)
                .astype(jnp.float32)
            )
    return xs, masks


@pytest.mark.parametrize("kind", ["gcn", "sage", "gat"])
class TestForward:
    def test_logit_shape(self, kind):
        cfg = M.ModelConfig(kind=kind, **SMALL)
        xs, masks = _sample(cfg)
        logits = M.forward(cfg, M.init_params(cfg), xs, masks)
        assert logits.shape == (cfg.batch, cfg.classes)
        assert bool(jnp.all(jnp.isfinite(logits)))

    def test_padding_invariance(self, kind):
        """Features of masked-out subtrees must not change seed logits."""
        cfg = M.ModelConfig(kind=kind, **SMALL)
        xs, masks = _sample(cfg)
        params = M.init_params(cfg)
        base = M.forward(cfg, params, xs, masks, use_kernel=False)
        # Scramble every masked position's features at each level >= 1.
        xs2 = [xs[0]]
        for lvl in range(1, len(xs)):
            m = masks[lvl - 1][:, None]
            noise = 1e3 * jax.random.normal(jax.random.PRNGKey(9), xs[lvl].shape)
            xs2.append(xs[lvl] * m + noise * (1 - m))
        pert = M.forward(cfg, params, xs2, masks, use_kernel=False)
        np.testing.assert_allclose(base, pert, rtol=1e-4, atol=1e-4)

    def test_train_step_reduces_loss(self, kind):
        cfg = M.ModelConfig(kind=kind, **SMALL)
        xs, masks = _sample(cfg, full_mask=True)
        labels = jnp.arange(cfg.batch, dtype=jnp.int32) % cfg.classes
        params = M.init_params(cfg)
        losses = []
        lr = 0.1 if kind == "sage" else 0.5
        for _ in range(20):
            loss, params = M.train_step(cfg, params, xs, masks, labels, lr)
            losses.append(float(loss))
        assert losses[-1] < losses[0] - 0.05, losses


class TestPathEquivalence:
    def test_gat_kernel_vs_ref_forward(self):
        """GAT eval (Pallas kernel) must match GAT train forward (jnp ref)."""
        cfg = M.ModelConfig(kind="gat", heads=4, **SMALL)
        xs, masks = _sample(cfg)
        params = M.init_params(cfg)
        a = M.forward(cfg, params, xs, masks, use_kernel=True)
        b = M.forward(cfg, params, xs, masks, use_kernel=False)
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)

    def test_layerwise_equals_samplewise_embedding(self):
        """The layerwise slice composition must reproduce the tree forward
        when given the same (full) neighborhood — the inference engine's
        correctness contract, checked here at the numerics level."""
        f = 4
        cfg = M.ModelConfig(kind="sage", din=8, hidden=16, classes=1,
                            batch=32, fanouts=(f, f))
        params = M.init_params(cfg)
        xs, masks = _sample(cfg, full_mask=True)
        tree_emb = M.embed_forward(cfg, params, xs, masks)

        # Layerwise: compute h1 for level-0 and level-1 nodes, then h2 for
        # level-0 from level-1's h1 — exactly what the Rust engine does with
        # cached chunks.
        lp0, lp1 = params[0:3], params[3:6]
        n0, n1 = cfg.batch, cfg.batch * f
        h1_l0 = M.sage_layer_slice(
            xs[0], xs[1].reshape(n0, f, -1), masks[0].reshape(n0, f), *lp0,
            relu=True)
        h1_l1 = M.sage_layer_slice(
            xs[1], xs[2].reshape(n1, f, -1), masks[1].reshape(n1, f), *lp0,
            relu=True)
        h2 = M.sage_layer_slice(
            h1_l0, h1_l1.reshape(n0, f, -1), masks[0].reshape(n0, f), *lp1,
            relu=False)
        np.testing.assert_allclose(h2, tree_emb, rtol=1e-4, atol=1e-4)

    def test_link_decode_range_and_symmetry_breaking(self):
        h = 16
        ks = jax.random.split(jax.random.PRNGKey(0), 6)
        u = jax.random.normal(ks[0], (8, h))
        v = jax.random.normal(ks[1], (8, h))
        w1 = jax.random.normal(ks[2], (2 * h, h)) * 0.1
        b1 = jnp.zeros(h)
        w2 = jax.random.normal(ks[3], (h, 1))
        b2 = jnp.zeros(1)
        s = M.link_decode(u, v, w1, b1, w2, b2)
        assert s.shape == (8,)
        assert bool(jnp.all((s > 0) & (s < 1)))
        s_swapped = M.link_decode(v, u, w1, b1, w2, b2)
        assert not np.allclose(s, s_swapped)  # decoder is direction-aware


class TestGradStep:
    def test_grads_match_train_step_delta(self):
        cfg = M.ModelConfig(kind="sage", **SMALL)
        xs, masks = _sample(cfg)
        labels = jnp.zeros((cfg.batch,), jnp.int32)
        params = M.init_params(cfg)
        loss_g, grads = M.grad_step(cfg, params, xs, masks, labels)
        loss_t, new_params = M.train_step(cfg, params, xs, masks, labels, 0.5)
        assert abs(float(loss_g) - float(loss_t)) < 1e-6
        for p, g, np_ in zip(params, grads, new_params):
            np.testing.assert_allclose(np_, p - 0.5 * g, rtol=1e-5, atol=1e-6)
