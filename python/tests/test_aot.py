"""AOT contract tests: the manifest + HLO text artifacts Rust depends on.

These validate the build-time interchange: manifest input/output specs match
what executing the artifact's source function produces, and the emitted HLO
text parses back through the XLA client (the same parser family the Rust
side's xla_extension uses).
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot
from compile import model as M

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
pytestmark = pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="artifacts not built (run `make artifacts`)",
)


@pytest.fixture(scope="module")
def manifest():
    with open(os.path.join(ART, "manifest.json")) as f:
        return json.load(f)


def test_manifest_lists_all_expected_artifacts(manifest):
    names = {e["name"] for e in manifest["artifacts"]}
    expected = {
        "sage_train", "sage_eval", "sage_grad",
        "gcn_train", "gcn_eval", "gat_train", "gat_eval",
        "sage_infer_layer0", "sage_infer_layer1",
        "sage_embed", "link_decode",
    }
    assert expected <= names


def test_every_artifact_file_exists_and_is_hlo_text(manifest):
    for e in manifest["artifacts"]:
        path = os.path.join(ART, e["file"])
        assert os.path.exists(path), e["file"]
        head = open(path).read(200)
        assert "HloModule" in head, f"{e['file']} does not look like HLO text"


def test_train_artifact_io_specs(manifest):
    entry = next(e for e in manifest["artifacts"] if e["name"] == "sage_train")
    cfg = M.ModelConfig(kind="sage", **aot.TRAIN_CFG)
    n_params = len(M.param_specs(cfg))
    sizes = cfg.level_sizes()
    # inputs: params + xs + masks + labels + lr
    assert len(entry["inputs"]) == n_params + len(sizes) + cfg.layers + 2
    assert entry["inputs"][-1]["name"] == "lr"
    assert entry["inputs"][-2]["dtype"] == "i32"
    # outputs: loss + new params
    assert len(entry["outputs"]) == 1 + n_params
    assert entry["outputs"][0]["shape"] == [1]
    # param output shapes mirror param input shapes
    for spec, out in zip(entry["inputs"][:n_params], entry["outputs"][1:]):
        assert spec["shape"] == out["shape"]


def test_infer_layer_specs_chain(manifest):
    l0 = next(e for e in manifest["artifacts"] if e["name"] == "sage_infer_layer0")
    l1 = next(e for e in manifest["artifacts"] if e["name"] == "sage_infer_layer1")
    assert l0["meta"]["dout"] == l1["meta"]["din"]
    assert l0["outputs"][0]["shape"] == [l0["meta"]["chunk"], l0["meta"]["dout"]]


def test_hlo_text_round_trips_through_xla_parser(manifest):
    from jax._src.lib import xla_client as xc

    # Parse the smallest artifact back via the XLA HLO text parser.
    entry = next(e for e in manifest["artifacts"] if e["name"] == "link_decode")
    text = open(os.path.join(ART, entry["file"])).read()
    # mlir path exists in this jaxlib; hlo text parse is exercised on the
    # rust side — here we sanity-check structure instead.
    assert text.count("parameter(") >= len(entry["inputs"])


def test_executed_artifact_matches_source_function(manifest):
    """Execute link_decode's source fn on concrete inputs and compare with
    re-lowered + jax-executed HLO semantics (numeric ground truth)."""
    entry = next(e for e in manifest["artifacts"] if e["name"] == "link_decode")
    rng = np.random.default_rng(0)
    args = [
        jnp.asarray(rng.normal(size=s["shape"]).astype(np.float32))
        for s in entry["inputs"]
    ]
    out = M.link_decode(*args)
    assert out.shape == tuple(entry["outputs"][0]["shape"])
    assert bool(jnp.all((out >= 0) & (out <= 1)))
