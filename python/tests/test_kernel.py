"""Layer-1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

This is the CORE numeric signal of the repo: the Rust runtime executes HLO
lowered from these kernels, so kernel == ref (swept over shapes/dtypes by
hypothesis) transfers correctness to the whole stack.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed")
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.sage_agg import sage_agg, BN
from compile.kernels.gat_attn import gat_attn

jax.config.update("jax_platform_name", "cpu")


def _rand(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


def _mask(key, n, f, p_real=0.7, ensure_row=False):
    m = (jax.random.uniform(key, (n, f)) < p_real).astype(jnp.float32)
    if ensure_row:
        m = m.at[:, 0].set(1.0)
    return m


def _tols(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(
        rtol=1e-5, atol=1e-5
    )


shape_strategy = st.tuples(
    st.sampled_from([1, 2, 32, 64, 96]),   # N (32-multiples + ragged tails)
    st.integers(min_value=1, max_value=17),  # F
    st.sampled_from([4, 8, 64]),             # D
    st.sampled_from([8, 16, 128]),           # H
)


class TestSageAgg:
    @settings(max_examples=25, deadline=None)
    @given(shape_strategy, st.integers(0, 2**31 - 1))
    def test_matches_ref(self, shape, seed):
        n, f, d, h = shape
        ks = jax.random.split(jax.random.PRNGKey(seed), 6)
        hs = _rand(ks[0], (n, d), jnp.float32)
        hn = _rand(ks[1], (n, f, d), jnp.float32)
        m = _mask(ks[2], n, f)
        ws = _rand(ks[3], (d, h), jnp.float32)
        wn = _rand(ks[4], (d, h), jnp.float32)
        b = _rand(ks[5], (h,), jnp.float32)
        out = sage_agg(hs, hn, m, ws, wn, b)
        exp = ref.sage_agg_ref(hs, hn, m, ws, wn, b)
        np.testing.assert_allclose(out, exp, **_tols(jnp.float32))

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtypes(self, dtype):
        ks = jax.random.split(jax.random.PRNGKey(0), 6)
        n, f, d, h = 64, 10, 16, 32
        hs = _rand(ks[0], (n, d), dtype)
        hn = _rand(ks[1], (n, f, d), dtype)
        m = _mask(ks[2], n, f)
        ws = _rand(ks[3], (d, h), dtype)
        wn = _rand(ks[4], (d, h), dtype)
        b = _rand(ks[5], (h,), dtype)
        out = sage_agg(hs, hn, m.astype(dtype), ws, wn, b)
        exp = ref.sage_agg_ref(
            hs.astype(jnp.float32), hn.astype(jnp.float32), m,
            ws.astype(jnp.float32), wn.astype(jnp.float32),
            b.astype(jnp.float32),
        )
        np.testing.assert_allclose(
            out.astype(jnp.float32), exp, **_tols(dtype)
        )

    def test_all_padding_rows_are_zero_aggregate(self):
        """Isolated vertices (all-zero mask) must aggregate to b + h·W_s only."""
        n, f, d, h = 32, 4, 8, 8
        hs = jnp.ones((n, d))
        hn = 100.0 * jnp.ones((n, f, d))  # must NOT leak into the output
        m = jnp.zeros((n, f))
        ws = jnp.eye(d, h)
        wn = jnp.eye(d, h)
        b = jnp.zeros((h,))
        out = sage_agg(hs, hn, m, ws, wn, b)
        np.testing.assert_allclose(out, hs @ ws, rtol=1e-6)

    def test_grid_blocking_equals_single_block(self):
        """N=96 (3 grid blocks) must agree with the same rows run block-free."""
        ks = jax.random.split(jax.random.PRNGKey(7), 6)
        n, f, d, h = 3 * BN, 6, 8, 8
        hs = _rand(ks[0], (n, d), jnp.float32)
        hn = _rand(ks[1], (n, f, d), jnp.float32)
        m = _mask(ks[2], n, f)
        ws = _rand(ks[3], (d, h), jnp.float32)
        wn = _rand(ks[4], (d, h), jnp.float32)
        b = _rand(ks[5], (h,), jnp.float32)
        full = sage_agg(hs, hn, m, ws, wn, b)
        for i in range(3):
            sl = slice(i * BN, (i + 1) * BN)
            part = sage_agg(hs[sl], hn[sl], m[sl], ws, wn, b)
            np.testing.assert_allclose(full[sl], part, rtol=1e-5, atol=1e-5)


class TestSageAggVjp:
    @settings(max_examples=15, deadline=None)
    @given(st.integers(0, 2**31 - 1))
    def test_input_grads_match_ref(self, seed):
        ks = jax.random.split(jax.random.PRNGKey(seed), 6)
        n, f, d, h = 64, 7, 8, 16
        hs = _rand(ks[0], (n, d), jnp.float32)
        hn = _rand(ks[1], (n, f, d), jnp.float32)
        m = _mask(ks[2], n, f)
        ws = _rand(ks[3], (d, h), jnp.float32)
        wn = _rand(ks[4], (d, h), jnp.float32)
        b = _rand(ks[5], (h,), jnp.float32)

        def loss_k(hs, hn, ws, wn, b):
            return jnp.sum(jnp.tanh(sage_agg(hs, hn, m, ws, wn, b)))

        def loss_r(hs, hn, ws, wn, b):
            return jnp.sum(jnp.tanh(ref.sage_agg_ref(hs, hn, m, ws, wn, b)))

        gk = jax.grad(loss_k, argnums=(0, 1, 2, 3, 4))(hs, hn, ws, wn, b)
        gr = jax.grad(loss_r, argnums=(0, 1, 2, 3, 4))(hs, hn, ws, wn, b)
        for a, e in zip(gk, gr):
            np.testing.assert_allclose(a, e, rtol=1e-4, atol=1e-4)

    def test_bwd_kernel_matches_bwd_ref_directly(self):
        ks = jax.random.split(jax.random.PRNGKey(3), 4)
        n, f, d, h = 32, 5, 8, 16
        g = _rand(ks[0], (n, h), jnp.float32)
        m = _mask(ks[1], n, f)
        ws = _rand(ks[2], (d, h), jnp.float32)
        wn = _rand(ks[3], (d, h), jnp.float32)
        from compile.kernels.sage_agg import _sage_agg_fwd, _sage_agg_bwd

        hs = _rand(ks[0], (n, d), jnp.float32)
        hn = _rand(ks[1], (n, f, d), jnp.float32)
        _, res = _sage_agg_fwd(hs, hn, m, ws, wn, jnp.zeros(h))
        d_self, d_neigh = _sage_agg_bwd(res, g)[:2]
        e_self, e_neigh = ref.sage_agg_bwd_inputs_ref(g, m, ws, wn)
        np.testing.assert_allclose(d_self, e_self, rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(d_neigh, e_neigh, rtol=1e-5, atol=1e-5)


class TestGatAttn:
    @settings(max_examples=20, deadline=None)
    @given(
        st.sampled_from([1, 32, 64]),
        st.integers(1, 12),
        st.sampled_from([8, 16, 32]),
        st.integers(0, 2**31 - 1),
    )
    def test_matches_ref(self, n, f, h, seed):
        ks = jax.random.split(jax.random.PRNGKey(seed), 5)
        hw_s = _rand(ks[0], (n, h), jnp.float32)
        hw_n = _rand(ks[1], (n, f, h), jnp.float32)
        m = _mask(ks[2], n, f)
        a_s = _rand(ks[3], (h,), jnp.float32)
        a_n = _rand(ks[4], (h,), jnp.float32)
        out = gat_attn(hw_s, hw_n, m, a_s, a_n)
        exp = ref.gat_attn_ref(hw_s, hw_n, m, a_s, a_n)
        np.testing.assert_allclose(out, exp, rtol=1e-4, atol=1e-4)

    def test_all_masked_reduces_to_self_loop(self):
        """With every neighbor masked, attention collapses onto the self loop."""
        n, f, h = 32, 4, 8
        ks = jax.random.split(jax.random.PRNGKey(1), 3)
        hw_s = _rand(ks[0], (n, h), jnp.float32)
        hw_n = 1e6 * jnp.ones((n, f, h))
        m = jnp.zeros((n, f))
        a_s = _rand(ks[1], (h,), jnp.float32)
        a_n = _rand(ks[2], (h,), jnp.float32)
        out = gat_attn(hw_s, hw_n, m, a_s, a_n)
        np.testing.assert_allclose(out, hw_s, rtol=1e-4, atol=1e-4)

    def test_attention_weights_sum_to_one(self):
        """Uniform features ⇒ output == that feature row (softmax sums to 1)."""
        n, f, h = 32, 6, 8
        row = jnp.arange(h, dtype=jnp.float32)
        hw_s = jnp.tile(row, (n, 1))
        hw_n = jnp.tile(row, (n, f, 1))
        m = jnp.ones((n, f))
        out = gat_attn(hw_s, hw_n, m, jnp.ones(h), jnp.ones(h))
        np.testing.assert_allclose(out, hw_s, rtol=1e-5, atol=1e-5)
