"""Layer-1 Pallas kernel: fused masked GAT attention-softmax-aggregate.

Computes, per seed row, attention over the fanout-padded neighbor tile plus
a self loop (semantics = ref.gat_attn_ref):

    e_j    = leaky_relu(a_s·hw_self + a_n·hw_neigh_j)   (masked to -inf)
    e_loop = leaky_relu(a_s·hw_self + a_n·hw_self)
    alpha  = softmax([e_loop, e_1..e_F])
    out    = alpha_loop·hw_self + Σ_j alpha_j·hw_neigh_j

The softmax is computed with the usual max-subtraction inside the VMEM tile,
so the kernel performs a single pass over the [BN, F, H] neighbor tile. The
scores are VPU reductions against the broadcast attention vectors; the
weighted sum reduces the fanout axis in-register. Used on the GAT
forward/eval path (no VJP: the GAT train step uses the jnp reference, and
pytest pins kernel == ref).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BN = 32
NEG_SLOPE = 0.2


def _leaky_relu(x):
    return jnp.where(x >= 0, x, NEG_SLOPE * x)


def _kernel(hw_self_ref, hw_neigh_ref, mask_ref, a_s_ref, a_n_ref, o_ref):
    hw_self = hw_self_ref[...]      # [BN, H]
    hw_neigh = hw_neigh_ref[...]    # [BN, F, H]
    mask = mask_ref[...]            # [BN, F]
    a_s = a_s_ref[...]              # [H]
    a_n = a_n_ref[...]              # [H]

    e_self_part = hw_self @ a_s                       # [BN]
    e_nbr = _leaky_relu(e_self_part[:, None] + hw_neigh @ a_n)  # [BN, F]
    e_loop = _leaky_relu(e_self_part + hw_self @ a_n)           # [BN]
    neg = jnp.finfo(jnp.float32).min
    e_nbr = jnp.where(mask > 0, e_nbr, neg)

    m = jnp.maximum(jnp.max(e_nbr, axis=1), e_loop)   # [BN]
    w_loop = jnp.exp(e_loop - m)                      # [BN]
    w_nbr = jnp.exp(e_nbr - m[:, None]) * mask        # [BN, F]
    denom = w_loop + jnp.sum(w_nbr, axis=1)           # [BN]
    out = (
        w_loop[:, None] * hw_self
        + jnp.sum(w_nbr[..., None] * hw_neigh, axis=1)
    ) / denom[:, None]
    o_ref[...] = out.astype(o_ref.dtype)


def gat_attn(hw_self, hw_neigh, mask, a_self, a_neigh):
    """Fused single-head GAT attention; see module docstring."""
    n, h = hw_self.shape
    f = hw_neigh.shape[1]
    bn = BN if n % BN == 0 else n
    return pl.pallas_call(
        _kernel,
        grid=(n // bn,),
        in_specs=[
            pl.BlockSpec((bn, h), lambda i: (i, 0)),
            pl.BlockSpec((bn, f, h), lambda i: (i, 0, 0)),
            pl.BlockSpec((bn, f), lambda i: (i, 0)),
            pl.BlockSpec((h,), lambda i: (0,)),
            pl.BlockSpec((h,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bn, h), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, h), hw_self.dtype),
        interpret=True,
    )(hw_self, hw_neigh, mask, a_self, a_neigh)
