"""Pure-jnp correctness oracles for the Pallas kernels.

Every Pallas kernel in this package has its semantics defined here first;
pytest (python/tests/) sweeps shapes/dtypes with hypothesis and asserts
allclose between the kernel (interpret=True) and these references. The L2
models also reuse these functions directly for the GCN/GAT train paths,
so "kernel == ref" is the single correctness contract of Layer 1.

Conventions (the "tree format", DESIGN.md §6):
  h_self  : [N, D]     node features of a level
  h_neigh : [N, F, D]  fanout-padded neighbor features (next level reshaped)
  mask    : [N, F]     1.0 for a real neighbor, 0.0 for padding
"""

import jax
import jax.numpy as jnp


def masked_mean(h_neigh, mask):
    """Mean over the fanout axis, counting only real neighbors.

    Vertices with zero sampled neighbors get a zero vector (the samplers
    emit an all-zero mask row for isolated vertices).
    """
    m = mask[..., None]
    cnt = jnp.maximum(jnp.sum(mask, axis=-1, keepdims=True), 1.0)
    return jnp.sum(h_neigh * m, axis=1) / cnt


def sage_agg_ref(h_self, h_neigh, mask, w_self, w_neigh, b):
    """GraphSAGE-mean aggregation + dual projection (no activation).

    out = h_self @ W_s + masked_mean(h_neigh) @ W_n + b
    """
    agg = masked_mean(h_neigh, mask)
    return h_self @ w_self + agg @ w_neigh + b


def gcn_agg_ref(h_self, h_neigh, mask, w, b):
    """GCN-style aggregation: mean over {self} ∪ neighbors, then project."""
    cnt = jnp.sum(mask, axis=-1, keepdims=True) + 1.0
    s = h_self + jnp.sum(h_neigh * mask[..., None], axis=1)
    return (s / cnt) @ w + b


def gat_attn_ref(hw_self, hw_neigh, mask, a_self, a_neigh, negative_slope=0.2):
    """Single-head GAT attention over fanout-padded neighbors (+ self loop).

    hw_* are features already projected by the layer weight W.
    score_j    = leaky_relu(a_s·hw_self + a_n·hw_neigh_j)
    score_self = leaky_relu(a_s·hw_self + a_n·hw_self)
    alpha      = softmax over {self} ∪ masked neighbors
    out        = alpha_self * hw_self + Σ_j alpha_j * hw_neigh_j
    """
    e_self_part = hw_self @ a_self  # [N]
    e_nbr = jax.nn.leaky_relu(
        e_self_part[:, None] + hw_neigh @ a_neigh, negative_slope
    )  # [N, F]
    e_loop = jax.nn.leaky_relu(e_self_part + hw_self @ a_neigh, negative_slope)
    neg = jnp.finfo(hw_self.dtype).min
    e_nbr = jnp.where(mask > 0, e_nbr, neg)
    e_all = jnp.concatenate([e_loop[:, None], e_nbr], axis=1)  # [N, 1+F]
    alpha = jax.nn.softmax(e_all, axis=1)
    h_all = jnp.concatenate([hw_self[:, None, :], hw_neigh], axis=1)
    return jnp.sum(alpha[..., None] * h_all, axis=1)


def sage_agg_bwd_inputs_ref(g, mask, w_self, w_neigh):
    """Reference for the input-side VJP of sage_agg.

    d h_self  = g @ W_s^T
    d h_neigh = (g @ W_n^T / cnt)[:, None, :] * mask[..., None]
    """
    d_self = g @ w_self.T
    cnt = jnp.maximum(jnp.sum(mask, axis=-1, keepdims=True), 1.0)
    d_agg = g @ w_neigh.T / cnt  # [N, D]
    d_neigh = d_agg[:, None, :] * mask[..., None]
    return d_self, d_neigh
