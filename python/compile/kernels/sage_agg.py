"""Layer-1 Pallas kernel: fused GraphSAGE-mean aggregation + dual projection.

This is the compute hot-spot of GLISP's training and layerwise-inference
paths: for every level of the tree-format subgraph,

    out = h_self @ W_s + masked_mean(h_neigh) @ W_n + b

The kernel fuses the masked fanout reduction with both projections so the
[N, F, D] neighbor tensor is read from HBM exactly once and never
materializes an intermediate [N, D] aggregate in HBM.

TPU mapping (DESIGN.md §Hardware-Adaptation): the grid tiles the seed axis
N into blocks of BN rows; each grid step holds one [BN, F, D] neighbor tile,
the full [D, H] weight panels and the [BN, H] output tile in VMEM. The
fanout reduction is a VPU masked sum over axis 1; the two projections are
MXU matmuls. interpret=True is mandatory on this image (CPU PJRT cannot run
Mosaic custom-calls), so wall-clock here is meaningless — the §Perf VMEM /
MXU numbers in DESIGN.md are derived from these BlockSpecs.

A custom VJP makes the kernel trainable: the input-side gradients (the
large tensors) run as a second Pallas kernel; the weight-side gradients are
cross-block reductions and stay in jnp, where XLA emits them as plain
matmuls over the same tiles.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Seed-axis block size. Every level size the Rust samplers emit is a
# multiple of 32 (level sizes are B·∏f with B a multiple of 32).
BN = 32


def _fwd_kernel(h_self_ref, h_neigh_ref, mask_ref, ws_ref, wn_ref, b_ref, o_ref):
    h_self = h_self_ref[...]            # [BN, D]
    h_neigh = h_neigh_ref[...]          # [BN, F, D]
    mask = mask_ref[...]                # [BN, F]
    cnt = jnp.maximum(jnp.sum(mask, axis=-1, keepdims=True), 1.0)
    agg = jnp.sum(h_neigh * mask[..., None], axis=1) / cnt  # [BN, D]
    out = (
        jnp.dot(h_self, ws_ref[...], preferred_element_type=jnp.float32)
        + jnp.dot(agg, wn_ref[...], preferred_element_type=jnp.float32)
        + b_ref[...]
    )
    o_ref[...] = out.astype(o_ref.dtype)


def _bwd_kernel(g_ref, mask_ref, ws_ref, wn_ref, d_self_ref, d_neigh_ref):
    g = g_ref[...]                      # [BN, H]
    mask = mask_ref[...]                # [BN, F]
    d_self_ref[...] = jnp.dot(
        g, ws_ref[...].T, preferred_element_type=jnp.float32
    ).astype(d_self_ref.dtype)
    cnt = jnp.maximum(jnp.sum(mask, axis=-1, keepdims=True), 1.0)
    d_agg = jnp.dot(g, wn_ref[...].T, preferred_element_type=jnp.float32) / cnt
    d_neigh_ref[...] = (d_agg[:, None, :] * mask[..., None]).astype(
        d_neigh_ref.dtype
    )


def _block(n):
    """Seed-axis block size: BN when divisible, else the whole axis."""
    return BN if n % BN == 0 else n


@functools.partial(jax.custom_vjp, nondiff_argnums=())
def sage_agg(h_self, h_neigh, mask, w_self, w_neigh, b):
    """Fused SAGE-mean layer. See module docstring; semantics = ref.sage_agg_ref."""
    out, _ = _sage_agg_fwd(h_self, h_neigh, mask, w_self, w_neigh, b)
    return out


def _sage_agg_fwd(h_self, h_neigh, mask, w_self, w_neigh, b):
    n, d = h_self.shape
    f = h_neigh.shape[1]
    h = w_self.shape[1]
    bn = _block(n)
    out = pl.pallas_call(
        _fwd_kernel,
        grid=(n // bn,),
        in_specs=[
            pl.BlockSpec((bn, d), lambda i: (i, 0)),
            pl.BlockSpec((bn, f, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((bn, f), lambda i: (i, 0)),
            pl.BlockSpec((d, h), lambda i: (0, 0)),
            pl.BlockSpec((d, h), lambda i: (0, 0)),
            pl.BlockSpec((h,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bn, h), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, h), h_self.dtype),
        interpret=True,
    )(h_self, h_neigh, mask, w_self, w_neigh, b)
    return out, (h_self, h_neigh, mask, w_self, w_neigh)


def _sage_agg_bwd(res, g):
    h_self, h_neigh, mask, w_self, w_neigh = res
    n, d = h_self.shape
    f = h_neigh.shape[1]
    h = w_self.shape[1]
    bn = _block(n)
    d_self, d_neigh = pl.pallas_call(
        _bwd_kernel,
        grid=(n // bn,),
        in_specs=[
            pl.BlockSpec((bn, h), lambda i: (i, 0)),
            pl.BlockSpec((bn, f), lambda i: (i, 0)),
            pl.BlockSpec((d, h), lambda i: (0, 0)),
            pl.BlockSpec((d, h), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bn, d), lambda i: (i, 0)),
            pl.BlockSpec((bn, f, d), lambda i: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, d), h_self.dtype),
            jax.ShapeDtypeStruct((n, f, d), h_neigh.dtype),
        ],
        interpret=True,
    )(g, mask, w_self, w_neigh)
    # Weight-side grads are reductions across grid blocks: leave them to XLA.
    cnt = jnp.maximum(jnp.sum(mask, axis=-1, keepdims=True), 1.0)
    agg = jnp.sum(h_neigh * mask[..., None], axis=1) / cnt
    d_ws = h_self.T @ g
    d_wn = agg.T @ g
    d_b = jnp.sum(g, axis=0)
    return d_self, d_neigh, None, d_ws, d_wn, d_b


sage_agg.defvjp(_sage_agg_fwd, _sage_agg_bwd)
