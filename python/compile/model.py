"""Layer-2: GLISP's GNN models as JAX functions over tree-format subgraphs.

Three message-passing models from the paper's evaluation — GCN [Kipf &
Welling], GraphSAGE-mean [Hamilton et al.] and GAT [Velickovic et al.] — plus
the layerwise-inference slices and the link-prediction decoder used by the
graph inference engine. Everything here is lowered ONCE by aot.py to HLO
text; at runtime the Rust coordinator feeds these functions fixed-shape
tensors produced by the Gather-Apply sampling service.

Tree format (DESIGN.md §6): a K-hop sample with seed batch B and fanouts
[f1..fK] is K+1 per-level feature arrays xs[k] of shape [n_k, D] with
n_0 = B, n_k = n_{k-1}·f_k, plus per-level masks (mask[k] in {0,1}^{n_k},
k ≥ 1). Neighbors of level-k node i are rows [i·f_{k+1}, (i+1)·f_{k+1}) of
level k+1. Padding subtrees carry mask 0 and cannot influence real nodes.

The GraphSAGE path runs through the Pallas kernel `sage_agg` (with its
custom VJP) in both training and inference; GCN/GAT train on the jnp
reference math, and the GAT eval path exercises the `gat_attn` kernel.
pytest pins kernel == reference so the two paths are interchangeable.
"""

import math
from dataclasses import dataclass, field
from typing import List, Tuple

import jax
import jax.numpy as jnp

from compile.kernels import ref
from compile.kernels.sage_agg import sage_agg
from compile.kernels.gat_attn import gat_attn

F32 = jnp.float32
I32 = jnp.int32


@dataclass(frozen=True)
class ModelConfig:
    """Static configuration baked into each AOT artifact."""

    kind: str = "sage"  # "gcn" | "sage" | "gat"
    din: int = 64
    hidden: int = 128
    classes: int = 8
    batch: int = 32
    fanouts: Tuple[int, ...] = (10, 5, 5)
    heads: int = 4  # GAT only; hidden % heads == 0
    lr: float = 0.0  # 0 → lr passed as a runtime input

    @property
    def layers(self) -> int:
        return len(self.fanouts)

    def level_sizes(self) -> List[int]:
        sizes = [self.batch]
        for f in self.fanouts:
            sizes.append(sizes[-1] * f)
        return sizes


# ---------------------------------------------------------------------------
# Parameter construction. Params are a flat list of arrays with a parallel
# spec list [(name, shape)], so the Rust side can address them by manifest
# order without any pytree machinery.
# ---------------------------------------------------------------------------


def _glorot(key, shape):
    fan_in, fan_out = shape[0], shape[-1]
    limit = math.sqrt(6.0 / (fan_in + fan_out))
    return jax.random.uniform(key, shape, F32, -limit, limit)


def param_specs(cfg: ModelConfig) -> List[Tuple[str, Tuple[int, ...]]]:
    """Flat (name, shape) list for cfg's model, in artifact input order."""
    specs = []
    d_in = cfg.din
    for j in range(cfg.layers):
        d_out = cfg.hidden
        p = f"l{j}_"
        if cfg.kind == "sage":
            specs += [
                (p + "w_self", (d_in, d_out)),
                (p + "w_neigh", (d_in, d_out)),
                (p + "b", (d_out,)),
            ]
        elif cfg.kind == "gcn":
            specs += [(p + "w", (d_in, d_out)), (p + "b", (d_out,))]
        elif cfg.kind == "gat":
            hd = d_out // cfg.heads
            specs += [
                (p + "w", (d_in, d_out)),
                (p + "a_self", (cfg.heads, hd)),
                (p + "a_neigh", (cfg.heads, hd)),
                (p + "b", (d_out,)),
            ]
        else:
            raise ValueError(cfg.kind)
        d_in = d_out
    specs += [
        ("head_w", (cfg.hidden, cfg.classes)),
        ("head_b", (cfg.classes,)),
    ]
    return specs


def init_params(cfg: ModelConfig, seed: int = 0) -> List[jnp.ndarray]:
    key = jax.random.PRNGKey(seed)
    params = []
    for name, shape in param_specs(cfg):
        key, sub = jax.random.split(key)
        if name.endswith("b"):
            params.append(jnp.zeros(shape, F32))
        else:
            params.append(_glorot(sub, shape))
    return params


def _layer_param_count(cfg: ModelConfig) -> int:
    return {"sage": 3, "gcn": 2, "gat": 4}[cfg.kind]


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------


def _gat_layer(h_self, h_neigh, mask, w, a_s, a_n, b, heads, use_kernel):
    """Multi-head GAT layer over a fanout block; heads are H-dim chunks."""
    n, f = mask.shape
    hw_self = h_self @ w  # [n, H]
    hw_neigh = h_neigh.reshape(n * f, -1) @ w  # [n·f, H]
    hd = hw_self.shape[1] // heads
    outs = []
    for hidx in range(heads):
        sl = slice(hidx * hd, (hidx + 1) * hd)
        hs = hw_self[:, sl]
        hn = hw_neigh[:, sl].reshape(n, f, hd)
        if use_kernel:
            outs.append(gat_attn(hs, hn, mask, a_s[hidx], a_n[hidx]))
        else:
            outs.append(ref.gat_attn_ref(hs, hn, mask, a_s[hidx], a_n[hidx]))
    return jnp.concatenate(outs, axis=1) + b


def forward(cfg: ModelConfig, params, xs, masks, use_kernel: bool = True):
    """Seed logits [B, C] for a K-layer model over the tree-format sample.

    xs:    K+1 level arrays, xs[k] of shape [n_k, din]
    masks: K level masks,   masks[k] of shape [n_{k+1}] (neighbor validity)
    """
    npl = _layer_param_count(cfg)
    h = list(xs)
    for j in range(cfg.layers):
        lp = params[j * npl : (j + 1) * npl]
        depth = cfg.layers - j  # levels 0..depth-1 get new reps
        new_h = []
        for lvl in range(depth):
            n = h[lvl].shape[0]
            f = cfg.fanouts[lvl]
            neigh = h[lvl + 1].reshape(n, f, h[lvl + 1].shape[-1])
            m = masks[lvl].reshape(n, f)
            if cfg.kind == "sage":
                z = sage_agg(h[lvl], neigh, m, *lp)
            elif cfg.kind == "gcn":
                z = ref.gcn_agg_ref(h[lvl], neigh, m, *lp)
            else:
                z = _gat_layer(h[lvl], neigh, m, *lp, cfg.heads, use_kernel)
            if j < cfg.layers - 1:
                z = jax.nn.relu(z)
            new_h.append(z)
        h = new_h
    return h[0] @ params[-2] + params[-1]


def embed_forward(cfg: ModelConfig, params, xs, masks):
    """Like forward() but returns the final hidden embedding [B, hidden]
    (no classification head) — the samplewise-inference baseline."""
    head_less = params  # head params are simply unused
    npl = _layer_param_count(cfg)
    h = list(xs)
    for j in range(cfg.layers):
        lp = head_less[j * npl : (j + 1) * npl]
        depth = cfg.layers - j
        new_h = []
        for lvl in range(depth):
            n = h[lvl].shape[0]
            f = cfg.fanouts[lvl]
            neigh = h[lvl + 1].reshape(n, f, h[lvl + 1].shape[-1])
            m = masks[lvl].reshape(n, f)
            z = sage_agg(h[lvl], neigh, m, *lp)
            if j < cfg.layers - 1:
                z = jax.nn.relu(z)
            new_h.append(z)
        h = new_h
    return h[0]


def sage_layer_slice(h_self, h_neigh, mask, w_self, w_neigh, b, relu: bool):
    """One GNN slice of the layerwise inference engine (paper §III-D):
    consumes layer k-1 embeddings of a vertex block + its one-hop sampled
    neighbors, produces layer k embeddings for the block."""
    z = sage_agg(h_self, h_neigh, mask, w_self, w_neigh, b)
    return jax.nn.relu(z) if relu else z


def link_decode(emb_u, emb_v, w1, b1, w2, b2):
    """Edge-score decoder: sigmoid(relu([u‖v]·W1 + b1)·w2 + b2) → [B]."""
    x = jnp.concatenate([emb_u, emb_v], axis=1)
    hdn = jax.nn.relu(x @ w1 + b1)
    return jax.nn.sigmoid(hdn @ w2 + b2)[:, 0]


# ---------------------------------------------------------------------------
# Training step
# ---------------------------------------------------------------------------


def cross_entropy(logits, labels):
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))


def train_step(cfg: ModelConfig, params, xs, masks, labels, lr):
    """One SGD step; returns (loss, new_params). GCN/GAT differentiate the
    jnp reference math; SAGE differentiates through the Pallas custom VJP."""

    def loss_fn(ps):
        logits = forward(cfg, ps, xs, masks, use_kernel=False)
        return cross_entropy(logits, labels)

    loss, grads = jax.value_and_grad(loss_fn)(params)
    new_params = [p - lr * g for p, g in zip(params, grads)]
    return loss, new_params


def grad_step(cfg: ModelConfig, params, xs, masks, labels):
    """Loss + raw gradients (for the multi-trainer synchronous data-parallel
    path, where the Rust coordinator averages gradients across trainers)."""

    def loss_fn(ps):
        logits = forward(cfg, ps, xs, masks, use_kernel=False)
        return cross_entropy(logits, labels)

    return jax.value_and_grad(loss_fn)(params)
