"""AOT entry point: lower every L2 function to HLO *text* + a JSON manifest.

Run once by `make artifacts`; Rust never imports Python. For each artifact we
emit `artifacts/<name>.hlo.txt` plus an entry in `artifacts/manifest.json`
recording the exact input order/shapes/dtypes and output arity, which is the
only contract the Rust runtime needs (rust/src/runtime/manifest.rs).

HLO text — NOT `.serialize()` — is the interchange format: jax ≥ 0.5 emits
HloModuleProto with 64-bit instruction ids that xla_extension 0.5.1 rejects
(`proto.id() <= INT_MAX`); the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model as M

F32 = jnp.float32
I32 = jnp.int32

# The two model configurations baked into the artifact set.
TRAIN_CFG = dict(din=64, hidden=128, classes=8, batch=32, fanouts=(10, 5, 5))
# Layerwise-inference encoder: 2-layer SAGE, embedding dim == hidden.
ENC = dict(din=64, hidden=128, fanout=10, chunk=256)
EMBED_BATCH = 64  # samplewise baseline seed batch
DECODE_BATCH = 256


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(name, shape, dtype="f32"):
    return {"name": name, "shape": list(shape), "dtype": dtype}


def _sds(spec):
    dt = {"f32": F32, "i32": I32}[spec["dtype"]]
    return jax.ShapeDtypeStruct(tuple(spec["shape"]), dt)


class Builder:
    def __init__(self, out_dir):
        self.out_dir = out_dir
        self.entries = []

    def add(self, name, fn, inputs, meta=None):
        """Lower fn(*inputs) and record the artifact."""
        lowered = jax.jit(fn).lower(*[_sds(s) for s in inputs])
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(self.out_dir, fname), "w") as f:
            f.write(text)
        out_avals = jax.eval_shape(fn, *[_sds(s) for s in inputs])
        if not isinstance(out_avals, (tuple, list)):
            out_avals = (out_avals,)
        outputs = [
            {"shape": list(a.shape), "dtype": "f32" if a.dtype == F32 else str(a.dtype)}
            for a in jax.tree_util.tree_leaves(out_avals)
        ]
        self.entries.append(
            {
                "name": name,
                "file": fname,
                "inputs": inputs,
                "outputs": outputs,
                "meta": meta or {},
            }
        )
        print(f"  [aot] {name}: {len(inputs)} inputs -> {len(outputs)} outputs, "
              f"{len(text)//1024} KiB hlo")

    def finish(self):
        path = os.path.join(self.out_dir, "manifest.json")
        with open(path, "w") as f:
            json.dump({"artifacts": self.entries}, f, indent=1)
        print(f"  [aot] wrote {path} ({len(self.entries)} artifacts)")


def level_input_specs(cfg: M.ModelConfig):
    """xs + masks specs for a tree-format sample of cfg's geometry."""
    sizes = cfg.level_sizes()
    xs = [_spec(f"x{k}", (n, cfg.din)) for k, n in enumerate(sizes)]
    masks = [_spec(f"mask{k+1}", (sizes[k + 1],)) for k in range(cfg.layers)]
    return xs, masks


def add_train_artifacts(b: Builder, kind: str):
    cfg = M.ModelConfig(kind=kind, **TRAIN_CFG)
    pspecs = [_spec(n, s) for n, s in M.param_specs(cfg)]
    xs, masks = level_input_specs(cfg)
    labels = _spec("labels", (cfg.batch,), "i32")
    lr = _spec("lr", (1,))
    np_, nx, nm = len(pspecs), len(xs), len(masks)
    meta = {
        "kind": kind, "din": cfg.din, "hidden": cfg.hidden,
        "classes": cfg.classes, "batch": cfg.batch,
        "fanouts": list(cfg.fanouts), "n_params": np_,
    }

    def tstep(*args):
        ps = list(args[:np_])
        xs_ = list(args[np_ : np_ + nx])
        ms_ = list(args[np_ + nx : np_ + nx + nm])
        lab = args[np_ + nx + nm]
        lr_ = args[np_ + nx + nm + 1][0]
        loss, new_ps = M.train_step(cfg, ps, xs_, ms_, lab, lr_)
        return (jnp.reshape(loss, (1,)), *new_ps)

    b.add(f"{kind}_train", tstep, pspecs + xs + masks + [labels, lr], meta)

    def eval_fn(*args):
        ps = list(args[:np_])
        xs_ = list(args[np_ : np_ + nx])
        ms_ = list(args[np_ + nx :])
        return M.forward(cfg, ps, xs_, ms_, use_kernel=True)

    b.add(f"{kind}_eval", eval_fn, pspecs + xs + masks, meta)

    if kind == "sage":
        # Raw-gradient artifact for synchronous multi-trainer data parallelism
        # (Fig. 12): each trainer computes grads, the coordinator averages.
        def gstep(*args):
            ps = list(args[:np_])
            xs_ = list(args[np_ : np_ + nx])
            ms_ = list(args[np_ + nx : np_ + nx + nm])
            lab = args[np_ + nx + nm]
            loss, grads = M.grad_step(cfg, ps, xs_, ms_, lab)
            return (jnp.reshape(loss, (1,)), *grads)

        b.add("sage_grad", gstep, pspecs + xs + masks + [labels], meta)


def add_inference_artifacts(b: Builder):
    d, h, f, n = ENC["din"], ENC["hidden"], ENC["fanout"], ENC["chunk"]
    # Layer slices of the 2-layer SAGE encoder (layerwise inference engine).
    for j, (di, do, relu) in enumerate([(d, h, True), (h, h, False)]):
        inputs = [
            _spec("h_self", (n, di)),
            _spec("h_neigh", (n, f, di)),
            _spec("mask", (n, f)),
            _spec("w_self", (di, do)),
            _spec("w_neigh", (di, do)),
            _spec("b", (do,)),
        ]
        b.add(
            f"sage_infer_layer{j}",
            lambda hs, hn, m, ws, wn, bb, relu=relu: M.sage_layer_slice(
                hs, hn, m, ws, wn, bb, relu
            ),
            inputs,
            {"layer": j, "relu": relu, "chunk": n, "fanout": f,
             "din": di, "dout": do},
        )

    # Samplewise-inference baseline: full 2-hop tree forward to embeddings.
    ecfg = M.ModelConfig(kind="sage", din=d, hidden=h, classes=1,
                         batch=EMBED_BATCH, fanouts=(f, f))
    enc_pspecs = [_spec(nm, s) for nm, s in M.param_specs(ecfg)[:-2]]
    xs, masks = level_input_specs(ecfg)
    np_, nx = len(enc_pspecs), len(xs)

    def embed(*args):
        ps = list(args[:np_]) + [jnp.zeros((h, 1), F32), jnp.zeros((1,), F32)]
        xs_ = list(args[np_ : np_ + nx])
        ms_ = list(args[np_ + nx :])
        return M.embed_forward(ecfg, ps, xs_, ms_)

    b.add("sage_embed", embed, enc_pspecs + xs + masks,
          {"batch": EMBED_BATCH, "fanouts": [f, f], "din": d, "hidden": h})

    # Link-prediction decoder over cached endpoint embeddings.
    inputs = [
        _spec("emb_u", (DECODE_BATCH, h)),
        _spec("emb_v", (DECODE_BATCH, h)),
        _spec("w1", (2 * h, h)),
        _spec("b1", (h,)),
        _spec("w2", (h, 1)),
        _spec("b2", (1,)),
    ]
    b.add("link_decode", M.link_decode, inputs,
          {"batch": DECODE_BATCH, "hidden": h})


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts", help="output dir")
    ap.add_argument("--only", default=None, help="comma list of artifact prefixes")
    args = ap.parse_args()
    out_dir = args.out
    os.makedirs(out_dir, exist_ok=True)
    b = Builder(out_dir)
    only = args.only.split(",") if args.only else None

    def want(prefix):
        return only is None or any(prefix.startswith(o) for o in only)

    for kind in ("sage", "gcn", "gat"):
        if want(kind):
            add_train_artifacts(b, kind)
    if want("infer") or only is None:
        add_inference_artifacts(b)
    b.finish()


if __name__ == "__main__":
    main()
