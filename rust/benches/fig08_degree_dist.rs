//! Fig. 8: degree distributions of the dataset suite in log scale.
//! Paper's claim: all datasets except OGBN-Products follow a power law.

use glisp::graph::metrics::degree_distribution;
use glisp::harness::workloads::{bench_datasets, load};
use glisp::harness::{f2, Table};

fn main() {
    println!("== Fig. 8 — degree distribution of datasets (log-binned) ==");
    for spec in bench_datasets() {
        let g = load(&spec, 1);
        let d = degree_distribution(&g);
        let mut t = Table::new(
            &format!("{} (n={}, m={})", spec.name, g.n, g.m()),
            &["degree >=", "vertices"],
        );
        for (deg, cnt) in &d.hist {
            t.row(&[format!("{deg}"), format!("{cnt}")]);
        }
        t.print();
        println!(
            "avg degree {:.1}, max degree {}, log-log slope {} => power law: {}",
            d.avg_degree,
            d.max_degree,
            f2(d.slope),
            d.slope < -0.8 && d.max_degree as f64 > 10.0 * d.avg_degree
        );
    }
    println!("\npaper: every dataset except OGBN-Products is power-law; the ER");
    println!("control (products-s) must show a bounded tail, the rest heavy tails.");
}
