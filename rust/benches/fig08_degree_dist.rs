//! Fig. 8: degree distributions of the dataset suite in log scale.
//! Paper's claim: all datasets except OGBN-Products follow a power law.

use glisp::graph::metrics::degree_distribution;
use glisp::harness::workloads::{bench_datasets, load};
use glisp::harness::{BenchRecorder, BenchTable, Cell};

fn main() -> anyhow::Result<()> {
    println!("== Fig. 8 — degree distribution of datasets (log-binned) ==");
    let mut rec = BenchRecorder::new("fig08_degree_dist");
    let mut summary = BenchTable::new(
        "summary",
        "Degree summary per dataset",
        &["dataset", "avg deg", "max deg", "slope", "power law"],
    );
    for spec in bench_datasets() {
        let g = load(&spec, 1);
        let d = degree_distribution(&g);
        let mut t = BenchTable::new(
            spec.name,
            &format!("{} (n={}, m={})", spec.name, g.n, g.m()),
            &["degree >=", "vertices"],
        );
        t.param_usize("n", g.n).param_usize("m", g.m());
        for &(deg, cnt) in &d.hist {
            t.row(vec![Cell::n(deg), Cell::n(cnt)]);
        }
        rec.table(&t);
        let power_law = d.slope < -0.8 && d.max_degree as f64 > 10.0 * d.avg_degree;
        summary.row(vec![
            Cell::str(spec.name),
            Cell::f2(d.avg_degree),
            Cell::n(d.max_degree as u64),
            Cell::f2(d.slope),
            Cell::str(if power_law { "yes" } else { "no" }),
        ]);
    }
    rec.table(&summary);
    println!("\npaper: every dataset except OGBN-Products is power-law; the ER");
    println!("control (products-s) must show a bounded tail, the rest heavy tails.");
    rec.finish()?;
    Ok(())
}
