//! DESIGN.md §14 — the gather→tensor hot path, measured at both ends:
//!
//! * **gather**: ns/edge for uniform and weighted one-hop gathers against a
//!   *cold* server (a fresh `PartitionServer` — and thus a fresh
//!   `GatherScratch` arena — per request) vs a *warm* server whose arena is
//!   reused across requests, the way pool workers actually run. Responses
//!   are asserted bit-identical (the arena is computational scratch only).
//! * **assembly**: batches/s for fresh `assemble_tensors` vs the pooled
//!   variant that moves mask vectors and recycles feature buffers through
//!   a `TensorPool`, with the recorder asserting the pool stops allocating
//!   after warmup (`pooled_assembly_allocs_zero`) — the property the
//!   pipelined trainer relies on for allocation-free steady state.

use glisp::coordinator::pipeline::{assemble_tensors, assemble_tensors_pooled};
use glisp::coordinator::FeatureStore;
use glisp::graph::csr::VId;
use glisp::graph::generator;
use glisp::graph::hetero::{build_partitions, PartitionGraph};
use glisp::harness::{BenchRecorder, BenchTable, Cell};
use glisp::partition::{AdaDNE, Partitioner};
use glisp::runtime::TensorPool;
use glisp::sampling::server::{PartitionServer, ServerStats};
use glisp::sampling::{GatherRequest, SampleConfig};
use glisp::util::rng::Rng;
use glisp::util::timer::Timer;
use std::sync::Arc;

const FANOUT: usize = 10;
const GATHER_REPS: usize = 3;

/// Fold a response into a byte stream for bit-equality digests.
fn fold_resp(bytes: &mut Vec<u8>, r: &glisp::sampling::GatherResponse) {
    for x in &r.offsets {
        bytes.extend_from_slice(&x.to_le_bytes());
    }
    for x in &r.neighbors {
        bytes.extend_from_slice(&x.to_le_bytes());
    }
    for s in &r.scores {
        bytes.extend_from_slice(&s.to_le_bytes());
    }
}

/// Serve every request; `warm` reuses one server (arena and all), cold
/// builds a fresh server per request. Returns (best wall secs over
/// GATHER_REPS, edges scanned per pass, digest of all responses).
fn run_gathers(
    pg: &Arc<PartitionGraph>,
    reqs: &[GatherRequest],
    warm: bool,
) -> (f64, u64, u64) {
    let mut best = f64::INFINITY;
    let mut edges = 0u64;
    let mut digest = 0u64;
    for _ in 0..GATHER_REPS {
        // Fresh stats per rep: after the pass, edges_scanned is exactly
        // one pass's edge work.
        let stats = Arc::new(ServerStats::default());
        let mut srv = PartitionServer::new(pg.clone(), stats.clone(), 17);
        let mut bytes = Vec::new();
        let timer = Timer::start();
        for req in reqs {
            if !warm {
                srv = PartitionServer::new(pg.clone(), stats.clone(), 17);
            }
            let resp = srv.gather(req);
            fold_resp(&mut bytes, &resp);
        }
        best = best.min(timer.secs());
        edges = stats
            .edges_scanned
            .load(std::sync::atomic::Ordering::Relaxed);
        digest = glisp::util::digest::fnv1a(&bytes);
    }
    (best, edges, digest)
}

fn main() -> anyhow::Result<()> {
    println!("== bench_hotpath — gather arena + pooled assembly (DESIGN.md §14) ==");
    let n: usize = std::env::var("GLISP_BENCH_N")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(20_000);
    let batches = 24usize;
    let seeds_per_batch = 64usize;
    let mut rec = BenchRecorder::new("bench_hotpath");
    rec.config_usize("n", n)
        .config_usize("batches", batches)
        .config_usize("seeds_per_batch", seeds_per_batch)
        .config_usize("fanout", FANOUT)
        .config_usize("gather_reps", GATHER_REPS);

    // -- gather: cold vs warm scratch arena ------------------------------
    let mut rng = Rng::new(41);
    let g = generator::heterogeneous_graph(n, n * 8, 2, 3, 2.2, &mut rng);
    let ea = AdaDNE::default().partition(&g, 1, 0);
    let pg = Arc::new(build_partitions(&g, &ea.part_of_edge, 1)?.remove(0));
    let mut t = BenchTable::new(
        "gather",
        &format!("one-hop gather, n={n}, fanout {FANOUT}, {batches}x{seeds_per_batch} seeds (best of {GATHER_REPS})"),
        &["op", "cold ns/edge", "warm ns/edge", "warm vs cold"],
    );
    let mut warm_ok = true;
    let mut bits_ok = true;
    for weighted in [false, true] {
        let cfg = SampleConfig {
            weighted,
            ..Default::default()
        };
        // Duplicate-heavy, hub-biased seed lists — the power-law shape the
        // fast paths target.
        let mut reqs = Vec::new();
        for b in 0..batches {
            let seeds: Vec<VId> = (0..seeds_per_batch)
                .map(|_| pg.global(rng.usize(pg.nv()) as u32))
                .collect();
            reqs.push(GatherRequest {
                seeds,
                fanout: FANOUT,
                salt: 0xB0B0 + b as u64,
                cfg: cfg.clone(),
                seed_offset: 0,
                token: b as u64,
            });
        }
        let (cold_s, edges, cold_digest) = run_gathers(&pg, &reqs, false);
        let (warm_s, _, warm_digest) = run_gathers(&pg, &reqs, true);
        bits_ok &= cold_digest == warm_digest;
        let cold_ns = cold_s * 1e9 / edges.max(1) as f64;
        let warm_ns = warm_s * 1e9 / edges.max(1) as f64;
        // 10% guard band: the contract is "reuse never costs", not an
        // exact wall-clock ratio on a noisy runner.
        warm_ok &= warm_ns <= cold_ns * 1.10;
        t.row(vec![
            Cell::str(if weighted { "weighted (A-ES)" } else { "uniform (Alg. D)" }),
            Cell::f2(cold_ns),
            Cell::f2(warm_ns),
            Cell::x(cold_ns / warm_ns.max(1e-12)),
        ]);
    }
    rec.check(
        "arena_bits_identical",
        bits_ok,
        "warm (arena-reused) gather responses bit-equal cold fresh-server responses",
    );
    rec.check(
        "warm_not_slower_than_cold",
        warm_ok,
        "warm-arena ns/edge within 1.10x of cold for uniform and weighted gathers \
         (best-of-reps wall clock)",
    );
    rec.table(&t);

    // -- assembly: fresh vs pooled tensors -------------------------------
    let din = 64usize;
    let fs = FeatureStore::unlabeled(din);
    // A realistic 3-level tree shape: 64 seeds, fanouts [10, 5].
    let mut levels: Vec<Vec<VId>> = Vec::new();
    let mut sizes = vec![seeds_per_batch];
    for f in [FANOUT, 5] {
        sizes.push(sizes.last().unwrap() * f);
    }
    for &sz in &sizes {
        levels.push((0..sz).map(|_| rng.usize(n) as VId).collect());
    }
    let masks: Vec<Vec<f32>> = sizes[1..]
        .iter()
        .map(|&sz| (0..sz).map(|i| (i % 7 != 0) as u32 as f32).collect())
        .collect();
    let iters = 200usize;
    let pool = TensorPool::new(16);
    let mut t = BenchTable::new(
        "assembly",
        &format!("batch tensor assembly, levels {sizes:?}, din {din}, {iters} iters"),
        &["path", "batches/s", "vs fresh"],
    );
    // Fresh path: allocate + clone every iteration (the sync path).
    let timer = Timer::start();
    for _ in 0..iters {
        let m = masks.clone();
        let (f, ms) = assemble_tensors(&levels, &m, &fs);
        std::hint::black_box((&f, &ms));
    }
    let fresh_rate = iters as f64 / timer.secs();
    // Pooled path: masks moved, feature buffers recycled trainer-style.
    let mut warm_misses = 0u64;
    let mut misses_flat = true;
    let timer = Timer::start();
    for i in 0..iters {
        let mut m = masks.clone();
        let (f, ms) = assemble_tensors_pooled(&levels, &mut m, &fs, &pool);
        for tsr in f.into_iter().chain(ms) {
            pool.put(tsr.into_f32());
        }
        match i {
            0 => warm_misses = pool.misses(),
            _ => misses_flat &= pool.misses() == warm_misses,
        }
    }
    let pooled_rate = iters as f64 / timer.secs();
    rec.check(
        "pooled_assembly_allocs_zero",
        misses_flat,
        "TensorPool misses unchanged after the first assembly — steady state \
         draws every buffer from the pool",
    );
    t.row(vec![Cell::str("fresh"), Cell::f2(fresh_rate), Cell::x(1.0)]);
    t.row(vec![
        Cell::str("pooled"),
        Cell::f2(pooled_rate),
        Cell::x(pooled_rate / fresh_rate.max(1e-12)),
    ]);
    rec.table(&t);

    println!("\nThe gather arena reuses the TopK heap and score/pick buffers across");
    println!("requests (bit-transparent: all scratch is cleared or overwritten per");
    println!("seed); block A-ES scoring pre-draws uniforms and vectorizes the powf");
    println!("pass when all weights clear W_MIN. Pooled assembly moves mask vectors");
    println!("and recycles feature buffers through the trainer's return pool, so");
    println!("steady-state training allocates no per-batch tensors (asserted).");
    rec.finish()?;
    Ok(())
}
