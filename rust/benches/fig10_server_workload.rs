//! Fig. 10: normalized per-server workload under balanced seeds, DistDGL
//! baseline vs GLISP, plus the GLISP-P0 worst case (all seeds from
//! partition 0). Paper's claim: baseline skewed despite balanced seeds;
//! GLISP flat; GLISP-P0 degrades slightly but stays far better.

use glisp::coordinator::metrics::normalized_workload;
use glisp::harness::workloads::{bench_datasets, load};
use glisp::harness::{bar_chart, f2, Table};
use glisp::partition::{edge_cut_to_assignment, AdaDNE, EdgeCutLDG, Partitioner};
use glisp::sampling::{
    balanced_seeds, sample_tree, SampleConfig, SamplingService, ServiceConfig,
};
use glisp::util::rng::Rng;

const FANOUTS: [usize; 3] = [15, 10, 5];

fn main() {
    println!("== Fig. 10 — normalized server workload (balanced seeds) ==");
    let parts = 4;
    let rounds = 20;
    for spec in bench_datasets().into_iter().skip(1) {
        // skip the ER control: the paper skips OGBN-Products here too
        let g = load(&spec, 1);
        let mut t = Table::new(
            &format!("{} × {parts} servers (W_i / min W)", spec.name),
            &["stack", "s0", "s1", "s2", "s3", "max/min"],
        );

        // DistDGL-like.
        let va = EdgeCutLDG::default().partition_vertices(&g, parts, 1);
        let owner = std::sync::Arc::new(va.part_of_vertex.clone());
        let ea = edge_cut_to_assignment(&g, &va);
        let svc = SamplingService::launch(&g, &ea, 1).unwrap();
        let mut client = svc.owner_client(owner, 2);
        let mut rng = Rng::new(5);
        for _ in 0..rounds {
            let seeds = balanced_seeds(&svc, 16, &mut rng);
            sample_tree(&mut client, &seeds, &FANOUTS, &SampleConfig::default()).unwrap();
        }
        let w = normalized_workload(&svc.workload());
        t.row(&[
            "DistDGL-like".into(),
            f2(w[0]), f2(w[1]), f2(w[2]), f2(w[3]),
            f2(w.iter().cloned().fold(f64::MIN, f64::max)),
        ]);
        svc.shutdown();

        // The exact balanced-seed traffic both GLISP variants replay
        // (same client seed + seed RNG, so workloads must be byte-equal).
        let run_glisp_traffic = |svc: &SamplingService| {
            let mut client = svc.client(2);
            let mut rng = Rng::new(5);
            for _ in 0..rounds {
                let seeds = balanced_seeds(svc, 16, &mut rng);
                sample_tree(&mut client, &seeds, &FANOUTS, &SampleConfig::default()).unwrap();
            }
        };

        // GLISP, balanced seeds.
        let ea = AdaDNE::default().partition(&g, parts, 1);
        let svc = SamplingService::launch(&g, &ea, 1).unwrap();
        run_glisp_traffic(&svc);
        let glisp_raw = svc.workload();
        let w = normalized_workload(&glisp_raw);
        t.row(&[
            "GLISP".into(),
            f2(w[0]), f2(w[1]), f2(w[2]), f2(w[3]),
            f2(w.iter().cloned().fold(f64::MIN, f64::max)),
        ]);

        // GLISP with a 4-worker pool per partition + sharded gathers: the
        // per-seed RNG contract (DESIGN.md §9) means the *workload* row is
        // byte-identical to the 1-worker run above — asserted, not assumed
        // — while the shards spread over the pool (attribution printed).
        let pool = SamplingService::launch_cfg(&g, &ea, 1, ServiceConfig::new(4, 16)).unwrap();
        run_glisp_traffic(&pool);
        assert_eq!(
            pool.workload(),
            glisp_raw,
            "pooled workload must be bit-identical to the 1-worker run"
        );
        let wp = normalized_workload(&pool.workload());
        t.row(&[
            "GLISP 4w-pool".into(),
            f2(wp[0]), f2(wp[1]), f2(wp[2]), f2(wp[3]),
            f2(wp.iter().cloned().fold(f64::MIN, f64::max)),
        ]);
        let attribution = pool.worker_requests();
        let busy = pool.worker_busy_secs();
        pool.shutdown();

        // GLISP-P0 worst case: all seeds from partition 0.
        svc.reset_stats();
        let mut client = svc.client(3);
        let mut rng = Rng::new(6);
        for _ in 0..rounds {
            let p0 = &svc.partitions[0];
            let seeds: Vec<u32> = (0..64)
                .map(|_| p0.global(rng.usize(p0.nv()) as u32))
                .collect();
            sample_tree(&mut client, &seeds, &FANOUTS, &SampleConfig::default()).unwrap();
        }
        let w = normalized_workload(&svc.workload());
        t.row(&[
            "GLISP-P0".into(),
            f2(w[0]), f2(w[1]), f2(w[2]), f2(w[3]),
            f2(w.iter().cloned().fold(f64::MIN, f64::max)),
        ]);
        svc.shutdown();
        t.print();

        println!("per-worker gather shards served (GLISP 4w-pool): {attribution:?}");
        let busy_ms: Vec<Vec<f64>> = busy
            .iter()
            .map(|p| p.iter().map(|s| (s * 1e5).round() / 100.0).collect())
            .collect();
        println!("per-worker busy ms (GLISP 4w-pool):              {busy_ms:?}");
        let labels: Vec<String> = (0..parts).map(|i| format!("s{i}")).collect();
        print!("{}", bar_chart(&format!("{} GLISP workload", spec.name), &labels, &w));
    }
    println!("\npaper Fig. 10: DistDGL shows severe imbalance even with balanced");
    println!("seeds; GLISP stays near 1.0; GLISP-P0 degrades server 0 slightly but");
    println!("still significantly outperforms DistDGL. The 4w-pool row shows the");
    println!("intra-partition worker pool preserves the workload bit-for-bit while");
    println!("spreading each server's shards over its pool members.");
}
