//! Fig. 10: normalized per-server workload under balanced seeds, DistDGL
//! baseline vs GLISP, plus the GLISP-P0 worst case (all seeds from
//! partition 0). Paper's claim: baseline skewed despite balanced seeds;
//! GLISP flat; GLISP-P0 degrades slightly but stays far better.

use glisp::coordinator::metrics::{fmt_durations, normalized_workload};
use glisp::harness::workloads::{bench_datasets, load};
use glisp::harness::{bar_chart, BenchRecorder, BenchTable, Cell};
use glisp::partition::{edge_cut_to_assignment, AdaDNE, EdgeCutLDG, Partitioner};
use glisp::sampling::{
    balanced_seeds, sample_tree, SampleConfig, SamplingService, ServiceConfig,
};
use glisp::util::rng::Rng;

const FANOUTS: [usize; 3] = [15, 10, 5];

fn workload_row(t: &mut BenchTable, stack: &str, w: &[f64]) {
    t.row(vec![
        Cell::str(stack),
        Cell::f2(w[0]),
        Cell::f2(w[1]),
        Cell::f2(w[2]),
        Cell::f2(w[3]),
        Cell::f2(w.iter().cloned().fold(f64::MIN, f64::max)),
    ]);
}

fn main() -> anyhow::Result<()> {
    println!("== Fig. 10 — normalized server workload (balanced seeds) ==");
    let parts = 4;
    let rounds = 20;
    let mut rec = BenchRecorder::new("fig10_server_workload");
    rec.config_usize("parts", parts)
        .config_usize("rounds", rounds)
        .config_str("fanouts", "15,10,5");
    for spec in bench_datasets().into_iter().skip(1) {
        // skip the ER control: the paper skips OGBN-Products here too
        let g = load(&spec, 1);
        let mut t = BenchTable::new(
            spec.name,
            &format!("{} × {parts} servers (W_i / min W)", spec.name),
            &["stack", "s0", "s1", "s2", "s3", "max/min"],
        );
        t.param_str("dataset", spec.name);

        // DistDGL-like.
        let va = EdgeCutLDG::default().partition_vertices(&g, parts, 1);
        let owner = std::sync::Arc::new(va.part_of_vertex.clone());
        let ea = edge_cut_to_assignment(&g, &va);
        let svc = SamplingService::launch(&g, &ea, 1).unwrap();
        let mut client = svc.owner_client(owner, 2);
        let mut rng = Rng::new(5);
        for _ in 0..rounds {
            let seeds = balanced_seeds(&svc, 16, &mut rng);
            sample_tree(&mut client, &seeds, &FANOUTS, &SampleConfig::default()).unwrap();
        }
        workload_row(&mut t, "DistDGL-like", &normalized_workload(&svc.workload()?));
        svc.shutdown();

        // The exact balanced-seed traffic both GLISP variants replay
        // (same client seed + seed RNG, so workloads must be byte-equal).
        let run_glisp_traffic = |svc: &SamplingService| {
            let mut client = svc.client(2);
            let mut rng = Rng::new(5);
            for _ in 0..rounds {
                let seeds = balanced_seeds(svc, 16, &mut rng);
                sample_tree(&mut client, &seeds, &FANOUTS, &SampleConfig::default()).unwrap();
            }
        };

        // GLISP, balanced seeds.
        let ea = AdaDNE::default().partition(&g, parts, 1);
        let svc = SamplingService::launch(&g, &ea, 1).unwrap();
        run_glisp_traffic(&svc);
        let glisp_raw = svc.workload()?;
        let w = normalized_workload(&glisp_raw);
        workload_row(&mut t, "GLISP", &w);

        // GLISP with a 4-worker pool per partition + sharded gathers: the
        // per-seed RNG contract (DESIGN.md §9) means the *workload* row is
        // byte-identical to the 1-worker run above — asserted, not assumed
        // — while the shards spread over the pool (attribution recorded).
        let pool = SamplingService::launch_cfg(&g, &ea, 1, ServiceConfig::new(4, 16)).unwrap();
        run_glisp_traffic(&pool);
        rec.check(
            &format!("{}_pooled_workload_bit_identical", spec.name),
            pool.workload()? == glisp_raw,
            "4-worker pooled run must replay the 1-worker per-server workload byte-for-byte \
             (per-seed RNG streams, DESIGN.md §9)",
        );
        workload_row(&mut t, "GLISP 4w-pool", &normalized_workload(&pool.workload()?));
        let attribution = pool.worker_requests()?;
        let busy = pool.worker_busy_secs()?;
        pool.shutdown();

        // GLISP-P0 worst case: all seeds from partition 0.
        svc.reset_stats()?;
        let mut client = svc.client(3);
        let mut rng = Rng::new(6);
        for _ in 0..rounds {
            let p0 = &svc.partitions[0];
            let seeds: Vec<u32> = (0..64)
                .map(|_| p0.global(rng.usize(p0.nv()) as u32))
                .collect();
            sample_tree(&mut client, &seeds, &FANOUTS, &SampleConfig::default()).unwrap();
        }
        workload_row(&mut t, "GLISP-P0", &normalized_workload(&svc.workload()?));
        svc.shutdown();
        rec.table(&t);

        // Pool attribution: which worker served how many gather shards on
        // each server, and for how long it was busy.
        let mut pt = BenchTable::new(
            &format!("{}_pool", spec.name),
            &format!("{} GLISP 4w-pool attribution (shards per worker)", spec.name),
            &["server", "w0", "w1", "w2", "w3", "busy"],
        );
        pt.param_str("dataset", spec.name);
        for (srv, reqs) in attribution.iter().enumerate() {
            let total_busy: f64 = busy[srv].iter().sum();
            pt.row(vec![
                Cell::str(format!("s{srv}")),
                Cell::n(reqs[0]),
                Cell::n(reqs[1]),
                Cell::n(reqs[2]),
                Cell::n(reqs[3]),
                Cell::d(total_busy),
            ]);
            println!("s{srv} per-worker busy: {:?}", fmt_durations(&busy[srv]));
        }
        rec.table(&pt);
        let labels: Vec<String> = (0..parts).map(|i| format!("s{i}")).collect();
        print!("{}", bar_chart(&format!("{} GLISP workload", spec.name), &labels, &w));
    }
    println!("\npaper Fig. 10: DistDGL shows severe imbalance even with balanced");
    println!("seeds; GLISP stays near 1.0; GLISP-P0 degrades server 0 slightly but");
    println!("still significantly outperforms DistDGL. The 4w-pool row shows the");
    println!("intra-partition worker pool preserves the workload bit-for-bit while");
    println!("spreading each server's shards over its pool members.");
    rec.finish()?;
    Ok(())
}
