//! Fig. 15: (a) interior vs boundary vertex fraction under AdaDNE per
//! dataset (paper: interior > 70–75% on power-law graphs, justifying the
//! partition-based static cache); (b) dynamic-cache hit ratio, LRU vs
//! FIFO (paper: LRU is not better — GLISP ships FIFO).

use glisp::graph::hetero::build_partitions;
use glisp::harness::workloads::{bench_datasets, load};
use glisp::harness::{BenchRecorder, BenchTable, Cell};
use glisp::inference::dynamic_cache::{DynamicCache, EvictPolicy};
use glisp::inference::ChunkStore;
use glisp::partition::{AdaDNE, Partitioner};
use glisp::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    println!("== Fig. 15a — interior vertex fraction under AdaDNE ==");
    let mut rec = BenchRecorder::new("fig15_interior_lru");
    let mut t = BenchTable::new(
        "interior",
        "interior vs boundary vertices",
        &["dataset", "parts", "interior %", "boundary %"],
    );
    for spec in bench_datasets() {
        let g = load(&spec, 1);
        let parts = 4;
        let ea = AdaDNE::default().partition(&g, parts, 1);
        let pgs = build_partitions(&g, &ea.part_of_edge, parts)?;
        let interior: usize = pgs.iter().map(|p| p.interior_count()).sum();
        let total: usize = pgs.iter().map(|p| p.nv()).sum();
        let frac = 100.0 * interior as f64 / total as f64;
        t.row(vec![
            Cell::str(spec.name),
            Cell::n(parts as u64),
            Cell::f2(frac),
            Cell::f2(100.0 - frac),
        ]);
    }
    rec.table(&t);
    println!("paper Fig. 15a: interior vertices dominate (>70%), justifying the");
    println!("partition-based static cache design.\n");

    println!("== Fig. 15b — dynamic cache hit ratio, LRU vs FIFO ==");
    // Replay the engine's real access pattern shape: per-vertex accesses to
    // its own chunk + its sampled neighbors' chunks, PDS-ordered.
    let spec = &bench_datasets()[2]; // twitter-like, the skewed one
    let g = load(spec, 1);
    let ea = AdaDNE::default().partition(&g, 4, 1);
    let part_of = glisp::partition::primary_partition(&g, &ea);
    let order = glisp::graph::reorder::reorder(
        &g,
        glisp::graph::reorder::ReorderAlgo::PDS,
        &part_of,
    );
    let rank = glisp::graph::reorder::rank_of(&order);
    let chunk_size = 512usize;
    let dir = std::env::temp_dir().join("glisp_fig15b");
    let _ = std::fs::remove_dir_all(&dir);
    let store = ChunkStore::create(dir, g.n, chunk_size, 1)?;
    let num_chunks = store.num_chunks;
    let mut rng = Rng::new(3);

    let mut t = BenchTable::new(
        "lru_vs_fifo",
        &format!("{} access replay, cache = 10% of chunks", spec.name),
        &["policy", "hits", "misses", "hit ratio"],
    );
    t.param_str("dataset", spec.name).param_usize("chunk_size", chunk_size);
    for policy in [EvictPolicy::Lru, EvictPolicy::Fifo] {
        let mut cache = DynamicCache::new(num_chunks / 10, policy);
        for &v in &order {
            let c = rank[v as usize] as usize / chunk_size;
            if cache.get(c).is_none() {
                cache.insert(c, std::sync::Arc::new(Vec::new()));
            }
            let nbrs = g.out_neighbors(v);
            for _ in 0..nbrs.len().min(10) {
                let nb = nbrs[rng.usize(nbrs.len())];
                let c = rank[nb as usize] as usize / chunk_size;
                if cache.get(c).is_none() {
                    cache.insert(c, std::sync::Arc::new(Vec::new()));
                }
            }
        }
        t.row(vec![
            Cell::str(format!("{policy:?}")),
            Cell::n(cache.hits),
            Cell::n(cache.misses),
            Cell::f3(cache.hit_ratio()),
        ]);
    }
    rec.table(&t);
    println!("paper Fig. 15b: LRU does not beat FIFO, so GLISP ships the simpler");
    println!("FIFO policy for the dynamic cache.");
    rec.finish()?;
    Ok(())
}
