//! Table V: time cost of filling the static cache vs model inference in
//! the layerwise engine. Paper: fill < 10% of model time.
//!
//! The engine accounts both as wall time and as virtual IO cost; both are
//! reported (wall time on CPU-PJRT under-weights the paper's GPU compute,
//! so the virtual-cost column is the transferable one). Since the
//! worker-parallel sweep, the report also breaks fill/model down per
//! worker — the per-partition rows below are the Table V accounting.

use glisp::harness::{f2, f3, infer_stack, Table};
use glisp::inference::{init_decode_params, EngineConfig};

fn main() -> anyhow::Result<()> {
    let art = glisp::test_artifacts_dir();
    println!("== Table V — static cache fill vs model inference ==");
    let n = std::env::var("GLISP_BENCH_N")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(6_000usize);
    let parts = 4usize;
    let work = std::env::temp_dir().join("glisp_table5");
    let mut stack = infer_stack(n, parts, &art, work, EngineConfig::default())?;

    let mut t = Table::new(
        &format!("n={n}, {parts} workers"),
        &["task", "fill chunks", "fill cost", "model secs", "fill secs", "fill/model wall"],
    );
    let (h, rep) = stack.engine.run_vertex_embedding()?;
    t.row(&[
        "vertex embedding".into(),
        format!("{}", rep.fill_chunks),
        format!("{}", rep.fill_cost),
        f2(rep.model_secs),
        f2(rep.fill_secs),
        f2(rep.fill_secs / rep.model_secs.max(1e-9)),
    ]);
    let dec = init_decode_params(&stack.engine.runtime, 9)?;
    let edges: Vec<(u32, u32)> = (0..stack.g.n as u32)
        .filter(|&u| !stack.g.out_neighbors(u).is_empty())
        .take(n / 2)
        .map(|u| (u, stack.g.out_neighbors(u)[0]))
        .collect();
    let (_, rep_l) = stack.engine.run_link_prediction(&h, &edges, &dec)?;
    t.row(&[
        "link prediction".into(),
        format!("{}", rep_l.fill_chunks),
        format!("{}", rep_l.fill_cost),
        f2(rep_l.model_secs),
        f2(rep_l.fill_secs),
        f2(rep_l.fill_secs / rep_l.model_secs.max(1e-9)),
    ]);
    t.print();

    // Per-worker breakdown of the vertex-embedding run (fills sum to the
    // aggregate row above — asserted so the accounting cannot drift).
    let mut pw = Table::new(
        "vertex embedding, per worker (summed over K slices)",
        &["worker", "vertices", "fill chunks", "fill cost", "model secs", "dyn hit ratio"],
    );
    for w in rep.workers.iter().filter(|w| w.vertices_computed > 0) {
        pw.row(&[
            format!("{}", w.worker),
            format!("{}", w.vertices_computed),
            format!("{}", w.fill_chunks),
            format!("{}", w.fill_cost),
            f2(w.model_secs),
            f3(w.dynamic_hit_ratio()),
        ]);
    }
    pw.print();
    let fill_sum: u64 = rep.workers.iter().map(|w| w.fill_chunks).sum();
    assert_eq!(fill_sum, rep.fill_chunks, "per-worker fills must sum to the total");

    println!("\npaper Table V: fill 3251s vs model 59987s (vertex embedding) and");
    println!("5635s vs 61760s (link prediction) — fill < 10% of model time.");
    Ok(())
}
