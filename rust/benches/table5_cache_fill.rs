//! Table V: time cost of filling the static cache vs model inference in
//! the layerwise engine. Paper: fill < 10% of model time.
//!
//! The engine accounts both as wall time and as virtual IO cost; both are
//! reported (wall time on CPU-PJRT under-weights the paper's GPU compute,
//! so the virtual-cost column is the transferable one). Since the
//! worker-parallel sweep, the report also breaks fill/model down per
//! worker — the per-partition rows below are the Table V accounting.

use glisp::harness::{infer_stack, BenchRecorder, BenchTable, Cell};
use glisp::inference::{init_decode_params, EngineConfig};

fn main() -> anyhow::Result<()> {
    let art = glisp::test_artifacts_dir();
    println!("== Table V — static cache fill vs model inference ==");
    let n = std::env::var("GLISP_BENCH_N")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(6_000usize);
    let parts = 4usize;
    let work = std::env::temp_dir().join("glisp_table5");
    let mut stack = infer_stack(n, parts, &art, work, EngineConfig::default())?;

    let mut rec = BenchRecorder::new("table5_cache_fill");
    rec.config_usize("n", n).config_usize("parts", parts);
    let mut t = BenchTable::new(
        "tasks",
        &format!("n={n}, {parts} workers"),
        &["task", "fill chunks", "fill cost", "model", "fill", "fill/model wall"],
    );
    let (h, rep) = stack.engine.run_vertex_embedding()?;
    t.row(vec![
        Cell::str("vertex embedding"),
        Cell::n(rep.fill_chunks),
        Cell::n(rep.fill_cost),
        Cell::d(rep.model_secs),
        Cell::d(rep.fill_secs),
        Cell::f2(rep.fill_secs / rep.model_secs.max(1e-9)),
    ]);
    let dec = init_decode_params(&stack.engine.runtime, 9)?;
    let edges: Vec<(u32, u32)> = (0..stack.g.n as u32)
        .filter(|&u| !stack.g.out_neighbors(u).is_empty())
        .take(n / 2)
        .map(|u| (u, stack.g.out_neighbors(u)[0]))
        .collect();
    let (_, rep_l) = stack.engine.run_link_prediction(&h, &edges, &dec)?;
    t.row(vec![
        Cell::str("link prediction"),
        Cell::n(rep_l.fill_chunks),
        Cell::n(rep_l.fill_cost),
        Cell::d(rep_l.model_secs),
        Cell::d(rep_l.fill_secs),
        Cell::f2(rep_l.fill_secs / rep_l.model_secs.max(1e-9)),
    ]);
    rec.table(&t);

    // Per-worker breakdown of the vertex-embedding run (fills sum to the
    // aggregate row above — asserted so the accounting cannot drift).
    let mut pw = BenchTable::new(
        "per_worker",
        "vertex embedding, per worker (summed over K slices)",
        &["worker", "vertices", "fill chunks", "fill cost", "model", "dyn hit ratio"],
    );
    for w in rep.workers.iter().filter(|w| w.vertices_computed > 0) {
        pw.row(vec![
            Cell::str(format!("{}", w.worker)),
            Cell::n(w.vertices_computed),
            Cell::n(w.fill_chunks),
            Cell::n(w.fill_cost),
            Cell::d(w.model_secs),
            Cell::f3(w.dynamic_hit_ratio()),
        ]);
    }
    rec.table(&pw);
    let fill_sum: u64 = rep.workers.iter().map(|w| w.fill_chunks).sum();
    rec.check(
        "per_worker_fills_sum_to_total",
        fill_sum == rep.fill_chunks,
        "per-worker fill_chunks must sum to the aggregate report's total",
    );

    println!("\npaper Table V: fill 3251s vs model 59987s (vertex embedding) and");
    println!("5635s vs 61760s (link prediction) — fill < 10% of model time.");
    rec.finish()?;
    Ok(())
}
