//! Table V: time cost of filling the static cache vs model inference in
//! the layerwise engine. Paper: fill < 10% of model time.
//!
//! The engine accounts both as wall time and as virtual IO cost; both are
//! reported (wall time on CPU-PJRT under-weights the paper's GPU compute,
//! so the virtual-cost column is the transferable one).

use glisp::coordinator::FeatureStore;
use glisp::graph::generator;
use glisp::harness::{f2, Table};
use glisp::inference::{init_decode_params, init_encoder_params, EngineConfig, LayerwiseEngine};
use glisp::partition::{AdaDNE, Partitioner};
use glisp::runtime::Runtime;
use glisp::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let art = glisp::test_artifacts_dir();
    println!("== Table V — static cache fill vs model inference ==");
    let n = std::env::var("GLISP_BENCH_N")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(6_000usize);
    let mut rng = Rng::new(1);
    let g = generator::chung_lu(n, n * 7, 2.1, &mut rng);
    let ea = AdaDNE::default().partition(&g, 4, 1);

    let mut t = Table::new(
        &format!("n={n}, 4 workers"),
        &["task", "fill chunks", "fill cost", "model secs", "fill secs", "fill/model wall"],
    );
    let work = std::env::temp_dir().join("glisp_table5");
    let _ = std::fs::remove_dir_all(&work);
    let runtime = Runtime::load(&art)?;
    let enc = init_encoder_params(&runtime, 3)?;
    let mut engine = LayerwiseEngine::new(
        &g, &ea, runtime,
        FeatureStore::unlabeled(64),
        enc,
        EngineConfig::default(),
        work,
    )?;
    let (h, rep) = engine.run_vertex_embedding()?;
    t.row(&[
        "vertex embedding".into(),
        format!("{}", rep.fill_chunks),
        format!("{}", rep.fill_cost),
        f2(rep.model_secs),
        f2(rep.fill_secs),
        f2(rep.fill_secs / rep.model_secs.max(1e-9)),
    ]);
    let dec = init_decode_params(&engine.runtime, 9)?;
    let edges: Vec<(u32, u32)> = (0..g.n as u32)
        .filter(|&u| !g.out_neighbors(u).is_empty())
        .take(n / 2)
        .map(|u| (u, g.out_neighbors(u)[0]))
        .collect();
    let (_, rep_l) = engine.run_link_prediction(&h, &edges, &dec)?;
    t.row(&[
        "link prediction".into(),
        format!("{}", rep_l.fill_chunks),
        format!("{}", rep_l.fill_cost),
        f2(rep_l.model_secs),
        f2(rep_l.fill_secs),
        f2(rep_l.fill_secs / rep_l.model_secs.max(1e-9)),
    ]);
    t.print();
    println!("\npaper Table V: fill 3251s vs model 59987s (vertex embedding) and");
    println!("5635s vs 61760s (link prediction) — fill < 10% of model time.");
    Ok(())
}
