//! Pipelined vs synchronous training throughput: the same GraphSAGE train
//! step fed by (a) the strictly sequential sample → assemble → execute
//! loop and (b) the producer pipeline (coordinator::pipeline, DESIGN.md
//! §7) at several producer counts. Overlap hides the sampling round behind
//! the model step, so pipelined steps/s ≥ sync steps/s whenever a spare
//! core exists; ordered mode additionally reproduces the sync loss curve
//! bit-for-bit (asserted here on the first pipelined run).

use glisp::coordinator::PipelineConfig;
use glisp::harness::workloads::train_stack;
use glisp::harness::{f2, Table};
use glisp::util::timer::Timer;

fn main() -> anyhow::Result<()> {
    let art = glisp::test_artifacts_dir();
    println!("== pipeline_throughput — sync vs pipelined train steps/s ==");
    let steps = std::env::var("GLISP_BENCH_STEPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(30usize);
    let n = 8_000;
    let parts = 4;

    let modes: [(&str, Option<PipelineConfig>); 4] = [
        ("sync", None),
        (
            "pipelined x1 ordered",
            Some(PipelineConfig {
                producers: 1,
                queue_depth: 2,
                ordered: true,
            }),
        ),
        (
            "pipelined x2 ordered",
            Some(PipelineConfig {
                producers: 2,
                queue_depth: 2,
                ordered: true,
            }),
        ),
        (
            "pipelined x4 unordered",
            Some(PipelineConfig {
                producers: 4,
                queue_depth: 2,
                ordered: false,
            }),
        ),
    ];

    let mut t = Table::new(
        &format!("n={n}, {parts} servers, sage, {steps} timed steps"),
        &["mode", "steps/s", "seeds/s", "vs sync"],
    );
    let mut base_rate = 0.0f64;
    let mut sync_losses: Vec<f32> = Vec::new();
    for (name, pcfg) in modes {
        let mut s = train_stack(n, parts, "sage", &art)?;
        s.trainer.train(&mut s.batcher, 3)?; // warmup + compile
        let timer = Timer::start();
        let losses = match &pcfg {
            None => s.trainer.train(&mut s.batcher, steps)?,
            Some(p) => s.trainer.train_pipelined(&mut s.batcher, steps, p)?,
        };
        let secs = timer.secs();
        let rate = steps as f64 / secs;
        if base_rate == 0.0 {
            base_rate = rate;
            sync_losses = losses;
        } else if pcfg.as_ref().is_some_and(|p| p.ordered) {
            assert_eq!(
                sync_losses, losses,
                "{name}: ordered pipelined losses must equal sync"
            );
        }
        t.row(&[
            name.into(),
            f2(rate),
            f2(rate * s.trainer.batch as f64),
            format!("{:.2}x", rate / base_rate),
        ]);
        s.service.shutdown();
    }
    t.print();
    println!("\nThe producer pipeline overlaps K-hop sampling + feature assembly with");
    println!("the model step (paper §III-C keeps sampling off the trainer's critical");
    println!("path). Ordered mode is bit-exact vs sync (verified above); unordered");
    println!("trades the exact update order for immunity to producer skew. On a");
    println!("single-core runner the pipeline degrades gracefully to ~sync speed.");
    Ok(())
}
