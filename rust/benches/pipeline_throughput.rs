//! Pipelined vs synchronous training throughput: the same GraphSAGE train
//! step fed by (a) the strictly sequential sample → assemble → execute
//! loop and (b) the producer pipeline (coordinator::pipeline, DESIGN.md
//! §7) at several producer counts, and (c) the pipeline backed by a
//! 4-worker sampling pool per partition with sharded gathers (DESIGN.md
//! §9). Overlap hides the sampling round behind the model step, so
//! pipelined steps/s ≥ sync steps/s whenever a spare core exists, and the
//! server pool lets the sampling side itself scale with cores; ordered
//! mode additionally reproduces the sync loss curve bit-for-bit — for the
//! pool rows too (per-seed server RNG) — asserted below.

use glisp::coordinator::PipelineConfig;
use glisp::graph::generator;
use glisp::harness::workloads::train_stack_cfg;
use glisp::harness::{BenchRecorder, BenchTable, Cell};
use glisp::partition::{AdaDNE, Partitioner};
use glisp::sampling::ServiceConfig;
use glisp::util::rng::Rng;
use glisp::util::timer::Timer;

fn main() -> anyhow::Result<()> {
    let art = glisp::test_artifacts_dir();
    println!("== pipeline_throughput — sync vs pipelined train steps/s ==");
    let steps = std::env::var("GLISP_BENCH_STEPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(30usize);
    let n = 8_000;
    let parts = 4;
    let pool = ServiceConfig::new(4, 16);

    let modes: [(&str, Option<PipelineConfig>, ServiceConfig); 6] = [
        ("sync", None, ServiceConfig::default()),
        (
            "pipelined x1 ordered",
            Some(PipelineConfig {
                producers: 1,
                queue_depth: 2,
                ordered: true,
            }),
            ServiceConfig::default(),
        ),
        (
            "pipelined x2 ordered",
            Some(PipelineConfig {
                producers: 2,
                queue_depth: 2,
                ordered: true,
            }),
            ServiceConfig::default(),
        ),
        (
            "pipelined x2 ordered, 4w pool",
            Some(PipelineConfig {
                producers: 2,
                queue_depth: 2,
                ordered: true,
            }),
            pool,
        ),
        (
            "pipelined x4 unordered",
            Some(PipelineConfig {
                producers: 4,
                queue_depth: 2,
                ordered: false,
            }),
            ServiceConfig::default(),
        ),
        (
            "pipelined x4 unordered, 4w pool",
            Some(PipelineConfig {
                producers: 4,
                queue_depth: 2,
                ordered: false,
            }),
            pool,
        ),
    ];

    let mut rec = BenchRecorder::new("pipeline_throughput");
    rec.config_usize("n", n)
        .config_usize("parts", parts)
        .config_usize("steps", steps)
        .config_str("model", "sage");
    let mut t = BenchTable::new(
        "modes",
        &format!(
            "n={n}, {parts} servers, sage, {steps} timed steps \
             (4w pool = 4 workers/partition, shard 16)"
        ),
        &["mode", "steps/s", "seeds/s", "vs sync"],
    );
    let mut base_rate = 0.0f64;
    let mut sync_losses: Vec<f32> = Vec::new();
    for (name, pcfg, svc_cfg) in modes {
        let mut s = train_stack_cfg(n, parts, "sage", &art, svc_cfg)?;
        s.trainer.train(&mut s.batcher, 3)?; // warmup + compile
        let timer = Timer::start();
        let losses = match &pcfg {
            None => s.trainer.train(&mut s.batcher, steps)?,
            Some(p) => s.trainer.train_pipelined(&mut s.batcher, steps, p)?,
        };
        let secs = timer.secs();
        let rate = steps as f64 / secs;
        if base_rate == 0.0 {
            base_rate = rate;
            sync_losses = losses;
        } else if pcfg.as_ref().is_some_and(|p| p.ordered) {
            // Bit-exactness across producer counts AND server pool
            // geometries — the per-seed determinism contract (DESIGN §9).
            rec.check(
                &format!("{}_losses_bit_equal_sync", glisp::harness::bench::slug(name)),
                sync_losses == losses,
                "ordered pipelined losses must reproduce the sync loss curve \
                 bit-for-bit (DESIGN.md §7/§9)",
            );
        }
        t.row(vec![
            Cell::str(name),
            Cell::f2(rate),
            Cell::f2(rate * s.trainer.batch as f64),
            Cell::x(rate / base_rate),
        ]);
        s.service.shutdown();
    }
    rec.table(&t);

    // -- negative sampling (the unsupervised-training primitive): client-
    // local, so throughput is pure client CPU — no server round trip.
    {
        let mut grng = Rng::new(5);
        let g = generator::heterogeneous_graph(n, n * 8, 2, 3, 2.2, &mut grng);
        let ea = AdaDNE::default().partition(&g, parts, 0);
        let svc = glisp::sampling::SamplingService::launch_cfg(&g, &ea, 1, pool)?;
        let seeds: Vec<u32> = (0..512).map(|i| (i * 7 % n) as u32).collect();
        let k = 5usize;
        // Determinism: twin clients produce identical negatives.
        let a = svc.client(21).sample_negatives(&seeds, k, None);
        let b = svc.client(21).sample_negatives(&seeds, k, None);
        rec.check(
            "negative_sampling_deterministic",
            a.offsets == b.offsets && a.neighbors == b.neighbors,
            "sample_negatives reproduces bit-identically for twin clients",
        );
        let mut client = svc.client(22);
        let iters = 50usize;
        let timer = Timer::start();
        for _ in 0..iters {
            std::hint::black_box(client.sample_negatives(&seeds, k, None));
        }
        let rate = (iters * seeds.len() * k) as f64 / timer.secs();
        let mut t = BenchTable::new(
            "negatives",
            &format!("client-local uniform negative sampling, {} seeds, k={k}", seeds.len()),
            &["op", "negatives/s"],
        );
        t.row(vec![Cell::str("sample_negatives"), Cell::f2(rate)]);
        rec.table(&t);
        svc.shutdown();
    }

    println!("\nThe producer pipeline overlaps K-hop sampling + feature assembly with");
    println!("the model step (paper §III-C keeps sampling off the trainer's critical");
    println!("path). Ordered mode is bit-exact vs sync (verified above, including");
    println!("with the 4-worker server pool); unordered trades the exact update");
    println!("order for immunity to producer skew. The pool rows let a hotspot");
    println!("gather parallelize inside each partition — on a multi-core host the");
    println!("4w rows should lead; on a single-core runner everything degrades");
    println!("gracefully to ~sync speed.");
    rec.finish()?;
    Ok(())
}
