//! Fig. 11: end-to-end training throughput of the three models, GLISP's
//! sampling stack vs the DistDGL-like baseline feeding the *same* AOT
//! train step. Any difference is therefore attributable to the sampling
//! architecture — the paper's 1.57×–6.53× claim.

use std::sync::Arc;

use glisp::coordinator::{Batcher, FeatureStore, Trainer, TrainerConfig};
use glisp::graph::generator;
use glisp::harness::{BenchRecorder, BenchTable, Cell};
use glisp::partition::{AdaDNE, Partitioner};
use glisp::sampling::baseline::BaselineStack;
use glisp::sampling::SamplingService;
use glisp::util::rng::Rng;
use glisp::util::timer::Timer;

fn main() -> anyhow::Result<()> {
    let art = glisp::test_artifacts_dir();
    println!("== Fig. 11 — end-to-end training speed (steps/s) ==");
    let steps = std::env::var("GLISP_BENCH_STEPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(30usize);
    let parts = 4;
    let n = 12_000;
    let classes = 8;
    let mut rng = Rng::new(1);
    // A skewed labeled graph so sampling imbalance matters.
    let g = generator::labeled_community_graph(n, n * 14, classes, 0.85, &mut rng);
    let labels = Arc::new(g.label.clone());
    let split = (n * 8) / 10;

    let mut rec = BenchRecorder::new("fig11_train_speed");
    rec.config_usize("n", n)
        .config_usize("parts", parts)
        .config_usize("steps", steps)
        .config_usize("classes", classes);
    let mut t = BenchTable::new(
        "models",
        &format!("n={n}, {parts} servers, {steps} timed steps (sim = parallel servers)"),
        &[
            "model",
            "GLISP sim",
            "base sim",
            "sim speedup",
            "sampling speedup",
            "GLISP wall",
            "base wall",
        ],
    );
    for model in ["gcn", "sage", "gat"] {
        let mut sim_rates = Vec::new();
        let mut wall_rates = Vec::new();
        let mut makespans = Vec::new();
        for glisp_stack in [true, false] {
            // Build the sampling stack.
            let (svc, client);
            let _baseline;
            if glisp_stack {
                let ea = AdaDNE::default().partition(&g, parts, 1);
                svc = Some(SamplingService::launch(&g, &ea, 1)?);
                client = svc.as_ref().unwrap().client(2);
                _baseline = None;
            } else {
                let stack = BaselineStack::launch(&g, parts, 1)?;
                client = stack.client(2);
                _baseline = Some(stack);
                svc = None;
            }
            let service = svc
                .as_ref()
                .unwrap_or_else(|| &_baseline.as_ref().unwrap().service);
            let features = FeatureStore::labeled(64, labels.clone(), classes, 0.6);
            let mut trainer = Trainer::new(
                &art,
                client,
                features,
                TrainerConfig { model: model.into(), lr: 0.1 },
                7,
            )?;
            let train_seeds: Vec<u32> = (0..split as u32).collect();
            let train_labels: Vec<u16> =
                train_seeds.iter().map(|&v| labels[v as usize]).collect();
            let mut batcher = Batcher::new(train_seeds, train_labels, trainer.batch, 5)?;
            trainer.train(&mut batcher, 3)?; // warmup + compile
            service.reset_stats()?;
            let timer = Timer::start();
            trainer.train(&mut batcher, steps)?;
            let wall = timer.secs();
            // Simulated distributed step time: servers run in parallel, so
            // replace the (serialized) total server busy time with the
            // busiest server's time.
            let busy = service.busy_secs()?;
            let makespan = busy.iter().cloned().fold(0f64, f64::max);
            let sim = (wall - busy.iter().sum::<f64>() + makespan).max(1e-9);
            sim_rates.push(steps as f64 / sim);
            wall_rates.push(steps as f64 / wall);
            makespans.push(makespan);
            if let Some(s) = svc {
                s.shutdown();
            }
            if let Some(b) = _baseline {
                b.shutdown();
            }
        }
        t.row(vec![
            Cell::str(model),
            Cell::f2(sim_rates[0]),
            Cell::f2(sim_rates[1]),
            Cell::x(sim_rates[0] / sim_rates[1]),
            Cell::x(makespans[1] / makespans[0].max(1e-9)),
            Cell::f2(wall_rates[0]),
            Cell::f2(wall_rates[1]),
        ]);
    }
    rec.table(&t);
    println!("\npaper Fig. 11: GLISP achieves 1.57x–6.53x over DistDGL/GraphLearn.");
    println!("'sim' replaces serialized server time with the bottleneck server's");
    println!("(parallel deployment). 'sampling speedup' is the ratio of bottleneck-");
    println!("server sampling time (base/GLISP) — the paper's GPU trainers are");
    println!("sampling-bound, so its end-to-end speedup tracks this column; on this");
    println!("1-core CPU testbed the model step dominates and compresses 'sim'.");
    rec.finish()?;
    Ok(())
}
