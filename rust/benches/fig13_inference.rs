//! Fig. 13: full-graph inference, layerwise engine vs naive samplewise,
//! on vertex-embedding and link-prediction tasks. Paper: 7.89× and 70.77×
//! wall-clock speedups respectively; the mechanism is the eliminated
//! recomputation, which we report alongside wall time.

use glisp::coordinator::FeatureStore;
use glisp::graph::generator;
use glisp::harness::{f2, ix, Table};
use glisp::inference::{
    init_decode_params, init_encoder_params, EngineConfig, LayerwiseEngine, SamplewiseRunner,
};
use glisp::partition::{AdaDNE, Partitioner};
use glisp::runtime::Runtime;
use glisp::util::rng::Rng;
use glisp::util::timer::Timer;

fn main() -> anyhow::Result<()> {
    let art = glisp::test_artifacts_dir();
    println!("== Fig. 13 — layerwise vs samplewise full-graph inference ==");
    let n = std::env::var("GLISP_BENCH_N")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(6_000usize);
    let mut rng = Rng::new(1);
    let g = generator::chung_lu(n, n * 7, 2.1, &mut rng);
    let ea = AdaDNE::default().partition(&g, 4, 1);
    let work = std::env::temp_dir().join("glisp_fig13");
    let _ = std::fs::remove_dir_all(&work);

    let runtime = Runtime::load(&art)?;
    let enc = init_encoder_params(&runtime, 3)?;
    let mut engine = LayerwiseEngine::new(
        &g, &ea, runtime,
        FeatureStore::unlabeled(64),
        enc.clone(),
        EngineConfig::default(),
        work,
    )?;
    let mut sw = SamplewiseRunner::new(
        &g,
        Runtime::load(&art)?,
        FeatureStore::unlabeled(64),
        enc,
        5,
    )?;

    // --- vertex embedding ---
    let timer = Timer::start();
    let (h, lw_rep) = engine.run_vertex_embedding()?;
    let lw_v = timer.secs();
    let timer = Timer::start();
    let (_, sw_rep) = sw.run_vertex_embedding()?;
    let sw_v = timer.secs();

    // --- link prediction ---
    let edges: Vec<(u32, u32)> = (0..g.n as u32)
        .filter(|&u| !g.out_neighbors(u).is_empty())
        .take(n / 2)
        .map(|u| (u, g.out_neighbors(u)[0]))
        .collect();
    let dec = init_decode_params(&engine.runtime, 9)?;
    let timer = Timer::start();
    engine.run_link_prediction(&h, &edges, &dec)?;
    let lw_l = timer.secs();
    let timer = Timer::start();
    let (_, sw_rep_l) = sw.run_link_prediction(&edges, &dec)?;
    let sw_l = timer.secs();

    let mut t = Table::new(
        &format!("full-graph inference, n={n} ({} edges scored)", edges.len()),
        &["task", "samplewise (s)", "layerwise (s)", "speedup", "computations SW", "computations LW"],
    );
    t.row(&[
        "vertex embedding".into(),
        f2(sw_v),
        f2(lw_v),
        format!("{:.2}x", sw_v / lw_v),
        ix(sw_rep.vertices_computed as usize),
        ix(lw_rep.vertices_computed as usize),
    ]);
    t.row(&[
        "link prediction".into(),
        f2(sw_l),
        f2(lw_l),
        format!("{:.2}x", sw_l / lw_l),
        ix(sw_rep_l.vertices_computed as usize),
        ix((edges.len() * 2) as usize),
    ]);
    t.print();
    println!("\npaper Fig. 13: 7.89x (vertex embedding) and 70.77x (link prediction);");
    println!("link prediction speeds up more because both endpoints' K-hop trees are");
    println!("recomputed per edge under samplewise inference.");
    Ok(())
}
