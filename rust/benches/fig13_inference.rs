//! Fig. 13: full-graph inference, layerwise engine vs naive samplewise,
//! on vertex-embedding and link-prediction tasks. Paper: 7.89× and 70.77×
//! wall-clock speedups respectively; the mechanism is the eliminated
//! recomputation, which we report alongside wall time.
//!
//! Since the worker-parallel sweep landed (DESIGN.md §8) the layerwise
//! engine is measured twice — partition sweeps on one thread vs one
//! thread per partition — so the bench also shows the multi-worker
//! wall-clock win on top of the recomputation win. Both engine variants
//! produce bit-identical embeddings (asserted below).

use glisp::harness::{infer_stack, BenchRecorder, BenchTable, Cell};
use glisp::inference::{init_decode_params, EngineConfig, SamplewiseRunner};
use glisp::runtime::Runtime;
use glisp::util::timer::Timer;

fn main() -> anyhow::Result<()> {
    let art = glisp::test_artifacts_dir();
    println!("== Fig. 13 — layerwise vs samplewise full-graph inference ==");
    let n = std::env::var("GLISP_BENCH_N")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(6_000usize);
    let parts = 4usize;
    let work = std::env::temp_dir().join("glisp_fig13");
    let mut rec = BenchRecorder::new("fig13_inference");
    rec.config_usize("n", n).config_usize("parts", parts);

    // --- layerwise, worker-parallel (the engine's default) ---
    let mut par = infer_stack(n, parts, &art, work, EngineConfig::default())?;
    let timer = Timer::start();
    let (h, lw_rep) = par.engine.run_vertex_embedding()?;
    let lw_v = timer.secs();

    // --- layerwise, single-thread partition sweeps (PR-2-era baseline);
    //     same engine, same graph — only the threading knob changes ---
    par.engine.cfg.parallel = false;
    let timer = Timer::start();
    let (h_seq, _) = par.engine.run_vertex_embedding()?;
    let seq_v = timer.secs();
    par.engine.cfg.parallel = true;
    rec.check(
        "vertex_embedding_parallel_bit_identical",
        h == h_seq,
        "worker-parallel partition sweeps must reproduce the single-thread embeddings \
         bit-for-bit (DESIGN.md §8)",
    );

    // --- samplewise baseline ---
    let mut sw = SamplewiseRunner::new(
        &par.g,
        Runtime::load(&art)?,
        glisp::coordinator::FeatureStore::unlabeled(64),
        par.engine.enc_params.clone(),
        5,
    )?;
    let timer = Timer::start();
    let (_, sw_rep) = sw.run_vertex_embedding()?;
    let sw_v = timer.secs();

    // --- link prediction ---
    let edges: Vec<(u32, u32)> = (0..par.g.n as u32)
        .filter(|&u| !par.g.out_neighbors(u).is_empty())
        .take(n / 2)
        .map(|u| (u, par.g.out_neighbors(u)[0]))
        .collect();
    let dec = init_decode_params(&par.engine.runtime, 9)?;
    let timer = Timer::start();
    par.engine.run_link_prediction(&h, &edges, &dec)?;
    let lw_l = timer.secs();
    let timer = Timer::start();
    let (_, sw_rep_l) = sw.run_link_prediction(&edges, &dec)?;
    let sw_l = timer.secs();

    let mut t = BenchTable::new(
        "inference",
        &format!(
            "full-graph inference, n={n}, {parts} workers ({} edges scored)",
            edges.len()
        ),
        &[
            "task",
            "samplewise",
            "layerwise 1-thr",
            "layerwise par",
            "vs samplewise",
            "par vs 1-thr",
            "computations SW",
            "computations LW",
        ],
    );
    t.param_usize("edges_scored", edges.len());
    t.row(vec![
        Cell::str("vertex embedding"),
        Cell::d(sw_v),
        Cell::d(seq_v),
        Cell::d(lw_v),
        Cell::x(sw_v / lw_v),
        Cell::x(seq_v / lw_v),
        Cell::n(sw_rep.vertices_computed),
        Cell::n(lw_rep.vertices_computed),
    ]);
    t.row(vec![
        Cell::str("link prediction"),
        Cell::d(sw_l),
        Cell::na(),
        Cell::d(lw_l),
        Cell::x(sw_l / lw_l),
        Cell::na(),
        Cell::n(sw_rep_l.vertices_computed),
        Cell::n((edges.len() * 2) as u64),
    ]);
    rec.table(&t);
    println!("\npaper Fig. 13: 7.89x (vertex embedding) and 70.77x (link prediction);");
    println!("link prediction speeds up more because both endpoints' K-hop trees are");
    println!("recomputed per edge under samplewise inference. The 'par vs 1-thr'");
    println!("column is the additional win from one sweep thread per partition.");
    rec.finish()?;
    Ok(())
}
