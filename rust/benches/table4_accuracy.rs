//! Table IV: inductive vertex-classification test accuracy of GCN /
//! GraphSAGE / GAT trained through the full GLISP stack. The paper's
//! claim is *parity* — GLISP's accuracies agree with the baseline
//! frameworks (correctness of the sampling + training path), not a win.
//! Here the parity band is: all three models beat chance by a wide margin
//! and land within a few points of each other on the same synthetic task.

use std::sync::Arc;

use glisp::coordinator::{Batcher, FeatureStore, Trainer, TrainerConfig};
use glisp::graph::generator;
use glisp::harness::{BenchRecorder, BenchTable, Cell};
use glisp::partition::{AdaDNE, Partitioner};
use glisp::sampling::SamplingService;
use glisp::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let art = glisp::test_artifacts_dir();
    println!("== Table IV — test accuracy via the full stack ==");
    let steps = std::env::var("GLISP_BENCH_STEPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(120usize);
    let classes = 8;
    let n = 8_000;
    let mut rng = Rng::new(1);
    let g = generator::labeled_community_graph(n, n * 10, classes, 0.9, &mut rng);
    let labels = Arc::new(g.label.clone());
    let ea = AdaDNE::default().partition(&g, 2, 1);
    let svc = SamplingService::launch(&g, &ea, 1)?;
    let split = (n * 8) / 10;

    let mut rec = BenchRecorder::new("table4_accuracy");
    rec.config_usize("n", n)
        .config_usize("classes", classes)
        .config_usize("steps", steps);
    let mut t = BenchTable::new(
        "accuracy",
        &format!("labeled community graph (n={n}, {classes} classes, {steps} steps)"),
        &["model", "test accuracy", "final loss"],
    );
    let mut accs = Vec::new();
    for model in ["gcn", "sage", "gat"] {
        let features = FeatureStore::labeled(64, labels.clone(), classes, 0.6);
        let lr = if model == "sage" { 0.1 } else { 0.4 };
        let mut trainer = Trainer::new(
            &art,
            svc.client(2),
            features,
            TrainerConfig { model: model.into(), lr },
            7,
        )?;
        let train_seeds: Vec<u32> = (0..split as u32).collect();
        let train_labels: Vec<u16> =
            train_seeds.iter().map(|&v| labels[v as usize]).collect();
        let mut batcher = Batcher::new(train_seeds, train_labels, trainer.batch, 5)?;
        let losses = trainer.train(&mut batcher, steps)?;
        let test_seeds: Vec<u32> = (split as u32..(split + 1600) as u32).collect();
        let test_labels: Vec<u16> =
            test_seeds.iter().map(|&v| labels[v as usize]).collect();
        let acc = trainer.evaluate(&test_seeds, &test_labels)?;
        accs.push(acc);
        t.row(vec![
            Cell::str(model),
            Cell::f3(acc),
            Cell::f3(*losses.last().unwrap() as f64),
        ]);
    }
    let chance = 1.0 / classes as f64;
    let spread = accs.iter().cloned().fold(f64::MIN, f64::max)
        - accs.iter().cloned().fold(f64::MAX, f64::min);
    t.param("chance", glisp::util::json::Json::Num(chance));
    t.param("spread", glisp::util::json::Json::Num(spread));
    rec.table(&t);
    println!("\nchance accuracy: {chance:.3}");
    println!(
        "parity band: max-min spread {spread:.3} (paper Table IV spreads are <= 0.02 per dataset)"
    );
    svc.shutdown();
    rec.finish()?;
    Ok(())
}
