//! DESIGN.md §15 — the online serving hot path under power-law traffic:
//!
//! * **serve**: closed-loop load (concurrent clients, degree-skewed
//!   request trace) against the request-driven K-slice serving engine —
//!   p50/p99 latency, QPS and the static/dynamic hit ratios, cold vs
//!   warmed-from-offline. The recorder asserts the warm hit ratio beats
//!   cold (`warm_hit_ratio_exceeds_cold`).
//! * **bits**: the served embeddings and fleet-sampled link scores are
//!   FNV-digested across all four sampling deployments —
//!   {heap, mmap} structures × {channel, socket} transport — and must
//!   bit-match the offline layerwise sweep for the same snapshot
//!   (`online_bits_identical_to_offline`, `link_scores_transport_invariant`).

use glisp::graph::csr::VId;
use glisp::graph::StoreBackend;
use glisp::harness::{
    infer_stack, power_law_trace, run_closed_loop, serving_fleet, serving_stack, BenchRecorder,
    BenchTable, Cell,
};
use glisp::inference::{init_decode_params, EngineConfig};
use glisp::sampling::{SampleConfig, ServiceConfig, PAD};
use glisp::serving::ServingConfig;
use glisp::util::digest::f32_digest;

const PARTS: usize = 2;
const CLIENTS: usize = 4;
const BATCH: usize = 6;
const LINK_FANOUT: usize = 5;

fn main() -> anyhow::Result<()> {
    println!("== bench_serving — online serving hot path (DESIGN.md §15) ==");
    let n: usize = std::env::var("GLISP_BENCH_N")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(3000);
    let requests: usize = std::env::var("GLISP_BENCH_REQUESTS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(160);
    let trace_len = requests * BATCH;
    let art = glisp::test_artifacts_dir();
    let root = std::env::temp_dir().join("glisp_bench_serving");
    let _ = std::fs::remove_dir_all(&root);

    let mut rec = BenchRecorder::new("bench_serving");
    rec.config_usize("n", n)
        .config_usize("parts", PARTS)
        .config_usize("requests", requests)
        .config_usize("clients", CLIENTS)
        .config_usize("batch", BATCH)
        .config_usize("link_fanout", LINK_FANOUT);

    // -- offline reference: one layerwise sweep over the same stack ------
    let mut off = infer_stack(n, PARTS, &art, root.join("off"), EngineConfig::default())?;
    let (h, _) = off.engine.run_vertex_embedding()?;
    let hidden = off.engine.hidden();
    let trace = power_law_trace(&off.g, trace_len, 23);
    let mut offline_trace = Vec::with_capacity(trace.len() * hidden);
    for &v in &trace {
        let r = off.engine.rank[v as usize] as usize;
        offline_trace.extend_from_slice(&h[r * hidden..(r + 1) * hidden]);
    }
    let offline_digest = f32_digest(&offline_trace);
    let dec = init_decode_params(&off.engine.runtime, 9)?;

    // Hub-heavy link-request seeds: the head of the power-law trace.
    let mut link_seeds: Vec<VId> = trace[..trace.len().min(48)].to_vec();
    link_seeds.sort_unstable();
    link_seeds.dedup();

    let mut t = BenchTable::new(
        "serve",
        &format!(
            "closed-loop serving, n={n}, {requests} reqs x {BATCH} verts, {CLIENTS} clients"
        ),
        &["deployment", "state", "p50 µs", "p99 µs", "QPS", "static hit", "dyn hit"],
    );
    let mut bits_ok = true;
    let mut load_digest: Option<u64> = None;
    let mut link_digest: Option<u64> = None;
    let mut offline_link: Option<Vec<f32>> = None;
    let mut cold_ratio = 0.0;
    let (mut cold_p99, mut cold_qps) = (0.0, 0.0);
    let save = root.join("parts");
    let configs = [
        ("heap/channel", StoreBackend::Heap, false),
        ("mmap/channel", StoreBackend::Mmap, false),
        ("heap/socket", StoreBackend::Heap, true),
        ("mmap/socket", StoreBackend::Mmap, true),
    ];
    for (name, backend, socket) in configs {
        // A fresh cold serving stack per deployment: same (n, parts,
        // seeds) → bit-identical graph, partition and snapshot.
        let tag = name.replace('/', "_");
        let mut stack = serving_stack(
            n,
            PARTS,
            &art,
            root.join(format!("srv_{tag}")),
            EngineConfig::default(),
            ServingConfig::default(),
        )?;
        let rep = run_closed_loop(&mut stack.serving, &trace, CLIENTS, BATCH)?;
        bits_ok &= *load_digest.get_or_insert(rep.digest) == rep.digest;
        // Full-trace read-back against the offline sweep's bytes.
        let served = stack.serving.embed(&trace)?;
        bits_ok &= f32_digest(&served) == offline_digest;

        // Link-score path: candidates come from the fleet (this is where
        // the storage × transport axis runs), scores from the engine.
        let (svc, servers) =
            serving_fleet(&stack.g, &stack.ea, &save, backend, socket, ServiceConfig::default())?;
        let mut client = svc.client(7);
        let sample = client.sample_topk(&link_seeds, LINK_FANOUT, &SampleConfig::default())?;
        let mut edges: Vec<(VId, VId)> = Vec::new();
        for (i, &s) in link_seeds.iter().enumerate() {
            for &nb in sample.neighbors_of(i) {
                if nb != PAD {
                    edges.push((s, nb));
                }
            }
        }
        let scores = stack.serving.link_scores(&edges, &dec)?;
        bits_ok &= *link_digest.get_or_insert(f32_digest(&scores)) == f32_digest(&scores);
        if offline_link.is_none() {
            let (want, _) = off.engine.run_link_prediction(&h, &edges, &dec)?;
            bits_ok &= scores == want;
            offline_link = Some(want);
        }
        svc.shutdown();
        for srv in servers {
            srv.join();
        }

        let st = stack.serving.stats();
        if name == "heap/channel" {
            cold_ratio = st.static_hit_ratio() + st.dynamic_hit_ratio();
            cold_p99 = rep.p99_us;
            cold_qps = rep.qps;
        }
        t.row(vec![
            Cell::str(name),
            Cell::str("cold"),
            Cell::f2(rep.p50_us),
            Cell::f2(rep.p99_us),
            Cell::f2(rep.qps),
            Cell::f3(st.static_hit_ratio()),
            Cell::f3(st.dynamic_hit_ratio()),
        ]);
    }

    // -- warm run: offline pass pre-populates every slab's static tier ---
    let mut warm = serving_stack(
        n,
        PARTS,
        &art,
        root.join("srv_warm"),
        EngineConfig::default(),
        ServingConfig::default(),
    )?;
    warm.serving.warm()?;
    let wrep = run_closed_loop(&mut warm.serving, &trace, CLIENTS, BATCH)?;
    bits_ok &= Some(wrep.digest) == load_digest;
    bits_ok &= f32_digest(&warm.serving.embed(&trace)?) == offline_digest;
    let wst = warm.serving.stats();
    let warm_ratio = wst.static_hit_ratio() + wst.dynamic_hit_ratio();
    t.row(vec![
        Cell::str("heap/channel"),
        Cell::str("warm"),
        Cell::f2(wrep.p50_us),
        Cell::f2(wrep.p99_us),
        Cell::f2(wrep.qps),
        Cell::f3(wst.static_hit_ratio()),
        Cell::f3(wst.dynamic_hit_ratio()),
    ]);

    // The EXPERIMENTS.md claims table reads this row: warmup is expected
    // to at least hold QPS (no frontier compute left on the request path).
    let mut wt = BenchTable::new(
        "warm_vs_cold",
        "warmup effect on the closed-loop path (heap/channel, same trace)",
        &["metric", "cold p99 µs", "warm p99 µs", "cold QPS", "warm QPS", "warm vs cold QPS"],
    );
    wt.row(vec![
        Cell::str("closed-loop"),
        Cell::f2(cold_p99),
        Cell::f2(wrep.p99_us),
        Cell::f2(cold_qps),
        Cell::f2(wrep.qps),
        Cell::x(if cold_qps > 0.0 { wrep.qps / cold_qps } else { 0.0 }),
    ]);
    rec.table(&wt);

    rec.check(
        "online_bits_identical_to_offline",
        bits_ok,
        "served embeddings (cold and warm, every deployment) and link scores \
         bit-match the offline layerwise sweep for the same snapshot",
    );
    rec.check(
        "link_scores_transport_invariant",
        link_digest.is_some() && bits_ok,
        "fleet-sampled link candidates and their scores agree across \
         {heap,mmap} x {channel,socket}",
    );
    rec.check(
        "warm_hit_ratio_exceeds_cold",
        warm_ratio > cold_ratio && wst.rows_computed == 0,
        "warmed static tier serves every read locally (0 rows computed) and \
         its hit ratio beats the cold run's",
    );
    rec.table(&t);

    println!("\nCold serving resolves each request's K-hop frontier, truncated at");
    println!("every already-valid slab row, so the hot head of the power-law trace");
    println!("is computed once and reused; warmup replays the offline layerwise");
    println!("sweep through the per-layer observer so requests become pure cache");
    println!("reads. Both paths serve bytes identical to the offline engine.");
    rec.finish()?;
    Ok(())
}
