//! Table II: RF / VB / EB / runtime of the edge-cut comparator (ParMETIS
//! stand-in), DistributedNE and AdaDNE over the dataset suite at two
//! partition counts. Expected shape (paper): AdaDNE lowest VB+EB
//! everywhere, RF and time comparable to DNE, edge-cut far worse on the
//! power-law graphs.

use glisp::harness::workloads::{bench_datasets, load};
use glisp::harness::{f2, f3, Table};
use glisp::partition::{quality, AdaDNE, DistributedNE, EdgeCutLDG, Partitioner};
use glisp::util::timer::Timer;

fn main() {
    println!("== Table II — partition quality ==");
    let algos: Vec<Box<dyn Partitioner>> = vec![
        Box::new(EdgeCutLDG::default()),
        Box::new(DistributedNE::default()),
        Box::new(AdaDNE::default()),
    ];
    for spec in bench_datasets() {
        let g = load(&spec, 1);
        for &parts in &[4usize, 8] {
            let mut t = Table::new(
                &format!("{} × {} partitions", spec.name, parts),
                &["algorithm", "RF", "VB", "EB", "time(s)"],
            );
            for algo in &algos {
                let timer = Timer::start();
                let ea = algo.partition(&g, parts, 1);
                let secs = timer.secs();
                let q = quality(&g, &ea);
                t.row(&[algo.name().into(), f3(q.rf), f3(q.vb), f3(q.eb), f2(secs)]);
            }
            t.print();
        }
    }
    println!("\npaper Table II: AdaDNE achieves the lowest VB and EB in all cases,");
    println!("with RF and elapsed time comparable to DistributedNE; the edge-cut");
    println!("comparator degrades sharply on power-law graphs.");
}
