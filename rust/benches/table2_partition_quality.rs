//! Table II: RF / VB / EB / runtime of the edge-cut comparator (ParMETIS
//! stand-in), DistributedNE and AdaDNE over the dataset suite at two
//! partition counts. Expected shape (paper): AdaDNE lowest VB+EB
//! everywhere, RF and time comparable to DNE, edge-cut far worse on the
//! power-law graphs.
//!
//! The neighbor-expansion rows run twice — propose phase on 1 thread and
//! on PAR_THREADS — and assert the assignments are bit-identical
//! (DESIGN.md §10), so the wall-clock pair isolates the parallel offline
//! stage's win without any quality caveat.

use glisp::graph::Graph;
use glisp::harness::workloads::{bench_datasets, load};
use glisp::harness::{BenchRecorder, BenchTable, Cell};
use glisp::partition::{quality, AdaDNE, DistributedNE, EdgeAssignment, EdgeCutLDG, Partitioner};
use glisp::util::timer::Timer;

const PAR_THREADS: usize = 4;

/// (name, threaded partition fn). Thread count 0 = "no knob" (single-pass
/// streaming baseline, run once).
type Algo = (&'static str, Box<dyn Fn(&Graph, usize, usize) -> EdgeAssignment>);

fn algos() -> Vec<Algo> {
    vec![
        (
            "EdgeCutLDG",
            Box::new(|g: &Graph, parts, _t| EdgeCutLDG::default().partition(g, parts, 1)),
        ),
        (
            "DistributedNE",
            Box::new(|g: &Graph, parts, t| {
                DistributedNE {
                    threads: t,
                    ..Default::default()
                }
                .partition(g, parts, 1)
            }),
        ),
        (
            "AdaDNE",
            Box::new(|g: &Graph, parts, t| {
                AdaDNE {
                    threads: t,
                    ..Default::default()
                }
                .partition(g, parts, 1)
            }),
        ),
    ]
}

fn main() -> anyhow::Result<()> {
    println!("== Table II — partition quality ==");
    let mut rec = BenchRecorder::new("table2_partition_quality");
    rec.config_usize("par_threads", PAR_THREADS);
    for spec in bench_datasets() {
        let g = load(&spec, 1);
        for &parts in &[4usize, 8] {
            let mut t = BenchTable::new(
                &format!("{}_x{}", spec.name, parts),
                &format!(
                    "{} × {} partitions (1t/{PAR_THREADS}t = propose threads, \
                     assignments asserted bit-identical)",
                    spec.name, parts
                ),
                &["algorithm", "RF", "VB", "EB", "1t(s)", &format!("{PAR_THREADS}t(s)")],
            );
            t.param_str("dataset", spec.name).param_usize("parts", parts);
            for (name, algo) in &algos() {
                let timer = Timer::start();
                let ea = algo(&g, parts, 1);
                let serial_secs = timer.secs();
                let par_cell = if *name == "EdgeCutLDG" {
                    // Streaming baseline: no propose phase to parallelize.
                    Cell::na()
                } else {
                    let timer = Timer::start();
                    let par = algo(&g, parts, PAR_THREADS);
                    let par_secs = timer.secs();
                    rec.check(
                        &format!(
                            "{}_x{}_{}_assignment_thread_invariant",
                            spec.name,
                            parts,
                            name.to_lowercase()
                        ),
                        ea.part_of_edge == par.part_of_edge,
                        "propose-phase thread count must not leak into the edge \
                         assignment (DESIGN.md §10)",
                    );
                    Cell::d(par_secs)
                };
                let q = quality(&g, &ea);
                t.row(vec![
                    Cell::str(*name),
                    Cell::f3(q.rf),
                    Cell::f3(q.vb),
                    Cell::f3(q.eb),
                    Cell::d(serial_secs),
                    par_cell,
                ]);
            }
            rec.table(&t);
        }
    }
    println!("\npaper Table II: AdaDNE achieves the lowest VB and EB in all cases,");
    println!("with RF and elapsed time comparable to DistributedNE; the edge-cut");
    println!("comparator degrades sharply on power-law graphs. The {PAR_THREADS}t column");
    println!("reruns the identical schedule with a parallel propose phase — on a");
    println!("≥{PAR_THREADS}-core host it should approach the thread count; on a 1-core");
    println!("testbed it degrades gracefully to ~1x.");
    rec.finish()?;
    Ok(())
}
