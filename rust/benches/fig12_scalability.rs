//! Fig. 12: (a) convergence is unaffected by the number of synchronous
//! data-parallel trainers; (b) throughput scales with trainer count
//! (paper: slope ≈ 0.8 of ideal on its RelNet KGE task).
//!
//! Trainers here are sequentially-executed logical workers sharing the
//! leader's parameters (gradient averaging is exact either way); the
//! scaling series reports aggregate samples/s per round relative to one
//! trainer, with the per-trainer sampling clients hitting the same server
//! group concurrently.

use std::sync::Arc;

use glisp::coordinator::trainer::sync_round;
use glisp::coordinator::{Batcher, FeatureStore, Trainer, TrainerConfig};
use glisp::graph::{build_partitions_threads, generator};
use glisp::harness::{BenchRecorder, BenchTable, Cell};
use glisp::partition::{AdaDNE, Partitioner};
use glisp::sampling::SamplingService;
use glisp::util::rng::Rng;
use glisp::util::timer::Timer;

const OFFLINE_THREADS: usize = 4;

fn main() -> anyhow::Result<()> {
    let art = glisp::test_artifacts_dir();
    println!("== Fig. 12 — convergence + scaling with trainer count ==");
    let rounds = std::env::var("GLISP_BENCH_STEPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(12usize);
    let n = 8_000;
    let classes = 8;
    let mut rng = Rng::new(1);
    let g = generator::labeled_community_graph(n, n * 10, classes, 0.9, &mut rng);
    let labels = Arc::new(g.label.clone());

    let mut rec = BenchRecorder::new("fig12_scalability");
    rec.config_usize("n", n)
        .config_usize("rounds", rounds)
        .config_usize("offline_threads", OFFLINE_THREADS);

    // Offline-stage scaling: the same partition + build pipeline on one
    // thread vs OFFLINE_THREADS, asserted bit-identical (DESIGN.md §10) —
    // the offline analogue of the trainer-count scaling below.
    let timer = Timer::start();
    let ea = AdaDNE::default().partition(&g, 4, 1);
    let pgs = build_partitions_threads(&g, &ea.part_of_edge, 4, 1)?;
    let offline_1t = timer.secs();
    let timer = Timer::start();
    let ea_par = AdaDNE {
        threads: OFFLINE_THREADS,
        ..Default::default()
    }
    .partition(&g, 4, 1);
    let pgs_par = build_partitions_threads(&g, &ea_par.part_of_edge, 4, OFFLINE_THREADS)?;
    let offline_par = timer.secs();
    rec.check(
        "adadne_assignment_thread_invariant",
        ea.part_of_edge == ea_par.part_of_edge,
        "thread count must not leak into the AdaDNE edge assignment (DESIGN.md §10)",
    );
    let builds_match = pgs.iter().zip(&pgs_par).all(|(a, b)| {
        a.global_id == b.global_id && a.out_dst == b.out_dst && a.in_eid == b.in_eid
    });
    rec.check(
        "parallel_build_bit_identical",
        builds_match,
        "compact partition structures built on 1 vs 4 threads must match byte-for-byte",
    );
    let mut off = BenchTable::new(
        "offline_stage",
        &format!("offline stage, 4 parts, 1 vs {OFFLINE_THREADS} threads"),
        &["stage", "1t", "4t", "speedup"],
    );
    off.param_usize("parts", 4).param_usize("threads", OFFLINE_THREADS);
    off.row(vec![
        Cell::str("partition+build"),
        Cell::d(offline_1t),
        Cell::d(offline_par),
        Cell::x(offline_1t / offline_par.max(1e-9)),
    ]);
    rec.table(&off);
    let svc = SamplingService::launch_with_partitions(g.n, pgs_par, 1);

    let mut t = BenchTable::new(
        "scaling",
        &format!("synchronous data parallelism ({rounds} rounds each; sim = parallel trainers)"),
        &["trainers", "first loss", "last loss", "sim samples/s", "sim scaling", "ideal"],
    );
    let mut base_rate = 0.0f64;
    for &workers in &[1usize, 2, 4, 8] {
        let mut trainers = Vec::new();
        let mut batchers = Vec::new();
        for w in 0..workers {
            let features = FeatureStore::labeled(64, labels.clone(), classes, 0.6);
            let tr = Trainer::new(
                &art,
                svc.client(10 + w as u64),
                features,
                TrainerConfig { model: "sage".into(), lr: 0.1 },
                7, // identical init across runs
            )?;
            let seeds: Vec<u32> = (0..(n as u32 * 8) / 10).collect();
            let lab: Vec<u16> = seeds.iter().map(|&v| labels[v as usize]).collect();
            let batch = tr.batch;
            trainers.push(tr);
            batchers.push(Batcher::new(seeds, lab, batch, 100 + w as u64)?);
        }
        // Warmup (compile).
        sync_round(&mut trainers, &mut batchers, 0.1)?;
        let _ = Timer::start();
        let mut first = 0f32;
        let mut last = 0f32;
        let mut sim_secs = 0f64;
        for r in 0..rounds {
            let rep = sync_round(&mut trainers, &mut batchers, 0.1)?;
            sim_secs += rep.simulated_secs();
            if r == 0 {
                first = rep.loss;
            }
            last = rep.loss;
        }
        let samples = rounds * workers * trainers[0].batch;
        let rate = samples as f64 / sim_secs;
        if workers == 1 {
            base_rate = rate;
        }
        t.row(vec![
            Cell::str(format!("{workers}")),
            Cell::f3(first as f64),
            Cell::f3(last as f64),
            Cell::f2(rate),
            Cell::x(rate / base_rate),
            Cell::x(workers as f64),
        ]);
    }
    rec.table(&t);
    println!("\npaper Fig. 12: (a) trainer count does not change the convergence");
    println!("trajectory (same loss trend per round); (b) speedup slope ≈ 0.8 of");
    println!("ideal. 'sim' charges each round max(trainer time) + sync/apply time");
    println!("(trainers run in parallel in the paper's deployment; stragglers and");
    println!("the barrier produce the sublinear slope).");
    svc.shutdown();
    rec.finish()?;
    Ok(())
}
