//! Fig. 14: (a) embedding-retrieval speedup of the caching system per
//! reorder algorithm vs reading every chunk "remotely", and (b) total
//! chunks read. NS / DS / PS / PDS — the paper's four sort keys.
//! Expected shape: PDS reads the fewest chunks and wins; DS < PS (DS
//! discards the partitioner's locality).

use glisp::coordinator::FeatureStore;
use glisp::graph::generator;
use glisp::graph::reorder::ReorderAlgo;
use glisp::harness::{BenchRecorder, BenchTable, Cell};
use glisp::inference::chunk_store::COST_REMOTE;
use glisp::inference::{init_encoder_params, EngineConfig, LayerwiseEngine};
use glisp::partition::{AdaDNE, Partitioner};
use glisp::runtime::Runtime;
use glisp::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let art = glisp::test_artifacts_dir();
    println!("== Fig. 14 — caching-system speedup & chunk reads per reorder ==");
    let n = std::env::var("GLISP_BENCH_N")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(6_000usize);
    let mut rng = Rng::new(1);
    let g = generator::chung_lu(n, n * 7, 2.1, &mut rng);
    let ea = AdaDNE::default().partition(&g, 4, 1);

    let mut rec = BenchRecorder::new("fig14_reorder_cache");
    rec.config_usize("n", n).config_usize("parts", 4);
    let mut t = BenchTable::new(
        "reorder",
        &format!("n={n}, 4 partitions, chunk 128, dyn cache 10% FIFO"),
        &["reorder", "chunk reads", "dyn hits", "hit ratio", "reads vs NS", "speedup vs no-cache"],
    );
    // The paper's Fig. 14a baseline is FIXED: reading every chunk remotely
    // with no caches and no reordering (= the NS access pattern). All four
    // rows are normalized against it.
    let mut rows = Vec::new();
    for algo in [
        ReorderAlgo::NS,
        ReorderAlgo::DS,
        ReorderAlgo::PS,
        ReorderAlgo::PDS,
    ] {
        let work = std::env::temp_dir().join(format!("glisp_fig14_{}", algo.name()));
        let _ = std::fs::remove_dir_all(&work);
        let runtime = Runtime::load(&art)?;
        let enc = init_encoder_params(&runtime, 3)?;
        let mut engine = LayerwiseEngine::new(
            &g, &ea, runtime,
            FeatureStore::unlabeled(64),
            enc,
            EngineConfig {
                reorder: algo,
                ..Default::default()
            },
            work,
        )?;
        let (_, rep) = engine.run_vertex_embedding()?;
        rows.push((algo, rep));
    }
    let ns_reads = rows[0].1.chunk_reads;
    let baseline_cost = ns_reads * COST_REMOTE;
    for (algo, rep) in &rows {
        // With a 100% static fill, retrieval cost = chunk fetches at the
        // local-disk tier (+ the dynamic tier absorbing row reuse for free).
        let cost = rep.virtual_cost - rep.dynamic_hits; // exclude row-hit pennies
        t.row(vec![
            Cell::str(algo.name()),
            Cell::n(rep.chunk_reads),
            Cell::n(rep.dynamic_hits),
            Cell::f3(rep.dynamic_hit_ratio),
            Cell::f2(rep.chunk_reads as f64 / ns_reads as f64),
            Cell::x(baseline_cost as f64 / cost.max(1) as f64),
        ]);
    }
    rec.table(&t);
    println!("\npaper Fig. 14: NS already gains 2.52x from the caches alone; PDS");
    println!("reads the fewest chunks (41.5% of NS) with the highest dynamic hit");
    println!("ratio (>29%), reaching 8.10x; DS lands below PS because plain degree");
    println!("sort discards the locality the partitioner already mined.");
    rec.finish()?;
    Ok(())
}
