//! Fig. 9: subgraph sampling throughput, uniform and weighted, GLISP
//! (AdaDNE + Gather-Apply replica routing) vs the DistDGL-like baseline
//! (edge-cut + owner routing) vs the GraphLearn-like baseline (1D-hash +
//! owner routing). Fanouts [15, 10, 5], balanced seeds (paper §IV-C).

use glisp::graph::Graph;
use glisp::harness::workloads::{bench_datasets, load};
use glisp::harness::{f2, Table};
use glisp::partition::{edge_cut_to_assignment, AdaDNE, EdgeCutLDG, Hash1D, Partitioner};
use glisp::sampling::{balanced_seeds, sample_tree, SampleConfig, SamplingService};
use glisp::util::rng::Rng;
use glisp::util::timer::Timer;

const FANOUTS: [usize; 3] = [15, 10, 5];

/// Returns (wall seeds/s, simulated-distributed seeds/s). The simulated
/// number divides by the *busiest server's* serving time — on this 1-core
/// testbed all P servers timeshare one CPU, so wall-clock cannot reward
/// balance; in the paper's deployment the P servers run in parallel and
/// the bottleneck server gates throughput (DESIGN.md §3).
fn run_stack(
    g: &Graph,
    svc: &SamplingService,
    mut client: glisp::sampling::SamplingClient,
    weighted: bool,
    batches: usize,
) -> (f64, f64) {
    let _ = g;
    let mut rng = Rng::new(7);
    let cfg = SampleConfig {
        weighted,
        ..Default::default()
    };
    // warmup
    let seeds = balanced_seeds(svc, 8, &mut rng);
    sample_tree(&mut client, &seeds, &FANOUTS, &cfg).unwrap();
    svc.reset_stats();
    let timer = Timer::start();
    let mut seeds_done = 0usize;
    for _ in 0..batches {
        let seeds = balanced_seeds(svc, 64 / svc.partitions.len().max(1), &mut rng);
        seeds_done += seeds.len();
        sample_tree(&mut client, &seeds, &FANOUTS, &cfg).unwrap();
    }
    let wall = timer.secs();
    let client_secs = wall - svc.busy_secs().iter().sum::<f64>();
    let makespan = svc
        .busy_secs()
        .into_iter()
        .fold(0f64, f64::max)
        + client_secs.max(0.0);
    (seeds_done as f64 / wall, seeds_done as f64 / makespan.max(1e-9))
}

fn main() {
    println!("== Fig. 9 — sampling throughput (seeds/s), fanouts {FANOUTS:?} ==");
    let parts = 4;
    let batches = std::env::var("GLISP_BENCH_BATCHES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(30);
    for spec in bench_datasets() {
        let g = load(&spec, 1);
        let mut t = Table::new(
            &format!("{} × {parts} servers (sim = distributed makespan)", spec.name),
            &["framework", "uniform sim", "uniform wall", "weighted sim", "weighted wall"],
        );
        // GLISP
        let ea = AdaDNE::default().partition(&g, parts, 1);
        let svc = SamplingService::launch(&g, &ea, 1);
        let uni = run_stack(&g, &svc, svc.client(2), false, batches);
        let wei = run_stack(&g, &svc, svc.client(3), true, batches);
        t.row(&["GLISP (AdaDNE+GA)".into(), f2(uni.1), f2(uni.0), f2(wei.1), f2(wei.0)]);
        svc.shutdown();
        // DistDGL-like
        let va = EdgeCutLDG::default().partition_vertices(&g, parts, 1);
        let owner = std::sync::Arc::new(va.part_of_vertex.clone());
        let ea = edge_cut_to_assignment(&g, &va);
        let svc = SamplingService::launch(&g, &ea, 1);
        let uni = run_stack(&g, &svc, svc.owner_client(owner.clone(), 2), false, batches);
        let wei = run_stack(&g, &svc, svc.owner_client(owner, 3), true, batches);
        t.row(&["DistDGL-like (edge-cut)".into(), f2(uni.1), f2(uni.0), f2(wei.1), f2(wei.0)]);
        svc.shutdown();
        // GraphLearn-like (1D hash, owner = hash of src)
        let ea = Hash1D.partition(&g, parts, 1);
        // 1D hash = all out-edges of v on one server; that server is the owner.
        let owner: Vec<u16> = {
            let mut o = vec![0u16; g.n];
            for u in 0..g.n {
                let (a, b) = g.edge_range(u as u32);
                if b > a {
                    o[u] = ea.part_of_edge[a];
                }
            }
            o
        };
        let svc = SamplingService::launch(&g, &ea, 1);
        let owner = std::sync::Arc::new(owner);
        let uni = run_stack(&g, &svc, svc.owner_client(owner.clone(), 2), false, batches);
        let wei = run_stack(&g, &svc, svc.owner_client(owner, 3), true, batches);
        t.row(&["GraphLearn-like (hash)".into(), f2(uni.1), f2(uni.0), f2(wei.1), f2(wei.0)]);
        svc.shutdown();
        t.print();
    }
    println!("\npaper Fig. 9: GLISP fastest everywhere, and more so for weighted");
    println!("sampling, where workload imbalance is amplified by the heavier op.");
    println!("'sim' divides by max per-server busy time + client time (servers run");
    println!("in parallel in the paper's deployment); 'wall' is single-core wall");
    println!("clock, which cannot reward load balance and is shown for honesty.");
}
