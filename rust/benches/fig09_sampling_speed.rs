//! Fig. 9: subgraph sampling throughput, uniform and weighted, GLISP
//! (AdaDNE + Gather-Apply replica routing) vs the DistDGL-like baseline
//! (edge-cut + owner routing) vs the GraphLearn-like baseline (1D-hash +
//! owner routing). Fanouts [15, 10, 5], balanced seeds (paper §IV-C).
//!
//! The `1w`/`4w` column pairs run the identical workload against a
//! 1-worker and a 4-worker sampling pool per partition (shard size
//! POOL_SHARD, DESIGN.md §9); per-seed RNG streams make the sampled trees
//! bit-identical, so the pair isolates the pool's wall-clock win.
//!
//! A final `wire_transport` table reruns the pooled GLISP row over the
//! in-process channel, a TCP loopback socket, and a Unix domain socket
//! (DESIGN.md §12), asserting a witness tree is bit-identical across all
//! three transports (`wire_bits_identical` in BENCH_fig09*.json).

use glisp::graph::{build_partitions, Graph};
use glisp::harness::workloads::{bench_datasets, load};
use glisp::harness::{BenchRecorder, BenchTable, Cell};
use glisp::partition::{
    edge_cut_to_assignment, AdaDNE, EdgeAssignment, EdgeCutLDG, Hash1D, Partitioner,
};
use glisp::sampling::{
    balanced_seeds, sample_tree, SampleConfig, SamplingClient, SamplingService, ServiceConfig,
};
use glisp::util::rng::Rng;
use glisp::util::timer::Timer;

const FANOUTS: [usize; 3] = [15, 10, 5];
const POOL_WORKERS: usize = 4;
const POOL_SHARD: usize = 16;

/// Returns (wall seeds/s, simulated-distributed seeds/s). The simulated
/// number divides by the *busiest server's* serving time — on this 1-core
/// testbed all P servers timeshare one CPU, so wall-clock cannot reward
/// balance; in the paper's deployment the P servers run in parallel and
/// the bottleneck server gates throughput (DESIGN.md §3).
fn run_stack(
    svc: &SamplingService,
    mut client: SamplingClient,
    weighted: bool,
    batches: usize,
) -> (f64, f64) {
    let mut rng = Rng::new(7);
    let cfg = SampleConfig {
        weighted,
        ..Default::default()
    };
    // warmup
    let seeds = balanced_seeds(svc, 8, &mut rng);
    sample_tree(&mut client, &seeds, &FANOUTS, &cfg).unwrap();
    svc.reset_stats().unwrap();
    let timer = Timer::start();
    let mut seeds_done = 0usize;
    for _ in 0..batches {
        let seeds = balanced_seeds(svc, 64 / svc.num_partitions().max(1), &mut rng);
        seeds_done += seeds.len();
        sample_tree(&mut client, &seeds, &FANOUTS, &cfg).unwrap();
    }
    let wall = timer.secs();
    let busy = svc.busy_secs().unwrap();
    let client_secs = wall - busy.iter().sum::<f64>();
    let makespan = busy.into_iter().fold(0f64, f64::max) + client_secs.max(0.0);
    (seeds_done as f64 / wall, seeds_done as f64 / makespan.max(1e-9))
}

/// One framework row: the same (assignment, routing) measured against a
/// 1-worker service and a POOL_WORKERS pool with sharded gathers.
fn framework_row(
    name: &str,
    g: &Graph,
    ea: &EdgeAssignment,
    owner: Option<std::sync::Arc<Vec<u16>>>,
    batches: usize,
    t: &mut BenchTable,
) {
    // Build the compact partition structures ONCE per framework; each
    // (weighted × workers) cell launches from a memcpy clone instead of
    // re-running the full partition assembly four times.
    let parts = build_partitions(g, &ea.part_of_edge, ea.num_parts).unwrap();
    let mut cells = vec![Cell::str(name)];
    for weighted in [false, true] {
        for (workers, shard) in [(1usize, 0usize), (POOL_WORKERS, POOL_SHARD)] {
            let svc = SamplingService::launch_with_partitions_cfg(
                g.n,
                parts.clone(),
                1,
                ServiceConfig::new(workers, shard),
            );
            let client = match &owner {
                None => svc.client(2),
                Some(o) => svc.owner_client(o.clone(), 2),
            };
            let (wall, sim) = run_stack(&svc, client, weighted, batches);
            if workers == 1 {
                // The simulated-distributed number is a balance metric;
                // one column (1-worker) suffices.
                cells.push(Cell::f2(sim));
            }
            cells.push(Cell::f2(wall));
            svc.shutdown();
        }
    }
    t.row(cells);
}

fn main() -> anyhow::Result<()> {
    println!("== Fig. 9 — sampling throughput (seeds/s), fanouts {FANOUTS:?} ==");
    let parts = 4;
    let batches = std::env::var("GLISP_BENCH_BATCHES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(30);
    let mut rec = BenchRecorder::new("fig09_sampling_speed");
    rec.config_usize("parts", parts)
        .config_usize("batches", batches)
        .config_str("fanouts", "15,10,5")
        .config_usize("pool_workers", POOL_WORKERS)
        .config_usize("pool_shard", POOL_SHARD);
    for spec in bench_datasets() {
        let g = load(&spec, 1);
        let mut t = BenchTable::new(
            spec.name,
            &format!(
                "{} × {parts} servers (sim = distributed makespan; \
                 4w = {POOL_WORKERS}-worker pool, shard {POOL_SHARD})",
                spec.name
            ),
            &[
                "framework",
                "uni sim",
                "uni wall 1w",
                "uni wall 4w",
                "wei sim",
                "wei wall 1w",
                "wei wall 4w",
            ],
        );
        t.param_str("dataset", spec.name);
        // GLISP
        let ea = AdaDNE::default().partition(&g, parts, 1);
        framework_row("GLISP (AdaDNE+GA)", &g, &ea, None, batches, &mut t);
        // DistDGL-like
        let va = EdgeCutLDG::default().partition_vertices(&g, parts, 1);
        let owner = std::sync::Arc::new(va.part_of_vertex.clone());
        let ea = edge_cut_to_assignment(&g, &va);
        framework_row("DistDGL-like (edge-cut)", &g, &ea, Some(owner), batches, &mut t);
        // GraphLearn-like (1D hash, owner = hash of src)
        let ea = Hash1D.partition(&g, parts, 1);
        // 1D hash = all out-edges of v on one server; that server is the owner.
        let owner: Vec<u16> = {
            let mut o = vec![0u16; g.n];
            for u in 0..g.n {
                let (a, b) = g.edge_range(u as u32);
                if b > a {
                    o[u] = ea.part_of_edge[a];
                }
            }
            o
        };
        let owner = std::sync::Arc::new(owner);
        framework_row("GraphLearn-like (hash)", &g, &ea, Some(owner), batches, &mut t);
        rec.table(&t);
    }

    // == Wire-transport rows (DESIGN.md §12): the identical pooled GLISP
    // workload served over the in-process channel, a TCP loopback socket,
    // and a Unix domain socket. Timing may differ (syscalls + frame
    // codec); the sampled bits must not — per-seed RNG streams are keyed
    // on (partition seed, salt, seed index) only, so the recorder check
    // asserts a shared witness tree is bit-identical across transports.
    {
        let spec = &bench_datasets()[0];
        let g = load(spec, 1);
        let ea = AdaDNE::default().partition(&g, parts, 1);
        let cfg = ServiceConfig::new(POOL_WORKERS, POOL_SHARD);
        let mut t = BenchTable::new(
            "wire_transport",
            &format!(
                "{} × {parts} servers per transport ({POOL_WORKERS}-worker pools, shard {POOL_SHARD})",
                spec.name
            ),
            &["transport", "uni wall", "wei wall"],
        );
        t.param_str("dataset", spec.name);
        let mut trees: Vec<Vec<u32>> = Vec::new();
        for transport in ["channel", "tcp", "unix"] {
            let (svc, servers) = match transport {
                "channel" => (SamplingService::launch_cfg(&g, &ea, 1, cfg)?, Vec::new()),
                "tcp" => SamplingService::launch_remote(
                    &g,
                    &ea,
                    1,
                    cfg,
                    &vec!["tcp:127.0.0.1:0".to_string(); parts],
                )?,
                _ => {
                    let listens: Vec<String> = (0..parts)
                        .map(|p| {
                            let path =
                                std::env::temp_dir().join(format!("glisp_fig09_wire_{p}.sock"));
                            format!("unix:{}", path.display())
                        })
                        .collect();
                    SamplingService::launch_remote(&g, &ea, 1, cfg, &listens)?
                }
            };
            // Bit-equality witness: same seeds + same client seed on every
            // transport, flattened levels compared below.
            let mut wrng = Rng::new(99);
            let wseeds = balanced_seeds(&svc, 16, &mut wrng);
            let tree = sample_tree(
                &mut svc.client(11),
                &wseeds,
                &FANOUTS,
                &SampleConfig::default(),
            )
            .unwrap();
            trees.push(tree.levels.concat());
            let mut cells = vec![Cell::str(transport)];
            for weighted in [false, true] {
                let (wall, _) = run_stack(&svc, svc.client(2), weighted, batches);
                cells.push(Cell::f2(wall));
            }
            t.row(cells);
            svc.shutdown();
            for s in servers {
                s.join();
            }
        }
        let identical = trees.iter().all(|tr| *tr == trees[0]);
        rec.check(
            "wire_bits_identical",
            identical,
            "flattened sample_tree levels bit-equal across channel/tcp/unix transports",
        );
        assert!(identical, "wire transport changed sampled bits");
        rec.table(&t);
    }

    println!("\npaper Fig. 9: GLISP fastest everywhere, and more so for weighted");
    println!("sampling, where workload imbalance is amplified by the heavier op.");
    println!("'sim' divides by max per-server busy time + client time (servers run");
    println!("in parallel in the paper's deployment); 'wall' is single-core wall");
    println!("clock, which cannot reward load balance and is shown for honesty.");
    println!("'4w' reruns the same traffic against a {POOL_WORKERS}-worker pool per");
    println!("partition with sharded gathers — identical samples (per-seed RNG),");
    println!("higher wall throughput wherever spare cores exist.");
    rec.finish()?;
    Ok(())
}
