//! Table III: server memory footprint of the graph structure per
//! framework layout, measured on the same heterogeneous graph loaded
//! unpartitioned (the paper's protocol). GLISP is measured from the real
//! compact structure; the others are byte-accounting models of the
//! documented layouts (graph::memfoot).

use glisp::graph::generator;
use glisp::graph::hetero::build_partitions;
use glisp::graph::memfoot;
use glisp::harness::{BenchRecorder, BenchTable, Cell};
use glisp::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    println!("== Table III — graph structure memory footprint (MB) ==");
    let mut rng = Rng::new(1);
    let scale = glisp::harness::workloads::bench_scale();
    let mut rec = BenchRecorder::new("table3_memory");
    let cases = [
        ("products-h", (12_000.0 * scale) as usize, (300_000.0 * scale) as usize, 2, 3),
        ("wiki-h", (45_000.0 * scale) as usize, (300_000.0 * scale) as usize, 3, 4),
        ("twitter-h", (21_000.0 * scale) as usize, (740_000.0 * scale) as usize, 2, 4),
        ("paper-h", (55_000.0 * scale) as usize, (800_000.0 * scale) as usize, 3, 5),
    ];
    let mut t = BenchTable::new(
        "memory",
        "memory footprint by layout (MB)",
        &[
            "dataset",
            "DistDGL-like",
            "GraphLearn-like",
            "Euler-like",
            "GLISP",
            "GLISP vs best other",
        ],
    );
    for (name, n, m, vt, et) in cases {
        let g = generator::heterogeneous_graph(n, m, vt, et, 2.1, &mut rng);
        let parts = build_partitions(&g, &vec![0u16; g.m()], 1).unwrap();
        let ours = memfoot::glisp_bytes(&parts) as f64 / 1e6;
        let dgl = memfoot::distdgl_like_bytes(&g) as f64 / 1e6;
        let gl = memfoot::graphlearn_like_bytes(&g) as f64 / 1e6;
        let euler = memfoot::euler_like_bytes(&g) as f64 / 1e6;
        let best_other = dgl.min(gl).min(euler);
        t.row(vec![
            Cell::str(name),
            Cell::f2(dgl),
            Cell::f2(gl),
            Cell::f2(euler),
            Cell::f2(ours),
            Cell::x(best_other / ours),
        ]);
    }
    rec.table(&t);
    println!("\npaper Table III: GLISP has the smallest footprint on all datasets");
    println!("(e.g. OGBN-Products 0.6 GB vs DistDGL 2.0 GB vs GraphLearn 5.5 GB).");
    rec.finish()?;
    Ok(())
}
