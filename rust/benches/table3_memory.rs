//! Table III: server memory footprint of the graph structure per
//! framework layout, measured on the same heterogeneous graph loaded
//! unpartitioned (the paper's protocol). GLISP is measured from the real
//! compact structure; the others are byte-accounting models of the
//! documented layouts (graph::memfoot). A second table measures the
//! out-of-core seam: the same structures saved and re-opened through the
//! heap vs mmap backends (DESIGN.md §13) — the mapped rows show where the
//! bytes live, not a model.

use glisp::graph::generator;
use glisp::graph::hetero::build_partitions;
use glisp::graph::memfoot;
use glisp::graph::store::{open_partitions, StoreBackend};
use glisp::harness::{BenchRecorder, BenchTable, Cell};
use glisp::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    println!("== Table III — graph structure memory footprint (MB) ==");
    let mut rng = Rng::new(1);
    let scale = glisp::harness::workloads::bench_scale();
    let mut rec = BenchRecorder::new("table3_memory");
    let cases = [
        ("products-h", (12_000.0 * scale) as usize, (300_000.0 * scale) as usize, 2, 3),
        ("wiki-h", (45_000.0 * scale) as usize, (300_000.0 * scale) as usize, 3, 4),
        ("twitter-h", (21_000.0 * scale) as usize, (740_000.0 * scale) as usize, 2, 4),
        ("paper-h", (55_000.0 * scale) as usize, (800_000.0 * scale) as usize, 3, 5),
    ];
    let mut t = BenchTable::new(
        "memory",
        "memory footprint by layout (MB)",
        &[
            "dataset",
            "DistDGL-like",
            "GraphLearn-like",
            "Euler-like",
            "GLISP",
            "GLISP vs best other",
        ],
    );
    let mut oc = BenchTable::new(
        "out_of_core",
        "measured residency by storage backend (MB)",
        &[
            "dataset",
            "heap resident",
            "mmap heap resident",
            "mmap file-backed",
        ],
    );
    let mut mmap_heap_total = 0usize;
    for (name, n, m, vt, et) in cases {
        let g = generator::heterogeneous_graph(n, m, vt, et, 2.1, &mut rng);
        let parts = build_partitions(&g, &vec![0u16; g.m()], 1).unwrap();
        let ours = memfoot::glisp_bytes(&parts) as f64 / 1e6;
        let dgl = memfoot::distdgl_like_bytes(&g) as f64 / 1e6;
        let gl = memfoot::graphlearn_like_bytes(&g) as f64 / 1e6;
        let euler = memfoot::euler_like_bytes(&g) as f64 / 1e6;
        let best_other = dgl.min(gl).min(euler);
        t.row(vec![
            Cell::str(name),
            Cell::f2(dgl),
            Cell::f2(gl),
            Cell::f2(euler),
            Cell::f2(ours),
            Cell::x(best_other / ours),
        ]);

        // Out-of-core seam: save the structure, re-open through both
        // backends, report MEASURED residency (not a model).
        let dir = std::env::temp_dir().join(format!("glisp_t3m_{name}"));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir)?;
        for p in &parts {
            glisp::graph::io::save_partition(p, &dir, &format!("part{}", p.part_id))?;
        }
        let heap = memfoot::partition_residency(&open_partitions(&dir, StoreBackend::Heap)?);
        let mapped = memfoot::partition_residency(&open_partitions(&dir, StoreBackend::Mmap)?);
        mmap_heap_total += mapped.heap_bytes;
        oc.row(vec![
            Cell::str(name),
            Cell::f2(heap.heap_bytes as f64 / 1e6),
            Cell::f2(mapped.heap_bytes as f64 / 1e6),
            Cell::f2(mapped.mapped_bytes as f64 / 1e6),
        ]);
        let _ = std::fs::remove_dir_all(&dir);
    }
    rec.table(&t);
    rec.table(&oc);
    rec.check(
        "mmap_heap_resident_zero",
        mmap_heap_total == 0,
        &format!("mmap-backed structures keep {mmap_heap_total} bytes on the heap"),
    );
    println!("\npaper Table III: GLISP has the smallest footprint on all datasets");
    println!("(e.g. OGBN-Products 0.6 GB vs DistDGL 2.0 GB vs GraphLearn 5.5 GB).");
    rec.finish()?;
    Ok(())
}
