//! Loopback multi-process integration tests (DESIGN.md §12): partition
//! servers behind real sockets must be observably identical — bit for bit
//! — to the in-process channel pool, because the wire carries only
//! (seeds, salt, seed_offset, config) and every sampled value is drawn
//! from a per-seed RNG stream the transport never touches.
//!
//! The listeners here run as in-test threads (`serve_partition` is exactly
//! what `glisp serve` wraps); the CI wire job repeats the same assertion
//! with genuine separate processes by diffing digest lines.

use glisp::coordinator::PipelineConfig;
use glisp::graph::generator;
use glisp::harness::workloads::{train_stack_cfg, train_stack_connect, train_stack_graph};
use glisp::harness::workloads::stack_partitioner;
use glisp::partition::{AdaDNE, Partitioner};
use glisp::sampling::{
    balanced_seeds, sample_tree, SampleConfig, SamplingService, ServiceConfig,
};
use glisp::util::rng::Rng;

/// The ISSUE acceptance geometry: 4 partitions, each behind a 4-worker
/// pool serving 16-seed shards, over TCP loopback. Uniform, weighted and
/// edge-type-restricted one-hop sampling, the multi-level tree sampler,
/// and the (deterministic) stats counters must all match the in-process
/// reference exactly.
#[test]
fn four_server_tcp_fleet_matches_in_process_bit_for_bit() {
    let mut rng = Rng::new(500);
    let g = generator::heterogeneous_graph(900, 10_000, 2, 3, 2.2, &mut rng);
    let parts = 4;
    let ea = AdaDNE::default().partition(&g, parts, 1);
    let cfg = ServiceConfig::new(4, 16);

    let local = SamplingService::launch_cfg(&g, &ea, 1, cfg).unwrap();
    let (remote, servers) = SamplingService::launch_remote(
        &g,
        &ea,
        1,
        cfg,
        &vec!["tcp:127.0.0.1:0".to_string(); parts],
    )
    .unwrap();
    assert_eq!(remote.num_partitions(), parts);

    // One-hop matrix: uniform, weighted, single-edge-type.
    let configs = [
        SampleConfig::default(),
        SampleConfig {
            weighted: true,
            ..Default::default()
        },
        SampleConfig {
            etype: Some(1),
            ..Default::default()
        },
    ];
    for (k, scfg) in configs.iter().enumerate() {
        let mut lrng = Rng::new(900 + k as u64);
        let mut rrng = Rng::new(900 + k as u64);
        let lseeds = balanced_seeds(&local, 12, &mut lrng);
        let rseeds = balanced_seeds(&remote, 12, &mut rrng);
        assert_eq!(lseeds, rseeds, "membership must round-trip the Members RPC");
        let want = local.client(21 + k as u64).sample_one_hop(&lseeds, 7, scfg).unwrap();
        let got = remote.client(21 + k as u64).sample_one_hop(&rseeds, 7, scfg).unwrap();
        assert_eq!(got.offsets, want.offsets, "config {k}: offsets drifted over the wire");
        assert_eq!(got.neighbors, want.neighbors, "config {k}: neighbors drifted over the wire");
    }

    // Multi-level tree.
    let mut lrng = Rng::new(950);
    let mut rrng = Rng::new(950);
    let lseeds = balanced_seeds(&local, 16, &mut lrng);
    let rseeds = balanced_seeds(&remote, 16, &mut rrng);
    let want = sample_tree(&mut local.client(5), &lseeds, &[6, 4], &SampleConfig::default()).unwrap();
    let got = sample_tree(&mut remote.client(5), &rseeds, &[6, 4], &SampleConfig::default()).unwrap();
    assert_eq!(got.levels, want.levels);
    assert_eq!(got.masks, want.masks);

    // The traffic above was symmetric, so every *deterministic* counter
    // must agree (busy_ns is wall time and excluded).
    let ls = local.stats_snapshots().unwrap();
    let rs = remote.stats_snapshots().unwrap();
    for (l, r) in ls.iter().zip(&rs) {
        assert_eq!((l.part_id, l.requests, l.seeds), (r.part_id, r.requests, r.seeds));
        assert_eq!(l.edges_scanned, r.edges_scanned);
        assert_eq!(l.neighbors_returned, r.neighbors_returned);
        assert_eq!(l.graph_bytes, r.graph_bytes);
    }

    local.shutdown();
    remote.shutdown();
    for s in servers {
        s.join();
    }
}

/// Short pipelined training run against a Unix-socket fleet: the loss
/// curve must replay the in-process run bit-for-bit (ordered pipeline,
/// per-seed sampling streams, transport-independent trainer RNG).
#[test]
fn unix_socket_pipelined_training_replays_in_process_losses() {
    let art = glisp::test_artifacts_dir();
    let n = 3000;
    let parts = 2;
    let steps = 6;
    let pcfg = PipelineConfig {
        producers: 2,
        queue_depth: 2,
        ordered: true,
    };

    // In-process reference.
    let stack = train_stack_cfg(n, parts, "sage", &art, ServiceConfig::new(1, 16)).unwrap();
    let mut trainer = stack.trainer;
    let mut batcher = stack.batcher;
    let want = trainer.train_pipelined(&mut batcher, steps, &pcfg).unwrap();
    drop(trainer);
    stack.service.shutdown();

    // The same stack behind Unix-socket partition servers.
    let (g, _labels) = train_stack_graph(n);
    let ea = stack_partitioner().partition(&g, parts, 1);
    let listens: Vec<String> = (0..parts)
        .map(|p| {
            let path = std::env::temp_dir().join(format!("glisp_wire_train_{p}.sock"));
            let _ = std::fs::remove_file(&path);
            format!("unix:{}", path.display())
        })
        .collect();
    let (svc, servers) =
        SamplingService::launch_remote(&g, &ea, 1, ServiceConfig::new(1, 16), &listens).unwrap();
    let addrs: Vec<String> = servers.iter().map(|s| s.addr().to_string()).collect();
    // Drop the bootstrap connection; train over a fresh one exactly the way
    // a separate client process would join the fleet.
    svc.disconnect();

    let stack = train_stack_connect(n, "sage", &art, &addrs, 16).unwrap();
    let mut trainer = stack.trainer;
    let mut batcher = stack.batcher;
    let got = trainer.train_pipelined(&mut batcher, steps, &pcfg).unwrap();
    drop(trainer);
    stack.service.shutdown();
    for s in servers {
        s.join();
    }

    let want_bits: Vec<u32> = want.iter().map(|x| x.to_bits()).collect();
    let got_bits: Vec<u32> = got.iter().map(|x| x.to_bits()).collect();
    assert_eq!(got_bits, want_bits, "losses must be bit-identical across the wire");
}
