//! Property tests over the Gather-Apply sampling service: routing
//! totality, fanout bounds, edge fidelity, tree-shape invariants, and the
//! uniform/weighted statistics contracts.

use glisp::graph::csr::{Graph, VId};
use glisp::graph::generator;
use glisp::partition::{AdaDNE, Partitioner};
use glisp::sampling::{
    balanced_seeds, sample_tree, SampleConfig, SamplingService, PAD,
};
use glisp::util::proptest::prop_check;
use glisp::util::rng::Rng;
use glisp::{prop_assert, prop_assert_eq};

fn arbitrary_powerlaw(rng: &mut Rng) -> Graph {
    let n = rng.range(200, 1200);
    let m = rng.range(n * 2, n * 10);
    generator::chung_lu(n, m, 1.9 + rng.f64() * 0.6, rng)
}

#[test]
fn tree_shapes_and_masks_are_consistent() {
    prop_check("tree shape", 10, |rng| {
        let g = arbitrary_powerlaw(rng);
        let parts = rng.range(2, 5);
        let ea = AdaDNE::default().partition(&g, parts, rng.next_u64());
        let svc = SamplingService::launch(&g, &ea, rng.next_u64()).unwrap();
        let mut client = svc.client(rng.next_u64());
        let hops = rng.range(1, 4);
        let fanouts: Vec<usize> = (0..hops).map(|_| rng.range(2, 8)).collect();
        let seeds = balanced_seeds(&svc, 4, rng);
        let t = sample_tree(&mut client, &seeds, &fanouts, &SampleConfig::default())
            .expect("sampling failed");
        // Level sizes multiply by fanouts.
        let mut expect = seeds.len();
        prop_assert_eq!(t.levels[0].len(), expect);
        for (k, &f) in fanouts.iter().enumerate() {
            expect *= f;
            prop_assert_eq!(t.levels[k + 1].len(), expect);
            prop_assert_eq!(t.masks[k].len(), expect);
            for (v, m) in t.levels[k + 1].iter().zip(&t.masks[k]) {
                prop_assert!((*v == PAD) == (*m == 0.0), "mask/PAD inconsistent");
            }
        }
        // Padding parents never have real children.
        for k in 1..t.levels.len() - 1 {
            let f = fanouts[k];
            for (i, &p) in t.levels[k].iter().enumerate() {
                if p == PAD {
                    for s in 0..f {
                        prop_assert_eq!(t.levels[k + 1][i * f + s], PAD);
                    }
                }
            }
        }
        svc.shutdown();
        Ok(())
    });
}

#[test]
fn sampled_children_are_true_neighbors() {
    prop_check("edge fidelity", 10, |rng| {
        let g = arbitrary_powerlaw(rng);
        let parts = rng.range(2, 5);
        let ea = AdaDNE::default().partition(&g, parts, rng.next_u64());
        let svc = SamplingService::launch(&g, &ea, rng.next_u64()).unwrap();
        for weighted in [false, true] {
            let mut client = svc.client(rng.next_u64());
            let seeds = balanced_seeds(&svc, 8, rng);
            let cfg = SampleConfig {
                weighted,
                ..Default::default()
            };
            let f = rng.range(2, 7);
            let t = sample_tree(&mut client, &seeds, &[f], &cfg).expect("sampling failed");
            for (i, &p) in t.levels[0].iter().enumerate() {
                for s in 0..f {
                    let c = t.levels[1][i * f + s];
                    if c != PAD {
                        prop_assert!(
                            g.out_neighbors(p).contains(&c),
                            "sampled {c} not a neighbor of {p} (weighted={weighted})"
                        );
                    }
                }
            }
        }
        svc.shutdown();
        Ok(())
    });
}

#[test]
fn full_neighborhood_when_fanout_exceeds_degree() {
    prop_check("exhaustive small-degree", 8, |rng| {
        // Fanout far above max degree: every real neighbor must appear.
        let n = rng.range(100, 400);
        let g = generator::erdos_renyi(n, n * 2, rng);
        let ea = AdaDNE::default().partition(&g, 2, rng.next_u64());
        let svc = SamplingService::launch(&g, &ea, rng.next_u64()).unwrap();
        let mut client = svc.client(rng.next_u64());
        let seeds: Vec<VId> = (0..16.min(n as u32)).collect();
        let f = 64;
        let t = sample_tree(&mut client, &seeds, &[f], &SampleConfig::default())
            .expect("sampling failed");
        for (i, &p) in t.levels[0].iter().enumerate() {
            let mut got: Vec<VId> = (0..f)
                .map(|s| t.levels[1][i * f + s])
                .filter(|&v| v != PAD)
                .collect();
            let mut want: Vec<VId> = g.out_neighbors(p).to_vec();
            got.sort_unstable();
            want.sort_unstable();
            prop_assert_eq!(got, want);
        }
        svc.shutdown();
        Ok(())
    });
}

#[test]
fn uniform_sampling_is_unbiased_across_partitions() {
    // A hub whose neighbors straddle partitions must still sample each
    // neighbor with equal probability (the r = f·local/global contract).
    prop_check("uniform marginals", 3, |rng| {
        let deg = 40usize;
        let mut edges = Vec::new();
        for i in 0..deg {
            edges.push((0 as VId, (i + 1) as VId));
        }
        // Filler edges so partitions are non-trivial.
        for i in 1..=deg {
            edges.push((i as VId, ((i % deg) + 1) as VId));
        }
        let g = Graph::from_edges(deg + 1, &edges);
        let ea = AdaDNE::default().partition(&g, 3, rng.next_u64());
        let svc = SamplingService::launch(&g, &ea, rng.next_u64()).unwrap();
        let mut client = svc.client(rng.next_u64());
        let f = 8;
        let trials = 3000;
        let mut counts = vec![0usize; deg + 1];
        for _ in 0..trials {
            let t = sample_tree(&mut client, &[0], &[f], &SampleConfig::default())
                .expect("sampling failed");
            for s in 0..f {
                let c = t.levels[1][s];
                if c != PAD {
                    counts[c as usize] += 1;
                }
            }
        }
        let total: usize = counts.iter().sum();
        let expected = total as f64 / deg as f64;
        for (v, &c) in counts.iter().enumerate().skip(1) {
            prop_assert!(
                (c as f64 - expected).abs() < expected * 0.35,
                "neighbor {v} sampled {c} times vs expected {expected:.0}"
            );
        }
        svc.shutdown();
        Ok(())
    });
}

#[test]
fn weighted_sampling_prefers_heavy_edges() {
    prop_check("weight preference", 3, |rng| {
        // Star with one heavy edge (weight 50) and 19 light ones (1).
        let deg = 20;
        let mut edges: Vec<(VId, VId, u8, f32)> = (0..deg)
            .map(|i| (0, (i + 1) as VId, 0, 1.0f32))
            .collect();
        edges[0].3 = 50.0;
        for i in 1..=deg {
            edges.push((i as VId, ((i % deg) + 1) as VId, 0, 1.0));
        }
        let g = Graph::from_typed_edges(deg + 1, &edges);
        let ea = AdaDNE::default().partition(&g, 2, rng.next_u64());
        let svc = SamplingService::launch(&g, &ea, rng.next_u64()).unwrap();
        let mut client = svc.client(rng.next_u64());
        let cfg = SampleConfig {
            weighted: true,
            ..Default::default()
        };
        let trials = 800;
        let mut heavy = 0usize;
        for _ in 0..trials {
            let t = sample_tree(&mut client, &[0], &[1], &cfg).expect("sampling failed");
            if t.levels[1][0] == 1 {
                heavy += 1;
            }
        }
        // P(heavy picked as the single sample) = 50/69 ≈ 0.725.
        let frac = heavy as f64 / trials as f64;
        prop_assert!(
            (frac - 50.0 / 69.0).abs() < 0.08,
            "heavy edge sampled at rate {frac:.3}, expected ~0.725"
        );
        svc.shutdown();
        Ok(())
    });
}

#[test]
fn workload_spreads_under_replica_routing() {
    prop_check("workload spread", 5, |rng| {
        let g = arbitrary_powerlaw(rng);
        let parts = 4;
        let ea = AdaDNE::default().partition(&g, parts, rng.next_u64());
        let svc = SamplingService::launch(&g, &ea, rng.next_u64()).unwrap();
        let mut client = svc.client(rng.next_u64());
        for _ in 0..10 {
            let seeds = balanced_seeds(&svc, 16, rng);
            sample_tree(&mut client, &seeds, &[10, 5], &SampleConfig::default())
                .expect("sampling failed");
        }
        let wl = svc.workload().expect("stats snapshot failed");
        prop_assert!(wl.iter().all(|&w| w > 0), "an idle server: {wl:?}");
        svc.shutdown();
        Ok(())
    });
}
