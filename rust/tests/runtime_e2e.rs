//! Full-stack integration tests: training convergence, eval parity, and
//! the layerwise-vs-samplewise numerical equivalence that anchors the
//! inference engine's correctness.
//!
//! The tests run against whatever backend `Runtime::load` selects — the
//! hermetic reference backend when `make artifacts` has not run, PJRT/XLA
//! over the AOT artifacts when it has (with the `pjrt` feature).

use std::sync::Arc;

use glisp::coordinator::{Batcher, FeatureStore, Trainer, TrainerConfig};
use glisp::graph::generator;
use glisp::inference::{
    init_decode_params, init_encoder_params, EngineConfig, LayerwiseEngine, SamplewiseRunner,
};
use glisp::partition::{AdaDNE, Partitioner};
use glisp::runtime::Runtime;
use glisp::sampling::SamplingService;
use glisp::util::rng::Rng;

#[test]
fn training_converges_for_all_three_models() {
    let art = glisp::test_artifacts_dir();
    let mut rng = Rng::new(400);
    let n = 3000;
    let g = generator::labeled_community_graph(n, n * 12, 8, 0.9, &mut rng);
    let labels = Arc::new(g.label.clone());
    let ea = AdaDNE::default().partition(&g, 2, 1);
    let svc = SamplingService::launch(&g, &ea, 1).unwrap();
    for model in ["gcn", "sage", "gat"] {
        let features = FeatureStore::labeled(64, labels.clone(), 8, 0.6);
        let lr = if model == "sage" { 0.1 } else { 0.4 };
        let mut trainer = Trainer::new(
            &art,
            svc.client(2),
            features,
            TrainerConfig { model: model.into(), lr },
            7,
        )
        .unwrap();
        let seeds: Vec<u32> = (0..2000).collect();
        let lab: Vec<u16> = seeds.iter().map(|&v| labels[v as usize]).collect();
        let mut batcher = Batcher::new(seeds, lab, trainer.batch, 5).unwrap();
        let losses = trainer.train(&mut batcher, 25).unwrap();
        let head: f32 = losses[..5].iter().sum::<f32>() / 5.0;
        let tail: f32 = losses[20..].iter().sum::<f32>() / 5.0;
        assert!(
            tail < head,
            "{model}: loss did not fall (head {head:.3}, tail {tail:.3})"
        );
    }
    svc.shutdown();
}

#[test]
fn trained_model_beats_chance_on_held_out_vertices() {
    let art = glisp::test_artifacts_dir();
    let mut rng = Rng::new(401);
    let n = 4000;
    let classes = 8;
    let g = generator::labeled_community_graph(n, n * 12, classes, 0.9, &mut rng);
    let labels = Arc::new(g.label.clone());
    let ea = AdaDNE::default().partition(&g, 2, 1);
    let svc = SamplingService::launch(&g, &ea, 1).unwrap();
    let features = FeatureStore::labeled(64, labels.clone(), classes, 0.6);
    let mut trainer = Trainer::new(
        &art,
        svc.client(2),
        features,
        TrainerConfig { model: "sage".into(), lr: 0.1 },
        7,
    )
    .unwrap();
    let split = 3200;
    let seeds: Vec<u32> = (0..split).collect();
    let lab: Vec<u16> = seeds.iter().map(|&v| labels[v as usize]).collect();
    let mut batcher = Batcher::new(seeds, lab, trainer.batch, 5).unwrap();
    trainer.train(&mut batcher, 60).unwrap();
    let test: Vec<u32> = (split..n as u32).collect();
    let test_lab: Vec<u16> = test.iter().map(|&v| labels[v as usize]).collect();
    let acc = trainer.evaluate(&test, &test_lab).unwrap();
    assert!(
        acc > 2.0 / classes as f64,
        "accuracy {acc:.3} not above 2x chance"
    );
    svc.shutdown();
}

#[test]
fn layerwise_equals_samplewise_on_full_neighborhoods() {
    // When every vertex's degree <= fanout, sampling is exhaustive and the
    // layerwise engine must reproduce samplewise embeddings EXACTLY (up to
    // f32 tolerance): the two paths compute the same GNN.
    let art = glisp::test_artifacts_dir();
    let mut rng = Rng::new(402);
    // Sparse ER graph: max out-degree stays < 10 (the artifact fanout).
    let n = 1024;
    let g = generator::erdos_renyi(n, 2 * n, &mut rng);
    let max_deg = (0..n).map(|v| g.out_degree(v as u32)).max().unwrap();
    assert!(max_deg <= 10, "test graph degree {max_deg} exceeds fanout");
    let ea = AdaDNE::default().partition(&g, 2, 1);

    let runtime = Runtime::load(&art).unwrap();
    let enc = init_encoder_params(&runtime, 3).unwrap();
    let dir = std::env::temp_dir().join("glisp_e2e_equiv");
    let _ = std::fs::remove_dir_all(&dir);
    let mut engine = LayerwiseEngine::new(
        &g,
        &ea,
        runtime,
        FeatureStore::unlabeled(64),
        enc.clone(),
        EngineConfig::default(),
        dir,
    )
    .unwrap();
    let (h_lw, _) = engine.run_vertex_embedding().unwrap();

    let mut sw = SamplewiseRunner::new(
        &g,
        Runtime::load(&art).unwrap(),
        FeatureStore::unlabeled(64),
        enc,
        5,
    )
    .unwrap();
    let (h_sw, _) = sw.run_vertex_embedding().unwrap();

    // h_lw is rank-indexed; h_sw is vertex-indexed.
    let hid = sw.hidden();
    let mut max_err = 0f32;
    for v in 0..n {
        let r = engine.rank[v] as usize;
        for d in 0..hid {
            let a = h_lw[r * hid + d];
            let b = h_sw[v * hid + d];
            max_err = max_err.max((a - b).abs());
        }
    }
    assert!(
        max_err < 1e-3,
        "layerwise and samplewise embeddings diverge: max err {max_err}"
    );
}

#[test]
fn link_scores_agree_between_paths_on_full_neighborhoods() {
    let art = glisp::test_artifacts_dir();
    let mut rng = Rng::new(403);
    let n = 512;
    let g = generator::erdos_renyi(n, n, &mut rng);
    if (0..n).map(|v| g.out_degree(v as u32)).max().unwrap() > 10 {
        return; // exhaustiveness precondition not met for this seed
    }
    let ea = AdaDNE::default().partition(&g, 2, 1);
    let runtime = Runtime::load(&art).unwrap();
    let enc = init_encoder_params(&runtime, 3).unwrap();
    let dir = std::env::temp_dir().join("glisp_e2e_link");
    let _ = std::fs::remove_dir_all(&dir);
    let mut engine = LayerwiseEngine::new(
        &g, &ea, runtime,
        FeatureStore::unlabeled(64),
        enc.clone(),
        EngineConfig::default(),
        dir,
    )
    .unwrap();
    let (h, _) = engine.run_vertex_embedding().unwrap();
    let dec = init_decode_params(&engine.runtime, 9).unwrap();
    let edges: Vec<(u32, u32)> = (0..n as u32)
        .filter(|&u| !g.out_neighbors(u).is_empty())
        .take(100)
        .map(|u| (u, g.out_neighbors(u)[0]))
        .collect();
    let (s_lw, _) = engine.run_link_prediction(&h, &edges, &dec).unwrap();

    let mut sw = SamplewiseRunner::new(
        &g,
        Runtime::load(&art).unwrap(),
        FeatureStore::unlabeled(64),
        enc,
        5,
    )
    .unwrap();
    let (s_sw, _) = sw.run_link_prediction(&edges, &dec).unwrap();
    for (i, (a, b)) in s_lw.iter().zip(&s_sw).enumerate() {
        assert!(
            (a - b).abs() < 1e-3,
            "edge {i}: layerwise {a} vs samplewise {b}"
        );
    }
}

#[test]
fn manifest_geometry_matches_trainer_expectations() {
    let art = glisp::test_artifacts_dir();
    let runtime = Runtime::load(&art).unwrap();
    for model in ["gcn", "sage", "gat"] {
        let spec = runtime.spec(&format!("{model}_train")).unwrap();
        let b = spec.meta_usize("batch").unwrap();
        let fanouts = spec.meta_usizes("fanouts").unwrap();
        let n_params = spec.meta_usize("n_params").unwrap();
        // level sizes
        let mut sizes = vec![b];
        for f in &fanouts {
            sizes.push(sizes.last().unwrap() * f);
        }
        // inputs: params + levels + masks + labels + lr
        assert_eq!(
            spec.inputs.len(),
            n_params + sizes.len() + fanouts.len() + 2,
            "{model} manifest arity"
        );
        // level feature shapes
        let din = spec.meta_usize("din").unwrap();
        for (k, &sz) in sizes.iter().enumerate() {
            assert_eq!(spec.inputs[n_params + k].shape, vec![sz, din]);
        }
        // outputs: loss + params, shapes mirrored
        assert_eq!(spec.outputs.len(), 1 + n_params);
        for i in 0..n_params {
            assert_eq!(spec.outputs[1 + i].shape, spec.inputs[i].shape);
        }
    }
}
