//! Property tests for the gather→tensor hot path (DESIGN.md §14): arena
//! reuse, client scratch reuse, block-scored A-ES and pooled assembly are
//! all *bit-transparent* — warm reused state must reproduce cold
//! fresh-allocation runs exactly, across `(workers, shard_size)` pool
//! geometries and channel/socket transports. Checks are FNV digests over
//! the exact little-endian bytes (props_store.rs style), so one flipped
//! bit anywhere in offsets, neighbors, wire scores, masks or losses fails
//! the property. Replay failures with GLISP_PROP_SEED.

use glisp::coordinator::PipelineConfig;
use glisp::graph::generator;
use glisp::graph::hetero::build_partitions;
use glisp::harness::workloads::train_stack_cfg;
use glisp::partition::{AdaDNE, Partitioner};
use glisp::prop_assert_eq;
use glisp::sampling::server::{PartitionServer, ServerStats};
use glisp::sampling::subgraph::TreeSample;
use glisp::sampling::{
    sample_tree, GatherRequest, GatherResponse, SampleConfig, SamplingClient, SamplingService,
    ServiceConfig,
};
use glisp::util::digest::{f32_digest, fnv1a};
use glisp::util::proptest::prop_check;
use glisp::util::rng::Rng;
use std::sync::Arc;

fn fold_resp(bytes: &mut Vec<u8>, r: &GatherResponse) {
    for x in &r.offsets {
        bytes.extend_from_slice(&x.to_le_bytes());
    }
    for x in &r.neighbors {
        bytes.extend_from_slice(&x.to_le_bytes());
    }
    for s in &r.scores {
        bytes.extend_from_slice(&s.to_le_bytes());
    }
}

fn fold_tree(bytes: &mut Vec<u8>, t: &TreeSample) {
    for lvl in &t.levels {
        for v in lvl {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
    }
    for m in &t.masks {
        for x in m {
            bytes.extend_from_slice(&x.to_le_bytes());
        }
    }
}

/// Warm server arenas (one `PartitionServer` reused across every request,
/// as pool workers run) must reproduce cold fresh-server gathers — offsets,
/// neighbors AND wire scores — bit-for-bit on arbitrary graphs.
#[test]
fn warm_gather_arena_bit_identical_to_cold_servers() {
    prop_check("warm gather arena bits", 6, |rng| {
        let n = rng.range(200, 700);
        let g = generator::heterogeneous_graph(n, n * 6, 2, 3, 2.2, rng);
        let parts = rng.range(1, 4);
        let ea = AdaDNE::default().partition(&g, parts, rng.next_u64());
        let built = build_partitions(&g, &ea.part_of_edge, parts).unwrap();
        let seed = rng.next_u64();
        for cfg in [
            SampleConfig::default(),
            SampleConfig {
                weighted: true,
                ..Default::default()
            },
        ] {
            for pg in &built {
                let pg = Arc::new(pg.clone());
                let mut reqs = Vec::new();
                for b in 0..5u64 {
                    // Duplicate-heavy seed lists to exercise the per-seed
                    // stream indexing under arena reuse.
                    let len = rng.range(4, 40);
                    let seeds: Vec<u32> = (0..len)
                        .map(|_| pg.global(rng.usize(pg.nv()) as u32))
                        .collect();
                    reqs.push(GatherRequest {
                        seeds,
                        fanout: rng.range(2, 9),
                        salt: rng.next_u64(),
                        cfg: cfg.clone(),
                        seed_offset: rng.usize(64) as u32,
                        token: b,
                    });
                }
                let mut warm_bytes = Vec::new();
                let mut srv =
                    PartitionServer::new(pg.clone(), Arc::new(ServerStats::default()), seed);
                for req in &reqs {
                    fold_resp(&mut warm_bytes, &srv.gather(req));
                }
                let mut cold_bytes = Vec::new();
                for req in &reqs {
                    let mut cold =
                        PartitionServer::new(pg.clone(), Arc::new(ServerStats::default()), seed);
                    fold_resp(&mut cold_bytes, &cold.gather(req));
                }
                prop_assert_eq!(fnv1a(&warm_bytes), fnv1a(&cold_bytes));
            }
        }
        Ok(())
    });
}

/// One digest per deployment shape — pool geometries (workers, shard) and
/// the TCP socket transport — over multi-batch K-hop trees sampled both
/// with a warm reused client (scratch carried across batches) and cold
/// per-batch clients. All digests must agree.
#[test]
fn hotpath_bits_invariant_across_geometries_and_transports() {
    prop_check("hotpath geometry/transport bits", 3, |rng| {
        let n = rng.range(300, 900);
        let g = generator::heterogeneous_graph(n, n * 8, 2, 3, 2.2, rng);
        let parts = rng.range(2, 4);
        let ea = AdaDNE::default().partition(&g, parts, 1);
        let fanouts = [rng.range(2, 7), rng.range(2, 5)];
        let batches: Vec<Vec<u32>> = (0..3)
            .map(|b| {
                (0..32)
                    .map(|i| ((b * 97 + i * 13) % g.n) as u32)
                    .collect()
            })
            .collect();

        let digest_of = |mut warm: SamplingClient,
                         fresh: &dyn Fn() -> SamplingClient|
         -> Result<u64, String> {
            let mut bytes = Vec::new();
            for cfg in [
                SampleConfig::default(),
                SampleConfig {
                    weighted: true,
                    ..Default::default()
                },
            ] {
                let mut warm_bytes = Vec::new();
                for (b, seeds) in batches.iter().enumerate() {
                    warm.rng = Rng::new(0xA11CE ^ b as u64);
                    let t = sample_tree(&mut warm, seeds, &fanouts, &cfg).unwrap();
                    fold_tree(&mut warm_bytes, &t);
                }
                // Cold clients: fresh scratch per batch, same RNG stream.
                let mut cold_bytes = Vec::new();
                for (b, seeds) in batches.iter().enumerate() {
                    let mut c = fresh();
                    c.rng = Rng::new(0xA11CE ^ b as u64);
                    let t = sample_tree(&mut c, seeds, &fanouts, &cfg).unwrap();
                    fold_tree(&mut cold_bytes, &t);
                }
                prop_assert_eq!(fnv1a(&warm_bytes), fnv1a(&cold_bytes));
                bytes.extend_from_slice(&warm_bytes);
            }
            Ok(fnv1a(&bytes))
        };

        let mut digests = Vec::new();
        for (workers, shard) in [(1usize, 0usize), (2, 16), (4, 7)] {
            let svc =
                SamplingService::launch_cfg(&g, &ea, 1, ServiceConfig::new(workers, shard))
                    .unwrap();
            digests.push(digest_of(svc.client(9), &|| svc.client(9))?);
            svc.shutdown();
        }
        let (svc, servers) = SamplingService::launch_remote(
            &g,
            &ea,
            1,
            ServiceConfig::new(2, 16),
            &vec!["tcp:127.0.0.1:0".to_string(); parts],
        )
        .unwrap();
        digests.push(digest_of(svc.client(9), &|| svc.client(9))?);
        svc.shutdown();
        for s in servers {
            s.join();
        }
        for d in &digests[1..] {
            prop_assert_eq!(*d, digests[0]);
        }
        Ok(())
    });
}

/// Golden-digest end-to-end check: the pipelined trainer — pooled tensor
/// assembly, client scratch reuse, warm server arenas, block-scored A-ES —
/// must reproduce the plain synchronous path's loss curve and parameters
/// bit-for-bit, compared as FNV digests over exact f32 bit patterns.
#[test]
fn golden_digest_pipelined_pooled_training_matches_sync() {
    let art = glisp::test_artifacts_dir();
    let mut sync = train_stack_cfg(2_000, 2, "sage", &art, ServiceConfig::default()).unwrap();
    let sync_losses = sync.trainer.train(&mut sync.batcher, 6).unwrap();
    let sync_params = sync.trainer.params.tensors[0].as_f32().to_vec();
    sync.service.shutdown();

    let mut pipe = train_stack_cfg(2_000, 2, "sage", &art, ServiceConfig::new(2, 16)).unwrap();
    let pcfg = PipelineConfig {
        producers: 3,
        queue_depth: 2,
        ordered: true,
    };
    let pipe_losses = pipe.trainer.train_pipelined(&mut pipe.batcher, 6, &pcfg).unwrap();
    let pipe_params = pipe.trainer.params.tensors[0].as_f32().to_vec();
    pipe.service.shutdown();

    assert_eq!(
        f32_digest(&sync_losses),
        f32_digest(&pipe_losses),
        "pooled pipelined losses diverged from sync: {sync_losses:?} vs {pipe_losses:?}"
    );
    assert_eq!(
        f32_digest(&sync_params),
        f32_digest(&pipe_params),
        "pooled pipelined parameters diverged from sync"
    );
}
