//! Property tests over the out-of-core storage seam (util::proptest
//! mini-framework; replay failures with GLISP_PROP_SEED): for arbitrary
//! graphs an `MmapStore`-opened partition must be indistinguishable from
//! the `HeapStore` one — identical array views, identical residency
//! split, and identical sampled bits through every deployment shape
//! (pooled in-process and socket fleet), per DESIGN.md §13.

use glisp::graph::generator;
use glisp::graph::hetero::build_partitions_threads;
use glisp::graph::store::{open_partitions, StoreBackend};
use glisp::partition::{AdaDNE, Partitioner};
use glisp::sampling::{
    sample_tree, serve_partition, SampleConfig, SamplingService, ServiceConfig,
};
use glisp::util::proptest::prop_check;
use glisp::{prop_assert, prop_assert_eq};
use std::sync::Arc;

#[test]
fn mapped_sections_equal_heap_sections_for_arbitrary_graphs() {
    prop_check("store section equality", 10, |rng| {
        let n = rng.range(80, 900);
        let g = generator::heterogeneous_graph(n, n * rng.range(4, 10), 2, 4, 2.1, rng);
        let parts = rng.range(1, 5);
        let ea = AdaDNE::default().partition(&g, parts, rng.next_u64());
        let built =
            build_partitions_threads(&g, &ea.part_of_edge, parts, rng.range(1, 4)).unwrap();
        let dir = std::env::temp_dir().join(format!("glisp_prop_store_{}", rng.next_u64()));
        let _ = std::fs::remove_dir_all(&dir);
        for p in &built {
            glisp::graph::io::save_partition(p, &dir, &format!("part{}", p.part_id)).unwrap();
        }
        let heap = open_partitions(&dir, StoreBackend::Heap).unwrap();
        let mapped = open_partitions(&dir, StoreBackend::Mmap).unwrap();
        prop_assert_eq!(heap.len(), built.len());
        prop_assert_eq!(mapped.len(), built.len());
        for ((b, h), m) in built.iter().zip(&heap).zip(&mapped) {
            prop_assert_eq!(b.part_id, m.part_id);
            prop_assert_eq!(b.num_parts, m.num_parts);
            // Every section, bit for bit, through the mapping.
            prop_assert_eq!(b.global_id.clone(), m.global_id.clone());
            prop_assert_eq!(b.out_indptr.clone(), m.out_indptr.clone());
            prop_assert_eq!(b.out_dst.clone(), m.out_dst.clone());
            prop_assert_eq!(b.out_weight.clone(), m.out_weight.clone());
            prop_assert_eq!(b.out_et_indptr.clone(), m.out_et_indptr.clone());
            prop_assert_eq!(b.out_et_ids.clone(), m.out_et_ids.clone());
            prop_assert_eq!(b.out_et_end.clone(), m.out_et_end.clone());
            prop_assert_eq!(b.in_indptr.clone(), m.in_indptr.clone());
            prop_assert_eq!(b.in_src.clone(), m.in_src.clone());
            prop_assert_eq!(b.in_eid.clone(), m.in_eid.clone());
            prop_assert_eq!(b.out_deg_global.clone(), m.out_deg_global.clone());
            prop_assert_eq!(b.in_deg_global.clone(), m.in_deg_global.clone());
            prop_assert_eq!(
                b.partition_set.raw().to_vec(),
                m.partition_set.raw().to_vec()
            );
            // Residency split: heap-opened is all heap, mapped is all file.
            prop_assert_eq!(h.nbytes(), m.nbytes());
            prop_assert_eq!(h.heap_bytes(), h.nbytes());
            prop_assert_eq!(h.mapped_bytes(), 0);
            prop_assert_eq!(m.heap_bytes(), 0);
            prop_assert_eq!(m.mapped_bytes(), m.nbytes());
        }
        std::fs::remove_dir_all(&dir).ok();
        Ok(())
    });
}

#[test]
fn mapped_store_samples_bit_identically_across_transports() {
    prop_check("store sampling bits", 4, |rng| {
        let n = rng.range(300, 1200);
        let g = generator::heterogeneous_graph(n, n * 8, 2, 3, 2.2, rng);
        let parts = rng.range(2, 4);
        let ea = AdaDNE::default().partition(&g, parts, 1);
        let built = build_partitions_threads(&g, &ea.part_of_edge, parts, 2).unwrap();
        let dir = std::env::temp_dir().join(format!("glisp_prop_wire_{}", rng.next_u64()));
        let _ = std::fs::remove_dir_all(&dir);
        for p in &built {
            glisp::graph::io::save_partition(p, &dir, &format!("part{}", p.part_id)).unwrap();
        }
        let mapped = open_partitions(&dir, StoreBackend::Mmap).unwrap();
        prop_assert!(mapped.iter().all(|p| p.heap_bytes() == 0));

        // Pooled in-process services: heap-built vs mapped partitions.
        let cfg = ServiceConfig::new(2, 8);
        let mem = SamplingService::launch_with_partitions_cfg(g.n, built, 1, cfg);
        let disk = SamplingService::launch_with_partitions_cfg(g.n, mapped, 1, cfg);

        // Socket fleet over a SECOND mapping of the same files: one server
        // process-equivalent per partition, same service seed 1.
        let wire_parts = open_partitions(&dir, StoreBackend::Mmap).unwrap();
        let mut servers = Vec::new();
        let mut addrs = Vec::new();
        for p in wire_parts {
            let srv = serve_partition(Arc::new(p), "tcp:127.0.0.1:0", 1, 2).unwrap();
            addrs.push(srv.addr().to_string());
            servers.push(srv);
        }
        let wire = SamplingService::connect(&addrs, g.n, cfg).unwrap();

        let seeds: Vec<u32> = (0..48).collect();
        let fanouts = [rng.range(2, 8), rng.range(2, 6)];
        for scfg in [
            SampleConfig::default(),
            SampleConfig {
                weighted: true,
                ..Default::default()
            },
        ] {
            let tm = sample_tree(&mut mem.client(9), &seeds, &fanouts, &scfg).unwrap();
            let td = sample_tree(&mut disk.client(9), &seeds, &fanouts, &scfg).unwrap();
            let tw = sample_tree(&mut wire.client(9), &seeds, &fanouts, &scfg).unwrap();
            prop_assert_eq!(tm.levels.clone(), td.levels);
            prop_assert_eq!(tm.masks.clone(), td.masks);
            prop_assert_eq!(tm.levels.clone(), tw.levels);
            prop_assert_eq!(tm.masks, tw.masks);
        }
        mem.shutdown();
        disk.shutdown();
        wire.shutdown(); // stops the socket servers too
        for s in servers {
            s.join();
        }
        std::fs::remove_dir_all(&dir).ok();
        Ok(())
    });
}
