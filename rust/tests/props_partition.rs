//! Property tests over the partitioning + compact-structure invariants
//! (util::proptest mini-framework; replay failures with GLISP_PROP_SEED).

use glisp::graph::csr::{Graph, VId};
use glisp::graph::hetero::build_partitions;
use glisp::graph::reorder::{rank_of, reorder, ReorderAlgo};
use glisp::graph::{generator, metrics};
use glisp::partition::{
    primary_partition, quality, AdaDNE, DistributedNE, EdgeCutLDG, Hash1D, Hash2D, Partitioner,
};
use glisp::util::proptest::prop_check;
use glisp::util::rng::Rng;
use glisp::{prop_assert, prop_assert_eq};

fn arbitrary_graph(rng: &mut Rng) -> Graph {
    let n = rng.range(50, 1500);
    let m = rng.range(n, n * 12);
    match rng.usize(3) {
        0 => generator::chung_lu(n, m, 1.8 + rng.f64(), rng),
        1 => generator::erdos_renyi(n, m, rng),
        _ => generator::rmat(n.next_power_of_two(), m, rng),
    }
}

fn partitioners() -> Vec<Box<dyn Partitioner>> {
    vec![
        Box::new(Hash1D),
        Box::new(Hash2D),
        Box::new(EdgeCutLDG::default()),
        Box::new(DistributedNE::default()),
        Box::new(AdaDNE::default()),
    ]
}

#[test]
fn every_partitioner_assigns_every_edge_exactly_once() {
    prop_check("edge totality", 25, |rng| {
        let g = arbitrary_graph(rng);
        let parts = rng.range(2, 9);
        for p in partitioners() {
            let ea = p.partition(&g, parts, rng.next_u64());
            prop_assert_eq!(ea.part_of_edge.len(), g.m());
            prop_assert!(
                ea.part_of_edge.iter().all(|&x| (x as usize) < parts),
                "{} emitted an out-of-range partition id",
                p.name()
            );
        }
        Ok(())
    });
}

#[test]
fn quality_metrics_are_well_formed() {
    prop_check("quality bounds", 20, |rng| {
        let g = arbitrary_graph(rng);
        let parts = rng.range(2, 7);
        // RF is normalized by |V| including isolated vertices (which RMAT
        // produces); every *connected* vertex must appear at least once, so
        // RF >= connected/|V|.
        let mut connected = vec![false; g.n];
        for u in 0..g.n {
            for &v in g.out_neighbors(u as VId) {
                connected[u] = true;
                connected[v as usize] = true;
            }
        }
        let min_rf = connected.iter().filter(|&&c| c).count() as f64 / g.n as f64;
        for p in partitioners() {
            let ea = p.partition(&g, parts, rng.next_u64());
            let q = quality(&g, &ea);
            prop_assert!(q.rf >= min_rf - 1e-9, "{}: RF {} < {min_rf}", p.name(), q.rf);
            prop_assert!(q.vb >= 1.0, "{}: VB {} < 1", p.name(), q.vb);
            prop_assert!(q.eb >= 1.0, "{}: EB {} < 1", p.name(), q.eb);
            let edge_sum: usize = q.edges_per_part.iter().sum();
            prop_assert_eq!(edge_sum, g.m());
        }
        Ok(())
    });
}

#[test]
fn partition_structures_preserve_the_graph() {
    prop_check("structure fidelity", 15, |rng| {
        let g = arbitrary_graph(rng);
        let parts = rng.range(2, 5);
        let ea = AdaDNE::default().partition(&g, parts, rng.next_u64());
        let pgs = build_partitions(&g, &ea.part_of_edge, parts).unwrap();
        // Edge conservation.
        let total: usize = pgs.iter().map(|p| p.ne()).sum();
        prop_assert_eq!(total, g.m());
        // Every partition edge exists in the original graph.
        for p in &pgs {
            for v in 0..p.nv() as u32 {
                let src = p.global(v);
                for &dst in p.out_neighbors(v) {
                    prop_assert!(
                        g.out_neighbors(src).contains(&dst),
                        "phantom edge {src}->{dst} in partition {}",
                        p.part_id
                    );
                }
            }
        }
        // Local/global bijection + sortedness.
        for p in &pgs {
            prop_assert!(p.global_id.windows(2).all(|w| w[0] < w[1]));
            for l in 0..p.nv() as u32 {
                prop_assert_eq!(p.local_id(p.global(l)), Some(l));
            }
        }
        // Membership rows match the quality computation's vertex counts.
        let q = quality(&g, &ea);
        for p in &pgs {
            prop_assert_eq!(p.nv(), q.vertices_per_part[p.part_id]);
        }
        Ok(())
    });
}

#[test]
fn expansion_is_thread_count_invariant_on_arbitrary_graphs() {
    prop_check("offline thread invariance", 10, |rng| {
        let g = arbitrary_graph(rng);
        let parts = rng.range(2, 7);
        let seed = rng.next_u64();
        let threads = rng.range(2, 9);
        let serial = AdaDNE::default().partition(&g, parts, seed);
        let par = AdaDNE {
            threads,
            ..Default::default()
        }
        .partition(&g, parts, seed);
        prop_assert_eq!(serial.part_of_edge.clone(), par.part_of_edge);
        let serial = DistributedNE::default().partition(&g, parts, seed);
        let par = DistributedNE {
            threads,
            ..Default::default()
        }
        .partition(&g, parts, seed);
        prop_assert_eq!(serial.part_of_edge.clone(), par.part_of_edge.clone());
        // The parallel builder over the parallel assignment matches the
        // fully-serial offline pipeline structure-for-structure.
        let a = build_partitions(&g, &serial.part_of_edge, parts).unwrap();
        let b = glisp::graph::build_partitions_threads(&g, &par.part_of_edge, parts, threads)
            .unwrap();
        for (x, y) in a.iter().zip(&b) {
            prop_assert_eq!(x.global_id.clone(), y.global_id.clone());
            prop_assert_eq!(x.out_dst.clone(), y.out_dst.clone());
            prop_assert_eq!(x.in_eid.clone(), y.in_eid.clone());
            prop_assert_eq!(x.partition_set.raw().to_vec(), y.partition_set.raw().to_vec());
        }
        Ok(())
    });
}

#[test]
fn adadne_balances_beat_dne_on_power_law() {
    prop_check("adadne balance", 8, |rng| {
        let n = rng.range(2000, 5000);
        let g = generator::chung_lu(n, n * 10, 2.0, rng);
        let parts = 8;
        let qd = quality(&g, &DistributedNE::default().partition(&g, parts, 1));
        let qa = quality(&g, &AdaDNE::default().partition(&g, parts, 1));
        prop_assert!(
            qa.vb <= qd.vb * 1.10,
            "AdaDNE VB {} vs DNE VB {}",
            qa.vb,
            qd.vb
        );
        prop_assert!(qa.eb < 1.6, "AdaDNE EB {}", qa.eb);
        Ok(())
    });
}

#[test]
fn reorders_are_permutations_and_invertible() {
    prop_check("reorder permutation", 15, |rng| {
        let g = arbitrary_graph(rng);
        let parts = rng.range(2, 5);
        let ea = Hash2D.partition(&g, parts, rng.next_u64());
        let part_of = primary_partition(&g, &ea);
        for algo in [
            ReorderAlgo::NS,
            ReorderAlgo::DS,
            ReorderAlgo::PS,
            ReorderAlgo::PDS,
            ReorderAlgo::BFS,
            ReorderAlgo::HubCluster,
        ] {
            let order = reorder(&g, algo, &part_of);
            prop_assert_eq!(order.len(), g.n);
            let mut seen = vec![false; g.n];
            for &v in &order {
                prop_assert!(!seen[v as usize], "{:?} duplicated {v}", algo);
                seen[v as usize] = true;
            }
            let rank = rank_of(&order);
            for (r, &v) in order.iter().enumerate() {
                prop_assert_eq!(rank[v as usize] as usize, r);
            }
        }
        Ok(())
    });
}

#[test]
fn io_round_trip_arbitrary_partitions() {
    prop_check("io round trip", 8, |rng| {
        let n = rng.range(100, 800);
        let g = generator::heterogeneous_graph(n, n * 8, 3, 4, 2.2, rng);
        let parts = rng.range(1, 4);
        let ea = Hash2D.partition(&g, parts, rng.next_u64());
        let pgs = build_partitions(&g, &ea.part_of_edge, parts).unwrap();
        let dir = std::env::temp_dir().join(format!("glisp_prop_io_{}", rng.next_u64()));
        for p in &pgs {
            glisp::graph::io::save_partition(p, &dir, &format!("p{}", p.part_id)).unwrap();
            let loaded =
                glisp::graph::io::load_partition(&dir, &format!("p{}", p.part_id)).unwrap();
            prop_assert_eq!(loaded.global_id, p.global_id.clone());
            prop_assert_eq!(loaded.out_dst, p.out_dst.clone());
            prop_assert_eq!(loaded.in_eid, p.in_eid.clone());
            prop_assert_eq!(loaded.nbytes(), p.nbytes());
        }
        std::fs::remove_dir_all(&dir).ok();
        Ok(())
    });
}

#[test]
fn generators_hit_their_degree_regimes() {
    prop_check("generator regimes", 6, |rng| {
        let n = rng.range(5000, 15_000);
        let pl = generator::chung_lu(n, n * 8, 2.0, rng);
        prop_assert!(metrics::is_power_law(&pl), "chung_lu not power law");
        let er = generator::erdos_renyi(n, n * 8, rng);
        prop_assert!(!metrics::is_power_law(&er), "ER flagged power law");
        Ok(())
    });
}

#[test]
fn edge_type_queries_match_ground_truth() {
    prop_check("etype queries", 8, |rng| {
        let n = rng.range(100, 600);
        let g = generator::heterogeneous_graph(n, n * 6, 2, 5, 2.2, rng);
        let ea = Hash1D.partition(&g, 2, rng.next_u64());
        for p in build_partitions(&g, &ea.part_of_edge, 2).unwrap() {
            for v in 0..p.nv() as u32 {
                let (a, b) = p.out_range(v);
                // Reconstruct per-edge types via the query and check the
                // multiset matches the original graph's.
                let src = p.global(v);
                let mut got: Vec<u8> =
                    (a..b).map(|e| p.edge_type_of(e as u32)).collect();
                let (ga, gb) = g.edge_range(src);
                let mut want: Vec<u8> = (ga..gb)
                    .filter(|&e| ea.part_of_edge[e] == p.part_id as u16)
                    .map(|e| g.edge_type(e))
                    .collect();
                got.sort_unstable();
                want.sort_unstable();
                prop_assert_eq!(got, want);
            }
        }
        Ok(())
    });
}

#[test]
fn primary_partition_is_always_a_member() {
    prop_check("primary membership", 10, |rng| {
        let g = arbitrary_graph(rng);
        let parts = rng.range(2, 6);
        let ea = AdaDNE::default().partition(&g, parts, rng.next_u64());
        let pp = primary_partition(&g, &ea);
        let pgs = build_partitions(&g, &ea.part_of_edge, parts).unwrap();
        for v in 0..g.n {
            // A vertex with any incident edge must be present in its
            // primary partition's structure.
            let has_edges = g.out_degree(v as VId) > 0
                || pgs.iter().any(|p| {
                    p.local_id(v as VId)
                        .map(|l| p.local_in_degree(l) > 0)
                        .unwrap_or(false)
                });
            if has_edges {
                prop_assert!(
                    pgs[pp[v] as usize].local_id(v as VId).is_some(),
                    "vertex {v} missing from its primary partition {}",
                    pp[v]
                );
            }
        }
        Ok(())
    });
}
