//! Out-of-core budget scenario (DESIGN.md §13): a synthetic workload
//! whose structure + features + layer embeddings exceed a heap budget by
//! ≥4× runs the full partition → build+save → serve → train → layerwise
//! infer pipeline with every large array either file-mapped (partitions)
//! or chunk-spilled (embeddings), and every digest — sampled ids, train
//! losses, final embeddings — is bit-identical to the all-in-memory run,
//! for both the channel and the socket transport.
//!
//! The budget comes from `GLISP_MEM_BUDGET` (bytes; default 2_000_000 —
//! the CI `out-of-core` job pins it). Assertions use the deterministic
//! residency numbers (`memfoot::partition_residency`, wave-build peak,
//! `EngineReport::spill_peak_bytes`), not process RSS.

use glisp::coordinator::{Batcher, FeatureStore, PipelineConfig, Trainer, TrainerConfig};
use glisp::graph::memfoot;
use glisp::graph::store::{open_partitions, StoreBackend};
use glisp::graph::{build_and_save_partitions, build_single_partition};
use glisp::harness::workloads::train_stack_graph;
use glisp::inference::{init_encoder_params, EngineConfig, LayerwiseEngine};
use glisp::partition::{AdaDNE, Partitioner};
use glisp::runtime::Runtime;
use glisp::sampling::{
    sample_tree, serve_partition, SampleConfig, SamplingService, ServiceConfig,
};
use glisp::util::digest::{f32_digest, u32_digest};
use std::sync::Arc;

const N: usize = 6_000;
const PARTS: usize = 4;
const DIN: usize = 64;
const HIDDEN: usize = 128;
const K_LAYERS: usize = 2;

fn budget() -> usize {
    memfoot::mem_budget().unwrap_or(2_000_000)
}

#[test]
fn budget_scenario_runs_out_of_core_bit_identical_to_in_memory() {
    let art = glisp::test_artifacts_dir();
    let budget = budget();
    let (g, labels) = train_stack_graph(N);
    let ea = AdaDNE::default().partition(&g, PARTS, 1);

    // ---- Offline: wave-synchronous build+save, peak residency bounded.
    let dir = std::env::temp_dir().join("glisp_ooc_parts");
    let _ = std::fs::remove_dir_all(&dir);
    let peak = build_and_save_partitions(&g, &ea.part_of_edge, PARTS, 2, &dir).unwrap();
    assert!(
        peak > 0 && peak < budget,
        "wave-build peak {peak} must stay under the {budget}-byte budget"
    );

    // ---- The workload genuinely exceeds the budget: structure + feature
    // matrix + one embedding matrix per layer, measured not modeled.
    let heap_parts = open_partitions(&dir, StoreBackend::Heap).unwrap();
    let structure: usize = heap_parts.iter().map(|p| p.nbytes()).sum();
    let total = structure + N * DIN * 4 + K_LAYERS * N * HIDDEN * 4;
    assert!(
        total >= 4 * budget,
        "scenario holds {total} bytes of graph data but must exceed 4x the {budget} budget"
    );
    // The single dense matrix the spill path avoids is itself over budget.
    assert!(N * HIDDEN * 4 > budget);

    // ---- Mapped partitions: zero heap residency, full bytes file-backed.
    let mapped = open_partitions(&dir, StoreBackend::Mmap).unwrap();
    let res = memfoot::partition_residency(&mapped);
    assert_eq!(res.heap_bytes, 0, "mmap-opened partitions must not touch the heap");
    assert_eq!(res.mapped_bytes, structure);

    // ---- Sampling digests across backend x transport.
    let cfg = ServiceConfig::new(2, 8);
    let heap_svc = SamplingService::launch_with_partitions_cfg(g.n, heap_parts, 1, cfg);
    let mmap_svc = SamplingService::launch_with_partitions_cfg(g.n, mapped, 1, cfg);
    // Socket fleet over a second mapping of the same files — the
    // `glisp serve --load DIR --mmap` deployment, in-process.
    let wire_parts = open_partitions(&dir, StoreBackend::Mmap).unwrap();
    let mut servers = Vec::new();
    let mut addrs = Vec::new();
    for p in wire_parts {
        let path = std::env::temp_dir().join(format!("glisp_ooc_{}.sock", p.part_id));
        let _ = std::fs::remove_file(&path);
        let srv =
            serve_partition(Arc::new(p), &format!("unix:{}", path.display()), 1, 2).unwrap();
        addrs.push(srv.addr().to_string());
        servers.push(srv);
    }
    let wire_svc = SamplingService::connect(&addrs, g.n, cfg).unwrap();

    let seeds: Vec<u32> = (0..128).collect();
    let sample_digest = |svc: &SamplingService| -> (u64, u64) {
        let t = sample_tree(&mut svc.client(9), &seeds, &[10, 5], &SampleConfig::default())
            .unwrap();
        let ids: Vec<u32> = t.levels.iter().flatten().copied().collect();
        let mk: Vec<f32> = t.masks.iter().flatten().copied().collect();
        (u32_digest(&ids), f32_digest(&mk))
    };
    let want = sample_digest(&heap_svc);
    assert_eq!(sample_digest(&mmap_svc), want, "sample digest drifted heap→mmap");
    assert_eq!(sample_digest(&wire_svc), want, "sample digest drifted channel→socket");

    // ---- Training digests: same trainer stack over each service.
    let train = |svc: &SamplingService| -> u64 {
        let features = FeatureStore::labeled(DIN, labels.clone(), 8, 0.6);
        let mut trainer = Trainer::new(
            &art,
            svc.client(2),
            features,
            TrainerConfig {
                model: "sage".into(),
                lr: 0.1,
            },
            7,
        )
        .unwrap();
        let split = (N * 8) / 10;
        let train_seeds: Vec<u32> = (0..split as u32).collect();
        let train_labels: Vec<u16> =
            train_seeds.iter().map(|&v| labels[v as usize]).collect();
        let mut batcher = Batcher::new(train_seeds, train_labels, trainer.batch, 5).unwrap();
        let pcfg = PipelineConfig {
            producers: 2,
            queue_depth: 2,
            ordered: true,
        };
        let losses = trainer.train_pipelined(&mut batcher, 6, &pcfg).unwrap();
        assert!(losses.iter().all(|l| l.is_finite()));
        f32_digest(&losses)
    };
    let loss_want = train(&heap_svc);
    assert_eq!(train(&mmap_svc), loss_want, "loss digest drifted heap→mmap");
    assert_eq!(train(&wire_svc), loss_want, "loss digest drifted channel→socket");

    heap_svc.shutdown();
    mmap_svc.shutdown();
    wire_svc.shutdown();
    for s in servers {
        s.join();
    }

    // ---- Layerwise inference: disk-spill vs in-memory, bit-identical,
    // with the spill window far under budget.
    let work = std::env::temp_dir().join("glisp_ooc_infer");
    let _ = std::fs::remove_dir_all(&work);
    let mk_engine = |sub: &str| -> LayerwiseEngine {
        let runtime = Runtime::load(&art).unwrap();
        let enc = init_encoder_params(&runtime, 3).unwrap();
        LayerwiseEngine::new(
            &g,
            &ea,
            runtime,
            FeatureStore::unlabeled(DIN),
            enc,
            EngineConfig::default(),
            work.join(sub),
        )
        .unwrap()
    };
    let (h, _) = mk_engine("mem").run_vertex_embedding().unwrap();
    let (store, rep) = mk_engine("spill").run_vertex_embedding_spilled().unwrap();
    let mut h_spill = Vec::with_capacity(N * HIDDEN);
    for c in 0..store.num_chunks {
        h_spill.extend(
            store
                .read_chunk(c, glisp::inference::chunk_store::Tier::Static)
                .unwrap(),
        );
    }
    assert_eq!(
        f32_digest(&h),
        f32_digest(&h_spill),
        "embedding digest drifted in-memory→spilled"
    );
    assert_eq!(h, h_spill);
    assert!(
        rep.spill_peak_bytes > 0 && rep.spill_peak_bytes < budget,
        "spill window {} must stay under the {budget}-byte budget",
        rep.spill_peak_bytes
    );

    std::fs::remove_dir_all(&dir).ok();
    std::fs::remove_dir_all(&work).ok();
}

/// The serve-side rebuild path: one partition built alone must equal the
/// same partition out of a full build — `glisp serve` without `--load`
/// never assembles all P structures.
#[test]
fn single_partition_build_matches_saved_files() {
    let (g, _labels) = train_stack_graph(1500);
    let ea = AdaDNE::default().partition(&g, 3, 1);
    let dir = std::env::temp_dir().join("glisp_ooc_single");
    let _ = std::fs::remove_dir_all(&dir);
    build_and_save_partitions(&g, &ea.part_of_edge, 3, 2, &dir).unwrap();
    for part in 0..3 {
        let alone = build_single_partition(&g, &ea.part_of_edge, part, 3, 2).unwrap();
        let loaded =
            glisp::graph::io::load_partition(&dir, &format!("part{part}")).unwrap();
        assert_eq!(alone.global_id, loaded.global_id);
        assert_eq!(alone.out_dst, loaded.out_dst);
        assert_eq!(alone.in_eid, loaded.in_eid);
        assert_eq!(alone.nbytes(), loaded.nbytes());
    }
    std::fs::remove_dir_all(&dir).ok();
}
