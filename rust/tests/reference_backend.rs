//! Reference-backend correctness: (1) single-layer outputs pinned against
//! JAX goldens computed from python/compile/kernels/ref.py, (2) trainer
//! loss decreases within 10 SGD steps on a generated power-law community
//! graph for each of gcn/sage/gat through the full stack (partitioner →
//! sampling service → tree batches → reference train step).
//!
//! Golden inputs use `val(i) = ((i² + 3i) mod 11) / 8 − 1/2`, exact in
//! f32, so Python and Rust construct bit-identical tensors.

use std::sync::Arc;

use glisp::coordinator::{Batcher, FeatureStore, Trainer, TrainerConfig};
use glisp::graph::generator;
use glisp::partition::{AdaDNE, Partitioner};
use glisp::runtime::reference::{
    cross_entropy_with_grad, gat_layer_forward, gcn_layer_forward, link_decode_forward,
    sage_layer_forward,
};
use glisp::sampling::SamplingService;
use glisp::util::rng::Rng;

fn val(i: usize) -> f32 {
    ((i * i + 3 * i) % 11) as f32 * 0.125 - 0.5
}

fn fill(base: usize, n: usize) -> Vec<f32> {
    (0..n).map(|k| val(base + k)).collect()
}

fn assert_close(got: &[f32], want: &[f32], tol: f32, what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert!(
            (g - w).abs() <= tol,
            "{what}[{i}]: got {g}, want {w} (tol {tol})"
        );
    }
}

// Shared single-layer geometry: n=2 vertices, fanout 3, din=4. The second
// vertex has an all-zero mask row (isolated vertex path).
const N: usize = 2;
const F: usize = 3;
const DIN: usize = 4;
const MASK: [f32; 6] = [1.0, 1.0, 0.0, 0.0, 0.0, 0.0];

#[test]
fn sage_layer_matches_jax_golden() {
    let (z, _, _) = sage_layer_forward(
        &fill(0, N * DIN),
        &fill(100, N * F * DIN),
        &MASK,
        &fill(200, DIN * 5),
        &fill(300, DIN * 5),
        &fill(400, 5),
        N,
        F,
        DIN,
        5,
    );
    // python/compile/kernels/ref.py sage_agg_ref on the same inputs.
    let want = [
        -0.0078125, 1.3984375, 1.2265625, -0.0078125, -0.15625, 0.4375, 0.84375, 1.328125,
        0.515625, -0.21875,
    ];
    assert_close(&z, &want, 2e-5, "sage");
}

#[test]
fn gcn_layer_matches_jax_golden() {
    let (z, _, _) = gcn_layer_forward(
        &fill(0, N * DIN),
        &fill(100, N * F * DIN),
        &MASK,
        &fill(200, DIN * 5),
        &fill(400, 5),
        N,
        F,
        DIN,
        5,
    );
    let want = [
        0.25, 0.390625, 1.171875, 0.41666666, -0.61458331, 0.4375, 0.84375, 1.328125, 0.515625,
        -0.21875,
    ];
    assert_close(&z, &want, 2e-5, "gcn");
}

#[test]
fn gat_layer_matches_jax_golden() {
    // 2 heads over hidden 4 (hd=2); mirrors model._gat_layer +
    // kernels/ref.py gat_attn_ref.
    let (z, _) = gat_layer_forward(
        &fill(0, N * DIN),
        &fill(100, N * F * DIN),
        &MASK,
        &fill(200, DIN * 4),
        &fill(500, 4),
        &fill(600, 4),
        &fill(400, 4),
        N,
        F,
        DIN,
        4,
        2,
    );
    let want = [
        0.88929451, 0.20691511, 0.50247121, 0.64912462, 1.1875, 0.09375, 0.625, 0.890625,
    ];
    assert_close(&z, &want, 2e-5, "gat");
}

#[test]
fn link_decode_matches_jax_golden() {
    let h = 3;
    let scores = link_decode_forward(
        &fill(0, 2 * h),
        &fill(50, 2 * h),
        &fill(200, 2 * h * h),
        &fill(400, h),
        &fill(300, h),
        &fill(700, 1),
        2,
        h,
    );
    let want = [0.70659554, 0.73791432];
    assert_close(&scores, &want, 2e-5, "link_decode");
}

#[test]
fn cross_entropy_matches_jax_golden() {
    let (loss, dlogits) = cross_entropy_with_grad(&fill(10, 6), &[2, 0], 3).unwrap();
    assert!((loss - 1.03787434).abs() < 2e-5, "xent loss {loss}");
    // Gradient rows sum to zero (softmax minus one-hot, averaged).
    for i in 0..2 {
        let s: f32 = dlogits[i * 3..(i + 1) * 3].iter().sum();
        assert!(s.abs() < 1e-6, "xent grad row {i} sums to {s}");
    }
}

/// Golden-value convergence: through the full stack, the trainer loss must
/// fall within 10 steps on a power-law labeled community graph for every
/// model family the reference backend implements.
#[test]
fn loss_decreases_in_ten_steps_for_all_models() {
    let art = glisp::test_artifacts_dir();
    let mut rng = Rng::new(77);
    let n = 1500;
    let g = generator::labeled_community_graph(n, n * 12, 8, 0.9, &mut rng);
    let labels = Arc::new(g.label.clone());
    let ea = AdaDNE::default().partition(&g, 2, 1);
    let svc = SamplingService::launch(&g, &ea, 1).unwrap();
    for model in ["gcn", "sage", "gat"] {
        let features = FeatureStore::labeled(64, labels.clone(), 8, 0.6);
        let lr = if model == "sage" { 0.1 } else { 0.4 };
        let mut trainer = Trainer::new(
            &art,
            svc.client(4),
            features,
            TrainerConfig {
                model: model.into(),
                lr,
            },
            7,
        )
        .unwrap();
        let seeds: Vec<u32> = (0..1200).collect();
        let lab: Vec<u16> = seeds.iter().map(|&v| labels[v as usize]).collect();
        let mut batcher = Batcher::new(seeds, lab, trainer.batch, 5).unwrap();
        let losses = trainer.train(&mut batcher, 10).unwrap();
        assert!(losses.iter().all(|l| l.is_finite()));
        let first: f32 = losses[..3].iter().sum::<f32>() / 3.0;
        let last: f32 = losses[7..].iter().sum::<f32>() / 3.0;
        assert!(
            last < first,
            "{model}: loss did not fall in 10 steps (first3 {first:.3}, last3 {last:.3}, {losses:?})"
        );
    }
    svc.shutdown();
}
