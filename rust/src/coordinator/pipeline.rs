//! Pipelined mini-batch producer (paper §III-C, Fig. 11): K-hop sampling
//! and feature/label tensor assembly run on N producer threads while the
//! trainer executes the model step, so the backend never idles on the
//! sampling round — the overlap that sampling-based GNN systems exist for.
//!
//! Architecture (DESIGN.md §7):
//!
//! * a shared epoch-ordered [`BatchFeed`] (the [`Batcher`] behind a mutex)
//!   hands each producer the next `(index, seeds, labels)` triple;
//! * each producer owns a [`SamplingClient::split`] clone and a
//!   [`FeatureStore`] handle, runs `sample_tree` + tensor assembly off the
//!   training thread, and pushes fully-materialized [`ReadyBatch`]es into a
//!   bounded (double-buffered by default) channel — backpressure, not an
//!   unbounded queue;
//! * the consumer (trainer / samplewise runner) executes batches as they
//!   arrive, optionally reassembled in index order via [`Reorder`].
//!
//! Determinism: batch `i`'s sampling stream is [`batch_rng`]`(seed, i)`,
//! and on the server side every seed occurrence samples from its own
//! (salt, seed-index)-derived stream (DESIGN.md §7/§9) — so a sampled
//! batch is a pure function of its index, independent of producer
//! interleaving, server worker-pool size, and gather shard splits. With
//! ordered reassembly, pipelined training reproduces the synchronous loss
//! curve bit-for-bit.

use std::collections::HashMap;
use std::sync::{Condvar, Mutex};

use anyhow::Result;

use crate::coordinator::batcher::Batcher;
use crate::coordinator::features::FeatureStore;
use crate::graph::csr::VId;
use crate::runtime::tensor::{HostTensor, TensorPool};
use crate::sampling::client::SamplingClient;
use crate::sampling::request::SampleConfig;
use crate::sampling::subgraph::sample_tree;
use crate::util::rng::Rng;

/// Knobs of the producer pipeline.
#[derive(Clone, Debug)]
pub struct PipelineConfig {
    /// Producer threads sampling + assembling batches.
    pub producers: usize,
    /// Ready batches buffered per producer before `send` blocks
    /// (2 = classic double buffering).
    pub queue_depth: usize,
    /// Apply batches in epoch order (bit-exact vs the sync path) instead of
    /// arrival order (slightly better overlap under producer skew).
    pub ordered: bool,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        Self {
            producers: 2,
            queue_depth: 2,
            ordered: true,
        }
    }
}

/// The per-batch sampling stream: a pure function of (seed, batch index),
/// shared by the sync trainer path and the pipelined producers so both
/// draw identical trees for the same batch sequence.
pub fn batch_rng(sample_seed: u64, index: u64) -> Rng {
    Rng::new(sample_seed ^ index.wrapping_add(1).wrapping_mul(0x9E37_79B9_7F4A_7C15))
}

/// A fully-materialized training batch: everything `Trainer::execute_ready`
/// appends after the parameter tensors, assembled off the training thread.
pub struct ReadyBatch {
    /// Global step index (epoch-ordered, assigned by the feed).
    pub index: usize,
    /// Batcher epoch the batch was drawn in.
    pub epoch: usize,
    pub seeds: Vec<VId>,
    pub labels: Vec<i32>,
    /// One `[n_k, din]` feature tensor per tree level (seeds first).
    pub features: Vec<HostTensor>,
    /// One `[n_k]` {0,1} mask tensor per sampled level.
    pub masks: Vec<HostTensor>,
    /// Total tree slots (all levels) — throughput accounting.
    pub tree_slots: usize,
}

/// One batch drawn from the shared feed, not yet sampled.
pub struct FeedItem {
    pub index: usize,
    pub epoch: usize,
    pub seeds: Vec<VId>,
    pub labels: Vec<i32>,
}

struct FeedInner<'a> {
    batcher: &'a mut Batcher,
    issued: usize,
    consumed: usize,
    closed: bool,
}

/// The shared, epoch-ordered batch source: producers pull under a mutex so
/// the (index → seeds) mapping is exactly the sequence the sync path would
/// draw, regardless of which producer wins the race.
///
/// The feed also bounds how far production may run ahead of consumption
/// (`window` batches in flight): without it, a straggler producer in
/// ordered mode would let its peers drain the whole epoch into the
/// consumer's reorder buffer. Consumers report progress via
/// [`BatchFeed::mark_consumed`] and must call [`BatchFeed::close`] on an
/// early exit so producers blocked on the window wake up.
pub struct BatchFeed<'a> {
    inner: Mutex<FeedInner<'a>>,
    cv: Condvar,
    base_index: usize,
    limit: usize,
    window: usize,
}

impl<'a> BatchFeed<'a> {
    pub fn new(batcher: &'a mut Batcher, base_index: usize, limit: usize, window: usize) -> Self {
        Self {
            inner: Mutex::new(FeedInner {
                batcher,
                issued: 0,
                consumed: 0,
                closed: false,
            }),
            cv: Condvar::new(),
            base_index,
            limit,
            window: window.max(1),
        }
    }

    /// Draw the next batch; blocks while `window` batches are already in
    /// flight. `None` once `limit` batches were issued or the feed closed.
    pub fn next(&self) -> Option<FeedItem> {
        let mut st = self.inner.lock().unwrap();
        loop {
            if st.issued == self.limit || st.closed {
                return None;
            }
            if st.issued < st.consumed + self.window {
                break;
            }
            st = self.cv.wait(st).unwrap();
        }
        let index = self.base_index + st.issued;
        st.issued += 1;
        let (seeds, labels) = st.batcher.next_batch();
        Some(FeedItem {
            index,
            epoch: st.batcher.epoch,
            seeds,
            labels,
        })
    }

    /// Advance the consumption frontier, letting producers issue further.
    pub fn mark_consumed(&self) {
        let mut st = self.inner.lock().unwrap();
        st.consumed += 1;
        drop(st);
        self.cv.notify_all();
    }

    /// Stop issuing batches and wake producers blocked on the window —
    /// required on every early consumer exit to avoid a stuck join.
    pub fn close(&self) {
        let mut st = self.inner.lock().unwrap();
        st.closed = true;
        drop(st);
        self.cv.notify_all();
    }
}

/// Level features + masks as host tensors — the single assembly path used
/// by the sync trainer, the pipelined producers, and the samplewise
/// inference runner (so the three can never drift numerically).
pub fn assemble_tensors(
    levels: &[Vec<VId>],
    masks: &[Vec<f32>],
    features: &FeatureStore,
) -> (Vec<HostTensor>, Vec<HostTensor>) {
    let din = features.din;
    let feats = levels
        .iter()
        .map(|lvl| {
            let mut buf = vec![0f32; lvl.len() * din];
            features.batch_into(lvl, &mut buf);
            HostTensor::f32(vec![lvl.len(), din], buf)
        })
        .collect();
    let ms = masks
        .iter()
        .map(|m| HostTensor::f32(vec![m.len()], m.clone()))
        .collect();
    (feats, ms)
}

/// [`assemble_tensors`] without the per-batch heap traffic: mask vectors
/// are *moved* into their tensors (the tree is consumed anyway) and
/// feature buffers are drawn from a [`TensorPool`] that the trainer
/// refills with consumed batches. Output values are bit-identical to the
/// unpooled path — `TensorPool::get` zero-fills and `batch_into`
/// overwrites every slot, so buffer provenance cannot leak.
pub fn assemble_tensors_pooled(
    levels: &[Vec<VId>],
    masks: &mut [Vec<f32>],
    features: &FeatureStore,
    pool: &TensorPool,
) -> (Vec<HostTensor>, Vec<HostTensor>) {
    let din = features.din;
    let feats = levels
        .iter()
        .map(|lvl| {
            let mut buf = pool.get(lvl.len() * din);
            features.batch_into(lvl, &mut buf);
            HostTensor::f32(vec![lvl.len(), din], buf)
        })
        .collect();
    let ms = masks
        .iter_mut()
        .map(|m| {
            let data = std::mem::take(m);
            HostTensor::f32(vec![data.len()], data)
        })
        .collect();
    (feats, ms)
}

/// Sample + assemble one feed item into a [`ReadyBatch`] — the producer
/// body. The client's RNG is re-derived from the batch index, so any
/// producer building any index gets the same tree. With `pool`, tensor
/// backing buffers are recycled via [`assemble_tensors_pooled`].
pub fn produce_batch(
    client: &mut SamplingClient,
    features: &FeatureStore,
    fanouts: &[usize],
    cfg: &SampleConfig,
    sample_seed: u64,
    item: FeedItem,
    pool: Option<&TensorPool>,
) -> Result<ReadyBatch> {
    client.rng = batch_rng(sample_seed, item.index as u64);
    let mut tree = sample_tree(client, &item.seeds, fanouts, cfg)?;
    let (features_t, masks_t) = match pool {
        Some(p) => assemble_tensors_pooled(&tree.levels, &mut tree.masks, features, p),
        None => assemble_tensors(&tree.levels, &tree.masks, features),
    };
    Ok(ReadyBatch {
        index: item.index,
        epoch: item.epoch,
        seeds: item.seeds,
        labels: item.labels,
        features: features_t,
        masks: masks_t,
        tree_slots: tree.total_slots(),
    })
}

/// Index-ordered reassembly buffer for out-of-order producer completions.
pub struct Reorder<T> {
    pending: HashMap<usize, T>,
    next: usize,
}

impl<T> Reorder<T> {
    pub fn new(start: usize) -> Self {
        Self {
            pending: HashMap::new(),
            next: start,
        }
    }

    pub fn push(&mut self, index: usize, item: T) {
        self.pending.insert(index, item);
    }

    /// The item with the next consecutive index, if it has arrived.
    pub fn pop_ready(&mut self) -> Option<T> {
        let item = self.pending.remove(&self.next)?;
        self.next += 1;
        Some(item)
    }

    /// Batches buffered ahead of the consumption frontier.
    pub fn buffered(&self) -> usize {
        self.pending.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_rng_is_pure_and_index_sensitive() {
        let mut a = batch_rng(42, 3);
        let mut b = batch_rng(42, 3);
        let mut c = batch_rng(42, 4);
        let sa: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let sb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        let sc: Vec<u64> = (0..8).map(|_| c.next_u64()).collect();
        assert_eq!(sa, sb);
        assert_ne!(sa, sc);
    }

    #[test]
    fn feed_issues_epoch_ordered_indices_up_to_limit() {
        let seeds: Vec<VId> = (0..10).collect();
        let labels: Vec<u16> = seeds.iter().map(|&v| (v % 3) as u16).collect();
        let mut sync = Batcher::new(seeds.clone(), labels.clone(), 4, 9).unwrap();
        let expect: Vec<(Vec<VId>, Vec<i32>)> = (0..5).map(|_| sync.next_batch()).collect();

        let mut b = Batcher::new(seeds, labels, 4, 9).unwrap();
        let feed = BatchFeed::new(&mut b, 7, 5, 8);
        for (i, want) in expect.iter().enumerate() {
            let item = feed.next().unwrap();
            assert_eq!(item.index, 7 + i);
            assert_eq!(item.seeds, want.0);
            assert_eq!(item.labels, want.1);
        }
        assert!(feed.next().is_none(), "feed must stop at the limit");
        assert!(feed.next().is_none());
    }

    #[test]
    fn feed_window_bounds_in_flight_batches() {
        let seeds: Vec<VId> = (0..12).collect();
        let labels: Vec<u16> = vec![0; 12];
        let mut b = Batcher::new(seeds, labels, 4, 1).unwrap();
        let feed = BatchFeed::new(&mut b, 0, 6, 2);
        // Window of 2: two batches issue immediately, the third blocks
        // until the consumer reports progress.
        std::thread::scope(|scope| {
            let handle = scope.spawn(|| {
                let mut got = Vec::new();
                while let Some(item) = feed.next() {
                    got.push(item.index);
                }
                got
            });
            std::thread::sleep(std::time::Duration::from_millis(50));
            // Producer must be parked at the window by now; release it
            // batch by batch.
            for _ in 0..6 {
                feed.mark_consumed();
            }
            assert_eq!(handle.join().unwrap(), vec![0, 1, 2, 3, 4, 5]);
        });
    }

    #[test]
    fn feed_close_wakes_blocked_producers() {
        let seeds: Vec<VId> = (0..12).collect();
        let labels: Vec<u16> = vec![0; 12];
        let mut b = Batcher::new(seeds, labels, 4, 1).unwrap();
        let feed = BatchFeed::new(&mut b, 0, 100, 1);
        std::thread::scope(|scope| {
            let handle = scope.spawn(|| {
                let mut n = 0;
                while feed.next().is_some() {
                    n += 1;
                }
                n
            });
            std::thread::sleep(std::time::Duration::from_millis(50));
            feed.close();
            // Producer drew at most the window before blocking, then saw
            // the close and exited — the join not hanging is the point.
            assert!(handle.join().unwrap() <= 1);
        });
    }

    #[test]
    fn reorder_restores_index_order() {
        let mut r = Reorder::new(10);
        assert!(r.pop_ready().is_none());
        r.push(12, "c");
        r.push(10, "a");
        assert_eq!(r.buffered(), 2);
        assert_eq!(r.pop_ready(), Some("a"));
        assert!(r.pop_ready().is_none(), "11 has not arrived yet");
        r.push(11, "b");
        assert_eq!(r.pop_ready(), Some("b"));
        assert_eq!(r.pop_ready(), Some("c"));
        assert!(r.pop_ready().is_none());
    }

    #[test]
    fn assemble_matches_feature_store_batch() {
        let fs = FeatureStore::unlabeled(8);
        let levels: Vec<Vec<VId>> = vec![vec![1, 2, 3], vec![4, crate::sampling::request::PAD]];
        let masks = vec![vec![1.0f32, 0.0]];
        let (feats, ms) = assemble_tensors(&levels, &masks, &fs);
        assert_eq!(feats.len(), 2);
        assert_eq!(ms.len(), 1);
        assert_eq!(feats[0].shape(), &[3usize, 8][..]);
        assert_eq!(feats[0].as_f32(), &fs.batch(&levels[0])[..]);
        assert_eq!(feats[1].as_f32(), &fs.batch(&levels[1])[..]);
        assert_eq!(ms[0].as_f32(), &[1.0f32, 0.0][..]);
    }

    #[test]
    fn pooled_assembly_matches_unpooled_and_stops_allocating() {
        let fs = FeatureStore::unlabeled(8);
        let pool = TensorPool::new(16);
        let mut warm_misses = 0;
        for round in 0..6 {
            let levels: Vec<Vec<VId>> =
                vec![vec![1, 2, 3], vec![4, crate::sampling::request::PAD, 5, 6]];
            let mut masks = vec![vec![1.0f32, 0.0, 1.0, 1.0]];
            let (f0, m0) = assemble_tensors(&levels, &masks, &fs);
            let (f1, m1) = assemble_tensors_pooled(&levels, &mut masks, &fs, &pool);
            for (a, b) in f0.iter().zip(f1.iter()).chain(m0.iter().zip(m1.iter())) {
                assert_eq!(a.shape(), b.shape());
                assert_eq!(a.as_f32(), b.as_f32());
            }
            assert!(
                masks.iter().all(|m| m.is_empty()),
                "mask vectors are moved into tensors, not copied"
            );
            // The consumer hands every backing buffer back, as the trainer
            // does after a step — from the second round on, assembly must
            // be served entirely from the pool.
            for t in f1.into_iter().chain(m1) {
                pool.put(t.into_f32());
            }
            match round {
                0 => warm_misses = pool.misses(),
                _ => assert_eq!(pool.misses(), warm_misses, "steady state must not allocate"),
            }
        }
        assert!(pool.hits() > 0);
    }
}
