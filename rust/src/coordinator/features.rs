//! Deterministic vertex feature store — the synthetic stand-in for the
//! paper's vertex feature tensors. Features are a pure function of the
//! vertex id (and its label for labeled graphs), so every trainer/server
//! derives identical features with zero coordination, and classification
//! is learnable: `x = signal·embed(label) + (1−signal)·noise(v)`.

use crate::graph::csr::VId;
use crate::sampling::request::PAD;
use crate::util::rng::SplitMix64;
use anyhow::Result;
use std::sync::Arc;

#[derive(Clone)]
pub struct FeatureStore {
    pub din: usize,
    labels: Option<Arc<Vec<u16>>>,
    classes: usize,
    signal: f32,
}

impl FeatureStore {
    /// Unlabeled graphs: pure hash features.
    pub fn unlabeled(din: usize) -> Self {
        Self {
            din,
            labels: None,
            classes: 0,
            signal: 0.0,
        }
    }

    /// Labeled graphs: blend of a label-derived pattern and per-vertex
    /// noise. signal≈0.5 keeps Table IV's task non-trivial.
    pub fn labeled(din: usize, labels: Arc<Vec<u16>>, classes: usize, signal: f32) -> Self {
        Self {
            din,
            labels: Some(labels),
            classes,
            signal,
        }
    }

    /// Write vertex v's features into `out` (len = din). PAD → zeros.
    pub fn fill(&self, v: VId, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.din);
        if v == PAD {
            out.fill(0.0);
            return;
        }
        let mut h = SplitMix64::new(0x5EED ^ (v as u64).wrapping_mul(0x2545F4914F6CDD1D));
        match &self.labels {
            None => {
                for o in out.iter_mut() {
                    *o = unit(h.next_u64());
                }
            }
            Some(labels) => {
                let label = labels[v as usize] as u64;
                // Label pattern: a fixed pseudo-random direction per class.
                let mut hl = SplitMix64::new(0xC1A55 ^ label.wrapping_mul(0x9E3779B97F4A7C15));
                let _ = self.classes;
                for o in out.iter_mut() {
                    let sig = unit(hl.next_u64());
                    let noise = unit(h.next_u64());
                    *o = self.signal * sig + (1.0 - self.signal) * noise;
                }
            }
        }
    }

    /// Flattened [n, din] feature matrix for a vertex list (PAD → zeros).
    pub fn batch(&self, vids: &[VId]) -> Vec<f32> {
        let mut out = vec![0f32; vids.len() * self.din];
        self.batch_into(vids, &mut out);
        out
    }

    /// Fill a caller-owned [n, din] buffer (PAD → zeros) — lets the
    /// pipelined batch producers assemble feature tensors without an extra
    /// allocation per level.
    pub fn batch_into(&self, vids: &[VId], out: &mut [f32]) {
        debug_assert_eq!(out.len(), vids.len() * self.din);
        for (i, &v) in vids.iter().enumerate() {
            self.fill(v, &mut out[i * self.din..(i + 1) * self.din]);
        }
    }

    /// Assemble the [n, din] matrix for `vids` chunk-by-chunk without ever
    /// materializing it. `f` receives `(chunk_index, rows)` where `rows` is
    /// the flattened `[rows_in_chunk, din]` slab for
    /// `vids[chunk*chunk_rows ..]` (short final slab allowed). The resident
    /// window is a single chunk buffer, reused across calls; both the
    /// in-memory and the disk-spill inference paths feed their feature
    /// ChunkStore through here, so the chunk bytes are identical by
    /// construction.
    pub fn for_each_chunk(
        &self,
        vids: &[VId],
        chunk_rows: usize,
        mut f: impl FnMut(usize, &[f32]) -> Result<()>,
    ) -> Result<()> {
        assert!(chunk_rows > 0);
        let mut buf = vec![0f32; chunk_rows * self.din];
        for (c, ids) in vids.chunks(chunk_rows).enumerate() {
            let out = &mut buf[..ids.len() * self.din];
            self.batch_into(ids, out);
            f(c, out)?;
        }
        Ok(())
    }
}

#[inline]
fn unit(x: u64) -> f32 {
    // uniform in [-1, 1)
    ((x >> 11) as f64 * (1.0 / (1u64 << 53) as f64) * 2.0 - 1.0) as f32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_vertex() {
        let fs = FeatureStore::unlabeled(16);
        let a = fs.batch(&[3, 7]);
        let b = fs.batch(&[3, 7]);
        assert_eq!(a, b);
        let c = fs.batch(&[4, 7]);
        assert_ne!(a[..16], c[..16]);
        assert_eq!(a[16..], c[16..]); // vertex 7 unchanged
    }

    #[test]
    fn pad_is_zero() {
        let fs = FeatureStore::unlabeled(8);
        let x = fs.batch(&[PAD]);
        assert!(x.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn same_label_vertices_correlate() {
        let labels = Arc::new(vec![0u16, 0, 1, 1]);
        let fs = FeatureStore::labeled(64, labels, 2, 0.8);
        let x = fs.batch(&[0, 1, 2, 3]);
        let dot = |a: usize, b: usize| -> f32 {
            (0..64).map(|i| x[a * 64 + i] * x[b * 64 + i]).sum()
        };
        // Same-class similarity must dominate cross-class.
        assert!(dot(0, 1) > dot(0, 2).abs() * 2.0);
        assert!(dot(2, 3) > dot(1, 2).abs() * 2.0);
    }

    #[test]
    fn chunked_assembly_matches_batch() {
        let fs = FeatureStore::unlabeled(5);
        let vids: Vec<VId> = (0..23).map(|v| v as VId).collect();
        let whole = fs.batch(&vids);
        let mut rebuilt = Vec::new();
        let mut chunks = Vec::new();
        fs.for_each_chunk(&vids, 4, |c, rows| {
            chunks.push(c);
            rebuilt.extend_from_slice(rows);
            Ok(())
        })
        .unwrap();
        assert_eq!(rebuilt, whole);
        assert_eq!(chunks, (0..6).collect::<Vec<_>>()); // 23 rows / 4 → 6 slabs
    }

    #[test]
    fn feature_range_bounded() {
        let fs = FeatureStore::unlabeled(32);
        let x = fs.batch(&[0, 1, 2, 100, 1000]);
        assert!(x.iter().all(|&v| (-1.0..=1.0).contains(&v)));
    }
}
