//! Training/serving metrics: loss curves, throughput, and the normalized
//! per-server workload of Fig. 10. Human-facing timing strings go through
//! [`crate::util::timer::fmt_duration`] (re-exported here as the slice
//! helper [`fmt_durations`]) — no per-call-site unit choices.

use crate::util::stats::Summary;
use crate::util::timer::fmt_duration;

#[derive(Clone, Debug, Default)]
pub struct LossCurve {
    pub losses: Vec<f32>,
}

impl LossCurve {
    pub fn push(&mut self, l: f32) {
        self.losses.push(l);
    }

    /// Mean of the first and last `w` points — the convergence check used
    /// by tests and EXPERIMENTS.md.
    pub fn head_tail(&self, w: usize) -> (f32, f32) {
        let n = self.losses.len();
        let w = w.min(n);
        let head = self.losses[..w].iter().sum::<f32>() / w as f32;
        let tail = self.losses[n - w..].iter().sum::<f32>() / w as f32;
        (head, tail)
    }

    /// Smoothed curve (window mean) for reports.
    pub fn smoothed(&self, window: usize) -> Vec<f32> {
        if window <= 1 {
            return self.losses.clone();
        }
        self.losses
            .windows(window)
            .map(|w| w.iter().sum::<f32>() / w.len() as f32)
            .collect()
    }
}

/// Normalized per-server workload (Fig. 10): W̄_i = W_i / min_p(W_p).
pub fn normalized_workload(raw: &[u64]) -> Vec<f64> {
    let min = raw.iter().copied().min().unwrap_or(1).max(1) as f64;
    raw.iter().map(|&w| w as f64 / min).collect()
}

/// Throughput summary over per-iteration seconds.
pub fn throughput(items_per_iter: usize, secs: &[f64]) -> Summary {
    Summary::from_iter(secs.iter().map(|&s| items_per_iter as f64 / s.max(1e-12)))
}

/// Format a slice of per-server/per-worker durations (seconds) with the
/// shared [`fmt_duration`] rounding — e.g. Fig. 10's busy-time columns.
pub fn fmt_durations(secs: &[f64]) -> Vec<String> {
    secs.iter().map(|&s| fmt_duration(s)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn head_tail() {
        let c = LossCurve {
            losses: vec![4.0, 4.0, 2.0, 1.0, 1.0],
        };
        let (h, t) = c.head_tail(2);
        assert_eq!(h, 4.0);
        assert_eq!(t, 1.0);
    }

    #[test]
    fn normalized_workload_min_is_one() {
        let w = normalized_workload(&[10, 20, 40]);
        assert_eq!(w, vec![1.0, 2.0, 4.0]);
    }

    #[test]
    fn durations_use_the_shared_formatter() {
        assert_eq!(
            fmt_durations(&[1.5, 0.001234, 0.0]),
            vec!["1.50s", "1.23ms", "0ns"]
        );
    }

    #[test]
    fn smoothing_shrinks() {
        let c = LossCurve {
            losses: (0..10).map(|i| i as f32).collect(),
        };
        assert_eq!(c.smoothed(3).len(), 8);
    }
}
