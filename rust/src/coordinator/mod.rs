//! Training coordinator (L3): feature store, parameter store, seed batcher,
//! the pipelined batch producer, and the trainer loop that feeds
//! Gather-Apply samples into the AOT train-step artifacts. `sync_round`
//! implements the synchronous data-parallel mode of the Fig. 12
//! scalability experiment.

pub mod batcher;
pub mod features;
pub mod metrics;
pub mod params;
pub mod pipeline;
pub mod trainer;

pub use batcher::Batcher;
pub use features::FeatureStore;
pub use params::ParamStore;
pub use pipeline::{PipelineConfig, ReadyBatch};
pub use trainer::{sync_round, Trainer, TrainerConfig};
