//! Seed batcher: epoch-shuffled fixed-size mini-batches over the labeled
//! training set. AOT artifacts have a static batch dimension, so short
//! final batches wrap around into the next epoch instead of emitting a
//! ragged batch; training sets smaller than one batch wrap (and reshuffle)
//! as many times as needed *within* a batch rather than being rejected.

use anyhow::Result;

use crate::graph::csr::VId;
use crate::util::rng::Rng;

pub struct Batcher {
    seeds: Vec<VId>,
    labels: Vec<u16>,
    batch: usize,
    cursor: usize,
    rng: Rng,
    pub epoch: usize,
}

impl Batcher {
    pub fn new(seeds: Vec<VId>, labels: Vec<u16>, batch: usize, seed: u64) -> Result<Self> {
        anyhow::ensure!(
            seeds.len() == labels.len(),
            "seeds/labels length mismatch: {} vs {}",
            seeds.len(),
            labels.len()
        );
        anyhow::ensure!(!seeds.is_empty(), "empty training set");
        anyhow::ensure!(batch > 0, "batch size must be positive");
        let mut b = Self {
            seeds,
            labels,
            batch,
            cursor: 0,
            rng: Rng::new(seed),
            epoch: 0,
        };
        b.shuffle();
        Ok(b)
    }

    /// Number of training examples (one epoch).
    pub fn len(&self) -> usize {
        self.seeds.len()
    }

    pub fn is_empty(&self) -> bool {
        self.seeds.is_empty()
    }

    fn shuffle(&mut self) {
        // Shuffle seeds and labels with the same permutation.
        let n = self.seeds.len();
        for i in (1..n).rev() {
            let j = self.rng.usize(i + 1);
            self.seeds.swap(i, j);
            self.labels.swap(i, j);
        }
    }

    /// Next (seeds, labels) batch of exactly `batch` items.
    pub fn next_batch(&mut self) -> (Vec<VId>, Vec<i32>) {
        let mut seeds = Vec::with_capacity(self.batch);
        let mut labels = Vec::with_capacity(self.batch);
        while seeds.len() < self.batch {
            if self.cursor == self.seeds.len() {
                self.cursor = 0;
                self.epoch += 1;
                self.shuffle();
            }
            seeds.push(self.seeds[self.cursor]);
            labels.push(self.labels[self.cursor] as i32);
            self.cursor += 1;
        }
        (seeds, labels)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batches_are_exact_size_and_cover_epoch() {
        let seeds: Vec<VId> = (0..10).collect();
        let labels: Vec<u16> = (0..10).map(|i| i as u16 % 3).collect();
        let mut b = Batcher::new(seeds, labels, 4, 1).unwrap();
        let mut seen = std::collections::HashMap::new();
        for _ in 0..5 {
            let (s, l) = b.next_batch();
            assert_eq!(s.len(), 4);
            assert_eq!(l.len(), 4);
            for &v in &s {
                *seen.entry(v).or_insert(0usize) += 1;
            }
        }
        // 20 draws over 10 seeds => each seen exactly twice.
        assert!(seen.values().all(|&c| c == 2));
    }

    #[test]
    fn labels_stay_aligned_through_shuffles() {
        let seeds: Vec<VId> = (0..50).collect();
        let labels: Vec<u16> = seeds.iter().map(|&v| (v % 7) as u16).collect();
        let mut b = Batcher::new(seeds, labels, 8, 2).unwrap();
        for _ in 0..30 {
            let (s, l) = b.next_batch();
            for (v, lab) in s.iter().zip(&l) {
                assert_eq!(*lab, (*v % 7) as i32);
            }
        }
        assert!(b.epoch >= 3);
    }

    #[test]
    fn small_training_set_wraps_instead_of_panicking() {
        // Regression: sets smaller than one static batch used to assert.
        let seeds: Vec<VId> = vec![1, 2, 3];
        let labels: Vec<u16> = vec![1, 2, 0];
        let mut b = Batcher::new(seeds, labels, 8, 3).unwrap();
        for _ in 0..4 {
            let (s, l) = b.next_batch();
            assert_eq!(s.len(), 8);
            assert_eq!(l.len(), 8);
            for (v, lab) in s.iter().zip(&l) {
                let want = match *v {
                    1 => 1i32,
                    2 => 2,
                    3 => 0,
                    other => panic!("unexpected seed {other}"),
                };
                assert_eq!(*lab, want, "label alignment survives mid-batch wraps");
            }
        }
        // 32 draws over 3 seeds wrap the epoch ~10 times.
        assert!(b.epoch >= 8);
    }

    #[test]
    fn invalid_constructions_are_errors_not_panics() {
        assert!(Batcher::new(vec![], vec![], 4, 0).is_err());
        assert!(Batcher::new(vec![1, 2], vec![0], 4, 0).is_err());
        assert!(Batcher::new(vec![1], vec![0], 0, 0).is_err());
    }
}
