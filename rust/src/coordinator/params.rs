//! Model parameter store: the host-side copy of the artifact's parameter
//! tensors. Train-step artifacts return updated parameters as outputs on
//! every backend (buffer donation is not part of the `ExecutorBackend`
//! contract), so the store simply swaps in the returned tensors each
//! step; for the data-parallel path it averages gradients and applies
//! SGD host-side.

use anyhow::Result;

use crate::runtime::manifest::TensorSpec;
use crate::runtime::tensor::HostTensor;
use crate::util::rng::Rng;

#[derive(Clone)]
pub struct ParamStore {
    pub specs: Vec<TensorSpec>,
    pub tensors: Vec<HostTensor>,
}

impl ParamStore {
    /// Glorot-uniform init for weight matrices, zeros for vectors (names
    /// ending in "b" are biases, mirroring python/compile/model.py).
    pub fn init_glorot(specs: &[TensorSpec], rng: &mut Rng) -> Self {
        let tensors = specs
            .iter()
            .map(|s| {
                let n: usize = s.shape.iter().product();
                if s.name.ends_with('b') || s.shape.len() == 1 {
                    HostTensor::f32(s.shape.clone(), vec![0.0; n])
                } else {
                    let fan_in = s.shape[0] as f64;
                    let fan_out = *s.shape.last().unwrap() as f64;
                    let limit = (6.0 / (fan_in + fan_out)).sqrt();
                    HostTensor::f32(
                        s.shape.clone(),
                        (0..n)
                            .map(|_| ((rng.f64() * 2.0 - 1.0) * limit) as f32)
                            .collect(),
                    )
                }
            })
            .collect();
        Self {
            specs: specs.to_vec(),
            tensors,
        }
    }

    pub fn len(&self) -> usize {
        self.tensors.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tensors.is_empty()
    }

    /// Total parameter count (scalars).
    pub fn num_parameters(&self) -> usize {
        self.tensors.iter().map(|t| t.len()).sum()
    }

    /// Replace with the updated tensors a train-step artifact returned.
    pub fn replace(&mut self, new: Vec<HostTensor>) -> Result<()> {
        anyhow::ensure!(new.len() == self.tensors.len(), "param arity changed");
        self.tensors = new;
        Ok(())
    }

    /// SGD with pre-averaged gradients (data-parallel path).
    pub fn sgd(&mut self, grads: &[HostTensor], lr: f32) {
        assert_eq!(grads.len(), self.tensors.len());
        for (p, g) in self.tensors.iter_mut().zip(grads) {
            if let (HostTensor::F32 { data: pd, .. }, HostTensor::F32 { data: gd, .. }) =
                (p, g)
            {
                for (x, &d) in pd.iter_mut().zip(gd) {
                    *x -= lr * d;
                }
            }
        }
    }
}

/// Average per-trainer gradient lists element-wise (synchronous data
/// parallelism, Fig. 12).
pub fn average_grads(all: &[Vec<HostTensor>]) -> Vec<HostTensor> {
    assert!(!all.is_empty());
    let t = all.len() as f32;
    let mut out = all[0].clone();
    for grads in &all[1..] {
        for (acc, g) in out.iter_mut().zip(grads) {
            if let (HostTensor::F32 { data: a, .. }, HostTensor::F32 { data: b, .. }) =
                (acc, g)
            {
                for (x, &y) in a.iter_mut().zip(b) {
                    *x += y;
                }
            }
        }
    }
    for acc in &mut out {
        if let HostTensor::F32 { data, .. } = acc {
            for x in data {
                *x /= t;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::tensor::DType;

    fn specs() -> Vec<TensorSpec> {
        vec![
            TensorSpec {
                name: "w".into(),
                shape: vec![4, 8],
                dtype: DType::F32,
            },
            TensorSpec {
                name: "b".into(),
                shape: vec![8],
                dtype: DType::F32,
            },
        ]
    }

    #[test]
    fn glorot_ranges() {
        let mut rng = Rng::new(200);
        let ps = ParamStore::init_glorot(&specs(), &mut rng);
        let limit = (6.0f64 / 12.0).sqrt() as f32;
        assert!(ps.tensors[0].as_f32().iter().all(|&x| x.abs() <= limit));
        assert!(ps.tensors[1].as_f32().iter().all(|&x| x == 0.0));
        assert_eq!(ps.num_parameters(), 40);
    }

    #[test]
    fn sgd_moves_against_gradient() {
        let mut rng = Rng::new(201);
        let mut ps = ParamStore::init_glorot(&specs(), &mut rng);
        let before = ps.tensors[0].as_f32()[0];
        let grads = vec![
            HostTensor::f32(vec![4, 8], vec![1.0; 32]),
            HostTensor::f32(vec![8], vec![0.0; 8]),
        ];
        ps.sgd(&grads, 0.1);
        assert!((ps.tensors[0].as_f32()[0] - (before - 0.1)).abs() < 1e-6);
    }

    #[test]
    fn average_of_identical_is_identity() {
        let g = vec![HostTensor::f32(vec![2], vec![2.0, 4.0])];
        let avg = average_grads(&[g.clone(), g.clone()]);
        assert_eq!(avg[0].as_f32(), &[2.0, 4.0]);
    }

    #[test]
    fn average_mixes_trainers() {
        let a = vec![HostTensor::f32(vec![2], vec![0.0, 2.0])];
        let b = vec![HostTensor::f32(vec![2], vec![4.0, 2.0])];
        let avg = average_grads(&[a, b]);
        assert_eq!(avg[0].as_f32(), &[2.0, 2.0]);
    }
}
