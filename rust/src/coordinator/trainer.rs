//! The training coordinator: glue between the sampling service (L3), the
//! feature store, and the train-step artifacts (L2/L1) executed through
//! the backend-agnostic [`Runtime`] (reference backend by default, PJRT
//! behind the `pjrt` feature). One `Trainer` = one logical GPU worker of
//! the paper's Fig. 1; the data-parallel scalability experiment (Fig. 12)
//! runs several in synchronous gradient-averaging mode.

use anyhow::{Context, Result};

use crate::coordinator::batcher::Batcher;
use crate::coordinator::features::FeatureStore;
use crate::coordinator::params::{average_grads, ParamStore};
use crate::graph::csr::VId;
use crate::runtime::tensor::HostTensor;
use crate::runtime::Runtime;
use crate::sampling::client::SamplingClient;
use crate::sampling::request::SampleConfig;
use crate::sampling::subgraph::{sample_tree, TreeSample};
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct TrainerConfig {
    /// "gcn" | "sage" | "gat" — selects the artifact pair
    /// `<model>_train` / `<model>_eval`.
    pub model: String,
    pub lr: f32,
}

pub struct Trainer {
    pub runtime: Runtime,
    pub params: ParamStore,
    pub client: SamplingClient,
    pub features: FeatureStore,
    pub cfg: TrainerConfig,
    /// Static geometry from the manifest.
    pub batch: usize,
    pub fanouts: Vec<usize>,
    pub n_params: usize,
    sample_cfg: SampleConfig,
}

impl Trainer {
    pub fn new(
        artifacts_dir: impl AsRef<std::path::Path>,
        client: SamplingClient,
        features: FeatureStore,
        cfg: TrainerConfig,
        seed: u64,
    ) -> Result<Self> {
        let runtime = Runtime::load(artifacts_dir)?;
        let spec = runtime.spec(&format!("{}_train", cfg.model))?.clone();
        let n_params = spec.meta_usize("n_params").context("meta.n_params")?;
        let batch = spec.meta_usize("batch").context("meta.batch")?;
        let fanouts = spec.meta_usizes("fanouts").context("meta.fanouts")?;
        let din = spec.meta_usize("din").context("meta.din")?;
        anyhow::ensure!(features.din == din, "feature store din {} != artifact {din}", features.din);
        let mut rng = Rng::new(seed);
        let params = ParamStore::init_glorot(&spec.inputs[..n_params], &mut rng);
        Ok(Self {
            runtime,
            params,
            client,
            features,
            cfg,
            batch,
            fanouts,
            n_params,
            sample_cfg: SampleConfig::default(),
        })
    }

    /// Assemble the artifact input list for a sampled tree: params ++ level
    /// features ++ masks [++ labels ++ lr].
    fn model_inputs(
        &self,
        tree: &TreeSample,
        labels: Option<&[i32]>,
        lr: Option<f32>,
    ) -> Vec<HostTensor> {
        let din = self.features.din;
        let mut inputs: Vec<HostTensor> = self.params.tensors.clone();
        for level in &tree.levels {
            inputs.push(HostTensor::f32(
                vec![level.len(), din],
                self.features.batch(level),
            ));
        }
        for mask in &tree.masks {
            inputs.push(HostTensor::f32(vec![mask.len()], mask.clone()));
        }
        if let Some(l) = labels {
            inputs.push(HostTensor::i32(vec![l.len()], l.to_vec()));
        }
        if let Some(lr) = lr {
            inputs.push(HostTensor::scalar1(lr));
        }
        inputs
    }

    pub fn sample_batch(&mut self, seeds: &[VId]) -> TreeSample {
        sample_tree(&mut self.client, seeds, &self.fanouts, &self.sample_cfg)
    }

    /// One SGD step over a seed batch; returns the loss.
    pub fn train_step(&mut self, seeds: &[VId], labels: &[i32]) -> Result<f32> {
        assert_eq!(seeds.len(), self.batch);
        let tree = self.sample_batch(seeds);
        let inputs = self.model_inputs(&tree, Some(labels), Some(self.cfg.lr));
        let mut out = self
            .runtime
            .execute(&format!("{}_train", self.cfg.model), &inputs)?;
        let loss = out.remove(0).as_f32()[0];
        self.params.replace(out)?;
        Ok(loss)
    }

    /// Loss + raw gradients (synchronous data-parallel mode; sage only).
    pub fn grad_step(&mut self, seeds: &[VId], labels: &[i32]) -> Result<(f32, Vec<HostTensor>)> {
        let tree = self.sample_batch(seeds);
        let inputs = self.model_inputs(&tree, Some(labels), None);
        let mut out = self
            .runtime
            .execute(&format!("{}_grad", self.cfg.model), &inputs)?;
        let loss = out.remove(0).as_f32()[0];
        Ok((loss, out))
    }

    /// Train for `steps` mini-batches from the batcher; returns loss curve.
    pub fn train(&mut self, batcher: &mut Batcher, steps: usize) -> Result<Vec<f32>> {
        let mut losses = Vec::with_capacity(steps);
        for _ in 0..steps {
            let (seeds, labels) = batcher.next_batch();
            losses.push(self.train_step(&seeds, &labels)?);
        }
        Ok(losses)
    }

    /// Predicted class per seed via the eval artifact.
    pub fn predict(&mut self, seeds: &[VId]) -> Result<Vec<usize>> {
        assert_eq!(seeds.len(), self.batch);
        let tree = self.sample_batch(seeds);
        let inputs = self.model_inputs(&tree, None, None);
        let out = self
            .runtime
            .execute(&format!("{}_eval", self.cfg.model), &inputs)?;
        let logits = out[0].as_f32();
        let classes = out[0].shape()[1];
        Ok((0..seeds.len())
            .map(|i| {
                let row = &logits[i * classes..(i + 1) * classes];
                row.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .unwrap()
                    .0
            })
            .collect())
    }

    /// Accuracy over a labeled evaluation set (batched; remainder dropped).
    pub fn evaluate(&mut self, seeds: &[VId], labels: &[u16]) -> Result<f64> {
        let mut correct = 0usize;
        let mut total = 0usize;
        for (chunk_s, chunk_l) in seeds.chunks(self.batch).zip(labels.chunks(self.batch)) {
            if chunk_s.len() < self.batch {
                break;
            }
            let preds = self.predict(chunk_s)?;
            for (p, &l) in preds.iter().zip(chunk_l) {
                correct += (*p == l as usize) as usize;
                total += 1;
            }
        }
        anyhow::ensure!(total > 0, "evaluation set smaller than one batch");
        Ok(correct as f64 / total as f64)
    }
}

/// Timing breakdown of one synchronous round. Logical trainers execute
/// sequentially on this testbed; in the paper's deployment they run in
/// parallel, so the simulated round time is `max(trainer_secs) +
/// apply_secs` (stragglers + the synchronization barrier — the mechanism
/// behind Fig. 12's ~0.8 scaling slope).
pub struct SyncRoundReport {
    pub loss: f32,
    pub trainer_secs: Vec<f64>,
    pub apply_secs: f64,
}

impl SyncRoundReport {
    pub fn simulated_secs(&self) -> f64 {
        self.trainer_secs.iter().cloned().fold(0f64, f64::max) + self.apply_secs
    }
}

/// One synchronous data-parallel round (Fig. 12): every trainer computes
/// gradients on its own batch from shared parameters; the leader averages
/// and applies.
pub fn sync_round(
    trainers: &mut [Trainer],
    batchers: &mut [Batcher],
    lr: f32,
) -> Result<SyncRoundReport> {
    // Broadcast leader parameters.
    let leader_params = trainers[0].params.clone();
    let mut all_grads = Vec::with_capacity(trainers.len());
    let mut loss_sum = 0f32;
    let mut trainer_secs = Vec::with_capacity(trainers.len());
    for (t, b) in trainers.iter_mut().zip(batchers.iter_mut()) {
        t.params = leader_params.clone();
        let (seeds, labels) = b.next_batch();
        let timer = crate::util::timer::Timer::start();
        let (loss, grads) = t.grad_step(&seeds, &labels)?;
        trainer_secs.push(timer.secs());
        loss_sum += loss;
        all_grads.push(grads);
    }
    let timer = crate::util::timer::Timer::start();
    let avg = average_grads(&all_grads);
    let n = trainers.len();
    trainers[0].params.sgd(&avg, lr);
    Ok(SyncRoundReport {
        loss: loss_sum / n as f32,
        trainer_secs,
        apply_secs: timer.secs(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generator;
    use crate::partition::{AdaDNE, Partitioner};
    use crate::sampling::service::SamplingService;
    use std::sync::Arc;

    fn stack() -> (SamplingService, Trainer, Batcher) {
        let dir = crate::test_artifacts_dir();
        let mut rng = Rng::new(210);
        let g = generator::labeled_community_graph(2000, 24_000, 8, 0.9, &mut rng);
        let labels = Arc::new(g.label.clone());
        let ea = AdaDNE::default().partition(&g, 2, 0);
        let svc = SamplingService::launch(&g, &ea, 1);
        let features = FeatureStore::labeled(64, labels.clone(), 8, 0.6);
        let trainer = Trainer::new(
            &dir,
            svc.client(3),
            features,
            TrainerConfig {
                model: "sage".into(),
                lr: 0.1,
            },
            7,
        )
        .unwrap();
        let seeds: Vec<VId> = (0..1000).collect();
        let lab: Vec<u16> = seeds.iter().map(|&v| labels[v as usize]).collect();
        let batcher = Batcher::new(seeds, lab, trainer.batch, 5);
        (svc, trainer, batcher)
    }

    #[test]
    fn train_step_runs_and_updates_params() {
        let (svc, mut t, mut b) = stack();
        let before = t.params.tensors[0].as_f32().to_vec();
        let (seeds, labels) = b.next_batch();
        let loss = t.train_step(&seeds, &labels).unwrap();
        assert!(loss.is_finite() && loss > 0.0);
        assert_ne!(before, t.params.tensors[0].as_f32());
        svc.shutdown();
    }

    #[test]
    fn loss_decreases_over_training() {
        let (svc, mut t, mut b) = stack();
        let losses = t.train(&mut b, 30).unwrap();
        let head: f32 = losses[..5].iter().sum::<f32>() / 5.0;
        let tail: f32 = losses[losses.len() - 5..].iter().sum::<f32>() / 5.0;
        assert!(
            tail < head,
            "loss should fall: head {head:.3} tail {tail:.3} ({losses:?})"
        );
        svc.shutdown();
    }

    #[test]
    fn grad_step_matches_train_step_arity() {
        let (svc, mut t, mut b) = stack();
        let (seeds, labels) = b.next_batch();
        let (loss, grads) = t.grad_step(&seeds, &labels).unwrap();
        assert!(loss.is_finite());
        assert_eq!(grads.len(), t.params.len());
        for (g, p) in grads.iter().zip(&t.params.tensors) {
            assert_eq!(g.shape(), p.shape());
        }
        svc.shutdown();
    }
}
