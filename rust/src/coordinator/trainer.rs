//! The training coordinator: glue between the sampling service (L3), the
//! feature store, and the train-step artifacts (L2/L1) executed through
//! the backend-agnostic [`Runtime`] (reference backend by default, PJRT
//! behind the `pjrt` feature). One `Trainer` = one logical GPU worker of
//! the paper's Fig. 1; the data-parallel scalability experiment (Fig. 12)
//! runs several in synchronous gradient-averaging mode.
//!
//! Two data paths feed the model step:
//!
//! * **sync** ([`Trainer::train`]): sample → assemble → execute strictly in
//!   sequence on the calling thread;
//! * **pipelined** ([`Trainer::train_pipelined`]): N producer threads
//!   overlap sampling + tensor assembly with model execution
//!   (`coordinator::pipeline`, DESIGN.md §7). In ordered mode the loss
//!   curve is bit-identical to the sync path for the same seeds.

use anyhow::{Context, Result};

use crate::coordinator::batcher::Batcher;
use crate::coordinator::features::FeatureStore;
use crate::coordinator::params::{average_grads, ParamStore};
use crate::coordinator::pipeline::{
    assemble_tensors, batch_rng, produce_batch, BatchFeed, PipelineConfig, ReadyBatch, Reorder,
};
use crate::graph::csr::VId;
use crate::runtime::tensor::{HostTensor, TensorPool};
use crate::runtime::Runtime;
use crate::sampling::client::SamplingClient;
use crate::sampling::request::SampleConfig;
use crate::sampling::subgraph::{sample_tree, TreeSample};
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct TrainerConfig {
    /// "gcn" | "sage" | "gat" — selects the artifact pair
    /// `<model>_train` / `<model>_eval`.
    pub model: String,
    pub lr: f32,
}

pub struct Trainer {
    pub runtime: Runtime,
    pub params: ParamStore,
    pub client: SamplingClient,
    pub features: FeatureStore,
    pub cfg: TrainerConfig,
    /// Static geometry from the manifest.
    pub batch: usize,
    pub fanouts: Vec<usize>,
    pub n_params: usize,
    sample_cfg: SampleConfig,
    /// Base seed of the per-batch sampling streams (`pipeline::batch_rng`).
    sample_seed: u64,
    /// Global train-step counter — the batch index both the sync path and
    /// the pipelined feed derive their sampling streams from.
    steps_taken: usize,
}

impl Trainer {
    pub fn new(
        artifacts_dir: impl AsRef<std::path::Path>,
        client: SamplingClient,
        features: FeatureStore,
        cfg: TrainerConfig,
        seed: u64,
    ) -> Result<Self> {
        let runtime = Runtime::load(artifacts_dir)?;
        let spec = runtime.spec(&format!("{}_train", cfg.model))?.clone();
        let n_params = spec.meta_usize("n_params").context("meta.n_params")?;
        let batch = spec.meta_usize("batch").context("meta.batch")?;
        let fanouts = spec.meta_usizes("fanouts").context("meta.fanouts")?;
        let din = spec.meta_usize("din").context("meta.din")?;
        anyhow::ensure!(features.din == din, "feature store din {} != artifact {din}", features.din);
        let mut rng = Rng::new(seed);
        let params = ParamStore::init_glorot(&spec.inputs[..n_params], &mut rng);
        let mut client = client;
        // Fold the client's stream into the sampling seed: data-parallel
        // trainers sharing a constructor seed but holding distinct clients
        // still sample decorrelated batches, while identical (seed, client)
        // pairs reproduce exactly.
        let sample_seed = rng.next_u64() ^ client.rng.next_u64();
        Ok(Self {
            runtime,
            params,
            client,
            features,
            cfg,
            batch,
            fanouts,
            n_params,
            sample_cfg: SampleConfig::default(),
            sample_seed,
            steps_taken: 0,
        })
    }

    /// Train-step batches consumed so far (sync + pipelined).
    pub fn steps_taken(&self) -> usize {
        self.steps_taken
    }

    fn next_step_index(&mut self) -> u64 {
        let i = self.steps_taken;
        self.steps_taken += 1;
        i as u64
    }

    /// Assemble the artifact input list for a sampled tree: params ++ level
    /// features ++ masks [++ labels ++ lr].
    fn model_inputs(
        &self,
        tree: &TreeSample,
        labels: Option<&[i32]>,
        lr: Option<f32>,
    ) -> Vec<HostTensor> {
        let (feats, masks) = assemble_tensors(&tree.levels, &tree.masks, &self.features);
        let mut inputs: Vec<HostTensor> = self.params.tensors.clone();
        inputs.extend(feats);
        inputs.extend(masks);
        if let Some(l) = labels {
            inputs.push(HostTensor::i32(vec![l.len()], l.to_vec()));
        }
        if let Some(lr) = lr {
            inputs.push(HostTensor::scalar1(lr));
        }
        inputs
    }

    pub fn sample_batch(&mut self, seeds: &[VId]) -> Result<TreeSample> {
        sample_tree(&mut self.client, seeds, &self.fanouts, &self.sample_cfg)
    }

    /// One SGD step over a seed batch; returns the loss.
    pub fn train_step(&mut self, seeds: &[VId], labels: &[i32]) -> Result<f32> {
        assert_eq!(seeds.len(), self.batch);
        self.client.rng = batch_rng(self.sample_seed, self.next_step_index());
        let tree = self.sample_batch(seeds)?;
        let inputs = self.model_inputs(&tree, Some(labels), Some(self.cfg.lr));
        let mut out = self
            .runtime
            .execute(&format!("{}_train", self.cfg.model), &inputs)?;
        let loss = out.remove(0).as_f32()[0];
        self.params.replace(out)?;
        Ok(loss)
    }

    /// Loss + raw gradients (synchronous data-parallel mode; sage only).
    pub fn grad_step(&mut self, seeds: &[VId], labels: &[i32]) -> Result<(f32, Vec<HostTensor>)> {
        self.client.rng = batch_rng(self.sample_seed, self.next_step_index());
        let tree = self.sample_batch(seeds)?;
        let inputs = self.model_inputs(&tree, Some(labels), None);
        let mut out = self
            .runtime
            .execute(&format!("{}_grad", self.cfg.model), &inputs)?;
        let loss = out.remove(0).as_f32()[0];
        Ok((loss, out))
    }

    /// Train for `steps` mini-batches from the batcher; returns loss curve.
    pub fn train(&mut self, batcher: &mut Batcher, steps: usize) -> Result<Vec<f32>> {
        let mut losses = Vec::with_capacity(steps);
        for _ in 0..steps {
            let (seeds, labels) = batcher.next_batch();
            losses.push(self.train_step(&seeds, &labels)?);
        }
        Ok(losses)
    }

    /// Execute the model step on a producer-assembled batch: append the
    /// ready tensors after the current parameters (moved, not copied — the
    /// batch is on the hot path), run, apply.
    pub fn execute_ready(&mut self, rb: ReadyBatch) -> Result<f32> {
        self.execute_ready_pooled(rb, None)
    }

    /// [`Trainer::execute_ready`] plus the return half of the tensor
    /// recycle loop (DESIGN.md §14): after the step, the batch's f32
    /// feature/mask backing buffers go back into `pool` for the producers
    /// to reuse. The i32 labels and the length-1 lr scalar stay out.
    pub fn execute_ready_pooled(&mut self, rb: ReadyBatch, pool: Option<&TensorPool>) -> Result<f32> {
        let mut inputs: Vec<HostTensor> = self.params.tensors.clone();
        inputs.extend(rb.features);
        inputs.extend(rb.masks);
        let n_labels = rb.labels.len();
        inputs.push(HostTensor::i32(vec![n_labels], rb.labels));
        inputs.push(HostTensor::scalar1(self.cfg.lr));
        let mut out = self
            .runtime
            .execute(&format!("{}_train", self.cfg.model), &inputs)?;
        let loss = out.remove(0).as_f32()[0];
        self.params.replace(out)?;
        if let Some(pool) = pool {
            for t in inputs.drain(self.n_params..) {
                if let HostTensor::F32 { data, .. } = t {
                    if data.len() > 1 {
                        pool.put(data);
                    }
                }
            }
        }
        Ok(loss)
    }

    /// Train for `steps` mini-batches with sampling + tensor assembly
    /// pipelined onto `pcfg.producers` background threads (DESIGN.md §7).
    /// Ordered mode applies updates in epoch order and is bit-identical to
    /// [`Trainer::train`] for the same batcher seed; unordered mode applies
    /// them in arrival order (same batches, better overlap under skew).
    pub fn train_pipelined(
        &mut self,
        batcher: &mut Batcher,
        steps: usize,
        pcfg: &PipelineConfig,
    ) -> Result<Vec<f32>> {
        if steps == 0 {
            return Ok(Vec::new());
        }
        let producers = pcfg.producers.max(1);
        let depth = pcfg.queue_depth.max(1);
        let base = self.steps_taken;
        self.steps_taken += steps;
        let sample_seed = self.sample_seed;
        let fanouts = self.fanouts.clone();
        let sample_cfg = self.sample_cfg.clone();
        let features = self.features.clone();
        let clients: Vec<SamplingClient> =
            (0..producers).map(|p| self.client.split(p as u64)).collect();
        // In-flight bound: everything the channel can hold plus one batch
        // under construction per producer. Caps the ordered-mode reorder
        // buffer as well — a straggler cannot let its peers materialize
        // the rest of the epoch.
        let window = producers * (depth + 1);
        let feed = BatchFeed::new(batcher, base, steps, window);
        // Tensor recycle loop (DESIGN.md §14): the consumer returns each
        // executed batch's f32 buffers here, producers draw from it for
        // the next assembly. Capacity covers every buffer a full window of
        // batches can hold (levels + masks), so steady-state training
        // allocates no per-batch tensors.
        let pool = TensorPool::new(window * (2 * fanouts.len() + 2));

        std::thread::scope(|scope| -> Result<Vec<f32>> {
            // The channel lives inside the scope so that on an early error
            // return the receiver is dropped *before* the implicit join,
            // unblocking producers stuck in `send`.
            let (tx, rx) =
                std::sync::mpsc::sync_channel::<(usize, Result<ReadyBatch>)>(depth * producers);
            for mut client in clients {
                let tx = tx.clone();
                let feed = &feed;
                let fanouts = &fanouts;
                let sample_cfg = &sample_cfg;
                let features = features.clone();
                let pool = &pool;
                scope.spawn(move || {
                    while let Some(item) = feed.next() {
                        let index = item.index;
                        let out = produce_batch(
                            &mut client,
                            &features,
                            fanouts,
                            sample_cfg,
                            sample_seed,
                            item,
                            Some(pool),
                        );
                        let failed = out.is_err();
                        if tx.send((index, out)).is_err() || failed {
                            break;
                        }
                    }
                });
            }
            drop(tx);

            let feed = &feed;
            let consume = |trainer: &mut Self| -> Result<Vec<f32>> {
                let mut losses = Vec::with_capacity(steps);
                let mut reorder: Reorder<ReadyBatch> = Reorder::new(base);
                while losses.len() < steps {
                    if pcfg.ordered {
                        if let Some(rb) = reorder.pop_ready() {
                            losses.push(trainer.execute_ready_pooled(rb, Some(&pool))?);
                            feed.mark_consumed();
                            continue;
                        }
                    }
                    let (index, rb) = rx.recv().map_err(|_| {
                        anyhow::anyhow!("batch producers exited before delivering all batches")
                    })?;
                    let rb = rb.with_context(|| format!("producing batch {index}"))?;
                    if pcfg.ordered {
                        reorder.push(index, rb);
                    } else {
                        losses.push(trainer.execute_ready_pooled(rb, Some(&pool))?);
                        feed.mark_consumed();
                    }
                }
                Ok(losses)
            };
            let result = consume(self);
            // Wake any producer parked on the in-flight window before the
            // scope joins (the dropped receiver handles those in `send`).
            feed.close();
            result
        })
    }

    /// Predicted class per seed via the eval artifact.
    pub fn predict(&mut self, seeds: &[VId]) -> Result<Vec<usize>> {
        assert_eq!(seeds.len(), self.batch);
        let tree = self.sample_batch(seeds)?;
        let inputs = self.model_inputs(&tree, None, None);
        let out = self
            .runtime
            .execute(&format!("{}_eval", self.cfg.model), &inputs)?;
        let logits = out[0].as_f32();
        let classes = out[0].shape()[1];
        Ok((0..seeds.len())
            .map(|i| {
                let row = &logits[i * classes..(i + 1) * classes];
                row.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .unwrap()
                    .0
            })
            .collect())
    }

    /// Accuracy over a labeled evaluation set (batched; remainder dropped).
    pub fn evaluate(&mut self, seeds: &[VId], labels: &[u16]) -> Result<f64> {
        let mut correct = 0usize;
        let mut total = 0usize;
        for (chunk_s, chunk_l) in seeds.chunks(self.batch).zip(labels.chunks(self.batch)) {
            if chunk_s.len() < self.batch {
                break;
            }
            let preds = self.predict(chunk_s)?;
            for (p, &l) in preds.iter().zip(chunk_l) {
                correct += (*p == l as usize) as usize;
                total += 1;
            }
        }
        anyhow::ensure!(total > 0, "evaluation set smaller than one batch");
        Ok(correct as f64 / total as f64)
    }
}

/// Timing breakdown of one synchronous round. Logical trainers execute
/// sequentially on this testbed; in the paper's deployment they run in
/// parallel, so the simulated round time is `max(trainer_secs) +
/// apply_secs` (stragglers + the synchronization barrier — the mechanism
/// behind Fig. 12's ~0.8 scaling slope).
pub struct SyncRoundReport {
    pub loss: f32,
    pub trainer_secs: Vec<f64>,
    pub apply_secs: f64,
}

impl SyncRoundReport {
    pub fn simulated_secs(&self) -> f64 {
        self.trainer_secs.iter().cloned().fold(0f64, f64::max) + self.apply_secs
    }
}

/// One synchronous data-parallel round (Fig. 12): every trainer computes
/// gradients on its own batch from shared parameters; the leader averages
/// and applies.
pub fn sync_round(
    trainers: &mut [Trainer],
    batchers: &mut [Batcher],
    lr: f32,
) -> Result<SyncRoundReport> {
    // Broadcast leader parameters.
    let leader_params = trainers[0].params.clone();
    let mut all_grads = Vec::with_capacity(trainers.len());
    let mut loss_sum = 0f32;
    let mut trainer_secs = Vec::with_capacity(trainers.len());
    for (t, b) in trainers.iter_mut().zip(batchers.iter_mut()) {
        t.params = leader_params.clone();
        let (seeds, labels) = b.next_batch();
        let timer = crate::util::timer::Timer::start();
        let (loss, grads) = t.grad_step(&seeds, &labels)?;
        trainer_secs.push(timer.secs());
        loss_sum += loss;
        all_grads.push(grads);
    }
    let timer = crate::util::timer::Timer::start();
    let avg = average_grads(&all_grads);
    let n = trainers.len();
    trainers[0].params.sgd(&avg, lr);
    Ok(SyncRoundReport {
        loss: loss_sum / n as f32,
        trainer_secs,
        apply_secs: timer.secs(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generator;
    use crate::partition::{AdaDNE, Partitioner};
    use crate::sampling::service::SamplingService;
    use std::sync::Arc;

    fn test_graph() -> crate::graph::csr::Graph {
        let mut rng = Rng::new(210);
        generator::labeled_community_graph(2000, 24_000, 8, 0.9, &mut rng)
    }

    /// A trainer + batcher wired to `svc` with fixed seeds — calling it
    /// twice against one service yields identically-initialized trainers
    /// (responses are derived per seed occurrence from (salt, index), so
    /// sharing the service is interference-free), which is what the
    /// bit-exactness tests compare.
    fn twin(svc: &SamplingService) -> (Trainer, Batcher) {
        let dir = crate::test_artifacts_dir();
        let labels = Arc::new(test_graph().label);
        let features = FeatureStore::labeled(64, labels.clone(), 8, 0.6);
        let trainer = Trainer::new(
            &dir,
            svc.client(3),
            features,
            TrainerConfig {
                model: "sage".into(),
                lr: 0.1,
            },
            7,
        )
        .unwrap();
        let seeds: Vec<VId> = (0..1000).collect();
        let lab: Vec<u16> = seeds.iter().map(|&v| labels[v as usize]).collect();
        let batcher = Batcher::new(seeds, lab, trainer.batch, 5).unwrap();
        (trainer, batcher)
    }

    fn stack() -> (SamplingService, Trainer, Batcher) {
        let g = test_graph();
        let ea = AdaDNE::default().partition(&g, 2, 0);
        // A 2-worker pool with mid-request shard splits: the bit-exactness
        // tests below thereby also pin the pool path to the sync semantics
        // (per-seed server streams, DESIGN.md §9).
        let svc = SamplingService::launch_cfg(
            &g,
            &ea,
            1,
            crate::sampling::ServiceConfig::new(2, 48),
        )
        .unwrap();
        let (trainer, batcher) = twin(&svc);
        (svc, trainer, batcher)
    }

    #[test]
    fn train_step_runs_and_updates_params() {
        let (svc, mut t, mut b) = stack();
        let before = t.params.tensors[0].as_f32().to_vec();
        let (seeds, labels) = b.next_batch();
        let loss = t.train_step(&seeds, &labels).unwrap();
        assert!(loss.is_finite() && loss > 0.0);
        assert_ne!(before, t.params.tensors[0].as_f32());
        svc.shutdown();
    }

    #[test]
    fn loss_decreases_over_training() {
        let (svc, mut t, mut b) = stack();
        let losses = t.train(&mut b, 30).unwrap();
        let head: f32 = losses[..5].iter().sum::<f32>() / 5.0;
        let tail: f32 = losses[losses.len() - 5..].iter().sum::<f32>() / 5.0;
        assert!(
            tail < head,
            "loss should fall: head {head:.3} tail {tail:.3} ({losses:?})"
        );
        svc.shutdown();
    }

    #[test]
    fn grad_step_matches_train_step_arity() {
        let (svc, mut t, mut b) = stack();
        let (seeds, labels) = b.next_batch();
        let (loss, grads) = t.grad_step(&seeds, &labels).unwrap();
        assert!(loss.is_finite());
        assert_eq!(grads.len(), t.params.len());
        for (g, p) in grads.iter().zip(&t.params.tensors) {
            assert_eq!(g.shape(), p.shape());
        }
        svc.shutdown();
    }

    #[test]
    fn ordered_pipelined_matches_sync_losses_bit_exactly() {
        let (svc, mut t_sync, mut b_sync) = stack();
        let sync_losses = t_sync.train(&mut b_sync, 8).unwrap();

        let (mut t_pipe, mut b_pipe) = twin(&svc);
        let pcfg = PipelineConfig {
            producers: 3,
            queue_depth: 2,
            ordered: true,
        };
        let pipe_losses = t_pipe.train_pipelined(&mut b_pipe, 8, &pcfg).unwrap();

        assert_eq!(
            sync_losses, pipe_losses,
            "ordered pipelined training must reproduce the sync loss curve"
        );
        assert_eq!(
            t_sync.params.tensors[0].as_f32(),
            t_pipe.params.tensors[0].as_f32(),
            "parameters must match bit-for-bit too"
        );
        assert_eq!(t_pipe.steps_taken(), 8);
        svc.shutdown();
    }

    #[test]
    fn pipelined_runs_resume_after_sync_steps() {
        // Mixing modes keeps one global step sequence: sync, then
        // pipelined, then sync again equals an all-sync run.
        let (svc, mut a, mut ba) = stack();
        let la = a.train(&mut ba, 6).unwrap();

        let (mut b, mut bb) = twin(&svc);
        let pcfg = PipelineConfig::default();
        let mut lb = b.train(&mut bb, 2).unwrap();
        lb.extend(b.train_pipelined(&mut bb, 3, &pcfg).unwrap());
        lb.extend(b.train(&mut bb, 1).unwrap());
        assert_eq!(la, lb);
        svc.shutdown();
    }

    #[test]
    fn unordered_pipelined_still_converges() {
        let (svc, mut t, mut b) = stack();
        let pcfg = PipelineConfig {
            producers: 2,
            queue_depth: 2,
            ordered: false,
        };
        let losses = t.train_pipelined(&mut b, 30, &pcfg).unwrap();
        assert_eq!(losses.len(), 30);
        assert!(losses.iter().all(|l| l.is_finite()));
        let head: f32 = losses[..5].iter().sum::<f32>() / 5.0;
        let tail: f32 = losses[losses.len() - 5..].iter().sum::<f32>() / 5.0;
        assert!(
            tail < head,
            "unordered pipelined loss should fall: head {head:.3} tail {tail:.3}"
        );
        svc.shutdown();
    }
}
