//! # GLISP — Graph Learning driven by Inherent Structural Properties
//!
//! A from-scratch reproduction of *"GLISP: A Scalable GNN Learning System by
//! Exploiting Inherent Structural Properties of Graphs"* (Zhu et al., Ant
//! Group, 2024) as a three-layer Rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the distributed systems contribution:
//!   [`partition`] (AdaDNE vertex-cut partitioner + baselines), [`sampling`]
//!   (Gather-Apply K-hop neighbor sampling service), [`inference`]
//!   (layerwise inference engine with the two-level embedding cache), and
//!   the [`coordinator`] training loop.
//! * **Layer 2/1 (python/, build-time only)** — GNN models and Pallas
//!   kernels, AOT-lowered to HLO text; [`runtime`] loads and executes the
//!   artifacts on the PJRT CPU client. Python never runs on the request
//!   path.
//!
//! See DESIGN.md for the experiment index and EXPERIMENTS.md for measured
//! results.

pub mod cli;
pub mod coordinator;
pub mod graph;
pub mod harness;
pub mod inference;
pub mod partition;
pub mod runtime;
pub mod sampling;
pub mod util;

/// Artifacts directory for tests: Some(dir) iff `make artifacts` has run.
/// Tests that need AOT artifacts self-skip otherwise.
pub fn test_artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = runtime::Runtime::default_dir();
    let dir = if dir.is_relative() {
        // Tests run from the workspace root; examples may chdir.
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join(dir)
    } else {
        dir
    };
    dir.join("manifest.json").exists().then_some(dir)
}
