//! # GLISP — Graph Learning driven by Inherent Structural Properties
//!
//! A from-scratch reproduction of *"GLISP: A Scalable GNN Learning System by
//! Exploiting Inherent Structural Properties of Graphs"* (Zhu et al., Ant
//! Group, 2024) as a three-layer Rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the distributed systems contribution:
//!   [`partition`] (AdaDNE vertex-cut partitioner + baselines), [`sampling`]
//!   (Gather-Apply K-hop neighbor sampling service), [`inference`]
//!   (layerwise inference engine with the two-level embedding cache),
//!   [`serving`] (request-driven online serving over the K-slice engine),
//!   and the [`coordinator`] training loop.
//! * **Layer 2/1 (python/, build-time only)** — GNN models and Pallas
//!   kernels, AOT-lowered to HLO text. Python never runs on the request
//!   path.
//! * **[`runtime`]** — manifest-validated artifact execution behind the
//!   [`runtime::ExecutorBackend`] seam: the hermetic pure-Rust reference
//!   backend by default, PJRT/XLA behind the `pjrt` cargo feature.
//!
//! See README.md for build/test instructions, DESIGN.md for the experiment
//! index and EXPERIMENTS.md for measured results.

pub mod cli;
pub mod coordinator;
pub mod graph;
pub mod harness;
pub mod inference;
pub mod partition;
pub mod runtime;
pub mod sampling;
pub mod serving;
pub mod util;

/// Artifacts directory for tests, benches and examples, resolved relative
/// to the workspace root (examples may chdir). The directory may not
/// exist: [`runtime::Runtime::load`] degrades to the built-in reference
/// backend when `manifest.json` is absent, so callers no longer self-skip.
pub fn test_artifacts_dir() -> std::path::PathBuf {
    let dir = runtime::Runtime::default_dir();
    if dir.is_relative() {
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join(dir)
    } else {
        dir
    }
}
