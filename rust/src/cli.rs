//! Minimal CLI argument parser (clap is not in the offline vendor set).
//! Supports `glisp <subcommand> --flag value --switch` with typed lookups,
//! e.g. `glisp train --model sage --server-workers 4 --shard-size 16`
//! (the sampling-pool knobs shared by the CLI and the examples).

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub flags: BTreeMap<String, String>,
    pub switches: Vec<String>,
    /// Bare tokens after the subcommand that are neither a `--flag`'s name
    /// nor its value, in order — e.g. the bench names in
    /// `glisp bench fig13 table5 --report`.
    pub positionals: Vec<String>,
}

impl Args {
    pub fn parse(argv: impl Iterator<Item = String>) -> Args {
        let mut out = Args::default();
        let mut argv = argv.peekable();
        if let Some(first) = argv.peek() {
            if !first.starts_with('-') {
                out.subcommand = argv.next();
            }
        }
        while let Some(a) = argv.next() {
            if let Some(name) = a.strip_prefix("--") {
                match argv.peek() {
                    Some(v) if !v.starts_with("--") => {
                        let v = argv.next().unwrap();
                        out.flags.insert(name.to_string(), v);
                    }
                    _ => out.switches.push(name.to_string()),
                }
            } else {
                out.positionals.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Self::parse(std::env::args().skip(1))
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(String::as_str)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_u64(&self, name: &str, default: u64) -> u64 {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_str<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn has(&self, name: &str) -> bool {
        self.switches.iter().any(|s| s == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(str::to_string))
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse("train --model sage --steps 100 --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.get("model"), Some("sage"));
        assert_eq!(a.get_usize("steps", 0), 100);
        assert!(a.has("verbose"));
        assert!(!a.has("quiet"));
    }

    #[test]
    fn defaults() {
        let a = parse("");
        assert_eq!(a.subcommand, None);
        assert_eq!(a.get_usize("x", 7), 7);
        assert_eq!(a.get_str("m", "gcn"), "gcn");
    }

    #[test]
    fn negative_number_values() {
        let a = parse("x --alpha -1.5");
        assert_eq!(a.get_f64("alpha", 0.0), -1.5);
    }

    #[test]
    fn positionals_after_subcommand() {
        let a = parse("bench fig13 table5 --report --scale 0.25");
        assert_eq!(a.subcommand.as_deref(), Some("bench"));
        assert_eq!(a.positionals, vec!["fig13", "table5"]);
        assert!(a.has("report"));
        assert_eq!(a.get_f64("scale", 1.0), 0.25);
        // A flag's value is consumed by the flag, never misread as a
        // positional.
        let a = parse("bench --scale 0.25 fig13");
        assert_eq!(a.positionals, vec!["fig13"]);
    }
}
