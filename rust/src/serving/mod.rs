//! Online inference serving (DESIGN.md §15): a request-driven front end
//! over the layerwise K-slice engine. Offline, the engine sweeps every
//! vertex once per slice; online, a request for a handful of vertices must
//! not pay a full sweep — so the serving engine keeps one *slab* per slice
//! (chunk store + [`CacheSystem`] + validity bitmap) and resolves each
//! request by expanding its K-hop need-set top-down, **truncating the
//! frontier at every row a slab already holds**, then executing only the
//! uncached remainder bottom-up through the same `sage_infer_layer{k}`
//! artifacts the offline sweep runs.
//!
//! Determinism contract: the serving path follows the engine's pre-sampled
//! one-hop [`LayerwiseEngine::neighbor_snapshot`] and executes the same
//! per-row math (`execute_rows` output is independent of how rows are
//! blocked — the engine's tail-block test pins this), so every served
//! embedding is bit-identical to the offline sweep's row for the same
//! snapshot, cold or warm, whatever the request order.
//!
//! Cache warmup: [`ServingEngine::warm`] runs the offline pass once through
//! the [`LayerwiseEngine::run_vertex_embedding_with`] observer seam; every
//! slice's activations land in the slabs, all chunks are flushed, and the
//! static tier is pre-populated — after which requests are pure cache reads
//! (`rows_computed == 0`). Cold slabs fill on demand instead: computed rows
//! live in the slab arena (counted as dynamic hits) until their chunk
//! completes and graduates to the store's static/dynamic read path.
//!
//! Eviction is per request class ([`ServingConfig`]): embedding resolution
//! reads through each slab's own cache under `embed_policy`, while link
//! scoring reads final embeddings through a dedicated cache under
//! `link_policy` — the two traffic classes never thrash each other.

use anyhow::{Context, Result};

use crate::graph::csr::VId;
use crate::inference::chunk_store::ChunkStore;
use crate::inference::dynamic_cache::EvictPolicy;
use crate::inference::engine::{EngineReport, LayerwiseEngine};
use crate::inference::static_cache::CacheSystem;
use crate::runtime::tensor::HostTensor;
use crate::sampling::request::PAD;
use crate::util::bitset::BitSet;

/// Serving knobs: the dynamic-tier eviction policy per request class and
/// the cache sizing fraction (mirrors `EngineConfig::dyn_cache_frac`).
#[derive(Clone, Copy, Debug)]
pub struct ServingConfig {
    /// Eviction policy of every slab cache on the embedding-resolution path.
    pub embed_policy: EvictPolicy,
    /// Eviction policy of the dedicated final-embedding cache the
    /// link-scoring path reads through.
    pub link_policy: EvictPolicy,
    /// Fraction of a slab's chunks held by its dynamic tier (floored at 4).
    pub dyn_cache_frac: f64,
}

impl Default for ServingConfig {
    fn default() -> Self {
        Self {
            embed_policy: EvictPolicy::Fifo,
            link_policy: EvictPolicy::Fifo,
            dyn_cache_frac: 0.1,
        }
    }
}

/// Cumulative serving counters plus the per-tier read totals aggregated
/// across the feature store and every slab store.
#[derive(Clone, Copy, Debug, Default)]
pub struct ServingStats {
    /// `embed`/`link_scores` calls served.
    pub requests: u64,
    /// Vertices whose final embedding was returned.
    pub vertices_served: u64,
    /// Vertex-slice computations executed (the online redundancy metric —
    /// 0 once warm).
    pub rows_computed: u64,
    /// Need-set expansions stopped at an already-valid slab row (the
    /// frontier-truncation counter).
    pub rows_truncated: u64,
    pub remote_reads: u64,
    pub static_reads: u64,
    pub dynamic_hits: u64,
}

impl ServingStats {
    fn total_reads(&self) -> u64 {
        self.remote_reads + self.static_reads + self.dynamic_hits
    }

    /// Fraction of reads served by the static tier.
    pub fn static_hit_ratio(&self) -> f64 {
        let t = self.total_reads();
        if t == 0 {
            0.0
        } else {
            self.static_reads as f64 / t as f64
        }
    }

    /// Fraction of reads served from memory (dynamic tier + slab arena).
    pub fn dynamic_hit_ratio(&self) -> f64 {
        let t = self.total_reads();
        if t == 0 {
            0.0
        } else {
            self.dynamic_hits as f64 / t as f64
        }
    }
}

/// One slice's serving state: the `serve_h{k}` chunk store, its two-tier
/// cache, the rank-indexed validity bitmap, and the resident arena holding
/// rows whose chunk has not completed yet.
struct LayerSlab {
    store: ChunkStore,
    cache: CacheSystem,
    /// Rank-indexed rows materialized (by a request or by warmup).
    valid: BitSet,
    /// Chunks written to the store (complete — readable through the cache).
    flushed: BitSet,
    /// Rank-indexed `[n, dim]` arena; a row is meaningful iff `valid`.
    host: Vec<f32>,
}

impl LayerSlab {
    fn new(
        dir: std::path::PathBuf,
        n: usize,
        chunk_size: usize,
        dim: usize,
        dyn_cap: usize,
        policy: EvictPolicy,
    ) -> Result<Self> {
        let store = ChunkStore::create(dir, n, chunk_size, dim)?;
        let num_chunks = store.num_chunks;
        Ok(Self {
            store,
            cache: CacheSystem::new(num_chunks, dyn_cap, policy),
            valid: BitSet::new(n),
            flushed: BitSet::new(num_chunks),
            host: vec![0f32; n * dim],
        })
    }

    /// Read one valid row: through the cache hierarchy when its chunk has
    /// been flushed, else straight from the arena (a memory read, counted
    /// as a dynamic hit like the engine's block-memo reuse).
    fn read_row(&mut self, r: usize, out: &mut [f32]) -> Result<()> {
        debug_assert!(self.valid.get(r), "read of unmaterialized row {r}");
        let dim = self.store.dim;
        let c = self.store.chunk_of_row(r);
        if self.flushed.get(c) {
            let data = self.cache.get_chunk(&self.store, c)?;
            let off = (r - c * self.store.chunk_size) * dim;
            out.copy_from_slice(&data[off..off + dim]);
        } else {
            self.store.note_dynamic_hit();
            out.copy_from_slice(&self.host[r * dim..(r + 1) * dim]);
        }
        Ok(())
    }

    /// Land freshly-computed rows (`data` is `[rows.len(), dim]` in `rows`
    /// order, ascending): copy into the arena, mark valid, and flush any
    /// chunk whose rows are now all valid — from then on it is served
    /// through the store's tiered read path.
    fn put_rows(&mut self, rows: &[usize], data: &[f32]) -> Result<()> {
        let dim = self.store.dim;
        debug_assert_eq!(data.len(), rows.len() * dim);
        let mut touched: Vec<usize> = Vec::new();
        for (i, &r) in rows.iter().enumerate() {
            self.host[r * dim..(r + 1) * dim].copy_from_slice(&data[i * dim..(i + 1) * dim]);
            self.valid.set(r);
            let c = self.store.chunk_of_row(r);
            if touched.last() != Some(&c) {
                touched.push(c);
            }
        }
        for c in touched {
            if self.flushed.get(c) {
                continue;
            }
            let lo = c * self.store.chunk_size;
            let hi = (lo + self.store.chunk_size).min(self.store.n_rows);
            if (lo..hi).all(|r| self.valid.get(r)) {
                self.store.write_chunk(c, &self.host[lo * dim..hi * dim])?;
                self.flushed.set(c);
            }
        }
        Ok(())
    }

    /// Warmup: absorb a complete rank-indexed `[n, dim]` slice output —
    /// every row valid, every chunk flushed and pinned in the static tier.
    fn absorb_full(&mut self, h: &[f32]) -> Result<()> {
        let dim = self.store.dim;
        debug_assert_eq!(h.len(), self.store.n_rows * dim);
        self.host.copy_from_slice(h);
        for r in 0..self.store.n_rows {
            self.valid.set(r);
        }
        for c in 0..self.store.num_chunks {
            let lo = c * self.store.chunk_size;
            let hi = (lo + self.store.chunk_size).min(self.store.n_rows);
            self.store.write_chunk(c, &self.host[lo * dim..hi * dim])?;
            self.flushed.set(c);
        }
        self.cache.fill_static(0..self.store.num_chunks);
        Ok(())
    }
}

/// Request-driven serving front end over a [`LayerwiseEngine`]. Owns the
/// engine (snapshot, runtime, params) plus one [`LayerSlab`] per slice;
/// slab k holds slice k's output, so slab K−1 is the final embedding tier.
pub struct ServingEngine {
    pub engine: LayerwiseEngine,
    pub cfg: ServingConfig,
    /// Layer-0 input: the feature matrix by rank, fully materialized at
    /// construction (features are a pure function of the vertex id) and
    /// pinned static — the base tier every cold request bottoms out on.
    f_store: ChunkStore,
    f_cache: CacheSystem,
    slabs: Vec<LayerSlab>,
    /// The link-scoring class's own cache over the final slab's store.
    link_cache: CacheSystem,
    warmed: bool,
    requests: u64,
    vertices_served: u64,
    rows_computed: u64,
    rows_truncated: u64,
}

impl ServingEngine {
    pub fn new(engine: LayerwiseEngine, cfg: ServingConfig) -> Result<Self> {
        let n = engine.num_vertices();
        let hidden = engine.hidden();
        let chunk_size = engine.cfg.chunk_size;
        let din = engine.features.din;

        let f_store = ChunkStore::create(engine.work_dir().join("serve_f"), n, chunk_size, din)?;
        engine
            .features
            .for_each_chunk(&engine.order, chunk_size, |c, rows| {
                f_store.write_chunk(c, rows)
            })?;
        let dyn_cap = |chunks: usize| -> usize {
            ((chunks as f64 * cfg.dyn_cache_frac).ceil() as usize).max(4)
        };
        let mut f_cache =
            CacheSystem::new(f_store.num_chunks, dyn_cap(f_store.num_chunks), cfg.embed_policy);
        f_cache.fill_static(0..f_store.num_chunks);

        let slabs = (0..engine.cfg.layers)
            .map(|k| {
                LayerSlab::new(
                    engine.work_dir().join(format!("serve_h{k}")),
                    n,
                    chunk_size,
                    hidden,
                    dyn_cap(n.div_ceil(chunk_size)),
                    cfg.embed_policy,
                )
            })
            .collect::<Result<Vec<_>>>()?;
        let num_chunks = n.div_ceil(chunk_size);
        let link_cache = CacheSystem::new(num_chunks, dyn_cap(num_chunks), cfg.link_policy);
        Ok(Self {
            engine,
            cfg,
            f_store,
            f_cache,
            slabs,
            link_cache,
            warmed: false,
            requests: 0,
            vertices_served: 0,
            rows_computed: 0,
            rows_truncated: 0,
        })
    }

    /// Whether [`Self::warm`] has run.
    pub fn warmed(&self) -> bool {
        self.warmed
    }

    /// Final embedding width.
    pub fn hidden(&self) -> usize {
        self.engine.hidden()
    }

    /// Pre-populate every slab from one offline layerwise pass: each
    /// slice's full activations land via the engine's per-layer observer,
    /// chunks flush, and the static tiers fill. Subsequent requests compute
    /// nothing (`rows_computed` stays flat) and serve pure cache reads.
    pub fn warm(&mut self) -> Result<EngineReport> {
        let slabs = &mut self.slabs;
        let (_, rep) = self
            .engine
            .run_vertex_embedding_with(|layer, h| slabs[layer].absorb_full(h))?;
        self.warmed = true;
        Ok(rep)
    }

    /// Serve final embeddings for `verts` (request order), resolving the
    /// uncached frontier first. Bytes are bit-identical to the offline
    /// sweep's rows for the same engine snapshot.
    pub fn embed(&mut self, verts: &[VId]) -> Result<Vec<f32>> {
        self.ensure(verts)?;
        let hidden = self.engine.hidden();
        let last = self.engine.cfg.layers - 1;
        let mut out = vec![0f32; verts.len() * hidden];
        for (i, &v) in verts.iter().enumerate() {
            let r = self.engine.rank[v as usize] as usize;
            self.slabs[last].read_row(r, &mut out[i * hidden..(i + 1) * hidden])?;
        }
        self.requests += 1;
        self.vertices_served += verts.len() as u64;
        Ok(out)
    }

    /// Score candidate edges `(u, v)` with the `link_decode` artifact:
    /// endpoint embeddings resolve through the slabs, then read through the
    /// link class's dedicated cache. Bit-identical to
    /// [`LayerwiseEngine::run_link_prediction`] over the offline embeddings.
    pub fn link_scores(
        &mut self,
        edges: &[(VId, VId)],
        decode_params: &[HostTensor],
    ) -> Result<Vec<f32>> {
        let mut uniq: Vec<VId> = edges.iter().flat_map(|&(a, b)| [a, b]).collect();
        uniq.sort_unstable();
        uniq.dedup();
        self.ensure(&uniq)?;

        let hidden = self.engine.hidden();
        let spec = self.engine.runtime.spec("link_decode")?;
        let batch = spec.meta_usize("batch").context("meta.batch")?;
        let mut scores = Vec::with_capacity(edges.len());
        for chunk in edges.chunks(batch) {
            let rows = chunk.len();
            let mut u = vec![0f32; rows * hidden];
            let mut v = vec![0f32; rows * hidden];
            for (i, &(a, b)) in chunk.iter().enumerate() {
                self.read_final_row(a, &mut u[i * hidden..(i + 1) * hidden])?;
                self.read_final_row(b, &mut v[i * hidden..(i + 1) * hidden])?;
            }
            let mut inputs = vec![
                HostTensor::f32(vec![rows, hidden], u),
                HostTensor::f32(vec![rows, hidden], v),
            ];
            inputs.extend(decode_params.iter().cloned());
            // Only emb_u/emb_v are row-shaped (matches the engine's decode).
            let out = self.engine.runtime.execute_rows("link_decode", rows, 2, &inputs)?;
            scores.extend_from_slice(out[0].as_f32());
        }
        self.requests += 1;
        Ok(scores)
    }

    /// Final-row read on the link-scoring class: same store as slab K−1,
    /// but through the dedicated `link_policy` cache.
    fn read_final_row(&mut self, v: VId, out: &mut [f32]) -> Result<()> {
        let last = self.engine.cfg.layers - 1;
        let r = self.engine.rank[v as usize] as usize;
        let slab = &mut self.slabs[last];
        let dim = slab.store.dim;
        let c = slab.store.chunk_of_row(r);
        if slab.flushed.get(c) {
            let data = self.link_cache.get_chunk(&slab.store, c)?;
            let off = (r - c * slab.store.chunk_size) * dim;
            out.copy_from_slice(&data[off..off + dim]);
        } else {
            slab.store.note_dynamic_hit();
            out.copy_from_slice(&slab.host[r * dim..(r + 1) * dim]);
        }
        Ok(())
    }

    /// Resolve the request's K-hop need-set: expand top-down along the
    /// engine's pre-sampled neighbor snapshot, truncating at every row a
    /// slab already holds, then execute the remaining rows bottom-up slice
    /// by slice (each slice's inputs are complete by construction).
    fn ensure(&mut self, verts: &[VId]) -> Result<()> {
        let k_layers = self.engine.cfg.layers;
        let n = self.engine.num_vertices();
        let fanout = self.engine.fanout();

        let mut need: Vec<BitSet> = (0..k_layers).map(|_| BitSet::new(n)).collect();
        for &v in verts {
            let r = self.engine.rank[v as usize] as usize;
            if self.slabs[k_layers - 1].valid.get(r) {
                self.rows_truncated += 1;
            } else {
                need[k_layers - 1].set(r);
            }
        }
        for k in (1..k_layers).rev() {
            let rows: Vec<usize> = need[k].iter_ones().collect();
            let nbrs = self.engine.neighbor_snapshot();
            for r in rows {
                let v = self.engine.order[r] as usize;
                // Slice k reads slice k−1's rows for v and its snapshot
                // neighbors; a valid row is the truncated frontier.
                if self.slabs[k - 1].valid.get(r) {
                    self.rows_truncated += 1;
                } else {
                    need[k - 1].set(r);
                }
                for s in 0..fanout {
                    let nb = nbrs[v * fanout + s];
                    if nb == PAD {
                        continue;
                    }
                    let nr = self.engine.rank[nb as usize] as usize;
                    if self.slabs[k - 1].valid.get(nr) {
                        self.rows_truncated += 1;
                    } else {
                        need[k - 1].set(nr);
                    }
                }
            }
        }
        for (k, need_k) in need.iter().enumerate() {
            let rows: Vec<usize> = need_k.iter_ones().collect();
            if rows.is_empty() {
                continue;
            }
            self.compute_slice(k, &rows)?;
            self.rows_computed += rows.len() as u64;
        }
        Ok(())
    }

    /// Execute slice k for `rows` (ascending ranks): assemble h_self /
    /// h_neigh / mask from the slice's input tier, run the artifact in
    /// engine-sized blocks (`execute_rows` output is block-composition
    /// independent), and land the rows in slab k.
    fn compute_slice(&mut self, k: usize, rows: &[usize]) -> Result<()> {
        let in_dim = if k == 0 {
            self.engine.features.din
        } else {
            self.engine.hidden()
        };
        let hidden = self.engine.hidden();
        let fanout = self.engine.fanout();
        let block = self.engine.block_rows();
        let artifact = format!("sage_infer_layer{k}");
        let mut out_all = Vec::with_capacity(rows.len() * hidden);
        for blk in rows.chunks(block) {
            let nrows = blk.len();
            let mut h_self = vec![0f32; nrows * in_dim];
            let mut h_neigh = vec![0f32; nrows * fanout * in_dim];
            let mut mask = vec![0f32; nrows * fanout];
            {
                let nbrs = self.engine.neighbor_snapshot();
                for (i, &r) in blk.iter().enumerate() {
                    let v = self.engine.order[r] as usize;
                    let dst = &mut h_self[i * in_dim..(i + 1) * in_dim];
                    if k == 0 {
                        read_cached_row(&mut self.f_cache, &self.f_store, r, dst)?;
                    } else {
                        self.slabs[k - 1].read_row(r, dst)?;
                    }
                    for s in 0..fanout {
                        let nb = nbrs[v * fanout + s];
                        if nb == PAD {
                            continue;
                        }
                        let nr = self.engine.rank[nb as usize] as usize;
                        let off = (i * fanout + s) * in_dim;
                        let dst = &mut h_neigh[off..off + in_dim];
                        if k == 0 {
                            read_cached_row(&mut self.f_cache, &self.f_store, nr, dst)?;
                        } else {
                            self.slabs[k - 1].read_row(nr, dst)?;
                        }
                        mask[i * fanout + s] = 1.0;
                    }
                }
            }
            let mut inputs = vec![
                HostTensor::f32(vec![nrows, in_dim], h_self),
                HostTensor::f32(vec![nrows, fanout, in_dim], h_neigh),
                HostTensor::f32(vec![nrows, fanout], mask),
            ];
            inputs.extend(self.engine.enc_params[k * 3..k * 3 + 3].iter().cloned());
            let out = self.engine.runtime.execute_rows(&artifact, nrows, 3, &inputs)?;
            out_all.extend_from_slice(&out[0].as_f32()[..nrows * hidden]);
        }
        self.slabs[k].put_rows(rows, &out_all)
    }

    /// Cumulative counters plus per-tier read totals across the feature
    /// store and every slab store (the link cache reads the final slab's
    /// store, so its traffic is included).
    pub fn stats(&self) -> ServingStats {
        use std::sync::atomic::Ordering::Relaxed;
        let mut s = ServingStats {
            requests: self.requests,
            vertices_served: self.vertices_served,
            rows_computed: self.rows_computed,
            rows_truncated: self.rows_truncated,
            ..Default::default()
        };
        for store in std::iter::once(&self.f_store).chain(self.slabs.iter().map(|sl| &sl.store)) {
            s.remote_reads += store.stats.remote_reads.load(Relaxed);
            s.static_reads += store.stats.static_reads.load(Relaxed);
            s.dynamic_hits += store.stats.dynamic_hits.load(Relaxed);
        }
        s
    }
}

/// One-row read through a cache over a fully-flushed store (the feature
/// tier and the link path share this shape).
fn read_cached_row(
    cache: &mut CacheSystem,
    store: &ChunkStore,
    r: usize,
    out: &mut [f32],
) -> Result<()> {
    let c = store.chunk_of_row(r);
    let data = cache.get_chunk(store, c)?;
    let off = (r - c * store.chunk_size) * store.dim;
    out.copy_from_slice(&data[off..off + store.dim]);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::FeatureStore;
    use crate::graph::csr::Graph;
    use crate::graph::generator;
    use crate::inference::engine::{init_decode_params, init_encoder_params};
    use crate::inference::EngineConfig;
    use crate::partition::{AdaDNE, EdgeAssignment, Partitioner};
    use crate::runtime::Runtime;
    use crate::util::digest::f32_digest;
    use crate::util::rng::Rng;

    fn setup(name: &str) -> (Graph, EdgeAssignment, std::path::PathBuf) {
        let mut rng = Rng::new(310);
        let g = generator::chung_lu(900, 6300, 2.1, &mut rng);
        let ea = AdaDNE::default().partition(&g, 2, 0);
        let dir = std::env::temp_dir().join(format!("glisp_serving_{name}"));
        let _ = std::fs::remove_dir_all(&dir);
        (g, ea, dir)
    }

    fn engine(g: &Graph, ea: &EdgeAssignment, dir: std::path::PathBuf) -> LayerwiseEngine {
        let runtime = Runtime::load(crate::test_artifacts_dir()).unwrap();
        let enc = init_encoder_params(&runtime, 3).unwrap();
        LayerwiseEngine::new(
            g,
            ea,
            runtime,
            FeatureStore::unlabeled(64),
            enc,
            EngineConfig::default(),
            dir,
        )
        .unwrap()
    }

    /// Offline rank-indexed rows gathered in request order — the reference
    /// bytes every serving read must reproduce.
    fn offline_rows(h: &[f32], eng: &LayerwiseEngine, verts: &[VId]) -> Vec<f32> {
        let hid = eng.hidden();
        let mut out = Vec::with_capacity(verts.len() * hid);
        for &v in verts {
            let r = eng.rank[v as usize] as usize;
            out.extend_from_slice(&h[r * hid..(r + 1) * hid]);
        }
        out
    }

    #[test]
    fn cold_serving_is_bit_identical_to_offline() {
        let (g, ea, dir) = setup("cold");
        let mut off = engine(&g, &ea, dir.join("off"));
        let (h, _) = off.run_vertex_embedding().unwrap();

        let mut srv = ServingEngine::new(engine(&g, &ea, dir.join("on")), Default::default())
            .unwrap();
        let verts: Vec<VId> = (0..g.n as VId).step_by(7).collect();
        let got = srv.embed(&verts).unwrap();
        let want = offline_rows(&h, &off, &verts);
        assert_eq!(
            f32_digest(&got),
            f32_digest(&want),
            "cold-served bytes must bit-match the offline sweep"
        );
        assert_eq!(got, want);
        let st = srv.stats();
        assert!(st.rows_computed > 0, "cold path must execute the frontier");
        assert_eq!(st.vertices_served, verts.len() as u64);
    }

    #[test]
    fn warm_serving_matches_cold_and_computes_nothing() {
        let (g, ea, dir) = setup("warm");
        let mut off = engine(&g, &ea, dir.join("off"));
        let (h, _) = off.run_vertex_embedding().unwrap();

        let mut srv = ServingEngine::new(engine(&g, &ea, dir.join("on")), Default::default())
            .unwrap();
        srv.warm().unwrap();
        assert!(srv.warmed());
        let verts: Vec<VId> = (0..g.n as VId).step_by(3).collect();
        let got = srv.embed(&verts).unwrap();
        assert_eq!(got, offline_rows(&h, &off, &verts), "warm reads must serve offline bytes");
        let st = srv.stats();
        assert_eq!(st.rows_computed, 0, "a warmed engine computes nothing");
        assert!(st.rows_truncated >= verts.len() as u64);
        assert!(st.static_reads + st.dynamic_hits > 0);
        assert_eq!(st.remote_reads, 0, "warm tier covers every chunk");
    }

    #[test]
    fn frontier_truncation_makes_repeats_free() {
        let (g, ea, dir) = setup("trunc");
        let mut srv = ServingEngine::new(engine(&g, &ea, dir), Default::default()).unwrap();
        let verts: Vec<VId> = (0..40).collect();
        let first = srv.embed(&verts).unwrap();
        let computed_once = srv.stats().rows_computed;
        assert!(computed_once > 0);
        let second = srv.embed(&verts).unwrap();
        assert_eq!(first, second, "repeat requests serve identical bytes");
        assert_eq!(
            srv.stats().rows_computed,
            computed_once,
            "a fully-cached repeat request executes zero rows"
        );
        assert!(srv.stats().rows_truncated >= verts.len() as u64);
    }

    #[test]
    fn link_scores_match_offline_link_prediction_per_policy() {
        let (g, ea, dir) = setup("link");
        let mut off = engine(&g, &ea, dir.join("off"));
        let (h, _) = off.run_vertex_embedding().unwrap();
        let dec = init_decode_params(&off.runtime, 9).unwrap();
        let edges: Vec<(VId, VId)> = (0..g.n.min(200))
            .filter(|&u| !g.out_neighbors(u as VId).is_empty())
            .map(|u| (u as VId, g.out_neighbors(u as VId)[0]))
            .collect();
        let (want, _) = off.run_link_prediction(&h, &edges, &dec).unwrap();

        for policy in [EvictPolicy::Fifo, EvictPolicy::Lru] {
            let cfg = ServingConfig {
                link_policy: policy,
                ..Default::default()
            };
            let sub = if policy == EvictPolicy::Fifo { "fifo" } else { "lru" };
            let mut srv =
                ServingEngine::new(engine(&g, &ea, dir.join(format!("on_{sub}"))), cfg).unwrap();
            let got = srv.link_scores(&edges, &dec).unwrap();
            assert_eq!(got, want, "online link scores must bit-match offline ({policy:?})");
        }
    }

    /// Property: warming the static tier changes only the fill/hit
    /// counters — never a served byte. Over arbitrary Chung-Lu graphs,
    /// engine geometries (chunk size, eviction, dynamic-tier fraction,
    /// parallel vs sequential sweep) and sampling-pool `(workers,
    /// shard_size)` geometries for the link-candidate fleet, a warm and a
    /// cold engine on the same snapshot serve digest-equal embeddings and
    /// link scores, while the warm one computes zero rows remotely.
    #[test]
    fn prop_warm_tier_changes_counters_never_bytes() {
        use crate::sampling::{SampleConfig, SamplingService, ServiceConfig, PAD};
        use crate::util::proptest::prop_check;

        prop_check("warm tier never changes served bytes", 3, |rng| {
            let n = 220 + rng.usize(200);
            let m = n * 4 + rng.usize(n * 3);
            let g = generator::chung_lu(n, m, 1.9 + rng.f64() * 0.5, rng);
            let parts = 1 + rng.usize(3);
            let ea = AdaDNE::default().partition(&g, parts, 0);
            let dir = std::env::temp_dir().join(format!("glisp_serving_prop_{}", rng.next_u64()));
            let _ = std::fs::remove_dir_all(&dir);

            let ecfg = EngineConfig {
                parallel: rng.usize(2) == 0,
                chunk_size: [48, 96, 160][rng.usize(3)],
                dyn_cache_frac: 0.05 + rng.f64() * 0.25,
                policy: if rng.usize(2) == 0 { EvictPolicy::Fifo } else { EvictPolicy::Lru },
                ..Default::default()
            };
            let build = |sub: &str| {
                let runtime = Runtime::load(crate::test_artifacts_dir()).unwrap();
                let enc = init_encoder_params(&runtime, 3).unwrap();
                let eng = LayerwiseEngine::new(
                    &g,
                    &ea,
                    runtime,
                    FeatureStore::unlabeled(64),
                    enc,
                    ecfg.clone(),
                    dir.join(sub),
                )
                .unwrap();
                ServingEngine::new(eng, ServingConfig::default()).unwrap()
            };
            let mut cold = build("cold");
            let mut warm = build("warm");
            warm.warm().map_err(|e| e.to_string())?;

            // A short skewed trace with repeats, through both engines.
            let trace: Vec<VId> = (0..60).map(|_| rng.usize(n.min(80)) as VId).collect();
            let a = cold.embed(&trace).map_err(|e| e.to_string())?;
            let b = warm.embed(&trace).map_err(|e| e.to_string())?;
            crate::prop_assert_eq!(f32_digest(&a), f32_digest(&b));
            crate::prop_assert_eq!(a, b);

            // Link candidates through an arbitrary (workers, shard_size)
            // pool geometry; scores must agree byte-for-byte too.
            let scfg = ServiceConfig::new(1 + rng.usize(3), [8, 64, 256][rng.usize(3)]);
            let svc = SamplingService::launch_cfg(&g, &ea, 1, scfg).map_err(|e| e.to_string())?;
            let mut client = svc.client(7);
            let seeds: Vec<VId> = (0..16.min(n) as VId).collect();
            let sample = client
                .sample_topk(&seeds, 4, &SampleConfig::default())
                .map_err(|e| e.to_string())?;
            let mut edges = Vec::new();
            for (i, &s) in seeds.iter().enumerate() {
                for &nb in sample.neighbors_of(i) {
                    if nb != PAD {
                        edges.push((s, nb));
                    }
                }
            }
            svc.shutdown();
            let dec = init_decode_params(&cold.engine.runtime, 9).unwrap();
            let sa = cold.link_scores(&edges, &dec).map_err(|e| e.to_string())?;
            let sb = warm.link_scores(&edges, &dec).map_err(|e| e.to_string())?;
            crate::prop_assert_eq!(f32_digest(&sa), f32_digest(&sb));

            // Only the counters may differ: the cold engine had to execute
            // its request frontiers, the warm one served pure cache reads.
            let (cs, ws) = (cold.stats(), warm.stats());
            crate::prop_assert!(cs.rows_computed > 0, "cold path executed nothing");
            crate::prop_assert_eq!(ws.rows_computed, 0u64);
            crate::prop_assert_eq!(ws.remote_reads, 0u64);
            crate::prop_assert!(
                ws.static_reads + ws.dynamic_hits > 0,
                "warm reads must be tier hits"
            );
            let _ = std::fs::remove_dir_all(&dir);
            Ok(())
        });
    }
}
