//! DistributedNE (Hanai et al., VLDB'19) — the SOTA vertex-cut baseline
//! AdaDNE builds on. Fixed expansion factor λ = 0.1, hard edge threshold
//! with imbalance factor τ (paper default 1.1).

use crate::graph::csr::Graph;
use crate::partition::expansion::{expand, ExpansionConfig, Policy};
use crate::partition::types::{EdgeAssignment, Partitioner};

pub struct DistributedNE {
    pub lambda: f64,
    pub tau: f64,
    /// Propose-phase worker threads (DESIGN.md §10). Pure throughput knob:
    /// the assignment is bit-identical for any value.
    pub threads: usize,
}

impl Default for DistributedNE {
    fn default() -> Self {
        Self {
            lambda: 0.1,
            tau: 1.1,
            threads: 1,
        }
    }
}

impl Partitioner for DistributedNE {
    fn name(&self) -> &'static str {
        "DistributedNE"
    }

    fn partition(&self, g: &Graph, num_parts: usize, seed: u64) -> EdgeAssignment {
        expand(
            g,
            num_parts,
            seed,
            &ExpansionConfig {
                lambda0: self.lambda,
                policy: Policy::Dne { tau: self.tau },
                threads: self.threads,
            },
        )
    }
}
