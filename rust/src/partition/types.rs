//! Partitioning interfaces and quality metrics (paper §II-B, eqs. 2–4).

use crate::graph::csr::Graph;
use crate::util::bitset::BitMatrix;

/// A vertex-cut partitioning: each edge owned by exactly one partition.
#[derive(Clone, Debug)]
pub struct EdgeAssignment {
    pub num_parts: usize,
    /// Partition of each edge, indexed by CSR edge id.
    pub part_of_edge: Vec<u16>,
}

/// An edge-cut partitioning: each vertex owned by exactly one partition.
/// (Converted to an EdgeAssignment by `edge_cut_to_assignment` — edges
/// follow their source vertex, the convention the edge-cut frameworks use
/// so a vertex's out-neighborhood is co-located with it.)
#[derive(Clone, Debug)]
pub struct VertexAssignment {
    pub num_parts: usize,
    pub part_of_vertex: Vec<u16>,
}

pub fn edge_cut_to_assignment(g: &Graph, va: &VertexAssignment) -> EdgeAssignment {
    let mut part_of_edge = vec![0u16; g.m()];
    for u in 0..g.n {
        let (a, b) = g.edge_range(u as u32);
        for e in a..b {
            part_of_edge[e] = va.part_of_vertex[u];
        }
    }
    EdgeAssignment {
        num_parts: va.num_parts,
        part_of_edge,
    }
}

/// Partition quality (paper eqs. 2–4): Replication Factor, Vertex Balance,
/// Edge Balance — plus raw per-partition sizes for the reports.
#[derive(Clone, Debug)]
pub struct PartitionQuality {
    pub rf: f64,
    pub vb: f64,
    pub eb: f64,
    pub vertices_per_part: Vec<usize>,
    pub edges_per_part: Vec<usize>,
}

/// Compute RF/VB/EB for a vertex-cut assignment. |V_p| counts the distinct
/// endpoints of p's edges (replicated vertices count once per partition).
pub fn quality(g: &Graph, ea: &EdgeAssignment) -> PartitionQuality {
    let p = ea.num_parts;
    let mut edges = vec![0usize; p];
    let mut membership = BitMatrix::new(g.n, p);
    for u in 0..g.n {
        let (a, b) = g.edge_range(u as u32);
        for e in a..b {
            let part = ea.part_of_edge[e] as usize;
            edges[part] += 1;
            membership.set(u, part);
            membership.set(g.dst[e] as usize, part);
        }
    }
    let mut verts = vec![0usize; p];
    let mut total_replicas = 0usize;
    for v in 0..g.n {
        for part in membership.row_ones(v) {
            verts[part] += 1;
            total_replicas += 1;
        }
    }
    PartitionQuality {
        rf: total_replicas as f64 / g.n.max(1) as f64,
        vb: balance(&verts),
        eb: balance(&edges),
        vertices_per_part: verts,
        edges_per_part: edges,
    }
}

fn balance(xs: &[usize]) -> f64 {
    let lo = xs.iter().copied().min().unwrap_or(0);
    let hi = xs.iter().copied().max().unwrap_or(0);
    if lo == 0 {
        f64::INFINITY
    } else {
        hi as f64 / lo as f64
    }
}

/// Primary partition of each vertex under a vertex-cut assignment: the
/// partition owning most of its incident edges (ties → lowest id). Used by
/// the PS/PDS reorder keys and the inference workload allocation.
pub fn primary_partition(g: &Graph, ea: &EdgeAssignment) -> Vec<u16> {
    let p = ea.num_parts;
    let mut counts = vec![0u32; g.n * p];
    for u in 0..g.n {
        let (a, b) = g.edge_range(u as u32);
        for e in a..b {
            let part = ea.part_of_edge[e] as usize;
            counts[u * p + part] += 1;
            counts[g.dst[e] as usize * p + part] += 1;
        }
    }
    (0..g.n)
        .map(|v| {
            let row = &counts[v * p..(v + 1) * p];
            let mut best = 0usize;
            for (i, &c) in row.iter().enumerate() {
                if c > row[best] {
                    best = i;
                }
            }
            best as u16
        })
        .collect()
}

/// Every partitioner in the suite (Table II rows).
pub trait Partitioner {
    fn name(&self) -> &'static str;
    fn partition(&self, g: &Graph, num_parts: usize, seed: u64) -> EdgeAssignment;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generator;
    use crate::util::rng::Rng;

    #[test]
    fn quality_of_perfect_split() {
        // Two disjoint triangles, each to its own partition.
        let g = Graph::from_edges(
            6,
            &[(0, 1), (1, 2), (2, 0), (3, 4), (4, 5), (5, 3)],
        );
        let ea = EdgeAssignment {
            num_parts: 2,
            part_of_edge: vec![0, 0, 0, 1, 1, 1],
        };
        let q = quality(&g, &ea);
        assert!((q.rf - 1.0).abs() < 1e-12);
        assert!((q.vb - 1.0).abs() < 1e-12);
        assert!((q.eb - 1.0).abs() < 1e-12);
    }

    #[test]
    fn replication_counted_once_per_partition() {
        // Star: 0->1, 0->2 split across 2 partitions; vertex 0 in both.
        let g = Graph::from_edges(3, &[(0, 1), (0, 2)]);
        let ea = EdgeAssignment {
            num_parts: 2,
            part_of_edge: vec![0, 1],
        };
        let q = quality(&g, &ea);
        // V0 = {0,1}, V1 = {0,2} => RF = 4/3
        assert!((q.rf - 4.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn edge_cut_conversion_follows_src() {
        let g = Graph::from_edges(3, &[(0, 1), (1, 2), (2, 0)]);
        let va = VertexAssignment {
            num_parts: 2,
            part_of_vertex: vec![0, 1, 0],
        };
        let ea = edge_cut_to_assignment(&g, &va);
        assert_eq!(ea.part_of_edge, vec![0, 1, 0]);
    }

    #[test]
    fn primary_partition_majority() {
        let mut rng = Rng::new(60);
        let g = generator::chung_lu(500, 4000, 2.1, &mut rng);
        let ea = EdgeAssignment {
            num_parts: 4,
            part_of_edge: (0..g.m()).map(|e| (e % 4) as u16).collect(),
        };
        let pp = primary_partition(&g, &ea);
        assert_eq!(pp.len(), g.n);
        assert!(pp.iter().all(|&p| p < 4));
    }
}
