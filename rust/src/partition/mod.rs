//! Graph partitioners (paper §III-B): the AdaDNE contribution, the
//! DistributedNE and edge-cut/hash baselines, and the RF/VB/EB quality
//! metrics of Table II.

pub mod adadne;
pub mod dne;
pub mod edgecut;
pub mod expansion;
pub mod hash;
pub mod types;

pub use adadne::AdaDNE;
pub use dne::DistributedNE;
pub use edgecut::EdgeCutLDG;
pub use hash::{Hash1D, Hash2D};
pub use types::{
    edge_cut_to_assignment, primary_partition, quality, EdgeAssignment,
    PartitionQuality, Partitioner, VertexAssignment,
};
