//! Edge-cut comparator — the experimental stand-in for ParMETIS in
//! Table II / Fig. 9 (DESIGN.md §3). A multi-pass Linear Deterministic
//! Greedy (LDG) streaming partitioner with a vertex-balance capacity: each
//! vertex goes to the partition holding most of its neighbors, scaled by the
//! remaining capacity — the standard high-quality streaming edge-cut.
//!
//! What the experiments need from this comparator is the *architectural*
//! property the paper attributes to edge-cut on power-law graphs: balanced
//! vertices but skewed edges (hubs drag their whole out-neighborhood into
//! one partition), hence bad EB and server hotspots.

use crate::graph::csr::Graph;
use crate::partition::types::{
    edge_cut_to_assignment, EdgeAssignment, Partitioner, VertexAssignment,
};
use crate::util::rng::Rng;

pub struct EdgeCutLDG {
    pub passes: usize,
}

impl Default for EdgeCutLDG {
    fn default() -> Self {
        Self { passes: 3 }
    }
}

impl EdgeCutLDG {
    pub fn partition_vertices(
        &self,
        g: &Graph,
        num_parts: usize,
        seed: u64,
    ) -> VertexAssignment {
        let mut rng = Rng::new(seed);
        let inc = g.incidence();
        let capacity = (g.n as f64 / num_parts as f64) * 1.05;
        // Start from a random assignment, then LDG passes refine it.
        let mut part = vec![u16::MAX; g.n];
        let mut sizes = vec![0usize; num_parts];
        let mut order: Vec<u32> = (0..g.n as u32).collect();
        rng.shuffle(&mut order);
        let mut scores = vec![0f64; num_parts];
        for pass in 0..self.passes {
            for &v in &order {
                // Remove v from its current partition (after pass 0).
                if pass > 0 {
                    sizes[part[v as usize] as usize] -= 1;
                }
                scores.fill(0.0);
                for (_, w) in inc.edges_of(v) {
                    let pw = part[w as usize];
                    if pw != u16::MAX {
                        scores[pw as usize] += 1.0;
                    }
                }
                let mut best = 0usize;
                let mut best_score = f64::NEG_INFINITY;
                for p in 0..num_parts {
                    let s = (scores[p] + 1e-3)
                        * (1.0 - sizes[p] as f64 / capacity).max(0.0);
                    if s > best_score {
                        best_score = s;
                        best = p;
                    }
                }
                part[v as usize] = best as u16;
                sizes[best] += 1;
            }
        }
        VertexAssignment {
            num_parts,
            part_of_vertex: part,
        }
    }
}

impl Partitioner for EdgeCutLDG {
    fn name(&self) -> &'static str {
        "EdgeCutLDG"
    }

    fn partition(&self, g: &Graph, num_parts: usize, seed: u64) -> EdgeAssignment {
        let va = self.partition_vertices(g, num_parts, seed);
        edge_cut_to_assignment(g, &va)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generator;
    use crate::partition::types::quality;
    use crate::util::rng::Rng;

    #[test]
    fn vertex_balance_is_tight() {
        let mut rng = Rng::new(80);
        let g = generator::chung_lu(4000, 32_000, 2.1, &mut rng);
        let va = EdgeCutLDG::default().partition_vertices(&g, 4, 1);
        let mut sizes = vec![0usize; 4];
        for &p in &va.part_of_vertex {
            sizes[p as usize] += 1;
        }
        let lo = *sizes.iter().min().unwrap() as f64;
        let hi = *sizes.iter().max().unwrap() as f64;
        assert!(hi / lo < 1.3, "vertex balance {}", hi / lo);
    }

    #[test]
    fn edge_balance_degrades_on_power_law() {
        // The phenomenon Table II documents: on a skewed graph, edge-cut's
        // EB is visibly worse than its VB.
        let mut rng = Rng::new(81);
        let g = generator::chung_lu(4000, 60_000, 1.8, &mut rng);
        let q = quality(&g, &EdgeCutLDG::default().partition(&g, 8, 1));
        assert!(
            q.eb > q.vb,
            "expected EB ({}) worse than VB ({}) on power law",
            q.eb,
            q.vb
        );
    }

    #[test]
    fn locality_better_than_random() {
        // LDG must cut fewer edges than a random vertex assignment.
        let mut rng = Rng::new(82);
        let g = generator::chung_lu(2000, 16_000, 2.1, &mut rng);
        let va = EdgeCutLDG::default().partition_vertices(&g, 4, 1);
        let cut = |part: &[u16]| {
            let mut c = 0usize;
            for u in 0..g.n {
                for &v in g.out_neighbors(u as u32) {
                    if part[u] != part[v as usize] {
                        c += 1;
                    }
                }
            }
            c
        };
        let random: Vec<u16> = (0..g.n).map(|_| rng.usize(4) as u16).collect();
        assert!(cut(&va.part_of_vertex) < cut(&random));
    }
}
