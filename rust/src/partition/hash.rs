//! Hash partitioners: 1D (by source vertex — what GraphLearn provides) and
//! 2D grid hash (DistributedNE's initialization, paper §III-B).

use crate::graph::csr::Graph;
use crate::partition::types::{EdgeAssignment, Partitioner};

#[inline]
fn mix(x: u64) -> u64 {
    // splitmix64 finalizer as a cheap hash.
    let mut z = x.wrapping_add(0x9E3779B97F4A7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// 1D hash: edge follows hash(src) — GraphLearn's only partition scheme.
pub struct Hash1D;

impl Partitioner for Hash1D {
    fn name(&self) -> &'static str {
        "Hash1D"
    }

    fn partition(&self, g: &Graph, num_parts: usize, seed: u64) -> EdgeAssignment {
        let mut part_of_edge = vec![0u16; g.m()];
        for u in 0..g.n {
            let (a, b) = g.edge_range(u as u32);
            let p = (mix(u as u64 ^ seed) % num_parts as u64) as u16;
            part_of_edge[a..b].fill(p);
        }
        EdgeAssignment {
            num_parts,
            part_of_edge,
        }
    }
}

/// 2D grid hash: partitions arranged in an r×c grid; edge (u,v) goes to the
/// block (hash(u) mod r, hash(v) mod c). Bounds the replication factor of
/// any vertex by r + c − 1 regardless of degree — the classic vertex-cut
/// opening move.
pub struct Hash2D;

impl Partitioner for Hash2D {
    fn name(&self) -> &'static str {
        "Hash2D"
    }

    fn partition(&self, g: &Graph, num_parts: usize, seed: u64) -> EdgeAssignment {
        // Choose the most square grid r×c = num_parts.
        let mut r = (num_parts as f64).sqrt() as usize;
        while num_parts % r != 0 {
            r -= 1;
        }
        let c = num_parts / r;
        let mut part_of_edge = vec![0u16; g.m()];
        for u in 0..g.n {
            let (a, b) = g.edge_range(u as u32);
            let row = (mix(u as u64 ^ seed) % r as u64) as usize;
            for e in a..b {
                let col = (mix(g.dst[e] as u64 ^ seed.rotate_left(17)) % c as u64) as usize;
                part_of_edge[e] = (row * c + col) as u16;
            }
        }
        EdgeAssignment {
            num_parts,
            part_of_edge,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generator;
    use crate::partition::types::quality;
    use crate::util::rng::Rng;

    #[test]
    fn hash1d_all_out_edges_together() {
        let mut rng = Rng::new(70);
        let g = generator::chung_lu(1000, 8000, 2.1, &mut rng);
        let ea = Hash1D.partition(&g, 4, 1);
        for u in 0..g.n {
            let (a, b) = g.edge_range(u as u32);
            if b > a {
                let p = ea.part_of_edge[a];
                assert!(ea.part_of_edge[a..b].iter().all(|&x| x == p));
            }
        }
    }

    #[test]
    fn hash2d_bounds_replication() {
        let mut rng = Rng::new(71);
        // Heavy power law: a hub's neighbors land in every partition under
        // 1D hash, but 2D bounds each vertex to r+c-1 partitions.
        let g = generator::chung_lu(2000, 40_000, 1.8, &mut rng);
        let ea = Hash2D.partition(&g, 16, 1); // 4x4 grid => max 7 replicas
        let q = quality(&g, &ea);
        // Max row of membership <= r + c - 1 = 7 < 16.
        // RF must also be far below the 1D worst case on this graph.
        let q1 = quality(&g, &Hash1D.partition(&g, 16, 1));
        assert!(q.rf <= q1.rf * 1.2, "2d rf {} vs 1d rf {}", q.rf, q1.rf);
    }

    #[test]
    fn deterministic_given_seed() {
        let mut rng = Rng::new(72);
        let g = generator::erdos_renyi(500, 3000, &mut rng);
        let a = Hash2D.partition(&g, 4, 9).part_of_edge;
        let b = Hash2D.partition(&g, 4, 9).part_of_edge;
        assert_eq!(a, b);
    }

    #[test]
    fn all_parts_used() {
        let mut rng = Rng::new(73);
        let g = generator::erdos_renyi(2000, 20_000, &mut rng);
        for ea in [Hash1D.partition(&g, 8, 2), Hash2D.partition(&g, 8, 2)] {
            let mut used = vec![false; 8];
            for &p in &ea.part_of_edge {
                used[p as usize] = true;
            }
            assert!(used.iter().all(|&u| u));
        }
    }
}
