//! Shared neighbor-expansion engine behind DistributedNE and AdaDNE
//! (paper §III-B). The engine simulates the distributed algorithm's
//! per-partition parallel expansion as round-robin iterations; the two
//! algorithms differ only in the expansion-speed policy:
//!
//! * **DNE**: constant expansion factor λ, hard edge threshold
//!   `E_t = τ·|E|/|P|` that terminates a partition's expansion.
//! * **AdaDNE**: adaptive per-partition λ_p updated every iteration from
//!   the vertex/edge scores (eqs. 5–7), no hard threshold (τ = |P|):
//!   `λ_p ← λ_p · exp(α(1 − VS_p) + β(1 − ES_p))`.

use crate::graph::csr::{Graph, Incidence, VId};
use crate::partition::types::EdgeAssignment;
use crate::util::bitset::{BitMatrix, BitSet};
use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug)]
pub enum Policy {
    /// DistributedNE: fixed λ and an edge-count termination threshold.
    Dne { tau: f64 },
    /// AdaDNE: adaptive λ_p, soft vertex+edge balance constraints.
    Ada { alpha: f64, beta: f64 },
}

#[derive(Clone, Debug)]
pub struct ExpansionConfig {
    pub lambda0: f64,
    pub policy: Policy,
}

pub fn expand(g: &Graph, num_parts: usize, seed: u64, cfg: &ExpansionConfig) -> EdgeAssignment {
    Engine::new(g, num_parts, seed, cfg).run()
}

const UNASSIGNED: u16 = u16::MAX;

struct Engine<'a> {
    g: &'a Graph,
    inc: Incidence,
    p: usize,
    cfg: ExpansionConfig,
    rng: Rng,
    part_of_edge: Vec<u16>,
    /// Unassigned incident-edge count per vertex ("local degree" for the
    /// min-degree expansion heuristic).
    unassigned_deg: Vec<u32>,
    /// Vertex membership per partition (endpoints of assigned edges).
    membership: BitMatrix,
    vcount: Vec<usize>,
    ecount: Vec<usize>,
    /// Boundary vertex sets + dedup bits, one per partition.
    boundary: Vec<Vec<VId>>,
    in_boundary: Vec<BitSet>,
    lambda: Vec<f64>,
    stopped: Vec<bool>,
    remaining_edges: usize,
}

impl<'a> Engine<'a> {
    fn new(g: &'a Graph, num_parts: usize, seed: u64, cfg: &ExpansionConfig) -> Self {
        let inc = g.incidence();
        let unassigned_deg = (0..g.n).map(|v| inc.degree(v as VId) as u32).collect();
        Engine {
            g,
            inc,
            p: num_parts,
            cfg: cfg.clone(),
            rng: Rng::new(seed),
            part_of_edge: vec![UNASSIGNED; g.m()],
            unassigned_deg,
            membership: BitMatrix::new(g.n, num_parts),
            vcount: vec![0; num_parts],
            ecount: vec![0; num_parts],
            boundary: vec![Vec::new(); num_parts],
            in_boundary: (0..num_parts).map(|_| BitSet::new(g.n)).collect(),
            lambda: vec![cfg.lambda0; num_parts],
            stopped: vec![false; num_parts],
            remaining_edges: g.m(),
        }
    }

    fn run(mut self) -> EdgeAssignment {
        self.seed_partitions();
        let fixed_threshold = match self.cfg.policy {
            Policy::Dne { tau } => (tau * self.g.m() as f64 / self.p as f64) as usize,
            Policy::Ada { .. } => usize::MAX,
        };
        let mut idle_rounds = 0usize;
        let mut force = false;
        while self.remaining_edges > 0 {
            if let Policy::Ada { alpha, beta } = self.cfg.policy {
                self.update_lambdas(alpha, beta);
            }
            // The partition a "force round" unblocks: least-loaded by edges.
            let min_edge_part = (0..self.p)
                .filter(|&p| !self.stopped[p])
                .min_by_key(|&p| self.ecount[p]);
            let mut assigned_this_round = 0usize;
            for p in 0..self.p {
                if self.stopped[p] {
                    continue;
                }
                let forced = force && Some(p) == min_edge_part;
                // Ada's soft constraint realized in discrete time: the edge
                // budget tracks 1.15× the *current* average, so no partition
                // can run ahead of the group even within a single cascade
                // (the neighbor-expansion two-hop rule can otherwise claim
                // thousands of edges in one call). DNE keeps the paper's
                // fixed E_t = τ|E|/|P|.
                let edge_threshold = match self.cfg.policy {
                    Policy::Dne { .. } => fixed_threshold,
                    Policy::Ada { .. } if forced => usize::MAX,
                    Policy::Ada { .. } => {
                        let etot: usize = self.ecount.iter().sum();
                        ((1.15 * (etot + self.p) as f64 / self.p as f64) as usize).max(64)
                    }
                };
                if self.ecount[p] > edge_threshold {
                    if matches!(self.cfg.policy, Policy::Dne { .. }) {
                        self.stopped[p] = true;
                    }
                    continue; // Ada: paused this round
                }
                // Ada: a partition whose vertex score runs ahead of the
                // group pauses this round — the discrete-time analogue of
                // eq. 7 driving λ_p → 0 at the unbalanced fixed point.
                if !forced
                    && matches!(self.cfg.policy, Policy::Ada { .. })
                    && self.ahead(p)
                {
                    continue;
                }
                if self.boundary[p].is_empty() && !self.reseed(p) {
                    continue;
                }
                assigned_this_round += self.expand_one(p, edge_threshold);
            }
            if assigned_this_round == 0 {
                idle_rounds += 1;
                // Every eligible partition paused each other out (edge-heavy
                // ones edge-paused, vertex-heavy ones vertex-paused): force
                // the least-loaded partition next round to break the tie.
                force = true;
                if idle_rounds > 3 {
                    break; // genuinely stuck — finish via assign_leftovers
                }
            } else {
                idle_rounds = 0;
                force = false;
            }
        }
        self.assign_leftovers();
        EdgeAssignment {
            num_parts: self.p,
            part_of_edge: self.part_of_edge,
        }
    }

    /// Random distinct seed vertex per partition (the paper initializes
    /// from 2D-hash + random seeds; random seeds preserve the behaviour at
    /// our scale).
    fn seed_partitions(&mut self) {
        let mut tries = 0;
        for p in 0..self.p {
            loop {
                let v = self.rng.usize(self.g.n) as VId;
                tries += 1;
                if self.unassigned_deg[v as usize] > 0 || tries > 50 * self.p {
                    self.push_boundary(p, v);
                    break;
                }
            }
        }
    }

    fn push_boundary(&mut self, p: usize, v: VId) {
        if !self.in_boundary[p].get(v as usize) {
            self.in_boundary[p].set(v as usize);
            self.boundary[p].push(v);
        }
    }

    /// True if partition p's vertex or edge count is visibly above the
    /// current average (scores > 1.1) — used by the Ada pause rule.
    fn ahead(&self, p: usize) -> bool {
        let vtot: usize = self.vcount.iter().sum();
        let etot: usize = self.ecount.iter().sum();
        if vtot == 0 || etot == 0 {
            return false;
        }
        let vs = self.p as f64 * self.vcount[p] as f64 / vtot as f64;
        let es = self.p as f64 * self.ecount[p] as f64 / etot as f64;
        vs > 1.1 || es > 1.1
    }

    /// One expansion iteration for partition p; returns edges assigned.
    /// Stops mid-iteration once the edge threshold is crossed (limits DNE's
    /// overshoot past E_t to a single vertex's edges).
    fn expand_one(&mut self, p: usize, edge_threshold: usize) -> usize {
        // Drop boundary vertices with no unassigned edges left.
        let bnd = std::mem::take(&mut self.boundary[p]);
        let mut live: Vec<VId> = Vec::with_capacity(bnd.len());
        for v in bnd {
            if self.unassigned_deg[v as usize] > 0 {
                live.push(v);
            } else {
                self.in_boundary[p].clear(v as usize);
            }
        }
        if live.is_empty() {
            self.boundary[p] = live;
            return 0;
        }
        // Select the ⌈λ_p·|B_p|⌉ lowest-unassigned-degree vertices.
        let take = ((self.lambda[p] * live.len() as f64).ceil() as usize)
            .clamp(1, live.len());
        live.sort_unstable_by_key(|&v| self.unassigned_deg[v as usize]);
        let selected: Vec<VId> = live[..take].to_vec();
        self.boundary[p] = live[take..].to_vec();
        for &v in &selected {
            self.in_boundary[p].clear(v as usize);
        }

        let mut assigned = 0usize;
        for &v in &selected {
            if self.ecount[p] > edge_threshold {
                // Over budget mid-iteration: return the rest to the boundary.
                self.push_boundary(p, v);
                continue;
            }
            // One-hop edge allocation: every unassigned edge incident to v.
            let a = self.inc.indptr[v as usize] as usize;
            let b = self.inc.indptr[v as usize + 1] as usize;
            for i in a..b {
                if self.ecount[p] > edge_threshold {
                    self.push_boundary(p, v); // finish v later
                    break;
                }
                let e = self.inc.eid[i] as usize;
                if self.part_of_edge[e] != UNASSIGNED {
                    continue;
                }
                let w = self.inc.other[i];
                self.assign_edge(e, p, v, w);
                assigned += 1;
                // w joins the boundary.
                self.push_boundary(p, w);
                // Two-hop allocation (local form): unassigned edges from w
                // to vertices already in p are claimed now, keeping
                // intra-partition two-hop edges from leaking to others.
                let wa = self.inc.indptr[w as usize] as usize;
                let wb = self.inc.indptr[w as usize + 1] as usize;
                for j in wa..wb {
                    if self.ecount[p] > edge_threshold {
                        break;
                    }
                    let e2 = self.inc.eid[j] as usize;
                    if self.part_of_edge[e2] != UNASSIGNED {
                        continue;
                    }
                    let x = self.inc.other[j];
                    if self.membership.get(x as usize, p) {
                        self.assign_edge(e2, p, w, x);
                        assigned += 1;
                    }
                }
            }
        }
        assigned
    }

    fn assign_edge(&mut self, e: usize, p: usize, u: VId, w: VId) {
        debug_assert_eq!(self.part_of_edge[e], UNASSIGNED);
        self.part_of_edge[e] = p as u16;
        self.ecount[p] += 1;
        self.remaining_edges -= 1;
        self.unassigned_deg[u as usize] -= 1;
        self.unassigned_deg[w as usize] -= 1;
        for v in [u, w] {
            if !self.membership.get(v as usize, p) {
                self.membership.set(v as usize, p);
                self.vcount[p] += 1;
            }
        }
    }

    /// Partition starved (empty boundary): reseed from a random vertex that
    /// still has unassigned edges. Returns false if none exists.
    fn reseed(&mut self, p: usize) -> bool {
        for _ in 0..64 {
            let v = self.rng.usize(self.g.n) as VId;
            if self.unassigned_deg[v as usize] > 0 {
                self.push_boundary(p, v);
                return true;
            }
        }
        // Fall back to a scan (rare; only near the very end).
        for v in 0..self.g.n {
            if self.unassigned_deg[v] > 0 {
                self.push_boundary(p, v as VId);
                return true;
            }
        }
        false
    }

    /// DNE can terminate all partitions with a few edges left; give each to
    /// the least-loaded partition among those containing an endpoint.
    fn assign_leftovers(&mut self) {
        for u in 0..self.g.n {
            let (a, b) = self.g.edge_range(u as VId);
            for e in a..b {
                if self.part_of_edge[e] != UNASSIGNED {
                    continue;
                }
                let w = self.g.dst[e];
                let mut best: Option<usize> = None;
                for p in 0..self.p {
                    if self.membership.get(u, p) || self.membership.get(w as usize, p) {
                        if best.map(|bp| self.ecount[p] < self.ecount[bp]).unwrap_or(true) {
                            best = Some(p);
                        }
                    }
                }
                let p = best.unwrap_or_else(|| {
                    (0..self.p).min_by_key(|&p| self.ecount[p]).unwrap()
                });
                self.assign_edge(e, p, u as VId, w);
            }
        }
    }

    /// AdaDNE eqs. 5–7. Counts are synchronized at iteration start (the
    /// paper notes this sync is negligible: two integers per partition).
    fn update_lambdas(&mut self, alpha: f64, beta: f64) {
        let vtot: usize = self.vcount.iter().sum();
        let etot: usize = self.ecount.iter().sum();
        if vtot == 0 || etot == 0 {
            return;
        }
        for p in 0..self.p {
            let vs = self.p as f64 * self.vcount[p] as f64 / vtot as f64;
            let es = self.p as f64 * self.ecount[p] as f64 / etot as f64;
            let f = (alpha * (1.0 - vs) + beta * (1.0 - es)).exp();
            self.lambda[p] = (self.lambda[p] * f).clamp(1e-3, 1.0);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generator;
    use crate::partition::types::quality;

    fn powerlaw(seed: u64) -> Graph {
        let mut rng = Rng::new(seed);
        generator::chung_lu(5000, 50_000, 2.0, &mut rng)
    }

    fn run(g: &Graph, parts: usize, policy: Policy) -> EdgeAssignment {
        expand(
            g,
            parts,
            42,
            &ExpansionConfig {
                lambda0: 0.1,
                policy,
            },
        )
    }

    #[test]
    fn every_edge_assigned_exactly_once() {
        let g = powerlaw(90);
        for policy in [Policy::Dne { tau: 1.1 }, Policy::Ada { alpha: 1.0, beta: 1.0 }] {
            let ea = run(&g, 4, policy);
            assert_eq!(ea.part_of_edge.len(), g.m());
            assert!(ea.part_of_edge.iter().all(|&p| (p as usize) < 4));
        }
    }

    #[test]
    fn dne_respects_edge_balance() {
        let g = powerlaw(91);
        let q = quality(&g, &run(&g, 8, Policy::Dne { tau: 1.1 }));
        // Sequential simulation overshoots the paper's parallel runs a bit;
        // Table II reports DNE EB up to 1.43 — we accept < 2.2 here and
        // assert the *relative* claim (AdaDNE beats DNE) separately.
        assert!(q.eb < 2.2, "DNE EB {}", q.eb);
    }

    #[test]
    fn adadne_improves_vertex_balance_over_dne() {
        // The paper's core claim (Table II): AdaDNE's VB < DNE's VB while
        // EB stays comparable.
        let g = powerlaw(92);
        let qd = quality(&g, &run(&g, 8, Policy::Dne { tau: 1.1 }));
        let qa = quality(&g, &run(&g, 8, Policy::Ada { alpha: 1.0, beta: 1.0 }));
        assert!(
            qa.vb < qd.vb * 1.05,
            "AdaDNE VB {} should beat DNE VB {}",
            qa.vb,
            qd.vb
        );
        assert!(qa.eb < 1.8, "AdaDNE EB {}", qa.eb);
    }

    #[test]
    fn expansion_rf_beats_random() {
        // Neighbor expansion mines locality: RF far below random edge
        // assignment's.
        let g = powerlaw(93);
        let qa = quality(&g, &run(&g, 8, Policy::Ada { alpha: 1.0, beta: 1.0 }));
        let mut rng = Rng::new(1);
        let random = EdgeAssignment {
            num_parts: 8,
            part_of_edge: (0..g.m()).map(|_| rng.usize(8) as u16).collect(),
        };
        let qr = quality(&g, &random);
        assert!(qa.rf < qr.rf * 0.8, "ada rf {} vs random rf {}", qa.rf, qr.rf);
    }

    #[test]
    fn deterministic_given_seed() {
        let g = powerlaw(94);
        let a = run(&g, 4, Policy::Ada { alpha: 1.0, beta: 1.0 });
        let b = run(&g, 4, Policy::Ada { alpha: 1.0, beta: 1.0 });
        assert_eq!(a.part_of_edge, b.part_of_edge);
    }
}
