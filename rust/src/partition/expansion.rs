//! Shared neighbor-expansion engine behind DistributedNE and AdaDNE
//! (paper §III-B), executed as a **round-synchronous propose/commit state
//! machine** (DESIGN.md §10): every round, the P partition workers expand
//! their boundary heaps *in parallel* against a frozen round-start snapshot
//! and emit ordered edge-claim lists; a serial commit phase then resolves
//! conflicting claims by a fixed total order — ascending
//! `(round-start |E_p|, partition id, claim position)` — publishes the
//! winners, and refreshes the boundaries for the next round. Because the
//! propose phase is a pure function of (snapshot, per-partition state) and
//! the commit order never references thread identity, the resulting
//! `EdgeAssignment` is bit-identical for any `threads` value; `threads = 1`
//! runs the identical schedule on the calling thread.
//!
//! The two algorithms differ only in the expansion-speed policy:
//!
//! * **DNE**: constant expansion factor λ, hard edge threshold
//!   `E_t = τ·|E|/|P|` that terminates a partition's expansion.
//! * **AdaDNE**: adaptive per-partition λ_p updated every round from
//!   the vertex/edge scores (eqs. 5–7), no hard threshold (τ = |P|):
//!   `λ_p ← λ_p · exp(α(1 − VS_p) + β(1 − ES_p))`.

use crate::graph::csr::{Graph, Incidence, VId};
use crate::partition::types::EdgeAssignment;
use crate::util::bitset::{BitMatrix, BitSet};
use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug)]
pub enum Policy {
    /// DistributedNE: fixed λ and an edge-count termination threshold.
    Dne { tau: f64 },
    /// AdaDNE: adaptive λ_p, soft vertex+edge balance constraints.
    Ada { alpha: f64, beta: f64 },
}

#[derive(Clone, Debug)]
pub struct ExpansionConfig {
    pub lambda0: f64,
    pub policy: Policy,
    /// Worker threads for the propose phase (gating/commit stay serial).
    /// Pure throughput knob: the assignment is bit-identical for any value
    /// (DESIGN.md §10); 0 and 1 both mean "propose on the calling thread".
    pub threads: usize,
}

pub fn expand(g: &Graph, num_parts: usize, seed: u64, cfg: &ExpansionConfig) -> EdgeAssignment {
    Engine::new(g, num_parts, seed, cfg).run()
}

const UNASSIGNED: u16 = u16::MAX;

/// Round-start snapshot: everything the propose phase reads. Mutated only
/// by the serial gating/commit phases, shared immutably (`&Shared`) across
/// the propose workers.
struct Shared<'a> {
    g: &'a Graph,
    inc: Incidence,
    p: usize,
    part_of_edge: Vec<u16>,
    /// Unassigned incident-edge count per vertex ("local degree" for the
    /// min-degree expansion heuristic).
    unassigned_deg: Vec<u32>,
    /// Committed vertex membership per partition (endpoints of assigned
    /// edges).
    membership: BitMatrix,
    vcount: Vec<usize>,
    ecount: Vec<usize>,
    lambda: Vec<f64>,
}

impl Shared<'_> {
    /// True if partition p's vertex or edge count is visibly above the
    /// current average (scores > 1.1) — used by the Ada pause rule.
    fn ahead(&self, p: usize) -> bool {
        let vtot: usize = self.vcount.iter().sum();
        let etot: usize = self.ecount.iter().sum();
        if vtot == 0 || etot == 0 {
            return false;
        }
        let vs = self.p as f64 * self.vcount[p] as f64 / vtot as f64;
        let es = self.p as f64 * self.ecount[p] as f64 / etot as f64;
        vs > 1.1 || es > 1.1
    }

    /// AdaDNE eqs. 5–7, applied once per round from the committed counts
    /// (the paper notes this sync is negligible: two integers per
    /// partition).
    fn update_lambdas(&mut self, alpha: f64, beta: f64) {
        let vtot: usize = self.vcount.iter().sum();
        let etot: usize = self.ecount.iter().sum();
        if vtot == 0 || etot == 0 {
            return;
        }
        for p in 0..self.p {
            let vs = self.p as f64 * self.vcount[p] as f64 / vtot as f64;
            let es = self.p as f64 * self.ecount[p] as f64 / etot as f64;
            let f = (alpha * (1.0 - vs) + beta * (1.0 - es)).exp();
            self.lambda[p] = (self.lambda[p] * f).clamp(1e-3, 1.0);
        }
    }
}

/// One edge claim in a partition's proposal, in cascade order.
#[derive(Clone, Copy, Debug)]
struct Claim {
    edge: u32,
    /// The vertex whose expansion produced the claim (the boundary vertex
    /// for one-hop claims, its freshly-joined neighbor for two-hop ones).
    anchor: VId,
    other: VId,
    /// One-hop claims put `other` on the next round's boundary; two-hop
    /// claims target a vertex already inside the partition.
    one_hop: bool,
}

/// Per-partition propose worker: the boundary frontier plus proposal
/// scratch, reused across rounds. Owned exclusively by one propose thread
/// per round; the serial phases see all of them.
struct PartWorker {
    id: usize,
    boundary: Vec<VId>,
    in_boundary: BitSet,
    /// Edges claimed by this partition in the current proposal (m bits;
    /// cleared claim-by-claim at commit).
    claimed: BitSet,
    /// Vertices optimistically joined by the current proposal (n bits;
    /// cleared claim-by-claim at commit) — the two-hop membership overlay.
    joined: BitSet,
    claims: Vec<Claim>,
    /// Edge budget granted by the gating phase; `None` = sits this round
    /// out (stopped, paused, ahead, or starved with no reseed left).
    budget: Option<usize>,
    stopped: bool,
}

impl PartWorker {
    fn new(id: usize, n: usize, m: usize) -> Self {
        Self {
            id,
            boundary: Vec::new(),
            in_boundary: BitSet::new(n),
            claimed: BitSet::new(m),
            joined: BitSet::new(n),
            claims: Vec::new(),
            budget: None,
            stopped: false,
        }
    }

    fn push_boundary(&mut self, v: VId) {
        if !self.in_boundary.get(v as usize) {
            self.in_boundary.set(v as usize);
            self.boundary.push(v);
        }
    }

    fn claim(&mut self, e: usize, anchor: VId, other: VId, one_hop: bool) {
        self.claimed.set(e);
        self.joined.set(anchor as usize);
        self.joined.set(other as usize);
        self.claims.push(Claim {
            edge: e as u32,
            anchor,
            other,
            one_hop,
        });
    }

    /// Build this partition's proposal against the round-start snapshot.
    /// Pure function of (shared, self): no other partition's round state is
    /// visible, which is what makes the round thread-count-invariant.
    fn propose(&mut self, shared: &Shared<'_>) {
        let Some(budget) = self.budget else { return };
        // Drop boundary vertices with no unassigned edges left.
        let bnd = std::mem::take(&mut self.boundary);
        let mut live: Vec<VId> = Vec::with_capacity(bnd.len());
        for v in bnd {
            if shared.unassigned_deg[v as usize] > 0 {
                live.push(v);
            } else {
                self.in_boundary.clear(v as usize);
            }
        }
        if live.is_empty() {
            self.boundary = live;
            return;
        }
        // Select the ⌈λ_p·|B_p|⌉ lowest-unassigned-degree vertices (vertex
        // id breaks ties so the order is a canonical total order).
        let take = ((shared.lambda[self.id] * live.len() as f64).ceil() as usize)
            .clamp(1, live.len());
        live.sort_unstable_by_key(|&v| (shared.unassigned_deg[v as usize], v));
        let selected: Vec<VId> = live[..take].to_vec();
        self.boundary = live[take..].to_vec();
        for &v in &selected {
            self.in_boundary.clear(v as usize);
        }

        let base = shared.ecount[self.id];
        let mut proposed = 0usize;
        for &v in &selected {
            if base + proposed > budget {
                // Over budget mid-round: return the rest to the boundary.
                self.push_boundary(v);
                continue;
            }
            // One-hop claims: every edge incident to v that was unassigned
            // at round start and not already claimed by this proposal.
            let a = shared.inc.indptr[v as usize] as usize;
            let b = shared.inc.indptr[v as usize + 1] as usize;
            for i in a..b {
                if base + proposed > budget {
                    self.push_boundary(v); // finish v in a later round
                    break;
                }
                let e = shared.inc.eid[i] as usize;
                if shared.part_of_edge[e] != UNASSIGNED || self.claimed.get(e) {
                    continue;
                }
                let w = shared.inc.other[i];
                self.claim(e, v, w, true);
                proposed += 1;
                // Two-hop claims (local form): unassigned edges from w to
                // vertices already in p — committed members or joined by
                // this very proposal — are claimed now, keeping
                // intra-partition two-hop edges from leaking to others.
                let wa = shared.inc.indptr[w as usize] as usize;
                let wb = shared.inc.indptr[w as usize + 1] as usize;
                for j in wa..wb {
                    if base + proposed > budget {
                        break;
                    }
                    let e2 = shared.inc.eid[j] as usize;
                    if shared.part_of_edge[e2] != UNASSIGNED || self.claimed.get(e2) {
                        continue;
                    }
                    let x = shared.inc.other[j];
                    if shared.membership.get(x as usize, self.id)
                        || self.joined.get(x as usize)
                    {
                        self.claim(e2, w, x, false);
                        proposed += 1;
                    }
                }
            }
        }
    }
}

struct Engine<'a> {
    shared: Shared<'a>,
    workers: Vec<PartWorker>,
    cfg: ExpansionConfig,
    rng: Rng,
    remaining_edges: usize,
}

impl<'a> Engine<'a> {
    fn new(g: &'a Graph, num_parts: usize, seed: u64, cfg: &ExpansionConfig) -> Self {
        let inc = g.incidence();
        let unassigned_deg = (0..g.n).map(|v| inc.degree(v as VId) as u32).collect();
        Engine {
            shared: Shared {
                g,
                inc,
                p: num_parts,
                part_of_edge: vec![UNASSIGNED; g.m()],
                unassigned_deg,
                membership: BitMatrix::new(g.n, num_parts),
                vcount: vec![0; num_parts],
                ecount: vec![0; num_parts],
                lambda: vec![cfg.lambda0; num_parts],
            },
            workers: (0..num_parts).map(|p| PartWorker::new(p, g.n, g.m())).collect(),
            cfg: cfg.clone(),
            rng: Rng::new(seed),
            remaining_edges: g.m(),
        }
    }

    fn run(mut self) -> EdgeAssignment {
        self.seed_partitions();
        let fixed_threshold = match self.cfg.policy {
            Policy::Dne { tau } => (tau * self.shared.g.m() as f64 / self.shared.p as f64) as usize,
            Policy::Ada { .. } => usize::MAX,
        };
        let mut idle_rounds = 0usize;
        let mut force = false;
        while self.remaining_edges > 0 {
            // --- gating (serial): budgets, pauses, reseeds, λ updates ---
            let score = self.gate(force, fixed_threshold);
            // --- propose (parallel): pure reads of the snapshot ---
            self.propose_all();
            // --- commit (serial, deterministic total order) ---
            let assigned_this_round = self.commit(&score);
            if assigned_this_round == 0 {
                idle_rounds += 1;
                // Every eligible partition paused each other out (edge-heavy
                // ones edge-paused, vertex-heavy ones vertex-paused): force
                // the least-loaded partition next round to break the tie.
                force = true;
                if idle_rounds > 3 {
                    break; // genuinely stuck — finish via assign_leftovers
                }
            } else {
                idle_rounds = 0;
                force = false;
            }
        }
        self.assign_leftovers();
        EdgeAssignment {
            num_parts: self.shared.p,
            part_of_edge: self.shared.part_of_edge,
        }
    }

    /// Random distinct seed vertex per partition (the paper initializes
    /// from 2D-hash + random seeds; random seeds preserve the behaviour at
    /// our scale).
    fn seed_partitions(&mut self) {
        let mut tries = 0;
        for p in 0..self.shared.p {
            loop {
                let v = self.rng.usize(self.shared.g.n) as VId;
                tries += 1;
                if self.shared.unassigned_deg[v as usize] > 0 || tries > 50 * self.shared.p {
                    self.workers[p].push_boundary(v);
                    break;
                }
            }
        }
    }

    /// Serial pre-phase: decide which partitions expand this round and
    /// under which edge budget, reseeding starved ones. Returns the
    /// round-start edge counts — the conflict-priority score the commit
    /// phase orders by.
    fn gate(&mut self, force: bool, fixed_threshold: usize) -> Vec<usize> {
        if let Policy::Ada { alpha, beta } = self.cfg.policy {
            self.shared.update_lambdas(alpha, beta);
        }
        let score = self.shared.ecount.clone();
        // The partition a "force round" unblocks: least-loaded by edges.
        let min_edge_part = (0..self.shared.p)
            .filter(|&p| !self.workers[p].stopped)
            .min_by_key(|&p| self.shared.ecount[p]);
        let etot: usize = self.shared.ecount.iter().sum();
        for p in 0..self.shared.p {
            self.workers[p].budget = None;
            if self.workers[p].stopped {
                continue;
            }
            let forced = force && Some(p) == min_edge_part;
            // Ada's soft constraint realized in discrete time: the round
            // budget tracks 1.15× the round-start average, so no partition
            // can run ahead of the group even within a single cascade (the
            // neighbor-expansion two-hop rule can otherwise claim thousands
            // of edges in one proposal). DNE keeps the paper's fixed
            // E_t = τ|E|/|P|.
            let edge_threshold = match self.cfg.policy {
                Policy::Dne { .. } => fixed_threshold,
                Policy::Ada { .. } if forced => usize::MAX,
                Policy::Ada { .. } => {
                    ((1.15 * (etot + self.shared.p) as f64 / self.shared.p as f64) as usize)
                        .max(64)
                }
            };
            if self.shared.ecount[p] > edge_threshold {
                if matches!(self.cfg.policy, Policy::Dne { .. }) {
                    self.workers[p].stopped = true;
                }
                continue; // Ada: paused this round
            }
            // Ada: a partition whose vertex score runs ahead of the group
            // pauses this round — the discrete-time analogue of eq. 7
            // driving λ_p → 0 at the unbalanced fixed point.
            if !forced && matches!(self.cfg.policy, Policy::Ada { .. }) && self.shared.ahead(p) {
                continue;
            }
            if self.workers[p].boundary.is_empty() && !self.reseed(p) {
                continue;
            }
            self.workers[p].budget = Some(edge_threshold);
        }
        score
    }

    /// Propose phase: each eligible partition builds its claim list from
    /// the frozen snapshot. `threads > 1` spreads the partitions over that
    /// many scoped threads; the per-partition work is a pure function of
    /// (snapshot, partition state), so the chunking cannot change any
    /// proposal.
    fn propose_all(&mut self) {
        let threads = self.cfg.threads.max(1).min(self.shared.p.max(1));
        let shared = &self.shared;
        if threads <= 1 {
            for w in &mut self.workers {
                w.propose(shared);
            }
        } else {
            let chunk = self.shared.p.div_ceil(threads);
            std::thread::scope(|s| {
                for wchunk in self.workers.chunks_mut(chunk) {
                    s.spawn(move || {
                        for w in wchunk {
                            w.propose(shared);
                        }
                    });
                }
            });
        }
    }

    /// Serial commit: walk the partitions in ascending
    /// `(round-start |E_p|, partition id)` and each partition's claims in
    /// proposal order, committing every claim whose edge is still free —
    /// i.e. claims are resolved by the fixed total order
    /// `(score, part id, claim position)`, so a contested edge always goes
    /// to the least-loaded claimant and the outcome never depends on how
    /// the propose phase was threaded. Returns the number of edges
    /// committed this round.
    fn commit(&mut self, score: &[usize]) -> usize {
        let mut order: Vec<usize> = (0..self.shared.p).collect();
        order.sort_unstable_by_key(|&q| (score[q], q));
        let mut assigned = 0usize;
        for &q in &order {
            let claims = std::mem::take(&mut self.workers[q].claims);
            for c in &claims {
                let e = c.edge as usize;
                // Clear the proposal scratch as we go.
                self.workers[q].claimed.clear(e);
                self.workers[q].joined.clear(c.anchor as usize);
                self.workers[q].joined.clear(c.other as usize);
                if self.shared.part_of_edge[e] != UNASSIGNED {
                    continue; // lost to a lower-score claimant
                }
                // A two-hop claim was justified by its target being inside
                // the partition — possibly only *optimistically* joined by
                // an earlier claim of this proposal. Membership commits
                // claim-by-claim, so if the justifying join lost its edge
                // to another partition, the target is not a member here and
                // the claim is dropped (the edge stays free for a later
                // round) instead of replicating two outside vertices in.
                if !c.one_hop && !self.shared.membership.get(c.other as usize, q) {
                    continue;
                }
                self.assign_edge(e, q, c.anchor, c.other);
                assigned += 1;
                if c.one_hop {
                    self.workers[q].push_boundary(c.other);
                }
            }
            // Hand the (cleared) allocation back for the next round.
            let mut claims = claims;
            claims.clear();
            self.workers[q].claims = claims;
        }
        assigned
    }

    fn assign_edge(&mut self, e: usize, p: usize, u: VId, w: VId) {
        debug_assert_eq!(self.shared.part_of_edge[e], UNASSIGNED);
        self.shared.part_of_edge[e] = p as u16;
        self.shared.ecount[p] += 1;
        self.remaining_edges -= 1;
        self.shared.unassigned_deg[u as usize] -= 1;
        self.shared.unassigned_deg[w as usize] -= 1;
        for v in [u, w] {
            if !self.shared.membership.get(v as usize, p) {
                self.shared.membership.set(v as usize, p);
                self.shared.vcount[p] += 1;
            }
        }
    }

    /// Partition starved (empty boundary): reseed from a random vertex that
    /// still has unassigned edges. Returns false if none exists. Runs in
    /// the serial gating phase, so the engine RNG stays a single
    /// deterministic stream for any thread count.
    fn reseed(&mut self, p: usize) -> bool {
        for _ in 0..64 {
            let v = self.rng.usize(self.shared.g.n) as VId;
            if self.shared.unassigned_deg[v as usize] > 0 {
                self.workers[p].push_boundary(v);
                return true;
            }
        }
        // Fall back to a scan (rare; only near the very end).
        for v in 0..self.shared.g.n {
            if self.shared.unassigned_deg[v] > 0 {
                self.workers[p].push_boundary(v as VId);
                return true;
            }
        }
        false
    }

    /// DNE can terminate all partitions with a few edges left; give each to
    /// the least-loaded partition among those containing an endpoint.
    fn assign_leftovers(&mut self) {
        for u in 0..self.shared.g.n {
            let (a, b) = self.shared.g.edge_range(u as VId);
            for e in a..b {
                if self.shared.part_of_edge[e] != UNASSIGNED {
                    continue;
                }
                let w = self.shared.g.dst[e];
                let mut best: Option<usize> = None;
                for p in 0..self.shared.p {
                    let member = self.shared.membership.get(u, p)
                        || self.shared.membership.get(w as usize, p);
                    let lighter = best
                        .map(|bp| self.shared.ecount[p] < self.shared.ecount[bp])
                        .unwrap_or(true);
                    if member && lighter {
                        best = Some(p);
                    }
                }
                let p = best.unwrap_or_else(|| {
                    (0..self.shared.p)
                        .min_by_key(|&p| self.shared.ecount[p])
                        .unwrap()
                });
                self.assign_edge(e, p, u as VId, w);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generator;
    use crate::partition::types::quality;

    fn powerlaw(seed: u64) -> Graph {
        let mut rng = Rng::new(seed);
        generator::chung_lu(5000, 50_000, 2.0, &mut rng)
    }

    fn run_t(g: &Graph, parts: usize, policy: Policy, threads: usize) -> EdgeAssignment {
        expand(
            g,
            parts,
            42,
            &ExpansionConfig {
                lambda0: 0.1,
                policy,
                threads,
            },
        )
    }

    fn run(g: &Graph, parts: usize, policy: Policy) -> EdgeAssignment {
        run_t(g, parts, policy, 1)
    }

    #[test]
    fn every_edge_assigned_exactly_once() {
        let g = powerlaw(90);
        for policy in [Policy::Dne { tau: 1.1 }, Policy::Ada { alpha: 1.0, beta: 1.0 }] {
            let ea = run(&g, 4, policy);
            assert_eq!(ea.part_of_edge.len(), g.m());
            assert!(ea.part_of_edge.iter().all(|&p| (p as usize) < 4));
        }
    }

    #[test]
    fn dne_respects_edge_balance() {
        let g = powerlaw(91);
        let q = quality(&g, &run(&g, 8, Policy::Dne { tau: 1.1 }));
        // Round-synchronous simulation overshoots the paper's distributed
        // runs a bit; Table II reports DNE EB up to 1.43 — we accept < 2.2
        // here and assert the *relative* claim (AdaDNE beats DNE)
        // separately.
        assert!(q.eb < 2.2, "DNE EB {}", q.eb);
    }

    #[test]
    fn adadne_improves_vertex_balance_over_dne() {
        // The paper's core claim (Table II): AdaDNE's VB < DNE's VB while
        // EB stays comparable.
        let g = powerlaw(92);
        let qd = quality(&g, &run(&g, 8, Policy::Dne { tau: 1.1 }));
        let qa = quality(&g, &run(&g, 8, Policy::Ada { alpha: 1.0, beta: 1.0 }));
        assert!(
            qa.vb < qd.vb * 1.05,
            "AdaDNE VB {} should beat DNE VB {}",
            qa.vb,
            qd.vb
        );
        assert!(qa.eb < 1.8, "AdaDNE EB {}", qa.eb);
    }

    #[test]
    fn expansion_rf_beats_random() {
        // Neighbor expansion mines locality: RF far below random edge
        // assignment's.
        let g = powerlaw(93);
        let qa = quality(&g, &run(&g, 8, Policy::Ada { alpha: 1.0, beta: 1.0 }));
        let mut rng = Rng::new(1);
        let random = EdgeAssignment {
            num_parts: 8,
            part_of_edge: (0..g.m()).map(|_| rng.usize(8) as u16).collect(),
        };
        let qr = quality(&g, &random);
        assert!(qa.rf < qr.rf * 0.8, "ada rf {} vs random rf {}", qa.rf, qr.rf);
    }

    #[test]
    fn deterministic_given_seed() {
        let g = powerlaw(94);
        let a = run(&g, 4, Policy::Ada { alpha: 1.0, beta: 1.0 });
        let b = run(&g, 4, Policy::Ada { alpha: 1.0, beta: 1.0 });
        assert_eq!(a.part_of_edge, b.part_of_edge);
    }

    /// The acceptance bar of the parallel-offline refactor: the assignment
    /// is a pure function of (graph, parts, seed, policy) — the propose
    /// thread count must never show up in the output, for either policy.
    #[test]
    fn assignment_is_bit_identical_for_any_thread_count() {
        let g = powerlaw(95);
        for policy in [Policy::Dne { tau: 1.1 }, Policy::Ada { alpha: 1.0, beta: 1.0 }] {
            let serial = run_t(&g, 6, policy, 1);
            for threads in [2usize, 4, 16] {
                let par = run_t(&g, 6, policy, threads);
                assert_eq!(
                    serial.part_of_edge, par.part_of_edge,
                    "thread count leaked into the assignment (threads={threads}, {policy:?})"
                );
            }
        }
    }

    /// threads=0 is normalized to the serial schedule, and a thread count
    /// above the partition count clamps without changing the result.
    #[test]
    fn thread_knob_degenerate_values_are_safe() {
        let g = powerlaw(96);
        let policy = Policy::Ada { alpha: 1.0, beta: 1.0 };
        let want = run_t(&g, 3, policy, 1);
        assert_eq!(want.part_of_edge, run_t(&g, 3, policy, 0).part_of_edge);
        assert_eq!(want.part_of_edge, run_t(&g, 3, policy, 64).part_of_edge);
    }
}
