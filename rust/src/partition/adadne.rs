//! AdaDNE — the paper's partitioning contribution (§III-B). Neighbor
//! expansion with an *adaptive* per-partition expansion factor that soft-
//! constrains both vertex and edge balance:
//!
//! ```text
//! VS_p = |P|·|V_p| / Σ_q |V_q|          (eq. 5)
//! ES_p = |P|·|E_p| / Σ_q |E_q|          (eq. 6)
//! λ_p ← λ_p · exp(α(1−VS_p) + β(1−ES_p))  (eq. 7)
//! ```
//!
//! Partitions ahead of the average (scores > 1) slow down, laggards speed
//! up; the DNE hard threshold is removed (equivalent to τ = |P|). Paper
//! defaults: λ⁰ = 0.1, α = β = 1.

use crate::graph::csr::Graph;
use crate::partition::expansion::{expand, ExpansionConfig, Policy};
use crate::partition::types::{EdgeAssignment, Partitioner};

pub struct AdaDNE {
    pub lambda0: f64,
    pub alpha: f64,
    pub beta: f64,
    /// Propose-phase worker threads (DESIGN.md §10). Pure throughput knob:
    /// the assignment is bit-identical for any value.
    pub threads: usize,
}

impl Default for AdaDNE {
    fn default() -> Self {
        Self {
            lambda0: 0.1,
            alpha: 1.0,
            beta: 1.0,
            threads: 1,
        }
    }
}

impl Partitioner for AdaDNE {
    fn name(&self) -> &'static str {
        "AdaDNE"
    }

    fn partition(&self, g: &Graph, num_parts: usize, seed: u64) -> EdgeAssignment {
        expand(
            g,
            num_parts,
            seed,
            &ExpansionConfig {
                lambda0: self.lambda0,
                policy: Policy::Ada {
                    alpha: self.alpha,
                    beta: self.beta,
                },
                threads: self.threads,
            },
        )
    }
}
