//! GLISP leader binary: partition / sample / train / infer a synthetic
//! workload end-to-end from the command line.
//!
//! ```text
//! glisp partition --dataset twitter-s --parts 8 --algo adadne
//!                 [--threads 4] [--save /tmp/parts]
//! glisp sample    --dataset wiki-s --parts 4 --fanouts 15,10,5 --batches 50
//!                 [--server-workers 4 --shard-size 16]
//! glisp train     --model sage --steps 200 --parts 2 [--eval]
//!                 [--server-workers 4 --shard-size 16]
//! glisp infer     --n 20000 --parts 4 --layers 3 --task both [--seq]
//!                 [--evict fifo|lru --dyn-cache-frac 0.1]
//! glisp serve-infer --n 10000 --parts 4 [--warmup] [--evict fifo|lru]
//!                 [--link-evict fifo|lru] [--dyn-cache-frac 0.1]
//!                 [--requests 200 --clients 4 --batch 6]
//!                 [--listen a,b,... | --connect a,b,...]
//! glisp serve     --partition 0 --listen unix:/tmp/glisp0.sock
//!                 (--graph train|infer|quickstart [--n N] | --dataset wiki-s
//!                  | --load DIR [--mmap]) --parts 4 [--workers 4] [--service-seed 1]
//! glisp datasets
//! glisp bench     [fig13 table5 ...] [--all] [--list] [--report] [--check]
//!                 [--diff OLD.json --against NEW.json]
//! ```
//!
//! `--server-workers R` launches an R-worker pool per sampling partition
//! and `--shard-size S` splits gathers into S-seed shards the pool serves
//! concurrently (0 = never split). Sampled outputs are bit-identical for
//! any setting (DESIGN.md §9) — these are pure throughput knobs, and so is
//! `glisp partition --threads T`: the offline propose phase and the
//! compact-structure build run on T threads with a bit-identical result
//! (DESIGN.md §10). `--save DIR` additionally assembles the last
//! algorithm's partitions and writes the binary layouts to DIR.
//!
//! **Multi-process deployment (DESIGN.md §12):** `glisp serve` runs ONE
//! partition's server pool as its own process behind a TCP or Unix socket
//! (`tcp:HOST:PORT` / `unix:PATH` / bare `HOST:PORT`). `sample`, `train`
//! and `infer` accept `--connect ADDR,ADDR,...` to use such a fleet
//! instead of launching servers in-process; the per-seed RNG contract
//! makes every sampled bit — and therefore every loss — identical to the
//! in-process run (the `loss digest` / `sample digest` lines are FNV-1a
//! fingerprints CI diffs across deployments). `--shutdown-remote` stops
//! the fleet when the client finishes; otherwise the servers keep running
//! for the next client. The serving process must host the same graph the
//! client builds locally: `--graph train` pairs with `glisp train`,
//! `--graph infer` with `glisp infer --connect`, `--graph quickstart`
//! with the quickstart example, `--dataset NAME` with `glisp sample`, and
//! `--load DIR` serves partitions saved by `glisp partition --save`;
//! adding `--mmap` maps the file read-only instead of decoding it onto the
//! heap — same served bits, near-zero heap residency (DESIGN.md §13).

use anyhow::{bail, Context, Result};
use std::sync::Arc;

use glisp::cli::Args;
use glisp::coordinator::{Batcher, FeatureStore, PipelineConfig, Trainer, TrainerConfig};
use glisp::graph::{generator, metrics};
use glisp::harness::{
    f2, f3, infer_stack, ix, power_law_trace, run_closed_loop, serving_stack, Table,
};
use glisp::inference::{
    init_decode_params, init_encoder_params, EngineConfig, EvictPolicy, LayerwiseEngine,
    SamplewiseRunner,
};
use glisp::serving::ServingConfig;
use glisp::partition::{
    quality, AdaDNE, DistributedNE, EdgeCutLDG, Hash1D, Hash2D, Partitioner,
};
use glisp::runtime::Runtime;
use glisp::sampling::{
    balanced_seeds, sample_tree, serve_partition, SampleConfig, SamplingService, ServiceConfig,
    PAD,
};
use glisp::util::digest::{f32_digest, u32_digest};
use glisp::util::rng::Rng;
use glisp::util::timer::{fmt_duration, Timer};

fn main() {
    let args = Args::from_env();
    let result = match args.subcommand.as_deref() {
        Some("partition") => cmd_partition(&args),
        Some("sample") => cmd_sample(&args),
        Some("train") => cmd_train(&args),
        Some("infer") => cmd_infer(&args),
        Some("serve-infer") => cmd_serve_infer(&args),
        Some("serve") => cmd_serve(&args),
        Some("datasets") => cmd_datasets(&args),
        Some("bench") => cmd_bench(&args),
        _ => {
            eprintln!(
                "usage: glisp <partition|sample|train|infer|serve-infer|serve|datasets|bench> [--flags]\n\
                 see rust/src/main.rs for per-command flags"
            );
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// `glisp bench`: run bench targets (delegating to `cargo bench`), list the
/// bench↔paper-figure mapping, regenerate EXPERIMENTS.md from the committed
/// `BENCH_*.json` artifacts, or diff two artifact files. See README
/// §Benchmarking and DESIGN.md §11.
fn cmd_bench(args: &Args) -> Result<()> {
    use glisp::harness::bench::{self, BenchArtifact, BENCHES};
    use glisp::harness::report;

    if let Some(old) = args.get("diff") {
        let new = args
            .get("against")
            .context("usage: glisp bench --diff OLD.json --against NEW.json")?;
        return bench_diff(
            &BenchArtifact::load(std::path::Path::new(old))?,
            &BenchArtifact::load(std::path::Path::new(new))?,
        );
    }

    let wants_report = args.has("report") || args.has("check");
    if args.has("list") || (args.positionals.is_empty() && !args.has("all") && !wants_report) {
        let dir = bench::artifact_dir();
        let mut t = Table::new(
            "Bench suite (run with `glisp bench <name>` or `cargo bench --bench <target>`)",
            &["name", "target", "paper ref", "artifact"],
        );
        for (name, target, paper) in BENCHES {
            let present = dir.join(format!("BENCH_{target}.json")).exists();
            t.row(&[
                (*name).into(),
                (*target).into(),
                (*paper).into(),
                if present { "yes" } else { "-" }.into(),
            ]);
        }
        t.print();
        println!("artifact dir: {} (override with GLISP_BENCH_DIR)", dir.display());
        return Ok(());
    }

    let targets: Vec<&str> = if args.has("all") {
        BENCHES.iter().map(|(_, t, _)| *t).collect()
    } else {
        args.positionals
            .iter()
            .map(|n| {
                bench::resolve_bench(n)
                    .with_context(|| format!("unknown bench {n}; try `glisp bench --list`"))
            })
            .collect::<Result<_>>()?
    };
    for target in &targets {
        println!("== cargo bench --bench {target}");
        let status = std::process::Command::new("cargo")
            .args(["bench", "--bench", target])
            .current_dir(env!("CARGO_MANIFEST_DIR"))
            .status()
            .context("spawn cargo (is a Rust toolchain on PATH?)")?;
        anyhow::ensure!(status.success(), "bench {target} failed ({status})");
    }

    if wants_report {
        let (path, _, changed) =
            report::regenerate_experiments(&bench::artifact_dir(), !args.has("check"))?;
        if args.has("check") {
            anyhow::ensure!(
                !changed,
                "{} is out of sync with the committed artifacts; run `glisp bench --report`",
                path.display()
            );
            println!("{} is in sync with the artifacts", path.display());
        } else if changed {
            println!("regenerated measured sections of {}", path.display());
        } else {
            println!("{} already up to date", path.display());
        }
    }
    Ok(())
}

/// Print a cell-by-cell comparison of two bench artifacts (rows matched by
/// each section's first column, the label column by convention).
fn bench_diff(
    old: &glisp::harness::bench::BenchArtifact,
    new: &glisp::harness::bench::BenchArtifact,
) -> Result<()> {
    use glisp::harness::bench::Assertion;
    use glisp::util::json::{emit, Json};

    anyhow::ensure!(
        old.bench == new.bench,
        "artifacts are from different benches ({} vs {})",
        old.bench,
        new.bench
    );
    println!(
        "bench {}: {} ({}) -> {} ({})",
        new.bench, old.meta.git_sha, old.meta.date_utc, new.meta.git_sha, new.meta.date_utc
    );
    if old.meta.bench_scale != new.meta.bench_scale || old.meta.env != new.meta.env {
        println!(
            "  WARNING: workload knobs differ (scale {} vs {}) — timings not comparable",
            old.meta.bench_scale, new.meta.bench_scale
        );
    }
    for ns in &new.sections {
        let Some(os) = old.section(&ns.id) else {
            println!("  section {} only in new run", ns.id);
            continue;
        };
        println!("  section {}:", ns.id);
        let Some(key) = ns.columns.first().map(|c| c.key.clone()) else { continue };
        for row in &ns.rows {
            let label = match row.first() {
                Some(Json::Str(s)) => s.clone(),
                Some(v) => emit(v),
                None => continue,
            };
            for (ci, col) in ns.columns.iter().enumerate().skip(1) {
                let new_v = row.get(ci);
                let old_v = os.find_row(&key, &label).and_then(|r| r.get(ci));
                let (Some(Json::Num(a)), Some(Json::Num(b))) = (old_v, new_v) else {
                    continue;
                };
                if a == b {
                    continue;
                }
                if col.unit == "ns" {
                    println!(
                        "    {label} / {}: {} -> {} ({:+.1}%)",
                        col.label,
                        fmt_duration(a / 1e9),
                        fmt_duration(b / 1e9),
                        (b - a) / a * 100.0
                    );
                } else {
                    println!("    {label} / {}: {a} -> {b}", col.label);
                }
            }
        }
    }
    let named = |xs: &[Assertion]| -> Vec<String> {
        xs.iter().map(|x| format!("{}={}", x.name, x.passed)).collect()
    };
    if named(&old.assertions) != named(&new.assertions) {
        println!("  checks old: {:?}", named(&old.assertions));
        println!("  checks new: {:?}", named(&new.assertions));
    }
    Ok(())
}

fn dataset_by_name(name: &str, seed: u64) -> Result<glisp::graph::Graph> {
    let spec = generator::paper_datasets()
        .into_iter()
        .find(|d| d.name == name)
        .with_context(|| format!("unknown dataset {name}; try `glisp datasets`"))?;
    Ok(generator::generate(&spec, seed))
}

fn partitioner_by_name(name: &str, threads: usize) -> Result<Box<dyn Partitioner>> {
    Ok(match name {
        "adadne" => Box::new(AdaDNE {
            threads,
            ..Default::default()
        }),
        "dne" => Box::new(DistributedNE {
            threads,
            ..Default::default()
        }),
        // The remaining baselines are single-pass streams; the propose
        // thread knob does not apply.
        "edgecut" => Box::new(EdgeCutLDG::default()),
        "hash1d" => Box::new(Hash1D),
        "hash2d" => Box::new(Hash2D),
        other => bail!("unknown partitioner {other}"),
    })
}

fn cmd_datasets(_args: &Args) -> Result<()> {
    let mut t = Table::new(
        "Synthetic dataset suite (Table I analogue)",
        &["name", "vertices", "edges", "avg deg", "max deg", "power law"],
    );
    for spec in generator::paper_datasets() {
        if spec.n > 200_000 {
            // Skip generating the big one for the listing.
            t.row(&[
                spec.name.into(),
                ix(spec.n),
                ix(spec.m),
                f2(spec.m as f64 / spec.n as f64),
                "-".into(),
                "yes (by construction)".into(),
            ]);
            continue;
        }
        let g = generator::generate(&spec, 1);
        let s = metrics::summarize(spec.name, &g);
        t.row(&[
            s.name,
            ix(s.n),
            ix(s.m),
            f2(s.avg_degree),
            ix(s.max_degree as usize),
            if s.power_law { "yes" } else { "no" }.into(),
        ]);
    }
    t.print();
    Ok(())
}

fn cmd_partition(args: &Args) -> Result<()> {
    let g = dataset_by_name(args.get_str("dataset", "wiki-s"), args.get_u64("seed", 1))?;
    let parts = args.get_usize("parts", 8);
    let threads = args.get_usize("threads", 1);
    let mut t = Table::new(
        &format!("Partition quality, {parts} parts, {threads} offline threads"),
        &["algorithm", "RF", "VB", "EB", "time(s)"],
    );
    let algos = args.get_str("algo", "edgecut,dne,adadne").to_string();
    let mut last: Option<glisp::partition::EdgeAssignment> = None;
    for name in algos.split(',') {
        let p = partitioner_by_name(name, threads)?;
        let timer = Timer::start();
        let ea = p.partition(&g, parts, args.get_u64("seed", 1));
        let secs = timer.secs();
        let q = quality(&g, &ea);
        t.row(&[name.into(), f3(q.rf), f3(q.vb), f3(q.eb), f2(secs)]);
        last = Some(ea);
    }
    t.print();
    // --save DIR: assemble the compact structures for the last algorithm
    // in the list (with the same thread knob) and write the binary
    // layouts wave-by-wave — at most `threads` partition structures are
    // ever resident, completing the out-of-core offline path.
    if let (Some(dir), Some(ea)) = (args.get("save"), last) {
        let dir = std::path::PathBuf::from(dir);
        let timer = Timer::start();
        let peak =
            glisp::graph::build_and_save_partitions(&g, &ea.part_of_edge, parts, threads, &dir)?;
        let saved: u64 = (0..parts)
            .map(|i| {
                std::fs::metadata(dir.join(format!("part{i}.bin")))
                    .map(|m| m.len())
                    .unwrap_or(0)
            })
            .sum();
        println!(
            "built+saved {parts} partitions to {} in {} ({threads} threads, \
             {:.1} MiB on disk, wave peak {:.1} MiB resident)",
            dir.display(),
            fmt_duration(timer.secs()),
            saved as f64 / (1024.0 * 1024.0),
            peak as f64 / (1024.0 * 1024.0)
        );
    }
    Ok(())
}

/// The sampling-service threading knobs shared by `sample` and `train`.
fn service_config(args: &Args) -> ServiceConfig {
    ServiceConfig::new(
        args.get_usize("server-workers", 1),
        args.get_usize("shard-size", 0),
    )
}

/// `--connect a,b,c` parsed into socket addresses (None = in-process).
fn connect_addrs(args: &Args) -> Option<Vec<String>> {
    args.get("connect").map(|v| {
        v.split(',')
            .filter(|a| !a.is_empty())
            .map(str::to_string)
            .collect()
    })
}

/// `--evict fifo|lru` (and `--link-evict`) parsed into a cache policy.
fn evict_policy(name: &str) -> Result<EvictPolicy> {
    Ok(match name {
        "fifo" => EvictPolicy::Fifo,
        "lru" => EvictPolicy::Lru,
        other => bail!("unknown eviction policy {other} (fifo|lru)"),
    })
}

fn cmd_sample(args: &Args) -> Result<()> {
    let fanouts: Vec<usize> = args
        .get_str("fanouts", "15,10,5")
        .split(',')
        .filter_map(|x| x.parse().ok())
        .collect();
    let batches = args.get_usize("batches", 20);
    let batch = args.get_usize("batch", 64);
    let weighted = args.has("weighted");

    // In-process pool over the dataset, or an already-running socket fleet
    // (which must host the same dataset: `glisp serve --dataset ...`).
    let connected = connect_addrs(args);
    let svc = if let Some(addrs) = &connected {
        SamplingService::connect(addrs, 0, service_config(args))?
    } else {
        let g = dataset_by_name(args.get_str("dataset", "wiki-s"), args.get_u64("seed", 1))?;
        let parts = args.get_usize("parts", 4);
        let ea = AdaDNE::default().partition(&g, parts, 1);
        SamplingService::launch_cfg(&g, &ea, 1, service_config(args))?
    };
    let parts = svc.num_partitions();
    let mut client = svc.client(2);
    let mut rng = Rng::new(3);
    let cfg = SampleConfig {
        weighted,
        ..Default::default()
    };
    let timer = Timer::start();
    let mut slots = 0usize;
    // Running FNV fingerprint over every sampled level — the cross-process
    // bit-equality witness CI diffs between deployments.
    let mut sampled: Vec<u32> = Vec::new();
    for _ in 0..batches {
        let seeds = balanced_seeds(&svc, batch / parts.max(1), &mut rng);
        let tree = sample_tree(&mut client, &seeds, &fanouts, &cfg)?;
        slots += tree.total_slots();
        for lvl in &tree.levels {
            sampled.extend_from_slice(lvl);
        }
    }
    let secs = timer.secs();
    println!(
        "sampled {batches} batches (fanouts {fanouts:?}, weighted={weighted}) \
         in {} — {:.0} slots/s",
        fmt_duration(secs),
        slots as f64 / secs
    );
    println!("sample digest: {:016x}", u32_digest(&sampled));
    let wl = svc.workload()?;
    let norm = glisp::coordinator::metrics::normalized_workload(&wl);
    println!("per-server workload (edges scanned): {wl:?}");
    println!(
        "normalized: {:?}",
        norm.iter().map(|x| (x * 100.0).round() / 100.0).collect::<Vec<_>>()
    );
    if svc.config.workers > 1 || connected.is_some() {
        println!("per-worker requests (pool attribution): {:?}", svc.worker_requests()?);
    }
    if connected.is_some() && !args.has("shutdown-remote") {
        svc.disconnect();
    } else {
        svc.shutdown();
    }
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let model = args.get_str("model", "sage").to_string();
    let steps = args.get_usize("steps", 100);
    let parts = args.get_usize("parts", 2);
    let mut rng = Rng::new(args.get_u64("seed", 1));
    let n = args.get_usize("n", 20_000);
    let classes = 8;
    let g = generator::labeled_community_graph(n, n * 12, classes, 0.9, &mut rng);
    let labels = Arc::new(g.label.clone());
    // In-process service, or an already-running `glisp serve --graph train`
    // fleet hosting the identical graph/partitioning (losses bit-equal
    // either way — DESIGN.md §12).
    let connected = connect_addrs(args);
    let svc = if let Some(addrs) = &connected {
        SamplingService::connect(addrs, g.n, service_config(args))?
    } else {
        let ea = AdaDNE::default().partition(&g, parts, 1);
        SamplingService::launch_cfg(&g, &ea, 1, service_config(args))?
    };
    let parts = svc.num_partitions();
    let features = FeatureStore::labeled(64, labels.clone(), classes, 0.6);
    let mut trainer = Trainer::new(
        Runtime::default_dir(),
        svc.client(3),
        features,
        TrainerConfig {
            model: model.clone(),
            lr: args.get_f64("lr", 0.1) as f32,
        },
        7,
    )?;
    println!(
        "model={model} params={} batch={} fanouts={:?}",
        trainer.params.num_parameters(),
        trainer.batch,
        trainer.fanouts
    );
    println!(
        "sampling: {parts} partitions x {} pool workers, shard size {}",
        svc.config.workers,
        if svc.config.shard_size == usize::MAX {
            "off".to_string()
        } else {
            svc.config.shard_size.to_string()
        }
    );
    // 80/20 train/test split.
    let split = (n * 8) / 10;
    let train_seeds: Vec<u32> = (0..split as u32).collect();
    let train_labels: Vec<u16> = train_seeds.iter().map(|&v| labels[v as usize]).collect();
    let mut batcher = Batcher::new(train_seeds, train_labels, trainer.batch, 5)?;
    let timer = Timer::start();
    // Pipelined producer by default; `--sync` selects the sequential path.
    let losses = if args.has("sync") {
        trainer.train(&mut batcher, steps)?
    } else {
        let pcfg = PipelineConfig {
            producers: args.get_usize("producers", 2),
            queue_depth: args.get_usize("queue", 2),
            ordered: !args.has("unordered"),
        };
        trainer.train_pipelined(&mut batcher, steps, &pcfg)?
    };
    let secs = timer.secs();
    for (i, chunk) in losses.chunks(10).enumerate() {
        let mean: f32 = chunk.iter().sum::<f32>() / chunk.len() as f32;
        println!("step {:>5}  loss {:.4}", i * 10 + chunk.len(), mean);
    }
    // FNV-1a over the full loss curve's f32 bit patterns: equal digests ⇔
    // bit-equal training, the CI witness for in-process vs socket runs.
    println!("loss digest: {:016x}", f32_digest(&losses));
    println!(
        "trained {steps} steps in {} ({:.2} steps/s, {:.0} samples/s)",
        fmt_duration(secs),
        steps as f64 / secs,
        steps as f64 * trainer.batch as f64 / secs
    );
    if args.has("eval") {
        let test_seeds: Vec<u32> = (split as u32..n as u32).collect();
        let test_labels: Vec<u16> = test_seeds.iter().map(|&v| labels[v as usize]).collect();
        let acc = trainer.evaluate(&test_seeds, &test_labels)?;
        println!("test accuracy: {acc:.3}");
    }
    if connected.is_some() && !args.has("shutdown-remote") {
        svc.disconnect();
    } else {
        svc.shutdown();
    }
    Ok(())
}

fn cmd_infer(args: &Args) -> Result<()> {
    if let Some(addrs) = connect_addrs(args) {
        return cmd_infer_connect(args, &addrs);
    }
    let n = args.get_usize("n", 10_000);
    let parts = args.get_usize("parts", 4);
    let layers = args.get_usize("layers", 2);
    let task = args.get_str("task", "vertex").to_string();
    let mut rng = Rng::new(args.get_u64("seed", 1));
    let g = generator::chung_lu(n, n * 7, 2.1, &mut rng);
    let ea = AdaDNE::default().partition(&g, parts, 1);
    let dir = std::env::temp_dir().join("glisp_infer_cli");
    let _ = std::fs::remove_dir_all(&dir);

    let runtime = Runtime::load_with_layers(Runtime::default_dir(), layers)?;
    let enc = init_encoder_params(&runtime, 3)?;
    let mut engine = LayerwiseEngine::new(
        &g,
        &ea,
        runtime,
        FeatureStore::unlabeled(64),
        enc.clone(),
        EngineConfig {
            layers,
            // --seq: single-threaded partition sweeps (bit-identical,
            // slower; the fig13 baseline).
            parallel: !args.has("seq"),
            // Dynamic-tier knobs (same served bits for any setting; pure
            // hit-ratio/cost knobs).
            policy: evict_policy(args.get_str("evict", "fifo"))?,
            dyn_cache_frac: args.get_f64("dyn-cache-frac", 0.1),
            ..Default::default()
        },
        dir,
    )?;
    let timer = Timer::start();
    let (h, report) = engine.run_vertex_embedding()?;
    let lw_secs = timer.secs();
    println!(
        "layerwise vertex embedding (K={layers}): {lw_secs:.2}s, {} vertex-computations, \
         {} chunk reads, {} dynamic hits (ratio {:.3}), virtual cost {}",
        report.vertices_computed,
        report.chunk_reads,
        report.dynamic_hits,
        report.dynamic_hit_ratio,
        report.virtual_cost
    );
    println!(
        "  per tier: static hit {:.3}, dynamic hit {:.3}, {} remote reads \
         (policy {:?}, dyn frac {})",
        report.static_hit_ratio(),
        report.dynamic_hit_ratio,
        report.remote_reads,
        engine.cfg.policy,
        engine.cfg.dyn_cache_frac
    );
    for w in &report.workers {
        if w.vertices_computed > 0 {
            println!(
                "  worker {:>2}: {} vertices, fill {} chunks, model {:.2}s, \
                 dyn hit ratio {:.3}",
                w.worker,
                w.vertices_computed,
                w.fill_chunks,
                w.model_secs,
                w.dynamic_hit_ratio()
            );
        }
    }

    if task == "vertex" || task == "both" {
        let runtime2 = Runtime::load_with_layers(Runtime::default_dir(), layers)?;
        let mut sw = SamplewiseRunner::new(&g, runtime2, FeatureStore::unlabeled(64), enc, 5)?;
        let timer = Timer::start();
        let (_, rep) = sw.run_vertex_embedding()?;
        let sw_secs = timer.secs();
        println!(
            "samplewise vertex embedding: {sw_secs:.2}s, {} vertex-computations — \
             layerwise speedup {:.2}x (compute ratio {:.2}x)",
            rep.vertices_computed,
            sw_secs / lw_secs,
            rep.vertices_computed as f64 / report.vertices_computed as f64
        );
    }
    if task == "link" || task == "both" {
        let dec = init_decode_params(&engine.runtime, 9)?;
        let edges: Vec<(u32, u32)> = (0..(n as u32 / 4))
            .filter(|&u| !g.out_neighbors(u).is_empty())
            .map(|u| (u, g.out_neighbors(u)[0]))
            .collect();
        let timer = Timer::start();
        let (_, rep) = engine.run_link_prediction(&h, &edges, &dec)?;
        println!(
            "layerwise link prediction over {} edges: {:.2}s, {} chunk reads, \
             static hit {:.3}, dynamic hit {:.3}",
            edges.len(),
            timer.secs(),
            rep.chunk_reads,
            rep.static_hit_ratio(),
            rep.dynamic_hit_ratio
        );
    }
    Ok(())
}

/// `glisp infer --connect`: samplewise vertex embedding with every K-hop
/// tree sampled through the socket fleet (`glisp serve --graph infer`
/// processes hosting the same chung_lu graph). The layerwise engine reads
/// its partitions from local memory by design (DESIGN.md §8) and so has no
/// remote mode; the samplewise path is the honest distributed-inference
/// story (only trees cross the wire, features stay client-side).
fn cmd_infer_connect(args: &Args, addrs: &[String]) -> Result<()> {
    let n = args.get_usize("n", 10_000);
    let layers = args.get_usize("layers", 2);
    let mut rng = Rng::new(args.get_u64("seed", 1));
    let g = generator::chung_lu(n, n * 7, 2.1, &mut rng);

    let svc = SamplingService::connect(
        addrs,
        g.n,
        ServiceConfig::new(1, args.get_usize("shard-size", 0)),
    )?;
    println!(
        "connected to {} partition servers: {:?}",
        svc.num_partitions(),
        svc.endpoints.iter().map(|e| e.peer()).collect::<Vec<_>>()
    );
    let client = svc.client(4);
    let runtime = Runtime::load_with_layers(Runtime::default_dir(), layers)?;
    let enc = init_encoder_params(&runtime, 3)?;
    let mut sw = SamplewiseRunner::new(&g, runtime, FeatureStore::unlabeled(64), enc, 5)?;
    let timer = Timer::start();
    let (h, rep) = sw.run_vertex_embedding_via(&client, g.n)?;
    println!(
        "samplewise vertex embedding via sampling service: {:.2}s, {} vertex-computations",
        timer.secs(),
        rep.vertices_computed
    );
    println!("embedding digest: {:016x}", f32_digest(&h));
    if args.has("shutdown-remote") {
        svc.shutdown();
    } else {
        svc.disconnect();
    }
    Ok(())
}

/// `glisp serve-infer`: online embedding/link-score serving over the
/// request-driven K-slice engine (DESIGN.md §15). Builds the `infer` stack,
/// optionally warms every serving slab from one offline layerwise pass
/// (`--warmup`), then drives a closed-loop power-law workload with
/// concurrent clients and reports p50/p99/QPS plus the per-tier hit
/// ratios. Link candidates are sampled through the fleet: in-process
/// channels by default, `--listen a,b,...` spins up loopback socket
/// servers (one address per partition), `--connect a,b,...` joins an
/// already-running `glisp serve --graph infer` fleet. The `online digest`
/// line must equal the `offline digest` line — CI diffs them.
fn cmd_serve_infer(args: &Args) -> Result<()> {
    let n = args.get_usize("n", 10_000);
    let parts = args.get_usize("parts", 4);
    let layers = args.get_usize("layers", 2);
    let requests = args.get_usize("requests", 200);
    let clients = args.get_usize("clients", 4);
    let batch = args.get_usize("batch", 6);
    let embed_policy = evict_policy(args.get_str("evict", "fifo"))?;
    let scfg = ServingConfig {
        embed_policy,
        link_policy: match args.get("link-evict") {
            Some(name) => evict_policy(name)?,
            None => embed_policy,
        },
        dyn_cache_frac: args.get_f64("dyn-cache-frac", 0.1),
    };
    let ecfg = EngineConfig {
        layers,
        parallel: !args.has("seq"),
        ..Default::default()
    };
    let root = std::env::temp_dir().join("glisp_serve_infer_cli");
    let _ = std::fs::remove_dir_all(&root);
    let art = Runtime::default_dir();

    // Offline reference sweep over the identical stack — the byte-level
    // ground truth for every served embedding.
    let mut off = infer_stack(n, parts, &art, root.join("off"), ecfg.clone())?;
    let (h, _) = off.engine.run_vertex_embedding()?;
    let hidden = off.engine.hidden();
    let trace = power_law_trace(&off.g, requests * batch, args.get_u64("trace-seed", 23));
    let mut offline_rows = Vec::with_capacity(trace.len() * hidden);
    for &v in &trace {
        let r = off.engine.rank[v as usize] as usize;
        offline_rows.extend_from_slice(&h[r * hidden..(r + 1) * hidden]);
    }

    let mut stack = serving_stack(n, parts, &art, root.join("srv"), ecfg, scfg)?;
    if args.has("warmup") {
        let t = Timer::start();
        stack.serving.warm()?;
        println!("warmup (one offline layerwise pass): {}", fmt_duration(t.secs()));
    }
    let rep = run_closed_loop(&mut stack.serving, &trace, clients, batch)?;
    println!(
        "served {} requests ({} clients, batch {}): p50 {:.1}µs, p99 {:.1}µs, {:.0} QPS",
        rep.requests, clients, batch, rep.p50_us, rep.p99_us, rep.qps
    );
    let st = stack.serving.stats();
    println!(
        "cache tiers: static hit {:.3}, dynamic hit {:.3}, {} remote reads — \
         {} rows computed, {} frontier truncations (evict {:?}/{:?}, dyn frac {})",
        st.static_hit_ratio(),
        st.dynamic_hit_ratio(),
        st.remote_reads,
        st.rows_computed,
        st.rows_truncated,
        scfg.embed_policy,
        scfg.link_policy,
        scfg.dyn_cache_frac
    );
    println!("online digest: {:016x}", f32_digest(&stack.serving.embed(&trace)?));
    println!("offline digest: {:016x}", f32_digest(&offline_rows));

    // Link-score path: candidates from the sampling fleet (the transport
    // axis), endpoint embeddings from the serving slabs.
    let connected = connect_addrs(args);
    let (svc, servers) = if let Some(addrs) = &connected {
        (
            SamplingService::connect(addrs, stack.g.n, service_config(args))?,
            Vec::new(),
        )
    } else if let Some(listens) = args.get("listen") {
        let listens: Vec<String> = listens
            .split(',')
            .filter(|a| !a.is_empty())
            .map(str::to_string)
            .collect();
        SamplingService::launch_remote(&stack.g, &stack.ea, 1, service_config(args), &listens)?
    } else {
        (
            SamplingService::launch_cfg(&stack.g, &stack.ea, 1, service_config(args))?,
            Vec::new(),
        )
    };
    let mut client = svc.client(7);
    let mut link_seeds: Vec<u32> = trace[..trace.len().min(48)].to_vec();
    link_seeds.sort_unstable();
    link_seeds.dedup();
    let sample = client.sample_topk(&link_seeds, 5, &SampleConfig::default())?;
    let mut edges: Vec<(u32, u32)> = Vec::new();
    for (i, &s) in link_seeds.iter().enumerate() {
        for &nb in sample.neighbors_of(i) {
            if nb != PAD {
                edges.push((s, nb));
            }
        }
    }
    let dec = init_decode_params(&stack.serving.engine.runtime, 9)?;
    let scores = stack.serving.link_scores(&edges, &dec)?;
    println!(
        "link scores over {} fleet-sampled candidates — link digest: {:016x}",
        edges.len(),
        f32_digest(&scores)
    );
    if connected.is_some() && !args.has("shutdown-remote") {
        svc.disconnect();
    } else {
        svc.shutdown();
    }
    for s in servers {
        s.join();
    }
    Ok(())
}

/// `glisp serve`: run ONE partition's sampling-server pool as this process,
/// listening on a socket, until a client sends the Shutdown frame. The
/// partition comes from `--load DIR` (saved by `glisp partition --save`) or
/// is rebuilt from the named deterministic stack (`--graph train|infer|
/// quickstart` or `--dataset NAME`) — bit-identical to what the matching
/// client builds, because graph generation, AdaDNE and the structure build
/// are all seed-driven (DESIGN.md §10).
fn cmd_serve(args: &Args) -> Result<()> {
    let part_id = args
        .get_usize("partition", usize::MAX);
    anyhow::ensure!(part_id != usize::MAX, "serve requires --partition <id>");
    let listen = args
        .get("listen")
        .context("serve requires --listen tcp:HOST:PORT or unix:PATH")?;
    let workers = args.get_usize("workers", 1);
    // Must match the launch seed of the client-side reference run
    // (every in-repo launch site uses 1).
    let service_seed = args.get_u64("service-seed", 1);

    let part = if let Some(dir) = args.get("load") {
        // Storage seam: `--mmap` maps the saved file read-only instead of
        // decoding it onto the heap — the served bits are identical
        // (DESIGN.md §13), only residency changes.
        let backend = if args.has("mmap") {
            glisp::graph::StoreBackend::Mmap
        } else {
            glisp::graph::StoreBackend::Heap
        };
        let part = glisp::graph::store::store(backend)
            .open(std::path::Path::new(dir), &format!("part{part_id}"))?;
        println!(
            "loaded partition {part_id} from {dir} ({} backend, {} heap / {} mapped bytes)",
            backend.name(),
            part.heap_bytes(),
            part.mapped_bytes()
        );
        part
    } else {
        let parts = args.get_usize("parts", 4);
        let seed = args.get_u64("seed", 1);
        let g = if let Some(name) = args.get("dataset") {
            dataset_by_name(name, seed)?
        } else {
            match args.get_str("graph", "train") {
                // The `glisp train` / train_e2e stack.
                "train" => {
                    let n = args.get_usize("n", 20_000);
                    let mut rng = Rng::new(seed);
                    generator::labeled_community_graph(n, n * 12, 8, 0.9, &mut rng)
                }
                // The `glisp infer --connect` stack.
                "infer" => {
                    let n = args.get_usize("n", 10_000);
                    let mut rng = Rng::new(seed);
                    generator::chung_lu(n, n * 7, 2.1, &mut rng)
                }
                // The quickstart example's stack.
                "quickstart" => {
                    let mut rng = Rng::new(42);
                    generator::labeled_community_graph(5_000, 60_000, 8, 0.9, &mut rng)
                }
                other => bail!("unknown --graph {other} (train|infer|quickstart)"),
            }
        };
        let ea = AdaDNE::default().partition(&g, parts, 1);
        // Build ONLY this process's partition: the membership scan covers
        // the full graph, but just one compact structure is assembled —
        // a serve fleet never holds all P structures anywhere.
        glisp::graph::build_single_partition(
            &g,
            &ea.part_of_edge,
            part_id,
            parts,
            workers.max(1),
        )?
    };
    anyhow::ensure!(
        part.part_id == part_id,
        "partition file serves partition {} but --partition {part_id} was requested",
        part.part_id
    );

    let srv = serve_partition(Arc::new(part), listen, service_seed, workers)?;
    // CI and scripts wait for this line (and for unix socket files) before
    // starting clients.
    println!("serving partition {part_id} at {} ({workers} workers)", srv.addr());
    srv.join();
    println!("partition {part_id} server stopped");
    Ok(())
}
