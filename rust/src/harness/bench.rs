//! Machine-readable bench artifacts: every fig/table bench threads its
//! rows through a [`BenchRecorder`], which renders the familiar ASCII
//! tables *and* writes a schema-versioned `BENCH_<bench>.json` artifact
//! (at the repo root by default, `GLISP_BENCH_DIR` to redirect).
//!
//! The artifact carries, per run:
//! * **run metadata** ([`RunMeta`]) — git SHA + dirty flag, UTC date,
//!   host core count, executor backend, the `GLISP_*` env knobs in
//!   effect — so a number is never separated from its provenance;
//! * **sections** ([`Section`]) — one per rendered table, with typed
//!   columns (durations are recorded as wall nanoseconds, unit `"ns"`)
//!   and rows of raw scalar values, not display strings;
//! * **assertion outcomes** ([`Assertion`]) — the bit-equality and
//!   pool/thread-invariance checks the benches already perform
//!   (DESIGN.md §7–§10 contracts), recorded as machine-checkable fields
//!   *before* panicking on failure, so a red run still leaves evidence.
//!
//! Determinism contract: cell *values* are measurements and vary run to
//! run; everything else — key order (BTreeMap), section/row order, the
//! schema itself — is deterministic, so two artifacts from the same
//! commit diff cleanly (`glisp bench --diff A --against B`). The schema
//! is validated on every load by [`BenchArtifact::from_json`], which
//! rejects unknown fields and version mismatches: bump
//! [`SCHEMA_VERSION`] whenever a field is added, removed or retyped
//! (DESIGN.md §11 has the field-by-field reference and the bump policy).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::harness::report::{f2, f3, ix, Table};
use crate::util::json::{emit_pretty, Json};
use crate::util::timer::fmt_duration;

/// Version stamped into and required from every artifact. Bump on any
/// schema change; the CI schema-validation step then fails until the
/// committed artifacts and docs are regenerated.
pub const SCHEMA_VERSION: u32 = 1;

/// The bench suite: (short name, cargo bench target, paper target).
/// Shared by `glisp bench`, the EXPERIMENTS.md generator and CI so the
/// three can never disagree about what "all benches" means.
pub const BENCHES: &[(&str, &str, &str)] = &[
    ("fig08", "fig08_degree_dist", "Fig. 8 — degree distributions of the dataset suite"),
    ("fig09", "fig09_sampling_speed", "Fig. 9 — sampling throughput vs baselines"),
    ("fig10", "fig10_server_workload", "Fig. 10 — normalized server workload balance"),
    ("fig11", "fig11_train_speed", "Fig. 11 — end-to-end training speed vs baseline"),
    ("fig12", "fig12_scalability", "Fig. 12 — convergence + scaling with trainer count"),
    ("fig13", "fig13_inference", "Fig. 13 — layerwise vs samplewise inference"),
    ("fig14", "fig14_reorder_cache", "Fig. 14 — reorder algorithms + caching system"),
    ("fig15", "fig15_interior_lru", "Fig. 15 — interior fraction; LRU vs FIFO"),
    ("table2", "table2_partition_quality", "Table II — partition quality (RF/VB/EB)"),
    ("table3", "table3_memory", "Table III — graph structure memory footprint"),
    ("table4", "table4_accuracy", "Table IV — test accuracy parity via the full stack"),
    ("table5", "table5_cache_fill", "Table V — static cache fill vs model inference"),
    ("pipeline", "pipeline_throughput", "DESIGN.md §7/§9 — pipelined vs sync training"),
    ("hotpath", "bench_hotpath", "DESIGN.md §14 — gather arena + pooled assembly hot path"),
    ("serving", "bench_serving", "DESIGN.md §15 — online serving under power-law traffic"),
];

/// Resolve a short or full bench name to its cargo bench target.
pub fn resolve_bench(name: &str) -> Option<&'static str> {
    BENCHES
        .iter()
        .find(|(short, target, _)| *short == name || *target == name)
        .map(|(_, target, _)| *target)
}

/// Where `BENCH_*.json` artifacts are written and read: `GLISP_BENCH_DIR`
/// when set and non-empty, else the repo root (one level above the crate).
pub fn artifact_dir() -> PathBuf {
    match std::env::var("GLISP_BENCH_DIR") {
        Ok(d) if !d.is_empty() => PathBuf::from(d),
        _ => repo_root(),
    }
}

/// The repo root (one level above `rust/`), where artifacts are committed
/// and EXPERIMENTS.md lives.
pub fn repo_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .map(Path::to_path_buf)
        .unwrap_or_else(|| PathBuf::from("."))
}

/// Column key derived from a display label: lowercased, alnum runs joined
/// by single underscores ("uni wall 4w" -> "uni_wall_4w", "1t(s)" -> "1t_s").
pub fn slug(label: &str) -> String {
    let mut out = String::new();
    let mut gap = false;
    for c in label.chars() {
        if c.is_ascii_alphanumeric() {
            if gap && !out.is_empty() {
                out.push('_');
            }
            gap = false;
            out.push(c.to_ascii_lowercase());
        } else {
            gap = true;
        }
    }
    out
}

/// The value kind of one cell; the first typed cell fixes its column's
/// recorded unit.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CellKind {
    /// Free text (row labels).
    Str,
    /// Dimensionless float (ratios, rates, MB, ...).
    Num,
    /// Integer count.
    Count,
    /// Wall-clock duration, recorded as nanoseconds.
    DurNs,
    /// Speedup factor, displayed as "1.23x".
    Speedup,
    /// Not applicable ("-"), recorded as null; does not fix the unit.
    Na,
}

impl CellKind {
    fn unit(self) -> &'static str {
        match self {
            CellKind::Str => "str",
            CellKind::Num => "num",
            CellKind::Count => "count",
            CellKind::DurNs => "ns",
            CellKind::Speedup => "speedup",
            CellKind::Na => "num",
        }
    }
}

const UNITS: &[&str] = &["str", "num", "count", "ns", "speedup"];

/// One table cell: the raw JSON value that lands in the artifact plus the
/// display string for the rendered ASCII table. Non-finite floats record
/// as null and display as "-" (JSON has no NaN).
pub struct Cell {
    pub v: Json,
    pub s: String,
    pub kind: CellKind,
}

fn finite(x: f64) -> Option<f64> {
    x.is_finite().then_some(x)
}

impl Cell {
    pub fn str(x: impl Into<String>) -> Cell {
        let s = x.into();
        Cell { v: Json::Str(s.clone()), s, kind: CellKind::Str }
    }

    /// Dimensionless value displayed with 2 decimals.
    pub fn f2(x: f64) -> Cell {
        match finite(x) {
            Some(x) => Cell { v: Json::Num(x), s: f2(x), kind: CellKind::Num },
            None => Cell::na(),
        }
    }

    /// Dimensionless value displayed with 3 decimals.
    pub fn f3(x: f64) -> Cell {
        match finite(x) {
            Some(x) => Cell { v: Json::Num(x), s: f3(x), kind: CellKind::Num },
            None => Cell::na(),
        }
    }

    /// Integer count.
    pub fn n(x: u64) -> Cell {
        Cell { v: Json::Num(x as f64), s: ix(x as usize), kind: CellKind::Count }
    }

    /// Duration in seconds; recorded as wall nanoseconds, displayed via
    /// [`fmt_duration`].
    pub fn d(secs: f64) -> Cell {
        match finite(secs) {
            Some(secs) if secs >= 0.0 => Cell {
                v: Json::Num((secs * 1e9).round()),
                s: fmt_duration(secs),
                kind: CellKind::DurNs,
            },
            _ => Cell::na(),
        }
    }

    /// Speedup factor, displayed as "1.23x".
    pub fn x(r: f64) -> Cell {
        match finite(r) {
            Some(r) => Cell { v: Json::Num(r), s: format!("{r:.2}x"), kind: CellKind::Speedup },
            None => Cell::na(),
        }
    }

    /// Not-applicable cell ("-" / null).
    pub fn na() -> Cell {
        Cell { v: Json::Null, s: "-".to_string(), kind: CellKind::Na }
    }
}

/// A typed column of a [`Section`].
#[derive(Clone, Debug, PartialEq)]
pub struct Column {
    pub key: String,
    pub label: String,
    pub unit: String,
}

/// One recorded table: id + title, free-form params (the knobs this table
/// was produced under), typed columns and raw-value rows.
#[derive(Clone, Debug, PartialEq)]
pub struct Section {
    pub id: String,
    pub title: String,
    pub params: BTreeMap<String, Json>,
    pub columns: Vec<Column>,
    pub rows: Vec<Vec<Json>>,
}

impl Section {
    /// Index of the column with this key.
    pub fn col(&self, key: &str) -> Option<usize> {
        self.columns.iter().position(|c| c.key == key)
    }

    /// First row whose `key_col` cell is the string `key_val`.
    pub fn find_row(&self, key_col: &str, key_val: &str) -> Option<&[Json]> {
        let k = self.col(key_col)?;
        self.rows
            .iter()
            .find(|r| r.get(k).and_then(Json::as_str) == Some(key_val))
            .map(Vec::as_slice)
    }

    /// Numeric cell lookup: row keyed by (`key_col` == `key_val`), value
    /// from `col`.
    pub fn cell_f64(&self, key_col: &str, key_val: &str, col: &str) -> Option<f64> {
        let c = self.col(col)?;
        self.find_row(key_col, key_val)?.get(c)?.as_f64()
    }
}

/// One recorded assertion outcome (bit-equality, pool invariance, ...).
#[derive(Clone, Debug, PartialEq)]
pub struct Assertion {
    pub name: String,
    pub passed: bool,
    pub detail: String,
}

/// Provenance of a run: where, when, from which commit, with which knobs.
#[derive(Clone, Debug, PartialEq)]
pub struct RunMeta {
    /// `git rev-parse HEAD` (env `GLISP_GIT_SHA` overrides; "unknown"
    /// when git is unavailable).
    pub git_sha: String,
    /// `git status --porcelain` non-empty; `None` when git is unavailable.
    pub git_dirty: Option<bool>,
    /// UTC calendar date of the run, `YYYY-MM-DD`.
    pub date_utc: String,
    /// Seconds since the Unix epoch.
    pub unix_time: u64,
    /// `std::thread::available_parallelism()` on the measuring host.
    pub host_cores: usize,
    /// Executor backend compiled in: "pjrt" or "reference".
    pub backend: String,
    /// `GLISP_BENCH_SCALE` in effect (1.0 = default).
    pub bench_scale: f64,
    /// Every `GLISP_*` env knob that was set for the run.
    pub env: BTreeMap<String, String>,
}

impl RunMeta {
    pub fn capture() -> RunMeta {
        let unix_time = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_secs())
            .unwrap_or(0);
        let (git_sha, git_dirty) = git_info();
        RunMeta {
            git_sha,
            git_dirty,
            date_utc: utc_date(unix_time),
            unix_time,
            host_cores: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
            backend: if cfg!(feature = "pjrt") { "pjrt" } else { "reference" }.to_string(),
            bench_scale: crate::harness::workloads::bench_scale(),
            env: bench_env(),
        }
    }
}

/// The `GLISP_*` env knobs that shape bench workloads, captured verbatim
/// into the artifact so a run can be reproduced.
pub fn bench_env() -> BTreeMap<String, String> {
    const KNOBS: &[&str] = &[
        "GLISP_BENCH_SCALE",
        "GLISP_BENCH_N",
        "GLISP_BENCH_STEPS",
        "GLISP_BENCH_BATCHES",
        "GLISP_PARTITION_THREADS",
        "GLISP_BENCH_DIR",
        "GLISP_ARTIFACTS",
    ];
    let mut out = BTreeMap::new();
    for k in KNOBS {
        if let Ok(v) = std::env::var(k) {
            out.insert(k.to_string(), v);
        }
    }
    out
}

fn git_info() -> (String, Option<bool>) {
    if let Ok(sha) = std::env::var("GLISP_GIT_SHA") {
        if !sha.is_empty() {
            return (sha, None);
        }
    }
    let root = repo_root();
    let run = |args: &[&str]| -> Option<String> {
        let out = std::process::Command::new("git")
            .args(args)
            .current_dir(&root)
            .output()
            .ok()?;
        out.status
            .success()
            .then(|| String::from_utf8_lossy(&out.stdout).trim().to_string())
    };
    match run(&["rev-parse", "HEAD"]) {
        Some(sha) if !sha.is_empty() => {
            let dirty = run(&["status", "--porcelain"]).map(|s| !s.is_empty());
            (sha, dirty)
        }
        _ => ("unknown".to_string(), None),
    }
}

/// Civil UTC date from a Unix timestamp (Howard Hinnant's algorithm; no
/// external time crate in the offline vendor set).
pub fn utc_date(unix: u64) -> String {
    let days = (unix / 86_400) as i64;
    let z = days + 719_468;
    let era = z.div_euclid(146_097);
    let doe = z.rem_euclid(146_097);
    let yoe = (doe - doe / 1460 + doe / 36_524 - doe / 146_096) / 365;
    let y = yoe + era * 400;
    let doy = doe - (365 * yoe + yoe / 4 - yoe / 100);
    let mp = (5 * doy + 2) / 153;
    let d = doy - (153 * mp + 2) / 5 + 1;
    let m = if mp < 10 { mp + 3 } else { mp - 9 };
    let y = if m <= 2 { y + 1 } else { y };
    format!("{y:04}-{m:02}-{d:02}")
}

/// The full artifact: what `BENCH_<bench>.json` serializes to and what
/// every consumer (report generator, diff, CI validation) parses back.
#[derive(Clone, Debug, PartialEq)]
pub struct BenchArtifact {
    pub schema_version: u32,
    pub bench: String,
    pub meta: RunMeta,
    /// Bench-level knobs (partition count, fanouts, steps, ...).
    pub config: BTreeMap<String, Json>,
    pub sections: Vec<Section>,
    pub assertions: Vec<Assertion>,
}

fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

impl BenchArtifact {
    pub fn to_json(&self) -> Json {
        let meta = obj(vec![
            ("backend", Json::Str(self.meta.backend.clone())),
            ("bench_scale", Json::Num(self.meta.bench_scale)),
            ("date_utc", Json::Str(self.meta.date_utc.clone())),
            (
                "env",
                Json::Obj(
                    self.meta
                        .env
                        .iter()
                        .map(|(k, v)| (k.clone(), Json::Str(v.clone())))
                        .collect(),
                ),
            ),
            (
                "git_dirty",
                self.meta.git_dirty.map(Json::Bool).unwrap_or(Json::Null),
            ),
            ("git_sha", Json::Str(self.meta.git_sha.clone())),
            ("host_cores", Json::Num(self.meta.host_cores as f64)),
            ("unix_time", Json::Num(self.meta.unix_time as f64)),
        ]);
        let sections = self
            .sections
            .iter()
            .map(|s| {
                obj(vec![
                    (
                        "columns",
                        Json::Arr(
                            s.columns
                                .iter()
                                .map(|c| {
                                    obj(vec![
                                        ("key", Json::Str(c.key.clone())),
                                        ("label", Json::Str(c.label.clone())),
                                        ("unit", Json::Str(c.unit.clone())),
                                    ])
                                })
                                .collect(),
                        ),
                    ),
                    ("id", Json::Str(s.id.clone())),
                    ("params", Json::Obj(s.params.clone())),
                    (
                        "rows",
                        Json::Arr(s.rows.iter().map(|r| Json::Arr(r.clone())).collect()),
                    ),
                    ("title", Json::Str(s.title.clone())),
                ])
            })
            .collect();
        let assertions = self
            .assertions
            .iter()
            .map(|a| {
                obj(vec![
                    ("detail", Json::Str(a.detail.clone())),
                    ("name", Json::Str(a.name.clone())),
                    ("passed", Json::Bool(a.passed)),
                ])
            })
            .collect();
        obj(vec![
            ("assertions", Json::Arr(assertions)),
            ("bench", Json::Str(self.bench.clone())),
            ("config", Json::Obj(self.config.clone())),
            ("meta", meta),
            ("schema_version", Json::Num(self.schema_version as f64)),
            ("sections", Json::Arr(sections)),
        ])
    }

    /// Strict deserialization: unknown fields, a version mismatch, ragged
    /// rows or an unknown column unit are errors — this is the schema-drift
    /// detector CI runs over every emitted artifact.
    pub fn from_json(j: &Json) -> Result<BenchArtifact, String> {
        let top = as_obj(j, "artifact")?;
        expect_keys(
            top,
            &["assertions", "bench", "config", "meta", "schema_version", "sections"],
            "artifact",
        )?;
        let schema_version = get_u64(top, "schema_version")? as u32;
        if schema_version != SCHEMA_VERSION {
            return Err(format!(
                "schema_version {schema_version} != supported {SCHEMA_VERSION}; \
                 regenerate the artifact (see DESIGN.md §11 bump policy)"
            ));
        }
        let meta_obj = as_obj(top.get("meta").ok_or("missing meta")?, "meta")?;
        expect_keys(
            meta_obj,
            &[
                "backend", "bench_scale", "date_utc", "env", "git_dirty", "git_sha",
                "host_cores", "unix_time",
            ],
            "meta",
        )?;
        let env_obj = as_obj(meta_obj.get("env").ok_or("missing meta.env")?, "meta.env")?;
        let mut env = BTreeMap::new();
        for (k, v) in env_obj {
            env.insert(
                k.clone(),
                v.as_str().ok_or_else(|| format!("meta.env.{k}: not a string"))?.to_string(),
            );
        }
        let meta = RunMeta {
            git_sha: get_str(meta_obj, "git_sha")?,
            git_dirty: match meta_obj.get("git_dirty") {
                Some(Json::Null) | None => None,
                Some(Json::Bool(b)) => Some(*b),
                _ => return Err("meta.git_dirty: not a bool or null".into()),
            },
            date_utc: get_str(meta_obj, "date_utc")?,
            unix_time: get_u64(meta_obj, "unix_time")?,
            host_cores: get_u64(meta_obj, "host_cores")? as usize,
            backend: get_str(meta_obj, "backend")?,
            bench_scale: meta_obj
                .get("bench_scale")
                .and_then(Json::as_f64)
                .ok_or("meta.bench_scale: not a number")?,
            env,
        };
        let config = as_obj(top.get("config").ok_or("missing config")?, "config")?.clone();
        let mut sections = Vec::new();
        for (i, sj) in top
            .get("sections")
            .and_then(Json::as_arr)
            .ok_or("sections: not an array")?
            .iter()
            .enumerate()
        {
            sections.push(section_from_json(sj, i)?);
        }
        let mut assertions = Vec::new();
        for (i, aj) in top
            .get("assertions")
            .and_then(Json::as_arr)
            .ok_or("assertions: not an array")?
            .iter()
            .enumerate()
        {
            let a = as_obj(aj, "assertion")?;
            expect_keys(a, &["detail", "name", "passed"], &format!("assertions[{i}]"))?;
            assertions.push(Assertion {
                name: get_str(a, "name")?,
                passed: a
                    .get("passed")
                    .and_then(Json::as_bool)
                    .ok_or_else(|| format!("assertions[{i}].passed: not a bool"))?,
                detail: get_str(a, "detail")?,
            });
        }
        Ok(BenchArtifact {
            schema_version,
            bench: get_str(top, "bench")?,
            meta,
            config,
            sections,
            assertions,
        })
    }

    pub fn section(&self, id: &str) -> Option<&Section> {
        self.sections.iter().find(|s| s.id == id)
    }

    /// Parse + validate one artifact file.
    pub fn load(path: &Path) -> anyhow::Result<BenchArtifact> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?;
        BenchArtifact::from_json(&j).map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))
    }
}

fn as_obj<'a>(j: &'a Json, what: &str) -> Result<&'a BTreeMap<String, Json>, String> {
    match j {
        Json::Obj(m) => Ok(m),
        _ => Err(format!("{what}: not an object")),
    }
}

fn expect_keys(m: &BTreeMap<String, Json>, keys: &[&str], what: &str) -> Result<(), String> {
    for k in m.keys() {
        if !keys.contains(&k.as_str()) {
            return Err(format!("{what}: unknown field \"{k}\" (schema drift?)"));
        }
    }
    for k in keys {
        if !m.contains_key(*k) {
            return Err(format!("{what}: missing field \"{k}\""));
        }
    }
    Ok(())
}

fn get_str(m: &BTreeMap<String, Json>, k: &str) -> Result<String, String> {
    m.get(k)
        .and_then(Json::as_str)
        .map(str::to_string)
        .ok_or_else(|| format!("{k}: not a string"))
}

fn get_u64(m: &BTreeMap<String, Json>, k: &str) -> Result<u64, String> {
    match m.get(k).and_then(Json::as_f64) {
        Some(x) if x >= 0.0 && x.fract() == 0.0 => Ok(x as u64),
        _ => Err(format!("{k}: not a non-negative integer")),
    }
}

fn section_from_json(sj: &Json, i: usize) -> Result<Section, String> {
    let s = as_obj(sj, "section")?;
    expect_keys(s, &["columns", "id", "params", "rows", "title"], &format!("sections[{i}]"))?;
    let mut columns = Vec::new();
    for cj in s.get("columns").and_then(Json::as_arr).ok_or("columns: not an array")? {
        let c = as_obj(cj, "column")?;
        expect_keys(c, &["key", "label", "unit"], &format!("sections[{i}].columns"))?;
        let unit = get_str(c, "unit")?;
        if !UNITS.contains(&unit.as_str()) {
            return Err(format!("sections[{i}]: unknown column unit \"{unit}\""));
        }
        columns.push(Column { key: get_str(c, "key")?, label: get_str(c, "label")?, unit });
    }
    let mut rows = Vec::new();
    for (r, rj) in s
        .get("rows")
        .and_then(Json::as_arr)
        .ok_or("rows: not an array")?
        .iter()
        .enumerate()
    {
        let row = rj
            .as_arr()
            .ok_or_else(|| format!("sections[{i}].rows[{r}]: not an array"))?;
        if row.len() != columns.len() {
            return Err(format!(
                "sections[{i}].rows[{r}]: {} cells for {} columns",
                row.len(),
                columns.len()
            ));
        }
        for (c, cell) in row.iter().enumerate() {
            if matches!(cell, Json::Arr(_) | Json::Obj(_)) {
                return Err(format!("sections[{i}].rows[{r}][{c}]: cell is not a scalar"));
            }
        }
        rows.push(row.to_vec());
    }
    Ok(Section {
        id: get_str(s, "id")?,
        title: get_str(s, "title")?,
        params: as_obj(s.get("params").ok_or("missing params")?, "params")?.clone(),
        columns,
        rows,
    })
}

/// Load + validate every `BENCH_*.json` in a directory, sorted by file
/// name (deterministic report order).
pub fn load_dir(dir: &Path) -> anyhow::Result<Vec<BenchArtifact>> {
    let mut paths: Vec<PathBuf> = match std::fs::read_dir(dir) {
        Ok(rd) => rd
            .filter_map(|e| e.ok())
            .map(|e| e.path())
            .filter(|p| {
                p.file_name()
                    .and_then(|n| n.to_str())
                    .is_some_and(|n| n.starts_with("BENCH_") && n.ends_with(".json"))
            })
            .collect(),
        Err(_) => Vec::new(),
    };
    paths.sort();
    paths.iter().map(|p| BenchArtifact::load(p)).collect()
}

/// A table being recorded: renders exactly like [`Table`] and additionally
/// captures typed values for the artifact. Hand it to
/// [`BenchRecorder::table`] when complete.
pub struct BenchTable {
    id: String,
    title: String,
    labels: Vec<String>,
    kinds: Vec<Option<CellKind>>,
    display_rows: Vec<Vec<String>>,
    value_rows: Vec<Vec<Json>>,
    params: BTreeMap<String, Json>,
}

impl BenchTable {
    pub fn new(id: &str, title: &str, columns: &[&str]) -> BenchTable {
        BenchTable {
            id: id.to_string(),
            title: title.to_string(),
            labels: columns.iter().map(|s| s.to_string()).collect(),
            kinds: vec![None; columns.len()],
            display_rows: Vec::new(),
            value_rows: Vec::new(),
            params: BTreeMap::new(),
        }
    }

    /// Record a table-scoped parameter (dataset, parts, ...).
    pub fn param(&mut self, key: &str, v: Json) -> &mut Self {
        self.params.insert(key.to_string(), v);
        self
    }

    pub fn param_usize(&mut self, key: &str, v: usize) -> &mut Self {
        self.param(key, Json::Num(v as f64))
    }

    pub fn param_str(&mut self, key: &str, v: &str) -> &mut Self {
        self.param(key, Json::Str(v.to_string()))
    }

    pub fn row(&mut self, cells: Vec<Cell>) -> &mut Self {
        assert_eq!(cells.len(), self.labels.len(), "table {}: ragged row", self.id);
        let mut disp = Vec::with_capacity(cells.len());
        let mut vals = Vec::with_capacity(cells.len());
        for (i, c) in cells.into_iter().enumerate() {
            if c.kind != CellKind::Na {
                match self.kinds[i] {
                    None => self.kinds[i] = Some(c.kind),
                    Some(k) => assert_eq!(
                        k, c.kind,
                        "table {}: column \"{}\" mixes {:?} and {:?} cells",
                        self.id, self.labels[i], k, c.kind
                    ),
                }
            }
            disp.push(c.s);
            vals.push(c.v);
        }
        self.display_rows.push(disp);
        self.value_rows.push(vals);
        self
    }

    /// Render the human table (same layout as [`Table`]).
    pub fn render(&self) -> String {
        let headers: Vec<&str> = self.labels.iter().map(String::as_str).collect();
        let mut t = Table::new(&self.title, &headers);
        for r in &self.display_rows {
            t.row(r);
        }
        t.render()
    }

    fn section(&self) -> Section {
        Section {
            id: self.id.clone(),
            title: self.title.clone(),
            params: self.params.clone(),
            columns: self
                .labels
                .iter()
                .zip(&self.kinds)
                .map(|(l, k)| Column {
                    key: slug(l),
                    label: l.clone(),
                    unit: k.unwrap_or(CellKind::Num).unit().to_string(),
                })
                .collect(),
            rows: self.value_rows.clone(),
        }
    }
}

/// Records one bench run and writes its `BENCH_<bench>.json` on
/// [`finish`](BenchRecorder::finish).
pub struct BenchRecorder {
    art: BenchArtifact,
    dir: PathBuf,
}

impl BenchRecorder {
    pub fn new(bench: &str) -> BenchRecorder {
        BenchRecorder {
            art: BenchArtifact {
                schema_version: SCHEMA_VERSION,
                bench: bench.to_string(),
                meta: RunMeta::capture(),
                config: BTreeMap::new(),
                sections: Vec::new(),
                assertions: Vec::new(),
            },
            dir: artifact_dir(),
        }
    }

    /// Record a bench-level knob.
    pub fn config(&mut self, key: &str, v: Json) -> &mut Self {
        self.art.config.insert(key.to_string(), v);
        self
    }

    pub fn config_usize(&mut self, key: &str, v: usize) -> &mut Self {
        self.config(key, Json::Num(v as f64))
    }

    pub fn config_f64(&mut self, key: &str, v: f64) -> &mut Self {
        self.config(key, if v.is_finite() { Json::Num(v) } else { Json::Null })
    }

    pub fn config_str(&mut self, key: &str, v: &str) -> &mut Self {
        self.config(key, Json::Str(v.to_string()))
    }

    /// Print a finished table and record it as a section.
    pub fn table(&mut self, t: &BenchTable) {
        print!("{}", t.render());
        self.art.sections.push(t.section());
    }

    /// Record an assertion outcome, then enforce it: on failure the
    /// artifact is flushed first (with `passed: false`), so a red run
    /// still leaves machine-readable evidence of which contract broke.
    pub fn check(&mut self, name: &str, passed: bool, detail: &str) {
        self.art.assertions.push(Assertion {
            name: name.to_string(),
            passed,
            detail: detail.to_string(),
        });
        if !passed {
            let _ = self.write();
            panic!("bench assertion failed: {name}: {detail}");
        }
    }

    fn write(&self) -> anyhow::Result<PathBuf> {
        let path = self.dir.join(format!("BENCH_{}.json", self.art.bench));
        let mut text = emit_pretty(&self.art.to_json());
        text.push('\n');
        std::fs::create_dir_all(&self.dir)?;
        std::fs::write(&path, text).map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?;
        Ok(path)
    }

    /// Write `BENCH_<bench>.json` and report where it landed.
    pub fn finish(self) -> anyhow::Result<PathBuf> {
        let path = self.write()?;
        println!("\nbench artifact: {}", path.display());
        Ok(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_artifact() -> BenchArtifact {
        let mut t = BenchTable::new("demo", "Demo section", &["task", "wall (s)", "speedup", "n"]);
        t.param_str("dataset", "wiki-s").param_usize("parts", 4);
        t.row(vec![Cell::str("a"), Cell::d(1.5), Cell::x(2.0), Cell::n(7)]);
        t.row(vec![Cell::str("b"), Cell::na(), Cell::x(0.5), Cell::n(0)]);
        let mut rec = BenchRecorder::new("unit_test");
        rec.config_usize("steps", 10);
        rec.art.sections.push(t.section());
        rec.art.assertions.push(Assertion {
            name: "bit_identical".into(),
            passed: true,
            detail: "demo".into(),
        });
        rec.art
    }

    #[test]
    fn bench_artifact_schema_round_trip() {
        let a = sample_artifact();
        let text = emit_pretty(&a.to_json());
        let parsed = Json::parse(&text).unwrap();
        let b = BenchArtifact::from_json(&parsed).unwrap();
        assert_eq!(a, b);
        // Typed column units survive the trip.
        let s = b.section("demo").unwrap();
        let units: Vec<&str> = s.columns.iter().map(|c| c.unit.as_str()).collect();
        assert_eq!(units, ["str", "ns", "speedup", "count"]);
        assert_eq!(s.cell_f64("task", "a", "wall_s"), Some(1.5e9));
        assert_eq!(s.cell_f64("task", "b", "wall_s"), None); // na cell
        assert_eq!(s.params.get("parts"), Some(&Json::Num(4.0)));
    }

    #[test]
    fn bench_artifact_rejects_drift() {
        let a = sample_artifact();
        // Version bump required.
        let mut j = a.to_json();
        if let Json::Obj(m) = &mut j {
            m.insert("schema_version".into(), Json::Num((SCHEMA_VERSION + 1) as f64));
        }
        assert!(BenchArtifact::from_json(&j).unwrap_err().contains("schema_version"));
        // Unknown field rejected.
        let mut j = a.to_json();
        if let Json::Obj(m) = &mut j {
            m.insert("surprise".into(), Json::Null);
        }
        assert!(BenchArtifact::from_json(&j).unwrap_err().contains("unknown field"));
        // Ragged row rejected.
        let mut bad = a.clone();
        bad.sections[0].rows[0].pop();
        assert!(BenchArtifact::from_json(&bad.to_json()).is_err());
    }

    #[test]
    fn bench_artifact_slugs_and_dates() {
        assert_eq!(slug("uni wall 4w"), "uni_wall_4w");
        assert_eq!(slug("1t(s)"), "1t_s");
        assert_eq!(slug("par vs 1-thr"), "par_vs_1_thr");
        assert_eq!(slug("  RF  "), "rf");
        assert_eq!(utc_date(0), "1970-01-01");
        assert_eq!(utc_date(86_400), "1970-01-02");
        assert_eq!(utc_date(951_782_400), "2000-02-29"); // leap day
        assert_eq!(utc_date(1_786_147_200), "2026-08-08");
    }

    #[test]
    fn bench_artifact_resolves_bench_names() {
        assert_eq!(resolve_bench("fig13"), Some("fig13_inference"));
        assert_eq!(resolve_bench("fig13_inference"), Some("fig13_inference"));
        assert_eq!(resolve_bench("nope"), None);
        assert_eq!(resolve_bench("hotpath"), Some("bench_hotpath"));
        assert_eq!(resolve_bench("serving"), Some("bench_serving"));
        assert_eq!(BENCHES.len(), 15);
    }

    /// CI's schema-validation step: every artifact emitted by the sweep
    /// (GLISP_BENCH_DIR) and every artifact committed at the repo root
    /// must deserialize through the schema types. Vacuously green when no
    /// artifacts exist yet.
    #[test]
    fn bench_artifact_validate_emitted() {
        let mut dirs = vec![repo_root()];
        if let Ok(d) = std::env::var("GLISP_BENCH_DIR") {
            if !d.is_empty() {
                dirs.push(PathBuf::from(d));
            }
        }
        for dir in dirs {
            let arts = load_dir(&dir).unwrap_or_else(|e| panic!("{}: {e}", dir.display()));
            for a in arts {
                assert_eq!(a.schema_version, SCHEMA_VERSION);
                assert!(!a.bench.is_empty());
                // Round-trip: emit -> parse -> same value.
                let text = emit_pretty(&a.to_json());
                let again = BenchArtifact::from_json(&Json::parse(&text).unwrap()).unwrap();
                assert_eq!(a, again);
            }
        }
    }
}
