//! ASCII/markdown table rendering for the bench harness — every bench
//! prints the same rows the paper's table/figure reports (criterion is not
//! in the offline vendor set; see util::timer::measure for the timing
//! core).

/// A simple right-aligned table with a header row.
pub struct Table {
    title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells.to_vec());
        self
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("\n### {}\n\n", self.title));
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut s = String::from("|");
            for (c, w) in cells.iter().zip(widths) {
                s.push_str(&format!(" {c:>w$} |", w = w));
            }
            s.push('\n');
            s
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        let sep: Vec<String> = widths.iter().map(|&w| "-".repeat(w)).collect();
        out.push_str(&fmt_row(&sep, &widths));
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format helpers used across benches.
pub fn f2(x: f64) -> String {
    format!("{x:.2}")
}

pub fn f3(x: f64) -> String {
    format!("{x:.3}")
}

pub fn ix(x: usize) -> String {
    format!("{x}")
}

pub fn speedup(x: f64) -> String {
    format!("{x:.2}x")
}

/// Simple ASCII bar series for figure-shaped outputs.
pub fn bar_chart(title: &str, labels: &[String], values: &[f64]) -> String {
    let max = values.iter().cloned().fold(f64::MIN, f64::max).max(1e-12);
    let lw = labels.iter().map(|l| l.len()).max().unwrap_or(0);
    let mut out = format!("\n### {title}\n\n");
    for (l, &v) in labels.iter().zip(values) {
        let n = ((v / max) * 50.0).round() as usize;
        out.push_str(&format!("{l:>lw$} | {}{} {v:.3}\n", "#".repeat(n), "", lw = lw));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("Demo", &["name", "value"]);
        t.row(&["a".into(), "1.00".into()]);
        t.row(&["longer".into(), "2".into()]);
        let r = t.render();
        assert!(r.contains("### Demo"));
        assert!(r.contains("| longer |"));
        // All data lines have the same width.
        let lens: Vec<usize> = r.lines().filter(|l| l.starts_with('|')).map(|l| l.len()).collect();
        assert!(lens.windows(2).all(|w| w[0] == w[1]));
    }

    #[test]
    fn bars_scale() {
        let c = bar_chart("B", &["x".into(), "y".into()], &[1.0, 2.0]);
        let lines: Vec<&str> = c.lines().filter(|l| l.contains('|')).collect();
        let count = |s: &str| s.matches('#').count();
        assert_eq!(count(lines[1]), 2 * count(lines[0]));
    }
}
