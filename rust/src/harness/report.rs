//! Human-facing bench output: ASCII/markdown table rendering (criterion
//! is not in the offline vendor set; see `util::timer::measure` for the
//! timing core) plus the EXPERIMENTS.md writer, which regenerates the
//! measured section of that file from the `BENCH_*.json` artifacts the
//! [`bench`](crate::harness::bench) recorder emits.
//!
//! The regeneration contract: everything between [`GEN_BEGIN`] and
//! [`GEN_END`] in EXPERIMENTS.md is machine-written — `glisp bench
//! --report` replaces it from the artifacts committed at the repo root,
//! deterministically, so the committed file is always byte-for-byte
//! reproducible from the committed artifacts (pinned by the
//! `bench_artifact_experiments_md_in_sync` test and checked in CI). Hand
//! edits inside the markers are overwritten by design. Durations are
//! rendered through the one shared [`fmt_duration`] helper, the same one
//! the recorders use, so units cannot drift between the JSON and the
//! prose.

use std::path::{Path, PathBuf};

use anyhow::Context;

use crate::harness::bench::{self, BenchArtifact, Section, BENCHES};
use crate::util::json::{emit, Json};
use crate::util::timer::fmt_duration;

/// A simple right-aligned table with a header row.
pub struct Table {
    title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells.to_vec());
        self
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("\n### {}\n\n", self.title));
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut s = String::from("|");
            for (c, w) in cells.iter().zip(widths) {
                s.push_str(&format!(" {c:>w$} |", w = w));
            }
            s.push('\n');
            s
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        let sep: Vec<String> = widths.iter().map(|&w| "-".repeat(w)).collect();
        out.push_str(&fmt_row(&sep, &widths));
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format helpers used across benches.
pub fn f2(x: f64) -> String {
    format!("{x:.2}")
}

pub fn f3(x: f64) -> String {
    format!("{x:.3}")
}

pub fn ix(x: usize) -> String {
    format!("{x}")
}

pub fn speedup(x: f64) -> String {
    format!("{x:.2}x")
}

/// Simple ASCII bar series for figure-shaped outputs.
pub fn bar_chart(title: &str, labels: &[String], values: &[f64]) -> String {
    let max = values.iter().cloned().fold(f64::MIN, f64::max).max(1e-12);
    let lw = labels.iter().map(|l| l.len()).max().unwrap_or(0);
    let mut out = format!("\n### {title}\n\n");
    for (l, &v) in labels.iter().zip(values) {
        let n = ((v / max) * 50.0).round() as usize;
        out.push_str(&format!("{l:>lw$} | {}{} {v:.3}\n", "#".repeat(n), "", lw = lw));
    }
    out
}

/// Start marker of the machine-written span of EXPERIMENTS.md.
pub const GEN_BEGIN: &str =
    "<!-- BEGIN GENERATED BENCH RESULTS (regenerate with `glisp bench --report`; do not hand-edit) -->";
/// End marker of the machine-written span of EXPERIMENTS.md.
pub const GEN_END: &str = "<!-- END GENERATED BENCH RESULTS -->";

/// A PR 2–5 speedup claim: where in which artifact its measured value
/// lives, and the bar it was shipped against. `den_col` turns the lookup
/// into a ratio of two cells of the same row.
struct Claim {
    label: &'static str,
    origin: &'static str,
    bench: &'static str,
    section: &'static str,
    row_col: &'static str,
    row_val: &'static str,
    num_col: &'static str,
    den_col: Option<&'static str>,
    expected: &'static str,
    threshold: f64,
}

const CLAIMS: &[Claim] = &[
    Claim {
        label: "Pipelined producer overlaps sampling with the train step",
        origin: "PR 2",
        bench: "pipeline_throughput",
        section: "modes",
        row_col: "mode",
        row_val: "pipelined x2 ordered",
        num_col: "vs_sync",
        den_col: None,
        expected: ">=1.00x, losses bit-equal to sync",
        threshold: 1.0,
    },
    Claim {
        label: "Worker-parallel K-slice inference sweeps",
        origin: "PR 3",
        bench: "fig13_inference",
        section: "inference",
        row_col: "task",
        row_val: "vertex embedding",
        num_col: "par_vs_1_thr",
        den_col: None,
        expected: ">=1.50x, approaching the partition count on a >=4-core host",
        threshold: 1.5,
    },
    Claim {
        label: "Worker-pooled sampling accelerates hotspot gathers",
        origin: "PR 4",
        bench: "fig09_sampling_speed",
        section: "twitter-s",
        row_col: "framework",
        row_val: "GLISP (AdaDNE+GA)",
        num_col: "uni_wall_4w",
        den_col: Some("uni_wall_1w"),
        expected: ">=1.50x on a >=4-core host",
        threshold: 1.5,
    },
    Claim {
        label: "4-worker pool lifts pipelined training throughput",
        origin: "PR 4",
        bench: "pipeline_throughput",
        section: "modes",
        row_col: "mode",
        row_val: "pipelined x2 ordered, 4w pool",
        num_col: "vs_sync",
        den_col: None,
        expected: ">=1.50x on a >=4-core host",
        threshold: 1.5,
    },
    Claim {
        label: "Parallel offline stage (AdaDNE propose + build)",
        origin: "PR 5",
        bench: "fig12_scalability",
        section: "offline_stage",
        row_col: "stage",
        row_val: "partition+build",
        num_col: "speedup",
        den_col: None,
        expected: ">=1.50x at 4 threads on a >=4-core host",
        threshold: 1.5,
    },
    Claim {
        label: "Warmed serving outpaces cold under power-law load",
        origin: "PR 10",
        bench: "bench_serving",
        section: "warm_vs_cold",
        row_col: "metric",
        row_val: "closed-loop",
        num_col: "warm_vs_cold_qps",
        den_col: None,
        expected: ">=1.00x QPS, served bytes bit-equal to the offline sweep",
        threshold: 1.0,
    },
];

fn claim_measured(c: &Claim, artifacts: &[BenchArtifact]) -> Option<f64> {
    let a = artifacts.iter().find(|a| a.bench == c.bench)?;
    let s = a.section(c.section)?;
    let num = s.cell_f64(c.row_col, c.row_val, c.num_col)?;
    match c.den_col {
        None => Some(num),
        Some(d) => {
            let den = s.cell_f64(c.row_col, c.row_val, d)?;
            (den > 0.0).then(|| num / den)
        }
    }
}

/// Render one artifact cell for markdown, honoring the column unit: `ns`
/// cells go through [`fmt_duration`], `speedup` cells render as "1.23x",
/// numbers use the compact JSON float form, nulls render as an em dash.
fn fmt_cell(v: &Json, unit: &str) -> String {
    match v {
        Json::Null => "—".to_string(),
        Json::Str(s) => s.clone(),
        Json::Bool(b) => b.to_string(),
        Json::Num(x) => match unit {
            "ns" => fmt_duration(*x / 1e9),
            "speedup" => format!("{x:.2}x"),
            _ => emit(v),
        },
        other => emit(other),
    }
}

fn md_row(cells: &[String], out: &mut String) {
    out.push('|');
    for c in cells {
        out.push(' ');
        out.push_str(c);
        out.push_str(" |");
    }
    out.push('\n');
}

fn render_section_md(s: &Section, out: &mut String) {
    out.push_str(&format!("#### {} (`{}`)\n\n", s.title, s.id));
    if !s.params.is_empty() {
        let params: Vec<String> =
            s.params.iter().map(|(k, v)| format!("{k}={}", emit(v))).collect();
        out.push_str(&format!("_params: {}_\n\n", params.join(", ")));
    }
    let labels: Vec<String> = s.columns.iter().map(|c| c.label.clone()).collect();
    md_row(&labels, out);
    md_row(&vec!["---".to_string(); s.columns.len()], out);
    for row in &s.rows {
        let cells: Vec<String> = row
            .iter()
            .zip(&s.columns)
            .map(|(v, c)| fmt_cell(v, &c.unit))
            .collect();
        md_row(&cells, out);
    }
    out.push('\n');
}

/// Render the full machine-written body of EXPERIMENTS.md from the loaded
/// artifacts. Pure and deterministic: the same artifacts always produce
/// the same bytes.
pub fn render_measured(artifacts: &[BenchArtifact]) -> String {
    let mut out = String::new();
    out.push_str("## Measured (generated)\n\n");
    if artifacts.is_empty() {
        out.push_str(
            "No `BENCH_*.json` artifacts are committed at the repo root yet: every\n\
             measured cell below is pending until the first artifact sweep lands.\n\
             Run `glisp bench --all --report`, or download the artifacts from CI's\n\
             `bench-artifacts` job and re-run `glisp bench --report`.\n\n",
        );
    } else {
        out.push_str(&format!(
            "Generated from {} committed `BENCH_*.json` artifact(s). Regenerate with\n\
             `glisp bench --report` after a sweep; never edit inside the markers.\n\n",
            artifacts.len()
        ));
    }

    out.push_str("### Speedup claims — expected vs measured\n\n");
    md_row(
        &["claim", "source", "measures", "expected", "measured", "status"]
            .map(str::to_string),
        &mut out,
    );
    md_row(&vec!["---".to_string(); 6], &mut out);
    for c in CLAIMS {
        let measures = match c.den_col {
            None => format!(
                "`{}` `{}[{}={}].{}`",
                c.bench, c.section, c.row_col, c.row_val, c.num_col
            ),
            Some(d) => format!(
                "`{}` `{}[{}={}].{} / .{}`",
                c.bench, c.section, c.row_col, c.row_val, c.num_col, d
            ),
        };
        let (measured, status) = match claim_measured(c, artifacts) {
            None => ("—".to_string(), "pending".to_string()),
            Some(v) => (
                format!("{v:.2}x"),
                if v >= c.threshold { "met".to_string() } else { "below".to_string() },
            ),
        };
        md_row(
            &[
                c.label.to_string(),
                c.origin.to_string(),
                measures,
                c.expected.to_string(),
                measured,
                status,
            ],
            &mut out,
        );
    }
    out.push('\n');

    out.push_str("### Artifact inventory\n\n");
    md_row(
        &["bench", "paper target", "git sha", "date (UTC)", "backend", "cores", "checks"]
            .map(str::to_string),
        &mut out,
    );
    md_row(&vec!["---".to_string(); 7], &mut out);
    for (_, target, paper) in BENCHES {
        let row = match artifacts.iter().find(|a| a.bench == *target) {
            None => [
                format!("`{target}`"),
                paper.to_string(),
                "—".to_string(),
                "—".to_string(),
                "—".to_string(),
                "—".to_string(),
                "pending".to_string(),
            ],
            Some(a) => {
                let sha: String = a.meta.git_sha.chars().take(9).collect();
                let passed = a.assertions.iter().filter(|x| x.passed).count();
                let checks = if a.assertions.is_empty() {
                    "no checks".to_string()
                } else {
                    format!("{passed}/{} passed", a.assertions.len())
                };
                [
                    format!("`{target}`"),
                    paper.to_string(),
                    format!("`{sha}`"),
                    a.meta.date_utc.clone(),
                    a.meta.backend.clone(),
                    format!("{}", a.meta.host_cores),
                    checks,
                ]
            }
        };
        md_row(&row, &mut out);
    }
    out.push('\n');

    for a in artifacts {
        let paper = BENCHES
            .iter()
            .find(|(_, t, _)| *t == a.bench)
            .map(|(_, _, p)| *p)
            .unwrap_or("(unregistered bench)");
        out.push_str(&format!("### {} — {}\n\n", a.bench, paper));
        let dirty = match a.meta.git_dirty {
            Some(true) => ", dirty tree",
            Some(false) => ", clean tree",
            None => "",
        };
        out.push_str(&format!(
            "_git `{}`{dirty} · {} · {} backend · {} cores · scale {}_\n\n",
            a.meta.git_sha,
            a.meta.date_utc,
            a.meta.backend,
            a.meta.host_cores,
            emit(&Json::Num(a.meta.bench_scale)),
        ));
        if !a.config.is_empty() {
            let cfg: Vec<String> =
                a.config.iter().map(|(k, v)| format!("{k}={}", emit(v))).collect();
            out.push_str(&format!("_config: {}_\n\n", cfg.join(", ")));
        }
        for s in &a.sections {
            render_section_md(s, &mut out);
        }
        if !a.assertions.is_empty() {
            out.push_str("Recorded checks:\n\n");
            for x in &a.assertions {
                out.push_str(&format!(
                    "- [{}] {} — {}\n",
                    if x.passed { "x" } else { " " },
                    x.name,
                    x.detail
                ));
            }
            out.push('\n');
        }
    }
    out
}

/// Replace the machine-written span of `existing` (between [`GEN_BEGIN`]
/// and [`GEN_END`]) with `body`.
pub fn splice_generated(existing: &str, body: &str) -> anyhow::Result<String> {
    let start = existing
        .find(GEN_BEGIN)
        .context("EXPERIMENTS.md: BEGIN GENERATED marker not found")?;
    let end = existing
        .find(GEN_END)
        .context("EXPERIMENTS.md: END GENERATED marker not found")?;
    anyhow::ensure!(end > start, "EXPERIMENTS.md: END marker precedes BEGIN marker");
    let mut out = String::new();
    out.push_str(&existing[..start]);
    out.push_str(GEN_BEGIN);
    out.push_str("\n\n");
    out.push_str(body.trim_end());
    out.push_str("\n\n");
    out.push_str(GEN_END);
    out.push_str(&existing[end + GEN_END.len()..]);
    Ok(out)
}

/// Regenerate EXPERIMENTS.md from the artifacts in `artifact_dir`.
/// Returns the file path, the regenerated text and whether it differs
/// from what is on disk; writes only when `write` is set.
pub fn regenerate_experiments(
    artifact_dir: &Path,
    write: bool,
) -> anyhow::Result<(PathBuf, String, bool)> {
    let md_path = bench::repo_root().join("EXPERIMENTS.md");
    let existing = std::fs::read_to_string(&md_path)
        .with_context(|| format!("read {}", md_path.display()))?;
    let artifacts = bench::load_dir(artifact_dir)?;
    let body = render_measured(&artifacts);
    let new = splice_generated(&existing, &body)?;
    let changed = new != existing;
    if write && changed {
        std::fs::write(&md_path, &new).with_context(|| format!("write {}", md_path.display()))?;
    }
    Ok((md_path, new, changed))
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The acceptance gate for the regeneration path: splicing the render
    /// of the committed artifacts into the committed EXPERIMENTS.md must
    /// reproduce the committed file byte-for-byte. Reads the repo root
    /// directly (not `artifact_dir()`) so a `GLISP_BENCH_DIR` pointing at
    /// a fresh CI sweep cannot leak into the check.
    #[test]
    fn bench_artifact_experiments_md_in_sync() {
        let root = bench::repo_root();
        let md = std::fs::read_to_string(root.join("EXPERIMENTS.md")).unwrap();
        let artifacts = bench::load_dir(&root).unwrap();
        let body = render_measured(&artifacts);
        let spliced = splice_generated(&md, &body).unwrap();
        assert_eq!(
            spliced, md,
            "EXPERIMENTS.md is out of sync with the committed BENCH_*.json artifacts; \
             run `glisp bench --report`"
        );
    }

    #[test]
    fn bench_artifact_markers_spliced() {
        let doc = format!("intro\n\n{GEN_BEGIN}\nstale\n{GEN_END}\n\ntail\n");
        let out = splice_generated(&doc, "fresh body\n").unwrap();
        assert!(out.starts_with("intro\n\n"));
        assert!(out.ends_with("\n\ntail\n"));
        assert!(out.contains(&format!("{GEN_BEGIN}\n\nfresh body\n\n{GEN_END}")));
        assert!(!out.contains("stale"));
        // Idempotent: splicing the same body again changes nothing.
        assert_eq!(splice_generated(&out, "fresh body\n").unwrap(), out);
        // Missing markers are an error, not a silent append.
        assert!(splice_generated("no markers here", "x").is_err());
    }

    #[test]
    fn bench_artifact_empty_render_is_pending() {
        let body = render_measured(&[]);
        assert!(body.contains("## Measured (generated)"));
        assert!(body.contains("pending"));
        // Every registered bench appears in the inventory.
        for (_, target, _) in BENCHES {
            assert!(body.contains(&format!("`{target}`")), "missing {target}");
        }
        // Every registered claim renders with a pending measured column.
        assert_eq!(body.matches("| pending |").count(), CLAIMS.len() + BENCHES.len());
    }

    #[test]
    fn bench_artifact_cells_format_by_unit() {
        assert_eq!(fmt_cell(&Json::Num(1.5e9), "ns"), "1.50s");
        assert_eq!(fmt_cell(&Json::Num(2.0), "speedup"), "2.00x");
        assert_eq!(fmt_cell(&Json::Num(42.0), "count"), "42");
        assert_eq!(fmt_cell(&Json::Null, "num"), "—");
        assert_eq!(fmt_cell(&Json::Str("gcn".into()), "str"), "gcn");
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("Demo", &["name", "value"]);
        t.row(&["a".into(), "1.00".into()]);
        t.row(&["longer".into(), "2".into()]);
        let r = t.render();
        assert!(r.contains("### Demo"));
        assert!(r.contains("| longer |"));
        // All data lines have the same width.
        let lens: Vec<usize> = r.lines().filter(|l| l.starts_with('|')).map(|l| l.len()).collect();
        assert!(lens.windows(2).all(|w| w[0] == w[1]));
    }

    #[test]
    fn bars_scale() {
        let c = bar_chart("B", &["x".into(), "y".into()], &[1.0, 2.0]);
        let lines: Vec<&str> = c.lines().filter(|l| l.contains('|')).collect();
        let count = |s: &str| s.matches('#').count();
        assert_eq!(count(lines[1]), 2 * count(lines[0]));
    }
}
