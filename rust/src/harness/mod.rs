//! Bench harness: artifact recording, table/figure rendering, and the shared
//! synthetic workload suite (criterion substitute; see DESIGN.md §4).
//!
//! Three layers, each consumed by the 15 bench binaries in `rust/benches/`:
//!
//! - [`workloads`] builds the deterministic synthetic graph/training stacks
//!   every bench runs against. The determinism contract (DESIGN.md §7–§10)
//!   is inherited from there: fixed seeds, round-synchronous parallel
//!   stages, ordered pipelined training — so re-running a bench on the same
//!   host reproduces every non-timing column bit-for-bit.
//! - [`bench`] is the recording layer: a [`BenchRecorder`] collects the
//!   rows a bench would previously `println!`, plus run metadata (git SHA,
//!   date, thread/worker config, host cores) and the bit-equality /
//!   pool-invariance assertion outcomes, and writes a schema-versioned
//!   `BENCH_<bench>.json` artifact (DESIGN.md §11).
//! - [`report`] renders tables/figures for terminal output and regenerates
//!   the measured sections of EXPERIMENTS.md from committed artifacts
//!   (`glisp bench --report`).

pub mod bench;
pub mod report;
pub mod workloads;

pub use bench::{BenchRecorder, BenchTable, Cell};
pub use report::{bar_chart, f2, f3, ix, speedup, Table};
pub use workloads::{
    infer_stack, partition_threads, percentile_us, power_law_trace, run_closed_loop,
    run_open_loop, serving_fleet, serving_stack, stack_partitioner, train_stack, train_stack_cfg,
    train_stack_connect, train_stack_graph, InferStack, ServeLoadReport, ServingStack, TrainStack,
};
