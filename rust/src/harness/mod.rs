//! Bench harness: table/figure rendering and the shared synthetic workload
//! suite (criterion substitute; see DESIGN.md §4).

pub mod report;
pub mod workloads;

pub use report::{bar_chart, f2, f3, ix, speedup, Table};
pub use workloads::{
    infer_stack, partition_threads, stack_partitioner, train_stack, train_stack_cfg, InferStack,
    TrainStack,
};
