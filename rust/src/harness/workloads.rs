//! Shared bench workloads: the scaled-down dataset suite and common
//! experiment wiring, so every bench regenerates its table/figure from the
//! same graphs. Sizes are tuned so the full `cargo bench` suite finishes
//! in minutes on a laptop-class CPU; set GLISP_BENCH_SCALE to scale the
//! vertex/edge counts (1.0 = default).

use crate::graph::csr::Graph;
use crate::graph::generator::{self, DatasetSpec, GenKind};

pub fn bench_scale() -> f64 {
    std::env::var("GLISP_BENCH_SCALE")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1.0)
}

/// The Table I-analogue suite used by the partitioning/sampling benches.
pub fn bench_datasets() -> Vec<DatasetSpec> {
    let s = bench_scale();
    let scale = |x: usize| ((x as f64 * s) as usize).max(1000);
    vec![
        DatasetSpec { name: "products-s", n: scale(12_000), m: scale(300_000), alpha: 0.0, kind: GenKind::ErdosRenyi },
        DatasetSpec { name: "wiki-s", n: scale(45_000), m: scale(300_000), alpha: 2.1, kind: GenKind::ChungLu },
        DatasetSpec { name: "twitter-s", n: scale(21_000), m: scale(740_000), alpha: 1.9, kind: GenKind::ChungLu },
        DatasetSpec { name: "paper-s", n: scale(55_000), m: scale(800_000), alpha: 2.2, kind: GenKind::RMat },
    ]
}

/// The large sparse "RelNet"-regime graph for scale-flavoured benches.
pub fn relnet_like() -> DatasetSpec {
    let s = bench_scale();
    let scale = |x: usize| ((x as f64 * s) as usize).max(1000);
    DatasetSpec {
        name: "relnet-s",
        n: scale(400_000),
        m: scale(1_900_000),
        alpha: 2.3,
        kind: GenKind::ChungLu,
    }
}

pub fn load(spec: &DatasetSpec, seed: u64) -> Graph {
    generator::generate(spec, seed)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn suite_has_expected_regimes() {
        let ds = bench_datasets();
        assert_eq!(ds.len(), 4);
        assert_eq!(ds[0].kind, GenKind::ErdosRenyi); // the non-power-law control
        assert!(ds[1..].iter().all(|d| d.kind != GenKind::ErdosRenyi));
    }
}
