//! Shared bench workloads: the scaled-down dataset suite and common
//! experiment wiring, so every bench regenerates its table/figure from the
//! same graphs. Sizes are tuned so the full `cargo bench` suite finishes
//! in minutes on a laptop-class CPU; set GLISP_BENCH_SCALE to scale the
//! vertex/edge counts (1.0 = default).
//!
//! # Determinism contract
//!
//! Every stack built here is reproducible bit-for-bit given the same
//! `GLISP_BENCH_*` knobs — bench authors inherit this instead of
//! re-deriving it per bench:
//!
//! - Graphs come from [`generator`] under fixed seeds, so vertex/edge sets
//!   are identical across runs and hosts.
//! - Partitions come from [`stack_partitioner`], whose round-synchronous
//!   AdaDNE propose phase is bit-identical for any
//!   `GLISP_PARTITION_THREADS` value (DESIGN.md §10).
//! - Training through [`TrainStack`] is ordered-pipelined: losses are
//!   bit-equal to the synchronous loop for any pipeline depth or sampling
//!   worker-pool geometry (DESIGN.md §7, §9).
//! - Layerwise inference through [`InferStack`] produces embeddings
//!   bit-identical for any worker count (DESIGN.md §8).
//!
//! Consequently only *timing* columns of a bench may vary between runs;
//! every count/ratio/loss column is stable, which is what lets the
//! `BENCH_*.json` assertion outcomes ([`crate::harness::bench`]) make the
//! equality claims machine-checkable.

use std::sync::{Arc, Mutex};

use crate::coordinator::{Batcher, FeatureStore, Trainer, TrainerConfig};
use crate::graph::csr::{Graph, VId};
use crate::graph::generator::{self, DatasetSpec, GenKind};
use crate::graph::StoreBackend;
use crate::inference::{init_encoder_params, EngineConfig, LayerwiseEngine};
use crate::partition::{AdaDNE, EdgeAssignment, Partitioner};
use crate::runtime::Runtime;
use crate::sampling::{serve_partition, RemoteServer, SamplingService, ServiceConfig};
use crate::serving::{ServingConfig, ServingEngine};
use crate::util::digest::f32_digest;
use crate::util::rng::Rng;
use crate::util::timer::Timer;

/// Global size multiplier for the synthetic suite (GLISP_BENCH_SCALE,
/// default 1.0). Scaling changes the graphs, so artifacts are only
/// comparable at equal scale — the recorder stamps it into run metadata.
pub fn bench_scale() -> f64 {
    std::env::var("GLISP_BENCH_SCALE")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1.0)
}

/// Propose-phase threads for the offline AdaDNE stage of the shared
/// stacks (GLISP_PARTITION_THREADS, default 1). Pure throughput knob: the
/// assignment is bit-identical for any value (DESIGN.md §10), so benches
/// stay comparable whatever the setting.
pub fn partition_threads() -> usize {
    std::env::var("GLISP_PARTITION_THREADS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1)
        .max(1)
}

/// The AdaDNE instance every shared stack partitions with —
/// [`partition_threads`] propose threads, paper-default policy knobs.
pub fn stack_partitioner() -> AdaDNE {
    AdaDNE {
        threads: partition_threads(),
        ..Default::default()
    }
}

/// The Table I-analogue suite used by the partitioning/sampling benches.
pub fn bench_datasets() -> Vec<DatasetSpec> {
    let s = bench_scale();
    let scale = |x: usize| ((x as f64 * s) as usize).max(1000);
    vec![
        DatasetSpec { name: "products-s", n: scale(12_000), m: scale(300_000), alpha: 0.0, kind: GenKind::ErdosRenyi },
        DatasetSpec { name: "wiki-s", n: scale(45_000), m: scale(300_000), alpha: 2.1, kind: GenKind::ChungLu },
        DatasetSpec { name: "twitter-s", n: scale(21_000), m: scale(740_000), alpha: 1.9, kind: GenKind::ChungLu },
        DatasetSpec { name: "paper-s", n: scale(55_000), m: scale(800_000), alpha: 2.2, kind: GenKind::RMat },
    ]
}

/// The large sparse "RelNet"-regime graph for scale-flavoured benches.
pub fn relnet_like() -> DatasetSpec {
    let s = bench_scale();
    let scale = |x: usize| ((x as f64 * s) as usize).max(1000);
    DatasetSpec {
        name: "relnet-s",
        n: scale(400_000),
        m: scale(1_900_000),
        alpha: 2.3,
        kind: GenKind::ChungLu,
    }
}

/// Materialize one suite dataset. Same `(spec, seed)` → same graph,
/// bit-for-bit, on any host.
pub fn load(spec: &DatasetSpec, seed: u64) -> Graph {
    generator::generate(spec, seed)
}

/// A full training stack over a labeled community graph: AdaDNE partition
/// → sampling service → trainer → 80/20 split batcher. Used by the
/// pipeline_throughput bench; adopt it in new training-path benches
/// instead of hand-wiring the same stack.
pub struct TrainStack {
    pub service: SamplingService,
    pub trainer: Trainer,
    pub batcher: Batcher,
}

/// Build a [`TrainStack`] with default sampling-service threading.
pub fn train_stack(
    n: usize,
    parts: usize,
    model: &str,
    artifacts: &std::path::Path,
) -> anyhow::Result<TrainStack> {
    train_stack_cfg(n, parts, model, artifacts, ServiceConfig::default())
}

/// [`train_stack`] with explicit sampling-service threading knobs (worker
/// pool size / gather shard size, DESIGN.md §9) — the pool rows of the
/// pipeline_throughput bench and any bench that wants per-partition
/// parallel servers.
pub fn train_stack_cfg(
    n: usize,
    parts: usize,
    model: &str,
    artifacts: &std::path::Path,
    svc_cfg: ServiceConfig,
) -> anyhow::Result<TrainStack> {
    let (g, labels) = train_stack_graph(n);
    let ea = stack_partitioner().partition(&g, parts, 1);
    let service = SamplingService::launch_cfg(&g, &ea, 1, svc_cfg)?;
    train_stack_over(service, n, labels, model, artifacts)
}

/// [`train_stack`] against an already-running socket fleet (DESIGN.md
/// §12): the labeled graph is regenerated locally for features and the
/// train split, but every gather goes to the `glisp serve` processes at
/// `addrs` — which must host the SAME stack (`glisp serve --graph train
/// --n N --parts P --seed 1`), or the fleet's membership won't match the
/// local labels. Losses are bit-identical to [`train_stack_cfg`] at equal
/// shard_size because the trainer's client RNG and the per-seed server
/// streams are transport-independent.
pub fn train_stack_connect(
    n: usize,
    model: &str,
    artifacts: &std::path::Path,
    addrs: &[String],
    shard_size: usize,
) -> anyhow::Result<TrainStack> {
    let (g, labels) = train_stack_graph(n);
    let service = SamplingService::connect(addrs, g.n, ServiceConfig::new(1, shard_size))?;
    train_stack_over(service, n, labels, model, artifacts)
}

/// The stack's canonical labeled graph: same (generator, seed) as
/// [`train_stack_cfg`] uses, exposed so `glisp serve` can host exactly it.
pub fn train_stack_graph(n: usize) -> (Graph, Arc<Vec<u16>>) {
    let mut rng = Rng::new(1);
    let g = generator::labeled_community_graph(n, n * 12, 8, 0.9, &mut rng);
    let labels = Arc::new(g.label.clone());
    (g, labels)
}

/// Common tail of the train-stack builders: trainer + 80/20 batcher over
/// an already-launched (or connected) sampling service.
fn train_stack_over(
    service: SamplingService,
    n: usize,
    labels: Arc<Vec<u16>>,
    model: &str,
    artifacts: &std::path::Path,
) -> anyhow::Result<TrainStack> {
    let classes = 8;
    let features = FeatureStore::labeled(64, labels.clone(), classes, 0.6);
    let trainer = Trainer::new(
        artifacts,
        service.client(2),
        features,
        TrainerConfig {
            model: model.into(),
            lr: 0.1,
        },
        7,
    )?;
    let split = (n * 8) / 10;
    let train_seeds: Vec<u32> = (0..split as u32).collect();
    let train_labels: Vec<u16> = train_seeds.iter().map(|&v| labels[v as usize]).collect();
    let batcher = Batcher::new(train_seeds, train_labels, trainer.batch, 5)?;
    Ok(TrainStack {
        service,
        trainer,
        batcher,
    })
}

/// A full layerwise-inference stack over a chung_lu power-law graph:
/// AdaDNE partition → K-layer runtime (`cfg.layers`) → engine. Shared by
/// the fig13/table5 benches and the inference example so every inference
/// experiment wires the same stack; adopt it in new inference benches.
pub struct InferStack {
    pub g: Graph,
    pub ea: EdgeAssignment,
    pub engine: LayerwiseEngine,
}

/// Build an [`InferStack`] over a fresh work dir (any stale cache files
/// under `work_dir` are removed first so fill-cost columns start cold).
pub fn infer_stack(
    n: usize,
    parts: usize,
    artifacts: &std::path::Path,
    work_dir: std::path::PathBuf,
    cfg: EngineConfig,
) -> anyhow::Result<InferStack> {
    let mut rng = Rng::new(1);
    let g = generator::chung_lu(n, n * 7, 2.1, &mut rng);
    let ea = stack_partitioner().partition(&g, parts, 1);
    let _ = std::fs::remove_dir_all(&work_dir);
    let runtime = Runtime::load_with_layers(artifacts, cfg.layers)?;
    let enc = init_encoder_params(&runtime, 3)?;
    let engine = LayerwiseEngine::new(
        &g,
        &ea,
        runtime,
        FeatureStore::unlabeled(64),
        enc,
        cfg,
        work_dir,
    )?;
    Ok(InferStack { g, ea, engine })
}

/// The online-serving stack (DESIGN.md §15): the [`infer_stack`] graph and
/// engine wrapped in a [`ServingEngine`] — same generator, same seeds, so
/// `glisp serve --graph infer --n N` hosts exactly this graph and the
/// offline layerwise sweep over the same stack is the byte-level reference
/// for every served embedding.
pub struct ServingStack {
    pub g: Graph,
    pub ea: EdgeAssignment,
    pub serving: ServingEngine,
}

/// Build a [`ServingStack`] over a fresh work dir.
pub fn serving_stack(
    n: usize,
    parts: usize,
    artifacts: &std::path::Path,
    work_dir: std::path::PathBuf,
    cfg: EngineConfig,
    scfg: ServingConfig,
) -> anyhow::Result<ServingStack> {
    let InferStack { g, ea, engine } = infer_stack(n, parts, artifacts, work_dir, cfg)?;
    Ok(ServingStack {
        g,
        ea,
        serving: ServingEngine::new(engine, scfg)?,
    })
}

/// Launch the sampling fleet for a serving deployment in one of the four
/// storage × transport configurations bench_serving sweeps: partitions are
/// saved to `save_dir` once (reused if present), then served either
/// in-process over [`crate::sampling::ChannelTransport`] or as loopback
/// socket processes, with structures decoded to the heap or mapped from
/// the saved files. Samples are bit-identical across all four
/// (DESIGN.md §12–§13).
pub fn serving_fleet(
    g: &Graph,
    ea: &EdgeAssignment,
    save_dir: &std::path::Path,
    backend: StoreBackend,
    socket: bool,
    svc_cfg: ServiceConfig,
) -> anyhow::Result<(SamplingService, Vec<RemoteServer>)> {
    if !save_dir.join("part0.bin").exists() {
        crate::graph::build_and_save_partitions(
            g,
            &ea.part_of_edge,
            ea.num_parts,
            partition_threads(),
            save_dir,
        )?;
    }
    if socket {
        let parts = crate::graph::open_partitions(save_dir, backend)?;
        let mut servers = Vec::new();
        for p in parts {
            servers.push(serve_partition(
                Arc::new(p),
                "tcp:127.0.0.1:0",
                1,
                svc_cfg.workers.max(1),
            )?);
        }
        let addrs: Vec<String> = servers.iter().map(|s| s.addr().to_string()).collect();
        let svc = SamplingService::connect(&addrs, g.n, svc_cfg)?;
        Ok((svc, servers))
    } else {
        let svc = SamplingService::launch_from_dir(save_dir, 1, svc_cfg, backend)?;
        Ok((svc, Vec::new()))
    }
}

/// Power-law request trace: vertex v is drawn with probability
/// ∝ out_degree(v) + 1, so the Chung-Lu degree skew of the serving graph
/// carries straight into request popularity — the hot head a warm cache
/// should absorb. Same `(graph, len, seed)` → same trace, bit-for-bit.
pub fn power_law_trace(g: &Graph, len: usize, seed: u64) -> Vec<VId> {
    let mut cum: Vec<u64> = Vec::with_capacity(g.n);
    let mut acc = 0u64;
    for v in 0..g.n {
        acc += g.out_neighbors(v as VId).len() as u64 + 1;
        cum.push(acc);
    }
    let mut rng = Rng::new(seed);
    (0..len)
        .map(|_| {
            let t = (rng.f64() * acc as f64) as u64;
            cum.partition_point(|&c| c <= t).min(g.n - 1) as VId
        })
        .collect()
}

/// Nearest-rank percentile (`p` in 0..=100) over nanosecond latency
/// samples, reported in microseconds. Sorts in place.
pub fn percentile_us(lat_ns: &mut [u64], p: f64) -> f64 {
    if lat_ns.is_empty() {
        return 0.0;
    }
    lat_ns.sort_unstable();
    let idx = ((p / 100.0) * (lat_ns.len() - 1) as f64).round() as usize;
    lat_ns[idx.min(lat_ns.len() - 1)] as f64 / 1_000.0
}

/// What one load-generator run measured.
#[derive(Clone, Debug)]
pub struct ServeLoadReport {
    /// Embedding requests issued (trace length / batch, across clients).
    pub requests: usize,
    pub wall_secs: f64,
    /// Requests per second over the whole run.
    pub qps: f64,
    /// Request latency percentiles in µs — for `clients > 1` these include
    /// the time queueing on the engine, which is the closed-loop point.
    pub p50_us: f64,
    pub p99_us: f64,
    /// FNV fold over every response's `f32_digest`, per client in issue
    /// order, then across clients in client order — deterministic for a
    /// fixed `(trace, clients, batch)` regardless of thread interleaving,
    /// because served bytes are interleaving-independent.
    pub digest: u64,
}

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// Closed-loop load generator: `clients` threads each own a contiguous
/// shard of `trace` and issue `batch`-vertex embedding requests
/// back-to-back (one outstanding request per client) against the shared
/// serving engine. With `clients == 1` this degenerates to the open-loop
/// single-stream probe: no queueing, latencies are pure service times
/// ([`run_open_loop`]).
pub fn run_closed_loop(
    serving: &mut ServingEngine,
    trace: &[VId],
    clients: usize,
    batch: usize,
) -> anyhow::Result<ServeLoadReport> {
    let clients = clients.max(1);
    let batch = batch.max(1);
    let engine = Mutex::new(serving);
    let per = trace.len().div_ceil(clients);
    let wall = Timer::start();
    let per_client: Vec<(Vec<u64>, u64)> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..clients)
            .map(|c| {
                let engine = &engine;
                let shard = &trace[(c * per).min(trace.len())..((c + 1) * per).min(trace.len())];
                s.spawn(move || -> anyhow::Result<(Vec<u64>, u64)> {
                    let mut lat_ns = Vec::with_capacity(shard.len() / batch + 1);
                    let mut acc = FNV_OFFSET;
                    for req in shard.chunks(batch) {
                        let t = Timer::start();
                        let out = engine.lock().unwrap().embed(req)?;
                        lat_ns.push((t.secs() * 1e9) as u64);
                        acc = (acc ^ f32_digest(&out)).wrapping_mul(FNV_PRIME);
                    }
                    Ok((lat_ns, acc))
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("load client panicked"))
            .collect::<anyhow::Result<Vec<_>>>()
    })?;
    let wall_secs = wall.secs();
    let mut lat_ns: Vec<u64> = Vec::new();
    let mut digest = FNV_OFFSET;
    for (lats, d) in per_client {
        lat_ns.extend(lats);
        digest = (digest ^ d).wrapping_mul(FNV_PRIME);
    }
    let requests = lat_ns.len();
    Ok(ServeLoadReport {
        requests,
        wall_secs,
        qps: requests as f64 / wall_secs.max(1e-9),
        p50_us: percentile_us(&mut lat_ns, 50.0),
        p99_us: percentile_us(&mut lat_ns, 99.0),
        digest,
    })
}

/// Open-loop single-stream probe: [`run_closed_loop`] with one client —
/// per-request service time with no queueing component.
pub fn run_open_loop(
    serving: &mut ServingEngine,
    trace: &[VId],
    batch: usize,
) -> anyhow::Result<ServeLoadReport> {
    run_closed_loop(serving, trace, 1, batch)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn infer_stack_wires_a_runnable_engine() {
        let dir = std::env::temp_dir().join("glisp_infer_stack_test");
        let mut stack = infer_stack(
            1200,
            3,
            &crate::test_artifacts_dir(),
            dir,
            EngineConfig {
                layers: 3,
                ..Default::default()
            },
        )
        .unwrap();
        let (h, rep) = stack.engine.run_vertex_embedding().unwrap();
        assert_eq!(h.len(), stack.g.n * 128);
        assert_eq!(rep.vertices_computed, 3 * stack.g.n as u64);
        assert_eq!(stack.ea.num_parts, 3);
    }

    #[test]
    fn power_law_trace_is_deterministic_and_skewed() {
        let mut rng = Rng::new(1);
        let g = generator::chung_lu(2000, 14_000, 2.1, &mut rng);
        let a = power_law_trace(&g, 500, 9);
        let b = power_law_trace(&g, 500, 9);
        assert_eq!(a, b);
        // Degree-proportional sampling concentrates on the head: the most
        // popular vertex must appear well above the uniform expectation.
        let mut freq = vec![0usize; g.n];
        for &v in &a {
            freq[v as usize] += 1;
        }
        let top = freq.iter().max().copied().unwrap();
        assert!(top * g.n > 4 * a.len(), "trace looks uniform (top={top})");
    }

    #[test]
    fn closed_loop_digest_is_interleaving_independent() {
        let art = crate::test_artifacts_dir();
        let mk = |tag: &str| {
            serving_stack(
                700,
                2,
                &art,
                std::env::temp_dir().join(format!("glisp_srv_stack_{tag}")),
                EngineConfig::default(),
                ServingConfig::default(),
            )
            .unwrap()
        };
        let mut s1 = mk("a");
        let trace = power_law_trace(&s1.g, 64, 5);
        let r1 = run_closed_loop(&mut s1.serving, &trace, 4, 4).unwrap();
        // A fresh identical stack under the same (trace, clients, batch)
        // must serve the same bytes whatever the thread interleaving did
        // to the cache state.
        let mut s2 = mk("b");
        let r2 = run_closed_loop(&mut s2.serving, &trace, 4, 4).unwrap();
        assert_eq!(r1.digest, r2.digest);
        assert_eq!(r1.requests, r2.requests);
        assert!(r1.p99_us >= r1.p50_us);
    }

    #[test]
    fn suite_has_expected_regimes() {
        let ds = bench_datasets();
        assert_eq!(ds.len(), 4);
        assert_eq!(ds[0].kind, GenKind::ErdosRenyi); // the non-power-law control
        assert!(ds[1..].iter().all(|d| d.kind != GenKind::ErdosRenyi));
    }
}
