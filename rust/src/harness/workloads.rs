//! Shared bench workloads: the scaled-down dataset suite and common
//! experiment wiring, so every bench regenerates its table/figure from the
//! same graphs. Sizes are tuned so the full `cargo bench` suite finishes
//! in minutes on a laptop-class CPU; set GLISP_BENCH_SCALE to scale the
//! vertex/edge counts (1.0 = default).
//!
//! # Determinism contract
//!
//! Every stack built here is reproducible bit-for-bit given the same
//! `GLISP_BENCH_*` knobs — bench authors inherit this instead of
//! re-deriving it per bench:
//!
//! - Graphs come from [`generator`] under fixed seeds, so vertex/edge sets
//!   are identical across runs and hosts.
//! - Partitions come from [`stack_partitioner`], whose round-synchronous
//!   AdaDNE propose phase is bit-identical for any
//!   `GLISP_PARTITION_THREADS` value (DESIGN.md §10).
//! - Training through [`TrainStack`] is ordered-pipelined: losses are
//!   bit-equal to the synchronous loop for any pipeline depth or sampling
//!   worker-pool geometry (DESIGN.md §7, §9).
//! - Layerwise inference through [`InferStack`] produces embeddings
//!   bit-identical for any worker count (DESIGN.md §8).
//!
//! Consequently only *timing* columns of a bench may vary between runs;
//! every count/ratio/loss column is stable, which is what lets the
//! `BENCH_*.json` assertion outcomes ([`crate::harness::bench`]) make the
//! equality claims machine-checkable.

use std::sync::Arc;

use crate::coordinator::{Batcher, FeatureStore, Trainer, TrainerConfig};
use crate::graph::csr::Graph;
use crate::graph::generator::{self, DatasetSpec, GenKind};
use crate::inference::{init_encoder_params, EngineConfig, LayerwiseEngine};
use crate::partition::{AdaDNE, EdgeAssignment, Partitioner};
use crate::runtime::Runtime;
use crate::sampling::{SamplingService, ServiceConfig};
use crate::util::rng::Rng;

/// Global size multiplier for the synthetic suite (GLISP_BENCH_SCALE,
/// default 1.0). Scaling changes the graphs, so artifacts are only
/// comparable at equal scale — the recorder stamps it into run metadata.
pub fn bench_scale() -> f64 {
    std::env::var("GLISP_BENCH_SCALE")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1.0)
}

/// Propose-phase threads for the offline AdaDNE stage of the shared
/// stacks (GLISP_PARTITION_THREADS, default 1). Pure throughput knob: the
/// assignment is bit-identical for any value (DESIGN.md §10), so benches
/// stay comparable whatever the setting.
pub fn partition_threads() -> usize {
    std::env::var("GLISP_PARTITION_THREADS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1)
        .max(1)
}

/// The AdaDNE instance every shared stack partitions with —
/// [`partition_threads`] propose threads, paper-default policy knobs.
pub fn stack_partitioner() -> AdaDNE {
    AdaDNE {
        threads: partition_threads(),
        ..Default::default()
    }
}

/// The Table I-analogue suite used by the partitioning/sampling benches.
pub fn bench_datasets() -> Vec<DatasetSpec> {
    let s = bench_scale();
    let scale = |x: usize| ((x as f64 * s) as usize).max(1000);
    vec![
        DatasetSpec { name: "products-s", n: scale(12_000), m: scale(300_000), alpha: 0.0, kind: GenKind::ErdosRenyi },
        DatasetSpec { name: "wiki-s", n: scale(45_000), m: scale(300_000), alpha: 2.1, kind: GenKind::ChungLu },
        DatasetSpec { name: "twitter-s", n: scale(21_000), m: scale(740_000), alpha: 1.9, kind: GenKind::ChungLu },
        DatasetSpec { name: "paper-s", n: scale(55_000), m: scale(800_000), alpha: 2.2, kind: GenKind::RMat },
    ]
}

/// The large sparse "RelNet"-regime graph for scale-flavoured benches.
pub fn relnet_like() -> DatasetSpec {
    let s = bench_scale();
    let scale = |x: usize| ((x as f64 * s) as usize).max(1000);
    DatasetSpec {
        name: "relnet-s",
        n: scale(400_000),
        m: scale(1_900_000),
        alpha: 2.3,
        kind: GenKind::ChungLu,
    }
}

/// Materialize one suite dataset. Same `(spec, seed)` → same graph,
/// bit-for-bit, on any host.
pub fn load(spec: &DatasetSpec, seed: u64) -> Graph {
    generator::generate(spec, seed)
}

/// A full training stack over a labeled community graph: AdaDNE partition
/// → sampling service → trainer → 80/20 split batcher. Used by the
/// pipeline_throughput bench; adopt it in new training-path benches
/// instead of hand-wiring the same stack.
pub struct TrainStack {
    pub service: SamplingService,
    pub trainer: Trainer,
    pub batcher: Batcher,
}

/// Build a [`TrainStack`] with default sampling-service threading.
pub fn train_stack(
    n: usize,
    parts: usize,
    model: &str,
    artifacts: &std::path::Path,
) -> anyhow::Result<TrainStack> {
    train_stack_cfg(n, parts, model, artifacts, ServiceConfig::default())
}

/// [`train_stack`] with explicit sampling-service threading knobs (worker
/// pool size / gather shard size, DESIGN.md §9) — the pool rows of the
/// pipeline_throughput bench and any bench that wants per-partition
/// parallel servers.
pub fn train_stack_cfg(
    n: usize,
    parts: usize,
    model: &str,
    artifacts: &std::path::Path,
    svc_cfg: ServiceConfig,
) -> anyhow::Result<TrainStack> {
    let (g, labels) = train_stack_graph(n);
    let ea = stack_partitioner().partition(&g, parts, 1);
    let service = SamplingService::launch_cfg(&g, &ea, 1, svc_cfg)?;
    train_stack_over(service, n, labels, model, artifacts)
}

/// [`train_stack`] against an already-running socket fleet (DESIGN.md
/// §12): the labeled graph is regenerated locally for features and the
/// train split, but every gather goes to the `glisp serve` processes at
/// `addrs` — which must host the SAME stack (`glisp serve --graph train
/// --n N --parts P --seed 1`), or the fleet's membership won't match the
/// local labels. Losses are bit-identical to [`train_stack_cfg`] at equal
/// shard_size because the trainer's client RNG and the per-seed server
/// streams are transport-independent.
pub fn train_stack_connect(
    n: usize,
    model: &str,
    artifacts: &std::path::Path,
    addrs: &[String],
    shard_size: usize,
) -> anyhow::Result<TrainStack> {
    let (g, labels) = train_stack_graph(n);
    let service = SamplingService::connect(addrs, g.n, ServiceConfig::new(1, shard_size))?;
    train_stack_over(service, n, labels, model, artifacts)
}

/// The stack's canonical labeled graph: same (generator, seed) as
/// [`train_stack_cfg`] uses, exposed so `glisp serve` can host exactly it.
pub fn train_stack_graph(n: usize) -> (Graph, Arc<Vec<u16>>) {
    let mut rng = Rng::new(1);
    let g = generator::labeled_community_graph(n, n * 12, 8, 0.9, &mut rng);
    let labels = Arc::new(g.label.clone());
    (g, labels)
}

/// Common tail of the train-stack builders: trainer + 80/20 batcher over
/// an already-launched (or connected) sampling service.
fn train_stack_over(
    service: SamplingService,
    n: usize,
    labels: Arc<Vec<u16>>,
    model: &str,
    artifacts: &std::path::Path,
) -> anyhow::Result<TrainStack> {
    let classes = 8;
    let features = FeatureStore::labeled(64, labels.clone(), classes, 0.6);
    let trainer = Trainer::new(
        artifacts,
        service.client(2),
        features,
        TrainerConfig {
            model: model.into(),
            lr: 0.1,
        },
        7,
    )?;
    let split = (n * 8) / 10;
    let train_seeds: Vec<u32> = (0..split as u32).collect();
    let train_labels: Vec<u16> = train_seeds.iter().map(|&v| labels[v as usize]).collect();
    let batcher = Batcher::new(train_seeds, train_labels, trainer.batch, 5)?;
    Ok(TrainStack {
        service,
        trainer,
        batcher,
    })
}

/// A full layerwise-inference stack over a chung_lu power-law graph:
/// AdaDNE partition → K-layer runtime (`cfg.layers`) → engine. Shared by
/// the fig13/table5 benches and the inference example so every inference
/// experiment wires the same stack; adopt it in new inference benches.
pub struct InferStack {
    pub g: Graph,
    pub ea: EdgeAssignment,
    pub engine: LayerwiseEngine,
}

/// Build an [`InferStack`] over a fresh work dir (any stale cache files
/// under `work_dir` are removed first so fill-cost columns start cold).
pub fn infer_stack(
    n: usize,
    parts: usize,
    artifacts: &std::path::Path,
    work_dir: std::path::PathBuf,
    cfg: EngineConfig,
) -> anyhow::Result<InferStack> {
    let mut rng = Rng::new(1);
    let g = generator::chung_lu(n, n * 7, 2.1, &mut rng);
    let ea = stack_partitioner().partition(&g, parts, 1);
    let _ = std::fs::remove_dir_all(&work_dir);
    let runtime = Runtime::load_with_layers(artifacts, cfg.layers)?;
    let enc = init_encoder_params(&runtime, 3)?;
    let engine = LayerwiseEngine::new(
        &g,
        &ea,
        runtime,
        FeatureStore::unlabeled(64),
        enc,
        cfg,
        work_dir,
    )?;
    Ok(InferStack { g, ea, engine })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn infer_stack_wires_a_runnable_engine() {
        let dir = std::env::temp_dir().join("glisp_infer_stack_test");
        let mut stack = infer_stack(
            1200,
            3,
            &crate::test_artifacts_dir(),
            dir,
            EngineConfig {
                layers: 3,
                ..Default::default()
            },
        )
        .unwrap();
        let (h, rep) = stack.engine.run_vertex_embedding().unwrap();
        assert_eq!(h.len(), stack.g.n * 128);
        assert_eq!(rep.vertices_computed, 3 * stack.g.n as u64);
        assert_eq!(stack.ea.num_parts, 3);
    }

    #[test]
    fn suite_has_expected_regimes() {
        let ds = bench_datasets();
        assert_eq!(ds.len(), 4);
        assert_eq!(ds[0].kind, GenKind::ErdosRenyi); // the non-power-law control
        assert!(ds[1..].iter().all(|d| d.kind != GenKind::ErdosRenyi));
    }
}
