//! The artifact manifest — the AOT contract between python/compile/aot.py
//! and the Rust runtime. Input order/shape/dtype and output arity per
//! artifact; the runtime validates every execute() call against it.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{Context, Result};

use crate::runtime::tensor::DType;
use crate::util::json::Json;

#[derive(Clone, Debug)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    pub meta: Json,
}

impl ArtifactSpec {
    /// Meta field as usize (fanouts, batch, dims...).
    pub fn meta_usize(&self, key: &str) -> Option<usize> {
        self.meta.get(key).and_then(Json::as_usize)
    }

    pub fn meta_usizes(&self, key: &str) -> Option<Vec<usize>> {
        Some(
            self.meta
                .get(key)?
                .as_arr()?
                .iter()
                .filter_map(Json::as_usize)
                .collect(),
        )
    }
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub artifacts: BTreeMap<String, ArtifactSpec>,
}

fn tensor_spec(j: &Json, idx: usize) -> Result<TensorSpec> {
    let shape = j
        .get("shape")
        .and_then(Json::as_arr)
        .context("spec.shape")?
        .iter()
        .filter_map(Json::as_usize)
        .collect();
    let dtype = DType::parse(
        j.get("dtype").and_then(Json::as_str).unwrap_or("f32"),
    )?;
    Ok(TensorSpec {
        name: j
            .get("name")
            .and_then(Json::as_str)
            .map(str::to_string)
            .unwrap_or_else(|| format!("out{idx}")),
        shape,
        dtype,
    })
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let raw = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("manifest.json not found in {dir:?} — run `make artifacts`"))?;
        Self::parse(&raw)
    }

    pub fn parse(raw: &str) -> Result<Manifest> {
        let j = Json::parse(raw).context("manifest parse")?;
        let mut artifacts = BTreeMap::new();
        for a in j
            .get("artifacts")
            .and_then(Json::as_arr)
            .context("manifest.artifacts")?
        {
            let name = a
                .get("name")
                .and_then(Json::as_str)
                .context("artifact.name")?
                .to_string();
            let file = a
                .get("file")
                .and_then(Json::as_str)
                .context("artifact.file")?
                .to_string();
            let inputs = a
                .get("inputs")
                .and_then(Json::as_arr)
                .context("artifact.inputs")?
                .iter()
                .enumerate()
                .map(|(i, s)| tensor_spec(s, i))
                .collect::<Result<Vec<_>>>()?;
            let outputs = a
                .get("outputs")
                .and_then(Json::as_arr)
                .context("artifact.outputs")?
                .iter()
                .enumerate()
                .map(|(i, s)| tensor_spec(s, i))
                .collect::<Result<Vec<_>>>()?;
            let meta = a.get("meta").cloned().unwrap_or(Json::Null);
            artifacts.insert(
                name.clone(),
                ArtifactSpec {
                    name,
                    file,
                    inputs,
                    outputs,
                    meta,
                },
            );
        }
        Ok(Manifest { artifacts })
    }

    pub fn get(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(name)
            .with_context(|| format!("artifact '{name}' not in manifest"))
    }

    /// Depth K of the inference encoder this manifest carries: the number
    /// of consecutive `sage_infer_layer{k}` slices starting at 0. The
    /// layerwise engine and `init_encoder_params` size themselves from
    /// this, so the manifest is the single source of truth for K.
    pub fn infer_layers(&self) -> usize {
        (0..)
            .take_while(|k| self.artifacts.contains_key(&format!("sage_infer_layer{k}")))
            .count()
    }

    /// The built-in manifest of the pure-Rust reference backend: the same
    /// artifact set, input order, shapes and metadata that
    /// python/compile/aot.py emits at its default configuration
    /// (din=64, hidden=128, classes=8, batch=32, fanouts=[10,5,5];
    /// 2-layer inference encoder with fanout 10, chunk 256). Used when
    /// `artifacts/manifest.json` has not been built, so the whole stack
    /// stays runnable with zero native dependencies.
    pub fn reference_default() -> Manifest {
        Self::reference_with_layers(2)
    }

    /// [`Self::reference_default`] with a K-layer inference encoder: emits
    /// `sage_infer_layer{0..k}` slices (layer 0 reads `din`, every slice
    /// writes `hidden`, relu on all but the final slice) and sizes the
    /// samplewise `sage_embed` baseline to the same K-hop geometry, so the
    /// layerwise engine and its Fig. 13 comparator stay aligned at any
    /// depth. The training artifacts are depth-independent and unchanged.
    pub fn reference_with_layers(k_infer: usize) -> Manifest {
        assert!(k_infer >= 1, "inference encoder needs at least one layer");
        let mut artifacts = BTreeMap::new();
        let mut add = |spec: ArtifactSpec| {
            artifacts.insert(spec.name.clone(), spec);
        };

        let fanouts_json = format!(
            "[{}]",
            REF_FANOUTS.map(|f| f.to_string()).join(",")
        );
        for kind in ["sage", "gcn", "gat"] {
            let params = ref_param_specs(kind);
            let n_params = params.len();
            let (xs, masks) = ref_level_specs(REF_BATCH, &REF_FANOUTS, REF_DIN);
            let meta = Json::parse(&format!(
                r#"{{"kind":"{kind}","din":{REF_DIN},"hidden":{REF_HIDDEN},"classes":{REF_CLASSES},"batch":{REF_BATCH},"fanouts":{fanouts_json},"n_params":{n_params}}}"#
            ))
            .expect("builtin meta");

            let mut train_in = params.clone();
            train_in.extend(xs.iter().cloned());
            train_in.extend(masks.iter().cloned());
            train_in.push(ispec("labels", &[REF_BATCH]));
            train_in.push(fspec("lr", &[1]));
            let mut train_out = vec![fspec("loss", &[1])];
            train_out.extend(params.iter().cloned());
            add(artifact(format!("{kind}_train"), train_in, train_out, meta.clone()));

            let mut eval_in = params.clone();
            eval_in.extend(xs.iter().cloned());
            eval_in.extend(masks.iter().cloned());
            let eval_out = vec![fspec("logits", &[REF_BATCH, REF_CLASSES])];
            add(artifact(format!("{kind}_eval"), eval_in, eval_out, meta.clone()));

            if kind == "sage" {
                let mut grad_in = params.clone();
                grad_in.extend(xs.iter().cloned());
                grad_in.extend(masks.iter().cloned());
                grad_in.push(ispec("labels", &[REF_BATCH]));
                let mut grad_out = vec![fspec("loss", &[1])];
                grad_out.extend(params.iter().cloned());
                add(artifact("sage_grad".to_string(), grad_in, grad_out, meta));
            }
        }

        // Layer slices of the K-layer SAGE inference encoder.
        for layer in 0..k_infer {
            let din = if layer == 0 { REF_DIN } else { REF_HIDDEN };
            let dout = REF_HIDDEN;
            // relu between layers; the final slice emits raw embeddings.
            let relu = layer + 1 < k_infer;
            let inputs = vec![
                fspec("h_self", &[REF_CHUNK, din]),
                fspec("h_neigh", &[REF_CHUNK, REF_ENC_FANOUT, din]),
                fspec("mask", &[REF_CHUNK, REF_ENC_FANOUT]),
                fspec("w_self", &[din, dout]),
                fspec("w_neigh", &[din, dout]),
                fspec("b", &[dout]),
            ];
            let outputs = vec![fspec("h_out", &[REF_CHUNK, dout])];
            let meta = Json::parse(&format!(
                r#"{{"layer":{layer},"relu":{relu},"chunk":{REF_CHUNK},"fanout":{REF_ENC_FANOUT},"din":{din},"dout":{dout}}}"#
            ))
            .expect("builtin meta");
            add(artifact(format!("sage_infer_layer{layer}"), inputs, outputs, meta));
        }

        // Samplewise baseline: full K-hop SAGE tree forward to embeddings.
        {
            let mut inputs = Vec::new();
            for j in 0..k_infer {
                let din = if j == 0 { REF_DIN } else { REF_HIDDEN };
                inputs.push(fspec(&format!("l{j}_w_self"), &[din, REF_HIDDEN]));
                inputs.push(fspec(&format!("l{j}_w_neigh"), &[din, REF_HIDDEN]));
                inputs.push(fspec(&format!("l{j}_b"), &[REF_HIDDEN]));
            }
            let fanouts = vec![REF_ENC_FANOUT; k_infer];
            let (xs, masks) = ref_level_specs(REF_EMBED_BATCH, &fanouts, REF_DIN);
            inputs.extend(xs);
            inputs.extend(masks);
            let outputs = vec![fspec("emb", &[REF_EMBED_BATCH, REF_HIDDEN])];
            let embed_fanouts = format!(
                "[{}]",
                fanouts.iter().map(|f| f.to_string()).collect::<Vec<_>>().join(",")
            );
            let meta = Json::parse(&format!(
                r#"{{"batch":{REF_EMBED_BATCH},"fanouts":{embed_fanouts},"din":{REF_DIN},"hidden":{REF_HIDDEN}}}"#
            ))
            .expect("builtin meta");
            add(artifact("sage_embed".to_string(), inputs, outputs, meta));
        }

        // Link-prediction decoder over cached endpoint embeddings.
        {
            let h = REF_HIDDEN;
            let inputs = vec![
                fspec("emb_u", &[REF_DECODE_BATCH, h]),
                fspec("emb_v", &[REF_DECODE_BATCH, h]),
                fspec("w1", &[2 * h, h]),
                fspec("b1", &[h]),
                fspec("w2", &[h, 1]),
                fspec("b2", &[1]),
            ];
            let outputs = vec![fspec("scores", &[REF_DECODE_BATCH])];
            let meta = Json::parse(&format!(
                r#"{{"batch":{REF_DECODE_BATCH},"hidden":{h}}}"#
            ))
            .expect("builtin meta");
            add(artifact("link_decode".to_string(), inputs, outputs, meta));
        }

        Manifest { artifacts }
    }
}

// Geometry constants of the built-in reference manifest (mirror the
// TRAIN_CFG / ENC dicts in python/compile/aot.py).
const REF_DIN: usize = 64;
const REF_HIDDEN: usize = 128;
const REF_CLASSES: usize = 8;
const REF_BATCH: usize = 32;
const REF_FANOUTS: [usize; 3] = [10, 5, 5];
const REF_HEADS: usize = 4;
const REF_ENC_FANOUT: usize = 10;
const REF_CHUNK: usize = 256;
const REF_EMBED_BATCH: usize = 64;
const REF_DECODE_BATCH: usize = 256;

fn fspec(name: &str, shape: &[usize]) -> TensorSpec {
    TensorSpec {
        name: name.to_string(),
        shape: shape.to_vec(),
        dtype: DType::F32,
    }
}

fn ispec(name: &str, shape: &[usize]) -> TensorSpec {
    TensorSpec {
        name: name.to_string(),
        shape: shape.to_vec(),
        dtype: DType::I32,
    }
}

fn artifact(
    name: String,
    inputs: Vec<TensorSpec>,
    outputs: Vec<TensorSpec>,
    meta: Json,
) -> ArtifactSpec {
    let file = format!("{name}.hlo.txt");
    ArtifactSpec {
        name,
        file,
        inputs,
        outputs,
        meta,
    }
}

/// Flat parameter spec list for one model kind at the reference training
/// geometry, in artifact input order (mirrors model.param_specs).
fn ref_param_specs(kind: &str) -> Vec<TensorSpec> {
    let mut specs = Vec::new();
    let mut d_in = REF_DIN;
    for j in 0..REF_FANOUTS.len() {
        let d_out = REF_HIDDEN;
        match kind {
            "sage" => {
                specs.push(fspec(&format!("l{j}_w_self"), &[d_in, d_out]));
                specs.push(fspec(&format!("l{j}_w_neigh"), &[d_in, d_out]));
                specs.push(fspec(&format!("l{j}_b"), &[d_out]));
            }
            "gcn" => {
                specs.push(fspec(&format!("l{j}_w"), &[d_in, d_out]));
                specs.push(fspec(&format!("l{j}_b"), &[d_out]));
            }
            "gat" => {
                let hd = d_out / REF_HEADS;
                specs.push(fspec(&format!("l{j}_w"), &[d_in, d_out]));
                specs.push(fspec(&format!("l{j}_a_self"), &[REF_HEADS, hd]));
                specs.push(fspec(&format!("l{j}_a_neigh"), &[REF_HEADS, hd]));
                specs.push(fspec(&format!("l{j}_b"), &[d_out]));
            }
            other => unreachable!("unknown builtin model kind {other}"),
        }
        d_in = d_out;
    }
    specs.push(fspec("head_w", &[REF_HIDDEN, REF_CLASSES]));
    specs.push(fspec("head_b", &[REF_CLASSES]));
    specs
}

/// Level-feature + mask specs for a tree sample of the given geometry.
fn ref_level_specs(batch: usize, fanouts: &[usize], din: usize) -> (Vec<TensorSpec>, Vec<TensorSpec>) {
    let mut sizes = vec![batch];
    for &f in fanouts {
        sizes.push(sizes.last().unwrap() * f);
    }
    let xs = sizes
        .iter()
        .enumerate()
        .map(|(k, &n)| fspec(&format!("x{k}"), &[n, din]))
        .collect();
    let masks = (0..fanouts.len())
        .map(|k| fspec(&format!("mask{}", k + 1), &[sizes[k + 1]]))
        .collect();
    (xs, masks)
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{"artifacts": [{
        "name": "m", "file": "m.hlo.txt",
        "inputs": [{"name": "x", "shape": [32, 64], "dtype": "f32"},
                   {"name": "labels", "shape": [32], "dtype": "i32"}],
        "outputs": [{"shape": [1], "dtype": "f32"}],
        "meta": {"batch": 32, "fanouts": [10, 5]}
    }]}"#;

    #[test]
    fn parse_fields() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let a = m.get("m").unwrap();
        assert_eq!(a.inputs.len(), 2);
        assert_eq!(a.inputs[0].shape, vec![32, 64]);
        assert_eq!(a.inputs[1].dtype, DType::I32);
        assert_eq!(a.outputs[0].shape, vec![1]);
        assert_eq!(a.meta_usize("batch"), Some(32));
        assert_eq!(a.meta_usizes("fanouts"), Some(vec![10, 5]));
    }

    #[test]
    fn missing_artifact_errors() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert!(m.get("nope").is_err());
    }

    #[test]
    fn reference_with_layers_emits_k_slices() {
        let m = Manifest::reference_with_layers(3);
        assert_eq!(m.infer_layers(), 3);
        let l0 = m.get("sage_infer_layer0").unwrap();
        assert_eq!(l0.meta_usize("din"), Some(64));
        assert_eq!(l0.meta.get("relu").and_then(Json::as_bool), Some(true));
        let l1 = m.get("sage_infer_layer1").unwrap();
        assert_eq!(l1.meta_usize("din"), Some(128));
        // Mid slices relu, the final slice does not.
        assert_eq!(l1.meta.get("relu").and_then(Json::as_bool), Some(true));
        let l2 = m.get("sage_infer_layer2").unwrap();
        assert_eq!(l2.meta.get("relu").and_then(Json::as_bool), Some(false));
        assert!(m.get("sage_infer_layer3").is_err());
        // The samplewise baseline follows the same depth: 9 params,
        // 4 level features, 3 masks.
        let emb = m.get("sage_embed").unwrap();
        assert_eq!(emb.inputs.len(), 9 + 4 + 3);
        assert_eq!(emb.meta_usizes("fanouts"), Some(vec![10, 10, 10]));
        // The default stays at the 2-layer aot.py geometry.
        assert_eq!(Manifest::reference_default().infer_layers(), 2);
    }

    #[test]
    fn reference_default_mirrors_aot_geometry() {
        let m = Manifest::reference_default();
        for kind in ["gcn", "sage", "gat"] {
            let t = m.get(&format!("{kind}_train")).unwrap();
            let n_params = t.meta_usize("n_params").unwrap();
            // params + 4 level features + 3 masks + labels + lr
            assert_eq!(t.inputs.len(), n_params + 4 + 3 + 2, "{kind} arity");
            assert_eq!(t.outputs.len(), 1 + n_params, "{kind} outputs");
            for i in 0..n_params {
                assert_eq!(t.outputs[1 + i].shape, t.inputs[i].shape);
            }
            assert_eq!(t.inputs[n_params].shape, vec![32, 64]);
            assert_eq!(t.inputs[n_params + 3].shape, vec![8000, 64]);
            assert_eq!(t.meta_usizes("fanouts"), Some(vec![10, 5, 5]));
            let e = m.get(&format!("{kind}_eval")).unwrap();
            assert_eq!(e.inputs.len(), n_params + 4 + 3);
            assert_eq!(e.outputs[0].shape, vec![32, 8]);
        }
        assert_eq!(
            m.get("sage_train").unwrap().meta_usize("n_params"),
            Some(11)
        );
        assert_eq!(m.get("gcn_train").unwrap().meta_usize("n_params"), Some(8));
        assert_eq!(m.get("gat_train").unwrap().meta_usize("n_params"), Some(14));
        let grad = m.get("sage_grad").unwrap();
        assert_eq!(grad.inputs.len(), 11 + 4 + 3 + 1);
        let l0 = m.get("sage_infer_layer0").unwrap();
        assert_eq!(l0.meta_usize("chunk"), Some(256));
        assert_eq!(l0.inputs[1].shape, vec![256, 10, 64]);
        let emb = m.get("sage_embed").unwrap();
        assert_eq!(emb.inputs.len(), 6 + 3 + 2);
        assert_eq!(emb.outputs[0].shape, vec![64, 128]);
        assert_eq!(m.get("link_decode").unwrap().outputs[0].shape, vec![256]);
    }
}
