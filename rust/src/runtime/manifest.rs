//! The artifact manifest — the AOT contract between python/compile/aot.py
//! and the Rust runtime. Input order/shape/dtype and output arity per
//! artifact; the runtime validates every execute() call against it.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{Context, Result};

use crate::runtime::tensor::DType;
use crate::util::json::Json;

#[derive(Clone, Debug)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    pub meta: Json,
}

impl ArtifactSpec {
    /// Meta field as usize (fanouts, batch, dims...).
    pub fn meta_usize(&self, key: &str) -> Option<usize> {
        self.meta.get(key).and_then(Json::as_usize)
    }

    pub fn meta_usizes(&self, key: &str) -> Option<Vec<usize>> {
        Some(
            self.meta
                .get(key)?
                .as_arr()?
                .iter()
                .filter_map(Json::as_usize)
                .collect(),
        )
    }
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub artifacts: BTreeMap<String, ArtifactSpec>,
}

fn tensor_spec(j: &Json, idx: usize) -> Result<TensorSpec> {
    let shape = j
        .get("shape")
        .and_then(Json::as_arr)
        .context("spec.shape")?
        .iter()
        .filter_map(Json::as_usize)
        .collect();
    let dtype = DType::parse(
        j.get("dtype").and_then(Json::as_str).unwrap_or("f32"),
    )?;
    Ok(TensorSpec {
        name: j
            .get("name")
            .and_then(Json::as_str)
            .map(str::to_string)
            .unwrap_or_else(|| format!("out{idx}")),
        shape,
        dtype,
    })
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let raw = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("manifest.json not found in {dir:?} — run `make artifacts`"))?;
        Self::parse(&raw)
    }

    pub fn parse(raw: &str) -> Result<Manifest> {
        let j = Json::parse(raw).context("manifest parse")?;
        let mut artifacts = BTreeMap::new();
        for a in j
            .get("artifacts")
            .and_then(Json::as_arr)
            .context("manifest.artifacts")?
        {
            let name = a
                .get("name")
                .and_then(Json::as_str)
                .context("artifact.name")?
                .to_string();
            let file = a
                .get("file")
                .and_then(Json::as_str)
                .context("artifact.file")?
                .to_string();
            let inputs = a
                .get("inputs")
                .and_then(Json::as_arr)
                .context("artifact.inputs")?
                .iter()
                .enumerate()
                .map(|(i, s)| tensor_spec(s, i))
                .collect::<Result<Vec<_>>>()?;
            let outputs = a
                .get("outputs")
                .and_then(Json::as_arr)
                .context("artifact.outputs")?
                .iter()
                .enumerate()
                .map(|(i, s)| tensor_spec(s, i))
                .collect::<Result<Vec<_>>>()?;
            let meta = a.get("meta").cloned().unwrap_or(Json::Null);
            artifacts.insert(
                name.clone(),
                ArtifactSpec {
                    name,
                    file,
                    inputs,
                    outputs,
                    meta,
                },
            );
        }
        Ok(Manifest { artifacts })
    }

    pub fn get(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(name)
            .with_context(|| format!("artifact '{name}' not in manifest"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{"artifacts": [{
        "name": "m", "file": "m.hlo.txt",
        "inputs": [{"name": "x", "shape": [32, 64], "dtype": "f32"},
                   {"name": "labels", "shape": [32], "dtype": "i32"}],
        "outputs": [{"shape": [1], "dtype": "f32"}],
        "meta": {"batch": 32, "fanouts": [10, 5]}
    }]}"#;

    #[test]
    fn parse_fields() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let a = m.get("m").unwrap();
        assert_eq!(a.inputs.len(), 2);
        assert_eq!(a.inputs[0].shape, vec![32, 64]);
        assert_eq!(a.inputs[1].dtype, DType::I32);
        assert_eq!(a.outputs[0].shape, vec![1]);
        assert_eq!(a.meta_usize("batch"), Some(32));
        assert_eq!(a.meta_usizes("fanouts"), Some(vec![10, 5]));
    }

    #[test]
    fn missing_artifact_errors() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert!(m.get("nope").is_err());
    }
}
