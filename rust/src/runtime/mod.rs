//! Runtime layer: the PJRT bridge between the Rust coordinator and the AOT
//! artifacts (HLO text lowered once from JAX + Pallas by `make artifacts`).

pub mod executor;
pub mod manifest;
pub mod tensor;

pub use executor::Runtime;
pub use manifest::{ArtifactSpec, Manifest, TensorSpec};
pub use tensor::{DType, HostTensor};
