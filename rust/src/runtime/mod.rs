//! Runtime layer: manifest-validated artifact execution behind the
//! [`ExecutorBackend`] seam. The pure-Rust [`reference`] backend is the
//! hermetic default; the PJRT/XLA executor of the AOT artifacts (HLO text
//! lowered once from JAX + Pallas by `make artifacts`) lives behind the
//! non-default `pjrt` cargo feature.

pub mod backend;
pub mod executor;
pub mod manifest;
#[cfg(feature = "pjrt")]
pub mod pjrt;
pub mod reference;
pub mod tensor;

pub use backend::ExecutorBackend;
pub use executor::Runtime;
pub use manifest::{ArtifactSpec, Manifest, TensorSpec};
pub use reference::ReferenceBackend;
pub use tensor::{DType, HostTensor, TensorPool};
