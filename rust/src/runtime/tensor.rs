//! Host-side tensors — the only data interchange between the Rust
//! coordinator and the executor backends. With the `pjrt` feature they
//! additionally convert to/from `xla::Literal`.

use anyhow::{bail, Result};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    pub fn parse(s: &str) -> Result<DType> {
        Ok(match s {
            "f32" | "float32" => DType::F32,
            "i32" | "int32" => DType::I32,
            other => bail!("unsupported dtype {other}"),
        })
    }
}

/// Dense host tensor, row-major.
#[derive(Clone, Debug)]
pub enum HostTensor {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl HostTensor {
    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        HostTensor::F32 { shape, data }
    }

    pub fn i32(shape: Vec<usize>, data: Vec<i32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        HostTensor::I32 { shape, data }
    }

    pub fn zeros(shape: &[usize]) -> Self {
        let n = shape.iter().product();
        HostTensor::F32 {
            shape: shape.to_vec(),
            data: vec![0.0; n],
        }
    }

    pub fn scalar1(v: f32) -> Self {
        HostTensor::f32(vec![1], vec![v])
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            HostTensor::F32 { shape, .. } | HostTensor::I32 { shape, .. } => shape,
        }
    }

    pub fn dtype(&self) -> DType {
        match self {
            HostTensor::F32 { .. } => DType::F32,
            HostTensor::I32 { .. } => DType::I32,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            HostTensor::F32 { data, .. } => data.len(),
            HostTensor::I32 { data, .. } => data.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> &[f32] {
        match self {
            HostTensor::F32 { data, .. } => data,
            _ => panic!("tensor is not f32"),
        }
    }

    pub fn as_i32(&self) -> &[i32] {
        match self {
            HostTensor::I32 { data, .. } => data,
            _ => panic!("tensor is not i32"),
        }
    }

    pub fn into_f32(self) -> Vec<f32> {
        match self {
            HostTensor::F32 { data, .. } => data,
            _ => panic!("tensor is not f32"),
        }
    }

    #[cfg(feature = "pjrt")]
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape().iter().map(|&d| d as i64).collect();
        let lit = match self {
            HostTensor::F32 { data, .. } => xla::Literal::vec1(data.as_slice()),
            HostTensor::I32 { data, .. } => xla::Literal::vec1(data.as_slice()),
        };
        Ok(lit.reshape(&dims)?)
    }

    #[cfg(feature = "pjrt")]
    pub fn from_literal(lit: &xla::Literal) -> Result<HostTensor> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            xla::ElementType::F32 => Ok(HostTensor::F32 {
                shape: dims,
                data: lit.to_vec::<f32>()?,
            }),
            xla::ElementType::S32 => Ok(HostTensor::I32 {
                shape: dims,
                data: lit.to_vec::<i32>()?,
            }),
            other => bail!("unsupported literal type {other:?}"),
        }
    }
}

/// Bounded recycle pool for `f32` tensor backing buffers (DESIGN.md §14).
/// The pipelined trainer returns a consumed batch's feature/mask buffers
/// here; producers draw from it when assembling the next batch, so
/// steady-state training allocates no per-batch tensors. Contents are
/// opaque scratch — `get` zero-fills to the requested length and every
/// assembly path overwrites what it uses, so pooling cannot change values.
/// `put` drops buffers beyond `cap` (bounded memory under producer skew).
pub struct TensorPool {
    bufs: Mutex<Vec<Vec<f32>>>,
    cap: usize,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl TensorPool {
    pub fn new(cap: usize) -> Self {
        Self {
            bufs: Mutex::new(Vec::new()),
            cap: cap.max(1),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    /// A zero-filled buffer of exactly `len` elements: the best-fit pooled
    /// buffer whose capacity already covers `len` (a *hit* — no heap
    /// traffic), or a fresh allocation (a *miss*).
    pub fn get(&self, len: usize) -> Vec<f32> {
        let mut q = self.bufs.lock().unwrap();
        let mut best: Option<usize> = None;
        for (i, b) in q.iter().enumerate() {
            if b.capacity() >= len && best.map_or(true, |j| b.capacity() < q[j].capacity()) {
                best = Some(i);
            }
        }
        if let Some(i) = best {
            let mut buf = q.swap_remove(i);
            drop(q);
            self.hits.fetch_add(1, Ordering::Relaxed);
            buf.clear();
            buf.resize(len, 0.0);
            buf
        } else {
            drop(q);
            self.misses.fetch_add(1, Ordering::Relaxed);
            vec![0.0; len]
        }
    }

    /// Return a buffer for reuse; dropped if the pool is at capacity.
    pub fn put(&self, mut buf: Vec<f32>) {
        if buf.capacity() == 0 {
            return;
        }
        let mut q = self.bufs.lock().unwrap();
        if q.len() < self.cap {
            buf.clear();
            q.push(buf);
        }
    }

    /// `get` calls served from the pool.
    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    /// `get` calls that had to allocate — flat in steady state, which is
    /// exactly what the `pooled_assembly_allocs_zero` bench check asserts.
    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    /// Buffers currently parked in the pool.
    pub fn pooled(&self) -> usize {
        self.bufs.lock().unwrap().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[cfg(feature = "pjrt")]
    #[test]
    fn literal_round_trip_f32() {
        let t = HostTensor::f32(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let l = t.to_literal().unwrap();
        let back = HostTensor::from_literal(&l).unwrap();
        assert_eq!(back.shape(), &[2, 3]);
        assert_eq!(back.as_f32(), t.as_f32());
    }

    #[cfg(feature = "pjrt")]
    #[test]
    fn literal_round_trip_i32() {
        let t = HostTensor::i32(vec![4], vec![7, -1, 0, 3]);
        let l = t.to_literal().unwrap();
        let back = HostTensor::from_literal(&l).unwrap();
        assert_eq!(back.as_i32(), t.as_i32());
    }

    #[test]
    fn accessors_and_shapes() {
        let t = HostTensor::f32(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.shape(), &[2, 3]);
        assert_eq!(t.dtype(), DType::F32);
        assert_eq!(t.len(), 6);
        assert!(!t.is_empty());
        assert_eq!(t.clone().into_f32(), vec![1., 2., 3., 4., 5., 6.]);
        let z = HostTensor::zeros(&[4]);
        assert!(z.as_f32().iter().all(|&x| x == 0.0));
        assert_eq!(HostTensor::scalar1(0.5).as_f32(), &[0.5]);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        HostTensor::f32(vec![2, 2], vec![1.0]);
    }

    #[test]
    fn pool_reuses_buffers_and_bounds_memory() {
        let pool = TensorPool::new(2);
        let a = pool.get(8);
        assert_eq!((pool.hits(), pool.misses()), (0, 1));
        pool.put(a);
        // Best fit: a request of 4 reuses the 8-capacity buffer, zero-filled.
        let b = pool.get(4);
        assert_eq!((pool.hits(), pool.misses()), (1, 1));
        assert_eq!(b.len(), 4);
        assert!(b.iter().all(|&x| x == 0.0));
        pool.put(b);
        pool.put(vec![1.0; 16]);
        pool.put(vec![1.0; 16]); // over cap → dropped
        assert_eq!(pool.pooled(), 2);
        // Only the 16-capacity buffer fits a request of 10, and reuse must
        // not leak the old contents.
        let c = pool.get(10);
        assert_eq!(c.len(), 10);
        assert!(c.iter().all(|&x| x == 0.0));
        assert_eq!((pool.hits(), pool.misses()), (2, 1));
    }
}
