//! PJRT/XLA backend (`pjrt` cargo feature): loads `artifacts/*.hlo.txt`
//! (AOT-lowered by python/compile/aot.py), compiles each once on the CPU
//! PJRT client, and executes them from the L3 hot paths. Adapted from
//! /opt/xla-example/load_hlo — HLO *text* is the interchange format (see
//! aot.py's docstring for why).
//!
//! `ExecutorBackend` requires `Send` (the inference engine moves split
//! handles onto worker threads), so this impl compiles only if your
//! xla-rs checkout's client/executable types are `Send`; wrap them in a
//! `Send` owner if they are not. Thread *safety* is not required: this
//! backend keeps the default `split() -> None`, so the engine never
//! shares it across threads and falls back to its sequential sweep.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::runtime::backend::ExecutorBackend;
use crate::runtime::manifest::ArtifactSpec;
use crate::runtime::tensor::HostTensor;

pub struct PjrtBackend {
    client: xla::PjRtClient,
    dir: PathBuf,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl PjrtBackend {
    /// Create the PJRT CPU client. Artifacts compile lazily on first use
    /// and are cached for the process lifetime.
    pub fn new(dir: impl AsRef<Path>) -> Result<PjrtBackend> {
        let client = xla::PjRtClient::cpu().context("PJRT cpu client")?;
        Ok(PjrtBackend {
            client,
            dir: dir.as_ref().to_path_buf(),
            executables: HashMap::new(),
        })
    }
}

impl ExecutorBackend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn prepare(&mut self, spec: &ArtifactSpec) -> Result<()> {
        if self.executables.contains_key(&spec.name) {
            return Ok(());
        }
        let path = self.dir.join(&spec.file);
        let proto =
            xla::HloModuleProto::from_text_file(path.to_str().context("non-utf8 path")?)
                .with_context(|| format!("parsing {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", spec.name))?;
        self.executables.insert(spec.name.clone(), exe);
        Ok(())
    }

    fn execute(&mut self, spec: &ArtifactSpec, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        self.prepare(spec)?;
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<_>>()?;
        let exe = self.executables.get(&spec.name).unwrap();
        let result = exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True: the result is an n-tuple;
        // Runtime::execute validates the arity against the manifest.
        let parts = result.to_tuple()?;
        parts.iter().map(HostTensor::from_literal).collect()
    }
}
