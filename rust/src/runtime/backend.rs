//! The executor-backend seam. `Runtime` — and through it the coordinator,
//! the inference engine and every bench — talks to tensor execution only
//! via [`ExecutorBackend`], so the graph-systems layer is decoupled from
//! any single tensor runtime (the seam industrial stacks like AGL and GiGL
//! cut for the same reason).
//!
//! Two backends ship today: the hermetic pure-Rust
//! [`reference`](crate::runtime::reference) interpreter (always available,
//! zero native dependencies) and the PJRT/XLA artifact executor behind the
//! non-default `pjrt` cargo feature. Future GPU/remote executors plug in
//! here without touching the callers.

use anyhow::Result;

use crate::runtime::manifest::ArtifactSpec;
use crate::runtime::tensor::HostTensor;

pub trait ExecutorBackend {
    /// Short backend id for logs and reports ("reference" | "pjrt").
    fn name(&self) -> &'static str;

    /// Compile or otherwise warm an artifact ahead of its first execution.
    /// Optional; the default is a no-op (the reference backend has nothing
    /// to compile).
    fn prepare(&mut self, _spec: &ArtifactSpec) -> Result<()> {
        Ok(())
    }

    /// Execute one artifact. Inputs arrive pre-validated against the
    /// manifest by [`Runtime::execute`](crate::runtime::Runtime::execute);
    /// implementations must return outputs matching the spec's arity, in
    /// manifest order.
    fn execute(&mut self, spec: &ArtifactSpec, inputs: &[HostTensor]) -> Result<Vec<HostTensor>>;
}
