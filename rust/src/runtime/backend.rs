//! The executor-backend seam. `Runtime` — and through it the coordinator,
//! the inference engine and every bench — talks to tensor execution only
//! via [`ExecutorBackend`], so the graph-systems layer is decoupled from
//! any single tensor runtime (the seam industrial stacks like AGL and GiGL
//! cut for the same reason).
//!
//! Two backends ship today: the hermetic pure-Rust
//! [`reference`](crate::runtime::reference) interpreter (always available,
//! zero native dependencies) and the PJRT/XLA artifact executor behind the
//! non-default `pjrt` cargo feature. Future GPU/remote executors plug in
//! here without touching the callers.

use anyhow::Result;

use crate::runtime::manifest::ArtifactSpec;
use crate::runtime::tensor::HostTensor;

/// Backends are `Send`: the layerwise inference engine moves split
/// handles onto scoped worker threads (one per partition sweep).
pub trait ExecutorBackend: Send {
    /// Short backend id for logs and reports ("reference" | "pjrt").
    fn name(&self) -> &'static str;

    /// Compile or otherwise warm an artifact ahead of its first execution.
    /// Optional; the default is a no-op (the reference backend has nothing
    /// to compile).
    fn prepare(&mut self, _spec: &ArtifactSpec) -> Result<()> {
        Ok(())
    }

    /// Execute one artifact. Inputs arrive pre-validated against the
    /// manifest by [`Runtime::execute`](crate::runtime::Runtime::execute);
    /// implementations must return outputs matching the spec's arity, in
    /// manifest order.
    fn execute(&mut self, spec: &ArtifactSpec, inputs: &[HostTensor]) -> Result<Vec<HostTensor>>;

    /// A second, independently-executing handle to this backend for a
    /// worker thread (mirrors `SamplingClient::split` on the training
    /// side). `None` — the default — means the backend cannot be shared
    /// and callers must fall back to a single-threaded sweep; the
    /// stateless reference interpreter splits freely.
    fn split(&self) -> Option<Box<dyn ExecutorBackend>> {
        None
    }

    /// Whether `execute` accepts a leading ("row") dimension smaller than
    /// the manifest's compiled value for THIS artifact — the tail block
    /// of a chunked sweep. Per-spec because an interpreter may derive row
    /// counts from the tensors for some artifact families while sizing
    /// others from metadata. AOT-compiled backends (fixed-shape
    /// executables) keep the default `false` and get zero-pad + truncate
    /// from [`Runtime::execute_rows`](crate::runtime::Runtime::execute_rows).
    fn supports_dynamic_rows(&self, _spec: &ArtifactSpec) -> bool {
        false
    }
}
