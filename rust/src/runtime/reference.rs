//! Pure-Rust reference backend: executes the artifact families of
//! python/compile (model.py over kernels/ref.py) directly on `HostTensor`,
//! including hand-derived backward passes for the train steps — so the
//! whole crate builds, tests and runs end-to-end with zero native
//! dependencies. PJRT/XLA execution of the AOT artifacts is the opt-in
//! `pjrt` feature; this backend is the hermetic default.
//!
//! Numerics mirror python/compile/kernels/ref.py exactly (masked-mean SAGE
//! aggregation, mean-over-{self}∪neighbors GCN, multi-head GAT attention
//! with a self loop, leaky-relu slope 0.2, log-softmax cross entropy).
//! rust/tests/reference_backend.rs pins single-layer outputs against JAX
//! goldens; the unit tests below check the analytic gradients against
//! finite differences.

#![allow(clippy::too_many_arguments)]

use anyhow::{bail, Context, Result};

use crate::runtime::backend::ExecutorBackend;
use crate::runtime::manifest::ArtifactSpec;
use crate::runtime::tensor::HostTensor;
use crate::util::json::Json;

/// Leaky-relu slope used by the GAT attention scores (jax.nn.leaky_relu
/// default, fixed in kernels/ref.py).
pub const LEAKY_SLOPE: f32 = 0.2;

#[derive(Debug, Default)]
pub struct ReferenceBackend;

impl ExecutorBackend for ReferenceBackend {
    fn name(&self) -> &'static str {
        "reference"
    }

    fn execute(&mut self, spec: &ArtifactSpec, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let name = spec.name.as_str();
        if name.ends_with("_train") {
            run_train(spec, inputs, TrainOutput::UpdatedParams)
        } else if name == "sage_grad" {
            run_train(spec, inputs, TrainOutput::Grads)
        } else if name.ends_with("_eval") {
            run_eval(spec, inputs)
        } else if name.starts_with("sage_infer_layer") {
            run_infer_layer(spec, inputs)
        } else if name == "sage_embed" {
            run_embed(spec, inputs)
        } else if name == "link_decode" {
            run_link_decode(spec, inputs)
        } else {
            bail!("reference backend: no implementation for artifact '{name}'")
        }
    }

    /// The interpreter is stateless — worker threads get fresh instances.
    fn split(&self) -> Option<Box<dyn ExecutorBackend>> {
        Some(Box::new(ReferenceBackend))
    }

    /// Only the row-sliced artifacts (`sage_infer_layer*`, `link_decode`)
    /// derive their row count from the input tensors; the tree-format
    /// handlers still size themselves from metadata and must take the
    /// zero-pad + truncate path.
    fn supports_dynamic_rows(&self, spec: &ArtifactSpec) -> bool {
        spec.name.starts_with("sage_infer_layer") || spec.name == "link_decode"
    }
}

// ---------------------------------------------------------------------------
// Dense f32 helpers (row-major). The `!= 0.0` skips exploit the tree
// format's zero padding rows.
// ---------------------------------------------------------------------------

#[inline]
fn dot(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(&x, &y)| x * y).sum()
}

/// out[n,m] += a[n,k] @ b[k,m]
fn matmul_acc(a: &[f32], b: &[f32], n: usize, k: usize, m: usize, out: &mut [f32]) {
    debug_assert_eq!(a.len(), n * k);
    debug_assert_eq!(b.len(), k * m);
    debug_assert_eq!(out.len(), n * m);
    for i in 0..n {
        let arow = &a[i * k..(i + 1) * k];
        let orow = &mut out[i * m..(i + 1) * m];
        for (p, &av) in arow.iter().enumerate() {
            if av != 0.0 {
                for (o, &bv) in orow.iter_mut().zip(&b[p * m..(p + 1) * m]) {
                    *o += av * bv;
                }
            }
        }
    }
}

fn matmul(a: &[f32], b: &[f32], n: usize, k: usize, m: usize) -> Vec<f32> {
    let mut out = vec![0f32; n * m];
    matmul_acc(a, b, n, k, m, &mut out);
    out
}

/// out[k,m] += a[n,k]^T @ g[n,m]
fn matmul_tn_acc(a: &[f32], g: &[f32], n: usize, k: usize, m: usize, out: &mut [f32]) {
    debug_assert_eq!(a.len(), n * k);
    debug_assert_eq!(g.len(), n * m);
    debug_assert_eq!(out.len(), k * m);
    for i in 0..n {
        let arow = &a[i * k..(i + 1) * k];
        let grow = &g[i * m..(i + 1) * m];
        for (p, &av) in arow.iter().enumerate() {
            if av != 0.0 {
                for (o, &gv) in out[p * m..(p + 1) * m].iter_mut().zip(grow) {
                    *o += av * gv;
                }
            }
        }
    }
}

/// out[n,k] += g[n,m] @ w[k,m]^T
fn matmul_nt_acc(g: &[f32], w: &[f32], n: usize, m: usize, k: usize, out: &mut [f32]) {
    debug_assert_eq!(g.len(), n * m);
    debug_assert_eq!(w.len(), k * m);
    debug_assert_eq!(out.len(), n * k);
    for i in 0..n {
        let grow = &g[i * m..(i + 1) * m];
        for (p, o) in out[i * k..(i + 1) * k].iter_mut().enumerate() {
            *o += dot(grow, &w[p * m..(p + 1) * m]);
        }
    }
}

/// z[n,m] += b[m] broadcast over rows.
fn add_bias(z: &mut [f32], b: &[f32], n: usize, m: usize) {
    for i in 0..n {
        for (zv, &bv) in z[i * m..(i + 1) * m].iter_mut().zip(b) {
            *zv += bv;
        }
    }
}

/// out[m] += column sums of g[n,m].
fn colsum_acc(g: &[f32], n: usize, m: usize, out: &mut [f32]) {
    for i in 0..n {
        for (o, &gv) in out.iter_mut().zip(&g[i * m..(i + 1) * m]) {
            *o += gv;
        }
    }
}

fn linear(x: &[f32], w: &[f32], b: &[f32], n: usize, k: usize, m: usize) -> Vec<f32> {
    let mut z = matmul(x, w, n, k, m);
    add_bias(&mut z, b, n, m);
    z
}

#[inline]
fn lrelu(x: f32) -> f32 {
    if x >= 0.0 {
        x
    } else {
        LEAKY_SLOPE * x
    }
}

#[inline]
fn lrelu_grad(x: f32) -> f32 {
    if x >= 0.0 {
        1.0
    } else {
        LEAKY_SLOPE
    }
}

// ---------------------------------------------------------------------------
// Layer primitives. Forwards are `pub` — they define the numeric contract
// the parity tests pin against JAX.
// ---------------------------------------------------------------------------

/// GraphSAGE-mean aggregation + dual projection (kernels/ref.py
/// sage_agg_ref): `z = h_self @ W_s + masked_mean(h_neigh) @ W_n + b`.
/// Returns `(z, agg, cnt)`; `agg`/`cnt` feed the backward pass.
pub fn sage_layer_forward(
    h_self: &[f32],
    h_neigh: &[f32],
    mask: &[f32],
    w_self: &[f32],
    w_neigh: &[f32],
    b: &[f32],
    n: usize,
    f: usize,
    d_in: usize,
    d_out: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut agg = vec![0f32; n * d_in];
    let mut cnt = vec![0f32; n];
    for i in 0..n {
        let mut c = 0f32;
        let ai = &mut agg[i * d_in..(i + 1) * d_in];
        for s in 0..f {
            let m = mask[i * f + s];
            if m != 0.0 {
                c += m;
                let nb = &h_neigh[(i * f + s) * d_in..(i * f + s + 1) * d_in];
                for (a, &x) in ai.iter_mut().zip(nb) {
                    *a += m * x;
                }
            }
        }
        let c = c.max(1.0);
        cnt[i] = c;
        for a in ai.iter_mut() {
            *a /= c;
        }
    }
    let mut z = matmul(h_self, w_self, n, d_in, d_out);
    matmul_acc(&agg, w_neigh, n, d_in, d_out, &mut z);
    add_bias(&mut z, b, n, d_out);
    (z, agg, cnt)
}

fn sage_layer_backward(
    dz: &[f32],
    h_self: &[f32],
    mask: &[f32],
    w_self: &[f32],
    w_neigh: &[f32],
    agg: &[f32],
    cnt: &[f32],
    n: usize,
    f: usize,
    d_in: usize,
    d_out: usize,
    gw_self: &mut [f32],
    gw_neigh: &mut [f32],
    gb: &mut [f32],
    d_self: &mut [f32],
    d_neigh: &mut [f32],
) {
    colsum_acc(dz, n, d_out, gb);
    matmul_tn_acc(h_self, dz, n, d_in, d_out, gw_self);
    matmul_tn_acc(agg, dz, n, d_in, d_out, gw_neigh);
    matmul_nt_acc(dz, w_self, n, d_out, d_in, d_self);
    let mut dagg = vec![0f32; n * d_in];
    matmul_nt_acc(dz, w_neigh, n, d_out, d_in, &mut dagg);
    for i in 0..n {
        let da = &dagg[i * d_in..(i + 1) * d_in];
        for s in 0..f {
            let m = mask[i * f + s];
            if m != 0.0 {
                let scale = m / cnt[i];
                let dn = &mut d_neigh[(i * f + s) * d_in..(i * f + s + 1) * d_in];
                for (o, &x) in dn.iter_mut().zip(da) {
                    *o += scale * x;
                }
            }
        }
    }
}

/// GCN-style aggregation (kernels/ref.py gcn_agg_ref): mean over
/// {self} ∪ masked neighbors, then project. Returns `(z, sb, cnt)` where
/// `sb` is the normalized sum feeding the projection.
pub fn gcn_layer_forward(
    h_self: &[f32],
    h_neigh: &[f32],
    mask: &[f32],
    w: &[f32],
    b: &[f32],
    n: usize,
    f: usize,
    d_in: usize,
    d_out: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut sb = vec![0f32; n * d_in];
    let mut cnt = vec![0f32; n];
    for i in 0..n {
        let si = &mut sb[i * d_in..(i + 1) * d_in];
        si.copy_from_slice(&h_self[i * d_in..(i + 1) * d_in]);
        let mut c = 1f32;
        for s in 0..f {
            let m = mask[i * f + s];
            if m != 0.0 {
                c += m;
                let nb = &h_neigh[(i * f + s) * d_in..(i * f + s + 1) * d_in];
                for (a, &x) in si.iter_mut().zip(nb) {
                    *a += m * x;
                }
            }
        }
        cnt[i] = c;
        for a in si.iter_mut() {
            *a /= c;
        }
    }
    let mut z = matmul(&sb, w, n, d_in, d_out);
    add_bias(&mut z, b, n, d_out);
    (z, sb, cnt)
}

fn gcn_layer_backward(
    dz: &[f32],
    mask: &[f32],
    w: &[f32],
    sb: &[f32],
    cnt: &[f32],
    n: usize,
    f: usize,
    d_in: usize,
    d_out: usize,
    gw: &mut [f32],
    gb: &mut [f32],
    d_self: &mut [f32],
    d_neigh: &mut [f32],
) {
    colsum_acc(dz, n, d_out, gb);
    matmul_tn_acc(sb, dz, n, d_in, d_out, gw);
    let mut ds = vec![0f32; n * d_in];
    matmul_nt_acc(dz, w, n, d_out, d_in, &mut ds);
    for i in 0..n {
        let c = cnt[i];
        for v in ds[i * d_in..(i + 1) * d_in].iter_mut() {
            *v /= c;
        }
    }
    for i in 0..n {
        let di = &ds[i * d_in..(i + 1) * d_in];
        for (o, &x) in d_self[i * d_in..(i + 1) * d_in].iter_mut().zip(di) {
            *o += x;
        }
        for s in 0..f {
            let m = mask[i * f + s];
            if m != 0.0 {
                let dn = &mut d_neigh[(i * f + s) * d_in..(i * f + s + 1) * d_in];
                for (o, &x) in dn.iter_mut().zip(di) {
                    *o += m * x;
                }
            }
        }
    }
}

/// Backward-pass cache of one multi-head GAT layer application.
pub struct GatCache {
    hw_self: Vec<f32>,   // [n, H]
    hw_neigh: Vec<f32>,  // [n*f, H]
    alpha: Vec<f32>,     // [heads][n][1+f]
    raw_loop: Vec<f32>,  // [heads][n]
    raw_nbr: Vec<f32>,   // [heads][n][f]
}

/// Multi-head GAT layer over a fanout block (model._gat_layer over
/// kernels/ref.py gat_attn_ref): per head, leaky-relu attention scores
/// over {self-loop} ∪ masked neighbors, softmax, convex combination of the
/// W-projected features; heads are concatenated and the bias added.
pub fn gat_layer_forward(
    h_self: &[f32],
    h_neigh: &[f32],
    mask: &[f32],
    w: &[f32],
    a_self: &[f32],
    a_neigh: &[f32],
    b: &[f32],
    n: usize,
    f: usize,
    d_in: usize,
    d_out: usize,
    heads: usize,
) -> (Vec<f32>, GatCache) {
    let hd = d_out / heads;
    let hw_self = matmul(h_self, w, n, d_in, d_out);
    let hw_neigh = matmul(h_neigh, w, n * f, d_in, d_out);
    let mut z = vec![0f32; n * d_out];
    let mut alpha = vec![0f32; heads * n * (1 + f)];
    let mut raw_loop = vec![0f32; heads * n];
    let mut raw_nbr = vec![0f32; heads * n * f];
    let mut e = vec![0f32; 1 + f];
    for h in 0..heads {
        let a_s = &a_self[h * hd..(h + 1) * hd];
        let a_n = &a_neigh[h * hd..(h + 1) * hd];
        for i in 0..n {
            let hs = &hw_self[i * d_out + h * hd..][..hd];
            let es = dot(hs, a_s);
            let rl = es + dot(hs, a_n);
            raw_loop[h * n + i] = rl;
            e[0] = lrelu(rl);
            for s in 0..f {
                let hn = &hw_neigh[(i * f + s) * d_out + h * hd..][..hd];
                let raw = es + dot(hn, a_n);
                raw_nbr[(h * n + i) * f + s] = raw;
                e[1 + s] = if mask[i * f + s] > 0.0 {
                    lrelu(raw)
                } else {
                    f32::MIN
                };
            }
            let mx = e.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let arow = &mut alpha[(h * n + i) * (1 + f)..(h * n + i + 1) * (1 + f)];
            let mut sum = 0f32;
            for (a, &x) in arow.iter_mut().zip(e.iter()) {
                let v = (x - mx).exp();
                *a = v;
                sum += v;
            }
            for a in arow.iter_mut() {
                *a /= sum;
            }
            let zi = &mut z[i * d_out + h * hd..][..hd];
            for (d, zv) in zi.iter_mut().enumerate() {
                *zv = arow[0] * hs[d];
            }
            for s in 0..f {
                let al = arow[1 + s];
                if al != 0.0 {
                    let hn = &hw_neigh[(i * f + s) * d_out + h * hd..][..hd];
                    for (zv, &hv) in zi.iter_mut().zip(hn) {
                        *zv += al * hv;
                    }
                }
            }
        }
    }
    add_bias(&mut z, b, n, d_out);
    (
        z,
        GatCache {
            hw_self,
            hw_neigh,
            alpha,
            raw_loop,
            raw_nbr,
        },
    )
}

fn gat_layer_backward(
    dz: &[f32],
    h_self: &[f32],
    h_neigh: &[f32],
    mask: &[f32],
    w: &[f32],
    a_self: &[f32],
    a_neigh: &[f32],
    cache: &GatCache,
    n: usize,
    f: usize,
    d_in: usize,
    d_out: usize,
    heads: usize,
    gw: &mut [f32],
    ga_self: &mut [f32],
    ga_neigh: &mut [f32],
    gb: &mut [f32],
    d_self: &mut [f32],
    d_neigh: &mut [f32],
) {
    let hd = d_out / heads;
    colsum_acc(dz, n, d_out, gb);
    let mut dhw_self = vec![0f32; n * d_out];
    let mut dhw_neigh = vec![0f32; n * f * d_out];
    let mut dalpha = vec![0f32; 1 + f];
    for h in 0..heads {
        let a_s = &a_self[h * hd..(h + 1) * hd];
        let a_n = &a_neigh[h * hd..(h + 1) * hd];
        for i in 0..n {
            let g = &dz[i * d_out + h * hd..][..hd];
            let hs = &cache.hw_self[i * d_out + h * hd..][..hd];
            let arow = &cache.alpha[(h * n + i) * (1 + f)..(h * n + i + 1) * (1 + f)];
            dalpha[0] = dot(g, hs);
            for s in 0..f {
                let hn = &cache.hw_neigh[(i * f + s) * d_out + h * hd..][..hd];
                dalpha[1 + s] = dot(g, hn);
            }
            let mut ssum = 0f32;
            for (a, da) in arow.iter().zip(dalpha.iter()) {
                ssum += a * da;
            }
            // Self-loop score path.
            let dr0 = arow[0] * (dalpha[0] - ssum) * lrelu_grad(cache.raw_loop[h * n + i]);
            let mut des = dr0;
            {
                let ds_row = &mut dhw_self[i * d_out + h * hd..][..hd];
                for d in 0..hd {
                    ds_row[d] += arow[0] * g[d] + dr0 * a_n[d];
                    ga_neigh[h * hd + d] += dr0 * hs[d];
                }
            }
            // Neighbor score paths (masked entries have alpha == 0 exactly).
            for s in 0..f {
                if mask[i * f + s] == 0.0 {
                    continue;
                }
                let de = arow[1 + s] * (dalpha[1 + s] - ssum);
                let dr = de * lrelu_grad(cache.raw_nbr[(h * n + i) * f + s]);
                des += dr;
                let hn = &cache.hw_neigh[(i * f + s) * d_out + h * hd..][..hd];
                let dn_row = &mut dhw_neigh[(i * f + s) * d_out + h * hd..][..hd];
                for d in 0..hd {
                    dn_row[d] += arow[1 + s] * g[d] + dr * a_n[d];
                    ga_neigh[h * hd + d] += dr * hn[d];
                }
            }
            // Shared e_self contribution.
            let ds_row = &mut dhw_self[i * d_out + h * hd..][..hd];
            for d in 0..hd {
                ds_row[d] += des * a_s[d];
                ga_self[h * hd + d] += des * hs[d];
            }
        }
    }
    matmul_tn_acc(h_self, &dhw_self, n, d_in, d_out, gw);
    matmul_tn_acc(h_neigh, &dhw_neigh, n * f, d_in, d_out, gw);
    matmul_nt_acc(&dhw_self, w, n, d_out, d_in, d_self);
    matmul_nt_acc(&dhw_neigh, w, n * f, d_out, d_in, d_neigh);
}

/// Edge-score decoder (model.link_decode):
/// `sigmoid(relu([u‖v]·W1 + b1)·w2 + b2)`.
pub fn link_decode_forward(
    emb_u: &[f32],
    emb_v: &[f32],
    w1: &[f32],
    b1: &[f32],
    w2: &[f32],
    b2: &[f32],
    batch: usize,
    hidden: usize,
) -> Vec<f32> {
    let h = hidden;
    let mut x = vec![0f32; batch * 2 * h];
    for i in 0..batch {
        x[i * 2 * h..i * 2 * h + h].copy_from_slice(&emb_u[i * h..(i + 1) * h]);
        x[i * 2 * h + h..(i + 1) * 2 * h].copy_from_slice(&emb_v[i * h..(i + 1) * h]);
    }
    let mut hdn = linear(&x, w1, b1, batch, 2 * h, h);
    for v in hdn.iter_mut() {
        *v = v.max(0.0);
    }
    let mut s = linear(&hdn, w2, b2, batch, h, 1);
    for v in s.iter_mut() {
        *v = 1.0 / (1.0 + (-*v).exp());
    }
    s
}

/// Mean log-softmax cross entropy and its logits gradient (model.
/// cross_entropy). `logits` is `[batch, classes]` row-major.
pub fn cross_entropy_with_grad(
    logits: &[f32],
    labels: &[i32],
    classes: usize,
) -> Result<(f32, Vec<f32>)> {
    let b = labels.len();
    anyhow::ensure!(b > 0 && logits.len() == b * classes, "bad logits shape");
    let mut dlogits = vec![0f32; b * classes];
    let mut loss = 0f32;
    for i in 0..b {
        let row = &logits[i * classes..(i + 1) * classes];
        let lab = labels[i];
        anyhow::ensure!(
            lab >= 0 && (lab as usize) < classes,
            "label {lab} out of range for {classes} classes"
        );
        let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0f32;
        for &x in row {
            sum += (x - mx).exp();
        }
        loss += mx + sum.ln() - row[lab as usize];
        let drow = &mut dlogits[i * classes..(i + 1) * classes];
        for (c, (d, &x)) in drow.iter_mut().zip(row).enumerate() {
            let p = (x - mx).exp() / sum;
            *d = (p - if c == lab as usize { 1.0 } else { 0.0 }) / b as f32;
        }
    }
    Ok((loss / b as f32, dlogits))
}

// ---------------------------------------------------------------------------
// Tree-format model execution (model.forward / train_step / grad_step).
// ---------------------------------------------------------------------------

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
enum Kind {
    Sage,
    Gcn,
    Gat,
}

impl Kind {
    fn parse(s: &str) -> Result<Kind> {
        Ok(match s {
            "sage" => Kind::Sage,
            "gcn" => Kind::Gcn,
            "gat" => Kind::Gat,
            other => bail!("unknown model kind '{other}'"),
        })
    }

    /// Parameter tensors per layer.
    fn npl(self) -> usize {
        match self {
            Kind::Sage => 3,
            Kind::Gcn => 2,
            Kind::Gat => 4,
        }
    }
}

/// Static geometry of one tree-format artifact, decoded from its manifest
/// entry.
struct Geom {
    kind: Kind,
    din: usize,
    hidden: usize,
    classes: usize,
    batch: usize,
    fanouts: Vec<usize>,
    n_params: usize,
    heads: usize,
    /// Level sizes: sizes[0] = batch, sizes[k] = sizes[k-1] * fanouts[k-1].
    sizes: Vec<usize>,
}

impl Geom {
    fn from_spec(spec: &ArtifactSpec) -> Result<Geom> {
        let kind = Kind::parse(
            spec.meta
                .get("kind")
                .and_then(Json::as_str)
                .unwrap_or("sage"),
        )?;
        let fanouts = spec.meta_usizes("fanouts").context("meta.fanouts")?;
        let batch = spec.meta_usize("batch").context("meta.batch")?;
        let din = spec.meta_usize("din").context("meta.din")?;
        let hidden = spec.meta_usize("hidden").context("meta.hidden")?;
        let classes = spec.meta_usize("classes").unwrap_or(0);
        let k = fanouts.len();
        // Embed artifacts carry no n_params meta; everything that is not a
        // level input is a parameter.
        let n_params = spec
            .meta_usize("n_params")
            .unwrap_or_else(|| spec.inputs.len().saturating_sub(2 * k + 1));
        anyhow::ensure!(
            n_params >= 2 && spec.inputs.len() >= n_params + 2 * k + 1,
            "{}: inconsistent manifest arity",
            spec.name
        );
        let heads = if kind == Kind::Gat {
            *spec.inputs[1]
                .shape
                .first()
                .context("gat a_self param shape")?
        } else {
            1
        };
        anyhow::ensure!(
            kind != Kind::Gat || (heads > 0 && hidden % heads == 0),
            "gat hidden {hidden} not divisible by heads {heads}"
        );
        let mut sizes = vec![batch];
        for &f in &fanouts {
            sizes.push(sizes.last().unwrap() * f);
        }
        Ok(Geom {
            kind,
            din,
            hidden,
            classes,
            batch,
            fanouts,
            n_params,
            heads,
            sizes,
        })
    }

    fn d_in(&self, layer: usize) -> usize {
        if layer == 0 {
            self.din
        } else {
            self.hidden
        }
    }
}

enum Aux {
    Sage { agg: Vec<f32>, cnt: Vec<f32> },
    Gcn { sb: Vec<f32>, cnt: Vec<f32> },
    Gat(Box<GatCache>),
}

struct LevelCache {
    /// Pre-activation output; kept only where relu applies on top
    /// (non-final layers), empty otherwise.
    z: Vec<f32>,
    aux: Aux,
}

struct TreeForward<'a> {
    /// Level features entering layer 0 (borrowed from the inputs).
    xs: &'a [&'a [f32]],
    /// acts[j-1] = activations entering layer j (j >= 1);
    /// acts[K-1][0] = the final seed embedding.
    acts: Vec<Vec<Vec<f32>>>,
    caches: Vec<Vec<LevelCache>>,
}

impl TreeForward<'_> {
    /// Activations entering layer `j` at `lvl` (layer 0 reads the inputs).
    fn act(&self, j: usize, lvl: usize) -> &[f32] {
        if j == 0 {
            self.xs[lvl]
        } else {
            &self.acts[j - 1][lvl]
        }
    }

    /// Final seed embedding after all `k_layers` layers.
    fn h_final(&self, k_layers: usize) -> &[f32] {
        self.act(k_layers, 0)
    }
}

fn tree_forward<'a>(
    geom: &Geom,
    params: &[&[f32]],
    xs: &'a [&'a [f32]],
    masks: &[&[f32]],
) -> TreeForward<'a> {
    let k_layers = geom.fanouts.len();
    let npl = geom.kind.npl();
    let mut fwd = TreeForward {
        xs,
        acts: Vec::with_capacity(k_layers),
        caches: Vec::with_capacity(k_layers),
    };
    for j in 0..k_layers {
        let d_in = geom.d_in(j);
        let d_out = geom.hidden;
        let lp = &params[j * npl..(j + 1) * npl];
        let depth = k_layers - j;
        let mut new_acts = Vec::with_capacity(depth);
        let mut lvl_caches = Vec::with_capacity(depth);
        for lvl in 0..depth {
            let n = geom.sizes[lvl];
            let f = geom.fanouts[lvl];
            let h_self = fwd.act(j, lvl);
            let h_neigh = fwd.act(j, lvl + 1);
            let mask = masks[lvl];
            let (z, aux) = match geom.kind {
                Kind::Sage => {
                    let (z, agg, cnt) = sage_layer_forward(
                        h_self, h_neigh, mask, lp[0], lp[1], lp[2], n, f, d_in, d_out,
                    );
                    (z, Aux::Sage { agg, cnt })
                }
                Kind::Gcn => {
                    let (z, sb, cnt) =
                        gcn_layer_forward(h_self, h_neigh, mask, lp[0], lp[1], n, f, d_in, d_out);
                    (z, Aux::Gcn { sb, cnt })
                }
                Kind::Gat => {
                    let (z, cache) = gat_layer_forward(
                        h_self, h_neigh, mask, lp[0], lp[1], lp[2], lp[3], n, f, d_in, d_out,
                        geom.heads,
                    );
                    (z, Aux::Gat(Box::new(cache)))
                }
            };
            // relu applies between layers; the final layer's output is the
            // activation itself, so its pre-activation need not be kept.
            let (act, z_keep): (Vec<f32>, Vec<f32>) = if j < k_layers - 1 {
                (z.iter().map(|&x| x.max(0.0)).collect(), z)
            } else {
                (z, Vec::new())
            };
            lvl_caches.push(LevelCache { z: z_keep, aux });
            new_acts.push(act);
        }
        fwd.acts.push(new_acts);
        fwd.caches.push(lvl_caches);
    }
    fwd
}

/// Backprop through the tree: consumes the gradient on the final seed
/// embedding, accumulates parameter gradients into `grads` (aligned with
/// `params`).
fn tree_backward(
    geom: &Geom,
    params: &[&[f32]],
    fwd: &TreeForward<'_>,
    masks: &[&[f32]],
    d_h_final: Vec<f32>,
    grads: &mut [Vec<f32>],
) {
    let k_layers = geom.fanouts.len();
    let npl = geom.kind.npl();
    let mut d_levels: Vec<Vec<f32>> = vec![d_h_final];
    for j in (0..k_layers).rev() {
        let d_in = geom.d_in(j);
        let d_out = geom.hidden;
        let lp = &params[j * npl..(j + 1) * npl];
        let depth = k_layers - j;
        let mut d_prev: Vec<Vec<f32>> = (0..=depth)
            .map(|lvl| vec![0f32; geom.sizes[lvl] * d_in])
            .collect();
        for lvl in 0..depth {
            let n = geom.sizes[lvl];
            let f = geom.fanouts[lvl];
            let mut dz = std::mem::take(&mut d_levels[lvl]);
            let cache = &fwd.caches[j][lvl];
            if j < k_layers - 1 {
                // relu backward against the stored pre-activation.
                for (d, &zv) in dz.iter_mut().zip(&cache.z) {
                    if zv <= 0.0 {
                        *d = 0.0;
                    }
                }
            }
            let h_self = fwd.act(j, lvl);
            let h_neigh = fwd.act(j, lvl + 1);
            let mask = masks[lvl];
            let (head, tail) = d_prev.split_at_mut(lvl + 1);
            let d_self = head[lvl].as_mut_slice();
            let d_neigh = tail[0].as_mut_slice();
            let base = j * npl;
            match &cache.aux {
                Aux::Sage { agg, cnt } => {
                    let [gw_self, gw_neigh, gb] = &mut grads[base..base + 3] else {
                        unreachable!("sage layer has 3 param tensors")
                    };
                    sage_layer_backward(
                        &dz, h_self, mask, lp[0], lp[1], agg, cnt, n, f, d_in, d_out, gw_self,
                        gw_neigh, gb, d_self, d_neigh,
                    );
                }
                Aux::Gcn { sb, cnt } => {
                    let [gw, gb] = &mut grads[base..base + 2] else {
                        unreachable!("gcn layer has 2 param tensors")
                    };
                    gcn_layer_backward(
                        &dz, mask, lp[0], sb, cnt, n, f, d_in, d_out, gw, gb, d_self, d_neigh,
                    );
                }
                Aux::Gat(cache) => {
                    let [gw, ga_self, ga_neigh, gb] = &mut grads[base..base + 4] else {
                        unreachable!("gat layer has 4 param tensors")
                    };
                    gat_layer_backward(
                        &dz,
                        h_self,
                        h_neigh,
                        mask,
                        lp[0],
                        lp[1],
                        lp[2],
                        cache,
                        n,
                        f,
                        d_in,
                        d_out,
                        geom.heads,
                        gw,
                        ga_self,
                        ga_neigh,
                        gb,
                        d_self,
                        d_neigh,
                    );
                }
            }
        }
        d_levels = d_prev;
    }
}

// ---------------------------------------------------------------------------
// Artifact entry points.
// ---------------------------------------------------------------------------

enum TrainOutput {
    /// `(loss, params - lr * grads)` — the `{kind}_train` artifacts.
    UpdatedParams,
    /// `(loss, grads)` — the `sage_grad` artifact.
    Grads,
}

fn split_tree_inputs<'a>(
    geom: &Geom,
    inputs: &'a [HostTensor],
) -> (Vec<&'a [f32]>, Vec<&'a [f32]>, Vec<&'a [f32]>) {
    let np = geom.n_params;
    let k = geom.fanouts.len();
    let params = inputs[..np].iter().map(HostTensor::as_f32).collect();
    let xs = inputs[np..np + k + 1].iter().map(HostTensor::as_f32).collect();
    let masks = inputs[np + k + 1..np + 2 * k + 1]
        .iter()
        .map(HostTensor::as_f32)
        .collect();
    (params, xs, masks)
}

fn run_train(
    spec: &ArtifactSpec,
    inputs: &[HostTensor],
    output: TrainOutput,
) -> Result<Vec<HostTensor>> {
    let geom = Geom::from_spec(spec)?;
    let np = geom.n_params;
    let k = geom.fanouts.len();
    let (params, xs, masks) = split_tree_inputs(&geom, inputs);
    let labels = inputs[np + 2 * k + 1].as_i32();
    let lr = match output {
        TrainOutput::UpdatedParams => Some(inputs[np + 2 * k + 2].as_f32()[0]),
        TrainOutput::Grads => None,
    };

    let fwd = tree_forward(&geom, &params, &xs, &masks);
    let h0 = fwd.h_final(k);
    let head_w = params[np - 2];
    let head_b = params[np - 1];
    let logits = linear(h0, head_w, head_b, geom.batch, geom.hidden, geom.classes);
    let (loss, dlogits) = cross_entropy_with_grad(&logits, labels, geom.classes)?;

    let mut grads: Vec<Vec<f32>> = params.iter().map(|p| vec![0f32; p.len()]).collect();
    matmul_tn_acc(
        h0,
        &dlogits,
        geom.batch,
        geom.hidden,
        geom.classes,
        &mut grads[np - 2],
    );
    colsum_acc(&dlogits, geom.batch, geom.classes, &mut grads[np - 1]);
    let mut d_h0 = vec![0f32; geom.batch * geom.hidden];
    matmul_nt_acc(
        &dlogits,
        head_w,
        geom.batch,
        geom.classes,
        geom.hidden,
        &mut d_h0,
    );
    tree_backward(&geom, &params, &fwd, &masks, d_h0, &mut grads);

    let mut out = vec![HostTensor::f32(vec![1], vec![loss])];
    for (i, g) in grads.into_iter().enumerate() {
        let shape = spec.inputs[i].shape.clone();
        let tensor = match lr {
            Some(lr) => HostTensor::f32(
                shape,
                params[i].iter().zip(&g).map(|(&p, &gv)| p - lr * gv).collect(),
            ),
            None => HostTensor::f32(shape, g),
        };
        out.push(tensor);
    }
    Ok(out)
}

fn run_eval(spec: &ArtifactSpec, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
    let geom = Geom::from_spec(spec)?;
    let k = geom.fanouts.len();
    let (params, xs, masks) = split_tree_inputs(&geom, inputs);
    let fwd = tree_forward(&geom, &params, &xs, &masks);
    let h0 = fwd.h_final(k);
    let logits = linear(
        h0,
        params[geom.n_params - 2],
        params[geom.n_params - 1],
        geom.batch,
        geom.hidden,
        geom.classes,
    );
    Ok(vec![HostTensor::f32(
        vec![geom.batch, geom.classes],
        logits,
    )])
}

fn run_embed(spec: &ArtifactSpec, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
    let geom = Geom::from_spec(spec)?;
    let k = geom.fanouts.len();
    let (params, xs, masks) = split_tree_inputs(&geom, inputs);
    let fwd = tree_forward(&geom, &params, &xs, &masks);
    Ok(vec![HostTensor::f32(
        vec![geom.batch, geom.hidden],
        fwd.h_final(k).to_vec(),
    )])
}

fn run_infer_layer(spec: &ArtifactSpec, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
    // Rows come from the h_self tensor, not meta.chunk: tail blocks of a
    // sweep arrive with fewer rows than the manifest's block size.
    let n = *inputs[0].shape().first().context("h_self rank")?;
    let f = spec.meta_usize("fanout").context("meta.fanout")?;
    let d_in = spec.meta_usize("din").context("meta.din")?;
    let d_out = spec.meta_usize("dout").context("meta.dout")?;
    let relu = spec
        .meta
        .get("relu")
        .and_then(Json::as_bool)
        .unwrap_or(false);
    let (mut z, _, _) = sage_layer_forward(
        inputs[0].as_f32(),
        inputs[1].as_f32(),
        inputs[2].as_f32(),
        inputs[3].as_f32(),
        inputs[4].as_f32(),
        inputs[5].as_f32(),
        n,
        f,
        d_in,
        d_out,
    );
    if relu {
        for v in z.iter_mut() {
            *v = v.max(0.0);
        }
    }
    Ok(vec![HostTensor::f32(vec![n, d_out], z)])
}

fn run_link_decode(spec: &ArtifactSpec, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
    // Rows come from the emb_u tensor (see run_infer_layer).
    let batch = *inputs[0].shape().first().context("emb_u rank")?;
    let hidden = spec.meta_usize("hidden").context("meta.hidden")?;
    let scores = link_decode_forward(
        inputs[0].as_f32(),
        inputs[1].as_f32(),
        inputs[2].as_f32(),
        inputs[3].as_f32(),
        inputs[4].as_f32(),
        inputs[5].as_f32(),
        batch,
        hidden,
    );
    Ok(vec![HostTensor::f32(vec![batch], scores)])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::{Manifest, TensorSpec};
    use crate::runtime::tensor::DType;

    /// Deterministic exact-in-f32 test values, shared with the JAX golden
    /// generator (tests/reference_backend.rs uses the same formula).
    fn val(i: usize) -> f32 {
        ((i * i + 3 * i) % 11) as f32 * 0.125 - 0.5
    }

    fn fill(base: usize, n: usize) -> Vec<f32> {
        (0..n).map(|k| val(base + k)).collect()
    }

    /// A miniature train artifact (din=3, hidden=4, classes=2, batch=2,
    /// fanouts=[2,2], heads=2) exercising the full tree backward cheaply.
    fn tiny_train_spec(kind: &str) -> ArtifactSpec {
        let (din, hidden, classes, batch) = (3usize, 4usize, 2usize, 2usize);
        let fanouts = [2usize, 2];
        let f = |name: &str, shape: &[usize]| TensorSpec {
            name: name.into(),
            shape: shape.to_vec(),
            dtype: DType::F32,
        };
        let mut inputs = Vec::new();
        let mut d_in = din;
        for j in 0..fanouts.len() {
            match kind {
                "sage" => {
                    inputs.push(f(&format!("l{j}_w_self"), &[d_in, hidden]));
                    inputs.push(f(&format!("l{j}_w_neigh"), &[d_in, hidden]));
                    inputs.push(f(&format!("l{j}_b"), &[hidden]));
                }
                "gcn" => {
                    inputs.push(f(&format!("l{j}_w"), &[d_in, hidden]));
                    inputs.push(f(&format!("l{j}_b"), &[hidden]));
                }
                "gat" => {
                    inputs.push(f(&format!("l{j}_w"), &[d_in, hidden]));
                    inputs.push(f(&format!("l{j}_a_self"), &[2, hidden / 2]));
                    inputs.push(f(&format!("l{j}_a_neigh"), &[2, hidden / 2]));
                    inputs.push(f(&format!("l{j}_b"), &[hidden]));
                }
                other => panic!("kind {other}"),
            }
            d_in = hidden;
        }
        inputs.push(f("head_w", &[hidden, classes]));
        inputs.push(f("head_b", &[classes]));
        let n_params = inputs.len();
        let sizes = [batch, batch * 2, batch * 4];
        for (k, &n) in sizes.iter().enumerate() {
            inputs.push(f(&format!("x{k}"), &[n, din]));
        }
        inputs.push(f("mask1", &[sizes[1]]));
        inputs.push(f("mask2", &[sizes[2]]));
        inputs.push(TensorSpec {
            name: "labels".into(),
            shape: vec![batch],
            dtype: DType::I32,
        });
        inputs.push(f("lr", &[1]));
        let mut outputs = vec![f("loss", &[1])];
        outputs.extend(inputs[..n_params].to_vec());
        ArtifactSpec {
            name: format!("{kind}_train"),
            file: String::new(),
            inputs,
            outputs,
            meta: Json::parse(&format!(
                r#"{{"kind":"{kind}","din":{din},"hidden":{hidden},"classes":{classes},"batch":{batch},"fanouts":[2,2],"n_params":{n_params}}}"#
            ))
            .unwrap(),
        }
    }

    fn tiny_inputs(spec: &ArtifactSpec) -> Vec<HostTensor> {
        let mut out = Vec::new();
        for (i, s) in spec.inputs.iter().enumerate() {
            let n: usize = s.shape.iter().product();
            let t = match s.name.as_str() {
                "mask1" => HostTensor::f32(s.shape.clone(), vec![0.0, 0.0, 1.0, 1.0]),
                "mask2" => {
                    HostTensor::f32(s.shape.clone(), vec![1.0, 1.0, 0.0, 0.0, 1.0, 0.0, 1.0, 1.0])
                }
                "labels" => HostTensor::i32(s.shape.clone(), vec![1, 0]),
                "lr" => HostTensor::f32(s.shape.clone(), vec![1.0]),
                _ => HostTensor::f32(s.shape.clone(), fill(i * 37 + 5, n)),
            };
            out.push(t);
        }
        out
    }

    fn loss_of(spec: &ArtifactSpec, inputs: &[HostTensor]) -> f32 {
        let mut be = ReferenceBackend;
        be.execute(spec, inputs).unwrap()[0].as_f32()[0]
    }

    fn set_elem(t: &mut HostTensor, idx: usize, v: f32) {
        match t {
            HostTensor::F32 { data, .. } => data[idx] = v,
            HostTensor::I32 { .. } => panic!("not f32"),
        }
    }

    #[test]
    fn train_gradients_match_finite_differences() {
        for kind in ["sage", "gcn", "gat"] {
            let spec = tiny_train_spec(kind);
            let n_params = spec.meta_usize("n_params").unwrap();
            let mut inputs = tiny_inputs(&spec);
            let out = ReferenceBackend.execute(&spec, &inputs).unwrap();
            assert_eq!(out.len(), 1 + n_params);
            // lr == 1, so the analytic gradient is p - p_new.
            let check: Vec<(usize, usize)> = vec![
                (0, 1),          // first layer weight
                (n_params - 2, 0), // head weight
                (n_params - 1, 1), // head bias
            ];
            for (pidx, elem) in check {
                let p0 = inputs[pidx].as_f32()[elem];
                let analytic = p0 - out[1 + pidx].as_f32()[elem];
                let eps = 1e-2f32;
                set_elem(&mut inputs[pidx], elem, p0 + eps);
                let lp = loss_of(&spec, &inputs);
                set_elem(&mut inputs[pidx], elem, p0 - eps);
                let lm = loss_of(&spec, &inputs);
                set_elem(&mut inputs[pidx], elem, p0);
                let fd = (lp - lm) / (2.0 * eps);
                assert!(
                    (fd - analytic).abs() <= 2e-3 + 0.1 * analytic.abs().max(fd.abs()),
                    "{kind} param {pidx}[{elem}]: fd {fd} vs analytic {analytic}"
                );
            }
        }
    }

    #[test]
    fn train_decreases_tiny_loss() {
        for kind in ["sage", "gcn", "gat"] {
            let spec = tiny_train_spec(kind);
            let n_params = spec.meta_usize("n_params").unwrap();
            let mut inputs = tiny_inputs(&spec);
            let lr_idx = inputs.len() - 1;
            set_elem(&mut inputs[lr_idx], 0, 0.2);
            let mut first = f32::NAN;
            let mut last = f32::NAN;
            for step in 0..8 {
                let out = ReferenceBackend.execute(&spec, &inputs).unwrap();
                let loss = out[0].as_f32()[0];
                if step == 0 {
                    first = loss;
                }
                last = loss;
                for (i, t) in out.into_iter().skip(1).enumerate().take(n_params) {
                    inputs[i] = t;
                }
            }
            assert!(
                last < first,
                "{kind}: tiny-loss did not fall ({first} -> {last})"
            );
        }
    }

    #[test]
    fn eval_matches_train_forward_shapes() {
        let train = tiny_train_spec("gcn");
        let n_params = train.meta_usize("n_params").unwrap();
        let mut eval = train.clone();
        eval.name = "gcn_eval".into();
        eval.inputs.truncate(eval.inputs.len() - 2); // drop labels + lr
        eval.outputs = vec![TensorSpec {
            name: "logits".into(),
            shape: vec![2, 2],
            dtype: DType::F32,
        }];
        let inputs = tiny_inputs(&train);
        let out = ReferenceBackend
            .execute(&eval, &inputs[..n_params + 5])
            .unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].shape(), &[2, 2]);
        assert!(out[0].as_f32().iter().all(|x| x.is_finite()));
    }

    #[test]
    fn builtin_manifest_artifacts_all_execute() {
        let m = Manifest::reference_default();
        let mut be = ReferenceBackend;
        for name in ["link_decode", "sage_infer_layer0", "sage_infer_layer1"] {
            let spec = m.get(name).unwrap();
            let inputs: Vec<HostTensor> = spec
                .inputs
                .iter()
                .enumerate()
                .map(|(i, s)| {
                    let n: usize = s.shape.iter().product();
                    HostTensor::f32(s.shape.clone(), fill(i * 13, n))
                })
                .collect();
            let out = be.execute(spec, &inputs).unwrap();
            assert_eq!(out.len(), spec.outputs.len(), "{name}");
            assert_eq!(out[0].shape(), spec.outputs[0].shape.as_slice(), "{name}");
            assert!(out[0].as_f32().iter().all(|x| x.is_finite()), "{name}");
        }
    }

    #[test]
    fn unknown_artifact_is_an_error() {
        let spec = ArtifactSpec {
            name: "mystery".into(),
            file: String::new(),
            inputs: vec![],
            outputs: vec![],
            meta: Json::Null,
        };
        assert!(ReferenceBackend.execute(&spec, &[]).is_err());
    }
}
