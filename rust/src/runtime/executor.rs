//! `Runtime`: manifest-validated artifact execution over a pluggable
//! [`ExecutorBackend`]. Backend selection is runtime-driven: when
//! `<dir>/manifest.json` exists (built by `make artifacts`) the manifest
//! is loaded from disk and — with the `pjrt` cargo feature enabled —
//! executed by the PJRT/XLA backend; in every other case the built-in
//! reference manifest and the pure-Rust reference backend keep the whole
//! stack runnable hermetically (no artifacts, no native deps).

use std::path::{Path, PathBuf};
use std::sync::atomic::AtomicU64;

use anyhow::{bail, Result};

use crate::runtime::backend::ExecutorBackend;
use crate::runtime::manifest::{ArtifactSpec, Manifest};
use crate::runtime::reference::ReferenceBackend;
use crate::runtime::tensor::HostTensor;

pub struct Runtime {
    pub manifest: Manifest,
    backend: Box<dyn ExecutorBackend>,
    /// Total artifact executions (perf accounting).
    pub executions: AtomicU64,
}

impl Runtime {
    /// Load a runtime for the artifacts directory. Never fails on a
    /// missing directory: without `manifest.json` it degrades to the
    /// built-in reference manifest + backend (with a log line), so
    /// examples, tests and benches run end-to-end hermetically.
    pub fn load(dir: impl AsRef<Path>) -> Result<Runtime> {
        Self::load_with_layers(dir, 2)
    }

    /// [`Self::load`] with a K-layer inference encoder in the fallback
    /// manifest (`Manifest::reference_with_layers`). An on-disk
    /// `manifest.json` wins unchanged — the engine validates its depth at
    /// construction time.
    pub fn load_with_layers(dir: impl AsRef<Path>, infer_layers: usize) -> Result<Runtime> {
        let dir = dir.as_ref();
        if dir.join("manifest.json").exists() {
            let manifest = Manifest::load(dir)?;
            Ok(Runtime {
                manifest,
                backend: Self::artifact_backend(dir)?,
                executions: AtomicU64::new(0),
            })
        } else {
            // Once per process: tests and benches construct many runtimes.
            static FALLBACK_NOTICE: std::sync::Once = std::sync::Once::new();
            FALLBACK_NOTICE.call_once(|| {
                eprintln!(
                    "[glisp::runtime] no artifacts at {} — using the built-in \
                     reference backend (run `make artifacts` for PJRT/XLA)",
                    dir.display()
                );
            });
            Ok(Runtime {
                manifest: Manifest::reference_with_layers(infer_layers),
                backend: Box::new(ReferenceBackend),
                executions: AtomicU64::new(0),
            })
        }
    }

    /// An independently-executing handle over the same manifest for a
    /// worker thread, or `None` when the backend cannot be shared (the
    /// engine then falls back to a single-threaded sweep). The split
    /// runtime counts its own executions; callers fold them back.
    pub fn split(&self) -> Option<Runtime> {
        Some(Runtime {
            manifest: self.manifest.clone(),
            backend: self.backend.split()?,
            executions: AtomicU64::new(0),
        })
    }

    #[cfg(feature = "pjrt")]
    fn artifact_backend(dir: &Path) -> Result<Box<dyn ExecutorBackend>> {
        Ok(Box::new(crate::runtime::pjrt::PjrtBackend::new(dir)?))
    }

    /// Without the `pjrt` feature the on-disk manifest is still honored
    /// (shape validation, geometry) but execution happens on the
    /// reference backend.
    #[cfg(not(feature = "pjrt"))]
    fn artifact_backend(_dir: &Path) -> Result<Box<dyn ExecutorBackend>> {
        Ok(Box::new(ReferenceBackend))
    }

    /// Short id of the active backend ("reference" | "pjrt").
    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// Default artifacts directory: $GLISP_ARTIFACTS or ./artifacts.
    pub fn default_dir() -> PathBuf {
        std::env::var("GLISP_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    /// Compile (or fetch cached) an artifact's executable, if the backend
    /// compiles at all.
    pub fn prepare(&mut self, name: &str) -> Result<()> {
        let spec = self.manifest.get(name)?;
        self.backend.prepare(spec)
    }

    pub fn spec(&self, name: &str) -> Result<&ArtifactSpec> {
        self.manifest.get(name)
    }

    /// Execute an artifact with shape/dtype validation against the
    /// manifest. Outputs arrive in manifest order.
    pub fn execute(&mut self, name: &str, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let spec = self.manifest.get(name)?;
        if inputs.len() != spec.inputs.len() {
            bail!(
                "{name}: {} inputs given, manifest wants {}",
                inputs.len(),
                spec.inputs.len()
            );
        }
        for (i, (t, s)) in inputs.iter().zip(&spec.inputs).enumerate() {
            if t.shape() != s.shape.as_slice() {
                bail!(
                    "{name} input {i} ({}): shape {:?} != manifest {:?}",
                    s.name,
                    t.shape(),
                    s.shape
                );
            }
            if t.dtype() != s.dtype {
                bail!("{name} input {i} ({}): dtype mismatch", s.name);
            }
        }
        let out = self.backend.execute(spec, inputs)?;
        if out.len() != spec.outputs.len() {
            bail!(
                "{name}: backend returned {} outputs, manifest wants {}",
                out.len(),
                spec.outputs.len()
            );
        }
        self.executions
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        Ok(out)
    }

    /// Execute an artifact whose leading ("row") dimension is dynamic:
    /// the first `row_inputs` inputs — and every output that leads with
    /// the artifact's compiled row count (input 0's leading dim) — are
    /// validated/produced with `rows` instead; the remaining inputs
    /// (parameters) keep their exact manifest shapes. The caller names
    /// the row-shaped prefix because shape alone is ambiguous: e.g.
    /// `link_decode`'s `w1` is `[2·hidden, hidden]`, whose leading dim
    /// happens to equal the compiled decode batch. This is the tail block
    /// of a chunked sweep: the last `n % block` vertices execute at their
    /// true size rather than padded with garbage rows. Backends without
    /// dynamic-row support get zero-padded inputs and truncated outputs,
    /// so callers always receive `rows`-sized tensors either way.
    pub fn execute_rows(
        &mut self,
        name: &str,
        rows: usize,
        row_inputs: usize,
        inputs: &[HostTensor],
    ) -> Result<Vec<HostTensor>> {
        use anyhow::Context;
        let full = *self
            .manifest
            .get(name)?
            .inputs
            .first()
            .and_then(|s| s.shape.first())
            .with_context(|| format!("{name}: artifact has no leading row dimension"))?;
        if rows == full {
            return self.execute(name, inputs);
        }
        anyhow::ensure!(
            rows >= 1 && rows < full,
            "{name}: {rows} rows outside 1..={full}"
        );
        let spec = self.manifest.get(name)?;
        if inputs.len() != spec.inputs.len() {
            bail!(
                "{name}: {} inputs given, manifest wants {}",
                inputs.len(),
                spec.inputs.len()
            );
        }
        anyhow::ensure!(
            row_inputs >= 1 && row_inputs <= spec.inputs.len(),
            "{name}: row_inputs {row_inputs} outside 1..={}",
            spec.inputs.len()
        );
        for (i, (t, s)) in inputs.iter().zip(&spec.inputs).enumerate() {
            let mut want = s.shape.clone();
            if i < row_inputs {
                anyhow::ensure!(
                    want.first() == Some(&full),
                    "{name} input {i} ({}): declared row-shaped but manifest \
                     shape {:?} does not lead with {full}",
                    s.name,
                    want
                );
                want[0] = rows;
            }
            if t.shape() != want.as_slice() {
                bail!(
                    "{name} input {i} ({}): shape {:?} != {:?} ({rows} of {full} rows)",
                    s.name,
                    t.shape(),
                    want
                );
            }
            if t.dtype() != s.dtype {
                bail!("{name} input {i} ({}): dtype mismatch", s.name);
            }
        }
        let out = if self.backend.supports_dynamic_rows(spec) {
            self.backend.execute(spec, inputs)?
        } else {
            // Fixed-shape executable: zero-pad the row inputs up to the
            // compiled size, then truncate the row outputs back down.
            // Every output must be row-shaped — refusing up front beats
            // guessing which outputs to truncate (the same leading-dim
            // ambiguity `row_inputs` resolves on the input side).
            for (i, s) in spec.outputs.iter().enumerate() {
                anyhow::ensure!(
                    s.shape.first() == Some(&full),
                    "{name} output {i} ({}): shape {:?} is not row-shaped; \
                     dynamic rows unsupported for this artifact on a \
                     fixed-shape backend",
                    s.name,
                    s.shape
                );
            }
            let padded: Vec<HostTensor> = inputs
                .iter()
                .zip(&spec.inputs)
                .enumerate()
                .map(|(i, (t, s))| {
                    if i >= row_inputs {
                        return t.clone();
                    }
                    let total: usize = s.shape.iter().product();
                    match t {
                        HostTensor::F32 { data, .. } => {
                            let mut d = data.clone();
                            d.resize(total, 0.0);
                            HostTensor::f32(s.shape.clone(), d)
                        }
                        HostTensor::I32 { data, .. } => {
                            let mut d = data.clone();
                            d.resize(total, 0);
                            HostTensor::i32(s.shape.clone(), d)
                        }
                    }
                })
                .collect();
            self.backend
                .execute(spec, &padded)?
                .into_iter()
                .zip(&spec.outputs)
                .map(|(t, s)| {
                    let rest: usize = s.shape[1..].iter().product();
                    let mut shape = s.shape.clone();
                    shape[0] = rows;
                    match t {
                        HostTensor::F32 { data, .. } => {
                            HostTensor::f32(shape, data[..rows * rest].to_vec())
                        }
                        HostTensor::I32 { data, .. } => {
                            HostTensor::i32(shape, data[..rows * rest].to_vec())
                        }
                    }
                })
                .collect()
        };
        if out.len() != spec.outputs.len() {
            bail!(
                "{name}: backend returned {} outputs, manifest wants {}",
                out.len(),
                spec.outputs.len()
            );
        }
        self.executions
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn runtime() -> Runtime {
        Runtime::load(crate::test_artifacts_dir()).unwrap()
    }

    #[test]
    fn missing_artifacts_dir_falls_back_to_reference() {
        let dir = std::env::temp_dir().join("glisp_no_artifacts_here");
        let rt = Runtime::load(&dir).unwrap();
        assert_eq!(rt.backend_name(), "reference");
        // The built-in manifest carries the full artifact set.
        for name in [
            "sage_train", "gcn_train", "gat_train", "sage_grad", "sage_eval",
            "sage_infer_layer0", "sage_infer_layer1", "sage_embed", "link_decode",
        ] {
            assert!(rt.spec(name).is_ok(), "missing builtin artifact {name}");
        }
    }

    #[test]
    fn link_decode_executes_and_bounds() {
        let mut rt = runtime();
        let spec = rt.spec("link_decode").unwrap().clone();
        let inputs: Vec<HostTensor> = spec
            .inputs
            .iter()
            .enumerate()
            .map(|(i, s)| {
                let n: usize = s.shape.iter().product();
                HostTensor::f32(
                    s.shape.clone(),
                    (0..n).map(|j| ((i + j) % 7) as f32 * 0.1 - 0.3).collect(),
                )
            })
            .collect();
        let out = rt.execute("link_decode", &inputs).unwrap();
        assert_eq!(out.len(), 1);
        assert!(out[0].as_f32().iter().all(|&s| (0.0..=1.0).contains(&s)));
    }

    #[test]
    fn input_validation_rejects_bad_shape() {
        let mut rt = runtime();
        let spec = rt.spec("link_decode").unwrap().clone();
        let mut inputs: Vec<HostTensor> = spec
            .inputs
            .iter()
            .map(|s| HostTensor::zeros(&s.shape))
            .collect();
        inputs[0] = HostTensor::zeros(&[1, 1]);
        assert!(rt.execute("link_decode", &inputs).is_err());
    }

    #[test]
    fn execute_rows_tail_block_matches_full_prefix() {
        let mut rt = runtime();
        let spec = rt.spec("sage_infer_layer0").unwrap().clone();
        let full_inputs: Vec<HostTensor> = spec
            .inputs
            .iter()
            .enumerate()
            .map(|(i, s)| {
                let n: usize = s.shape.iter().product();
                HostTensor::f32(
                    s.shape.clone(),
                    (0..n).map(|j| ((i + j) % 13) as f32 * 0.25 - 1.0).collect(),
                )
            })
            .collect();
        let full_out = rt.execute("sage_infer_layer0", &full_inputs).unwrap();
        // Same values, first 7 rows of every row-shaped input only.
        let rows = 7usize;
        let chunk = spec.inputs[0].shape[0];
        let tail_inputs: Vec<HostTensor> = full_inputs
            .iter()
            .zip(&spec.inputs)
            .map(|(t, s)| {
                if s.shape.first() == Some(&chunk) {
                    let rest: usize = s.shape[1..].iter().product();
                    let mut shape = s.shape.clone();
                    shape[0] = rows;
                    HostTensor::f32(shape, t.as_f32()[..rows * rest].to_vec())
                } else {
                    t.clone()
                }
            })
            .collect();
        let tail_out = rt
            .execute_rows("sage_infer_layer0", rows, 3, &tail_inputs)
            .unwrap();
        let dout = spec.outputs[0].shape[1];
        assert_eq!(tail_out[0].shape(), &[rows, dout]);
        // Row-independent math: the tail equals the full run's prefix
        // bit-for-bit.
        assert_eq!(tail_out[0].as_f32(), &full_out[0].as_f32()[..rows * dout]);
    }

    #[test]
    fn execute_rows_link_decode_params_keep_manifest_shapes() {
        // link_decode's w1 is [2*hidden, hidden] = [256, 128]: its leading
        // dim equals the compiled decode batch, so only the explicit
        // row-input prefix (emb_u, emb_v) may be row-substituted — the
        // params must pass validation at their full manifest shapes.
        let mut rt = runtime();
        let spec = rt.spec("link_decode").unwrap().clone();
        let batch = spec.inputs[0].shape[0];
        assert_eq!(
            spec.inputs[2].shape[0], batch,
            "test premise: w1's leading dim collides with the batch"
        );
        let full_inputs: Vec<HostTensor> = spec
            .inputs
            .iter()
            .enumerate()
            .map(|(i, s)| {
                let n: usize = s.shape.iter().product();
                HostTensor::f32(
                    s.shape.clone(),
                    (0..n).map(|j| ((i + j) % 7) as f32 * 0.1 - 0.3).collect(),
                )
            })
            .collect();
        let full_out = rt.execute("link_decode", &full_inputs).unwrap();
        let rows = 5usize;
        let hidden = spec.inputs[0].shape[1];
        let mut tail_inputs = full_inputs.clone();
        for t in tail_inputs.iter_mut().take(2) {
            *t = HostTensor::f32(vec![rows, hidden], t.as_f32()[..rows * hidden].to_vec());
        }
        let tail_out = rt
            .execute_rows("link_decode", rows, 2, &tail_inputs)
            .unwrap();
        assert_eq!(tail_out[0].shape(), &[rows]);
        assert_eq!(tail_out[0].as_f32(), &full_out[0].as_f32()[..rows]);
    }

    #[test]
    fn execute_rows_rejects_oversized_and_zero_rows() {
        let mut rt = runtime();
        let spec = rt.spec("sage_infer_layer0").unwrap().clone();
        let inputs: Vec<HostTensor> = spec
            .inputs
            .iter()
            .map(|s| HostTensor::zeros(&s.shape))
            .collect();
        assert!(rt.execute_rows("sage_infer_layer0", 0, 3, &inputs).is_err());
        let chunk = spec.inputs[0].shape[0];
        assert!(rt
            .execute_rows("sage_infer_layer0", chunk + 1, 3, &inputs)
            .is_err());
    }

    #[test]
    fn split_runtime_executes_independently() {
        let rt = runtime();
        let mut worker = rt.split().expect("reference backend splits");
        assert_eq!(worker.backend_name(), "reference");
        let spec = worker.spec("sage_infer_layer0").unwrap().clone();
        let inputs: Vec<HostTensor> = spec
            .inputs
            .iter()
            .map(|s| HostTensor::zeros(&s.shape))
            .collect();
        worker.execute("sage_infer_layer0", &inputs).unwrap();
        assert_eq!(
            worker.executions.load(std::sync::atomic::Ordering::Relaxed),
            1
        );
        assert_eq!(rt.executions.load(std::sync::atomic::Ordering::Relaxed), 0);
    }

    #[test]
    fn load_with_layers_sizes_fallback_manifest() {
        let dir = std::env::temp_dir().join("glisp_no_artifacts_here");
        let rt = Runtime::load_with_layers(&dir, 3).unwrap();
        assert_eq!(rt.manifest.infer_layers(), 3);
        assert!(rt.spec("sage_infer_layer2").is_ok());
    }

    #[test]
    fn execution_counter_increments() {
        let mut rt = runtime();
        let spec = rt.spec("sage_infer_layer0").unwrap().clone();
        let inputs: Vec<HostTensor> = spec
            .inputs
            .iter()
            .map(|s| HostTensor::zeros(&s.shape))
            .collect();
        rt.execute("sage_infer_layer0", &inputs).unwrap();
        rt.execute("sage_infer_layer0", &inputs).unwrap();
        assert_eq!(
            rt.executions.load(std::sync::atomic::Ordering::Relaxed),
            2
        );
    }
}
