//! PJRT executor: loads `artifacts/*.hlo.txt` (AOT-lowered by
//! python/compile/aot.py), compiles each once on the CPU PJRT client, and
//! executes them from the L3 hot paths. Adapted from
//! /opt/xla-example/load_hlo — HLO *text* is the interchange format (see
//! aot.py's docstring for why).

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::{bail, Context, Result};

use crate::runtime::manifest::{ArtifactSpec, Manifest};
use crate::runtime::tensor::HostTensor;

pub struct Runtime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    dir: PathBuf,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
    /// Total artifact executions (perf accounting).
    pub executions: AtomicU64,
}

impl Runtime {
    /// Load the manifest and create the PJRT CPU client. Artifacts compile
    /// lazily on first use and are cached for the process lifetime.
    pub fn load(dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir)?;
        let client = xla::PjRtClient::cpu().context("PJRT cpu client")?;
        Ok(Runtime {
            client,
            manifest,
            dir,
            executables: HashMap::new(),
            executions: AtomicU64::new(0),
        })
    }

    /// Default artifacts directory: $GLISP_ARTIFACTS or ./artifacts.
    pub fn default_dir() -> PathBuf {
        std::env::var("GLISP_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    /// Compile (or fetch cached) an artifact's executable.
    pub fn prepare(&mut self, name: &str) -> Result<()> {
        if self.executables.contains_key(name) {
            return Ok(());
        }
        let spec = self.manifest.get(name)?.clone();
        let path = self.dir.join(&spec.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .with_context(|| format!("parsing {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {name}"))?;
        self.executables.insert(name.to_string(), exe);
        Ok(())
    }

    pub fn spec(&self, name: &str) -> Result<&ArtifactSpec> {
        self.manifest.get(name)
    }

    /// Execute an artifact with shape/dtype validation against the
    /// manifest. Outputs arrive in manifest order.
    pub fn execute(&mut self, name: &str, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        self.prepare(name)?;
        let spec = self.manifest.get(name)?;
        if inputs.len() != spec.inputs.len() {
            bail!(
                "{name}: {} inputs given, manifest wants {}",
                inputs.len(),
                spec.inputs.len()
            );
        }
        for (i, (t, s)) in inputs.iter().zip(&spec.inputs).enumerate() {
            if t.shape() != s.shape.as_slice() {
                bail!(
                    "{name} input {i} ({}): shape {:?} != manifest {:?}",
                    s.name,
                    t.shape(),
                    s.shape
                );
            }
            if t.dtype() != s.dtype {
                bail!("{name} input {i} ({}): dtype mismatch", s.name);
            }
        }
        let n_out = spec.outputs.len();
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<_>>()?;
        let exe = self.executables.get(name).unwrap();
        let result = exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        self.executions.fetch_add(1, Ordering::Relaxed);
        // aot.py lowers with return_tuple=True: the result is an n-tuple.
        let parts = result.to_tuple()?;
        if parts.len() != n_out {
            bail!("{name}: got {} outputs, manifest wants {n_out}", parts.len());
        }
        parts.iter().map(HostTensor::from_literal).collect()
    }
}

#[cfg(test)]
mod tests {
    //! Executor tests need built artifacts; they self-skip when
    //! artifacts/manifest.json is absent so `cargo test` stays green before
    //! `make artifacts`. Full coverage lives in rust/tests/runtime_e2e.rs.
    use super::*;

    fn runtime() -> Option<Runtime> {
        let dir = crate::test_artifacts_dir()?;
        Runtime::load(dir).ok()
    }

    #[test]
    fn link_decode_executes_and_bounds() {
        let Some(mut rt) = runtime() else { return };
        let spec = rt.spec("link_decode").unwrap().clone();
        let inputs: Vec<HostTensor> = spec
            .inputs
            .iter()
            .enumerate()
            .map(|(i, s)| {
                let n: usize = s.shape.iter().product();
                HostTensor::f32(
                    s.shape.clone(),
                    (0..n).map(|j| ((i + j) % 7) as f32 * 0.1 - 0.3).collect(),
                )
            })
            .collect();
        let out = rt.execute("link_decode", &inputs).unwrap();
        assert_eq!(out.len(), 1);
        assert!(out[0].as_f32().iter().all(|&s| (0.0..=1.0).contains(&s)));
    }

    #[test]
    fn input_validation_rejects_bad_shape() {
        let Some(mut rt) = runtime() else { return };
        let spec = rt.spec("link_decode").unwrap().clone();
        let mut inputs: Vec<HostTensor> = spec
            .inputs
            .iter()
            .map(|s| HostTensor::zeros(&s.shape))
            .collect();
        inputs[0] = HostTensor::zeros(&[1, 1]);
        assert!(rt.execute("link_decode", &inputs).is_err());
    }
}
