//! `Runtime`: manifest-validated artifact execution over a pluggable
//! [`ExecutorBackend`]. Backend selection is runtime-driven: when
//! `<dir>/manifest.json` exists (built by `make artifacts`) the manifest
//! is loaded from disk and — with the `pjrt` cargo feature enabled —
//! executed by the PJRT/XLA backend; in every other case the built-in
//! reference manifest and the pure-Rust reference backend keep the whole
//! stack runnable hermetically (no artifacts, no native deps).

use std::path::{Path, PathBuf};
use std::sync::atomic::AtomicU64;

use anyhow::{bail, Result};

use crate::runtime::backend::ExecutorBackend;
use crate::runtime::manifest::{ArtifactSpec, Manifest};
use crate::runtime::reference::ReferenceBackend;
use crate::runtime::tensor::HostTensor;

pub struct Runtime {
    pub manifest: Manifest,
    backend: Box<dyn ExecutorBackend>,
    /// Total artifact executions (perf accounting).
    pub executions: AtomicU64,
}

impl Runtime {
    /// Load a runtime for the artifacts directory. Never fails on a
    /// missing directory: without `manifest.json` it degrades to the
    /// built-in reference manifest + backend (with a log line), so
    /// examples, tests and benches run end-to-end hermetically.
    pub fn load(dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = dir.as_ref();
        if dir.join("manifest.json").exists() {
            let manifest = Manifest::load(dir)?;
            Ok(Runtime {
                manifest,
                backend: Self::artifact_backend(dir)?,
                executions: AtomicU64::new(0),
            })
        } else {
            // Once per process: tests and benches construct many runtimes.
            static FALLBACK_NOTICE: std::sync::Once = std::sync::Once::new();
            FALLBACK_NOTICE.call_once(|| {
                eprintln!(
                    "[glisp::runtime] no artifacts at {} — using the built-in \
                     reference backend (run `make artifacts` for PJRT/XLA)",
                    dir.display()
                );
            });
            Ok(Runtime {
                manifest: Manifest::reference_default(),
                backend: Box::new(ReferenceBackend),
                executions: AtomicU64::new(0),
            })
        }
    }

    #[cfg(feature = "pjrt")]
    fn artifact_backend(dir: &Path) -> Result<Box<dyn ExecutorBackend>> {
        Ok(Box::new(crate::runtime::pjrt::PjrtBackend::new(dir)?))
    }

    /// Without the `pjrt` feature the on-disk manifest is still honored
    /// (shape validation, geometry) but execution happens on the
    /// reference backend.
    #[cfg(not(feature = "pjrt"))]
    fn artifact_backend(_dir: &Path) -> Result<Box<dyn ExecutorBackend>> {
        Ok(Box::new(ReferenceBackend))
    }

    /// Short id of the active backend ("reference" | "pjrt").
    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// Default artifacts directory: $GLISP_ARTIFACTS or ./artifacts.
    pub fn default_dir() -> PathBuf {
        std::env::var("GLISP_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    /// Compile (or fetch cached) an artifact's executable, if the backend
    /// compiles at all.
    pub fn prepare(&mut self, name: &str) -> Result<()> {
        let spec = self.manifest.get(name)?;
        self.backend.prepare(spec)
    }

    pub fn spec(&self, name: &str) -> Result<&ArtifactSpec> {
        self.manifest.get(name)
    }

    /// Execute an artifact with shape/dtype validation against the
    /// manifest. Outputs arrive in manifest order.
    pub fn execute(&mut self, name: &str, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let spec = self.manifest.get(name)?;
        if inputs.len() != spec.inputs.len() {
            bail!(
                "{name}: {} inputs given, manifest wants {}",
                inputs.len(),
                spec.inputs.len()
            );
        }
        for (i, (t, s)) in inputs.iter().zip(&spec.inputs).enumerate() {
            if t.shape() != s.shape.as_slice() {
                bail!(
                    "{name} input {i} ({}): shape {:?} != manifest {:?}",
                    s.name,
                    t.shape(),
                    s.shape
                );
            }
            if t.dtype() != s.dtype {
                bail!("{name} input {i} ({}): dtype mismatch", s.name);
            }
        }
        let out = self.backend.execute(spec, inputs)?;
        if out.len() != spec.outputs.len() {
            bail!(
                "{name}: backend returned {} outputs, manifest wants {}",
                out.len(),
                spec.outputs.len()
            );
        }
        self.executions
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn runtime() -> Runtime {
        Runtime::load(crate::test_artifacts_dir()).unwrap()
    }

    #[test]
    fn missing_artifacts_dir_falls_back_to_reference() {
        let dir = std::env::temp_dir().join("glisp_no_artifacts_here");
        let rt = Runtime::load(&dir).unwrap();
        assert_eq!(rt.backend_name(), "reference");
        // The built-in manifest carries the full artifact set.
        for name in [
            "sage_train", "gcn_train", "gat_train", "sage_grad", "sage_eval",
            "sage_infer_layer0", "sage_infer_layer1", "sage_embed", "link_decode",
        ] {
            assert!(rt.spec(name).is_ok(), "missing builtin artifact {name}");
        }
    }

    #[test]
    fn link_decode_executes_and_bounds() {
        let mut rt = runtime();
        let spec = rt.spec("link_decode").unwrap().clone();
        let inputs: Vec<HostTensor> = spec
            .inputs
            .iter()
            .enumerate()
            .map(|(i, s)| {
                let n: usize = s.shape.iter().product();
                HostTensor::f32(
                    s.shape.clone(),
                    (0..n).map(|j| ((i + j) % 7) as f32 * 0.1 - 0.3).collect(),
                )
            })
            .collect();
        let out = rt.execute("link_decode", &inputs).unwrap();
        assert_eq!(out.len(), 1);
        assert!(out[0].as_f32().iter().all(|&s| (0.0..=1.0).contains(&s)));
    }

    #[test]
    fn input_validation_rejects_bad_shape() {
        let mut rt = runtime();
        let spec = rt.spec("link_decode").unwrap().clone();
        let mut inputs: Vec<HostTensor> = spec
            .inputs
            .iter()
            .map(|s| HostTensor::zeros(&s.shape))
            .collect();
        inputs[0] = HostTensor::zeros(&[1, 1]);
        assert!(rt.execute("link_decode", &inputs).is_err());
    }

    #[test]
    fn execution_counter_increments() {
        let mut rt = runtime();
        let spec = rt.spec("sage_infer_layer0").unwrap().clone();
        let inputs: Vec<HostTensor> = spec
            .inputs
            .iter()
            .map(|s| HostTensor::zeros(&s.shape))
            .collect();
        rt.execute("sage_infer_layer0", &inputs).unwrap();
        rt.execute("sage_infer_layer0", &inputs).unwrap();
        assert_eq!(
            rt.executions.load(std::sync::atomic::Ordering::Relaxed),
            2
        );
    }
}
