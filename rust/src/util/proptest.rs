//! In-repo property-testing mini-framework (proptest is not in the offline
//! vendor set). Generates seeded random cases, runs the property, and on
//! failure reports the failing seed so the case is replayable with
//! `GLISP_PROP_SEED=<seed>`.
//!
//! Usage:
//! ```ignore
//! prop_check("routing is total", 200, |rng| {
//!     let g = arbitrary_graph(rng, 100, 400);
//!     // ... assert invariant, or return Err(msg)
//!     Ok(())
//! });
//! ```

use crate::util::rng::Rng;

/// Number of cases can be overridden with GLISP_PROP_CASES.
pub fn prop_check<F>(name: &str, cases: usize, mut prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    let cases = std::env::var("GLISP_PROP_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(cases);
    if let Ok(seed) = std::env::var("GLISP_PROP_SEED") {
        let seed: u64 = seed.parse().expect("GLISP_PROP_SEED must be u64");
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("[prop:{name}] replay seed {seed} failed: {msg}");
        }
        return;
    }
    let base = 0xC0FFEE_u64;
    for case in 0..cases {
        let seed = base.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "[prop:{name}] case {case}/{cases} failed (replay with \
                 GLISP_PROP_SEED={seed}): {msg}"
            );
        }
    }
}

/// Assertion helpers that return Err instead of panicking, so prop_check can
/// attach the replay seed.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !($cond) {
            return Err(format!($($fmt)+));
        }
    };
    ($cond:expr) => {
        if !($cond) {
            return Err(format!("assertion failed: {}", stringify!($cond)));
        }
    };
}

#[macro_export]
macro_rules! prop_assert_eq {
    ($a:expr, $b:expr) => {{
        let (a, b) = (&$a, &$b);
        if a != b {
            return Err(format!(
                "{} != {} ({:?} vs {:?})",
                stringify!($a),
                stringify!($b),
                a,
                b
            ));
        }
    }};
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        prop_check("sum commutes", 50, |rng| {
            let a = rng.usize(1000);
            let b = rng.usize(1000);
            prop_assert_eq!(a + b, b + a);
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "replay with GLISP_PROP_SEED=")]
    fn reports_seed_on_failure() {
        prop_check("always fails eventually", 10, |rng| {
            let x = rng.usize(2);
            prop_assert!(x == 0, "x was {x}");
            Ok(())
        });
    }
}
