//! Minimal JSON parser/emitter — enough for the artifact manifest and the
//! bench reports. (serde is not in the offline vendor set; the manifest
//! grammar is plain RFC 8259 without extensions.)

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            b: s.as_bytes(),
            i: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// `obj.path("a.b.c")`
    pub fn path(&self, path: &str) -> Option<&Json> {
        let mut cur = self;
        for seg in path.split('.') {
            cur = cur.get(seg)?;
        }
        Some(cur)
    }
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.i,
            msg: msg.to_string(),
        }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err("bad literal"))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Copy a UTF-8 run verbatim.
                    let start = self.i;
                    while let Some(c) = self.peek() {
                        if c == b'"' || c == b'\\' {
                            break;
                        }
                        self.i += 1;
                    }
                    s.push_str(std::str::from_utf8(&self.b[start..self.i]).map_err(|_| {
                        self.err("invalid utf8")
                    })?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }
}

/// Emit compact JSON (used by bench reports).
pub fn emit(v: &Json) -> String {
    let mut s = String::new();
    write_json(v, &mut s);
    s
}

/// Emit human-diffable JSON: 2-space indentation, one object key per
/// line, and arrays kept on one line when every element is a scalar (so a
/// bench table row stays one line in the `BENCH_*.json` artifacts).
/// Object keys are emitted in `BTreeMap` order, so the output is
/// deterministic for a given value.
pub fn emit_pretty(v: &Json) -> String {
    let mut s = String::new();
    write_pretty(v, 0, &mut s);
    s
}

fn is_scalar(v: &Json) -> bool {
    matches!(v, Json::Null | Json::Bool(_) | Json::Num(_) | Json::Str(_))
}

fn write_pretty(v: &Json, indent: usize, out: &mut String) {
    let pad = "  ".repeat(indent + 1);
    let close_pad = "  ".repeat(indent);
    match v {
        Json::Arr(a) if !a.is_empty() && a.iter().all(is_scalar) => {
            out.push('[');
            for (i, x) in a.iter().enumerate() {
                if i > 0 {
                    out.push_str(", ");
                }
                write_json(x, out);
            }
            out.push(']');
        }
        Json::Arr(a) => {
            if a.is_empty() {
                out.push_str("[]");
                return;
            }
            out.push_str("[\n");
            for (i, x) in a.iter().enumerate() {
                out.push_str(&pad);
                write_pretty(x, indent + 1, out);
                if i + 1 < a.len() {
                    out.push(',');
                }
                out.push('\n');
            }
            out.push_str(&close_pad);
            out.push(']');
        }
        Json::Obj(m) => {
            if m.is_empty() {
                out.push_str("{}");
                return;
            }
            out.push_str("{\n");
            for (i, (k, x)) in m.iter().enumerate() {
                out.push_str(&pad);
                write_json(&Json::Str(k.clone()), out);
                out.push_str(": ");
                write_pretty(x, indent + 1, out);
                if i + 1 < m.len() {
                    out.push(',');
                }
                out.push('\n');
            }
            out.push_str(&close_pad);
            out.push('}');
        }
        _ => write_json(v, out),
    }
}

fn write_json(v: &Json, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 1e15 {
                out.push_str(&format!("{}", *n as i64));
            } else {
                out.push_str(&format!("{}", n));
            }
        }
        Json::Str(s) => {
            out.push('"');
            for c in s.chars() {
                match c {
                    '"' => out.push_str("\\\""),
                    '\\' => out.push_str("\\\\"),
                    '\n' => out.push_str("\\n"),
                    '\t' => out.push_str("\\t"),
                    '\r' => out.push_str("\\r"),
                    c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
                    c => out.push(c),
                }
            }
            out.push('"');
        }
        Json::Arr(a) => {
            out.push('[');
            for (i, x) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_json(x, out);
            }
            out.push(']');
        }
        Json::Obj(m) => {
            out.push('{');
            for (i, (k, x)) in m.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_json(&Json::Str(k.clone()), out);
                out.push(':');
                write_json(x, out);
            }
            out.push('}');
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(
            Json::parse("\"a\\nb\"").unwrap(),
            Json::Str("a\nb".into())
        );
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": {"d": false}}"#).unwrap();
        assert_eq!(j.path("c.d"), Some(&Json::Bool(false)));
        let arr = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[2].get("b").unwrap().as_str(), Some("x"));
    }

    #[test]
    fn parse_manifest_like() {
        let j = Json::parse(
            r#"{"artifacts": [{"name": "m", "inputs": [{"shape": [32, 64], "dtype": "f32"}]}]}"#,
        )
        .unwrap();
        let a = &j.get("artifacts").unwrap().as_arr().unwrap()[0];
        let shape: Vec<usize> = a.path("inputs").unwrap().as_arr().unwrap()[0]
            .get("shape")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|x| x.as_usize().unwrap())
            .collect();
        assert_eq!(shape, vec![32, 64]);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("{} x").is_err());
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(
            Json::parse("\"\\u00e9\"").unwrap(),
            Json::Str("é".into())
        );
    }

    #[test]
    fn round_trip_emit() {
        let src = r#"{"a":[1,2.5,"s"],"b":{"c":null}}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&emit(&j)).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn pretty_round_trips_and_inlines_scalar_rows() {
        let src = r#"{"rows":[[1,"a",null],[2,"b",true]],"meta":{"n":3},"empty":[],"eo":{}}"#;
        let j = Json::parse(src).unwrap();
        let p = emit_pretty(&j);
        assert_eq!(Json::parse(&p).unwrap(), j);
        // Scalar rows stay on one line; object keys are one per line.
        assert!(p.contains("[1, \"a\", null]"), "{p}");
        assert!(p.contains("\"empty\": []"), "{p}");
        assert!(p.contains("\"eo\": {}"), "{p}");
        assert!(p.starts_with("{\n  \""), "{p}");
    }
}
