//! Tiny content digests for cross-process bit-equality checks. The wire
//! CI job runs the same training workload in-process and against remote
//! `glisp serve` partitions, then diffs one printed digest line per run —
//! FNV-1a over the exact little-endian bytes, so a single flipped bit in
//! any loss (or any sampled value upstream of it) changes the line.

/// 64-bit FNV-1a over a byte stream.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Digest of an f32 sequence (e.g. a loss curve) over its exact bit
/// patterns — equality means bit-identical values, not "close".
pub fn f32_digest(xs: &[f32]) -> u64 {
    let mut bytes = Vec::with_capacity(xs.len() * 4);
    for x in xs {
        bytes.extend_from_slice(&x.to_le_bytes());
    }
    fnv1a(&bytes)
}

/// Digest of a u32 sequence (e.g. sampled tree levels).
pub fn u32_digest(xs: &[u32]) -> u64 {
    let mut bytes = Vec::with_capacity(xs.len() * 4);
    for x in xs {
        bytes.extend_from_slice(&x.to_le_bytes());
    }
    fnv1a(&bytes)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv1a_matches_reference_vectors() {
        // Published FNV-1a 64-bit test vectors.
        assert_eq!(fnv1a(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a(b"foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn digests_are_bit_sensitive() {
        let a = [0.5f32, 1.25, -3.0];
        let mut b = a;
        // Flip one mantissa bit.
        b[1] = f32::from_bits(b[1].to_bits() ^ 1);
        assert_ne!(f32_digest(&a), f32_digest(&b));
        assert_eq!(f32_digest(&a), f32_digest(&a.to_vec()));
        assert_ne!(u32_digest(&[1, 2, 3]), u32_digest(&[1, 2, 4]));
    }
}
