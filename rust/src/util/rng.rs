//! Deterministic PRNGs for the whole stack (no external `rand`: the build is
//! offline-vendored). SplitMix64 seeds Xoshiro256**, the same construction
//! the reference `rand_xoshiro` crate uses. Every component that needs
//! randomness takes an explicit `Rng` so runs are reproducible from a seed.

/// SplitMix64: used to expand a single u64 seed into state.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// Xoshiro256** — fast, high-quality, 256-bit state general-purpose PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    /// Derive an independent stream (e.g. per server / per trainer).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0xA076_1D64_78BD_642F))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1) with 53 bits of precision.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in (0, 1] — safe as a log() argument.
    #[inline]
    pub fn f64_open(&mut self) -> f64 {
        1.0 - self.f64()
    }

    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n) via Lemire's multiply-shift rejection.
    #[inline]
    pub fn usize(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.usize(hi - lo)
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64_open();
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.usize(i + 1));
        }
    }

    /// Sample k distinct indices from [0, n) (k <= n), order unspecified.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        if k * 3 >= n {
            let mut idx: Vec<usize> = (0..n).collect();
            self.shuffle(&mut idx);
            idx.truncate(k);
            idx
        } else {
            // Floyd's algorithm.
            let mut chosen = std::collections::HashSet::with_capacity(k);
            let mut out = Vec::with_capacity(k);
            for j in n - k..n {
                let t = self.usize(j + 1);
                let v = if chosen.contains(&t) { j } else { t };
                chosen.insert(v);
                out.push(v);
            }
            out
        }
    }

    /// Zipf-like sample in [0, n): rank r selected with p ∝ (r+1)^-alpha.
    /// Uses inverse-CDF on the harmonic partial sums approximation.
    pub fn zipf(&mut self, n: usize, alpha: f64) -> usize {
        // Approximate inverse CDF: H(x) ≈ x^(1-alpha)/(1-alpha) for alpha≠1.
        debug_assert!(n > 0);
        if (alpha - 1.0).abs() < 1e-9 {
            let h = (n as f64).ln();
            let u = self.f64() * h;
            return (u.exp() - 1.0).min((n - 1) as f64) as usize;
        }
        let one_m = 1.0 - alpha;
        let h_n = ((n as f64).powf(one_m) - 1.0) / one_m;
        let u = self.f64() * h_n;
        (((u * one_m + 1.0).powf(1.0 / one_m)) - 1.0)
            .min((n - 1) as f64)
            .max(0.0) as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn usize_bounds_and_coverage() {
        let mut r = Rng::new(2);
        let mut seen = [false; 10];
        for _ in 0..10_000 {
            let x = r.usize(10);
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn uniformity_chi_square_ish() {
        let mut r = Rng::new(3);
        let mut counts = [0usize; 16];
        let n = 160_000;
        for _ in 0..n {
            counts[r.usize(16)] += 1;
        }
        let expected = n as f64 / 16.0;
        for c in counts {
            assert!((c as f64 - expected).abs() < expected * 0.1);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(4);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(5);
        for &(n, k) in &[(10, 3), (100, 90), (1000, 10)] {
            let s = r.sample_indices(n, k);
            assert_eq!(s.len(), k);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), k);
            assert!(s.iter().all(|&i| i < n));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(6);
        let n = 100_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn zipf_is_skewed_and_bounded() {
        let mut r = Rng::new(7);
        let mut counts = vec![0usize; 100];
        for _ in 0..50_000 {
            counts[r.zipf(100, 1.5)] += 1;
        }
        assert!(counts[0] > counts[10]);
        assert!(counts[0] > counts.iter().sum::<usize>() / 10);
    }

    #[test]
    fn fork_streams_differ() {
        let mut root = Rng::new(8);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }
}
