//! Fixed-size bit array. Used for the `partition_set` field of the compact
//! graph structure (paper Fig. 6): partition membership of each vertex as a
//! bit per partition, and for visited sets in BFS/reorder passes.

#[derive(Clone, Debug, PartialEq)]
pub struct BitSet {
    words: Vec<u64>,
    len: usize,
}

impl BitSet {
    pub fn new(len: usize) -> Self {
        Self {
            words: vec![0; len.div_ceil(64)],
            len,
        }
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    #[inline]
    pub fn set(&mut self, i: usize) {
        debug_assert!(i < self.len);
        self.words[i / 64] |= 1 << (i % 64);
    }

    #[inline]
    pub fn clear(&mut self, i: usize) {
        debug_assert!(i < self.len);
        self.words[i / 64] &= !(1 << (i % 64));
    }

    #[inline]
    pub fn get(&self, i: usize) -> bool {
        debug_assert!(i < self.len);
        self.words[i / 64] >> (i % 64) & 1 == 1
    }

    pub fn count_ones(&self) -> usize {
        self.words.iter().map(|w| w.count_ones() as usize).sum()
    }

    pub fn iter_ones(&self) -> impl Iterator<Item = usize> + '_ {
        self.words.iter().enumerate().flat_map(|(wi, &w)| {
            let mut w = w;
            std::iter::from_fn(move || {
                if w == 0 {
                    None
                } else {
                    let b = w.trailing_zeros() as usize;
                    w &= w - 1;
                    Some(wi * 64 + b)
                }
            })
        })
    }

    pub fn clear_all(&mut self) {
        self.words.fill(0);
    }

    /// Raw words — the serialized form in the graph binary layout.
    pub fn words(&self) -> &[u64] {
        &self.words
    }

    pub fn from_words(words: Vec<u64>, len: usize) -> Self {
        assert!(words.len() == len.div_ceil(64));
        Self { words, len }
    }
}

/// A matrix of bit sets: one row of `bits` bits per item, packed into whole
/// words per row. This is the paper's `partition_set` field: row = vertex,
/// bit = partition ID.
#[derive(Clone, Debug)]
pub struct BitMatrix {
    words_per_row: usize,
    bits: usize,
    data: Vec<u64>,
}

impl BitMatrix {
    pub fn new(rows: usize, bits: usize) -> Self {
        let wpr = bits.div_ceil(64).max(1);
        Self {
            words_per_row: wpr,
            bits,
            data: vec![0; rows * wpr],
        }
    }

    pub fn rows(&self) -> usize {
        if self.words_per_row == 0 {
            0
        } else {
            self.data.len() / self.words_per_row
        }
    }

    pub fn bits(&self) -> usize {
        self.bits
    }

    #[inline]
    pub fn set(&mut self, row: usize, bit: usize) {
        debug_assert!(bit < self.bits);
        self.data[row * self.words_per_row + bit / 64] |= 1 << (bit % 64);
    }

    #[inline]
    pub fn get(&self, row: usize, bit: usize) -> bool {
        debug_assert!(bit < self.bits);
        self.data[row * self.words_per_row + bit / 64] >> (bit % 64) & 1 == 1
    }

    pub fn row_ones(&self, row: usize) -> impl Iterator<Item = usize> + '_ {
        let start = row * self.words_per_row;
        self.data[start..start + self.words_per_row]
            .iter()
            .enumerate()
            .flat_map(|(wi, &w)| {
                let mut w = w;
                std::iter::from_fn(move || {
                    if w == 0 {
                        None
                    } else {
                        let b = w.trailing_zeros() as usize;
                        w &= w - 1;
                        Some(wi * 64 + b)
                    }
                })
            })
    }

    pub fn row_count(&self, row: usize) -> usize {
        let start = row * self.words_per_row;
        self.data[start..start + self.words_per_row]
            .iter()
            .map(|w| w.count_ones() as usize)
            .sum()
    }

    /// Union another matrix of identical shape into this one — the shard
    /// merge of the parallel partition-membership scan (graph::hetero).
    pub fn or_with(&mut self, other: &BitMatrix) {
        assert_eq!(self.bits, other.bits);
        assert_eq!(self.data.len(), other.data.len());
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a |= b;
        }
    }

    /// Memory footprint in bytes (Table III accounting).
    pub fn nbytes(&self) -> usize {
        self.data.len() * 8
    }

    pub fn raw(&self) -> &[u64] {
        &self.data
    }

    /// Consume the matrix into its raw words — how `graph::store::PartBits`
    /// freezes a builder-produced membership matrix without a copy.
    pub fn into_raw(self) -> Vec<u64> {
        self.data
    }

    pub fn from_raw(data: Vec<u64>, bits: usize) -> Self {
        let wpr = bits.div_ceil(64).max(1);
        assert_eq!(data.len() % wpr, 0);
        Self {
            words_per_row: wpr,
            bits,
            data,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn set_get_clear() {
        let mut b = BitSet::new(130);
        b.set(0);
        b.set(64);
        b.set(129);
        assert!(b.get(0) && b.get(64) && b.get(129));
        assert!(!b.get(1) && !b.get(128));
        b.clear(64);
        assert!(!b.get(64));
        assert_eq!(b.count_ones(), 2);
    }

    #[test]
    fn iter_ones_order() {
        let mut b = BitSet::new(200);
        for i in [3usize, 64, 65, 199] {
            b.set(i);
        }
        assert_eq!(b.iter_ones().collect::<Vec<_>>(), vec![3, 64, 65, 199]);
    }

    #[test]
    fn matrix_rows_independent() {
        let mut m = BitMatrix::new(3, 100);
        m.set(0, 0);
        m.set(1, 99);
        m.set(2, 50);
        assert!(m.get(0, 0) && !m.get(0, 99));
        assert!(m.get(1, 99) && !m.get(1, 0));
        assert_eq!(m.row_ones(2).collect::<Vec<_>>(), vec![50]);
        assert_eq!(m.row_count(1), 1);
    }

    #[test]
    fn matrix_or_with_unions_rows() {
        let mut a = BitMatrix::new(3, 70);
        let mut b = BitMatrix::new(3, 70);
        a.set(0, 1);
        b.set(0, 69);
        b.set(2, 5);
        a.or_with(&b);
        assert!(a.get(0, 1) && a.get(0, 69) && a.get(2, 5));
        assert_eq!(a.row_count(1), 0);
    }

    #[test]
    fn matrix_roundtrip_raw() {
        let mut m = BitMatrix::new(4, 65);
        m.set(3, 64);
        let m2 = BitMatrix::from_raw(m.raw().to_vec(), 65);
        assert!(m2.get(3, 64));
        assert_eq!(m2.rows(), 4);
    }
}
