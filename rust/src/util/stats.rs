//! Small statistics helpers shared by metrics, benches and reports.

/// Running mean/min/max/variance accumulator (Welford).
#[derive(Clone, Debug, Default)]
pub struct Summary {
    pub n: u64,
    pub mean: f64,
    m2: f64,
    pub min: f64,
    pub max: f64,
}

impl Summary {
    pub fn new() -> Self {
        Self {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn from_iter<I: IntoIterator<Item = f64>>(it: I) -> Self {
        let mut s = Self::new();
        for x in it {
            s.add(x);
        }
        s
    }
}

/// Percentile over a copy of the data (nearest-rank on a sorted copy).
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((p / 100.0) * (v.len() - 1) as f64).round() as usize;
    v[rank.min(v.len() - 1)]
}

/// max/min ratio — the paper's balance metrics (EB, VB, normalized workload).
pub fn balance_ratio(xs: &[f64]) -> f64 {
    let mut lo = f64::INFINITY;
    let mut hi = f64::NEG_INFINITY;
    for &x in xs {
        lo = lo.min(x);
        hi = hi.max(x);
    }
    if lo <= 0.0 {
        f64::INFINITY
    } else {
        hi / lo
    }
}

/// Log-binned histogram for degree distributions (Fig. 8): bin k holds
/// counts with value in [2^k, 2^{k+1}).
pub fn log_histogram(values: impl Iterator<Item = u64>) -> Vec<(u64, u64)> {
    let mut bins: Vec<u64> = Vec::new();
    let mut zero = 0u64;
    for v in values {
        if v == 0 {
            zero += 1;
            continue;
        }
        let k = 63 - v.leading_zeros() as usize;
        if bins.len() <= k {
            bins.resize(k + 1, 0);
        }
        bins[k] += 1;
    }
    let mut out = Vec::new();
    if zero > 0 {
        out.push((0, zero));
    }
    for (k, &c) in bins.iter().enumerate() {
        if c > 0 {
            out.push((1u64 << k, c));
        }
    }
    out
}

/// Least-squares slope of log(count) vs log(degree) — a quick power-law
/// exponent estimate for generated graphs (Fig. 8 uses the visual shape;
/// tests use this to pin generator behaviour).
pub fn powerlaw_slope(hist: &[(u64, u64)]) -> f64 {
    let pts: Vec<(f64, f64)> = hist
        .iter()
        .filter(|&&(d, c)| d > 0 && c > 0)
        .map(|&(d, c)| ((d as f64).ln(), (c as f64).ln()))
        .collect();
    if pts.len() < 2 {
        return 0.0;
    }
    let n = pts.len() as f64;
    let sx: f64 = pts.iter().map(|p| p.0).sum();
    let sy: f64 = pts.iter().map(|p| p.1).sum();
    let sxx: f64 = pts.iter().map(|p| p.0 * p.0).sum();
    let sxy: f64 = pts.iter().map(|p| p.0 * p.1).sum();
    (n * sxy - sx * sy) / (n * sxx - sx * sx)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_matches_closed_form() {
        let s = Summary::from_iter([1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert!((s.var() - 2.5).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
    }

    #[test]
    fn percentiles() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 100.0);
        assert!((percentile(&xs, 50.0) - 50.0).abs() <= 1.0);
    }

    #[test]
    fn balance() {
        assert!((balance_ratio(&[2.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!(balance_ratio(&[0.0, 1.0]).is_infinite());
    }

    #[test]
    fn log_hist_bins() {
        let h = log_histogram([1u64, 1, 2, 3, 4, 8, 9, 0].into_iter());
        // zero bin, then 2^0:{1,1}, 2^1:{2,3}, 2^2:{4}, 2^3:{8,9}
        assert_eq!(h, vec![(0, 1), (1, 2), (2, 2), (4, 1), (8, 2)]);
    }

    #[test]
    fn slope_of_exact_powerlaw() {
        // count = degree^-2 scaled
        let hist: Vec<(u64, u64)> = (0..10)
            .map(|k| {
                let d = 1u64 << k;
                (d, (1e12 / (d as f64).powi(2)) as u64)
            })
            .collect();
        let s = powerlaw_slope(&hist);
        assert!((s + 2.0).abs() < 0.05, "slope {s}");
    }
}
