//! Bounded top-k selection by score — the Apply-phase primitive of the
//! distributed weighted sampler (paper Algorithm 4: `GetScoreTopK`).
//!
//! A fixed-capacity min-heap keyed on score: pushing beyond capacity evicts
//! the current minimum iff the new score beats it, so the heap always holds
//! the k best items seen. O(n log k), no allocation after construction.

#[derive(Clone, Debug)]
pub struct TopK<T> {
    k: usize,
    // Min-heap as (score, tiebreak, item); tiebreak keeps ordering total so
    // results are deterministic for equal scores.
    heap: Vec<(f64, u64, T)>,
}

impl<T> TopK<T> {
    pub fn new(k: usize) -> Self {
        Self {
            k,
            heap: Vec::with_capacity(k + 1),
        }
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Current k-th best score (the eviction threshold), if full.
    pub fn threshold(&self) -> Option<f64> {
        if self.heap.len() == self.k {
            self.heap.first().map(|e| e.0)
        } else {
            None
        }
    }

    pub fn push(&mut self, score: f64, tiebreak: u64, item: T) {
        if self.k == 0 {
            return;
        }
        if self.heap.len() < self.k {
            self.heap.push((score, tiebreak, item));
            self.sift_up(self.heap.len() - 1);
        } else {
            // Fast reject: once full, a strictly smaller score can never
            // enter — on power-law candidate lists this is the common case,
            // and it skips the tiebreak compare and all sift work.
            if score < self.heap[0].0 {
                return;
            }
            if Self::gt(score, tiebreak, self.heap[0].0, self.heap[0].1) {
                self.heap[0] = (score, tiebreak, item);
                self.sift_down(0);
            }
        }
    }

    #[inline]
    fn gt(s1: f64, t1: u64, s2: f64, t2: u64) -> bool {
        s1 > s2 || (s1 == s2 && t1 > t2)
    }

    fn sift_up(&mut self, mut i: usize) {
        while i > 0 {
            let p = (i - 1) / 2;
            if Self::gt(self.heap[p].0, self.heap[p].1, self.heap[i].0, self.heap[i].1) {
                self.heap.swap(i, p);
                i = p;
            } else {
                break;
            }
        }
    }

    fn sift_down(&mut self, mut i: usize) {
        loop {
            let (l, r) = (2 * i + 1, 2 * i + 2);
            let mut min = i;
            for c in [l, r] {
                if c < self.heap.len()
                    && Self::gt(
                        self.heap[min].0,
                        self.heap[min].1,
                        self.heap[c].0,
                        self.heap[c].1,
                    )
                {
                    min = c;
                }
            }
            if min == i {
                break;
            }
            self.heap.swap(i, min);
            i = min;
        }
    }

    /// Drain in descending score order. `total_cmp` keeps the sort total
    /// even for NaN scores (which sort last instead of panicking); for the
    /// non-NaN, non-negative scores A-ES produces it orders identically to
    /// the old `partial_cmp().unwrap()`.
    pub fn into_sorted(mut self) -> Vec<(f64, T)> {
        self.heap
            .sort_unstable_by(|a, b| b.0.total_cmp(&a.0).then(b.1.cmp(&a.1)));
        self.heap.into_iter().map(|(s, _, t)| (s, t)).collect()
    }

    /// Empty the heap and set a new capacity bound, keeping the backing
    /// allocation — lets one `TopK` serve many selections (the weighted
    /// Apply path reuses one per batch instead of allocating per seed).
    pub fn reset(&mut self, k: usize) {
        self.k = k;
        self.heap.clear();
        // No-op when the backing allocation already fits k + 1.
        self.heap.reserve(k + 1);
    }

    /// Drain in descending score order, leaving the heap empty but the
    /// allocation intact (pair with [`TopK::reset`]).
    pub fn drain_sorted(&mut self) -> impl Iterator<Item = (f64, T)> + '_ {
        self.heap
            .sort_unstable_by(|a, b| b.0.total_cmp(&a.0).then(b.1.cmp(&a.1)));
        self.heap.drain(..).map(|(s, _, t)| (s, t))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn keeps_k_best() {
        let mut tk = TopK::new(3);
        for (i, s) in [5.0, 1.0, 9.0, 3.0, 7.0, 2.0].iter().enumerate() {
            tk.push(*s, i as u64, i);
        }
        let out = tk.into_sorted();
        let scores: Vec<f64> = out.iter().map(|x| x.0).collect();
        assert_eq!(scores, vec![9.0, 7.0, 5.0]);
    }

    #[test]
    fn fewer_than_k() {
        let mut tk = TopK::new(10);
        tk.push(1.0, 0, "a");
        tk.push(2.0, 1, "b");
        let out = tk.into_sorted();
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].1, "b");
    }

    #[test]
    fn zero_k() {
        let mut tk = TopK::new(0);
        tk.push(1.0, 0, ());
        assert!(tk.is_empty());
    }

    #[test]
    fn matches_full_sort_randomized() {
        let mut rng = Rng::new(11);
        for _ in 0..50 {
            let n = rng.range(1, 200);
            let k = rng.range(1, 32);
            let xs: Vec<f64> = (0..n).map(|_| rng.f64()).collect();
            let mut tk = TopK::new(k);
            for (i, &s) in xs.iter().enumerate() {
                tk.push(s, i as u64, i);
            }
            let got: Vec<f64> = tk.into_sorted().iter().map(|x| x.0).collect();
            let mut want = xs.clone();
            want.sort_by(|a, b| b.partial_cmp(a).unwrap());
            want.truncate(k);
            assert_eq!(got, want);
        }
    }

    #[test]
    fn reset_and_drain_reuse_matches_fresh() {
        let mut reused = TopK::new(4);
        for round in 0..5u64 {
            reused.reset(3);
            let mut fresh = TopK::new(3);
            for i in 0..20u64 {
                let s = ((i * 7 + round) % 13) as f64;
                reused.push(s, i, i);
                fresh.push(s, i, i);
            }
            let a: Vec<(f64, u64)> = reused.drain_sorted().collect();
            let b = fresh.into_sorted();
            assert_eq!(a, b);
            assert!(reused.is_empty());
        }
    }

    #[test]
    fn nan_scores_do_not_panic_and_sort_last() {
        let mut tk = TopK::new(4);
        tk.push(0.5, 0, 0usize);
        tk.push(f64::NAN, 1, 1);
        tk.push(0.9, 2, 2);
        let out = tk.into_sorted();
        // total_cmp orders NaN above every finite value, so descending
        // order puts it first — the point is the sort no longer panics and
        // real scores keep their relative order.
        let finite: Vec<f64> = out.iter().map(|x| x.0).filter(|s| !s.is_nan()).collect();
        assert_eq!(finite, vec![0.9, 0.5]);
        let mut tk = TopK::new(2);
        tk.push(f64::NAN, 0, 0usize);
        tk.push(1.0, 1, 1);
        let _ = tk.drain_sorted().collect::<Vec<_>>(); // must not panic
    }

    #[test]
    fn full_heap_rejects_below_threshold() {
        let mut tk = TopK::new(2);
        tk.push(5.0, 0, 0usize);
        tk.push(7.0, 1, 1);
        let thr = tk.threshold().unwrap();
        assert_eq!(thr, 5.0);
        tk.push(4.9, 2, 2); // strictly below threshold — fast-rejected
        tk.push(5.0, 3, 3); // tie with threshold, larger tiebreak — replaces
        let out = tk.into_sorted();
        assert_eq!(out.iter().map(|x| x.1).collect::<Vec<_>>(), vec![1, 3]);
    }

    #[test]
    fn deterministic_on_ties() {
        let mut a = TopK::new(2);
        let mut b = TopK::new(2);
        for i in 0..10u64 {
            a.push(1.0, i, i);
            b.push(1.0, i, i);
        }
        assert_eq!(
            a.into_sorted().iter().map(|x| x.1).collect::<Vec<_>>(),
            b.into_sorted().iter().map(|x| x.1).collect::<Vec<_>>()
        );
    }
}
