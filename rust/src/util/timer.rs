//! Wall-clock timing helpers used by the bench harness and metrics.

use std::time::{Duration, Instant};

/// Scoped stopwatch.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Self {
            start: Instant::now(),
        }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }

    pub fn ms(&self) -> f64 {
        self.secs() * 1e3
    }
}

/// Time a closure, returning (result, seconds).
pub fn time<R>(f: impl FnOnce() -> R) -> (R, f64) {
    let t = Timer::start();
    let r = f();
    (r, t.secs())
}

/// Repeat a closure until `min_secs` of total runtime or `max_iters`,
/// returning per-iteration seconds (after `warmup` discarded runs). This is
/// the measurement core of the in-repo bench harness (criterion is not in
/// the offline vendor set).
pub fn measure(warmup: usize, max_iters: usize, min_secs: f64, mut f: impl FnMut()) -> Vec<f64> {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::new();
    let total = Timer::start();
    while samples.len() < max_iters && (samples.len() < 3 || total.secs() < min_secs) {
        let t = Timer::start();
        f();
        samples.push(t.secs());
    }
    samples
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_monotonic() {
        let t = Timer::start();
        std::thread::sleep(Duration::from_millis(5));
        assert!(t.ms() >= 4.0);
    }

    #[test]
    fn measure_counts() {
        // min_secs=0 stops at the 3-sample floor; a large min_secs runs to
        // the max_iters cap.
        let mut n = 0;
        let s = measure(2, 5, 0.0, || n += 1);
        assert_eq!(s.len(), 3);
        assert_eq!(n, 5); // 2 warmup + 3 measured
        let s = measure(0, 4, 60.0, || {});
        assert_eq!(s.len(), 4);
    }
}
