//! Wall-clock timing helpers used by the bench harness and metrics.

use std::time::{Duration, Instant};

/// Scoped stopwatch.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Self {
            start: Instant::now(),
        }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }

    pub fn ms(&self) -> f64 {
        self.secs() * 1e3
    }
}

/// Render a duration with an auto-selected unit (s / ms / us / ns) and
/// three significant digits. This is THE duration formatter: every
/// human-facing timing string (bench tables, coordinator metrics) and the
/// markdown regenerated from `BENCH_*.json` artifacts goes through it, so
/// units can no longer drift between call sites. Rounding is pinned by
/// unit test: >= 100 in-unit -> 0 decimals, >= 10 -> 1, else 2; the ns
/// tier is always a whole number. Negative or non-finite inputs render
/// as "-".
pub fn fmt_duration(secs: f64) -> String {
    if !secs.is_finite() || secs < 0.0 {
        return "-".to_string();
    }
    let sig3 = |v: f64, unit: &str| -> String {
        if v >= 100.0 {
            format!("{v:.0}{unit}")
        } else if v >= 10.0 {
            format!("{v:.1}{unit}")
        } else {
            format!("{v:.2}{unit}")
        }
    };
    if secs >= 1.0 {
        sig3(secs, "s")
    } else if secs >= 1e-3 {
        sig3(secs * 1e3, "ms")
    } else if secs >= 1e-6 {
        sig3(secs * 1e6, "us")
    } else {
        format!("{}ns", (secs * 1e9).round() as u64)
    }
}

/// Time a closure, returning (result, seconds).
pub fn time<R>(f: impl FnOnce() -> R) -> (R, f64) {
    let t = Timer::start();
    let r = f();
    (r, t.secs())
}

/// Repeat a closure until `min_secs` of total runtime or `max_iters`,
/// returning per-iteration seconds (after `warmup` discarded runs). This is
/// the measurement core of the in-repo bench harness (criterion is not in
/// the offline vendor set).
pub fn measure(warmup: usize, max_iters: usize, min_secs: f64, mut f: impl FnMut()) -> Vec<f64> {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::new();
    let total = Timer::start();
    while samples.len() < max_iters && (samples.len() < 3 || total.secs() < min_secs) {
        let t = Timer::start();
        f();
        samples.push(t.secs());
    }
    samples
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_monotonic() {
        let t = Timer::start();
        std::thread::sleep(Duration::from_millis(5));
        assert!(t.ms() >= 4.0);
    }

    #[test]
    fn fmt_duration_rounding_is_pinned() {
        assert_eq!(fmt_duration(1.5), "1.50s");
        assert_eq!(fmt_duration(123.4), "123s");
        assert_eq!(fmt_duration(12.34), "12.3s");
        assert_eq!(fmt_duration(0.001234), "1.23ms");
        assert_eq!(fmt_duration(0.0123), "12.3ms");
        assert_eq!(fmt_duration(0.1234), "123ms");
        assert_eq!(fmt_duration(0.0000123), "12.3us");
        assert_eq!(fmt_duration(1.23e-7), "123ns");
        assert_eq!(fmt_duration(0.0), "0ns");
        assert_eq!(fmt_duration(-1.0), "-");
        assert_eq!(fmt_duration(f64::NAN), "-");
    }

    #[test]
    fn measure_counts() {
        // min_secs=0 stops at the 3-sample floor; a large min_secs runs to
        // the max_iters cap.
        let mut n = 0;
        let s = measure(2, 5, 0.0, || n += 1);
        assert_eq!(s.len(), 3);
        assert_eq!(n, 5); // 2 warmup + 3 measured
        let s = measure(0, 4, 60.0, || {});
        assert_eq!(s.len(), 4);
    }
}
