//! Shared substrate: PRNG, JSON codec, statistics, bit sets, top-k
//! selection, timing, and the property-testing mini-framework. Everything
//! here exists because the build is offline-vendored (DESIGN.md §4).

pub mod bitset;
pub mod digest;
pub mod json;
pub mod proptest;
pub mod rng;
pub mod stats;
pub mod timer;
pub mod topk;
