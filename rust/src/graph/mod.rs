//! Graph substrate: CSR storage, synthetic generators, the compact
//! vertex-cut partition structure (paper Fig. 6), reorder algorithms,
//! degree metrics, binary IO, and Table III memory models.

pub mod csr;
pub mod generator;
pub mod hetero;
pub mod io;
pub mod memfoot;
pub mod metrics;
pub mod reorder;

pub use csr::{EId, Graph, VId};
pub use hetero::{build_partitions, build_partitions_threads, PartitionGraph};
