//! Graph substrate: CSR storage, synthetic generators, the compact
//! vertex-cut partition structure (paper Fig. 6), reorder algorithms,
//! degree metrics, binary IO, the out-of-core storage seam, and Table III
//! memory models.

pub mod csr;
pub mod generator;
pub mod hetero;
pub mod io;
pub mod memfoot;
pub mod metrics;
pub mod reorder;
pub mod store;

pub use csr::{EId, Graph, VId};
pub use hetero::{
    build_and_save_partitions, build_partitions, build_partitions_threads,
    build_single_partition, PartitionGraph,
};
pub use store::{open_partitions, HeapStore, MmapStore, PartitionStore, Section, StoreBackend};
