//! Graph-level metrics: degree distributions (Fig. 8) and dataset summary
//! rows (Table I analogue for the synthetic suite).

use crate::graph::csr::Graph;
use crate::util::stats::{log_histogram, powerlaw_slope};

#[derive(Clone, Debug)]
pub struct DegreeDistribution {
    /// (degree-bin lower bound, vertex count), log-binned.
    pub hist: Vec<(u64, u64)>,
    pub max_degree: u32,
    pub avg_degree: f64,
    /// log-log slope; ≤ -1 indicates a heavy tail.
    pub slope: f64,
}

pub fn degree_distribution(g: &Graph) -> DegreeDistribution {
    let degs = g.out_degrees();
    let hist = log_histogram(degs.iter().map(|&d| d as u64));
    let nonzero: Vec<(u64, u64)> = hist.iter().copied().filter(|&(d, _)| d > 0).collect();
    DegreeDistribution {
        slope: powerlaw_slope(&nonzero),
        max_degree: degs.iter().copied().max().unwrap_or(0),
        avg_degree: g.avg_degree(),
        hist,
    }
}

/// True iff the degree distribution is power-law-like: heavy negative
/// log-log slope and a hotspot far above the mean (paper Fig. 8 criterion).
pub fn is_power_law(g: &Graph) -> bool {
    let d = degree_distribution(g);
    d.slope < -0.8 && d.max_degree as f64 > 10.0 * d.avg_degree.max(1.0)
}

/// Table I-style summary row.
#[derive(Clone, Debug)]
pub struct DatasetSummary {
    pub name: String,
    pub n: usize,
    pub m: usize,
    pub avg_degree: f64,
    pub max_degree: u32,
    pub power_law: bool,
}

pub fn summarize(name: &str, g: &Graph) -> DatasetSummary {
    let d = degree_distribution(g);
    DatasetSummary {
        name: name.to_string(),
        n: g.n,
        m: g.m(),
        avg_degree: d.avg_degree,
        max_degree: d.max_degree,
        power_law: is_power_law(g),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generator;
    use crate::util::rng::Rng;

    #[test]
    fn power_law_detection_separates_regimes() {
        let mut rng = Rng::new(30);
        let pl = generator::chung_lu(20_000, 140_000, 2.0, &mut rng);
        let er = generator::erdos_renyi(20_000, 140_000, &mut rng);
        assert!(is_power_law(&pl));
        assert!(!is_power_law(&er));
    }

    #[test]
    fn summary_fields() {
        let mut rng = Rng::new(31);
        let g = generator::erdos_renyi(1000, 5000, &mut rng);
        let s = summarize("er", &g);
        assert_eq!(s.n, 1000);
        assert_eq!(s.m, 5000);
        assert!((s.avg_degree - 5.0).abs() < 1e-9);
    }
}
