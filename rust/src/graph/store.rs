//! The out-of-core storage seam: every array the compact partition
//! structure owns is a [`Section`] — either a heap `Vec` (the layout the
//! builders produce) or a window into a read-only `mmap` of the file
//! `graph::io::save_partition` writes. `Section` derefs to `&[T]`, so the
//! sampling servers, gather ops and inference engine read through the seam
//! without knowing which backing they got — which is exactly why a run on
//! [`MmapStore`] is bit-identical to one on [`HeapStore`] for any
//! (threads, workers, shard_size, transport): the stores serve identical
//! array views, and every random choice downstream is already pinned by
//! the per-seed RNG contract (DESIGN.md §9, §13).
//!
//! The map is `PROT_READ`/`MAP_PRIVATE` via `libc` (no new dependencies);
//! pages are faulted in by the kernel on demand and evicted under
//! pressure, so the partition's heap residency is O(1) regardless of graph
//! size — `memfoot::partition_residency` measures the split.

use std::fmt;
use std::fs::File;
use std::marker::PhantomData;
use std::ops::Deref;
use std::os::unix::io::AsRawFd;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::graph::hetero::PartitionGraph;
use crate::graph::io;
use crate::util::bitset::BitMatrix;

mod sealed {
    pub trait Sealed {}
    impl Sealed for u8 {}
    impl Sealed for u32 {}
    impl Sealed for u64 {}
    impl Sealed for f32 {}
}

/// Element types a [`Section`] may hold: fixed-size scalars for which
/// every bit pattern is a valid value, so reinterpreting mapped file bytes
/// can never produce an invalid representation. Sealed — the on-disk
/// format enumerates exactly these four dtypes.
pub trait Pod: sealed::Sealed + Copy + Sized + 'static {
    /// Dtype code in the on-disk section table (DESIGN.md §13).
    const DTYPE: u8;
    /// Dtype name in the human-readable meta.json sidecar.
    const DTYPE_NAME: &'static str;
}

impl Pod for u8 {
    const DTYPE: u8 = 1;
    const DTYPE_NAME: &'static str = "u8";
}
impl Pod for u32 {
    const DTYPE: u8 = 2;
    const DTYPE_NAME: &'static str = "u32";
}
impl Pod for u64 {
    const DTYPE: u8 = 3;
    const DTYPE_NAME: &'static str = "u64";
}
impl Pod for f32 {
    const DTYPE: u8 = 4;
    const DTYPE_NAME: &'static str = "f32";
}

/// A whole file mapped read-only. Shared by every [`Section`] carved out
/// of it; the mapping is released when the last section drops.
pub struct MmapFile {
    ptr: *mut libc::c_void,
    len: usize,
    path: PathBuf,
}

// SAFETY: the mapping is PROT_READ and never mutated or remapped after
// construction, so shared references from any thread are fine.
unsafe impl Send for MmapFile {}
unsafe impl Sync for MmapFile {}

impl MmapFile {
    pub fn open(path: &Path) -> Result<Arc<MmapFile>> {
        let file =
            File::open(path).with_context(|| format!("opening {} to map", path.display()))?;
        let len = file.metadata()?.len() as usize;
        if len == 0 {
            // mmap(len=0) is EINVAL; an empty file is a valid (if useless)
            // zero-section map.
            return Ok(Arc::new(MmapFile {
                ptr: std::ptr::null_mut(),
                len: 0,
                path: path.to_path_buf(),
            }));
        }
        // SAFETY: fresh read-only private mapping of a file we hold open;
        // length matches the file, offset 0.
        let ptr = unsafe {
            libc::mmap(
                std::ptr::null_mut(),
                len,
                libc::PROT_READ,
                libc::MAP_PRIVATE,
                file.as_raw_fd(),
                0,
            )
        };
        if ptr == libc::MAP_FAILED {
            bail!(
                "mmap of {} ({} bytes) failed: {}",
                path.display(),
                len,
                std::io::Error::last_os_error()
            );
        }
        Ok(Arc::new(MmapFile { ptr, len, path: path.to_path_buf() }))
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    #[inline]
    pub fn bytes(&self) -> &[u8] {
        if self.len == 0 {
            &[]
        } else {
            // SAFETY: ptr/len describe a live PROT_READ mapping owned by
            // self; the returned slice borrows self.
            unsafe { std::slice::from_raw_parts(self.ptr as *const u8, self.len) }
        }
    }
}

impl Drop for MmapFile {
    fn drop(&mut self) {
        if self.len != 0 {
            // SAFETY: ptr/len came from a successful mmap and are unmapped
            // exactly once.
            unsafe {
                libc::munmap(self.ptr, self.len);
            }
        }
    }
}

impl fmt::Debug for MmapFile {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "MmapFile({}, {} bytes)", self.path.display(), self.len)
    }
}

enum Back<T: Pod> {
    Heap(Vec<T>),
    Mapped {
        file: Arc<MmapFile>,
        byte_off: usize,
        len: usize,
        _marker: PhantomData<T>,
    },
}

/// One field array of the partition structure, behind the storage seam.
/// Derefs to `&[T]`, so all read paths are backend-oblivious; only
/// construction and the residency accounting know the difference.
pub struct Section<T: Pod> {
    back: Back<T>,
}

impl<T: Pod> Section<T> {
    /// A window of `len` elements at `byte_off` into a mapped file.
    /// Validates bounds and alignment up front so `deref` is infallible.
    pub fn mapped(file: Arc<MmapFile>, byte_off: usize, len: usize) -> Result<Section<T>> {
        let nbytes = len
            .checked_mul(std::mem::size_of::<T>())
            .context("section byte length overflows")?;
        let end = byte_off.checked_add(nbytes).context("section end overflows")?;
        if end > file.len() {
            bail!(
                "section [{byte_off}, {end}) exceeds {} ({} bytes)",
                file.path().display(),
                file.len()
            );
        }
        if byte_off % std::mem::align_of::<T>() != 0 {
            bail!(
                "section offset {byte_off} is not {}-byte aligned in {}",
                std::mem::align_of::<T>(),
                file.path().display()
            );
        }
        Ok(Section {
            back: Back::Mapped { file, byte_off, len, _marker: PhantomData },
        })
    }

    #[inline]
    pub fn is_mapped(&self) -> bool {
        matches!(self.back, Back::Mapped { .. })
    }

    /// Bytes this section keeps resident on the heap (0 when mapped —
    /// mapped pages are the kernel's to cache and evict).
    pub fn heap_bytes(&self) -> usize {
        match &self.back {
            Back::Heap(v) => v.len() * std::mem::size_of::<T>(),
            Back::Mapped { .. } => 0,
        }
    }

    /// Bytes this section addresses through a file mapping.
    pub fn mapped_bytes(&self) -> usize {
        match &self.back {
            Back::Heap(_) => 0,
            Back::Mapped { len, .. } => len * std::mem::size_of::<T>(),
        }
    }
}

impl<T: Pod> Deref for Section<T> {
    type Target = [T];

    #[inline]
    fn deref(&self) -> &[T] {
        match &self.back {
            Back::Heap(v) => v,
            Back::Mapped { file, byte_off, len, .. } => {
                if *len == 0 {
                    return &[];
                }
                // SAFETY: bounds + alignment were validated in `mapped`;
                // T is Pod (any bit pattern valid); the mapping is
                // read-only and outlives the slice via the Arc.
                unsafe {
                    std::slice::from_raw_parts(
                        file.bytes().as_ptr().add(*byte_off) as *const T,
                        *len,
                    )
                }
            }
        }
    }
}

impl<T: Pod> From<Vec<T>> for Section<T> {
    fn from(v: Vec<T>) -> Section<T> {
        Section { back: Back::Heap(v) }
    }
}

impl<T: Pod> Default for Section<T> {
    fn default() -> Section<T> {
        Vec::new().into()
    }
}

impl<T: Pod> Clone for Section<T> {
    fn clone(&self) -> Section<T> {
        match &self.back {
            Back::Heap(v) => Section { back: Back::Heap(v.clone()) },
            Back::Mapped { file, byte_off, len, .. } => Section {
                back: Back::Mapped {
                    file: Arc::clone(file),
                    byte_off: *byte_off,
                    len: *len,
                    _marker: PhantomData,
                },
            },
        }
    }
}

impl<T: Pod + fmt::Debug> fmt::Debug for Section<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_list().entries(self.iter()).finish()
    }
}

impl<T: Pod + PartialEq> PartialEq for Section<T> {
    fn eq(&self, other: &Section<T>) -> bool {
        self[..] == other[..]
    }
}

impl<T: Pod + PartialEq> PartialEq<Vec<T>> for Section<T> {
    fn eq(&self, other: &Vec<T>) -> bool {
        self[..] == other[..]
    }
}

impl<T: Pod + PartialEq> PartialEq<Section<T>> for Vec<T> {
    fn eq(&self, other: &Section<T>) -> bool {
        self[..] == other[..]
    }
}

impl<'a, T: Pod> IntoIterator for &'a Section<T> {
    type Item = &'a T;
    type IntoIter = std::slice::Iter<'a, T>;

    fn into_iter(self) -> Self::IntoIter {
        (**self).iter()
    }
}

/// Read-only partition-membership bit matrix over a [`Section`] of words —
/// the seam-aware twin of `util::bitset::BitMatrix` (which stays `Vec`
/// -backed and mutable for the builders). Row = local vertex, bit =
/// partition id.
#[derive(Clone, Debug)]
pub struct PartBits {
    words: Section<u64>,
    words_per_row: usize,
    bits: usize,
}

impl PartBits {
    /// Freeze a builder-produced matrix (heap words, zero copy).
    pub fn from_matrix(m: BitMatrix) -> PartBits {
        let bits = m.bits();
        let words_per_row = bits.div_ceil(64).max(1);
        PartBits { words: m.into_raw().into(), words_per_row, bits }
    }

    /// Wrap a word section (heap or mapped) as `bits`-wide rows.
    pub fn from_words(words: Section<u64>, bits: usize) -> Result<PartBits> {
        let words_per_row = bits.div_ceil(64).max(1);
        if words.len() % words_per_row != 0 {
            bail!(
                "partition_set holds {} words, not a multiple of {words_per_row} ({bits} bits/row)",
                words.len()
            );
        }
        Ok(PartBits { words, words_per_row, bits })
    }

    pub fn rows(&self) -> usize {
        self.words.len() / self.words_per_row
    }

    pub fn bits(&self) -> usize {
        self.bits
    }

    #[inline]
    pub fn get(&self, row: usize, bit: usize) -> bool {
        debug_assert!(bit < self.bits);
        self.words[row * self.words_per_row + bit / 64] >> (bit % 64) & 1 == 1
    }

    pub fn row_count(&self, row: usize) -> usize {
        let start = row * self.words_per_row;
        self.words[start..start + self.words_per_row]
            .iter()
            .map(|w| w.count_ones() as usize)
            .sum()
    }

    pub fn row_ones(&self, row: usize) -> impl Iterator<Item = usize> + '_ {
        let start = row * self.words_per_row;
        self.words[start..start + self.words_per_row]
            .iter()
            .enumerate()
            .flat_map(|(wi, &w)| {
                let mut w = w;
                std::iter::from_fn(move || {
                    if w == 0 {
                        None
                    } else {
                        let b = w.trailing_zeros() as usize;
                        w &= w - 1;
                        Some(wi * 64 + b)
                    }
                })
            })
    }

    /// Raw words — the serialized form in the binary layout.
    pub fn raw(&self) -> &[u64] {
        &self.words
    }

    pub fn nbytes(&self) -> usize {
        self.words.len() * 8
    }

    pub fn heap_bytes(&self) -> usize {
        self.words.heap_bytes()
    }

    pub fn mapped_bytes(&self) -> usize {
        self.words.mapped_bytes()
    }
}

/// Which backing [`open_partitions`] and `glisp serve --load` use.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StoreBackend {
    /// Decode the file into heap `Vec`s (the pre-seam behavior).
    Heap,
    /// Map the file and serve sections out of it, zero-copy.
    Mmap,
}

impl StoreBackend {
    pub fn name(self) -> &'static str {
        match self {
            StoreBackend::Heap => "heap",
            StoreBackend::Mmap => "mmap",
        }
    }
}

/// The pluggable opener: one saved partition in, a `PartitionGraph` whose
/// sections are backed per the store's policy out. Both stores decode the
/// same v2 layout with the same strict checks; they differ only in where
/// the section bytes live afterwards.
pub trait PartitionStore: Send + Sync {
    fn open(&self, dir: &Path, name: &str) -> Result<PartitionGraph>;
    fn backend(&self) -> StoreBackend;
}

/// `Vec`-backed: every section copied onto the heap at open time.
pub struct HeapStore;

impl PartitionStore for HeapStore {
    fn open(&self, dir: &Path, name: &str) -> Result<PartitionGraph> {
        io::load_partition(dir, name)
    }

    fn backend(&self) -> StoreBackend {
        StoreBackend::Heap
    }
}

/// mmap-backed: sections are windows into the mapped file; heap residency
/// of the structure is O(1) in the graph size.
pub struct MmapStore;

impl PartitionStore for MmapStore {
    fn open(&self, dir: &Path, name: &str) -> Result<PartitionGraph> {
        io::map_partition(dir, name)
    }

    fn backend(&self) -> StoreBackend {
        StoreBackend::Mmap
    }
}

/// The store singleton for a backend choice.
pub fn store(backend: StoreBackend) -> &'static dyn PartitionStore {
    match backend {
        StoreBackend::Heap => &HeapStore,
        StoreBackend::Mmap => &MmapStore,
    }
}

/// Open every partition of a saved set (`part0..partN`), inferring N from
/// part0's header and cross-checking each file's identity.
pub fn open_partitions(dir: &Path, backend: StoreBackend) -> Result<Vec<PartitionGraph>> {
    let s = store(backend);
    let first = s
        .open(dir, "part0")
        .with_context(|| format!("opening partition set in {}", dir.display()))?;
    if first.part_id != 0 {
        bail!("part0 in {} claims part_id {}", dir.display(), first.part_id);
    }
    let num_parts = first.num_parts;
    let mut parts = vec![first];
    for p in 1..num_parts {
        let g = s.open(dir, &format!("part{p}"))?;
        if g.part_id != p || g.num_parts != num_parts {
            bail!(
                "part{p} in {} claims part_id {} of {} (expected {p} of {num_parts})",
                dir.display(),
                g.part_id,
                g.num_parts
            );
        }
        parts.push(g);
    }
    Ok(parts)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("glisp_store_{name}"))
    }

    #[test]
    fn mmap_file_round_trips_bytes() {
        let p = tmp("bytes.bin");
        std::fs::write(&p, [1u8, 2, 3, 4, 5]).unwrap();
        let m = MmapFile::open(&p).unwrap();
        assert_eq!(m.bytes(), &[1, 2, 3, 4, 5]);
        assert_eq!(m.len(), 5);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn mmap_of_empty_file_is_empty_not_an_error() {
        let p = tmp("empty.bin");
        std::fs::write(&p, []).unwrap();
        let m = MmapFile::open(&p).unwrap();
        assert!(m.is_empty());
        assert_eq!(m.bytes(), &[] as &[u8]);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn mapped_section_reads_little_endian_payload() {
        let p = tmp("sec.bin");
        let mut bytes = Vec::new();
        for x in [7u32, 8, 9] {
            bytes.extend_from_slice(&x.to_le_bytes());
        }
        bytes.extend_from_slice(&1.5f32.to_le_bytes());
        std::fs::write(&p, &bytes).unwrap();
        let m = MmapFile::open(&p).unwrap();
        let s = Section::<u32>::mapped(m.clone(), 0, 3).unwrap();
        assert_eq!(s, vec![7u32, 8, 9]);
        assert_eq!(s.heap_bytes(), 0);
        assert_eq!(s.mapped_bytes(), 12);
        let f = Section::<f32>::mapped(m, 12, 1).unwrap();
        assert_eq!(f[0], 1.5);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn mapped_section_rejects_overrun_and_misalignment() {
        let p = tmp("bad.bin");
        std::fs::write(&p, [0u8; 16]).unwrap();
        let m = MmapFile::open(&p).unwrap();
        assert!(Section::<u64>::mapped(m.clone(), 0, 3).is_err(), "overrun");
        assert!(Section::<u64>::mapped(m.clone(), 4, 1).is_err(), "misaligned");
        assert!(Section::<u64>::mapped(m, 8, 1).is_ok());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn heap_and_mapped_sections_compare_and_iterate_alike() {
        let heap: Section<u32> = vec![3u32, 1, 4].into();
        assert!(!heap.is_mapped());
        assert_eq!(heap.heap_bytes(), 12);
        assert_eq!(heap.mapped_bytes(), 0);
        let collected: Vec<u32> = (&heap).into_iter().copied().collect();
        assert_eq!(collected, vec![3, 1, 4]);
        assert_eq!(heap.clone(), heap);
        assert_eq!(vec![3u32, 1, 4], heap);
        assert_eq!(format!("{heap:?}"), "[3, 1, 4]");
    }

    #[test]
    fn part_bits_matches_bit_matrix_semantics() {
        let mut m = BitMatrix::new(3, 70);
        m.set(0, 1);
        m.set(1, 69);
        m.set(1, 3);
        let raw = m.raw().to_vec();
        let pb = PartBits::from_matrix(m);
        assert_eq!(pb.rows(), 3);
        assert_eq!(pb.bits(), 70);
        assert!(pb.get(0, 1) && pb.get(1, 69) && !pb.get(2, 5));
        assert_eq!(pb.row_count(1), 2);
        assert_eq!(pb.row_ones(1).collect::<Vec<_>>(), vec![3, 69]);
        assert_eq!(pb.raw(), &raw[..]);
        assert_eq!(pb.nbytes(), raw.len() * 8);
        // Word count must tile into rows.
        assert!(PartBits::from_words(vec![0u64; 3].into(), 70).is_err());
    }
}
