//! Graph reorder algorithms (paper §II-C, §III-D). A reordering is a
//! permutation `order` where `order[rank] = vertex`: the vertex that gets
//! new consecutive ID `rank`. The inference engine assigns cache-local IDs
//! with these; Fig. 14 compares them.
//!
//! Keys (paper §IV-E): NS = global_id, DS = degree (desc), PS =
//! (partition_id, global_id), PDS = (partition_id, degree desc) — the
//! paper's contribution, reusing locality already mined by the partitioner.
//! BFS and Hub-Clustering are the classic lightweight comparators.

use crate::graph::csr::{Graph, VId};
use crate::util::bitset::BitSet;

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ReorderAlgo {
    /// Natural Sort — identity (ids as they arrive).
    NS,
    /// Degree Sort, descending.
    DS,
    /// Partition Sort: (partition, global id).
    PS,
    /// Partition based Degree Sort: (partition, degree desc) — GLISP's PDS.
    PDS,
    /// Breadth-first order from the highest-degree vertex.
    BFS,
    /// Hub clustering: hubs (deg > avg) first in degree order, then each
    /// hub's non-hub neighbors grouped behind it.
    HubCluster,
}

impl ReorderAlgo {
    pub fn name(&self) -> &'static str {
        match self {
            ReorderAlgo::NS => "NS",
            ReorderAlgo::DS => "DS",
            ReorderAlgo::PS => "PS",
            ReorderAlgo::PDS => "PDS",
            ReorderAlgo::BFS => "BFS",
            ReorderAlgo::HubCluster => "Hub",
        }
    }
}

/// Compute `order[rank] = vertex`. `part_of` gives each vertex's (primary)
/// partition for PS/PDS; pass `&[]` for partition-free algorithms.
pub fn reorder(g: &Graph, algo: ReorderAlgo, part_of: &[u16]) -> Vec<VId> {
    match algo {
        ReorderAlgo::NS => (0..g.n as VId).collect(),
        ReorderAlgo::DS => {
            let deg = total_degrees(g);
            let mut order: Vec<VId> = (0..g.n as VId).collect();
            order.sort_by_key(|&v| (std::cmp::Reverse(deg[v as usize]), v));
            order
        }
        ReorderAlgo::PS => {
            assert_eq!(part_of.len(), g.n, "PS needs a partition map");
            let mut order: Vec<VId> = (0..g.n as VId).collect();
            order.sort_by_key(|&v| (part_of[v as usize], v));
            order
        }
        ReorderAlgo::PDS => {
            assert_eq!(part_of.len(), g.n, "PDS needs a partition map");
            let deg = total_degrees(g);
            let mut order: Vec<VId> = (0..g.n as VId).collect();
            order.sort_by_key(|&v| {
                (
                    part_of[v as usize],
                    std::cmp::Reverse(deg[v as usize]),
                    v,
                )
            });
            order
        }
        ReorderAlgo::BFS => bfs_order(g),
        ReorderAlgo::HubCluster => hub_cluster(g),
    }
}

/// Inverse permutation: `rank_of[vertex] = rank` (the vertex's new ID).
pub fn rank_of(order: &[VId]) -> Vec<u32> {
    let mut rank = vec![0u32; order.len()];
    for (r, &v) in order.iter().enumerate() {
        rank[v as usize] = r as u32;
    }
    rank
}

fn total_degrees(g: &Graph) -> Vec<u32> {
    let ins = g.in_degrees();
    g.out_degrees()
        .iter()
        .zip(&ins)
        .map(|(&o, &i)| o + i)
        .collect()
}

fn bfs_order(g: &Graph) -> Vec<VId> {
    let deg = total_degrees(g);
    let mut order = Vec::with_capacity(g.n);
    let mut visited = BitSet::new(g.n);
    // Seed from the highest-degree vertex of each component, in degree order.
    let mut by_deg: Vec<VId> = (0..g.n as VId).collect();
    by_deg.sort_by_key(|&v| (std::cmp::Reverse(deg[v as usize]), v));
    let mut queue = std::collections::VecDeque::new();
    for &seed in &by_deg {
        if visited.get(seed as usize) {
            continue;
        }
        visited.set(seed as usize);
        queue.push_back(seed);
        while let Some(v) = queue.pop_front() {
            order.push(v);
            for &w in g.out_neighbors(v) {
                if !visited.get(w as usize) {
                    visited.set(w as usize);
                    queue.push_back(w);
                }
            }
        }
    }
    order
}

fn hub_cluster(g: &Graph) -> Vec<VId> {
    let deg = total_degrees(g);
    let avg = deg.iter().map(|&d| d as f64).sum::<f64>() / g.n.max(1) as f64;
    let mut order = Vec::with_capacity(g.n);
    let mut placed = BitSet::new(g.n);
    let mut hubs: Vec<VId> = (0..g.n as VId)
        .filter(|&v| deg[v as usize] as f64 > avg)
        .collect();
    hubs.sort_by_key(|&v| (std::cmp::Reverse(deg[v as usize]), v));
    for &h in &hubs {
        if !placed.get(h as usize) {
            placed.set(h as usize);
            order.push(h);
        }
        for &w in g.out_neighbors(h) {
            if !placed.get(w as usize) {
                placed.set(w as usize);
                order.push(w);
            }
        }
    }
    for v in 0..g.n as VId {
        if !placed.get(v as usize) {
            order.push(v);
        }
    }
    order
}

/// Locality figure of merit: average |rank(u) - rank(v)| over edges,
/// normalized by n. Lower = spatially closer neighbors = fewer chunks
/// touched by the inference engine.
pub fn avg_edge_span(g: &Graph, order: &[VId]) -> f64 {
    let rank = rank_of(order);
    let mut total = 0f64;
    for u in 0..g.n {
        for &v in g.out_neighbors(u as VId) {
            total += (rank[u] as f64 - rank[v as usize] as f64).abs();
        }
    }
    total / (g.m().max(1) as f64) / g.n.max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generator;
    use crate::util::rng::Rng;

    fn powerlaw() -> Graph {
        let mut rng = Rng::new(21);
        generator::chung_lu(3000, 24_000, 2.1, &mut rng)
    }

    fn assert_permutation(order: &[VId], n: usize) {
        assert_eq!(order.len(), n);
        let mut seen = vec![false; n];
        for &v in order {
            assert!(!seen[v as usize], "dup {v}");
            seen[v as usize] = true;
        }
    }

    #[test]
    fn all_algorithms_produce_permutations() {
        let g = powerlaw();
        let part: Vec<u16> = (0..g.n).map(|v| (v % 4) as u16).collect();
        for algo in [
            ReorderAlgo::NS,
            ReorderAlgo::DS,
            ReorderAlgo::PS,
            ReorderAlgo::PDS,
            ReorderAlgo::BFS,
            ReorderAlgo::HubCluster,
        ] {
            let order = reorder(&g, algo, &part);
            assert_permutation(&order, g.n);
        }
    }

    #[test]
    fn ds_is_degree_descending() {
        let g = powerlaw();
        let order = reorder(&g, ReorderAlgo::DS, &[]);
        let deg = total_degrees(&g);
        for w in order.windows(2) {
            assert!(deg[w[0] as usize] >= deg[w[1] as usize]);
        }
    }

    #[test]
    fn pds_groups_by_partition_then_degree() {
        let g = powerlaw();
        let part: Vec<u16> = (0..g.n).map(|v| (v % 3) as u16).collect();
        let order = reorder(&g, ReorderAlgo::PDS, &part);
        let deg = total_degrees(&g);
        for w in order.windows(2) {
            let (p0, p1) = (part[w[0] as usize], part[w[1] as usize]);
            assert!(p0 <= p1);
            if p0 == p1 {
                assert!(deg[w[0] as usize] >= deg[w[1] as usize]);
            }
        }
    }

    #[test]
    fn rank_inverts_order() {
        let g = powerlaw();
        let order = reorder(&g, ReorderAlgo::DS, &[]);
        let rank = rank_of(&order);
        for (r, &v) in order.iter().enumerate() {
            assert_eq!(rank[v as usize] as usize, r);
        }
    }

    #[test]
    fn bfs_improves_span_over_random_scramble() {
        let g = powerlaw();
        let bfs = reorder(&g, ReorderAlgo::BFS, &[]);
        let mut scrambled: Vec<VId> = (0..g.n as VId).collect();
        Rng::new(5).shuffle(&mut scrambled);
        assert!(avg_edge_span(&g, &bfs) < avg_edge_span(&g, &scrambled));
    }
}
