//! Binary (de)serialization of the compact partition structure — "a simple
//! contiguous binary layout, with the data size and type of each field being
//! maintained in a separate meta file" (paper §III-C).
//!
//! `<name>.bin` holds the raw little-endian field arrays back-to-back;
//! `<name>.meta.json` lists each field's name/dtype/element count plus the
//! partition header, so loading is a sequence of exact-size reads into
//! pre-allocated vectors — no parsing on the data path.

use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::graph::hetero::PartitionGraph;
use crate::util::bitset::BitMatrix;
use crate::util::json::{emit, Json};

struct FieldMeta {
    name: &'static str,
    dtype: &'static str,
    count: usize,
}

fn fields_of(p: &PartitionGraph) -> Vec<(FieldMeta, Vec<u8>)> {
    fn f32s(v: &[f32]) -> Vec<u8> {
        v.iter().flat_map(|x| x.to_le_bytes()).collect()
    }
    fn u32s(v: &[u32]) -> Vec<u8> {
        v.iter().flat_map(|x| x.to_le_bytes()).collect()
    }
    fn u64s(v: &[u64]) -> Vec<u8> {
        v.iter().flat_map(|x| x.to_le_bytes()).collect()
    }
    vec![
        (
            FieldMeta { name: "global_id", dtype: "u32", count: p.global_id.len() },
            u32s(&p.global_id),
        ),
        (
            FieldMeta { name: "out_indptr", dtype: "u64", count: p.out_indptr.len() },
            u64s(&p.out_indptr),
        ),
        (
            FieldMeta { name: "out_dst", dtype: "u32", count: p.out_dst.len() },
            u32s(&p.out_dst),
        ),
        (
            FieldMeta { name: "out_weight", dtype: "f32", count: p.out_weight.len() },
            f32s(&p.out_weight),
        ),
        (
            FieldMeta { name: "out_et_indptr", dtype: "u32", count: p.out_et_indptr.len() },
            u32s(&p.out_et_indptr),
        ),
        (
            FieldMeta { name: "out_et_ids", dtype: "u8", count: p.out_et_ids.len() },
            p.out_et_ids.clone(),
        ),
        (
            FieldMeta { name: "out_et_end", dtype: "u32", count: p.out_et_end.len() },
            u32s(&p.out_et_end),
        ),
        (
            FieldMeta { name: "in_indptr", dtype: "u64", count: p.in_indptr.len() },
            u64s(&p.in_indptr),
        ),
        (
            FieldMeta { name: "in_src", dtype: "u32", count: p.in_src.len() },
            u32s(&p.in_src),
        ),
        (
            FieldMeta { name: "in_eid", dtype: "u32", count: p.in_eid.len() },
            u32s(&p.in_eid),
        ),
        (
            FieldMeta { name: "out_deg_global", dtype: "u32", count: p.out_deg_global.len() },
            u32s(&p.out_deg_global),
        ),
        (
            FieldMeta { name: "in_deg_global", dtype: "u32", count: p.in_deg_global.len() },
            u32s(&p.in_deg_global),
        ),
        (
            FieldMeta { name: "partition_set", dtype: "u64", count: p.partition_set.raw().len() },
            u64s(p.partition_set.raw()),
        ),
    ]
}

pub fn save_partition(p: &PartitionGraph, dir: &Path, name: &str) -> Result<()> {
    std::fs::create_dir_all(dir)?;
    let fields = fields_of(p);
    let mut meta_fields = Vec::new();
    let bin_path = dir.join(format!("{name}.bin"));
    let mut w = BufWriter::new(File::create(&bin_path)?);
    for (m, bytes) in &fields {
        w.write_all(bytes)?;
        let mut obj = std::collections::BTreeMap::new();
        obj.insert("name".into(), Json::Str(m.name.into()));
        obj.insert("dtype".into(), Json::Str(m.dtype.into()));
        obj.insert("count".into(), Json::Num(m.count as f64));
        meta_fields.push(Json::Obj(obj));
    }
    w.flush()?;
    let mut meta = std::collections::BTreeMap::new();
    meta.insert("part_id".into(), Json::Num(p.part_id as f64));
    meta.insert("num_parts".into(), Json::Num(p.num_parts as f64));
    meta.insert("fields".into(), Json::Arr(meta_fields));
    std::fs::write(
        dir.join(format!("{name}.meta.json")),
        emit(&Json::Obj(meta)),
    )?;
    Ok(())
}

pub fn load_partition(dir: &Path, name: &str) -> Result<PartitionGraph> {
    let meta_raw = std::fs::read_to_string(dir.join(format!("{name}.meta.json")))
        .with_context(|| format!("missing meta for {name}"))?;
    let meta = Json::parse(&meta_raw).context("bad meta json")?;
    let part_id = meta.get("part_id").and_then(Json::as_usize).context("part_id")?;
    let num_parts = meta.get("num_parts").and_then(Json::as_usize).context("num_parts")?;
    let mut r = BufReader::new(File::open(dir.join(format!("{name}.bin")))?);

    fn read_u32s(r: &mut impl Read, n: usize) -> Result<Vec<u32>> {
        let mut buf = vec![0u8; n * 4];
        r.read_exact(&mut buf)?;
        Ok(buf.chunks_exact(4).map(|c| u32::from_le_bytes(c.try_into().unwrap())).collect())
    }
    fn read_u64s(r: &mut impl Read, n: usize) -> Result<Vec<u64>> {
        let mut buf = vec![0u8; n * 8];
        r.read_exact(&mut buf)?;
        Ok(buf.chunks_exact(8).map(|c| u64::from_le_bytes(c.try_into().unwrap())).collect())
    }
    fn read_f32s(r: &mut impl Read, n: usize) -> Result<Vec<f32>> {
        let mut buf = vec![0u8; n * 4];
        r.read_exact(&mut buf)?;
        Ok(buf.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect())
    }

    let mut g = PartitionGraph {
        part_id,
        num_parts,
        global_id: Vec::new(),
        out_indptr: Vec::new(),
        out_dst: Vec::new(),
        out_weight: Vec::new(),
        out_et_indptr: Vec::new(),
        out_et_ids: Vec::new(),
        out_et_end: Vec::new(),
        in_indptr: Vec::new(),
        in_src: Vec::new(),
        in_eid: Vec::new(),
        out_deg_global: Vec::new(),
        in_deg_global: Vec::new(),
        partition_set: BitMatrix::new(0, num_parts),
    };
    for f in meta.get("fields").and_then(Json::as_arr).context("fields")? {
        let name = f.get("name").and_then(Json::as_str).context("field name")?;
        let count = f.get("count").and_then(Json::as_usize).context("field count")?;
        match name {
            "global_id" => g.global_id = read_u32s(&mut r, count)?,
            "out_indptr" => g.out_indptr = read_u64s(&mut r, count)?,
            "out_dst" => g.out_dst = read_u32s(&mut r, count)?,
            "out_weight" => g.out_weight = read_f32s(&mut r, count)?,
            "out_et_indptr" => g.out_et_indptr = read_u32s(&mut r, count)?,
            "out_et_ids" => {
                let mut buf = vec![0u8; count];
                r.read_exact(&mut buf)?;
                g.out_et_ids = buf;
            }
            "out_et_end" => g.out_et_end = read_u32s(&mut r, count)?,
            "in_indptr" => g.in_indptr = read_u64s(&mut r, count)?,
            "in_src" => g.in_src = read_u32s(&mut r, count)?,
            "in_eid" => g.in_eid = read_u32s(&mut r, count)?,
            "out_deg_global" => g.out_deg_global = read_u32s(&mut r, count)?,
            "in_deg_global" => g.in_deg_global = read_u32s(&mut r, count)?,
            "partition_set" => {
                g.partition_set =
                    BitMatrix::from_raw(read_u64s(&mut r, count)?, num_parts)
            }
            other => bail!("unknown field {other}"),
        }
    }
    Ok(g)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generator;
    use crate::graph::hetero::build_partitions;
    use crate::util::rng::Rng;

    #[test]
    fn round_trip_preserves_everything() {
        let mut rng = Rng::new(40);
        let g = generator::heterogeneous_graph(800, 6000, 2, 3, 2.2, &mut rng);
        let assign: Vec<u16> = (0..g.m()).map(|e| (e % 2) as u16).collect();
        let parts = build_partitions(&g, &assign, 2).unwrap();
        let dir = std::env::temp_dir().join("glisp_io_test");
        save_partition(&parts[0], &dir, "p0").unwrap();
        let loaded = load_partition(&dir, "p0").unwrap();
        assert_eq!(loaded.global_id, parts[0].global_id);
        assert_eq!(loaded.out_indptr, parts[0].out_indptr);
        assert_eq!(loaded.out_dst, parts[0].out_dst);
        assert_eq!(loaded.out_weight, parts[0].out_weight);
        assert_eq!(loaded.out_et_ids, parts[0].out_et_ids);
        assert_eq!(loaded.out_et_end, parts[0].out_et_end);
        assert_eq!(loaded.in_src, parts[0].in_src);
        assert_eq!(loaded.in_eid, parts[0].in_eid);
        assert_eq!(loaded.partition_set.raw(), parts[0].partition_set.raw());
        assert_eq!(loaded.nbytes(), parts[0].nbytes());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_meta_errors() {
        let dir = std::env::temp_dir().join("glisp_io_missing");
        assert!(load_partition(&dir, "nope").is_err());
    }

    /// The full offline→online contract: AdaDNE (parallel propose) →
    /// parallel build → save → load → pooled SamplingService must
    /// reproduce the in-memory service's sampled bits exactly — the disk
    /// layout carries everything the per-seed RNG contract (DESIGN.md §9)
    /// depends on.
    #[test]
    fn saved_partitions_reproduce_in_memory_sample_bits() {
        use crate::graph::hetero::build_partitions_threads;
        use crate::partition::{AdaDNE, Partitioner};
        use crate::sampling::{sample_tree, SampleConfig, SamplingService, ServiceConfig};

        let mut rng = Rng::new(41);
        let g = generator::heterogeneous_graph(900, 9000, 2, 3, 2.2, &mut rng);
        let ea = AdaDNE {
            threads: 2,
            ..Default::default()
        }
        .partition(&g, 3, 1);
        let parts = build_partitions_threads(&g, &ea.part_of_edge, 3, 2).unwrap();

        let dir = std::env::temp_dir().join("glisp_io_sampling_round_trip");
        let _ = std::fs::remove_dir_all(&dir);
        let mut loaded = Vec::new();
        for p in &parts {
            save_partition(p, &dir, &format!("part{}", p.part_id)).unwrap();
            loaded.push(load_partition(&dir, &format!("part{}", p.part_id)).unwrap());
        }

        let cfg = ServiceConfig::new(2, 8);
        let mem = SamplingService::launch_with_partitions_cfg(g.n, parts, 1, cfg);
        let disk = SamplingService::launch_with_partitions_cfg(g.n, loaded, 1, cfg);
        let seeds: Vec<u32> = (0..64).collect();
        for scfg in [
            SampleConfig::default(),
            SampleConfig {
                weighted: true,
                ..Default::default()
            },
        ] {
            let mut mc = mem.client(9);
            let mut dc = disk.client(9);
            let tm = sample_tree(&mut mc, &seeds, &[6, 4], &scfg).unwrap();
            let td = sample_tree(&mut dc, &seeds, &[6, 4], &scfg).unwrap();
            assert_eq!(tm.levels, td.levels, "sampled ids drifted after save/load");
            assert_eq!(tm.masks, td.masks);
        }
        mem.shutdown();
        disk.shutdown();
        std::fs::remove_dir_all(&dir).ok();
    }
}
