//! Binary (de)serialization of the compact partition structure — "a simple
//! contiguous binary layout, with the data size and type of each field being
//! maintained in a separate meta file" (paper §III-C).
//!
//! Format v2 (DESIGN.md §13): `<name>.bin` opens with a magic header and a
//! fixed-order section table (field id, dtype, 8-byte-aligned byte offset,
//! element count), followed by the raw little-endian field arrays with zero
//! padding between sections. The self-describing header is what lets
//! `MmapStore` serve sections straight out of the mapped file with no
//! copies, and it makes decoding strict the way `sampling::wire` is: bad
//! magic, unknown version, truncation, misalignment or trailing bytes are
//! hard errors, not garbage structures. `<name>.meta.json` is still written
//! as the paper's human-readable sidecar, but loading reads only the `.bin`
//! header.

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::graph::hetero::PartitionGraph;
use crate::graph::store::{MmapFile, PartBits, Section};
use crate::util::json::{emit, Json};

/// First four bytes of every saved partition.
pub const MAGIC: [u8; 4] = *b"GLSP";
/// Bump on ANY layout change (field added/removed/reordered, dtype or
/// header change) — old readers must reject new files and vice versa.
pub const FORMAT_VERSION: u16 = 2;

const NUM_SECTIONS: usize = 13;
const HEADER_BYTES: usize = 24;
const ENTRY_BYTES: usize = 24;
/// Where the first section's payload starts (header + table, 8-aligned).
const TABLE_END: usize = HEADER_BYTES + NUM_SECTIONS * ENTRY_BYTES;

/// Dtype codes in the section table (match `store::Pod::DTYPE`).
const DT_U8: u8 = 1;
const DT_U32: u8 = 2;
const DT_U64: u8 = 3;
const DT_F32: u8 = 4;

/// The 13 sections in their fixed on-disk order.
const FIELDS: [(&str, u8); NUM_SECTIONS] = [
    ("global_id", DT_U32),
    ("out_indptr", DT_U64),
    ("out_dst", DT_U32),
    ("out_weight", DT_F32),
    ("out_et_indptr", DT_U32),
    ("out_et_ids", DT_U8),
    ("out_et_end", DT_U32),
    ("in_indptr", DT_U64),
    ("in_src", DT_U32),
    ("in_eid", DT_U32),
    ("out_deg_global", DT_U32),
    ("in_deg_global", DT_U32),
    ("partition_set", DT_U64),
];

fn dtype_size(code: u8) -> usize {
    match code {
        DT_U8 => 1,
        DT_U32 | DT_F32 => 4,
        DT_U64 => 8,
        _ => unreachable!("dtype codes are validated before sizing"),
    }
}

fn dtype_name(code: u8) -> &'static str {
    match code {
        DT_U8 => "u8",
        DT_U32 => "u32",
        DT_U64 => "u64",
        DT_F32 => "f32",
        _ => unreachable!(),
    }
}

fn pad8(n: usize) -> usize {
    n.div_ceil(8) * 8
}

fn field_counts(p: &PartitionGraph) -> [usize; NUM_SECTIONS] {
    [
        p.global_id.len(),
        p.out_indptr.len(),
        p.out_dst.len(),
        p.out_weight.len(),
        p.out_et_indptr.len(),
        p.out_et_ids.len(),
        p.out_et_end.len(),
        p.in_indptr.len(),
        p.in_src.len(),
        p.in_eid.len(),
        p.out_deg_global.len(),
        p.in_deg_global.len(),
        p.partition_set.raw().len(),
    ]
}

pub fn save_partition(p: &PartitionGraph, dir: &Path, name: &str) -> Result<()> {
    std::fs::create_dir_all(dir)?;
    let counts = field_counts(p);
    // Lay out the sections: contiguous, each start 8-byte aligned (zero
    // padding), so every dtype maps alignment-safe at any offset.
    let mut offs = [0usize; NUM_SECTIONS];
    let mut off = TABLE_END;
    for (i, &count) in counts.iter().enumerate() {
        offs[i] = off;
        off += pad8(count * dtype_size(FIELDS[i].1));
    }
    let total_len = off as u64;

    let bin_path = dir.join(format!("{name}.bin"));
    let mut w = BufWriter::new(File::create(&bin_path)?);
    w.write_all(&MAGIC)?;
    w.write_all(&FORMAT_VERSION.to_le_bytes())?;
    w.write_all(&(NUM_SECTIONS as u16).to_le_bytes())?;
    w.write_all(&(p.part_id as u32).to_le_bytes())?;
    w.write_all(&(p.num_parts as u32).to_le_bytes())?;
    w.write_all(&total_len.to_le_bytes())?;
    for (i, &(_, dtype)) in FIELDS.iter().enumerate() {
        w.write_all(&(i as u16).to_le_bytes())?;
        w.write_all(&[dtype])?;
        w.write_all(&[0u8; 5])?; // reserved
        w.write_all(&(offs[i] as u64).to_le_bytes())?;
        w.write_all(&(counts[i] as u64).to_le_bytes())?;
    }

    fn u32s(w: &mut impl Write, v: &[u32]) -> std::io::Result<()> {
        for x in v {
            w.write_all(&x.to_le_bytes())?;
        }
        Ok(())
    }
    fn u64s(w: &mut impl Write, v: &[u64]) -> std::io::Result<()> {
        for x in v {
            w.write_all(&x.to_le_bytes())?;
        }
        Ok(())
    }
    fn f32s(w: &mut impl Write, v: &[f32]) -> std::io::Result<()> {
        for x in v {
            w.write_all(&x.to_le_bytes())?;
        }
        Ok(())
    }
    fn pad(w: &mut impl Write, nbytes: usize) -> std::io::Result<()> {
        w.write_all(&[0u8; 8][..nbytes])
    }

    for (i, &count) in counts.iter().enumerate() {
        let nbytes = count * dtype_size(FIELDS[i].1);
        match i {
            0 => u32s(&mut w, &p.global_id)?,
            1 => u64s(&mut w, &p.out_indptr)?,
            2 => u32s(&mut w, &p.out_dst)?,
            3 => f32s(&mut w, &p.out_weight)?,
            4 => u32s(&mut w, &p.out_et_indptr)?,
            5 => w.write_all(&p.out_et_ids)?,
            6 => u32s(&mut w, &p.out_et_end)?,
            7 => u64s(&mut w, &p.in_indptr)?,
            8 => u32s(&mut w, &p.in_src)?,
            9 => u32s(&mut w, &p.in_eid)?,
            10 => u32s(&mut w, &p.out_deg_global)?,
            11 => u32s(&mut w, &p.in_deg_global)?,
            12 => u64s(&mut w, p.partition_set.raw())?,
            _ => unreachable!(),
        }
        pad(&mut w, pad8(nbytes) - nbytes)?;
    }
    w.flush()?;

    // Human-readable sidecar (paper §III-C); informational only — the
    // loader trusts the binary header.
    let mut meta_fields = Vec::new();
    for (i, &(fname, dtype)) in FIELDS.iter().enumerate() {
        let mut obj = std::collections::BTreeMap::new();
        obj.insert("name".into(), Json::Str(fname.into()));
        obj.insert("dtype".into(), Json::Str(dtype_name(dtype).into()));
        obj.insert("count".into(), Json::Num(counts[i] as f64));
        obj.insert("offset".into(), Json::Num(offs[i] as f64));
        meta_fields.push(Json::Obj(obj));
    }
    let mut meta = std::collections::BTreeMap::new();
    meta.insert("format_version".into(), Json::Num(FORMAT_VERSION as f64));
    meta.insert("part_id".into(), Json::Num(p.part_id as f64));
    meta.insert("num_parts".into(), Json::Num(p.num_parts as f64));
    meta.insert("fields".into(), Json::Arr(meta_fields));
    std::fs::write(dir.join(format!("{name}.meta.json")), emit(&Json::Obj(meta)))?;
    Ok(())
}

#[derive(Clone, Copy, Debug)]
struct SectionDesc {
    off: usize,
    count: usize,
}

struct Layout {
    part_id: usize,
    num_parts: usize,
    sections: [SectionDesc; NUM_SECTIONS],
}

/// Strict header + section-table decode, shared by the heap and mmap
/// loaders. `bytes` must be the entire file: truncation, trailing bytes,
/// overlap, misalignment or nonzero padding all fail here, before any
/// section is touched.
fn parse_layout(bytes: &[u8], what: &str) -> Result<Layout> {
    if bytes.len() < TABLE_END {
        bail!("{what}: truncated — {} bytes, header+table need {TABLE_END}", bytes.len());
    }
    if bytes[0..4] != MAGIC {
        bail!("{what}: bad magic {:02x?} (expected {:02x?} \"GLSP\")", &bytes[0..4], MAGIC);
    }
    let rd_u16 = |o: usize| u16::from_le_bytes(bytes[o..o + 2].try_into().unwrap());
    let rd_u32 = |o: usize| u32::from_le_bytes(bytes[o..o + 4].try_into().unwrap());
    let rd_u64 = |o: usize| u64::from_le_bytes(bytes[o..o + 8].try_into().unwrap());
    let version = rd_u16(4);
    if version != FORMAT_VERSION {
        bail!("{what}: format version {version}, this build reads only {FORMAT_VERSION}");
    }
    let nsec = rd_u16(6) as usize;
    if nsec != NUM_SECTIONS {
        bail!("{what}: {nsec} sections, expected {NUM_SECTIONS}");
    }
    let part_id = rd_u32(8) as usize;
    let num_parts = rd_u32(12) as usize;
    if num_parts == 0 || part_id >= num_parts {
        bail!("{what}: header claims part {part_id} of {num_parts}");
    }
    let total_len = rd_u64(16);
    if total_len != bytes.len() as u64 {
        bail!(
            "{what}: header says {total_len} bytes but the file has {} — truncated or grown",
            bytes.len()
        );
    }
    if total_len % 8 != 0 {
        bail!("{what}: total length {total_len} is not 8-byte aligned");
    }
    let mut sections = [SectionDesc { off: 0, count: 0 }; NUM_SECTIONS];
    let mut expect_off = TABLE_END;
    for (i, sec) in sections.iter_mut().enumerate() {
        let e = HEADER_BYTES + i * ENTRY_BYTES;
        let fid = rd_u16(e) as usize;
        let dtype = bytes[e + 2];
        if fid != i || dtype != FIELDS[i].1 {
            bail!(
                "{what}: section {i} is (field {fid}, dtype {dtype}), expected (field {i}, \
                 dtype {}) [{}]",
                FIELDS[i].1,
                FIELDS[i].0
            );
        }
        if bytes[e + 3..e + 8].iter().any(|&b| b != 0) {
            bail!("{what}: section {i} has nonzero reserved bytes");
        }
        let off = rd_u64(e + 8) as usize;
        let count = rd_u64(e + 16) as usize;
        if off != expect_off {
            bail!(
                "{what}: section {i} ({}) at offset {off}, expected {expect_off} — \
                 sections must be contiguous and 8-aligned",
                FIELDS[i].0
            );
        }
        let nbytes = count
            .checked_mul(dtype_size(dtype))
            .with_context(|| format!("{what}: section {i} size overflows"))?;
        let end = off + nbytes;
        if end > bytes.len() {
            bail!("{what}: section {i} ({}) runs to {end}, past EOF", FIELDS[i].0);
        }
        if bytes[end..off + pad8(nbytes)].iter().any(|&b| b != 0) {
            bail!("{what}: nonzero padding after section {i} ({})", FIELDS[i].0);
        }
        *sec = SectionDesc { off, count };
        expect_off = off + pad8(nbytes);
    }
    if expect_off != bytes.len() {
        bail!(
            "{what}: {} trailing bytes after the last section",
            bytes.len() - expect_off
        );
    }
    Ok(Layout { part_id, num_parts, sections })
}

fn assemble(
    part_id: usize,
    num_parts: usize,
    mut sec: impl FnMut(usize) -> Result<RawSection>,
) -> Result<PartitionGraph> {
    macro_rules! take {
        ($i:expr, $variant:ident) => {
            match sec($i)? {
                RawSection::$variant(s) => s,
                _ => unreachable!("dtype fixed by the validated table"),
            }
        };
    }
    Ok(PartitionGraph {
        part_id,
        num_parts,
        global_id: take!(0, U32),
        out_indptr: take!(1, U64),
        out_dst: take!(2, U32),
        out_weight: take!(3, F32),
        out_et_indptr: take!(4, U32),
        out_et_ids: take!(5, U8),
        out_et_end: take!(6, U32),
        in_indptr: take!(7, U64),
        in_src: take!(8, U32),
        in_eid: take!(9, U32),
        out_deg_global: take!(10, U32),
        in_deg_global: take!(11, U32),
        partition_set: PartBits::from_words(take!(12, U64), num_parts)?,
    })
}

enum RawSection {
    U8(Section<u8>),
    U32(Section<u32>),
    U64(Section<u64>),
    F32(Section<f32>),
}

/// `HeapStore` open: strict-decode the file and copy every section into
/// heap `Vec`s — the pre-seam loading behavior.
pub fn load_partition(dir: &Path, name: &str) -> Result<PartitionGraph> {
    let path = dir.join(format!("{name}.bin"));
    let bytes = std::fs::read(&path).with_context(|| format!("reading {}", path.display()))?;
    let what = path.display().to_string();
    let layout = parse_layout(&bytes, &what)?;
    assemble(layout.part_id, layout.num_parts, |i| {
        let d = layout.sections[i];
        let sz = dtype_size(FIELDS[i].1);
        let raw = &bytes[d.off..d.off + d.count * sz];
        Ok(match FIELDS[i].1 {
            DT_U8 => RawSection::U8(raw.to_vec().into()),
            DT_U32 => RawSection::U32(
                raw.chunks_exact(4)
                    .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
                    .collect::<Vec<_>>()
                    .into(),
            ),
            DT_U64 => RawSection::U64(
                raw.chunks_exact(8)
                    .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
                    .collect::<Vec<_>>()
                    .into(),
            ),
            DT_F32 => RawSection::F32(
                raw.chunks_exact(4)
                    .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                    .collect::<Vec<_>>()
                    .into(),
            ),
            _ => unreachable!(),
        })
    })
}

/// `MmapStore` open: strict-decode the same header, then serve every
/// section as a zero-copy window into the mapped file. Bit-identical to
/// [`load_partition`] on any little-endian host (the only kind the raw
/// layout targets; big-endian is rejected rather than silently byte-swapped
/// on the heap path only).
pub fn map_partition(dir: &Path, name: &str) -> Result<PartitionGraph> {
    if cfg!(target_endian = "big") {
        bail!("MmapStore reinterprets little-endian file bytes in place; use HeapStore here");
    }
    let path = dir.join(format!("{name}.bin"));
    let map = MmapFile::open(&path)?;
    let what = path.display().to_string();
    let layout = parse_layout(map.bytes(), &what)?;
    assemble(layout.part_id, layout.num_parts, |i| {
        let d = layout.sections[i];
        Ok(match FIELDS[i].1 {
            DT_U8 => RawSection::U8(Section::mapped(map.clone(), d.off, d.count)?),
            DT_U32 => RawSection::U32(Section::mapped(map.clone(), d.off, d.count)?),
            DT_U64 => RawSection::U64(Section::mapped(map.clone(), d.off, d.count)?),
            DT_F32 => RawSection::F32(Section::mapped(map.clone(), d.off, d.count)?),
            _ => unreachable!(),
        })
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generator;
    use crate::graph::hetero::build_partitions;
    use crate::util::rng::Rng;

    #[test]
    fn round_trip_preserves_everything() {
        let mut rng = Rng::new(40);
        let g = generator::heterogeneous_graph(800, 6000, 2, 3, 2.2, &mut rng);
        let assign: Vec<u16> = (0..g.m()).map(|e| (e % 2) as u16).collect();
        let parts = build_partitions(&g, &assign, 2).unwrap();
        let dir = std::env::temp_dir().join("glisp_io_test");
        save_partition(&parts[0], &dir, "p0").unwrap();
        let loaded = load_partition(&dir, "p0").unwrap();
        assert_eq!(loaded.global_id, parts[0].global_id);
        assert_eq!(loaded.out_indptr, parts[0].out_indptr);
        assert_eq!(loaded.out_dst, parts[0].out_dst);
        assert_eq!(loaded.out_weight, parts[0].out_weight);
        assert_eq!(loaded.out_et_ids, parts[0].out_et_ids);
        assert_eq!(loaded.out_et_end, parts[0].out_et_end);
        assert_eq!(loaded.in_src, parts[0].in_src);
        assert_eq!(loaded.in_eid, parts[0].in_eid);
        assert_eq!(loaded.partition_set.raw(), parts[0].partition_set.raw());
        assert_eq!(loaded.nbytes(), parts[0].nbytes());
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Every section a mapped partition serves must be byte-equal to the
    /// heap load, with zero heap residency for the structure itself.
    #[test]
    fn mapped_partition_serves_identical_sections() {
        let mut rng = Rng::new(42);
        let g = generator::heterogeneous_graph(700, 5000, 2, 3, 2.2, &mut rng);
        let assign: Vec<u16> = (0..g.m()).map(|e| (e % 2) as u16).collect();
        let parts = build_partitions(&g, &assign, 2).unwrap();
        let dir = std::env::temp_dir().join("glisp_io_map_test");
        let _ = std::fs::remove_dir_all(&dir);
        for p in &parts {
            save_partition(p, &dir, &format!("part{}", p.part_id)).unwrap();
            let mapped = map_partition(&dir, &format!("part{}", p.part_id)).unwrap();
            assert_eq!(mapped.global_id, p.global_id);
            assert_eq!(mapped.out_indptr, p.out_indptr);
            assert_eq!(mapped.out_dst, p.out_dst);
            assert_eq!(mapped.out_weight, p.out_weight);
            assert_eq!(mapped.out_et_indptr, p.out_et_indptr);
            assert_eq!(mapped.out_et_ids, p.out_et_ids);
            assert_eq!(mapped.out_et_end, p.out_et_end);
            assert_eq!(mapped.in_indptr, p.in_indptr);
            assert_eq!(mapped.in_src, p.in_src);
            assert_eq!(mapped.in_eid, p.in_eid);
            assert_eq!(mapped.out_deg_global, p.out_deg_global);
            assert_eq!(mapped.in_deg_global, p.in_deg_global);
            assert_eq!(mapped.partition_set.raw(), p.partition_set.raw());
            assert_eq!(mapped.nbytes(), p.nbytes());
            assert_eq!(mapped.heap_bytes(), 0, "mapped structure must keep nothing on heap");
            assert_eq!(mapped.mapped_bytes(), p.nbytes());
            assert_eq!(p.heap_bytes(), p.nbytes());
            assert_eq!(p.mapped_bytes(), 0);
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_file_errors() {
        let dir = std::env::temp_dir().join("glisp_io_missing");
        assert!(load_partition(&dir, "nope").is_err());
        assert!(map_partition(&dir, "nope").is_err());
    }

    /// Strict decode: bad magic, foreign version, truncation, bit-flipped
    /// padding and trailing bytes are hard errors on BOTH load paths.
    #[test]
    fn format_rejection_is_strict_on_both_stores() {
        let mut rng = Rng::new(43);
        let g = generator::heterogeneous_graph(300, 2000, 2, 3, 2.2, &mut rng);
        let assign: Vec<u16> = vec![0u16; g.m()];
        let parts = build_partitions(&g, &assign, 1).unwrap();
        let dir = std::env::temp_dir().join("glisp_io_reject");
        let _ = std::fs::remove_dir_all(&dir);
        save_partition(&parts[0], &dir, "good").unwrap();
        let good = std::fs::read(dir.join("good.bin")).unwrap();

        let write = |name: &str, bytes: &[u8]| {
            std::fs::write(dir.join(format!("{name}.bin")), bytes).unwrap();
        };
        let rejected = |name: &str, why: &str| {
            let h = load_partition(&dir, name);
            let m = map_partition(&dir, name);
            assert!(h.is_err(), "heap load accepted {why}");
            assert!(m.is_err(), "mmap open accepted {why}");
        };

        let mut bad = good.clone();
        bad[0] = b'X';
        write("magic", &bad);
        rejected("magic", "bad magic");

        let mut bad = good.clone();
        bad[4] = 99; // version
        write("version", &bad);
        rejected("version", "foreign version");

        write("trunc_header", &good[..10]);
        rejected("trunc_header", "truncated header");

        write("trunc_body", &good[..good.len() - 8]);
        rejected("trunc_body", "truncated body");

        let mut bad = good.clone();
        bad.extend_from_slice(&[0u8; 8]);
        write("trailing", &bad);
        rejected("trailing", "trailing bytes");

        let mut bad = good.clone();
        bad[HEADER_BYTES + 3] = 1; // reserved byte of section 0
        write("reserved", &bad);
        rejected("reserved", "nonzero reserved bytes");

        // The untouched file still loads — the rejections above are not
        // false positives from the harness.
        assert!(load_partition(&dir, "good").is_ok());
        assert!(map_partition(&dir, "good").is_ok());
        std::fs::remove_dir_all(&dir).ok();
    }

    /// The full offline→online contract: AdaDNE (parallel propose) →
    /// parallel build → save → load → pooled SamplingService must
    /// reproduce the in-memory service's sampled bits exactly — the disk
    /// layout carries everything the per-seed RNG contract (DESIGN.md §9)
    /// depends on.
    #[test]
    fn saved_partitions_reproduce_in_memory_sample_bits() {
        use crate::graph::hetero::build_partitions_threads;
        use crate::partition::{AdaDNE, Partitioner};
        use crate::sampling::{sample_tree, SampleConfig, SamplingService, ServiceConfig};

        let mut rng = Rng::new(41);
        let g = generator::heterogeneous_graph(900, 9000, 2, 3, 2.2, &mut rng);
        let ea = AdaDNE {
            threads: 2,
            ..Default::default()
        }
        .partition(&g, 3, 1);
        let parts = build_partitions_threads(&g, &ea.part_of_edge, 3, 2).unwrap();

        let dir = std::env::temp_dir().join("glisp_io_sampling_round_trip");
        let _ = std::fs::remove_dir_all(&dir);
        let mut loaded = Vec::new();
        for p in &parts {
            save_partition(p, &dir, &format!("part{}", p.part_id)).unwrap();
            loaded.push(load_partition(&dir, &format!("part{}", p.part_id)).unwrap());
        }

        let cfg = ServiceConfig::new(2, 8);
        let mem = SamplingService::launch_with_partitions_cfg(g.n, parts, 1, cfg);
        let disk = SamplingService::launch_with_partitions_cfg(g.n, loaded, 1, cfg);
        let seeds: Vec<u32> = (0..64).collect();
        for scfg in [
            SampleConfig::default(),
            SampleConfig {
                weighted: true,
                ..Default::default()
            },
        ] {
            let mut mc = mem.client(9);
            let mut dc = disk.client(9);
            let tm = sample_tree(&mut mc, &seeds, &[6, 4], &scfg).unwrap();
            let td = sample_tree(&mut dc, &seeds, &[6, 4], &scfg).unwrap();
            assert_eq!(tm.levels, td.levels, "sampled ids drifted after save/load");
            assert_eq!(tm.masks, td.masks);
        }
        mem.shutdown();
        disk.shutdown();
        std::fs::remove_dir_all(&dir).ok();
    }

    /// The same contract through the mmap seam: a pooled service over
    /// `MmapStore` partitions samples bit-identically to the in-memory
    /// build — the store serves identical array views, so the per-seed RNG
    /// contract sees no difference (DESIGN.md §13).
    #[test]
    fn mapped_partitions_reproduce_in_memory_sample_bits() {
        use crate::graph::hetero::build_partitions_threads;
        use crate::graph::store::{open_partitions, StoreBackend};
        use crate::partition::{AdaDNE, Partitioner};
        use crate::sampling::{sample_tree, SampleConfig, SamplingService, ServiceConfig};

        let mut rng = Rng::new(44);
        let g = generator::heterogeneous_graph(900, 9000, 2, 3, 2.2, &mut rng);
        let ea = AdaDNE {
            threads: 2,
            ..Default::default()
        }
        .partition(&g, 3, 1);
        let parts = build_partitions_threads(&g, &ea.part_of_edge, 3, 2).unwrap();

        let dir = std::env::temp_dir().join("glisp_io_mmap_sampling");
        let _ = std::fs::remove_dir_all(&dir);
        for p in &parts {
            save_partition(p, &dir, &format!("part{}", p.part_id)).unwrap();
        }
        let mapped = open_partitions(&dir, StoreBackend::Mmap).unwrap();
        assert!(mapped.iter().all(|p| p.heap_bytes() == 0));

        let cfg = ServiceConfig::new(2, 8);
        let mem = SamplingService::launch_with_partitions_cfg(g.n, parts, 1, cfg);
        let disk = SamplingService::launch_with_partitions_cfg(g.n, mapped, 1, cfg);
        let seeds: Vec<u32> = (0..64).collect();
        for scfg in [
            SampleConfig::default(),
            SampleConfig {
                weighted: true,
                ..Default::default()
            },
        ] {
            let mut mc = mem.client(9);
            let mut dc = disk.client(9);
            let tm = sample_tree(&mut mc, &seeds, &[6, 4], &scfg).unwrap();
            let td = sample_tree(&mut dc, &seeds, &[6, 4], &scfg).unwrap();
            assert_eq!(tm.levels, td.levels, "sampled ids drifted through the mmap seam");
            assert_eq!(tm.masks, td.masks);
        }
        mem.shutdown();
        disk.shutdown();
        std::fs::remove_dir_all(&dir).ok();
    }
}
