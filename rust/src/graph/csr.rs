//! In-memory full graph in CSR form — the partitioner's input and the
//! generators' output. Vertex ids are `u32` (the synthetic suite tops out at
//! a few million vertices); per-vertex/per-edge attributes are optional so
//! homogeneous graphs pay nothing.

pub type VId = u32;
pub type EId = u32;

/// Directed multigraph in CSR (out-edges), with optional heterogeneous
/// vertex/edge types and edge weights.
#[derive(Clone, Debug, Default)]
pub struct Graph {
    pub n: usize,
    /// CSR row offsets, len n+1.
    pub indptr: Vec<u64>,
    /// Destination of each out-edge, len m.
    pub dst: Vec<VId>,
    /// Vertex type per vertex (empty = homogeneous).
    pub vtype: Vec<u8>,
    /// Edge type per out-edge, aligned with `dst` (empty = homogeneous).
    pub etype: Vec<u8>,
    /// Edge weight per out-edge (empty = unweighted/1.0).
    pub weight: Vec<f32>,
    /// Class label per vertex (empty = unlabeled); used by Table IV tasks.
    pub label: Vec<u16>,
}

impl Graph {
    /// Build from an edge list (src, dst); attrs attached afterwards.
    pub fn from_edges(n: usize, edges: &[(VId, VId)]) -> Self {
        let mut deg = vec![0u64; n];
        for &(s, _) in edges {
            deg[s as usize] += 1;
        }
        let mut indptr = vec![0u64; n + 1];
        for i in 0..n {
            indptr[i + 1] = indptr[i] + deg[i];
        }
        let mut cursor = indptr.clone();
        let mut dst = vec![0 as VId; edges.len()];
        for &(s, d) in edges {
            let c = &mut cursor[s as usize];
            dst[*c as usize] = d;
            *c += 1;
        }
        Graph {
            n,
            indptr,
            dst,
            ..Default::default()
        }
    }

    /// Like `from_edges` but carries (etype, weight) per edge in the same
    /// order, preserving alignment through the CSR bucket sort.
    pub fn from_typed_edges(n: usize, edges: &[(VId, VId, u8, f32)]) -> Self {
        let mut deg = vec![0u64; n];
        for &(s, ..) in edges {
            deg[s as usize] += 1;
        }
        let mut indptr = vec![0u64; n + 1];
        for i in 0..n {
            indptr[i + 1] = indptr[i] + deg[i];
        }
        let mut cursor = indptr.clone();
        let mut dst = vec![0 as VId; edges.len()];
        let mut etype = vec![0u8; edges.len()];
        let mut weight = vec![0f32; edges.len()];
        for &(s, d, t, w) in edges {
            let c = &mut cursor[s as usize];
            let i = *c as usize;
            dst[i] = d;
            etype[i] = t;
            weight[i] = w;
            *c += 1;
        }
        Graph {
            n,
            indptr,
            dst,
            etype,
            weight,
            ..Default::default()
        }
    }

    #[inline]
    pub fn m(&self) -> usize {
        self.dst.len()
    }

    #[inline]
    pub fn out_degree(&self, v: VId) -> usize {
        (self.indptr[v as usize + 1] - self.indptr[v as usize]) as usize
    }

    #[inline]
    pub fn out_neighbors(&self, v: VId) -> &[VId] {
        let (a, b) = self.edge_range(v);
        &self.dst[a..b]
    }

    /// Edge-id range [a, b) of v's out-edges.
    #[inline]
    pub fn edge_range(&self, v: VId) -> (usize, usize) {
        (
            self.indptr[v as usize] as usize,
            self.indptr[v as usize + 1] as usize,
        )
    }

    pub fn avg_degree(&self) -> f64 {
        self.m() as f64 / self.n.max(1) as f64
    }

    /// In-degree per vertex (one pass over edges).
    pub fn in_degrees(&self) -> Vec<u32> {
        let mut d = vec![0u32; self.n];
        for &v in &self.dst {
            d[v as usize] += 1;
        }
        d
    }

    pub fn out_degrees(&self) -> Vec<u32> {
        (0..self.n).map(|v| self.out_degree(v as VId) as u32).collect()
    }

    /// Reverse CSR: (in_indptr, in_src, in_eid) where in_eid is the index of
    /// the corresponding out-edge. Needed by the partitioners (incident
    /// edges) and the paper's `in_edges` field.
    pub fn reverse_csr(&self) -> (Vec<u64>, Vec<VId>, Vec<EId>) {
        let mut deg = vec![0u64; self.n];
        for &v in &self.dst {
            deg[v as usize] += 1;
        }
        let mut indptr = vec![0u64; self.n + 1];
        for i in 0..self.n {
            indptr[i + 1] = indptr[i] + deg[i];
        }
        let mut cursor = indptr.clone();
        let mut src = vec![0 as VId; self.m()];
        let mut eid = vec![0 as EId; self.m()];
        for u in 0..self.n {
            let (a, b) = self.edge_range(u as VId);
            for e in a..b {
                let v = self.dst[e] as usize;
                let c = &mut cursor[v];
                src[*c as usize] = u as VId;
                eid[*c as usize] = e as EId;
                *c += 1;
            }
        }
        (indptr, src, eid)
    }

    /// Undirected incidence adjacency: for each vertex, the (edge_id,
    /// other_endpoint) of every incident edge in either direction. This is
    /// the neighbor-expansion view used by DNE/AdaDNE.
    pub fn incidence(&self) -> Incidence {
        let mut deg = vec![0u64; self.n];
        for u in 0..self.n {
            let (a, b) = self.edge_range(u as VId);
            deg[u] += (b - a) as u64;
            for e in a..b {
                deg[self.dst[e] as usize] += 1;
            }
        }
        let mut indptr = vec![0u64; self.n + 1];
        for i in 0..self.n {
            indptr[i + 1] = indptr[i] + deg[i];
        }
        let mut cursor = indptr.clone();
        let mut eid = vec![0 as EId; 2 * self.m()];
        let mut other = vec![0 as VId; 2 * self.m()];
        for u in 0..self.n {
            let (a, b) = self.edge_range(u as VId);
            for e in a..b {
                let v = self.dst[e];
                let cu = &mut cursor[u];
                eid[*cu as usize] = e as EId;
                other[*cu as usize] = v;
                *cu += 1;
                let cv = &mut cursor[v as usize];
                eid[*cv as usize] = e as EId;
                other[*cv as usize] = u as VId;
                *cv += 1;
            }
        }
        Incidence {
            indptr,
            eid,
            other,
        }
    }

    pub fn edge_weight(&self, e: usize) -> f32 {
        if self.weight.is_empty() {
            1.0
        } else {
            self.weight[e]
        }
    }

    pub fn edge_type(&self, e: usize) -> u8 {
        if self.etype.is_empty() {
            0
        } else {
            self.etype[e]
        }
    }

    pub fn num_edge_types(&self) -> usize {
        self.etype.iter().map(|&t| t as usize + 1).max().unwrap_or(1)
    }

    pub fn num_vertex_types(&self) -> usize {
        self.vtype.iter().map(|&t| t as usize + 1).max().unwrap_or(1)
    }
}

/// Undirected incidence view (see [`Graph::incidence`]).
pub struct Incidence {
    pub indptr: Vec<u64>,
    pub eid: Vec<EId>,
    pub other: Vec<VId>,
}

impl Incidence {
    #[inline]
    pub fn edges_of(&self, v: VId) -> impl Iterator<Item = (EId, VId)> + '_ {
        let a = self.indptr[v as usize] as usize;
        let b = self.indptr[v as usize + 1] as usize;
        (a..b).map(move |i| (self.eid[i], self.other[i]))
    }

    #[inline]
    pub fn degree(&self, v: VId) -> usize {
        (self.indptr[v as usize + 1] - self.indptr[v as usize]) as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn diamond() -> Graph {
        // 0->1, 0->2, 1->3, 2->3, 3->0
        Graph::from_edges(4, &[(0, 1), (0, 2), (1, 3), (2, 3), (3, 0)])
    }

    #[test]
    fn csr_shape() {
        let g = diamond();
        assert_eq!(g.n, 4);
        assert_eq!(g.m(), 5);
        assert_eq!(g.out_neighbors(0), &[1, 2]);
        assert_eq!(g.out_neighbors(3), &[0]);
        assert_eq!(g.out_degree(1), 1);
    }

    #[test]
    fn reverse_matches_forward() {
        let g = diamond();
        let (ip, src, eid) = g.reverse_csr();
        // in-neighbors of 3 are {1, 2}
        let a = ip[3] as usize;
        let b = ip[4] as usize;
        let mut ins: Vec<VId> = src[a..b].to_vec();
        ins.sort_unstable();
        assert_eq!(ins, vec![1, 2]);
        // every in-edge id maps back to an out-edge with the right endpoints
        for v in 0..g.n {
            for i in ip[v] as usize..ip[v + 1] as usize {
                let e = eid[i] as usize;
                assert_eq!(g.dst[e] as usize, v);
            }
        }
    }

    #[test]
    fn incidence_degree_counts_both_directions() {
        let g = diamond();
        let inc = g.incidence();
        assert_eq!(inc.degree(0), 3); // out:1,2 in:3
        assert_eq!(inc.degree(3), 3); // in:1,2 out:0
        let total: usize = (0..4).map(|v| inc.degree(v as VId)).sum();
        assert_eq!(total, 2 * g.m());
    }

    #[test]
    fn typed_edges_alignment() {
        let g = Graph::from_typed_edges(
            3,
            &[(2, 0, 1, 0.5), (0, 1, 0, 1.0), (0, 2, 3, 2.0)],
        );
        // vertex 0's edges keep their (etype, weight) pairing
        let (a, b) = g.edge_range(0);
        for e in a..b {
            match g.dst[e] {
                1 => {
                    assert_eq!(g.etype[e], 0);
                    assert_eq!(g.weight[e], 1.0);
                }
                2 => {
                    assert_eq!(g.etype[e], 3);
                    assert_eq!(g.weight[e], 2.0);
                }
                _ => panic!(),
            }
        }
        assert_eq!(g.num_edge_types(), 4);
    }
}
