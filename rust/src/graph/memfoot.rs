//! Memory-footprint models for Table III: bytes each framework's documented
//! graph layout needs for the same heterogeneous graph. GLISP's number is
//! measured from the real structure (`PartitionGraph::nbytes`); the
//! comparators are byte-accounting models of the layouts the paper
//! describes (§I, §III-C):
//!
//! * **DistDGL/GraphLearn-style**: one homogeneous graph per edge type
//!   (CSR per type, each with its own vertex id array and explicit
//!   global↔local id map), so per-type fixed costs multiply.
//! * **Euler-style**: a single graph but a stored type id per edge PLUS a
//!   per-vertex per-type index (offset table) — per-edge and per-vertex
//!   overheads add up.
//!
//! These are models, not reimplementations of third-party code — see
//! DESIGN.md §3 (substitutions). The *relative* ordering they produce is
//! what Table III asserts.

use crate::graph::csr::Graph;
use crate::graph::hetero::PartitionGraph;

/// Measured bytes of GLISP's compact structure over all partitions.
pub fn glisp_bytes(parts: &[PartitionGraph]) -> usize {
    parts.iter().map(|p| p.nbytes()).sum()
}

/// Where a structure's bytes actually live — the out-of-core seam's
/// measured answer (DESIGN.md §13). `heap` is owned allocations that
/// count against the process budget; `mapped` is file-backed mmap pages
/// the kernel can drop and re-fault at will, so they do not.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Residency {
    pub heap_bytes: usize,
    pub mapped_bytes: usize,
}

impl Residency {
    pub fn total(&self) -> usize {
        self.heap_bytes + self.mapped_bytes
    }
}

/// Measured residency of a partition set: splits [`glisp_bytes`] by
/// backing. A `HeapStore`-opened set is all heap; an `MmapStore`-opened
/// set is all mapped.
pub fn partition_residency(parts: &[PartitionGraph]) -> Residency {
    Residency {
        heap_bytes: parts.iter().map(|p| p.heap_bytes()).sum(),
        mapped_bytes: parts.iter().map(|p| p.mapped_bytes()).sum(),
    }
}

/// Process resident-set size in bytes from `/proc/self/statm` (Linux),
/// `None` elsewhere — the coarse cross-check for the budget scenario; the
/// assertions themselves use the deterministic [`Residency`] numbers.
pub fn process_rss_bytes() -> Option<usize> {
    let statm = std::fs::read_to_string("/proc/self/statm").ok()?;
    let resident_pages: usize = statm.split_whitespace().nth(1)?.parse().ok()?;
    let page = unsafe { libc::sysconf(libc::_SC_PAGESIZE) };
    if page <= 0 {
        return None;
    }
    Some(resident_pages * page as usize)
}

/// Heap budget for the out-of-core scenario: `GLISP_MEM_BUDGET` (bytes),
/// `None` when unset or unparsable.
pub fn mem_budget() -> Option<usize> {
    std::env::var("GLISP_MEM_BUDGET").ok()?.trim().parse().ok()
}

/// DistDGL-like: per edge type t, a homogeneous subgraph holding the
/// vertices incident to type-t edges: indptr (u64/vertex), dst (u32/edge,
/// stored as local ids), an explicit local→global id array (u64/vertex —
/// DistDGL uses int64 ids) and a global→local hash map (~16 B/entry:
/// key+value+load-factor overhead). Weights f32/edge. Degree arrays
/// int64/vertex for sampling.
pub fn distdgl_like_bytes(g: &Graph) -> usize {
    let ntypes = g.num_edge_types();
    let mut total = 0usize;
    for t in 0..ntypes {
        let mut edge_count = 0usize;
        let mut touched = vec![false; g.n];
        for u in 0..g.n {
            let (a, b) = g.edge_range(u as u32);
            for e in a..b {
                if g.edge_type(e) as usize == t {
                    edge_count += 1;
                    touched[u] = true;
                    touched[g.dst[e] as usize] = true;
                }
            }
        }
        let nv = touched.iter().filter(|&&x| x).count();
        total += (nv + 1) * 8 // CSR indptr int64
            + edge_count * 8 // CSR dst int64 (DGL uses int64 ids)
            + (nv + 1) * 8 // CSC indptr int64 (DGL materializes the reverse
            + edge_count * 8 // CSC src int64   format for in-neighbor sampling)
            + edge_count * 8 // CSC edge-id map int64
            + nv * 8 // local->global id array
            + nv * 16 // global->local hash map entry
            + nv * 8 // degree array int64
            + if g.weight.is_empty() { 0 } else { edge_count * 4 };
    }
    total
}

/// Euler-like: one CSR, int64 ids, plus a stored edge-type id per edge
/// (int32 in euler's proto layout) and a per-vertex edge-type index: for
/// each vertex, for each type present, an (type id, offset) pair, plus
/// per-vertex weight-sum tables for its weighted sampler.
pub fn euler_like_bytes(g: &Graph) -> usize {
    let mut type_runs = 0usize;
    for u in 0..g.n {
        let (a, b) = g.edge_range(u as u32);
        let mut seen = [false; 256];
        for e in a..b {
            let t = g.edge_type(e) as usize;
            if !seen[t] {
                seen[t] = true;
                type_runs += 1;
            }
        }
    }
    (g.n + 1) * 8 // out indptr int64
        + g.m() * 8 // out dst int64
        + (g.n + 1) * 8 // in indptr int64 (euler serves both directions)
        + g.m() * 8 // in src int64
        + 2 * g.m() * 4 // per-edge type id int32, stored for both directions
        + 2 * type_runs * 8 // per-vertex type index entries, both directions
        + g.n * 8 // degrees int64
        + g.m() * 4 // per-edge weight f32 (euler always stores weights)
        + g.n * 4 // per-vertex weight sums
}

/// GraphLearn-like hash-partitioned layout: same per-type decomposition as
/// DistDGL plus per-server hop tables keyed by hashed ids (~1.6× hash-table
/// overhead on adjacency storage, measured from its `IndexedGraph` design).
pub fn graphlearn_like_bytes(g: &Graph) -> usize {
    let base = distdgl_like_bytes(g);
    base + (g.m() * 8 * 6) / 10 // hash-bucket + pointer overhead on adjacency
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generator;
    use crate::graph::hetero::build_partitions;
    use crate::util::rng::Rng;

    #[test]
    fn glisp_is_smallest_on_heterogeneous_graph() {
        // Table III protocol: "to remove the data redundancy introduced by
        // different graph partition algorithms, we load the original graph
        // directly" — i.e. compare single-partition layouts.
        let mut rng = Rng::new(50);
        let g = generator::heterogeneous_graph(5_000, 60_000, 3, 4, 2.1, &mut rng);
        let assign: Vec<u16> = vec![0u16; g.m()];
        let parts = build_partitions(&g, &assign, 1).unwrap();
        let ours = glisp_bytes(&parts);
        let dgl = distdgl_like_bytes(&g);
        let euler = euler_like_bytes(&g);
        let gl = graphlearn_like_bytes(&g);
        assert!(ours < dgl, "glisp {ours} vs distdgl {dgl}");
        assert!(ours < euler, "glisp {ours} vs euler {euler}");
        assert!(dgl < gl, "graphlearn should exceed distdgl");
    }

    #[test]
    fn residency_splits_by_backing() {
        let mut rng = Rng::new(52);
        let g = generator::heterogeneous_graph(800, 6_000, 2, 3, 2.1, &mut rng);
        let assign: Vec<u16> = (0..g.m()).map(|e| (e % 2) as u16).collect();
        let parts = build_partitions(&g, &assign, 2).unwrap();
        let r = partition_residency(&parts);
        // In-memory build: everything on the heap, totals match nbytes.
        assert_eq!(r.mapped_bytes, 0);
        assert_eq!(r.heap_bytes, glisp_bytes(&parts));
        assert_eq!(r.total(), glisp_bytes(&parts));

        // Saved + mapped: everything file-backed, same totals.
        let dir = std::env::temp_dir().join("glisp_memfoot_res");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        for (i, p) in parts.iter().enumerate() {
            crate::graph::io::save_partition(p, &dir, &format!("part{i}")).unwrap();
        }
        let mapped =
            crate::graph::store::open_partitions(&dir, crate::graph::store::StoreBackend::Mmap)
                .unwrap();
        let rm = partition_residency(&mapped);
        assert_eq!(rm.heap_bytes, 0);
        assert_eq!(rm.mapped_bytes, glisp_bytes(&mapped));
        assert_eq!(rm.total(), r.total());
    }

    #[test]
    fn rss_is_measurable_on_linux() {
        if cfg!(target_os = "linux") {
            let rss = process_rss_bytes().expect("statm readable");
            assert!(rss > 0);
        }
    }

    #[test]
    fn models_scale_with_edge_types() {
        let mut rng = Rng::new(51);
        let g2 = generator::heterogeneous_graph(2_000, 20_000, 2, 2, 2.1, &mut rng);
        let g8 = generator::heterogeneous_graph(2_000, 20_000, 2, 8, 2.1, &mut rng);
        assert!(distdgl_like_bytes(&g8) > distdgl_like_bytes(&g2));
    }
}
