//! The compact, contiguous, read-only data structure for one vertex-cut
//! partition — paper Fig. 6. Distinctive properties reproduced here:
//!
//! * `global_id` is sorted ascending; the vertex **local ID is implicit**
//!   (position index), so global→local is a binary search (O(log N)) and
//!   local→global is an array access (O(1)) — no HashMap, no explicit map.
//! * out-edges are CSR sorted by `(src_local, edge_type, dst)`, so each
//!   vertex's neighbors are grouped by edge type; the per-edge type ID is
//!   NOT stored — it is recovered by binary search over the per-vertex
//!   run-length type index (`out_et_*`), which stores one (type, cumulative
//!   end) pair per run instead of one byte per edge.
//! * the **edge local ID is implicit** too: it is the position in `out_dst`.
//!   In-edges store `(src_global, edge_local_id)` — the paper's replacement
//!   of `(dst, src)` by `(dst, edge_id)` for O(1) edge-attribute access.
//! * `partition_set` is a bit array (vertex × partition) so the client can
//!   route Gather requests to every replica of a boundary vertex.
//! * global out/in degrees are carried per local vertex — the distributed
//!   uniform sampler needs `r = f · local_deg / global_deg`.

use std::path::Path;

use crate::graph::csr::{Graph, VId};
use crate::graph::store::{PartBits, Section};
use crate::util::bitset::BitMatrix;

/// Every field array sits behind the storage seam ([`Section`]): heap
/// `Vec`s when built or loaded by `HeapStore`, zero-copy windows into the
/// saved file when opened by `MmapStore`. All read APIs go through
/// `&[T]` deref, so the backing is invisible past this struct.
#[derive(Clone, Debug)]
pub struct PartitionGraph {
    pub part_id: usize,
    pub num_parts: usize,
    /// Sorted global IDs of the vertices present in this partition.
    pub global_id: Section<VId>,
    // --- out edges (CSR over local vertices, sorted by (etype, dst)) ---
    pub out_indptr: Section<u64>,
    pub out_dst: Section<VId>,
    /// Edge weights aligned with out_dst (empty if unweighted).
    pub out_weight: Section<f32>,
    // --- per-vertex edge-type run-length index ---
    /// Offsets into out_et_ids/out_et_end, len nv()+1.
    pub out_et_indptr: Section<u32>,
    /// Type ID of each run.
    pub out_et_ids: Section<u8>,
    /// Pre-accumulated (exclusive-end) local-edge offset of each run within
    /// its vertex's edge list.
    pub out_et_end: Section<u32>,
    // --- in edges: (dst_local implicit) -> (src_global, local edge id) ---
    pub in_indptr: Section<u64>,
    pub in_src: Section<VId>,
    pub in_eid: Section<u32>,
    // --- global degrees of local vertices ---
    pub out_deg_global: Section<u32>,
    pub in_deg_global: Section<u32>,
    /// Partition membership: row = local vertex, bit = partition id.
    pub partition_set: PartBits,
}

impl PartitionGraph {
    /// Number of (replicated) vertices in this partition.
    #[inline]
    pub fn nv(&self) -> usize {
        self.global_id.len()
    }

    /// Number of edges owned by this partition.
    #[inline]
    pub fn ne(&self) -> usize {
        self.out_dst.len()
    }

    /// Global → local: binary search over the sorted global_id array.
    #[inline]
    pub fn local_id(&self, gid: VId) -> Option<u32> {
        self.global_id.binary_search(&gid).ok().map(|i| i as u32)
    }

    /// Local → global: O(1) array access.
    #[inline]
    pub fn global(&self, local: u32) -> VId {
        self.global_id[local as usize]
    }

    #[inline]
    pub fn out_range(&self, local: u32) -> (usize, usize) {
        (
            self.out_indptr[local as usize] as usize,
            self.out_indptr[local as usize + 1] as usize,
        )
    }

    #[inline]
    pub fn out_neighbors(&self, local: u32) -> &[VId] {
        let (a, b) = self.out_range(local);
        &self.out_dst[a..b]
    }

    #[inline]
    pub fn local_out_degree(&self, local: u32) -> usize {
        let (a, b) = self.out_range(local);
        b - a
    }

    #[inline]
    pub fn in_range(&self, local: u32) -> (usize, usize) {
        (
            self.in_indptr[local as usize] as usize,
            self.in_indptr[local as usize + 1] as usize,
        )
    }

    #[inline]
    pub fn in_neighbors(&self, local: u32) -> &[VId] {
        let (a, b) = self.in_range(local);
        &self.in_src[a..b]
    }

    #[inline]
    pub fn local_in_degree(&self, local: u32) -> usize {
        let (a, b) = self.in_range(local);
        b - a
    }

    /// Absolute `[start, end)` local-edge index range of `local`'s
    /// out-edges restricted to `etype`, located via the run-length type
    /// index (runs per vertex are few; linear scan). Indices address
    /// `out_dst`/`out_weight` directly — this is what the sampling pool's
    /// shard workers use for weight lookup, with no pointer-provenance
    /// recovery. Empty range `(a, a)` when the vertex has no such edges.
    pub fn out_range_of_type(&self, local: u32, etype: u8) -> (usize, usize) {
        let (e0, _) = self.out_range(local);
        let (r0, r1) = (
            self.out_et_indptr[local as usize] as usize,
            self.out_et_indptr[local as usize + 1] as usize,
        );
        let mut start = 0u32;
        for r in r0..r1 {
            let end = self.out_et_end[r];
            if self.out_et_ids[r] == etype {
                return (e0 + start as usize, e0 + end as usize);
            }
            start = end;
        }
        (e0, e0)
    }

    /// Neighbors of `local` restricted to `etype` — a subslice of
    /// `out_dst` addressed by [`Self::out_range_of_type`].
    pub fn out_neighbors_of_type(&self, local: u32, etype: u8) -> &[VId] {
        let (a, b) = self.out_range_of_type(local, etype);
        &self.out_dst[a..b]
    }

    /// Recover the type of a local edge by binary search over its vertex's
    /// run index — the paper's trade of an O(log) query for per-edge bytes.
    pub fn edge_type_of(&self, local_edge: u32) -> u8 {
        // Find the owning vertex: binary search in out_indptr.
        let v = match self.out_indptr.binary_search(&(local_edge as u64)) {
            Ok(mut i) => {
                // Land on a boundary: the edge belongs to the next non-empty
                // vertex; indptr may contain repeats for empty vertices.
                while i + 1 < self.out_indptr.len()
                    && self.out_indptr[i + 1] == local_edge as u64
                {
                    i += 1;
                }
                i
            }
            Err(i) => i - 1,
        };
        let off = (local_edge as u64 - self.out_indptr[v]) as u32;
        let (r0, r1) = (
            self.out_et_indptr[v] as usize,
            self.out_et_indptr[v + 1] as usize,
        );
        // Binary search over pre-accumulated run ends.
        let runs = &self.out_et_end[r0..r1];
        let idx = match runs.binary_search(&(off + 1)) {
            Ok(i) => i,
            Err(i) => i,
        };
        self.out_et_ids[r0 + idx]
    }

    pub fn edge_weight(&self, local_edge: u32) -> f32 {
        if self.out_weight.is_empty() {
            1.0
        } else {
            self.out_weight[local_edge as usize]
        }
    }

    /// An interior vertex resides in exactly one partition (paper §III-D);
    /// its one-hop neighborhood is fully local.
    #[inline]
    pub fn is_interior(&self, local: u32) -> bool {
        self.partition_set.row_count(local as usize) == 1
    }

    pub fn interior_count(&self) -> usize {
        (0..self.nv() as u32).filter(|&v| self.is_interior(v)).count()
    }

    /// Total bytes of the contiguous layout — Table III accounting.
    pub fn nbytes(&self) -> usize {
        self.global_id.len() * 4
            + self.out_indptr.len() * 8
            + self.out_dst.len() * 4
            + self.out_weight.len() * 4
            + self.out_et_indptr.len() * 4
            + self.out_et_ids.len()
            + self.out_et_end.len() * 4
            + self.in_indptr.len() * 8
            + self.in_src.len() * 4
            + self.in_eid.len() * 4
            + self.out_deg_global.len() * 4
            + self.in_deg_global.len() * 4
            + self.partition_set.nbytes()
    }

    /// Bytes of this structure resident on the heap — `nbytes()` for a
    /// built/`HeapStore` partition, ~0 for an `MmapStore` one.
    pub fn heap_bytes(&self) -> usize {
        self.global_id.heap_bytes()
            + self.out_indptr.heap_bytes()
            + self.out_dst.heap_bytes()
            + self.out_weight.heap_bytes()
            + self.out_et_indptr.heap_bytes()
            + self.out_et_ids.heap_bytes()
            + self.out_et_end.heap_bytes()
            + self.in_indptr.heap_bytes()
            + self.in_src.heap_bytes()
            + self.in_eid.heap_bytes()
            + self.out_deg_global.heap_bytes()
            + self.in_deg_global.heap_bytes()
            + self.partition_set.heap_bytes()
    }

    /// Bytes addressed through a file mapping (kernel-cached, evictable).
    pub fn mapped_bytes(&self) -> usize {
        self.global_id.mapped_bytes()
            + self.out_indptr.mapped_bytes()
            + self.out_dst.mapped_bytes()
            + self.out_weight.mapped_bytes()
            + self.out_et_indptr.mapped_bytes()
            + self.out_et_ids.mapped_bytes()
            + self.out_et_end.mapped_bytes()
            + self.in_indptr.mapped_bytes()
            + self.in_src.mapped_bytes()
            + self.in_eid.mapped_bytes()
            + self.out_deg_global.mapped_bytes()
            + self.in_deg_global.mapped_bytes()
            + self.partition_set.mapped_bytes()
    }
}

/// Build all partitions' compact structures from the full graph and a
/// per-edge partition assignment (vertex-cut), on one thread. One pass
/// computes partition membership; each partition is then assembled
/// independently. Errors (instead of panicking) on an assignment whose
/// length or partition ids don't match the graph.
pub fn build_partitions(
    g: &Graph,
    assign: &[u16],
    num_parts: usize,
) -> anyhow::Result<Vec<PartitionGraph>> {
    build_partitions_threads(g, assign, num_parts, 1)
}

/// [`build_partitions`] with an explicit thread count (DESIGN.md §10): the
/// membership scan is sharded over `threads` vertex ranges (per-shard
/// `BitMatrix` OR-merged afterwards) and the per-partition assembly runs
/// one builder per partition, `threads` at a time. The output is identical
/// for any `threads` value — each partition's structure is a pure function
/// of (graph, assignment) and the membership union is commutative.
pub fn build_partitions_threads(
    g: &Graph,
    assign: &[u16],
    num_parts: usize,
    threads: usize,
) -> anyhow::Result<Vec<PartitionGraph>> {
    validate_assignment(g, assign, num_parts)?;
    let threads = threads.max(1);
    let out_deg = g.out_degrees();
    let in_deg = g.in_degrees();
    let membership = membership_scan(g, assign, num_parts, threads);

    let mut parts: Vec<Option<PartitionGraph>> = (0..num_parts).map(|_| None).collect();
    if threads == 1 || num_parts == 1 {
        for (p, slot) in parts.iter_mut().enumerate() {
            *slot = Some(build_one(g, assign, p, num_parts, &membership, &out_deg, &in_deg));
        }
    } else {
        let chunk = num_parts.div_ceil(threads.min(num_parts));
        let (membership, out_deg, in_deg) = (&membership, &out_deg, &in_deg);
        std::thread::scope(|s| {
            for (ci, slots) in parts.chunks_mut(chunk).enumerate() {
                s.spawn(move || {
                    for (i, slot) in slots.iter_mut().enumerate() {
                        let p = ci * chunk + i;
                        *slot =
                            Some(build_one(g, assign, p, num_parts, membership, out_deg, in_deg));
                    }
                });
            }
        });
    }
    Ok(parts.into_iter().map(|p| p.expect("builder filled every slot")).collect())
}

fn validate_assignment(g: &Graph, assign: &[u16], num_parts: usize) -> anyhow::Result<()> {
    if assign.len() != g.m() {
        anyhow::bail!(
            "edge assignment covers {} edges but the graph has {} — \
             partition and graph are out of sync",
            assign.len(),
            g.m()
        );
    }
    if let Some(&bad) = assign.iter().find(|&&p| p as usize >= num_parts) {
        anyhow::bail!(
            "edge assignment references partition {bad} but only {num_parts} partitions exist"
        );
    }
    Ok(())
}

/// Build exactly one partition's structure without materializing the other
/// `num_parts - 1` — the bounded-memory path a `glisp serve` process uses
/// when it rebuilds its own partition: peak residency is one partition plus
/// the shared membership matrix, not the whole set. Bit-identical to
/// `build_partitions_threads(..)[part]` (same membership scan, same
/// per-partition assembly).
pub fn build_single_partition(
    g: &Graph,
    assign: &[u16],
    part: usize,
    num_parts: usize,
    threads: usize,
) -> anyhow::Result<PartitionGraph> {
    validate_assignment(g, assign, num_parts)?;
    if part >= num_parts {
        anyhow::bail!("partition {part} out of range: only {num_parts} partitions exist");
    }
    let out_deg = g.out_degrees();
    let in_deg = g.in_degrees();
    let membership = membership_scan(g, assign, num_parts, threads.max(1));
    Ok(build_one(g, assign, part, num_parts, &membership, &out_deg, &in_deg))
}

/// Build and save the whole partition set without ever holding it all:
/// partitions are assembled `threads` at a time (same builder, so the
/// files are bit-identical to save-after-build-all), written with
/// `graph::io::save_partition`, and dropped before the next wave starts.
/// Returns the peak partition-structure bytes resident across waves — the
/// number the out-of-core budget scenario asserts against.
pub fn build_and_save_partitions(
    g: &Graph,
    assign: &[u16],
    num_parts: usize,
    threads: usize,
    dir: &Path,
) -> anyhow::Result<usize> {
    validate_assignment(g, assign, num_parts)?;
    let threads = threads.max(1);
    let out_deg = g.out_degrees();
    let in_deg = g.in_degrees();
    let membership = membership_scan(g, assign, num_parts, threads);
    let mut peak = 0usize;
    for wave in (0..num_parts).step_by(threads) {
        let hi = (wave + threads).min(num_parts);
        let built: Vec<PartitionGraph> = if hi - wave == 1 {
            vec![build_one(g, assign, wave, num_parts, &membership, &out_deg, &in_deg)]
        } else {
            let (membership, out_deg, in_deg) = (&membership, &out_deg, &in_deg);
            std::thread::scope(|s| {
                let handles: Vec<_> = (wave..hi)
                    .map(|p| {
                        s.spawn(move || {
                            build_one(g, assign, p, num_parts, membership, out_deg, in_deg)
                        })
                    })
                    .collect();
                handles
                    .into_iter()
                    .map(|h| h.join().expect("partition builder panicked"))
                    .collect()
            })
        };
        peak = peak.max(built.iter().map(|p| p.nbytes()).sum());
        for p in &built {
            crate::graph::io::save_partition(p, dir, &format!("part{}", p.part_id))?;
        }
    }
    Ok(peak)
}

/// Which partitions does each global vertex touch? Sharded over contiguous
/// source-vertex ranges; each shard sets bits for both endpoints of its
/// range's edges into a private matrix, and the shards OR-merge (set union
/// is commutative, so the result is shard-count invariant).
fn membership_scan(g: &Graph, assign: &[u16], num_parts: usize, threads: usize) -> BitMatrix {
    let scan_range = |lo: usize, hi: usize| {
        let mut m = BitMatrix::new(g.n, num_parts);
        for u in lo..hi {
            let (a, b) = g.edge_range(u as VId);
            for e in a..b {
                let p = assign[e] as usize;
                m.set(u, p);
                m.set(g.dst[e] as usize, p);
            }
        }
        m
    };
    if threads <= 1 || g.n < 2 {
        return scan_range(0, g.n);
    }
    let shard = g.n.div_ceil(threads);
    let mut shards: Vec<BitMatrix> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..g.n)
            .step_by(shard)
            .map(|lo| {
                let scan_range = &scan_range;
                s.spawn(move || scan_range(lo, (lo + shard).min(g.n)))
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("membership shard panicked")).collect()
    });
    let mut membership = shards.pop().expect("at least one shard");
    for other in &shards {
        membership.or_with(other);
    }
    membership
}

fn build_one(
    g: &Graph,
    assign: &[u16],
    part: usize,
    num_parts: usize,
    membership: &BitMatrix,
    out_deg: &[u32],
    in_deg: &[u32],
) -> PartitionGraph {
    // Vertices present in this partition, sorted (global_id order).
    let mut global_id: Vec<VId> = (0..g.n as VId)
        .filter(|&v| membership.get(v as usize, part))
        .collect();
    global_id.sort_unstable();
    let nv = global_id.len();
    // Direct-index global→local table, built once: the edge gather below
    // does two lookups per edge, and a per-lookup binary search made the
    // assembly O(E log V) per partition. `global_id` stays sorted, so the
    // table assigns exactly the ids `PartitionGraph::local_id`'s binary
    // search resolves at query time.
    let mut global_to_local = vec![u32::MAX; g.n];
    for (l, &gid) in global_id.iter().enumerate() {
        global_to_local[gid as usize] = l as u32;
    }
    let lid = |gid: VId| {
        let l = global_to_local[gid as usize];
        debug_assert_ne!(l, u32::MAX, "vertex {gid} not a member of partition {part}");
        l
    };

    // Gather this partition's edges as (src_local, etype, dst, weight, ...).
    let mut edges: Vec<(u32, u8, VId, f32)> = Vec::new();
    for u in 0..g.n {
        let (a, b) = g.edge_range(u as VId);
        for e in a..b {
            if assign[e] as usize == part {
                edges.push((
                    lid(u as VId),
                    g.edge_type(e),
                    g.dst[e],
                    g.edge_weight(e),
                ));
            }
        }
    }
    // Paper Fig. 6: sort by (src, edge_type, dst).
    edges.sort_unstable_by(|x, y| (x.0, x.1, x.2).cmp(&(y.0, y.1, y.2)));

    let ne = edges.len();
    let mut out_indptr = vec![0u64; nv + 1];
    let mut out_dst = Vec::with_capacity(ne);
    let weighted = !g.weight.is_empty();
    let mut out_weight = if weighted { Vec::with_capacity(ne) } else { Vec::new() };
    let mut out_et_indptr = vec![0u32; nv + 1];
    let mut out_et_ids: Vec<u8> = Vec::new();
    let mut out_et_end: Vec<u32> = Vec::new();

    let typed = !g.etype.is_empty();
    let mut i = 0usize;
    for v in 0..nv as u32 {
        let start = i;
        while i < ne && edges[i].0 == v {
            out_dst.push(edges[i].2);
            if weighted {
                out_weight.push(edges[i].3);
            }
            i += 1;
        }
        out_indptr[v as usize + 1] = out_dst.len() as u64;
        if typed {
            // Run-length encode edge types of [start, i).
            let mut r = start;
            while r < i {
                let t = edges[r].1;
                let mut r2 = r;
                while r2 < i && edges[r2].1 == t {
                    r2 += 1;
                }
                out_et_ids.push(t);
                out_et_end.push((r2 - start) as u32);
                r = r2;
            }
        }
        out_et_indptr[v as usize + 1] = out_et_ids.len() as u32;
    }

    // In-edges of this partition's edge set, keyed by dst; store
    // (src_global, local edge id). Sorted by (dst_local, src) for locality.
    // The sorted `edges` array is exactly out_dst's order, so the local
    // edge id of edges[i] is i.
    let mut ins: Vec<(u32, VId, u32)> = Vec::with_capacity(ne);
    for (eid, &(src_l, _, dst_g, _)) in edges.iter().enumerate() {
        ins.push((lid(dst_g), global_id[src_l as usize], eid as u32));
    }
    ins.sort_unstable();
    let mut in_indptr = vec![0u64; nv + 1];
    let mut in_src = Vec::with_capacity(ne);
    let mut in_eid = Vec::with_capacity(ne);
    {
        let mut i = 0usize;
        for v in 0..nv as u32 {
            while i < ins.len() && ins[i].0 == v {
                in_src.push(ins[i].1);
                in_eid.push(ins[i].2);
                i += 1;
            }
            in_indptr[v as usize + 1] = in_src.len() as u64;
        }
    }

    // Per-local-vertex global degrees + membership rows.
    let mut pset = BitMatrix::new(nv, num_parts);
    let mut odg = vec![0u32; nv];
    let mut idg = vec![0u32; nv];
    for (l, &gid) in global_id.iter().enumerate() {
        odg[l] = out_deg[gid as usize];
        idg[l] = in_deg[gid as usize];
        for p in membership.row_ones(gid as usize) {
            pset.set(l, p);
        }
    }

    PartitionGraph {
        part_id: part,
        num_parts,
        global_id: global_id.into(),
        out_indptr: out_indptr.into(),
        out_dst: out_dst.into(),
        out_weight: out_weight.into(),
        out_et_indptr: out_et_indptr.into(),
        out_et_ids: out_et_ids.into(),
        out_et_end: out_et_end.into(),
        in_indptr: in_indptr.into(),
        in_src: in_src.into(),
        in_eid: in_eid.into(),
        out_deg_global: odg.into(),
        in_deg_global: idg.into(),
        partition_set: PartBits::from_matrix(pset),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generator;
    use crate::util::rng::Rng;

    fn tiny() -> (Graph, Vec<u16>) {
        // 0->1(t0), 0->2(t1), 1->2(t0), 2->0(t2), 3->0(t0), 1->3(t1)
        let g = Graph::from_typed_edges(
            4,
            &[
                (0, 1, 0, 1.0),
                (0, 2, 1, 2.0),
                (1, 2, 0, 1.0),
                (2, 0, 2, 0.5),
                (3, 0, 0, 1.0),
                (1, 3, 1, 3.0),
            ],
        );
        // Edge ids after CSR: sorted by src: e0=0->1, e1=0->2, e2=1->2,
        // e3=1->3, e4=2->0, e5=3->0
        let assign = vec![0, 0, 1, 1, 0, 1];
        (g, assign)
    }

    #[test]
    fn partition_edge_conservation() {
        let (g, assign) = tiny();
        let parts = build_partitions(&g, &assign, 2).unwrap();
        let total: usize = parts.iter().map(|p| p.ne()).sum();
        assert_eq!(total, g.m());
        assert_eq!(parts[0].ne(), 3);
        assert_eq!(parts[1].ne(), 3);
    }

    #[test]
    fn mismatched_assignment_errors_with_both_counts() {
        let (g, assign) = tiny();
        let err = build_partitions(&g, &assign[..assign.len() - 1], 2).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains('5') && msg.contains('6'), "error must name both counts: {msg}");
    }

    #[test]
    fn out_of_range_partition_id_errors() {
        let (g, mut assign) = tiny();
        assign[3] = 7;
        let err = build_partitions(&g, &assign, 2).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains('7') && msg.contains('2'), "error must name the bad id: {msg}");
    }

    /// The parallel build (sharded membership scan + chunked builders) must
    /// produce byte-identical structures for any thread count, including
    /// thread counts above the partition count.
    #[test]
    fn parallel_build_matches_serial_bit_for_bit() {
        let mut rng = Rng::new(12);
        let g = generator::heterogeneous_graph(700, 6500, 2, 4, 2.2, &mut rng);
        let assign: Vec<u16> = (0..g.m()).map(|e| (e % 3) as u16).collect();
        let serial = build_partitions_threads(&g, &assign, 3, 1).unwrap();
        for threads in [2usize, 3, 8] {
            let par = build_partitions_threads(&g, &assign, 3, threads).unwrap();
            for (a, b) in serial.iter().zip(&par) {
                assert_eq!(a.global_id, b.global_id, "threads={threads}");
                assert_eq!(a.out_indptr, b.out_indptr);
                assert_eq!(a.out_dst, b.out_dst);
                assert_eq!(a.out_weight, b.out_weight);
                assert_eq!(a.out_et_indptr, b.out_et_indptr);
                assert_eq!(a.out_et_ids, b.out_et_ids);
                assert_eq!(a.out_et_end, b.out_et_end);
                assert_eq!(a.in_indptr, b.in_indptr);
                assert_eq!(a.in_src, b.in_src);
                assert_eq!(a.in_eid, b.in_eid);
                assert_eq!(a.out_deg_global, b.out_deg_global);
                assert_eq!(a.in_deg_global, b.in_deg_global);
                assert_eq!(a.partition_set.raw(), b.partition_set.raw());
            }
        }
    }

    /// Pins the local ids the direct-index global→local table assigns: they
    /// must be exactly the positions `local_id`'s binary search resolves,
    /// for every vertex referenced by the out/in edge arrays.
    #[test]
    fn lookup_table_assigns_binary_search_local_ids() {
        let (g, assign) = tiny();
        let parts = build_partitions(&g, &assign, 2).unwrap();
        // Partition 0 = {0,1,2} (edges 0->1, 0->2, 2->0): pinned layout.
        assert_eq!(parts[0].global_id, vec![0, 1, 2]);
        assert_eq!(parts[0].out_indptr, vec![0, 2, 2, 3]);
        assert_eq!(parts[0].out_dst, vec![1, 2, 0]);
        assert_eq!(parts[0].in_src, vec![2, 0, 0]);
        assert_eq!(parts[0].in_eid, vec![2, 0, 1]);
        for p in &parts {
            for l in 0..p.nv() as u32 {
                assert_eq!(p.local_id(p.global(l)), Some(l));
                // Every in-edge row keyed under l must reference a local
                // out-edge that really targets l's global id — i.e. the
                // table and the binary search agree on dst local ids too.
                for i in p.in_range(l).0..p.in_range(l).1 {
                    assert_eq!(p.out_dst[p.in_eid[i] as usize], p.global(l));
                }
            }
        }
    }

    #[test]
    fn local_global_bijection() {
        let (g, assign) = tiny();
        for p in build_partitions(&g, &assign, 2).unwrap() {
            for l in 0..p.nv() as u32 {
                assert_eq!(p.local_id(p.global(l)), Some(l));
            }
            assert!(p.global_id.windows(2).all(|w| w[0] < w[1]));
        }
    }

    #[test]
    fn edge_type_recovered_by_query() {
        let (g, assign) = tiny();
        let parts = build_partitions(&g, &assign, 2).unwrap();
        // Partition 0 holds 0->1(t0), 0->2(t1), 2->0(t2).
        let p0 = &parts[0];
        let l0 = p0.local_id(0).unwrap();
        assert_eq!(p0.out_neighbors_of_type(l0, 0), &[1]);
        assert_eq!(p0.out_neighbors_of_type(l0, 1), &[2]);
        assert_eq!(p0.out_neighbors_of_type(l0, 3), &[] as &[VId]);
        for e in 0..p0.ne() as u32 {
            // Type from query must equal the type the edge had originally.
            let t = p0.edge_type_of(e);
            assert!(t <= 2);
        }
        let l2 = p0.local_id(2).unwrap();
        let (a, _) = p0.out_range(l2);
        assert_eq!(p0.edge_type_of(a as u32), 2); // 2->0 is t2
    }

    #[test]
    fn out_range_of_type_indexes_match_slices_and_types() {
        let mut rng = Rng::new(11);
        let g = generator::heterogeneous_graph(400, 3500, 2, 4, 2.2, &mut rng);
        let assign: Vec<u16> = (0..g.m()).map(|e| (e % 2) as u16).collect();
        for p in build_partitions(&g, &assign, 2).unwrap() {
            for v in 0..p.nv() as u32 {
                let (v0, v1) = p.out_range(v);
                for t in 0..4u8 {
                    let (a, b) = p.out_range_of_type(v, t);
                    // The range addresses out_dst directly and stays within
                    // the vertex's edge window.
                    assert!(v0 <= a && a <= b && b <= v1);
                    assert_eq!(&p.out_dst[a..b], p.out_neighbors_of_type(v, t));
                    // Every edge in the range carries the requested type —
                    // the weight-lookup contract of the gather ops.
                    for e in a..b {
                        assert_eq!(p.edge_type_of(e as u32), t);
                    }
                }
            }
        }
    }

    #[test]
    fn in_edges_reference_local_out_edges() {
        let (g, assign) = tiny();
        for p in build_partitions(&g, &assign, 2).unwrap() {
            for v in 0..p.nv() as u32 {
                let (a, b) = p.in_range(v);
                for i in a..b {
                    let e = p.in_eid[i] as usize;
                    // The referenced out-edge must point back at v.
                    assert_eq!(p.out_dst[e], p.global(v));
                }
            }
        }
    }

    #[test]
    fn membership_bits_cover_both_endpoints() {
        let (g, assign) = tiny();
        let parts = build_partitions(&g, &assign, 2).unwrap();
        // Vertex 0 has edges in both partitions => boundary in both.
        for p in &parts {
            let l = p.local_id(0).unwrap();
            assert_eq!(p.partition_set.row_count(l as usize), 2);
            assert!(!p.is_interior(l));
        }
    }

    #[test]
    fn global_degrees_carried() {
        let (g, assign) = tiny();
        let parts = build_partitions(&g, &assign, 2).unwrap();
        let p0 = &parts[0];
        let l0 = p0.local_id(0).unwrap();
        assert_eq!(p0.out_deg_global[l0 as usize], 2);
        assert_eq!(p0.in_deg_global[l0 as usize], 2); // 2->0, 3->0
    }

    #[test]
    fn neighbors_sorted_by_type_then_dst() {
        let mut rng = Rng::new(9);
        let g = generator::heterogeneous_graph(500, 4000, 2, 4, 2.2, &mut rng);
        let assign: Vec<u16> = (0..g.m()).map(|e| (e % 3) as u16).collect();
        for p in build_partitions(&g, &assign, 3).unwrap() {
            for v in 0..p.nv() as u32 {
                let (a, b) = p.out_range(v);
                let types: Vec<u8> =
                    (a..b).map(|e| p.edge_type_of(e as u32)).collect();
                let mut sorted = types.clone();
                sorted.sort_unstable();
                assert_eq!(types, sorted, "types not grouped for v={v}");
            }
        }
    }

    /// `build_single_partition` must be a pure projection of the full
    /// build — same membership scan, same assembly — so a serve process
    /// rebuilding only its own partition serves identical bits.
    #[test]
    fn single_partition_build_matches_full_build() {
        let mut rng = Rng::new(13);
        let g = generator::heterogeneous_graph(600, 5000, 2, 3, 2.2, &mut rng);
        let assign: Vec<u16> = (0..g.m()).map(|e| (e % 3) as u16).collect();
        let all = build_partitions_threads(&g, &assign, 3, 2).unwrap();
        for part in 0..3 {
            let one = build_single_partition(&g, &assign, part, 3, 2).unwrap();
            let full = &all[part];
            assert_eq!(one.global_id, full.global_id);
            assert_eq!(one.out_indptr, full.out_indptr);
            assert_eq!(one.out_dst, full.out_dst);
            assert_eq!(one.in_src, full.in_src);
            assert_eq!(one.in_eid, full.in_eid);
            assert_eq!(one.partition_set.raw(), full.partition_set.raw());
        }
        assert!(build_single_partition(&g, &assign, 3, 3, 1).is_err());
    }

    /// The wave-by-wave build+save path writes files bit-identical to
    /// saving a full in-memory build, while never holding more than one
    /// wave of structures.
    #[test]
    fn build_and_save_waves_match_full_build_files() {
        use crate::graph::io::load_partition;
        let mut rng = Rng::new(14);
        let g = generator::heterogeneous_graph(500, 4000, 2, 3, 2.2, &mut rng);
        let assign: Vec<u16> = (0..g.m()).map(|e| (e % 4) as u16).collect();
        let all = build_partitions_threads(&g, &assign, 4, 2).unwrap();
        let dir = std::env::temp_dir().join("glisp_hetero_wave_save");
        let _ = std::fs::remove_dir_all(&dir);
        let peak = build_and_save_partitions(&g, &assign, 4, 2, &dir).unwrap();
        // Two builders per wave => peak is at most the two largest
        // structures, strictly less than the whole set.
        let total: usize = all.iter().map(|p| p.nbytes()).sum();
        assert!(peak > 0 && peak < total, "peak {peak} vs total {total}");
        for p in &all {
            let loaded = load_partition(&dir, &format!("part{}", p.part_id)).unwrap();
            assert_eq!(loaded.global_id, p.global_id);
            assert_eq!(loaded.out_dst, p.out_dst);
            assert_eq!(loaded.in_eid, p.in_eid);
            assert_eq!(loaded.nbytes(), p.nbytes());
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn interior_plus_boundary_equals_nv() {
        let mut rng = Rng::new(10);
        let g = generator::chung_lu(2000, 16_000, 2.1, &mut rng);
        let assign: Vec<u16> = (0..g.m()).map(|e| (e % 4) as u16).collect();
        for p in build_partitions(&g, &assign, 4).unwrap() {
            let interior = p.interior_count();
            assert!(interior <= p.nv());
            assert!(p.nbytes() > 0);
        }
    }
}
