//! Synthetic graph generators — the dataset substitution layer (DESIGN.md
//! §3). The paper evaluates on OGBN-Products/WikiKG90Mv2/Twitter-2010/
//! OGBN-Paper/RelNet; what its experiments actually exercise is the degree
//! *distribution* (power law with hotspots) and graph scale, which these
//! generators reproduce at laptop scale with controllable knobs.

use crate::graph::csr::{Graph, VId};
use crate::util::rng::Rng;

/// Parameters of a synthetic dataset emulating one of the paper's datasets.
#[derive(Clone, Debug)]
pub struct DatasetSpec {
    pub name: &'static str,
    pub n: usize,
    pub m: usize,
    /// Power-law exponent (≈2.0–2.5 for real web/social graphs); 0 = uniform.
    pub alpha: f64,
    pub kind: GenKind,
}

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum GenKind {
    ChungLu,
    RMat,
    ErdosRenyi,
}

/// The synthetic stand-ins for the paper's Table I datasets, scaled ~1000×
/// down but preserving average degree and skew regime.
pub fn paper_datasets() -> Vec<DatasetSpec> {
    vec![
        // OGBN-Products: avg deg 25.2, NOT power law (paper Fig. 8).
        DatasetSpec { name: "products-s", n: 25_000, m: 630_000, alpha: 0.0, kind: GenKind::ErdosRenyi },
        // WikiKG90Mv2: avg deg 6.6, power law.
        DatasetSpec { name: "wiki-s", n: 90_000, m: 600_000, alpha: 2.1, kind: GenKind::ChungLu },
        // Twitter-2010: avg deg 35.3, heavy power law.
        DatasetSpec { name: "twitter-s", n: 42_000, m: 1_480_000, alpha: 1.9, kind: GenKind::ChungLu },
        // OGBN-Paper: avg deg 14.5, power law (RMAT for structural variety).
        DatasetSpec { name: "paper-s", n: 110_000, m: 1_610_000, alpha: 2.2, kind: GenKind::RMat },
        // RelNet: avg deg 4.7, sparse power law, the "scale" dataset.
        DatasetSpec { name: "relnet-s", n: 1_000_000, m: 4_700_000, alpha: 2.3, kind: GenKind::ChungLu },
    ]
}

pub fn generate(spec: &DatasetSpec, seed: u64) -> Graph {
    let mut rng = Rng::new(seed);
    match spec.kind {
        GenKind::ChungLu => chung_lu(spec.n, spec.m, spec.alpha, &mut rng),
        GenKind::RMat => rmat(spec.n, spec.m, &mut rng),
        GenKind::ErdosRenyi => erdos_renyi(spec.n, spec.m, &mut rng),
    }
}

/// Chung–Lu: endpoints drawn independently with probability ∝ expected
/// degree w_i = (i+1)^(-1/(alpha-1)) — yields degree distribution with
/// power-law tail of exponent alpha. Self-loops are rejected; multi-edges
/// are kept (the data structure is a multigraph, like the paper's).
pub fn chung_lu(n: usize, m: usize, alpha: f64, rng: &mut Rng) -> Graph {
    assert!(alpha > 1.0, "chung_lu needs alpha > 1");
    // Inverse-CDF sampling over the discrete power-law weights via rng.zipf
    // with parameter gamma = 1/(alpha-1) (the weight exponent).
    let gamma = 1.0 / (alpha - 1.0);
    let mut edges = Vec::with_capacity(m);
    while edges.len() < m {
        let s = rng.zipf(n, gamma) as VId;
        let d = rng.zipf(n, gamma) as VId;
        if s != d {
            edges.push((s, d));
        }
    }
    scramble_ids(n, &mut edges, rng);
    Graph::from_edges(n, &edges)
}

/// R-MAT (Chakrabarti et al.): recursive quadrant descent with the classic
/// (a,b,c,d) = (0.57, 0.19, 0.19, 0.05) — power-law-ish in/out degrees.
pub fn rmat(n: usize, m: usize, rng: &mut Rng) -> Graph {
    let bits = (n as f64).log2().ceil() as u32;
    let size = 1usize << bits;
    let (a, b, c) = (0.57, 0.19, 0.19);
    let mut edges = Vec::with_capacity(m);
    while edges.len() < m {
        let (mut x, mut y) = (0usize, 0usize);
        let mut half = size >> 1;
        while half > 0 {
            let r = rng.f64();
            if r < a {
                // top-left
            } else if r < a + b {
                y += half;
            } else if r < a + b + c {
                x += half;
            } else {
                x += half;
                y += half;
            }
            half >>= 1;
        }
        if x < n && y < n && x != y {
            edges.push((x as VId, y as VId));
        }
    }
    Graph::from_edges(n, &edges)
}

/// Erdős–Rényi G(n, m): uniform endpoint pairs — the non-power-law control
/// (OGBN-Products regime in the paper's Fig. 8).
pub fn erdos_renyi(n: usize, m: usize, rng: &mut Rng) -> Graph {
    let mut edges = Vec::with_capacity(m);
    while edges.len() < m {
        let s = rng.usize(n) as VId;
        let d = rng.usize(n) as VId;
        if s != d {
            edges.push((s, d));
        }
    }
    Graph::from_edges(n, &edges)
}

/// Re-map vertex ids by a random permutation so id order carries no locality
/// (real datasets arrive in arbitrary id order; reorder algorithms must not
/// get the answer for free).
fn scramble_ids(n: usize, edges: &mut [(VId, VId)], rng: &mut Rng) {
    let mut perm: Vec<VId> = (0..n as VId).collect();
    rng.shuffle(&mut perm);
    for e in edges.iter_mut() {
        e.0 = perm[e.0 as usize];
        e.1 = perm[e.1 as usize];
    }
}

/// Planted-community labeled graph for the vertex-classification experiments
/// (Table IV): `classes` communities, intra-community edge probability
/// `p_intra`, plus a power-law degree profile. Labels are the community ids;
/// features downstream are derived from labels + noise so the task is
/// learnable but not trivial.
pub fn labeled_community_graph(
    n: usize,
    m: usize,
    classes: usize,
    p_intra: f64,
    rng: &mut Rng,
) -> Graph {
    let mut label = vec![0u16; n];
    for (i, l) in label.iter_mut().enumerate() {
        *l = (i % classes) as u16;
    }
    let gamma = 0.8; // mild skew inside each community
    let mut edges = Vec::with_capacity(m);
    while edges.len() < m {
        let s = rng.usize(n);
        let d = if rng.bool(p_intra) {
            // Pick a same-community vertex (labels are i % classes, so step
            // by `classes` from a random base with zipf-ish skew).
            let c = label[s] as usize;
            let per = n / classes;
            let k = rng.zipf(per.max(1), gamma);
            c + k * classes
        } else {
            rng.usize(n)
        };
        if d < n && s != d {
            edges.push((s as VId, d as VId));
        }
    }
    let mut g = Graph::from_edges(n, &edges);
    g.label = label;
    g
}

/// Heterogeneous multigraph: `vtypes` vertex types, `etypes` edge types with
/// a type-dependent weight scale — exercises the Fig. 6 compact structure's
/// edge-type run-length index and the weighted sampler.
pub fn heterogeneous_graph(
    n: usize,
    m: usize,
    vtypes: usize,
    etypes: usize,
    alpha: f64,
    rng: &mut Rng,
) -> Graph {
    let gamma = 1.0 / (alpha - 1.0);
    let mut edges = Vec::with_capacity(m);
    while edges.len() < m {
        let s = rng.zipf(n, gamma) as VId;
        let d = rng.zipf(n, gamma) as VId;
        if s == d {
            continue;
        }
        let t = rng.usize(etypes) as u8;
        let w = (rng.f64() * (1.0 + t as f64)) as f32 + 0.05;
        edges.push((s, d, t, w));
    }
    let mut g = Graph::from_typed_edges(n, &edges);
    g.vtype = (0..n).map(|i| (i % vtypes) as u8).collect();
    g
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::{log_histogram, powerlaw_slope};

    #[test]
    fn chung_lu_is_power_law() {
        let mut rng = Rng::new(1);
        let g = chung_lu(20_000, 200_000, 2.1, &mut rng);
        assert_eq!(g.m(), 200_000);
        let hist = log_histogram(g.out_degrees().iter().map(|&d| d as u64));
        let slope = powerlaw_slope(&hist[1..]); // skip the zero bin
        assert!(slope < -0.8, "expected heavy tail, slope {slope}");
        let max_deg = *g.out_degrees().iter().max().unwrap();
        assert!(
            max_deg as f64 > 20.0 * g.avg_degree(),
            "expected hotspots: max {max_deg} avg {}",
            g.avg_degree()
        );
    }

    #[test]
    fn erdos_renyi_is_not_power_law() {
        let mut rng = Rng::new(2);
        let g = erdos_renyi(10_000, 100_000, &mut rng);
        let max_deg = *g.out_degrees().iter().max().unwrap();
        assert!((max_deg as f64) < 4.0 * g.avg_degree());
    }

    #[test]
    fn rmat_shape() {
        let mut rng = Rng::new(3);
        let g = rmat(1 << 12, 40_000, &mut rng);
        assert_eq!(g.m(), 40_000);
        assert!(g.dst.iter().all(|&d| (d as usize) < g.n));
    }

    #[test]
    fn generators_deterministic() {
        let spec = &paper_datasets()[1];
        let spec = DatasetSpec { n: 5000, m: 30_000, ..spec.clone() };
        let a = generate(&spec, 7);
        let b = generate(&spec, 7);
        assert_eq!(a.dst, b.dst);
        let c = generate(&spec, 8);
        assert_ne!(a.dst, c.dst);
    }

    #[test]
    fn labeled_graph_has_community_structure() {
        let mut rng = Rng::new(4);
        let g = labeled_community_graph(4000, 40_000, 8, 0.9, &mut rng);
        assert_eq!(g.label.len(), 4000);
        let mut intra = 0usize;
        for u in 0..g.n {
            for &v in g.out_neighbors(u as VId) {
                if g.label[u] == g.label[v as usize] {
                    intra += 1;
                }
            }
        }
        let frac = intra as f64 / g.m() as f64;
        assert!(frac > 0.7, "intra fraction {frac}");
    }

    #[test]
    fn hetero_types_and_weights() {
        let mut rng = Rng::new(5);
        let g = heterogeneous_graph(2000, 24_000, 3, 4, 2.2, &mut rng);
        assert_eq!(g.num_vertex_types(), 3);
        assert_eq!(g.num_edge_types(), 4);
        assert!(g.weight.iter().all(|&w| w > 0.0));
    }
}
