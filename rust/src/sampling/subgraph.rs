//! K-hop tree-format subgraph assembly (paper Algorithm 1 + DESIGN.md §6).
//!
//! A K-hop sample with seed batch B and fanouts [f1..fK] is materialized as
//! K+1 per-level vertex arrays with n_0 = B, n_k = n_{k-1}·f_k: the
//! neighbors of level-k slot i occupy slots [i·f_{k+1}, (i+1)·f_{k+1}) of
//! level k+1, padded with `PAD` + mask 0. Static shapes are what the AOT
//! artifacts require; duplicates across branches are accepted (tree
//! expansion).

use anyhow::{Context, Result};

use crate::graph::csr::VId;
use crate::sampling::client::SamplingClient;
use crate::sampling::request::{SampleConfig, PAD};

#[derive(Clone, Debug)]
pub struct TreeSample {
    /// levels[0] = seeds; levels[k] has len B·∏_{j≤k} f_j, PAD = padding.
    pub levels: Vec<Vec<VId>>,
    /// masks[k-1] aligns with levels[k]: 1.0 = real vertex.
    pub masks: Vec<Vec<f32>>,
    pub fanouts: Vec<usize>,
}

impl TreeSample {
    pub fn batch(&self) -> usize {
        self.levels[0].len()
    }

    pub fn hops(&self) -> usize {
        self.fanouts.len()
    }

    /// Distinct real vertices across all levels (the subgraph size metric
    /// Fig. 9 throughput is reported over).
    pub fn distinct_vertices(&self) -> usize {
        let mut set = std::collections::HashSet::new();
        for lvl in &self.levels {
            for &v in lvl {
                if v != PAD {
                    set.insert(v);
                }
            }
        }
        set.len()
    }

    pub fn total_slots(&self) -> usize {
        self.levels.iter().map(|l| l.len()).sum()
    }
}

/// Sample a K-hop tree (Algorithm 1): K Gather-Apply rounds, one per hop.
/// Fails (naming the hop and, transitively, the partition) when a
/// partition server has died.
pub fn sample_tree(
    client: &mut SamplingClient,
    seeds: &[VId],
    fanouts: &[usize],
    cfg: &SampleConfig,
) -> Result<TreeSample> {
    let mut levels = vec![seeds.to_vec()];
    let mut masks: Vec<Vec<f32>> = Vec::new();
    for (k, &f) in fanouts.iter().enumerate() {
        let parents = levels.last().unwrap();
        // Gather for real parents only; padding parents produce padding.
        let real_idx: Vec<usize> =
            (0..parents.len()).filter(|&i| parents[i] != PAD).collect();
        let real_seeds: Vec<VId> = real_idx.iter().map(|&i| parents[i]).collect();
        let got = client
            .sample_one_hop(&real_seeds, f, cfg)
            .with_context(|| format!("sampling hop {k} (fanout {f})"))?;
        let mut level = vec![PAD; parents.len() * f];
        let mut mask = vec![0f32; parents.len() * f];
        for (j, &i) in real_idx.iter().enumerate() {
            let ns = got.neighbors_of(j);
            for (s, &n) in ns.iter().take(f).enumerate() {
                level[i * f + s] = n;
                mask[i * f + s] = 1.0;
            }
        }
        levels.push(level);
        masks.push(mask);
    }
    Ok(TreeSample {
        levels,
        masks,
        fanouts: fanouts.to_vec(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generator;
    use crate::partition::{AdaDNE, Partitioner};
    use crate::sampling::service::SamplingService;
    use crate::util::rng::Rng;

    fn service() -> SamplingService {
        let mut rng = Rng::new(150);
        let g = generator::chung_lu(1000, 10_000, 2.1, &mut rng);
        let ea = AdaDNE::default().partition(&g, 3, 0);
        SamplingService::launch(&g, &ea, 1).unwrap()
    }

    #[test]
    fn tree_shapes_are_static() {
        let svc = service();
        let mut client = svc.client(5);
        let seeds: Vec<VId> = (0..16).collect();
        let t = sample_tree(&mut client, &seeds, &[4, 3], &SampleConfig::default()).unwrap();
        assert_eq!(t.levels[0].len(), 16);
        assert_eq!(t.levels[1].len(), 64);
        assert_eq!(t.levels[2].len(), 192);
        assert_eq!(t.masks[0].len(), 64);
        assert_eq!(t.masks[1].len(), 192);
        svc.shutdown();
    }

    #[test]
    fn mask_matches_pad() {
        let svc = service();
        let mut client = svc.client(6);
        let seeds: Vec<VId> = (0..8).collect();
        let t = sample_tree(&mut client, &seeds, &[5, 4], &SampleConfig::default()).unwrap();
        for k in 1..t.levels.len() {
            for (v, m) in t.levels[k].iter().zip(&t.masks[k - 1]) {
                assert_eq!(*v == PAD, *m == 0.0, "mask/PAD mismatch");
            }
        }
        svc.shutdown();
    }

    #[test]
    fn padding_parents_have_padding_children() {
        let svc = service();
        let mut client = svc.client(7);
        let seeds: Vec<VId> = (0..8).collect();
        let t = sample_tree(&mut client, &seeds, &[3, 2], &SampleConfig::default()).unwrap();
        let f2 = 2;
        for (i, &p) in t.levels[1].iter().enumerate() {
            if p == PAD {
                for s in 0..f2 {
                    assert_eq!(t.levels[2][i * f2 + s], PAD);
                    assert_eq!(t.masks[1][i * f2 + s], 0.0);
                }
            }
        }
        svc.shutdown();
    }

    #[test]
    fn neighbors_are_real_edges() {
        // Every sampled child must be an actual out-neighbor of its parent
        // in the original graph.
        let mut rng = Rng::new(151);
        let g = generator::chung_lu(700, 7000, 2.1, &mut rng);
        let ea = AdaDNE::default().partition(&g, 3, 0);
        let svc = SamplingService::launch(&g, &ea, 1).unwrap();
        let mut client = svc.client(8);
        let seeds: Vec<VId> = (0..16).collect();
        let t = sample_tree(&mut client, &seeds, &[4], &SampleConfig::default()).unwrap();
        for (i, &p) in t.levels[0].iter().enumerate() {
            for s in 0..4 {
                let c = t.levels[1][i * 4 + s];
                if c != PAD {
                    assert!(
                        g.out_neighbors(p).contains(&c),
                        "{c} is not a neighbor of {p}"
                    );
                }
            }
        }
        svc.shutdown();
    }
}
