//! Gather-Apply sampling client (paper Fig. 5, Algorithms 1 & 4). The
//! client fans a one-hop request out to servers, then post-processes the
//! partial results:
//!
//! * **GLISP routing** (`RouteMode::AllReplicas`): a seed's request goes to
//!   *every* partition holding a replica — a hotspot's one-hop sampling is
//!   served cooperatively, which is the load-balancing contribution.
//! * **Baseline routing** (`RouteMode::Owner`): a seed's request goes to a
//!   single owner server (the edge-cut / DistDGL architecture Fig. 10
//!   measures against).

use std::sync::mpsc::Sender;
use std::sync::Arc;

use crate::graph::csr::VId;
use crate::sampling::aes::merge_top_k;
use crate::sampling::request::{GatherRequest, GatherResponse, SampleConfig, ServerMsg};
use crate::util::bitset::BitMatrix;
use crate::util::rng::Rng;

#[derive(Clone)]
pub enum RouteMode {
    /// Route each seed to all partitions containing it (vertex-cut, GLISP).
    AllReplicas,
    /// Route each seed to its unique owner (edge-cut baseline).
    Owner(Arc<Vec<u16>>),
}

/// Result of one Apply phase: per-seed neighbor lists, flattened.
#[derive(Clone, Debug, Default)]
pub struct OneHopSample {
    pub offsets: Vec<u32>,
    pub neighbors: Vec<VId>,
}

impl OneHopSample {
    pub fn neighbors_of(&self, i: usize) -> &[VId] {
        &self.neighbors[self.offsets[i] as usize..self.offsets[i + 1] as usize]
    }
}

#[derive(Clone)]
pub struct SamplingClient {
    pub servers: Vec<Sender<ServerMsg>>,
    /// Global vertex → partition membership bits (from the partitioner).
    pub membership: Arc<BitMatrix>,
    pub mode: RouteMode,
    pub rng: Rng,
}

impl SamplingClient {
    /// Partitions a seed is routed to under the current mode.
    fn route(&self, v: VId) -> Vec<usize> {
        match &self.mode {
            RouteMode::AllReplicas => self.membership.row_ones(v as usize).collect(),
            RouteMode::Owner(owner) => vec![owner[v as usize] as usize],
        }
    }

    /// One Gather + Apply round (Algorithm 1, lines 9–10): sample up to
    /// `fanout` neighbors for every seed. Duplicate seeds are sampled
    /// independently (each occurrence is its own tree slot).
    pub fn sample_one_hop(
        &mut self,
        seeds: &[VId],
        fanout: usize,
        cfg: &SampleConfig,
    ) -> OneHopSample {
        // --- Gather: bucket seed occurrences by server ---
        let p = self.servers.len();
        let mut per_server_seeds: Vec<Vec<VId>> = vec![Vec::new(); p];
        // seat[i] = list of (server, index within that server's request)
        let mut seat: Vec<Vec<(usize, u32)>> = vec![Vec::new(); seeds.len()];
        for (i, &s) in seeds.iter().enumerate() {
            for srv in self.route(s) {
                seat[i].push((srv, per_server_seeds[srv].len() as u32));
                per_server_seeds[srv].push(s);
            }
        }
        let (tx, rx) = std::sync::mpsc::channel();
        let mut expected = 0usize;
        for (srv, sv_seeds) in per_server_seeds.into_iter().enumerate() {
            if sv_seeds.is_empty() {
                continue;
            }
            expected += 1;
            self.servers[srv]
                .send(ServerMsg::Gather(
                    GatherRequest {
                        seeds: sv_seeds,
                        fanout,
                        cfg: cfg.clone(),
                    },
                    tx.clone(),
                ))
                .expect("server hung up");
        }
        drop(tx);
        let mut responses: Vec<Option<GatherResponse>> = (0..p).map(|_| None).collect();
        for _ in 0..expected {
            let r = rx.recv().expect("server died");
            let part = r.part_id;
            responses[part] = Some(r);
        }

        // --- Apply: join (uniform) or global top-k (weighted) per seed ---
        let mut out = OneHopSample {
            offsets: Vec::with_capacity(seeds.len() + 1),
            neighbors: Vec::new(),
        };
        out.offsets.push(0);
        for (i, _) in seeds.iter().enumerate() {
            if cfg.weighted {
                let lists: Vec<Vec<(VId, f64)>> = seat[i]
                    .iter()
                    .filter_map(|&(srv, pos)| {
                        responses[srv].as_ref().map(|r| {
                            r.neighbors_of(pos as usize)
                                .iter()
                                .zip(r.scores_of(pos as usize))
                                .map(|(&n, &s)| (n, s))
                                .collect()
                        })
                    })
                    .collect();
                for (n, _) in merge_top_k(&lists, fanout) {
                    out.neighbors.push(n);
                }
            } else {
                let start = out.neighbors.len();
                for &(srv, pos) in &seat[i] {
                    if let Some(r) = &responses[srv] {
                        out.neighbors.extend_from_slice(r.neighbors_of(pos as usize));
                    }
                }
                // Stochastic rounding can overshoot fanout by a little:
                // keep a uniform subset to stay exact.
                let got = out.neighbors.len() - start;
                if got > fanout {
                    let keep = self.rng.sample_indices(got, fanout);
                    let selected: Vec<VId> =
                        keep.iter().map(|&j| out.neighbors[start + j]).collect();
                    out.neighbors.truncate(start);
                    out.neighbors.extend(selected);
                }
            }
            out.offsets.push(out.neighbors.len() as u32);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generator;
    use crate::graph::hetero::build_partitions;
    use crate::partition::{AdaDNE, Partitioner};
    use crate::sampling::server::{spawn, ServerStats};

    fn launch_small() -> (SamplingClient, Vec<Sender<ServerMsg>>) {
        let mut rng = Rng::new(130);
        let g = generator::chung_lu(600, 6000, 2.1, &mut rng);
        let ea = AdaDNE::default().partition(&g, 3, 0);
        let parts = build_partitions(&g, &ea.part_of_edge, 3);
        let mut membership = BitMatrix::new(g.n, 3);
        for p in &parts {
            for (l, &gid) in p.global_id.iter().enumerate() {
                let _ = l;
                membership.set(gid as usize, p.part_id);
            }
        }
        let mut servers = Vec::new();
        for p in parts {
            let (tx, _h) = spawn(Arc::new(p), Arc::new(ServerStats::default()), 9);
            servers.push(tx);
        }
        let client = SamplingClient {
            servers: servers.clone(),
            membership: Arc::new(membership),
            mode: RouteMode::AllReplicas,
            rng: Rng::new(77),
        };
        (client, servers)
    }

    #[test]
    fn one_hop_respects_fanout() {
        let (mut client, _s) = launch_small();
        let seeds: Vec<VId> = (0..64).collect();
        let got = client.sample_one_hop(&seeds, 5, &SampleConfig::default());
        assert_eq!(got.offsets.len(), 65);
        for i in 0..64 {
            assert!(got.neighbors_of(i).len() <= 5);
        }
    }

    #[test]
    fn duplicate_seeds_sampled_independently() {
        let (mut client, _s) = launch_small();
        let seeds: Vec<VId> = vec![3, 3, 3, 3];
        let got = client.sample_one_hop(&seeds, 4, &SampleConfig::default());
        assert_eq!(got.offsets.len(), 5);
        // Each occurrence gets its own (possibly different) sample.
        let lens: Vec<usize> = (0..4).map(|i| got.neighbors_of(i).len()).collect();
        assert!(lens.iter().all(|&l| l <= 4));
    }

    #[test]
    fn weighted_one_hop_returns_at_most_fanout() {
        let (mut client, _s) = launch_small();
        let seeds: Vec<VId> = (0..32).collect();
        let got = client.sample_one_hop(
            &seeds,
            3,
            &SampleConfig {
                weighted: true,
                ..Default::default()
            },
        );
        for i in 0..32 {
            assert!(got.neighbors_of(i).len() <= 3);
        }
    }
}
