//! Gather-Apply sampling client (paper Fig. 5, Algorithms 1 & 4). The
//! client fans a one-hop request out to servers, then post-processes the
//! partial results:
//!
//! * **GLISP routing** (`RouteMode::AllReplicas`): a seed's request goes to
//!   *every* partition holding a replica — a hotspot's one-hop sampling is
//!   served cooperatively, which is the load-balancing contribution.
//! * **Baseline routing** (`RouteMode::Owner`): a seed's request goes to a
//!   single owner server (the edge-cut / DistDGL architecture Fig. 10
//!   measures against).
//!
//! Per-server requests larger than `shard_size` seeds are split into
//! seed-range **shards** sharing one salt, so a partition's worker pool
//! serves a hotspot gather concurrently (DESIGN.md §9); per-seed RNG
//! streams on the server make the merged response bit-identical for any
//! shard split and worker count.
//!
//! A dead partition server is an error, not a panic: `sample_one_hop`
//! reports *which* partitions failed so the coordinator can surface it.

use anyhow::{bail, Result};
use std::sync::Arc;

use crate::graph::csr::VId;
use crate::sampling::request::{
    seed_stream_key, GatherOp, GatherRequest, GatherResponse, SampleConfig,
};
use crate::sampling::transport::Transport;
use crate::util::bitset::BitMatrix;
use crate::util::rng::Rng;
use crate::util::topk::TopK;

/// Per-client request scratch (DESIGN.md §14): the bucketing, seat, shard
/// and response-slot buffers `sample_one_hop` needs, reused across calls so
/// the K hops of a tree (and every batch a pipelined producer assembles)
/// re-run the Gather/Apply round without re-allocating its spines. Purely
/// structural scratch — every entry is cleared or overwritten before use and
/// no RNG state lives here, so reuse cannot change sampled bits.
#[derive(Clone)]
pub struct ClientScratch {
    /// Seed occurrences bucketed by server (spine + inner buffers reused).
    per_server_seeds: Vec<Vec<VId>>,
    /// seat[i] = (server, index within that server's request) per replica.
    seat: Vec<Vec<(usize, u32)>>,
    /// Shards sent per server this round.
    shards_of: Vec<usize>,
    /// Response slots, indexed [server][shard].
    responses: Vec<Vec<Option<GatherResponse>>>,
    /// Weighted Apply heap, `reset` per seed.
    tk: TopK<VId>,
}

impl Default for ClientScratch {
    fn default() -> Self {
        Self {
            per_server_seeds: Vec::new(),
            seat: Vec::new(),
            shards_of: Vec::new(),
            responses: Vec::new(),
            tk: TopK::new(0),
        }
    }
}

#[derive(Clone)]
pub enum RouteMode {
    /// Route each seed to all partitions containing it (vertex-cut, GLISP).
    AllReplicas,
    /// Route each seed to its unique owner (edge-cut baseline).
    Owner(Arc<Vec<u16>>),
}

/// Result of one Apply phase: per-seed neighbor lists, flattened.
#[derive(Clone, Debug, Default)]
pub struct OneHopSample {
    pub offsets: Vec<u32>,
    pub neighbors: Vec<VId>,
}

impl OneHopSample {
    pub fn neighbors_of(&self, i: usize) -> &[VId] {
        &self.neighbors[self.offsets[i] as usize..self.offsets[i + 1] as usize]
    }
}

#[derive(Clone)]
pub struct SamplingClient {
    /// One transport endpoint per partition (in-process channel or socket
    /// connection — the Gather/Apply logic below cannot tell the
    /// difference, which is the DESIGN.md §12 bit-identity argument).
    pub servers: Vec<Arc<dyn Transport>>,
    /// Global vertex → partition membership bits (from the partitioner).
    pub membership: Arc<BitMatrix>,
    pub mode: RouteMode,
    pub rng: Rng,
    /// Max seeds per Gather shard: per-server requests longer than this
    /// are split into seed-range shards (same salt, increasing
    /// `seed_offset`) that a server pool executes concurrently.
    /// `usize::MAX` or 0 (normalized at use) disables splitting.
    pub shard_size: usize,
    /// Reused request scratch (see [`ClientScratch`]).
    pub scratch: ClientScratch,
}

impl SamplingClient {
    /// Derive an independent clone for another thread (e.g. one pipelined
    /// batch producer): same servers and routing, decorrelated RNG stream.
    /// Distinct `stream` values from the same client yield distinct,
    /// deterministic streams; `self` is not mutated.
    pub fn split(&self, stream: u64) -> Self {
        let mut c = self.clone();
        let forked = c.rng.fork(stream);
        c.rng = forked;
        c
    }

    /// One Gather + Apply round (Algorithm 1, lines 9–10): sample up to
    /// `fanout` neighbors for every seed. Duplicate seeds are sampled
    /// independently (each occurrence is its own tree slot).
    pub fn sample_one_hop(
        &mut self,
        seeds: &[VId],
        fanout: usize,
        cfg: &SampleConfig,
    ) -> Result<OneHopSample> {
        // --- Gather: bucket seed occurrences by server. Membership bits
        // are iterated in place — no per-seed route Vec allocation; the
        // bucketing/seat/slot buffers come from the reused scratch. ---
        let p = self.servers.len();
        let sc = &mut self.scratch;
        for b in sc.per_server_seeds.iter_mut() {
            b.clear();
        }
        sc.per_server_seeds.resize_with(p, Vec::new);
        for s in sc.seat.iter_mut() {
            s.clear();
        }
        if sc.seat.len() < seeds.len() {
            sc.seat.resize_with(seeds.len(), Vec::new);
        }
        let (seat, per_server_seeds) = (&mut sc.seat, &mut sc.per_server_seeds);
        for (i, &s) in seeds.iter().enumerate() {
            let mut take = |srv: usize| {
                seat[i].push((srv, per_server_seeds[srv].len() as u32));
                per_server_seeds[srv].push(s);
            };
            match &self.mode {
                RouteMode::AllReplicas => {
                    for srv in self.membership.row_ones(s as usize) {
                        take(srv);
                    }
                }
                RouteMode::Owner(owner) => take(owner[s as usize] as usize),
            }
        }
        // 0 and usize::MAX both mean "never split" (ServiceConfig::new's
        // CLI contract) — a shard size of 0 must not degenerate into
        // one-seed shards.
        let shard = if self.shard_size == 0 {
            usize::MAX
        } else {
            self.shard_size
        };
        let (tx, rx) = std::sync::mpsc::channel();
        // shards_of[srv] = number of shards sent to that server (0 = none).
        sc.shards_of.clear();
        sc.shards_of.resize(p, 0);
        let mut total_sent = 0usize;
        for (srv, sv_seeds) in sc.per_server_seeds.iter().enumerate() {
            if sv_seeds.is_empty() {
                continue;
            }
            // One salt per *logical* server request, drawn in server-index
            // order — the client RNG stream is therefore invariant to the
            // shard size, and all shards of one request share the salt.
            let salt = self.rng.next_u64();
            let n_shards = sv_seeds.len().div_ceil(shard);
            sc.shards_of[srv] = n_shards;
            total_sent += n_shards;
            // Transport errors already name the partition and its peer
            // address (socket) or channel (in-process). Requests own their
            // seed Vec (it travels on the wire), so shards copy out of the
            // reused bucket instead of consuming it.
            let send_shard =
                |req: GatherRequest| -> Result<()> { self.servers[srv].send_gather(req, &tx) };
            if n_shards == 1 {
                send_shard(GatherRequest {
                    seeds: sv_seeds.clone(),
                    fanout,
                    cfg: cfg.clone(),
                    salt,
                    seed_offset: 0,
                    token: 0,
                })?;
            } else {
                for (si, chunk) in sv_seeds.chunks(shard).enumerate() {
                    send_shard(GatherRequest {
                        seeds: chunk.to_vec(),
                        fanout,
                        cfg: cfg.clone(),
                        salt,
                        seed_offset: (si * shard) as u32,
                        token: 0,
                    })?;
                }
            }
        }
        drop(tx);
        // responses[srv][shard] slots, filled as shards come back in any
        // order (the echoed seed_offset identifies the slot). The slot
        // spines are reused; each slot is overwritten before it is read.
        for (b, &n) in sc.responses.iter_mut().zip(sc.shards_of.iter()) {
            b.clear();
            b.resize(n, None);
        }
        if sc.responses.len() < p {
            let start = sc.responses.len();
            sc.responses
                .extend(sc.shards_of[start..].iter().map(|&n| vec![None; n]));
        }
        let responses = &mut sc.responses;
        for _ in 0..total_sent {
            match rx.recv() {
                Ok(r) => {
                    let slot = r.seed_offset as usize / shard;
                    responses[r.part_id][slot] = Some(r);
                }
                Err(_) => {
                    let missing: Vec<String> = (0..p)
                        .filter(|&s| responses[s].iter().any(|r| r.is_none()))
                        .map(|s| format!("{s} ({})", self.servers[s].peer()))
                        .collect();
                    bail!("sampling server(s) for partition(s) {missing:?} died mid-gather");
                }
            }
        }
        // A seat (srv, pos) lands in shard pos/shard at local index
        // pos - shard_base.
        fn slice_of<'r>(
            responses: &'r [Vec<Option<GatherResponse>>],
            shard: usize,
            srv: usize,
            pos: u32,
        ) -> Option<(&'r GatherResponse, usize)> {
            let r = responses[srv].get(pos as usize / shard)?.as_ref()?;
            Some((r, pos as usize - r.seed_offset as usize))
        }

        // --- Apply: join (uniform) or global top-k (weighted) per seed ---
        let mut out = OneHopSample {
            offsets: Vec::with_capacity(seeds.len() + 1),
            neighbors: Vec::new(),
        };
        out.offsets.push(0);
        // One reusable top-k scratch for the whole client: the weighted
        // merge reads (neighbor, score) straight off the response slices
        // instead of materializing per-seed Vec<Vec<_>> lists. (`sc.seat`
        // may be longer than this batch — only the first seeds.len()
        // entries were filled above.)
        let tk = &mut sc.tk;
        for seats in &sc.seat[..seeds.len()] {
            if cfg.scored() {
                tk.reset(fanout);
                let mut tiebreak = 0u64;
                for &(srv, pos) in seats {
                    if let Some((r, j)) = slice_of(responses, shard, srv, pos) {
                        let nbrs = r.neighbors_of(j);
                        let scores = r.scores_of(j);
                        for (&n, &s) in nbrs.iter().zip(scores) {
                            tk.push(s, tiebreak, n);
                            tiebreak += 1;
                        }
                    }
                }
                for (_, n) in tk.drain_sorted() {
                    out.neighbors.push(n);
                }
            } else {
                let start = out.neighbors.len();
                for &(srv, pos) in seats {
                    if let Some((r, j)) = slice_of(&responses, shard, srv, pos) {
                        out.neighbors.extend_from_slice(r.neighbors_of(j));
                    }
                }
                // Stochastic rounding can overshoot fanout by a little:
                // keep a uniform subset to stay exact.
                let got = out.neighbors.len() - start;
                if got > fanout {
                    let keep = self.rng.sample_indices(got, fanout);
                    let selected: Vec<VId> =
                        keep.iter().map(|&j| out.neighbors[start + j]).collect();
                    out.neighbors.truncate(start);
                    out.neighbors.extend(selected);
                }
            }
            out.offsets.push(out.neighbors.len() as u32);
        }
        Ok(out)
    }

    /// Deterministic top-`fanout` neighbors by edge weight per seed
    /// ([`GatherOp::TopK`]): the servers rank their local edges RNG-free
    /// and the Apply phase merges the shipped weights globally, so the
    /// result is a pure function of the graph — identical across pool
    /// sizes, shard splits, and transports. The serving path uses this for
    /// link-candidate retrieval.
    pub fn sample_topk(
        &mut self,
        seeds: &[VId],
        fanout: usize,
        base: &SampleConfig,
    ) -> Result<OneHopSample> {
        let cfg = SampleConfig {
            op: GatherOp::TopK,
            ..base.clone()
        };
        self.sample_one_hop(seeds, fanout, &cfg)
    }

    /// In-degree-proportional weighted sampling without replacement per
    /// seed ([`GatherOp::InDegree`]): neighbor pick probability follows the
    /// candidate's global in-degree (the "popular destination" prior).
    /// Same per-seed RNG stream contract as the other sampled operators.
    pub fn sample_in_degree(
        &mut self,
        seeds: &[VId],
        fanout: usize,
        base: &SampleConfig,
    ) -> Result<OneHopSample> {
        let cfg = SampleConfig {
            op: GatherOp::InDegree,
            ..base.clone()
        };
        self.sample_one_hop(seeds, fanout, &cfg)
    }

    /// Uniform **negative sampling** over the global vertex space — the
    /// unsupervised-training primitive (GLE's `negative_sampler`). Entirely
    /// client-local: the membership matrix already knows the global vertex
    /// count, so no wire round-trip is needed. For each seed occurrence,
    /// up to `k` distinct vertices are drawn uniformly from `[0, n)`,
    /// excluding the seed itself and (when `positives` is given, e.g. the
    /// `sample_one_hop` result for the same seed list) that occurrence's
    /// positive neighbor set.
    ///
    /// Determinism: one salt is drawn from the client RNG per call, and
    /// each occurrence samples from its own `(salt, index)`-derived stream
    /// — the same keying as the servers' per-seed streams — so results
    /// depend only on the client's RNG position, never on batch splits.
    pub fn sample_negatives(
        &mut self,
        seeds: &[VId],
        k: usize,
        positives: Option<&OneHopSample>,
    ) -> OneHopSample {
        if let Some(p) = positives {
            debug_assert_eq!(p.offsets.len(), seeds.len() + 1);
        }
        let n = self.membership.rows();
        let salt = self.rng.next_u64();
        let mut out = OneHopSample {
            offsets: Vec::with_capacity(seeds.len() + 1),
            neighbors: Vec::with_capacity(seeds.len() * k),
        };
        out.offsets.push(0);
        for (i, &seed) in seeds.iter().enumerate() {
            let mut rng = Rng::new(seed_stream_key(salt, i as u64));
            let pos = positives.map_or(&[][..], |p| p.neighbors_of(i));
            let start = out.neighbors.len();
            // Rejection sampling: the excluded set (seed + positives +
            // already-drawn negatives) is tiny next to n, so a bounded
            // number of rounds nearly always fills k; degenerate graphs
            // where it cannot just return fewer negatives.
            let mut attempts = 0usize;
            let budget = 16 * k + 64;
            while out.neighbors.len() - start < k && attempts < budget {
                attempts += 1;
                let v = rng.usize(n) as VId;
                if v == seed
                    || pos.contains(&v)
                    || out.neighbors[start..].contains(&v)
                {
                    continue;
                }
                out.neighbors.push(v);
            }
            out.offsets.push(out.neighbors.len() as u32);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generator;
    use crate::graph::hetero::build_partitions;
    use crate::partition::{AdaDNE, Partitioner};
    use crate::sampling::request::ServerMsg;
    use crate::sampling::server::{spawn, spawn_pool, ServerStats};
    use crate::sampling::transport::ChannelTransport;
    use std::sync::mpsc::Sender;

    /// Raw pool inboxes are returned alongside the client so tests can
    /// sabotage individual servers (dead_server below).
    fn launch_small_sized(
        workers: usize,
        shard_size: usize,
    ) -> (SamplingClient, Vec<Sender<ServerMsg>>) {
        let mut rng = Rng::new(130);
        let g = generator::chung_lu(600, 6000, 2.1, &mut rng);
        let ea = AdaDNE::default().partition(&g, 3, 0);
        let parts = build_partitions(&g, &ea.part_of_edge, 3).unwrap();
        let mut membership = BitMatrix::new(g.n, 3);
        for p in &parts {
            for (l, &gid) in p.global_id.iter().enumerate() {
                let _ = l;
                membership.set(gid as usize, p.part_id);
            }
        }
        let mut servers = Vec::new();
        let mut endpoints: Vec<Arc<dyn Transport>> = Vec::new();
        for p in parts {
            let pa = Arc::new(p);
            let st = Arc::new(ServerStats::with_workers(workers));
            let tx = if workers == 1 {
                let (tx, _h) = spawn(pa.clone(), st.clone(), 9);
                tx
            } else {
                let (tx, _h) = spawn_pool(pa.clone(), st.clone(), 9, workers);
                tx
            };
            endpoints.push(Arc::new(ChannelTransport {
                part_id: pa.part_id,
                inbox: tx.clone(),
                stats: st,
                graph: pa,
                workers,
            }));
            servers.push(tx);
        }
        let client = SamplingClient {
            servers: endpoints,
            membership: Arc::new(membership),
            mode: RouteMode::AllReplicas,
            rng: Rng::new(77),
            shard_size,
            scratch: ClientScratch::default(),
        };
        (client, servers)
    }

    fn launch_small() -> (SamplingClient, Vec<Sender<ServerMsg>>) {
        launch_small_sized(1, usize::MAX)
    }

    #[test]
    fn one_hop_respects_fanout() {
        let (mut client, _s) = launch_small();
        let seeds: Vec<VId> = (0..64).collect();
        let got = client
            .sample_one_hop(&seeds, 5, &SampleConfig::default())
            .unwrap();
        assert_eq!(got.offsets.len(), 65);
        for i in 0..64 {
            assert!(got.neighbors_of(i).len() <= 5);
        }
    }

    #[test]
    fn duplicate_seeds_sampled_independently() {
        let (mut client, _s) = launch_small();
        let seeds: Vec<VId> = vec![3, 3, 3, 3];
        let got = client
            .sample_one_hop(&seeds, 4, &SampleConfig::default())
            .unwrap();
        assert_eq!(got.offsets.len(), 5);
        // Each occurrence gets its own (possibly different) sample.
        let lens: Vec<usize> = (0..4).map(|i| got.neighbors_of(i).len()).collect();
        assert!(lens.iter().all(|&l| l <= 4));
    }

    #[test]
    fn weighted_one_hop_returns_at_most_fanout() {
        let (mut client, _s) = launch_small();
        let seeds: Vec<VId> = (0..32).collect();
        let got = client
            .sample_one_hop(
                &seeds,
                3,
                &SampleConfig {
                    weighted: true,
                    ..Default::default()
                },
            )
            .unwrap();
        for i in 0..32 {
            assert!(got.neighbors_of(i).len() <= 3);
        }
    }

    #[test]
    fn topk_operator_is_client_seed_invariant() {
        // TopK is RNG-free end to end: two clients on decorrelated RNG
        // streams must produce identical results, and the convenience
        // wrapper must match sample_one_hop with the op set explicitly.
        let (client, _s) = launch_small();
        let mut c1 = client.split(1);
        let mut c2 = client.split(2);
        let seeds: Vec<VId> = (0..48).collect();
        let a = c1.sample_topk(&seeds, 4, &SampleConfig::default()).unwrap();
        let b = c2.sample_topk(&seeds, 4, &SampleConfig::default()).unwrap();
        assert_eq!(a.offsets, b.offsets);
        assert_eq!(a.neighbors, b.neighbors, "TopK must not depend on client RNG");
        let cfg = SampleConfig {
            op: GatherOp::TopK,
            ..Default::default()
        };
        let c = c1.sample_one_hop(&seeds, 4, &cfg).unwrap();
        assert_eq!(a.neighbors, c.neighbors);
    }

    #[test]
    fn in_degree_operator_reproduces_across_split_clients() {
        let (client, _s) = launch_small();
        let mut c1 = client.split(4);
        let mut c2 = client.split(4);
        let seeds: Vec<VId> = (0..48).collect();
        let a = c1.sample_in_degree(&seeds, 5, &SampleConfig::default()).unwrap();
        let b = c2.sample_in_degree(&seeds, 5, &SampleConfig::default()).unwrap();
        assert_eq!(a.offsets, b.offsets);
        assert_eq!(a.neighbors, b.neighbors);
        for i in 0..seeds.len() {
            assert!(a.neighbors_of(i).len() <= 5);
        }
    }

    #[test]
    fn dead_server_is_an_error_naming_the_partition() {
        let (mut client, servers) = launch_small();
        // Kill partition 1's server; sampling must fail with a message that
        // names it instead of panicking.
        servers[1].send(ServerMsg::Shutdown).unwrap();
        // Give the server thread a moment to drain its inbox and exit.
        std::thread::sleep(std::time::Duration::from_millis(50));
        let seeds: Vec<VId> = (0..64).collect();
        let err = client
            .sample_one_hop(&seeds, 5, &SampleConfig::default())
            .unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains('1'), "error should name the partition: {msg}");
    }

    #[test]
    fn split_clients_are_deterministic_and_decorrelated() {
        let (client, _s) = launch_small();
        let mut a1 = client.split(0);
        let mut a2 = client.split(0);
        let mut b = client.split(1);
        let sa1: Vec<u64> = (0..8).map(|_| a1.rng.next_u64()).collect();
        let sa2: Vec<u64> = (0..8).map(|_| a2.rng.next_u64()).collect();
        let sb: Vec<u64> = (0..8).map(|_| b.rng.next_u64()).collect();
        assert_eq!(sa1, sa2, "same stream id must reproduce");
        assert_ne!(sa1, sb, "distinct stream ids must decorrelate");
    }

    #[test]
    fn identical_salted_requests_commute() {
        // Two clients with the same seed issue the same batch in opposite
        // order; per-seed salted streams make the responses identical — the
        // arrival-order independence the pipelined trainer relies on.
        let (client, _s) = launch_small();
        let mut c1 = client.split(7);
        let mut c2 = client.split(7);
        let batch_a: Vec<VId> = (0..32).collect();
        let batch_b: Vec<VId> = (32..64).collect();
        let a1 = c1.sample_one_hop(&batch_a, 5, &SampleConfig::default()).unwrap();
        let b1 = c1.sample_one_hop(&batch_b, 5, &SampleConfig::default()).unwrap();
        // c2 replays the same stream, but a third client hammers the servers
        // between its draws — which must not perturb c2's results.
        let mut noise = client.split(99);
        let a2 = c2.sample_one_hop(&batch_a, 5, &SampleConfig::default()).unwrap();
        noise
            .sample_one_hop(&batch_b, 7, &SampleConfig::default())
            .unwrap();
        let b2 = c2.sample_one_hop(&batch_b, 5, &SampleConfig::default()).unwrap();
        assert_eq!(a1.neighbors, a2.neighbors);
        assert_eq!(b1.neighbors, b2.neighbors);
    }

    #[test]
    fn negative_sampling_deterministic_and_excludes_positives() {
        let (client, _s) = launch_small(); // 600-vertex graph
        let mut c1 = client.split(5);
        let mut c2 = client.split(5);
        let seeds: Vec<VId> = (0..32).collect();
        let pos1 = c1.sample_one_hop(&seeds, 5, &SampleConfig::default()).unwrap();
        let neg1 = c1.sample_negatives(&seeds, 6, Some(&pos1));
        let pos2 = c2.sample_one_hop(&seeds, 5, &SampleConfig::default()).unwrap();
        let neg2 = c2.sample_negatives(&seeds, 6, Some(&pos2));
        assert_eq!(neg1.offsets, neg2.offsets, "negatives must reproduce");
        assert_eq!(neg1.neighbors, neg2.neighbors);
        for (i, &seed) in seeds.iter().enumerate() {
            let negs = neg1.neighbors_of(i);
            assert_eq!(negs.len(), 6, "n=600 dwarfs the excluded set");
            let mut distinct = negs.to_vec();
            distinct.sort_unstable();
            distinct.dedup();
            assert_eq!(distinct.len(), negs.len(), "negatives must be distinct");
            for &v in negs {
                assert!((v as usize) < 600);
                assert_ne!(v, seed, "seed sampled as its own negative");
                assert!(
                    !pos1.neighbors_of(i).contains(&v),
                    "positive {v} leaked into negatives of seed {seed}"
                );
            }
        }
    }

    /// The reused scratch must not leak state between batches of different
    /// sizes: a big batch followed by a small one must produce exactly
    /// small.len() seats (a stale-seat bug would append ghost offsets).
    #[test]
    fn scratch_survives_shrinking_batches() {
        let (client, _s) = launch_small_sized(2, 7);
        let mut c = client.split(11);
        let big: Vec<VId> = (0..80).collect();
        c.sample_one_hop(&big, 5, &SampleConfig::default()).unwrap();
        let small: Vec<VId> = (3..11).collect();
        let got = c
            .sample_one_hop(
                &small,
                4,
                &SampleConfig {
                    weighted: true,
                    ..Default::default()
                },
            )
            .unwrap();
        assert_eq!(got.offsets.len(), small.len() + 1);
        for i in 0..small.len() {
            assert!(got.neighbors_of(i).len() <= 4);
        }
    }

    #[test]
    fn sharded_pool_client_reproduces_unsharded_samples() {
        // Same client seed against (1 worker, no sharding) and (4 workers,
        // shards that split every per-server request mid-way): bit-equal
        // neighbor lists — the client-visible face of the per-seed RNG.
        let mut seeds: Vec<VId> = (0..96).collect();
        seeds.extend([7; 16]); // duplicate occurrences straddling shards
        for cfg in [
            SampleConfig::default(),
            SampleConfig {
                weighted: true,
                ..Default::default()
            },
            SampleConfig {
                op: GatherOp::TopK,
                ..Default::default()
            },
            SampleConfig {
                op: GatherOp::InDegree,
                ..Default::default()
            },
        ] {
            let (base_client, _s1) = launch_small_sized(1, usize::MAX);
            let mut base = base_client.split(3);
            let want = base.sample_one_hop(&seeds, 5, &cfg).unwrap();
            for (workers, shard) in [(4usize, 9usize), (4, 1), (2, 30)] {
                let (pool_client, _s2) = launch_small_sized(workers, shard);
                let mut c = pool_client.split(3);
                let got = c.sample_one_hop(&seeds, 5, &cfg).unwrap();
                assert_eq!(
                    got.offsets, want.offsets,
                    "offsets drifted (workers={workers} shard={shard})"
                );
                assert_eq!(
                    got.neighbors, want.neighbors,
                    "neighbors drifted (workers={workers} shard={shard})"
                );
            }
        }
    }
}
