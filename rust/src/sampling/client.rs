//! Gather-Apply sampling client (paper Fig. 5, Algorithms 1 & 4). The
//! client fans a one-hop request out to servers, then post-processes the
//! partial results:
//!
//! * **GLISP routing** (`RouteMode::AllReplicas`): a seed's request goes to
//!   *every* partition holding a replica — a hotspot's one-hop sampling is
//!   served cooperatively, which is the load-balancing contribution.
//! * **Baseline routing** (`RouteMode::Owner`): a seed's request goes to a
//!   single owner server (the edge-cut / DistDGL architecture Fig. 10
//!   measures against).
//!
//! A dead partition server is an error, not a panic: `sample_one_hop`
//! reports *which* partitions failed so the coordinator can surface it.

use anyhow::{bail, Result};
use std::sync::mpsc::Sender;
use std::sync::Arc;

use crate::graph::csr::VId;
use crate::sampling::request::{GatherRequest, GatherResponse, SampleConfig, ServerMsg};
use crate::util::bitset::BitMatrix;
use crate::util::rng::Rng;
use crate::util::topk::TopK;

#[derive(Clone)]
pub enum RouteMode {
    /// Route each seed to all partitions containing it (vertex-cut, GLISP).
    AllReplicas,
    /// Route each seed to its unique owner (edge-cut baseline).
    Owner(Arc<Vec<u16>>),
}

/// Result of one Apply phase: per-seed neighbor lists, flattened.
#[derive(Clone, Debug, Default)]
pub struct OneHopSample {
    pub offsets: Vec<u32>,
    pub neighbors: Vec<VId>,
}

impl OneHopSample {
    pub fn neighbors_of(&self, i: usize) -> &[VId] {
        &self.neighbors[self.offsets[i] as usize..self.offsets[i + 1] as usize]
    }
}

#[derive(Clone)]
pub struct SamplingClient {
    pub servers: Vec<Sender<ServerMsg>>,
    /// Global vertex → partition membership bits (from the partitioner).
    pub membership: Arc<BitMatrix>,
    pub mode: RouteMode,
    pub rng: Rng,
}

impl SamplingClient {
    /// Derive an independent clone for another thread (e.g. one pipelined
    /// batch producer): same servers and routing, decorrelated RNG stream.
    /// Distinct `stream` values from the same client yield distinct,
    /// deterministic streams; `self` is not mutated.
    pub fn split(&self, stream: u64) -> Self {
        let mut c = self.clone();
        let forked = c.rng.fork(stream);
        c.rng = forked;
        c
    }

    /// Partitions a seed is routed to under the current mode.
    fn route(&self, v: VId) -> Vec<usize> {
        match &self.mode {
            RouteMode::AllReplicas => self.membership.row_ones(v as usize).collect(),
            RouteMode::Owner(owner) => vec![owner[v as usize] as usize],
        }
    }

    /// One Gather + Apply round (Algorithm 1, lines 9–10): sample up to
    /// `fanout` neighbors for every seed. Duplicate seeds are sampled
    /// independently (each occurrence is its own tree slot).
    pub fn sample_one_hop(
        &mut self,
        seeds: &[VId],
        fanout: usize,
        cfg: &SampleConfig,
    ) -> Result<OneHopSample> {
        // --- Gather: bucket seed occurrences by server ---
        let p = self.servers.len();
        let mut per_server_seeds: Vec<Vec<VId>> = vec![Vec::new(); p];
        // seat[i] = list of (server, index within that server's request)
        let mut seat: Vec<Vec<(usize, u32)>> = vec![Vec::new(); seeds.len()];
        for (i, &s) in seeds.iter().enumerate() {
            for srv in self.route(s) {
                seat[i].push((srv, per_server_seeds[srv].len() as u32));
                per_server_seeds[srv].push(s);
            }
        }
        let (tx, rx) = std::sync::mpsc::channel();
        let mut sent: Vec<usize> = Vec::new();
        for (srv, sv_seeds) in per_server_seeds.into_iter().enumerate() {
            if sv_seeds.is_empty() {
                continue;
            }
            // Per-request salt: the server derives its sampling stream from
            // it, keeping responses independent of request arrival order.
            let salt = self.rng.next_u64();
            let req = GatherRequest {
                seeds: sv_seeds,
                fanout,
                cfg: cfg.clone(),
                salt,
            };
            if self.servers[srv].send(ServerMsg::Gather(req, tx.clone())).is_err() {
                bail!("sampling server for partition {srv} hung up before the gather");
            }
            sent.push(srv);
        }
        drop(tx);
        let mut responses: Vec<Option<GatherResponse>> = (0..p).map(|_| None).collect();
        for _ in 0..sent.len() {
            match rx.recv() {
                Ok(r) => {
                    let part = r.part_id;
                    responses[part] = Some(r);
                }
                Err(_) => {
                    let missing: Vec<usize> = sent
                        .iter()
                        .copied()
                        .filter(|&s| responses[s].is_none())
                        .collect();
                    bail!("sampling server(s) for partition(s) {missing:?} died mid-gather");
                }
            }
        }

        // --- Apply: join (uniform) or global top-k (weighted) per seed ---
        let mut out = OneHopSample {
            offsets: Vec::with_capacity(seeds.len() + 1),
            neighbors: Vec::new(),
        };
        out.offsets.push(0);
        // One reusable top-k scratch for the whole batch: the weighted merge
        // reads (neighbor, score) straight off the response slices instead
        // of materializing per-seed Vec<Vec<_>> lists.
        let mut tk: TopK<VId> = TopK::new(fanout);
        for seats in &seat {
            if cfg.weighted {
                tk.reset(fanout);
                let mut tiebreak = 0u64;
                for &(srv, pos) in seats {
                    if let Some(r) = &responses[srv] {
                        let nbrs = r.neighbors_of(pos as usize);
                        let scores = r.scores_of(pos as usize);
                        for (&n, &s) in nbrs.iter().zip(scores) {
                            tk.push(s, tiebreak, n);
                            tiebreak += 1;
                        }
                    }
                }
                for (_, n) in tk.drain_sorted() {
                    out.neighbors.push(n);
                }
            } else {
                let start = out.neighbors.len();
                for &(srv, pos) in seats {
                    if let Some(r) = &responses[srv] {
                        out.neighbors.extend_from_slice(r.neighbors_of(pos as usize));
                    }
                }
                // Stochastic rounding can overshoot fanout by a little:
                // keep a uniform subset to stay exact.
                let got = out.neighbors.len() - start;
                if got > fanout {
                    let keep = self.rng.sample_indices(got, fanout);
                    let selected: Vec<VId> =
                        keep.iter().map(|&j| out.neighbors[start + j]).collect();
                    out.neighbors.truncate(start);
                    out.neighbors.extend(selected);
                }
            }
            out.offsets.push(out.neighbors.len() as u32);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generator;
    use crate::graph::hetero::build_partitions;
    use crate::partition::{AdaDNE, Partitioner};
    use crate::sampling::server::{spawn, ServerStats};

    fn launch_small() -> (SamplingClient, Vec<Sender<ServerMsg>>) {
        let mut rng = Rng::new(130);
        let g = generator::chung_lu(600, 6000, 2.1, &mut rng);
        let ea = AdaDNE::default().partition(&g, 3, 0);
        let parts = build_partitions(&g, &ea.part_of_edge, 3);
        let mut membership = BitMatrix::new(g.n, 3);
        for p in &parts {
            for (l, &gid) in p.global_id.iter().enumerate() {
                let _ = l;
                membership.set(gid as usize, p.part_id);
            }
        }
        let mut servers = Vec::new();
        for p in parts {
            let (tx, _h) = spawn(Arc::new(p), Arc::new(ServerStats::default()), 9);
            servers.push(tx);
        }
        let client = SamplingClient {
            servers: servers.clone(),
            membership: Arc::new(membership),
            mode: RouteMode::AllReplicas,
            rng: Rng::new(77),
        };
        (client, servers)
    }

    #[test]
    fn one_hop_respects_fanout() {
        let (mut client, _s) = launch_small();
        let seeds: Vec<VId> = (0..64).collect();
        let got = client
            .sample_one_hop(&seeds, 5, &SampleConfig::default())
            .unwrap();
        assert_eq!(got.offsets.len(), 65);
        for i in 0..64 {
            assert!(got.neighbors_of(i).len() <= 5);
        }
    }

    #[test]
    fn duplicate_seeds_sampled_independently() {
        let (mut client, _s) = launch_small();
        let seeds: Vec<VId> = vec![3, 3, 3, 3];
        let got = client
            .sample_one_hop(&seeds, 4, &SampleConfig::default())
            .unwrap();
        assert_eq!(got.offsets.len(), 5);
        // Each occurrence gets its own (possibly different) sample.
        let lens: Vec<usize> = (0..4).map(|i| got.neighbors_of(i).len()).collect();
        assert!(lens.iter().all(|&l| l <= 4));
    }

    #[test]
    fn weighted_one_hop_returns_at_most_fanout() {
        let (mut client, _s) = launch_small();
        let seeds: Vec<VId> = (0..32).collect();
        let got = client
            .sample_one_hop(
                &seeds,
                3,
                &SampleConfig {
                    weighted: true,
                    ..Default::default()
                },
            )
            .unwrap();
        for i in 0..32 {
            assert!(got.neighbors_of(i).len() <= 3);
        }
    }

    #[test]
    fn dead_server_is_an_error_naming_the_partition() {
        let (mut client, servers) = launch_small();
        // Kill partition 1's server; sampling must fail with a message that
        // names it instead of panicking.
        servers[1].send(ServerMsg::Shutdown).unwrap();
        // Give the server thread a moment to drain its inbox and exit.
        std::thread::sleep(std::time::Duration::from_millis(50));
        let seeds: Vec<VId> = (0..64).collect();
        let err = client
            .sample_one_hop(&seeds, 5, &SampleConfig::default())
            .unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains('1'), "error should name the partition: {msg}");
    }

    #[test]
    fn split_clients_are_deterministic_and_decorrelated() {
        let (client, _s) = launch_small();
        let mut a1 = client.split(0);
        let mut a2 = client.split(0);
        let mut b = client.split(1);
        let sa1: Vec<u64> = (0..8).map(|_| a1.rng.next_u64()).collect();
        let sa2: Vec<u64> = (0..8).map(|_| a2.rng.next_u64()).collect();
        let sb: Vec<u64> = (0..8).map(|_| b.rng.next_u64()).collect();
        assert_eq!(sa1, sa2, "same stream id must reproduce");
        assert_ne!(sa1, sb, "distinct stream ids must decorrelate");
    }

    #[test]
    fn identical_salted_requests_commute() {
        // Two clients with the same seed issue the same batch in opposite
        // order; the per-request salt makes the responses identical — the
        // arrival-order independence the pipelined trainer relies on.
        let (client, _s) = launch_small();
        let mut c1 = client.split(7);
        let mut c2 = client.split(7);
        let batch_a: Vec<VId> = (0..32).collect();
        let batch_b: Vec<VId> = (32..64).collect();
        let a1 = c1.sample_one_hop(&batch_a, 5, &SampleConfig::default()).unwrap();
        let b1 = c1.sample_one_hop(&batch_b, 5, &SampleConfig::default()).unwrap();
        // c2 replays the same stream, but a third client hammers the servers
        // between its draws — which must not perturb c2's results.
        let mut noise = client.split(99);
        let a2 = c2.sample_one_hop(&batch_a, 5, &SampleConfig::default()).unwrap();
        noise
            .sample_one_hop(&batch_b, 7, &SampleConfig::default())
            .unwrap();
        let b2 = c2.sample_one_hop(&batch_b, 5, &SampleConfig::default()).unwrap();
        assert_eq!(a1.neighbors, a2.neighbors);
        assert_eq!(b1.neighbors, b2.neighbors);
    }
}
