//! Sampling service lifecycle: launch P partition server *pools* (R
//! workers each over one shared inbox), hand out clients, expose per-server
//! workload counters, shut down cleanly. This is the in-process analogue of
//! the paper's "P servers will be launched, each for one partition", with
//! §III-C's "one hop sampling request of high degree vertices handled by
//! multiple servers" realized inside each partition by the worker pool +
//! client-side seed-range sharding (DESIGN.md §9).
//!
//! Since the wire refactor (DESIGN.md §12) the service is also the client
//! face of a *distributed* deployment: [`SamplingService::connect`] joins
//! partition servers running as separate `glisp serve` processes over
//! TCP/Unix sockets, and [`SamplingService::launch_remote`] spins up the
//! socket deployment in-process (loopback) for tests and benchmarks. Both
//! yield the same `SamplingClient` API, and the per-seed RNG contract
//! makes every sampled bit identical across transports.

use anyhow::{bail, Context, Result};
use std::sync::Arc;

use crate::graph::csr::{Graph, VId};
use crate::graph::hetero::{build_partitions_threads, PartitionGraph};
use crate::graph::store::StoreBackend;
use crate::partition::EdgeAssignment;
use crate::sampling::client::{RouteMode, SamplingClient};
use crate::sampling::server::{spawn_pool, ServerStats};
use crate::sampling::transport::{
    serve_partition, ChannelTransport, RemoteServer, SocketTransport, Transport,
};
use crate::sampling::wire::StatsSnapshot;
use crate::util::bitset::BitMatrix;
use crate::util::rng::Rng;

/// Threading knobs of the sampling service. Per-seed RNG streams make the
/// sampled output bit-identical for ANY (workers, shard_size) — these only
/// trade throughput (`workers=1` + no sharding keeps the old
/// one-thread-per-partition deployment: same thread layout and message
/// protocol).
#[derive(Clone, Copy, Debug)]
pub struct ServiceConfig {
    /// Pool workers per partition sharing one inbox. For a connected
    /// (socket) service this is decided by each `glisp serve` process and
    /// the field is ignored client-side.
    pub workers: usize,
    /// Max seeds per Gather shard (client-side request splitting);
    /// `usize::MAX` or 0 = never split.
    pub shard_size: usize,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        Self {
            workers: 1,
            shard_size: usize::MAX,
        }
    }
}

impl ServiceConfig {
    /// The canonical normalization (also applied by `launch_*_cfg`):
    /// `workers == 0` means 1; `shard_size == 0` means "never split"
    /// (the `--shard-size 0` default of the examples and the `glisp` CLI).
    pub fn new(workers: usize, shard_size: usize) -> Self {
        Self {
            workers: workers.max(1),
            shard_size: if shard_size == 0 { usize::MAX } else { shard_size },
        }
    }
}

/// Replica vertex-id list of one partition, as the service knows it:
/// borrowed from the in-process partition structure, or shipped over the
/// wire by the Members RPC when the partition lives in another process.
enum MembersRef {
    Local(Arc<PartitionGraph>),
    Remote(Arc<Vec<VId>>),
}

impl MembersRef {
    fn ids(&self) -> &[VId] {
        match self {
            MembersRef::Local(p) => &p.global_id,
            MembersRef::Remote(ids) => ids,
        }
    }
}

pub struct SamplingService {
    /// One transport endpoint per partition, ordered by partition id.
    pub endpoints: Vec<Arc<dyn Transport>>,
    /// Direct stats handles — populated only for in-process deployments
    /// (tests peek at individual counters through these); across the wire
    /// use [`Self::workload`] etc., which go through the Stats RPC.
    pub stats: Vec<Arc<ServerStats>>,
    pub membership: Arc<BitMatrix>,
    /// In-process partition structures; empty for a connected service
    /// (the graphs live in the server processes).
    pub partitions: Vec<Arc<PartitionGraph>>,
    pub config: ServiceConfig,
    members: Vec<MembersRef>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl SamplingService {
    /// Partition `g` with `assign` and launch one single-worker server per
    /// partition (the paper's base deployment). Errors if the assignment
    /// doesn't match the graph (edge count or partition ids).
    pub fn launch(g: &Graph, assign: &EdgeAssignment, seed: u64) -> Result<Self> {
        Self::launch_cfg(g, assign, seed, ServiceConfig::default())
    }

    /// Partition `g` with `assign` and launch one `cfg.workers`-strong
    /// server pool per partition. The compact structures are assembled with
    /// `cfg.workers` builder threads (output is thread-count invariant,
    /// DESIGN.md §10).
    pub fn launch_cfg(
        g: &Graph,
        assign: &EdgeAssignment,
        seed: u64,
        cfg: ServiceConfig,
    ) -> Result<Self> {
        let parts = build_partitions_threads(
            g,
            &assign.part_of_edge,
            assign.num_parts,
            cfg.workers.max(1),
        )?;
        Ok(Self::launch_with_partitions_cfg(g.n, parts, seed, cfg))
    }

    pub fn launch_with_partitions(n: usize, parts: Vec<PartitionGraph>, seed: u64) -> Self {
        Self::launch_with_partitions_cfg(n, parts, seed, ServiceConfig::default())
    }

    pub fn launch_with_partitions_cfg(
        n: usize,
        parts: Vec<PartitionGraph>,
        seed: u64,
        cfg: ServiceConfig,
    ) -> Self {
        // Normalize through the one canonical rule (0 workers -> 1,
        // shard 0 -> never split).
        let cfg = ServiceConfig::new(cfg.workers, cfg.shard_size);
        let num_parts = parts.len();
        let mut membership = BitMatrix::new(n, num_parts);
        for p in &parts {
            for &gid in &p.global_id {
                membership.set(gid as usize, p.part_id);
            }
        }
        let membership = Arc::new(membership);
        let mut endpoints: Vec<Arc<dyn Transport>> = Vec::new();
        let mut stats = Vec::new();
        let mut handles = Vec::new();
        let mut partitions = Vec::new();
        let mut members = Vec::new();
        for p in parts {
            let st = Arc::new(ServerStats::with_workers(cfg.workers));
            let pa = Arc::new(p);
            let (tx, hs) = spawn_pool(pa.clone(), st.clone(), seed, cfg.workers);
            endpoints.push(Arc::new(ChannelTransport {
                part_id: pa.part_id,
                inbox: tx,
                stats: st.clone(),
                graph: pa.clone(),
                workers: cfg.workers,
            }));
            stats.push(st);
            handles.extend(hs);
            members.push(MembersRef::Local(pa.clone()));
            partitions.push(pa);
        }
        Self {
            endpoints,
            stats,
            membership,
            partitions,
            config: cfg,
            members,
            handles,
        }
    }

    /// Launch the service over a saved partition set (`part0..partN` in
    /// `dir`), through the storage seam: `StoreBackend::Heap` decodes onto
    /// the heap, `StoreBackend::Mmap` serves the structures straight out
    /// of the mapped files. Either way the sampled bits are identical to a
    /// fresh in-memory build of the same partitions (DESIGN.md §13).
    pub fn launch_from_dir(
        dir: &std::path::Path,
        seed: u64,
        cfg: ServiceConfig,
        backend: StoreBackend,
    ) -> Result<Self> {
        let parts = crate::graph::store::open_partitions(dir, backend)?;
        let n = parts
            .iter()
            .filter_map(|p| p.global_id.last().map(|&g| g as usize + 1))
            .max()
            .unwrap_or(0);
        Ok(Self::launch_with_partitions_cfg(n, parts, seed, cfg))
    }

    /// Partition `g`, then run every partition server behind a socket
    /// listener (`listens[p]`, `tcp:`/`unix:` syntax; `tcp:127.0.0.1:0`
    /// picks a free port) and connect back to them — the loopback
    /// multi-process deployment in one call, used by tests and the fig09
    /// wire rows. Returns the connected service plus the server handles
    /// (shut the service down first, then `join` the servers).
    pub fn launch_remote(
        g: &Graph,
        assign: &EdgeAssignment,
        seed: u64,
        cfg: ServiceConfig,
        listens: &[String],
    ) -> Result<(Self, Vec<RemoteServer>)> {
        let cfg = ServiceConfig::new(cfg.workers, cfg.shard_size);
        if listens.len() != assign.num_parts {
            bail!(
                "need one listen address per partition: got {} for {} partitions",
                listens.len(),
                assign.num_parts
            );
        }
        let parts = build_partitions_threads(
            g,
            &assign.part_of_edge,
            assign.num_parts,
            cfg.workers.max(1),
        )?;
        let mut servers = Vec::new();
        for (p, listen) in parts.into_iter().zip(listens) {
            servers.push(serve_partition(Arc::new(p), listen, seed, cfg.workers)?);
        }
        let addrs: Vec<String> = servers.iter().map(|s| s.addr().to_string()).collect();
        let svc = Self::connect(&addrs, g.n, cfg)?;
        Ok((svc, servers))
    }

    /// Join an already-running socket deployment: dial each address, learn
    /// every server's partition id and replica set over the Members RPC,
    /// and assemble the same membership matrix a local launch would build.
    /// The servers must cover partitions 0..P exactly (any order of
    /// addresses); `n` is the global vertex count (grown to fit the
    /// replica ids if passed too small, e.g. 0 when unknown).
    pub fn connect(addrs: &[String], n: usize, cfg: ServiceConfig) -> Result<Self> {
        let cfg = ServiceConfig::new(cfg.workers, cfg.shard_size);
        let mut eps = Vec::new();
        for addr in addrs {
            let t = SocketTransport::connect(addr)
                .with_context(|| format!("joining sampling fleet member {addr}"))?;
            let info = t.members()?;
            eps.push((t, info));
        }
        eps.sort_by_key(|(_, m)| m.part_id);
        for (want, (t, m)) in eps.iter().enumerate() {
            if m.part_id as usize != want {
                bail!(
                    "connected servers must cover partitions 0..{} exactly: \
                     expected partition {want}, but {} serves partition {}",
                    addrs.len(),
                    t.peer(),
                    m.part_id
                );
            }
        }
        let max_gid = eps
            .iter()
            .flat_map(|(_, m)| m.ids.iter())
            .copied()
            .max()
            .map(|v| v as usize + 1)
            .unwrap_or(0);
        let n = n.max(max_gid);
        let mut membership = BitMatrix::new(n, eps.len());
        let mut endpoints: Vec<Arc<dyn Transport>> = Vec::new();
        let mut members = Vec::new();
        for (t, m) in eps {
            for &gid in &m.ids {
                membership.set(gid as usize, m.part_id as usize);
            }
            endpoints.push(t);
            members.push(MembersRef::Remote(Arc::new(m.ids)));
        }
        Ok(Self {
            endpoints,
            stats: Vec::new(),
            membership: Arc::new(membership),
            partitions: Vec::new(),
            config: cfg,
            members,
            handles: Vec::new(),
        })
    }

    /// Number of partitions the service fronts (local or remote).
    pub fn num_partitions(&self) -> usize {
        self.endpoints.len()
    }

    /// Replica vertex ids of partition `p` — local structure or the
    /// Members handshake, whichever this deployment has.
    pub fn members_of(&self, p: usize) -> &[VId] {
        self.members[p].ids()
    }

    /// A client with GLISP's cooperative replica routing.
    pub fn client(&self, seed: u64) -> SamplingClient {
        SamplingClient {
            servers: self.endpoints.clone(),
            membership: self.membership.clone(),
            mode: RouteMode::AllReplicas,
            rng: Rng::new(seed),
            shard_size: self.config.shard_size,
            scratch: Default::default(),
        }
    }

    /// A client with single-owner routing (the DistDGL-like baseline).
    pub fn owner_client(&self, owner: Arc<Vec<u16>>, seed: u64) -> SamplingClient {
        SamplingClient {
            servers: self.endpoints.clone(),
            membership: self.membership.clone(),
            mode: RouteMode::Owner(owner),
            rng: Rng::new(seed),
            shard_size: self.config.shard_size,
            scratch: Default::default(),
        }
    }

    /// Per-partition stats snapshots (one Stats RPC each for sockets,
    /// atomic loads in-process) — the backing for all counter views below.
    pub fn stats_snapshots(&self) -> Result<Vec<StatsSnapshot>> {
        self.endpoints.iter().map(|e| e.stats()).collect()
    }

    /// Per-server edges-scanned counters — the Fig. 10 workload metric.
    /// Invariant to `workers`/`shard_size` (per-seed streams).
    pub fn workload(&self) -> Result<Vec<u64>> {
        Ok(self.stats_snapshots()?.iter().map(|s| s.edges_scanned).collect())
    }

    /// Requests (shards) served per pool worker, per partition — the
    /// DESIGN.md §9 attribution view of how a partition's pool shares its
    /// inbox.
    pub fn worker_requests(&self) -> Result<Vec<Vec<u64>>> {
        Ok(self
            .stats_snapshots()?
            .into_iter()
            .map(|s| s.worker_requests)
            .collect())
    }

    /// CPU seconds spent serving gathers per pool worker, per partition
    /// (sums to [`Self::busy_secs`] per partition) — shows whether a
    /// pool's members actually share the serving time or one worker wins
    /// every inbox race.
    pub fn worker_busy_secs(&self) -> Result<Vec<Vec<f64>>> {
        Ok(self
            .stats_snapshots()?
            .into_iter()
            .map(|s| s.worker_busy_ns.iter().map(|&ns| ns as f64 / 1e9).collect())
            .collect())
    }

    pub fn reset_stats(&self) -> Result<()> {
        for e in &self.endpoints {
            e.reset_stats()?;
        }
        Ok(())
    }

    /// Per-server busy time in seconds (all pool workers summed). `max` of
    /// this vector is the simulated distributed makespan of the traffic
    /// since the last reset (the servers run in parallel in the paper's
    /// deployment).
    pub fn busy_secs(&self) -> Result<Vec<f64>> {
        Ok(self
            .stats_snapshots()?
            .iter()
            .map(|s| s.busy_ns as f64 / 1e9)
            .collect())
    }

    /// Total memory of the partitioned graph structures (Table III),
    /// wherever they live.
    pub fn graph_bytes(&self) -> Result<usize> {
        Ok(self
            .stats_snapshots()?
            .iter()
            .map(|s| s.graph_bytes as usize)
            .sum())
    }

    /// Stop every partition server this service fronts — pool workers
    /// in-process, whole `glisp serve` processes across the wire — then
    /// join any local threads. Errors from individual endpoints are
    /// swallowed (a server that already died is already shut down).
    pub fn shutdown(self) {
        for e in &self.endpoints {
            let _ = e.shutdown();
        }
        for h in self.handles {
            let _ = h.join();
        }
    }

    /// Drop the connections WITHOUT stopping the servers — the multi-client
    /// counterpart of [`Self::shutdown`] for socket deployments (another
    /// trainer may still be using the fleet). In-process pools have no
    /// detached existence, so for them this leaks the pool threads; only
    /// call it on connected services.
    pub fn disconnect(self) {}
}

/// Seeds spread evenly across partitions — the paper's "balanced seed"
/// experimental setup (§IV-C): uniformly sample an equal number of seed
/// vertices from each partition. Uses the replica id lists, so it works
/// identically (same RNG consumption, same seeds) for local and connected
/// services.
pub fn balanced_seeds(
    service: &SamplingService,
    per_part: usize,
    rng: &mut Rng,
) -> Vec<VId> {
    let mut seeds = Vec::with_capacity(per_part * service.num_partitions());
    for p in 0..service.num_partitions() {
        let ids = service.members_of(p);
        for _ in 0..per_part {
            let l = rng.usize(ids.len());
            seeds.push(ids[l]);
        }
    }
    seeds
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generator;
    use crate::partition::{AdaDNE, Partitioner};
    use crate::sampling::request::{Direction, SampleConfig};
    use crate::sampling::subgraph::sample_tree;

    #[test]
    fn launch_sample_shutdown() {
        let mut rng = Rng::new(140);
        let g = generator::chung_lu(800, 8000, 2.1, &mut rng);
        let ea = AdaDNE::default().partition(&g, 4, 0);
        let svc = SamplingService::launch(&g, &ea, 1).unwrap();
        let mut client = svc.client(2);
        let seeds = balanced_seeds(&svc, 8, &mut rng);
        assert_eq!(seeds.len(), 32);
        let got = client
            .sample_one_hop(&seeds, 5, &SampleConfig::default())
            .unwrap();
        assert_eq!(got.offsets.len(), 33);
        // Work must be spread across all servers for AllReplicas routing.
        let wl = svc.workload().unwrap();
        assert_eq!(wl.len(), 4);
        assert!(wl.iter().sum::<u64>() > 0);
        svc.shutdown();
    }

    #[test]
    fn launch_rejects_mismatched_assignment() {
        // PR 2's non-panicking data-path convention, extended offline: a
        // stale or truncated assignment must surface as an error naming the
        // counts, not as a build_partitions panic.
        let mut rng = Rng::new(144);
        let g = generator::chung_lu(300, 2000, 2.1, &mut rng);
        let ea = EdgeAssignment {
            num_parts: 2,
            part_of_edge: vec![0; g.m() - 1],
        };
        let err = SamplingService::launch(&g, &ea, 1).unwrap_err();
        assert!(format!("{err:#}").contains("out of sync"));
    }

    #[test]
    fn multiple_clients_share_servers() {
        let mut rng = Rng::new(141);
        let g = generator::chung_lu(500, 5000, 2.1, &mut rng);
        let ea = AdaDNE::default().partition(&g, 2, 0);
        let svc = SamplingService::launch(&g, &ea, 1).unwrap();
        let mut c1 = svc.client(10);
        let mut c2 = svc.client(11);
        let t1 = std::thread::spawn(move || {
            let seeds: Vec<VId> = (0..100).collect();
            c1.sample_one_hop(&seeds, 4, &SampleConfig::default()).unwrap()
        });
        let seeds: Vec<VId> = (100..200).collect();
        let r2 = c2.sample_one_hop(&seeds, 4, &SampleConfig::default()).unwrap();
        let r1 = t1.join().unwrap();
        assert_eq!(r1.offsets.len(), 101);
        assert_eq!(r2.offsets.len(), 101);
        svc.shutdown();
    }

    #[test]
    fn hotspot_seed_requests_spread_across_replicas() {
        use std::sync::atomic::Ordering;

        // A hub replicated on every partition must have its one-hop load
        // served cooperatively under AllReplicas routing (ServerStats::seeds
        // counts on every replica server), while Owner routing concentrates
        // the same traffic on a single server — the Fig. 10 contrast at the
        // granularity of one hotspot seed.
        let hub_deg = 120usize;
        let parts = 3usize;
        let mut edges: Vec<(VId, VId)> = Vec::new();
        for i in 0..hub_deg {
            edges.push((0, (i + 1) as VId));
        }
        for i in 1..=hub_deg {
            edges.push((i as VId, ((i % hub_deg) + 1) as VId));
        }
        let g = Graph::from_edges(hub_deg + 1, &edges);
        // Round-robin edge assignment: the hub's edges land on all servers.
        let ea = EdgeAssignment {
            num_parts: parts,
            part_of_edge: (0..g.m()).map(|e| (e % parts) as u16).collect(),
        };
        let svc = SamplingService::launch(&g, &ea, 1).unwrap();
        let occurrences = 40usize;
        let seeds: Vec<VId> = vec![0; occurrences];

        let mut client = svc.client(9);
        client
            .sample_one_hop(&seeds, 8, &SampleConfig::default())
            .unwrap();
        let per_server: Vec<u64> = svc
            .stats
            .iter()
            .map(|s| s.seeds.load(Ordering::Relaxed))
            .collect();
        assert!(
            per_server.iter().all(|&s| s == occurrences as u64),
            "every replica server must see every hub occurrence: {per_server:?}"
        );

        svc.reset_stats().unwrap();
        let owner = Arc::new(vec![0u16; g.n]);
        let mut oc = svc.owner_client(owner, 10);
        oc.sample_one_hop(&seeds, 8, &SampleConfig::default())
            .unwrap();
        let per_server: Vec<u64> = svc
            .stats
            .iter()
            .map(|s| s.seeds.load(Ordering::Relaxed))
            .collect();
        assert_eq!(per_server[0], occurrences as u64);
        assert!(
            per_server[1..].iter().all(|&s| s == 0),
            "owner routing must concentrate the load: {per_server:?}"
        );
        svc.shutdown();
    }

    /// Launch twin services over identical partitions and compare
    /// `sample_one_hop` bit-for-bit across pool geometries. This is the
    /// acceptance matrix of the worker-pool refactor: uniform / weighted /
    /// etype-filtered / In-direction, workers ∈ {1, 4}, and shard sizes
    /// that split requests mid-way (including mid-duplicate-run).
    #[test]
    fn one_hop_is_invariant_to_workers_and_shards() {
        let mut rng = Rng::new(142);
        let g = generator::heterogeneous_graph(900, 11_000, 2, 3, 2.2, &mut rng);
        let ea = AdaDNE::default().partition(&g, 3, 0);
        let cfgs = [
            SampleConfig::default(),
            SampleConfig {
                weighted: true,
                ..Default::default()
            },
            SampleConfig {
                etype: Some(1),
                ..Default::default()
            },
            SampleConfig {
                direction: Direction::In,
                ..Default::default()
            },
            SampleConfig {
                op: crate::sampling::request::GatherOp::TopK,
                ..Default::default()
            },
            SampleConfig {
                op: crate::sampling::request::GatherOp::InDegree,
                ..Default::default()
            },
        ];
        // Balanced seeds + a duplicated hub run straddling shard bounds.
        let base = SamplingService::launch(&g, &ea, 1).unwrap();
        let mut srng = Rng::new(4);
        let mut seeds = balanced_seeds(&base, 24, &mut srng);
        let hub = (0..g.n as VId).max_by_key(|&v| g.out_neighbors(v).len()).unwrap();
        seeds.extend([hub; 13]);
        let mut want = Vec::new();
        for cfg in &cfgs {
            let mut c = base.client(6);
            want.push(c.sample_one_hop(&seeds, 7, cfg).unwrap());
        }
        base.shutdown();
        for (workers, shard) in [(4usize, 10usize), (4, 3), (1, 5)] {
            let svc = SamplingService::launch_cfg(
                &g,
                &ea,
                1,
                ServiceConfig {
                    workers,
                    shard_size: shard,
                },
            )
            .unwrap();
            for (cfg, want) in cfgs.iter().zip(&want) {
                let mut c = svc.client(6);
                let got = c.sample_one_hop(&seeds, 7, cfg).unwrap();
                assert_eq!(
                    got.offsets, want.offsets,
                    "offsets drifted: workers={workers} shard={shard} cfg={cfg:?}"
                );
                assert_eq!(
                    got.neighbors, want.neighbors,
                    "neighbors drifted: workers={workers} shard={shard} cfg={cfg:?}"
                );
            }
            svc.shutdown();
        }
    }

    /// `sample_tree` (the full K-hop Gather-Apply loop) and the partition-
    /// level ServerStats totals must also be pool-invariant; only the
    /// per-worker attribution may differ (and must sum to the totals).
    #[test]
    fn sample_tree_and_stats_totals_are_pool_invariant() {
        use std::sync::atomic::Ordering;
        let mut rng = Rng::new(143);
        let g = generator::chung_lu(900, 9000, 2.1, &mut rng);
        let ea = AdaDNE::default().partition(&g, 3, 0);
        let fanouts = [6usize, 4];
        let seeds: Vec<VId> = (0..48).collect();

        // Both services use the same shard size so request counts match;
        // only the worker count differs.
        let shard = 11usize;
        let svc1 = SamplingService::launch_cfg(&g, &ea, 1, ServiceConfig::new(1, shard)).unwrap();
        let mut c1 = svc1.client(8);
        let t1 = sample_tree(&mut c1, &seeds, &fanouts, &SampleConfig::default()).unwrap();
        let totals1: Vec<[u64; 4]> = svc1
            .stats
            .iter()
            .map(|s| {
                [
                    s.requests.load(Ordering::Relaxed),
                    s.seeds.load(Ordering::Relaxed),
                    s.edges_scanned.load(Ordering::Relaxed),
                    s.neighbors_returned.load(Ordering::Relaxed),
                ]
            })
            .collect();
        svc1.shutdown();

        let svc4 = SamplingService::launch_cfg(&g, &ea, 1, ServiceConfig::new(4, shard)).unwrap();
        let mut c4 = svc4.client(8);
        let t4 = sample_tree(&mut c4, &seeds, &fanouts, &SampleConfig::default()).unwrap();
        let totals4: Vec<[u64; 4]> = svc4
            .stats
            .iter()
            .map(|s| {
                [
                    s.requests.load(Ordering::Relaxed),
                    s.seeds.load(Ordering::Relaxed),
                    s.edges_scanned.load(Ordering::Relaxed),
                    s.neighbors_returned.load(Ordering::Relaxed),
                ]
            })
            .collect();
        assert_eq!(t1.levels, t4.levels, "tree levels must be bit-equal");
        assert_eq!(t1.masks, t4.masks);
        assert_eq!(totals1, totals4, "per-partition stats totals must match");
        for (stats, tot) in svc4.worker_requests().unwrap().iter().zip(&totals4) {
            assert_eq!(stats.len(), 4);
            assert_eq!(stats.iter().sum::<u64>(), tot[0], "attribution sums to requests");
        }
        svc4.shutdown();
    }

    /// The headline invariant of DESIGN.md §12 at unit scope: a loopback
    /// socket deployment (launch_remote over ephemeral TCP ports) returns
    /// the same sampled bits, workload counters and balanced seeds as the
    /// in-process pool with identical (seed, workers, shard_size).
    #[test]
    fn loopback_socket_service_matches_in_process() {
        let mut rng = Rng::new(145);
        let g = generator::heterogeneous_graph(700, 8000, 2, 3, 2.2, &mut rng);
        let ea = AdaDNE::default().partition(&g, 3, 0);
        let cfg = ServiceConfig::new(2, 9);

        let local = SamplingService::launch_cfg(&g, &ea, 1, cfg).unwrap();
        let mut srng = Rng::new(5);
        let seeds = balanced_seeds(&local, 16, &mut srng);
        let mut c = local.client(6);
        let want = sample_tree(&mut c, &seeds, &[5, 3], &SampleConfig::default()).unwrap();
        let want_wl = local.workload().unwrap();
        local.shutdown();

        let listens: Vec<String> = (0..3).map(|_| "tcp:127.0.0.1:0".to_string()).collect();
        let (svc, servers) = SamplingService::launch_remote(&g, &ea, 1, cfg, &listens).unwrap();
        assert_eq!(svc.num_partitions(), 3);
        assert!(svc.partitions.is_empty(), "connected service holds no graphs");
        let mut srng = Rng::new(5);
        let remote_seeds = balanced_seeds(&svc, 16, &mut srng);
        assert_eq!(remote_seeds, seeds, "balanced seeds must not depend on transport");
        let mut c = svc.client(6);
        let got = sample_tree(&mut c, &remote_seeds, &[5, 3], &SampleConfig::default()).unwrap();
        assert_eq!(got.levels, want.levels, "socket transport changed sampled bits");
        assert_eq!(got.masks, want.masks);
        assert_eq!(svc.workload().unwrap(), want_wl, "workload counters must cross the wire");
        assert!(svc.graph_bytes().unwrap() > 0);
        svc.shutdown();
        for s in servers {
            s.join();
        }
    }

    /// Connecting in shuffled address order still yields partition-id
    /// ordered endpoints; a fleet that misses a partition is rejected with
    /// an error naming the offender.
    #[test]
    fn connect_orders_by_partition_and_rejects_gaps() {
        let mut rng = Rng::new(146);
        let g = generator::chung_lu(400, 3600, 2.1, &mut rng);
        let ea = AdaDNE::default().partition(&g, 3, 0);
        let cfg = ServiceConfig::new(1, usize::MAX);
        let listens: Vec<String> = (0..3).map(|_| "tcp:127.0.0.1:0".to_string()).collect();
        let (svc, servers) = SamplingService::launch_remote(&g, &ea, 1, cfg, &listens).unwrap();
        let addrs: Vec<String> =
            svc.endpoints.iter().map(|e| e.peer().to_string()).collect();
        svc.disconnect();

        // Reversed address order must still map endpoint i -> partition i.
        let shuffled: Vec<String> = addrs.iter().rev().cloned().collect();
        let svc2 = SamplingService::connect(&shuffled, g.n, cfg).unwrap();
        for (i, e) in svc2.endpoints.iter().enumerate() {
            assert_eq!(e.part_id(), i);
        }
        svc2.disconnect();

        // Dropping partition 0 from the fleet is a coverage error.
        let partial: Vec<String> = addrs[1..].to_vec();
        let err = SamplingService::connect(&partial, g.n, cfg).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("cover partitions"), "{msg}");
        assert!(msg.contains(&addrs[1]), "error must name the offending server: {msg}");

        // Shut the fleet down through a fresh connection.
        let svc3 = SamplingService::connect(&addrs, g.n, cfg).unwrap();
        svc3.shutdown();
        for s in servers {
            s.join();
        }
    }
}
