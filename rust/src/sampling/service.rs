//! Sampling service lifecycle: launch P partition servers (one thread
//! each), hand out clients, expose per-server workload counters, shut down
//! cleanly. This is the in-process analogue of the paper's "P servers will
//! be launched, each for one partition".

use std::sync::mpsc::Sender;
use std::sync::Arc;

use crate::graph::csr::{Graph, VId};
use crate::graph::hetero::{build_partitions, PartitionGraph};
use crate::partition::EdgeAssignment;
use crate::sampling::client::{RouteMode, SamplingClient};
use crate::sampling::request::ServerMsg;
use crate::sampling::server::{spawn, ServerStats};
use crate::util::bitset::BitMatrix;
use crate::util::rng::Rng;

pub struct SamplingService {
    pub servers: Vec<Sender<ServerMsg>>,
    pub stats: Vec<Arc<ServerStats>>,
    pub membership: Arc<BitMatrix>,
    pub partitions: Vec<Arc<PartitionGraph>>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl SamplingService {
    /// Partition `g` with `assign` and launch one server per partition.
    pub fn launch(g: &Graph, assign: &EdgeAssignment, seed: u64) -> Self {
        let parts = build_partitions(g, &assign.part_of_edge, assign.num_parts);
        Self::launch_with_partitions(g.n, parts, seed)
    }

    pub fn launch_with_partitions(
        n: usize,
        parts: Vec<PartitionGraph>,
        seed: u64,
    ) -> Self {
        let num_parts = parts.len();
        let mut membership = BitMatrix::new(n, num_parts);
        for p in &parts {
            for &gid in &p.global_id {
                membership.set(gid as usize, p.part_id);
            }
        }
        let membership = Arc::new(membership);
        let mut servers = Vec::new();
        let mut stats = Vec::new();
        let mut handles = Vec::new();
        let mut partitions = Vec::new();
        for p in parts {
            let st = Arc::new(ServerStats::default());
            let pa = Arc::new(p);
            let (tx, h) = spawn(pa.clone(), st.clone(), seed);
            servers.push(tx);
            stats.push(st);
            handles.push(h);
            partitions.push(pa);
        }
        Self {
            servers,
            stats,
            membership,
            partitions,
            handles,
        }
    }

    /// A client with GLISP's cooperative replica routing.
    pub fn client(&self, seed: u64) -> SamplingClient {
        SamplingClient {
            servers: self.servers.clone(),
            membership: self.membership.clone(),
            mode: RouteMode::AllReplicas,
            rng: Rng::new(seed),
        }
    }

    /// A client with single-owner routing (the DistDGL-like baseline).
    pub fn owner_client(&self, owner: Arc<Vec<u16>>, seed: u64) -> SamplingClient {
        SamplingClient {
            servers: self.servers.clone(),
            membership: self.membership.clone(),
            mode: RouteMode::Owner(owner),
            rng: Rng::new(seed),
        }
    }

    /// Per-server edges-scanned counters — the Fig. 10 workload metric.
    pub fn workload(&self) -> Vec<u64> {
        self.stats
            .iter()
            .map(|s| s.edges_scanned.load(std::sync::atomic::Ordering::Relaxed))
            .collect()
    }

    pub fn reset_stats(&self) {
        use std::sync::atomic::Ordering;
        for s in &self.stats {
            s.requests.store(0, Ordering::Relaxed);
            s.seeds.store(0, Ordering::Relaxed);
            s.edges_scanned.store(0, Ordering::Relaxed);
            s.neighbors_returned.store(0, Ordering::Relaxed);
            s.busy_ns.store(0, Ordering::Relaxed);
        }
    }

    /// Per-server busy time in seconds. `max` of this vector is the
    /// simulated distributed makespan of the traffic since the last reset
    /// (the servers run in parallel in the paper's deployment).
    pub fn busy_secs(&self) -> Vec<f64> {
        self.stats
            .iter()
            .map(|s| s.busy_ns.load(std::sync::atomic::Ordering::Relaxed) as f64 / 1e9)
            .collect()
    }

    /// Total memory of the partitioned graph structures (Table III).
    pub fn graph_bytes(&self) -> usize {
        self.partitions.iter().map(|p| p.nbytes()).sum()
    }

    pub fn shutdown(self) {
        for tx in &self.servers {
            let _ = tx.send(ServerMsg::Shutdown);
        }
        for h in self.handles {
            let _ = h.join();
        }
    }
}

/// Seeds spread evenly across partitions — the paper's "balanced seed"
/// experimental setup (§IV-C): uniformly sample an equal number of seed
/// vertices from each partition.
pub fn balanced_seeds(
    service: &SamplingService,
    per_part: usize,
    rng: &mut Rng,
) -> Vec<VId> {
    let mut seeds = Vec::with_capacity(per_part * service.partitions.len());
    for p in &service.partitions {
        for _ in 0..per_part {
            let l = rng.usize(p.nv());
            seeds.push(p.global(l as u32));
        }
    }
    seeds
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generator;
    use crate::partition::{AdaDNE, Partitioner};
    use crate::sampling::request::SampleConfig;

    #[test]
    fn launch_sample_shutdown() {
        let mut rng = Rng::new(140);
        let g = generator::chung_lu(800, 8000, 2.1, &mut rng);
        let ea = AdaDNE::default().partition(&g, 4, 0);
        let svc = SamplingService::launch(&g, &ea, 1);
        let mut client = svc.client(2);
        let seeds = balanced_seeds(&svc, 8, &mut rng);
        assert_eq!(seeds.len(), 32);
        let got = client
            .sample_one_hop(&seeds, 5, &SampleConfig::default())
            .unwrap();
        assert_eq!(got.offsets.len(), 33);
        // Work must be spread across all servers for AllReplicas routing.
        let wl = svc.workload();
        assert_eq!(wl.len(), 4);
        assert!(wl.iter().sum::<u64>() > 0);
        svc.shutdown();
    }

    #[test]
    fn multiple_clients_share_servers() {
        let mut rng = Rng::new(141);
        let g = generator::chung_lu(500, 5000, 2.1, &mut rng);
        let ea = AdaDNE::default().partition(&g, 2, 0);
        let svc = SamplingService::launch(&g, &ea, 1);
        let mut c1 = svc.client(10);
        let mut c2 = svc.client(11);
        let t1 = std::thread::spawn(move || {
            let seeds: Vec<VId> = (0..100).collect();
            c1.sample_one_hop(&seeds, 4, &SampleConfig::default()).unwrap()
        });
        let seeds: Vec<VId> = (100..200).collect();
        let r2 = c2.sample_one_hop(&seeds, 4, &SampleConfig::default()).unwrap();
        let r1 = t1.join().unwrap();
        assert_eq!(r1.offsets.len(), 101);
        assert_eq!(r2.offsets.len(), 101);
        svc.shutdown();
    }

    #[test]
    fn hotspot_seed_requests_spread_across_replicas() {
        use std::sync::atomic::Ordering;

        // A hub replicated on every partition must have its one-hop load
        // served cooperatively under AllReplicas routing (ServerStats::seeds
        // counts on every replica server), while Owner routing concentrates
        // the same traffic on a single server — the Fig. 10 contrast at the
        // granularity of one hotspot seed.
        let hub_deg = 120usize;
        let parts = 3usize;
        let mut edges: Vec<(VId, VId)> = Vec::new();
        for i in 0..hub_deg {
            edges.push((0, (i + 1) as VId));
        }
        for i in 1..=hub_deg {
            edges.push((i as VId, ((i % hub_deg) + 1) as VId));
        }
        let g = Graph::from_edges(hub_deg + 1, &edges);
        // Round-robin edge assignment: the hub's edges land on all servers.
        let ea = EdgeAssignment {
            num_parts: parts,
            part_of_edge: (0..g.m()).map(|e| (e % parts) as u16).collect(),
        };
        let svc = SamplingService::launch(&g, &ea, 1);
        let occurrences = 40usize;
        let seeds: Vec<VId> = vec![0; occurrences];

        let mut client = svc.client(9);
        client
            .sample_one_hop(&seeds, 8, &SampleConfig::default())
            .unwrap();
        let per_server: Vec<u64> = svc
            .stats
            .iter()
            .map(|s| s.seeds.load(Ordering::Relaxed))
            .collect();
        assert!(
            per_server.iter().all(|&s| s == occurrences as u64),
            "every replica server must see every hub occurrence: {per_server:?}"
        );

        svc.reset_stats();
        let owner = Arc::new(vec![0u16; g.n]);
        let mut oc = svc.owner_client(owner, 10);
        oc.sample_one_hop(&seeds, 8, &SampleConfig::default())
            .unwrap();
        let per_server: Vec<u64> = svc
            .stats
            .iter()
            .map(|s| s.seeds.load(Ordering::Relaxed))
            .collect();
        assert_eq!(per_server[0], occurrences as u64);
        assert!(
            per_server[1..].iter().all(|&s| s == 0),
            "owner routing must concentrate the load: {per_server:?}"
        );
        svc.shutdown();
    }
}
