//! The DistDGL-like comparator stack (DESIGN.md §3): edge-cut partitioning
//! (edges co-located with their source vertex) + single-owner routing, so a
//! hotspot's entire one-hop sampling lands on one server — the architecture
//! whose load imbalance Figs. 9–10 measure.

use std::sync::Arc;

use crate::graph::csr::Graph;
use crate::partition::{edge_cut_to_assignment, EdgeCutLDG};
use crate::sampling::client::SamplingClient;
use crate::sampling::service::SamplingService;

pub struct BaselineStack {
    pub service: SamplingService,
    pub owner: Arc<Vec<u16>>,
}

impl BaselineStack {
    /// Partition with the edge-cut comparator and launch owner-routed
    /// servers. `client()` then reproduces the DistDGL data path.
    pub fn launch(g: &Graph, num_parts: usize, seed: u64) -> anyhow::Result<Self> {
        let va = EdgeCutLDG::default().partition_vertices(g, num_parts, seed);
        let ea = edge_cut_to_assignment(g, &va);
        let service = SamplingService::launch(g, &ea, seed)?;
        Ok(Self {
            service,
            owner: Arc::new(va.part_of_vertex),
        })
    }

    pub fn client(&self, seed: u64) -> SamplingClient {
        self.service.owner_client(self.owner.clone(), seed)
    }

    pub fn shutdown(self) {
        self.service.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generator;
    use crate::sampling::request::SampleConfig;
    use crate::sampling::subgraph::sample_tree;
    use crate::util::rng::Rng;
    use crate::util::stats::balance_ratio;

    #[test]
    fn baseline_samples_correct_neighbors() {
        let mut rng = Rng::new(160);
        let g = generator::chung_lu(800, 8000, 2.1, &mut rng);
        let stack = BaselineStack::launch(&g, 4, 1).unwrap();
        let mut client = stack.client(2);
        let seeds: Vec<u32> = (0..32).collect();
        let t = sample_tree(&mut client, &seeds, &[5], &SampleConfig::default()).unwrap();
        for (i, &p) in t.levels[0].iter().enumerate() {
            for s in 0..5 {
                let c = t.levels[1][i * 5 + s];
                if c != u32::MAX {
                    assert!(g.out_neighbors(p).contains(&c));
                }
            }
        }
        stack.shutdown();
    }

    #[test]
    fn owner_routing_concentrates_hotspot_load() {
        // The core Fig. 10 phenomenon, as a unit test: on a power-law graph
        // with balanced seeds, owner routing must show visibly worse
        // workload balance than replica routing.
        let mut rng = Rng::new(161);
        let g = generator::chung_lu(3000, 60_000, 1.8, &mut rng);
        let parts = 4;

        // Baseline: edge-cut + owner routing.
        let stack = BaselineStack::launch(&g, parts, 1).unwrap();
        let mut bclient = stack.client(3);
        let seeds: Vec<u32> = (0..512).collect();
        sample_tree(&mut bclient, &seeds, &[15, 10], &SampleConfig::default()).unwrap();
        let base_wl: Vec<f64> = stack
            .service
            .workload()
            .unwrap()
            .iter()
            .map(|&w| w.max(1) as f64)
            .collect();
        let base_balance = balance_ratio(&base_wl);
        stack.shutdown();

        // GLISP: AdaDNE + replica routing.
        use crate::partition::{AdaDNE, Partitioner};
        let ea = AdaDNE::default().partition(&g, parts, 1);
        let svc = SamplingService::launch(&g, &ea, 1).unwrap();
        let mut gclient = svc.client(3);
        sample_tree(&mut gclient, &seeds, &[15, 10], &SampleConfig::default()).unwrap();
        let glisp_wl: Vec<f64> = svc.workload().unwrap().iter().map(|&w| w.max(1) as f64).collect();
        let glisp_balance = balance_ratio(&glisp_wl);
        svc.shutdown();

        assert!(
            glisp_balance < base_balance,
            "GLISP balance {glisp_balance:.2} should beat baseline {base_balance:.2}"
        );
    }
}
