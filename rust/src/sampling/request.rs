//! Wire types of the sampling service — the client↔server protocol of the
//! Gather-Apply architecture (paper Fig. 5 / Algorithms 1–4). The message
//! types are transport-independent (DESIGN.md §3): in-process they travel
//! over `std::sync::mpsc` channels, across processes they are serialized
//! by [`crate::sampling::wire`] and carried over TCP/Unix sockets by
//! [`crate::sampling::transport`] (DESIGN.md §12).

use crate::graph::csr::VId;

/// Padding marker in tree-format neighbor arrays.
pub const PAD: VId = VId::MAX;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Direction {
    Out,
    In,
}

/// One-hop gather operator (ROADMAP item 5 operator surface). `Auto`
/// preserves the original two-operator dispatch on
/// [`SampleConfig::weighted`]; the named operators override it.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum GatherOp {
    /// Dispatch on `weighted`: uniform Algorithm D, or A-ES on edge weight.
    #[default]
    Auto,
    /// Deterministic top-`fanout` neighbors by edge weight (RNG-free;
    /// ties broken by edge index, so the pick is unique and shard/pool
    /// invariant by construction).
    TopK,
    /// Weighted sampling without replacement with probability proportional
    /// to each candidate's *global in-degree* — the "popular destination"
    /// prior of recommendation-style link scoring.
    InDegree,
}

#[derive(Clone, Debug)]
pub struct SampleConfig {
    pub direction: Direction,
    pub weighted: bool,
    /// Restrict to one edge type (heterogeneous metapath-style sampling).
    pub etype: Option<u8>,
    /// Operator override; `Auto` keeps the legacy `weighted` dispatch.
    pub op: GatherOp,
}

impl Default for SampleConfig {
    fn default() -> Self {
        Self {
            direction: Direction::Out,
            weighted: false,
            etype: None,
            op: GatherOp::Auto,
        }
    }
}

impl SampleConfig {
    /// Whether responses carry per-neighbor scores the Apply phase must
    /// merge on (instead of concatenating + uniform subsampling).
    pub fn scored(&self) -> bool {
        self.weighted || self.op != GatherOp::Auto
    }
}

/// One-hop gather request: sample up to `fanout` neighbors for each seed.
/// Seeds are global vertex IDs already filtered to this server's replicas.
///
/// A large logical request may be split by the client into seed-range
/// *shards* — contiguous slices of the per-server seed list, each carrying
/// the same `salt` and its own `seed_offset` — so a partition's worker
/// pool can serve one hotspot gather concurrently (DESIGN.md §9).
#[derive(Clone, Debug)]
pub struct GatherRequest {
    pub seeds: Vec<VId>,
    pub fanout: usize,
    pub cfg: SampleConfig,
    /// Client-drawn RNG salt, one per *logical* per-server request (shared
    /// by all of its shards). The server derives each seed occurrence's
    /// sampling stream from (server seed, salt, seed index) — see
    /// `seed_offset` — instead of a persistent per-server stream, so
    /// responses depend neither on the order in which concurrent clients'
    /// requests arrive nor on which pool worker serves which shard — the
    /// property the pipelined producer's ordered (bit-exact) mode rests on
    /// (DESIGN.md §7/§9).
    pub salt: u64,
    /// Index of `seeds[0]` within the logical per-server request this shard
    /// belongs to (0 for an unsharded request). Seed occurrence i of this
    /// shard samples from the per-seed stream (server seed, salt,
    /// seed_offset + i), which makes responses bit-identical for any shard
    /// split and any worker count.
    pub seed_offset: u32,
    /// Transport correlation id, echoed verbatim in the response. Socket
    /// transports assign it so concurrent gathers (e.g. pipelined batch
    /// producers) can share one connection and still route each response
    /// back to its caller; in-process channels have a reply channel per
    /// call and leave it 0. Never an input to sampling.
    pub token: u64,
}

/// Per-seed sampling stream index mixer shared by server and tests: the
/// stream of occurrence `index` under `salt` is `Rng::new(server_seed ^
/// seed_stream_key(salt, index))`.
#[inline]
pub fn seed_stream_key(salt: u64, index: u64) -> u64 {
    salt.wrapping_mul(0xA076_1D64_78BD_642F)
        ^ index.wrapping_add(1).wrapping_mul(0x9E37_79B9_7F4A_7C15)
}

/// Per-seed sampled neighbors in a flattened (offsets, neighbors) layout.
/// `scores` is parallel to `neighbors` and only filled for weighted
/// sampling (the A-ES scores the Apply phase merges on).
#[derive(Clone, Debug, Default)]
pub struct GatherResponse {
    pub part_id: usize,
    /// Echo of the request's shard offset so the client can slot shard
    /// responses back into per-server seed order during the merge.
    pub seed_offset: u32,
    pub offsets: Vec<u32>,
    pub neighbors: Vec<VId>,
    pub scores: Vec<f64>,
    /// Edges scanned serving this request — the workload unit of Fig. 10.
    pub work_edges: u64,
    /// Echo of [`GatherRequest::token`] (response demultiplexing on shared
    /// socket connections; 0 in-process).
    pub token: u64,
}

impl GatherResponse {
    pub fn neighbors_of(&self, i: usize) -> &[VId] {
        &self.neighbors[self.offsets[i] as usize..self.offsets[i + 1] as usize]
    }

    pub fn scores_of(&self, i: usize) -> &[f64] {
        if self.scores.is_empty() {
            &[]
        } else {
            &self.scores[self.offsets[i] as usize..self.offsets[i + 1] as usize]
        }
    }
}

/// Messages a partition server accepts. With a worker pool, each pool
/// member consumes exactly one `Shutdown` off the shared inbox (the
/// service sends one per worker).
pub enum ServerMsg {
    Gather(GatherRequest, std::sync::mpsc::Sender<GatherResponse>),
    Shutdown,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn response_slicing() {
        let r = GatherResponse {
            part_id: 0,
            seed_offset: 0,
            offsets: vec![0, 2, 2, 5],
            neighbors: vec![7, 8, 1, 2, 3],
            scores: vec![],
            work_edges: 0,
            token: 0,
        };
        assert_eq!(r.neighbors_of(0), &[7, 8]);
        assert_eq!(r.neighbors_of(1), &[] as &[VId]);
        assert_eq!(r.neighbors_of(2), &[1, 2, 3]);
    }

    #[test]
    fn seed_stream_keys_are_index_and_salt_sensitive() {
        // The per-seed derivation must decorrelate across both axes: two
        // occurrences of the same vertex in one request (same salt,
        // different index) and the same index under different salts.
        assert_ne!(seed_stream_key(1, 0), seed_stream_key(1, 1));
        assert_ne!(seed_stream_key(1, 0), seed_stream_key(2, 0));
        assert_eq!(seed_stream_key(7, 3), seed_stream_key(7, 3));
    }
}
