//! Wire types of the sampling service — the client↔server protocol of the
//! Gather-Apply architecture (paper Fig. 5 / Algorithms 1–4). Transport is
//! `std::sync::mpsc` channels between threads (DESIGN.md §3: the paper's
//! load-balance phenomena are transport-independent).

use crate::graph::csr::VId;

/// Padding marker in tree-format neighbor arrays.
pub const PAD: VId = VId::MAX;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Direction {
    Out,
    In,
}

#[derive(Clone, Debug)]
pub struct SampleConfig {
    pub direction: Direction,
    pub weighted: bool,
    /// Restrict to one edge type (heterogeneous metapath-style sampling).
    pub etype: Option<u8>,
}

impl Default for SampleConfig {
    fn default() -> Self {
        Self {
            direction: Direction::Out,
            weighted: false,
            etype: None,
        }
    }
}

/// One-hop gather request: sample up to `fanout` neighbors for each seed.
/// Seeds are global vertex IDs already filtered to this server's replicas.
#[derive(Clone, Debug)]
pub struct GatherRequest {
    pub seeds: Vec<VId>,
    pub fanout: usize,
    pub cfg: SampleConfig,
    /// Client-drawn RNG salt: the server derives this request's sampling
    /// stream from (server seed, salt) instead of a persistent per-server
    /// stream, so responses do not depend on the order in which concurrent
    /// clients' requests arrive — the property the pipelined producer's
    /// ordered (bit-exact) mode rests on (DESIGN.md §7).
    pub salt: u64,
}

/// Per-seed sampled neighbors in a flattened (offsets, neighbors) layout.
/// `scores` is parallel to `neighbors` and only filled for weighted
/// sampling (the A-ES scores the Apply phase merges on).
#[derive(Clone, Debug, Default)]
pub struct GatherResponse {
    pub part_id: usize,
    pub offsets: Vec<u32>,
    pub neighbors: Vec<VId>,
    pub scores: Vec<f64>,
    /// Edges scanned serving this request — the workload unit of Fig. 10.
    pub work_edges: u64,
}

impl GatherResponse {
    pub fn neighbors_of(&self, i: usize) -> &[VId] {
        &self.neighbors[self.offsets[i] as usize..self.offsets[i + 1] as usize]
    }

    pub fn scores_of(&self, i: usize) -> &[f64] {
        if self.scores.is_empty() {
            &[]
        } else {
            &self.scores[self.offsets[i] as usize..self.offsets[i + 1] as usize]
        }
    }
}

/// Messages a partition server accepts.
pub enum ServerMsg {
    Gather(GatherRequest, std::sync::mpsc::Sender<GatherResponse>),
    /// Fetch the precomputed one-hop neighbor cache plan for boundary
    /// vertices (used by the inference engine's static cache fill).
    Shutdown,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn response_slicing() {
        let r = GatherResponse {
            part_id: 0,
            offsets: vec![0, 2, 2, 5],
            neighbors: vec![7, 8, 1, 2, 3],
            scores: vec![],
            work_edges: 0,
        };
        assert_eq!(r.neighbors_of(0), &[7, 8]);
        assert_eq!(r.neighbors_of(1), &[] as &[VId]);
        assert_eq!(r.neighbors_of(2), &[1, 2, 3]);
    }
}
