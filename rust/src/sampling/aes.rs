//! Efraimidis–Spirakis Algorithm A-ES (IPL'06): weighted sampling without
//! replacement reduced to Top-K over scores `s_i = u_i^(1/w_i)` (paper
//! §III-C). The reduction is what makes the *distributed* weighted sampler
//! trivial: each server scores its local neighbors (WeightedGatherOp), the
//! client keeps the global top-f (WeightedApplyOp) — no alias tables, no
//! cross-server normalization.

use crate::util::rng::Rng;
use crate::util::topk::TopK;

/// Score one item. Weights that are not strictly positive — zero, negative,
/// or NaN — are treated as impossible (score 0) and consume no RNG draw.
#[inline]
pub fn score(rng: &mut Rng, weight: f32) -> f64 {
    if !(weight > 0.0) {
        return 0.0;
    }
    rng.f64_open().powf(1.0 / weight as f64)
}

/// Smallest weight the block-scored fast path accepts. `f64_open()` is at
/// least 2^-53 (ln u ≥ −36.74), and `u^(1/w)` can only underflow to 0 when
/// `ln(u)/w < ln(2^-1075) ≈ −745`, i.e. when `w < 36.74/745 ≈ 0.0493`. With
/// every weight ≥ 2^-4 the score is therefore always strictly positive, so
/// the tiebreak draw that follows each uniform in [`score`]'s caller loop is
/// unconditional and the whole draw sequence is statically known.
pub const W_MIN: f32 = 0.0625;

/// Score a whole candidate block, reproducing bit-for-bit the draw sequence
/// of the scalar loop `{ s = score(rng, w); if s > 0 { t = rng.next_u64() } }`
/// per candidate. When every weight is ≥ [`W_MIN`] (the common case — graph
/// weights are sampled in [0.1, 1]) the uniforms and tiebreaks are pre-drawn
/// in one pass and `u^(1/w)` is computed densely over the slice with
/// precomputed reciprocal weights; otherwise it falls back to the scalar
/// lockstep reference, so candidates with non-positive (or NaN) weights get
/// score 0 and no tiebreak draw, exactly as before. Entries with score 0
/// carry tiebreak 0 and must not be pushed.
pub fn score_block(
    rng: &mut Rng,
    weights: &[f32],
    inv: &mut Vec<f64>,
    scores: &mut Vec<f64>,
    tiebreaks: &mut Vec<u64>,
) {
    scores.clear();
    tiebreaks.clear();
    if weights.iter().all(|&w| w >= W_MIN) {
        inv.clear();
        inv.extend(weights.iter().map(|&w| 1.0 / (w as f64)));
        scores.reserve(weights.len());
        tiebreaks.reserve(weights.len());
        for _ in 0..weights.len() {
            scores.push(rng.f64_open());
            tiebreaks.push(rng.next_u64());
        }
        for (s, &r) in scores.iter_mut().zip(inv.iter()) {
            *s = s.powf(r);
        }
    } else {
        for &w in weights {
            let s = score(rng, w);
            scores.push(s);
            tiebreaks.push(if s > 0.0 { rng.next_u64() } else { 0 });
        }
    }
}

/// Sample up to k items without replacement with probability proportional
/// to weight. Returns (index, score) sorted by score descending — scores
/// travel with the items so a downstream Top-K can merge across servers.
pub fn sample_weighted(rng: &mut Rng, weights: &[f32], k: usize) -> Vec<(usize, f64)> {
    let mut tk = TopK::new(k.min(weights.len()));
    for (i, &w) in weights.iter().enumerate() {
        let s = score(rng, w);
        if s > 0.0 {
            tk.push(s, rng.next_u64(), i);
        }
    }
    tk.into_sorted().into_iter().map(|(s, i)| (i, s)).collect()
}

/// Merge per-server (item, score) lists into the global top-k — the
/// WeightedApplyOp core (paper Algorithm 4, line 3). This is the *tested
/// reference* for the merge semantics: the hot path in
/// `SamplingClient::sample_one_hop` inlines the same push order and
/// tiebreak rule over a reused [`TopK`] to avoid per-seed allocations;
/// keep the two in lockstep.
pub fn merge_top_k<T: Copy>(lists: &[Vec<(T, f64)>], k: usize) -> Vec<(T, f64)> {
    let mut tk = TopK::new(k);
    let mut tiebreak = 0u64;
    for list in lists {
        for &(item, s) in list {
            tk.push(s, tiebreak, item);
            tiebreak += 1;
        }
    }
    tk.into_sorted().into_iter().map(|(s, t)| (t, s)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn respects_k_and_distinct() {
        let mut rng = Rng::new(110);
        let w = vec![1.0f32; 20];
        let s = sample_weighted(&mut rng, &w, 5);
        assert_eq!(s.len(), 5);
        let mut idx: Vec<usize> = s.iter().map(|x| x.0).collect();
        idx.sort_unstable();
        idx.dedup();
        assert_eq!(idx.len(), 5);
    }

    #[test]
    fn weight_proportionality() {
        // Item with weight 9 among weights 1 should be picked (k=1) ~ 9/(9+9)
        // of the time vs the aggregate of nine weight-1 items.
        let mut rng = Rng::new(111);
        let mut w = vec![1.0f32; 9];
        w.push(9.0);
        let mut heavy = 0usize;
        let trials = 20_000;
        for _ in 0..trials {
            let s = sample_weighted(&mut rng, &w, 1);
            if s[0].0 == 9 {
                heavy += 1;
            }
        }
        let frac = heavy as f64 / trials as f64;
        assert!((frac - 0.5).abs() < 0.02, "heavy frac {frac}");
    }

    #[test]
    fn zero_weight_never_sampled() {
        let mut rng = Rng::new(112);
        let w = [0.0f32, 1.0, 1.0];
        for _ in 0..200 {
            let s = sample_weighted(&mut rng, &w, 2);
            assert!(s.iter().all(|&(i, _)| i != 0));
        }
    }

    #[test]
    fn distributed_equals_centralized_in_distribution() {
        // Splitting candidates across "servers" and merging top-k must give
        // the same first-item marginals as scoring centrally: both are
        // A-ES over the same weight multiset.
        let trials = 30_000;
        let k = 2;
        let w_all = [4.0f32, 3.0, 2.0, 1.0];
        let mut rng = Rng::new(113);
        let mut count_central = [0usize; 4];
        let mut count_dist = [0usize; 4];
        for _ in 0..trials {
            for &(i, _) in &sample_weighted(&mut rng, &w_all, k) {
                count_central[i] += 1;
            }
            // two servers: {0,1} and {2,3}
            let a: Vec<(usize, f64)> = sample_weighted(&mut rng, &w_all[..2], k);
            let b: Vec<(usize, f64)> =
                sample_weighted(&mut rng, &w_all[2..], k)
                    .into_iter()
                    .map(|(i, s)| (i + 2, s))
                    .collect();
            for &(i, _) in &merge_top_k(&[a, b], k) {
                count_dist[i] += 1;
            }
        }
        for i in 0..4 {
            let pc = count_central[i] as f64 / trials as f64;
            let pd = count_dist[i] as f64 / trials as f64;
            assert!((pc - pd).abs() < 0.02, "item {i}: central {pc} dist {pd}");
        }
    }

    #[test]
    fn nan_and_negative_weights_score_zero_without_draws() {
        let mut rng = Rng::new(990);
        let mut twin = rng.clone();
        assert_eq!(score(&mut rng, f32::NAN), 0.0);
        assert_eq!(score(&mut rng, -1.0), 0.0);
        assert_eq!(score(&mut rng, 0.0), 0.0);
        // None of the above consumed a draw: the streams still agree.
        assert_eq!(rng.next_u64(), twin.next_u64());
    }

    /// The block scorer must replay the scalar loop's exact draw sequence —
    /// both on the dense fast path (all weights ≥ W_MIN) and on the scalar
    /// fallback (a below-threshold or non-positive weight present).
    #[test]
    fn score_block_matches_scalar_lockstep() {
        let cases: Vec<Vec<f32>> = vec![
            vec![1.0, 0.5, 0.25, 0.9, 0.0625], // fast path
            (0..200).map(|i| 0.1 + (i % 10) as f32 * 0.09).collect(),
            vec![1.0, 0.01, 0.7],     // sub-W_MIN → fallback
            vec![0.5, 0.0, -2.0, 0.8] // non-positive → fallback
        ];
        for (case, weights) in cases.iter().enumerate() {
            let mut a = Rng::new(7000 + case as u64);
            let mut b = a.clone();
            let mut scalar: Vec<(f64, u64)> = Vec::new();
            for &w in weights {
                let s = score(&mut a, w);
                scalar.push((s, if s > 0.0 { a.next_u64() } else { 0 }));
            }
            let (mut inv, mut scores, mut ties) = (Vec::new(), Vec::new(), Vec::new());
            score_block(&mut b, weights, &mut inv, &mut scores, &mut ties);
            let block: Vec<(f64, u64)> = scores.iter().copied().zip(ties.iter().copied()).collect();
            assert_eq!(scalar, block, "case {case}");
            // and the RNGs end in the same state
            assert_eq!(a.next_u64(), b.next_u64(), "case {case}");
        }
    }

    #[test]
    fn merge_keeps_global_best() {
        let lists = vec![
            vec![(1u32, 0.9), (2, 0.5)],
            vec![(3u32, 0.95), (4, 0.1)],
        ];
        let top = merge_top_k(&lists, 2);
        let ids: Vec<u32> = top.iter().map(|x| x.0).collect();
        assert_eq!(ids, vec![3, 1]);
    }
}
