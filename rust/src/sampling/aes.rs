//! Efraimidis–Spirakis Algorithm A-ES (IPL'06): weighted sampling without
//! replacement reduced to Top-K over scores `s_i = u_i^(1/w_i)` (paper
//! §III-C). The reduction is what makes the *distributed* weighted sampler
//! trivial: each server scores its local neighbors (WeightedGatherOp), the
//! client keeps the global top-f (WeightedApplyOp) — no alias tables, no
//! cross-server normalization.

use crate::util::rng::Rng;
use crate::util::topk::TopK;

/// Score one item. Weights ≤ 0 are treated as impossible (score 0).
#[inline]
pub fn score(rng: &mut Rng, weight: f32) -> f64 {
    if weight <= 0.0 {
        return 0.0;
    }
    rng.f64_open().powf(1.0 / weight as f64)
}

/// Sample up to k items without replacement with probability proportional
/// to weight. Returns (index, score) sorted by score descending — scores
/// travel with the items so a downstream Top-K can merge across servers.
pub fn sample_weighted(rng: &mut Rng, weights: &[f32], k: usize) -> Vec<(usize, f64)> {
    let mut tk = TopK::new(k.min(weights.len()));
    for (i, &w) in weights.iter().enumerate() {
        let s = score(rng, w);
        if s > 0.0 {
            tk.push(s, rng.next_u64(), i);
        }
    }
    tk.into_sorted().into_iter().map(|(s, i)| (i, s)).collect()
}

/// Merge per-server (item, score) lists into the global top-k — the
/// WeightedApplyOp core (paper Algorithm 4, line 3). This is the *tested
/// reference* for the merge semantics: the hot path in
/// `SamplingClient::sample_one_hop` inlines the same push order and
/// tiebreak rule over a reused [`TopK`] to avoid per-seed allocations;
/// keep the two in lockstep.
pub fn merge_top_k<T: Copy>(lists: &[Vec<(T, f64)>], k: usize) -> Vec<(T, f64)> {
    let mut tk = TopK::new(k);
    let mut tiebreak = 0u64;
    for list in lists {
        for &(item, s) in list {
            tk.push(s, tiebreak, item);
            tiebreak += 1;
        }
    }
    tk.into_sorted().into_iter().map(|(s, t)| (t, s)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn respects_k_and_distinct() {
        let mut rng = Rng::new(110);
        let w = vec![1.0f32; 20];
        let s = sample_weighted(&mut rng, &w, 5);
        assert_eq!(s.len(), 5);
        let mut idx: Vec<usize> = s.iter().map(|x| x.0).collect();
        idx.sort_unstable();
        idx.dedup();
        assert_eq!(idx.len(), 5);
    }

    #[test]
    fn weight_proportionality() {
        // Item with weight 9 among weights 1 should be picked (k=1) ~ 9/(9+9)
        // of the time vs the aggregate of nine weight-1 items.
        let mut rng = Rng::new(111);
        let mut w = vec![1.0f32; 9];
        w.push(9.0);
        let mut heavy = 0usize;
        let trials = 20_000;
        for _ in 0..trials {
            let s = sample_weighted(&mut rng, &w, 1);
            if s[0].0 == 9 {
                heavy += 1;
            }
        }
        let frac = heavy as f64 / trials as f64;
        assert!((frac - 0.5).abs() < 0.02, "heavy frac {frac}");
    }

    #[test]
    fn zero_weight_never_sampled() {
        let mut rng = Rng::new(112);
        let w = [0.0f32, 1.0, 1.0];
        for _ in 0..200 {
            let s = sample_weighted(&mut rng, &w, 2);
            assert!(s.iter().all(|&(i, _)| i != 0));
        }
    }

    #[test]
    fn distributed_equals_centralized_in_distribution() {
        // Splitting candidates across "servers" and merging top-k must give
        // the same first-item marginals as scoring centrally: both are
        // A-ES over the same weight multiset.
        let trials = 30_000;
        let k = 2;
        let w_all = [4.0f32, 3.0, 2.0, 1.0];
        let mut rng = Rng::new(113);
        let mut count_central = [0usize; 4];
        let mut count_dist = [0usize; 4];
        for _ in 0..trials {
            for &(i, _) in &sample_weighted(&mut rng, &w_all, k) {
                count_central[i] += 1;
            }
            // two servers: {0,1} and {2,3}
            let a: Vec<(usize, f64)> = sample_weighted(&mut rng, &w_all[..2], k);
            let b: Vec<(usize, f64)> =
                sample_weighted(&mut rng, &w_all[2..], k)
                    .into_iter()
                    .map(|(i, s)| (i + 2, s))
                    .collect();
            for &(i, _) in &merge_top_k(&[a, b], k) {
                count_dist[i] += 1;
            }
        }
        for i in 0..4 {
            let pc = count_central[i] as f64 / trials as f64;
            let pd = count_dist[i] as f64 / trials as f64;
            assert!((pc - pd).abs() < 0.02, "item {i}: central {pc} dist {pd}");
        }
    }

    #[test]
    fn merge_keeps_global_best() {
        let lists = vec![
            vec![(1u32, 0.9), (2, 0.5)],
            vec![(3u32, 0.95), (4, 0.1)],
        ];
        let top = merge_top_k(&lists, 2);
        let ids: Vec<u32> = top.iter().map(|x| x.0).collect();
        assert_eq!(ids, vec![3, 1]);
    }
}
