//! Per-partition sampling server pool (paper Algorithms 2–3, server side).
//! Each partition owns a read-only compact graph shared by R pool workers
//! (`spawn_pool`): the workers pull Gather shards off one shared inbox, so
//! a single hotspot gather — split into seed-range shards by the client —
//! parallelizes *inside* the partition ("the one hop sampling request of
//! high degree vertices handled by multiple servers", §III-C). Work
//! counters are shared atomics so the harness can measure the Fig. 10
//! workload skew without perturbing the servers; per-worker slots attribute
//! requests/busy-time to individual pool members (DESIGN.md §9).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{Receiver, Sender};
use std::sync::{Arc, Mutex};

use crate::graph::csr::VId;
use crate::graph::hetero::PartitionGraph;
use crate::sampling::algo_d;
use crate::sampling::request::{
    seed_stream_key, Direction, GatherOp, GatherRequest, GatherResponse, SampleConfig, ServerMsg,
};
use crate::util::rng::Rng;

/// Shared per-server workload counters (Fig. 10's measurement). The scalar
/// totals are partition-level and invariant to the pool size; the
/// `worker_*` vectors (sized by `with_workers`, empty for ad-hoc servers)
/// attribute requests and busy time to individual pool workers.
#[derive(Debug, Default)]
pub struct ServerStats {
    pub requests: AtomicU64,
    pub seeds: AtomicU64,
    pub edges_scanned: AtomicU64,
    pub neighbors_returned: AtomicU64,
    /// Per-thread CPU nanoseconds spent serving gathers (NOT wall clock:
    /// on a single-core testbed concurrent server threads timeshare the
    /// CPU and wall time would over-count contention ~P×). The simulated
    /// *distributed* makespan of a run is max_p(busy_ns): the paper's P
    /// servers run on parallel machines, so the busiest one gates
    /// completion (Fig. 9's simulated-throughput column).
    pub busy_ns: AtomicU64,
    /// Requests (shards) served by each pool worker; sums to `requests`.
    pub worker_requests: Vec<AtomicU64>,
    /// Per-worker CPU nanoseconds; sums to `busy_ns`.
    pub worker_busy_ns: Vec<AtomicU64>,
}

impl ServerStats {
    /// Stats with per-worker attribution slots for an R-worker pool.
    pub fn with_workers(workers: usize) -> Self {
        Self {
            worker_requests: (0..workers).map(|_| AtomicU64::new(0)).collect(),
            worker_busy_ns: (0..workers).map(|_| AtomicU64::new(0)).collect(),
            ..Default::default()
        }
    }

    pub fn reset(&self) {
        self.requests.store(0, Ordering::Relaxed);
        self.seeds.store(0, Ordering::Relaxed);
        self.edges_scanned.store(0, Ordering::Relaxed);
        self.neighbors_returned.store(0, Ordering::Relaxed);
        self.busy_ns.store(0, Ordering::Relaxed);
        for w in &self.worker_requests {
            w.store(0, Ordering::Relaxed);
        }
        for w in &self.worker_busy_ns {
            w.store(0, Ordering::Relaxed);
        }
    }
}

/// CPU time of the calling thread (CLOCK_THREAD_CPUTIME_ID).
pub fn thread_cpu_ns() -> u64 {
    let mut ts = libc::timespec {
        tv_sec: 0,
        tv_nsec: 0,
    };
    // Safety: ts is a valid out-pointer; the clock id is a constant.
    unsafe {
        libc::clock_gettime(libc::CLOCK_THREAD_CPUTIME_ID, &mut ts);
    }
    ts.tv_sec as u64 * 1_000_000_000 + ts.tv_nsec as u64
}

/// Per-worker gather arena (DESIGN.md §14): every buffer the gather ops
/// need, reused across all requests a pool worker serves so steady-state
/// gathering allocates nothing per seed. Strictly computational scratch —
/// each field is cleared (or fully overwritten) before use within one seed,
/// and no RNG state lives here, so reuse cannot change sampled bits.
pub struct GatherScratch {
    /// Weighted Apply heap, `reset` per seed (allocation kept).
    tk: crate::util::topk::TopK<VId>,
    /// Candidate edge weights gathered for block scoring.
    weights: Vec<f32>,
    /// `aes::score_block` internals: reciprocal weights, scores, tiebreaks.
    inv: Vec<f64>,
    scores: Vec<f64>,
    tiebreaks: Vec<u64>,
    /// Uniform path: Algorithm D output indices (`sample_into`).
    picks: Vec<usize>,
}

impl GatherScratch {
    pub fn new() -> Self {
        Self {
            tk: crate::util::topk::TopK::new(0),
            weights: Vec::new(),
            inv: Vec::new(),
            scores: Vec::new(),
            tiebreaks: Vec::new(),
            picks: Vec::new(),
        }
    }
}

impl Default for GatherScratch {
    fn default() -> Self {
        Self::new()
    }
}

pub struct PartitionServer {
    pub graph: Arc<PartitionGraph>,
    pub stats: Arc<ServerStats>,
    /// Per-partition seed; each seed occurrence's sampling stream is
    /// derived from (seed, request salt, per-server seed index) so
    /// responses are independent of arrival order under concurrent clients
    /// AND of how a request is sharded across pool workers (DESIGN.md
    /// §7/§9).
    seed: u64,
    /// Pool slot for worker-attributed stats (0 for single-thread servers).
    worker: usize,
    /// This worker's gather arena.
    scratch: GatherScratch,
}

impl PartitionServer {
    pub fn new(graph: Arc<PartitionGraph>, stats: Arc<ServerStats>, seed: u64) -> Self {
        Self::for_worker(graph, stats, seed, 0)
    }

    /// A pool member: identical sampling behavior, distinct stats slot.
    pub fn for_worker(
        graph: Arc<PartitionGraph>,
        stats: Arc<ServerStats>,
        seed: u64,
        worker: usize,
    ) -> Self {
        let part = graph.part_id as u64;
        Self {
            graph,
            stats,
            seed: seed ^ part.wrapping_mul(0x9E3779B97F4A7C15),
            worker,
            scratch: GatherScratch::new(),
        }
    }

    /// The sampling stream of one seed occurrence: a pure function of
    /// (partition seed, request salt, per-server seed index). `index` is
    /// the occurrence's position in the *logical* per-server request
    /// (shard offset + position within the shard), so any shard split and
    /// any worker count reproduce identical responses.
    fn seed_stream(&self, salt: u64, index: u64) -> Rng {
        Rng::new(self.seed ^ seed_stream_key(salt, index))
    }

    /// Blocking single-worker server loop; returns on Shutdown or closed
    /// inbox. Kept for ad-hoc servers (tests, tools); the service launches
    /// pools via [`spawn_pool`].
    pub fn run(mut self, inbox: Receiver<ServerMsg>) {
        while let Ok(msg) = inbox.recv() {
            match msg {
                ServerMsg::Gather(req, reply) => {
                    let resp = self.gather(&req);
                    // Client may have given up; ignore send errors.
                    let _ = reply.send(resp);
                }
                ServerMsg::Shutdown => break,
            }
        }
    }

    /// Pool-worker loop over a shared inbox. The mutex is held only while
    /// blocked in `recv` — the winner releases it before serving, so R
    /// workers serve R shards concurrently while one peer parks on the
    /// lock waiting for the next message. Each worker consumes exactly one
    /// `Shutdown` (the service sends one per worker).
    pub fn run_shared(mut self, inbox: Arc<Mutex<Receiver<ServerMsg>>>) {
        loop {
            let msg = {
                let rx = inbox.lock().unwrap();
                rx.recv()
            };
            match msg {
                Ok(ServerMsg::Gather(req, reply)) => {
                    let resp = self.gather(&req);
                    let _ = reply.send(resp);
                }
                Ok(ServerMsg::Shutdown) | Err(_) => break,
            }
        }
    }

    /// One-hop gather over the local partition. `GatherOp::Auto` keeps the
    /// legacy dispatch (UniformGatherOp / WeightedGatherOp on cfg.weighted);
    /// the named operators (TopK, InDegree) override it.
    pub fn gather(&mut self, req: &GatherRequest) -> GatherResponse {
        let t_busy = thread_cpu_ns();
        let g = self.graph.clone();
        let cap = req.seeds.len() * req.fanout;
        let mut resp = GatherResponse {
            part_id: g.part_id,
            seed_offset: req.seed_offset,
            offsets: Vec::with_capacity(req.seeds.len() + 1),
            neighbors: Vec::with_capacity(cap),
            scores: if req.cfg.scored() {
                Vec::with_capacity(cap)
            } else {
                Vec::new()
            },
            work_edges: 0,
            token: req.token,
        };
        resp.offsets.push(0);
        for (i, &seed) in req.seeds.iter().enumerate() {
            if let Some(local) = g.local_id(seed) {
                let mut rng = self.seed_stream(req.salt, req.seed_offset as u64 + i as u64);
                match req.cfg.op {
                    GatherOp::TopK => Self::gather_topk(
                        &g,
                        local,
                        req.fanout,
                        &req.cfg,
                        &mut resp,
                        &mut self.scratch,
                    ),
                    GatherOp::InDegree => Self::gather_in_degree(
                        &g,
                        &mut rng,
                        local,
                        req.fanout,
                        &req.cfg,
                        &mut resp,
                        &mut self.scratch,
                    ),
                    GatherOp::Auto if req.cfg.weighted => Self::gather_weighted(
                        &g,
                        &mut rng,
                        local,
                        req.fanout,
                        &req.cfg,
                        &mut resp,
                        &mut self.scratch,
                    ),
                    GatherOp::Auto => Self::gather_uniform(
                        &g,
                        &mut rng,
                        local,
                        req.fanout,
                        &req.cfg,
                        &mut resp,
                        &mut self.scratch,
                    ),
                }
            }
            resp.offsets.push(resp.neighbors.len() as u32);
        }
        self.stats.requests.fetch_add(1, Ordering::Relaxed);
        self.stats
            .seeds
            .fetch_add(req.seeds.len() as u64, Ordering::Relaxed);
        self.stats
            .edges_scanned
            .fetch_add(resp.work_edges, Ordering::Relaxed);
        self.stats
            .neighbors_returned
            .fetch_add(resp.neighbors.len() as u64, Ordering::Relaxed);
        let busy = thread_cpu_ns().saturating_sub(t_busy);
        self.stats.busy_ns.fetch_add(busy, Ordering::Relaxed);
        if let Some(w) = self.stats.worker_requests.get(self.worker) {
            w.fetch_add(1, Ordering::Relaxed);
        }
        if let Some(w) = self.stats.worker_busy_ns.get(self.worker) {
            w.fetch_add(busy, Ordering::Relaxed);
        }
        resp
    }

    /// Candidate edge range honoring direction + optional edge type.
    /// Returns (global neighbor ids, first local edge index).
    fn candidates<'g>(
        g: &'g PartitionGraph,
        local: u32,
        cfg: &SampleConfig,
    ) -> (&'g [VId], usize) {
        match cfg.direction {
            Direction::Out => match cfg.etype {
                None => {
                    let (a, _) = g.out_range(local);
                    (g.out_neighbors(local), a)
                }
                Some(t) => {
                    // Absolute local-edge indices straight from the type
                    // run index (for weight lookup) — no pointer-offset
                    // recovery games.
                    let (a, b) = g.out_range_of_type(local, t);
                    (&g.out_dst[a..b], a)
                }
            },
            Direction::In => {
                let (a, _) = g.in_range(local);
                (g.in_neighbors(local), a)
            }
        }
    }

    /// UniformGatherOp (Algorithm 2): the server samples
    /// `r = fanout · local_deg / global_deg` of its local neighbors with
    /// Algorithm D. Stochastic rounding keeps E[Σ r over servers] = fanout.
    fn gather_uniform(
        g: &PartitionGraph,
        rng: &mut Rng,
        local: u32,
        fanout: usize,
        cfg: &SampleConfig,
        resp: &mut GatherResponse,
        sc: &mut GatherScratch,
    ) {
        let (cands, _) = Self::candidates(g, local, cfg);
        let local_deg = cands.len();
        if local_deg == 0 {
            return;
        }
        let global_deg = match cfg.direction {
            Direction::Out => g.out_deg_global[local as usize] as usize,
            Direction::In => g.in_deg_global[local as usize] as usize,
        }
        .max(local_deg);
        let exact = fanout as f64 * local_deg as f64 / global_deg as f64;
        let mut r = exact.floor() as usize;
        if rng.f64() < exact - r as f64 {
            r += 1;
        }
        let r = r.min(local_deg);
        if r == 0 {
            return;
        }
        resp.work_edges += r as u64;
        if r == local_deg {
            resp.neighbors.extend_from_slice(cands);
        } else {
            algo_d::sample_into(rng, local_deg, r, &mut sc.picks);
            for &i in &sc.picks {
                resp.neighbors.push(cands[i]);
            }
        }
    }

    /// WeightedGatherOp (Algorithm 3): A-ES scores for local neighbors,
    /// keep the local top-fanout, ship (neighbor, score) to the client.
    /// Weights are gathered into the arena once, block-scored
    /// (`aes::score_block` — bit-identical to the scalar loop), and pushed
    /// through the arena's reused heap.
    fn gather_weighted(
        g: &PartitionGraph,
        rng: &mut Rng,
        local: u32,
        fanout: usize,
        cfg: &SampleConfig,
        resp: &mut GatherResponse,
        sc: &mut GatherScratch,
    ) {
        let (cands, first_edge) = Self::candidates(g, local, cfg);
        if cands.is_empty() {
            return;
        }
        resp.work_edges += cands.len() as u64;
        Self::collect_edge_weights(g, local, cands.len(), first_edge, cfg, &mut sc.weights);
        crate::sampling::aes::score_block(
            rng,
            &sc.weights,
            &mut sc.inv,
            &mut sc.scores,
            &mut sc.tiebreaks,
        );
        sc.tk.reset(fanout.min(cands.len()));
        for (i, &nbr) in cands.iter().enumerate() {
            let s = sc.scores[i];
            if s > 0.0 {
                sc.tk.push(s, sc.tiebreaks[i], nbr);
            }
        }
        for (s, nbr) in sc.tk.drain_sorted() {
            resp.neighbors.push(nbr);
            resp.scores.push(s);
        }
    }

    /// Local edge weights for `cands`, honoring direction (in-edges
    /// reference the owning out-edge for weight lookup — the paper's
    /// (dst, edge_id) trick).
    fn collect_edge_weights(
        g: &PartitionGraph,
        local: u32,
        n_cands: usize,
        first_edge: usize,
        cfg: &SampleConfig,
        out: &mut Vec<f32>,
    ) {
        out.clear();
        match cfg.direction {
            Direction::Out => {
                for i in 0..n_cands {
                    out.push(g.edge_weight((first_edge + i) as u32));
                }
            }
            Direction::In => {
                let (a, _) = g.in_range(local);
                for i in 0..n_cands {
                    out.push(g.edge_weight(g.in_eid[a + i]));
                }
            }
        }
    }

    /// TopKGatherOp: deterministic local top-`fanout` by edge weight, ties
    /// broken toward the lower edge index. RNG-free, so shard/pool
    /// invariance holds by construction; the shipped score is the weight
    /// itself, which the Apply phase merges exactly like A-ES scores.
    fn gather_topk(
        g: &PartitionGraph,
        local: u32,
        fanout: usize,
        cfg: &SampleConfig,
        resp: &mut GatherResponse,
        sc: &mut GatherScratch,
    ) {
        let (cands, first_edge) = Self::candidates(g, local, cfg);
        if cands.is_empty() {
            return;
        }
        resp.work_edges += cands.len() as u64;
        Self::collect_edge_weights(g, local, cands.len(), first_edge, cfg, &mut sc.weights);
        sc.tk.reset(fanout.min(cands.len()));
        for (i, &nbr) in cands.iter().enumerate() {
            // TopK keeps the larger tiebreak on equal scores, so negating
            // the index prefers the earlier edge.
            sc.tk.push(sc.weights[i] as f64, !(i as u64), nbr);
        }
        for (s, nbr) in sc.tk.drain_sorted() {
            resp.neighbors.push(nbr);
            resp.scores.push(s);
        }
    }

    /// InDegreeGatherOp: A-ES weighted sampling without replacement with
    /// probability proportional to each candidate's *global* in-degree (the
    /// "popular destination" prior of link scoring). Vertex-cut partitions
    /// replicate both endpoints of every local edge, so the candidate's
    /// global in-degree is always resolvable locally; the defensive
    /// fallback weight is 1.
    fn gather_in_degree(
        g: &PartitionGraph,
        rng: &mut Rng,
        local: u32,
        fanout: usize,
        cfg: &SampleConfig,
        resp: &mut GatherResponse,
        sc: &mut GatherScratch,
    ) {
        let (cands, _) = Self::candidates(g, local, cfg);
        if cands.is_empty() {
            return;
        }
        resp.work_edges += cands.len() as u64;
        sc.weights.clear();
        for &nbr in cands {
            let w = g
                .local_id(nbr)
                .map_or(1.0, |l| g.in_deg_global[l as usize] as f32);
            sc.weights.push(w.max(1.0));
        }
        crate::sampling::aes::score_block(
            rng,
            &sc.weights,
            &mut sc.inv,
            &mut sc.scores,
            &mut sc.tiebreaks,
        );
        sc.tk.reset(fanout.min(cands.len()));
        for (i, &nbr) in cands.iter().enumerate() {
            let s = sc.scores[i];
            if s > 0.0 {
                sc.tk.push(s, sc.tiebreaks[i], nbr);
            }
        }
        for (s, nbr) in sc.tk.drain_sorted() {
            resp.neighbors.push(nbr);
            resp.scores.push(s);
        }
    }
}

/// Spawn a single-worker server thread; returns its inbox sender. Kept for
/// tests and ad-hoc wiring — the service launches [`spawn_pool`]s.
pub fn spawn(
    graph: Arc<PartitionGraph>,
    stats: Arc<ServerStats>,
    seed: u64,
) -> (Sender<ServerMsg>, std::thread::JoinHandle<()>) {
    let (tx, rx) = std::sync::mpsc::channel();
    let server = PartitionServer::new(graph, stats, seed);
    let handle = std::thread::spawn(move || server.run(rx));
    (tx, handle)
}

/// Spawn an R-worker pool over one shared inbox for a partition. All
/// workers share the read-only `Arc<PartitionGraph>` and the same
/// partition seed (per-seed streams make them interchangeable); shutdown
/// requires one `ServerMsg::Shutdown` per worker.
pub fn spawn_pool(
    graph: Arc<PartitionGraph>,
    stats: Arc<ServerStats>,
    seed: u64,
    workers: usize,
) -> (Sender<ServerMsg>, Vec<std::thread::JoinHandle<()>>) {
    let workers = workers.max(1);
    let (tx, rx) = std::sync::mpsc::channel();
    let rx = Arc::new(Mutex::new(rx));
    let handles = (0..workers)
        .map(|w| {
            let server = PartitionServer::for_worker(graph.clone(), stats.clone(), seed, w);
            let rx = rx.clone();
            std::thread::spawn(move || server.run_shared(rx))
        })
        .collect();
    (tx, handles)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generator;
    use crate::graph::hetero::build_partitions;
    use crate::partition::{AdaDNE, Partitioner};

    fn one_partition() -> Arc<PartitionGraph> {
        let mut rng = Rng::new(120);
        let g = generator::heterogeneous_graph(1000, 12_000, 2, 3, 2.2, &mut rng);
        let ea = AdaDNE::default().partition(&g, 1, 0);
        Arc::new(build_partitions(&g, &ea.part_of_edge, 1).unwrap().remove(0))
    }

    fn req(seeds: Vec<VId>, fanout: usize, salt: u64, cfg: SampleConfig) -> GatherRequest {
        GatherRequest {
            seeds,
            fanout,
            salt,
            cfg,
            seed_offset: 0,
            token: 0,
        }
    }

    #[test]
    fn uniform_single_server_full_degree() {
        // With one partition, local_deg == global_deg => exactly min(f, deg)
        // neighbors per seed.
        let pg = one_partition();
        let mut srv =
            PartitionServer::new(pg.clone(), Arc::new(ServerStats::default()), 1);
        let seeds: Vec<VId> = (0..50).map(|i| pg.global(i)).collect();
        let resp = srv.gather(&req(seeds.clone(), 5, 11, SampleConfig::default()));
        for (i, &s) in seeds.iter().enumerate() {
            let l = pg.local_id(s).unwrap();
            let expect = pg.local_out_degree(l).min(5);
            assert_eq!(resp.neighbors_of(i).len(), expect, "seed {s}");
            // All sampled neighbors are real out-neighbors.
            for n in resp.neighbors_of(i) {
                assert!(pg.out_neighbors(l).contains(n));
            }
        }
    }

    #[test]
    fn uniform_no_duplicates_per_seed() {
        let pg = one_partition();
        let mut srv =
            PartitionServer::new(pg.clone(), Arc::new(ServerStats::default()), 2);
        // Pick a high-degree seed.
        let hub = (0..pg.nv() as u32)
            .max_by_key(|&l| pg.local_out_degree(l))
            .unwrap();
        let resp = srv.gather(&req(vec![pg.global(hub)], 10, 22, SampleConfig::default()));
        // Multigraph can hold genuine duplicate edges; compare against the
        // multiset of candidates instead of requiring distinct values.
        assert_eq!(resp.neighbors_of(0).len(), 10.min(pg.local_out_degree(hub)));
    }

    #[test]
    fn weighted_returns_scores_sorted() {
        let pg = one_partition();
        let mut srv =
            PartitionServer::new(pg.clone(), Arc::new(ServerStats::default()), 3);
        let seeds: Vec<VId> = (0..20).map(|i| pg.global(i)).collect();
        let resp = srv.gather(&req(
            seeds,
            4,
            33,
            SampleConfig {
                weighted: true,
                ..Default::default()
            },
        ));
        assert_eq!(resp.scores.len(), resp.neighbors.len());
        for i in 0..resp.offsets.len() - 1 {
            let sc = resp.scores_of(i);
            for w in sc.windows(2) {
                assert!(w[0] >= w[1], "scores not descending");
            }
        }
    }

    #[test]
    fn topk_matches_full_sort_and_is_rng_free() {
        // The deterministic operator must return exactly the fanout
        // heaviest local edges (ties toward the earlier edge index),
        // independent of the server seed.
        let pg = one_partition();
        let seeds: Vec<VId> = (0..60).map(|i| pg.global(i)).collect();
        let cfg = SampleConfig {
            op: GatherOp::TopK,
            ..Default::default()
        };
        let mut a = PartitionServer::new(pg.clone(), Arc::new(ServerStats::default()), 1);
        let mut b = PartitionServer::new(pg.clone(), Arc::new(ServerStats::default()), 999);
        let ra = a.gather(&req(seeds.clone(), 4, 11, cfg.clone()));
        let rb = b.gather(&req(seeds.clone(), 4, 22, cfg.clone()));
        assert_eq!(ra.neighbors, rb.neighbors, "TopK must ignore seed+salt");
        assert_eq!(ra.scores, rb.scores);
        for (i, &s) in seeds.iter().enumerate() {
            let l = pg.local_id(s).unwrap();
            let (first, _) = pg.out_range(l);
            let mut ranked: Vec<(f32, usize, VId)> = pg
                .out_neighbors(l)
                .iter()
                .enumerate()
                .map(|(j, &n)| (pg.edge_weight((first + j) as u32), j, n))
                .collect();
            ranked.sort_by(|x, y| y.0.total_cmp(&x.0).then(x.1.cmp(&y.1)));
            let want: Vec<VId> = ranked.iter().take(4).map(|r| r.2).collect();
            assert_eq!(ra.neighbors_of(i), &want[..], "seed {s}");
        }
    }

    #[test]
    fn in_degree_op_returns_scores_and_respects_fanout() {
        let pg = one_partition();
        let mut srv = PartitionServer::new(pg.clone(), Arc::new(ServerStats::default()), 8);
        let seeds: Vec<VId> = (0..40).map(|i| pg.global(i)).collect();
        let resp = srv.gather(&req(
            seeds.clone(),
            5,
            88,
            SampleConfig {
                op: GatherOp::InDegree,
                ..Default::default()
            },
        ));
        assert_eq!(resp.scores.len(), resp.neighbors.len());
        for (i, &s) in seeds.iter().enumerate() {
            let l = pg.local_id(s).unwrap();
            assert!(resp.neighbors_of(i).len() <= 5.min(pg.local_out_degree(l)));
            for n in resp.neighbors_of(i) {
                assert!(pg.out_neighbors(l).contains(n));
            }
            for w in resp.scores_of(i).windows(2) {
                assert!(w[0] >= w[1], "scores not descending");
            }
        }
    }

    #[test]
    fn etype_filter_respected() {
        let pg = one_partition();
        let mut srv =
            PartitionServer::new(pg.clone(), Arc::new(ServerStats::default()), 4);
        let seeds: Vec<VId> = (0..100).map(|i| pg.global(i)).collect();
        let resp = srv.gather(&req(
            seeds.clone(),
            8,
            44,
            SampleConfig {
                etype: Some(1),
                ..Default::default()
            },
        ));
        for (i, &s) in seeds.iter().enumerate() {
            let l = pg.local_id(s).unwrap();
            let allowed = pg.out_neighbors_of_type(l, 1);
            for n in resp.neighbors_of(i) {
                assert!(allowed.contains(n), "neighbor {n} not of etype 1");
            }
        }
    }

    #[test]
    fn in_direction_samples_in_neighbors() {
        let pg = one_partition();
        let mut srv =
            PartitionServer::new(pg.clone(), Arc::new(ServerStats::default()), 5);
        let seeds: Vec<VId> = (0..50).map(|i| pg.global(i)).collect();
        let resp = srv.gather(&req(
            seeds.clone(),
            5,
            55,
            SampleConfig {
                direction: Direction::In,
                ..Default::default()
            },
        ));
        for (i, &s) in seeds.iter().enumerate() {
            let l = pg.local_id(s).unwrap();
            for n in resp.neighbors_of(i) {
                assert!(pg.in_neighbors(l).contains(n));
            }
        }
    }

    #[test]
    fn stats_accumulate() {
        let pg = one_partition();
        let stats = Arc::new(ServerStats::default());
        let mut srv = PartitionServer::new(pg.clone(), stats.clone(), 6);
        let seeds: Vec<VId> = (0..10).map(|i| pg.global(i)).collect();
        srv.gather(&req(seeds, 3, 66, SampleConfig::default()));
        assert_eq!(stats.requests.load(Ordering::Relaxed), 1);
        assert_eq!(stats.seeds.load(Ordering::Relaxed), 10);
    }

    #[test]
    fn spawned_server_round_trip() {
        let pg = one_partition();
        let (tx, handle) = spawn(pg.clone(), Arc::new(ServerStats::default()), 7);
        let (rtx, rrx) = std::sync::mpsc::channel();
        tx.send(ServerMsg::Gather(
            req(vec![pg.global(0)], 3, 77, SampleConfig::default()),
            rtx,
        ))
        .unwrap();
        let resp = rrx.recv().unwrap();
        assert_eq!(resp.offsets.len(), 2);
        tx.send(ServerMsg::Shutdown).unwrap();
        handle.join().unwrap();
    }

    /// The tentpole regression: splitting a request into seed-range shards
    /// — including splits landing mid-way through a run of duplicate seeds
    /// — must reproduce the unsharded response bit-for-bit, because every
    /// seed occurrence samples from its own (salt, index)-derived stream.
    #[test]
    fn sharded_gather_is_bit_identical_to_full_request() {
        let pg = one_partition();
        let hub = (0..pg.nv() as u32)
            .max_by_key(|&l| pg.local_out_degree(l))
            .unwrap();
        // Duplicate-heavy seed list: the hub appears many times, straddling
        // every shard boundary below.
        let mut seeds: Vec<VId> = vec![pg.global(hub); 7];
        seeds.extend((0..23).map(|i| pg.global(i)));
        seeds.extend([pg.global(hub); 5]);
        for cfg in [
            SampleConfig::default(),
            SampleConfig {
                weighted: true,
                ..Default::default()
            },
            SampleConfig {
                etype: Some(1),
                ..Default::default()
            },
            SampleConfig {
                direction: Direction::In,
                ..Default::default()
            },
            SampleConfig {
                op: GatherOp::TopK,
                ..Default::default()
            },
            SampleConfig {
                op: GatherOp::InDegree,
                ..Default::default()
            },
            SampleConfig {
                op: GatherOp::InDegree,
                direction: Direction::In,
                ..Default::default()
            },
        ] {
            let mut srv =
                PartitionServer::new(pg.clone(), Arc::new(ServerStats::default()), 9);
            let salt = 0xF00D;
            let full = srv.gather(&req(seeds.clone(), 6, salt, cfg.clone()));
            for shard in [3usize, 5, 16] {
                let mut neighbors = Vec::new();
                let mut scores = Vec::new();
                let mut lens = Vec::new();
                for (si, chunk) in seeds.chunks(shard).enumerate() {
                    let r = srv.gather(&GatherRequest {
                        seeds: chunk.to_vec(),
                        fanout: 6,
                        salt,
                        cfg: cfg.clone(),
                        seed_offset: (si * shard) as u32,
                        token: 0,
                    });
                    assert_eq!(r.seed_offset as usize, si * shard);
                    for i in 0..chunk.len() {
                        lens.push(r.neighbors_of(i).len());
                    }
                    neighbors.extend_from_slice(&r.neighbors);
                    scores.extend_from_slice(&r.scores);
                }
                assert_eq!(neighbors, full.neighbors, "shard={shard} cfg={cfg:?}");
                assert_eq!(scores, full.scores, "shard={shard} cfg={cfg:?}");
                let full_lens: Vec<usize> =
                    (0..seeds.len()).map(|i| full.neighbors_of(i).len()).collect();
                assert_eq!(lens, full_lens, "shard={shard} cfg={cfg:?}");
            }
        }
    }

    /// Duplicate occurrences of one seed draw from distinct index-derived
    /// streams — sampling them independently — while the same occurrence
    /// index reproduces exactly (the per-seed determinism contract).
    #[test]
    fn duplicate_occurrences_use_independent_per_seed_streams() {
        let pg = one_partition();
        let hub = (0..pg.nv() as u32)
            .max_by_key(|&l| pg.local_out_degree(l))
            .unwrap();
        assert!(pg.local_out_degree(hub) > 16, "need a hub for this test");
        let mut srv = PartitionServer::new(pg.clone(), Arc::new(ServerStats::default()), 10);
        let r1 = srv.gather(&req(vec![pg.global(hub); 8], 4, 5, SampleConfig::default()));
        let r2 = srv.gather(&req(vec![pg.global(hub); 8], 4, 5, SampleConfig::default()));
        // Same salt + same indices => identical response.
        assert_eq!(r1.neighbors, r2.neighbors);
        // Occurrences must not all be identical draws (independence): with
        // deg > 16 and fanout 4 the probability of 8 identical samples is
        // negligible.
        let first = r1.neighbors_of(0).to_vec();
        assert!(
            (1..8).any(|i| r1.neighbors_of(i) != &first[..]),
            "duplicate occurrences all drew the same sample: {first:?}"
        );
    }

    #[test]
    fn pool_round_trip_and_worker_attribution() {
        let pg = one_partition();
        let workers = 4;
        let stats = Arc::new(ServerStats::with_workers(workers));
        let (tx, handles) = spawn_pool(pg.clone(), stats.clone(), 11, workers);
        assert_eq!(handles.len(), workers);
        let (rtx, rrx) = std::sync::mpsc::channel();
        let shards = 12usize;
        for s in 0..shards {
            tx.send(ServerMsg::Gather(
                GatherRequest {
                    seeds: (0..8).map(|i| pg.global(i)).collect(),
                    fanout: 3,
                    salt: 13,
                    cfg: SampleConfig::default(),
                    seed_offset: (s * 8) as u32,
                    token: s as u64,
                },
                rtx.clone(),
            ))
            .unwrap();
        }
        drop(rtx);
        let mut got = 0;
        while rrx.recv().is_ok() {
            got += 1;
        }
        assert_eq!(got, shards);
        assert_eq!(stats.requests.load(Ordering::Relaxed), shards as u64);
        let per_worker: u64 = stats
            .worker_requests
            .iter()
            .map(|w| w.load(Ordering::Relaxed))
            .sum();
        assert_eq!(per_worker, shards as u64, "attribution must sum to totals");
        let per_worker_busy: u64 = stats
            .worker_busy_ns
            .iter()
            .map(|w| w.load(Ordering::Relaxed))
            .sum();
        assert_eq!(
            per_worker_busy,
            stats.busy_ns.load(Ordering::Relaxed),
            "busy-time attribution must sum to the partition total"
        );
        // Per-worker shutdown: one Shutdown per pool member.
        for _ in 0..workers {
            tx.send(ServerMsg::Shutdown).unwrap();
        }
        for h in handles {
            h.join().unwrap();
        }
    }
}
