//! Per-partition sampling server (paper Algorithms 2–3, server side). One
//! OS thread per partition owns that partition's compact graph and serves
//! one-hop Gather requests over an mpsc inbox. Work counters are shared
//! atomics so the harness can measure the Fig. 10 workload skew without
//! perturbing the servers.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{Receiver, Sender};
use std::sync::Arc;

use crate::graph::csr::VId;
use crate::graph::hetero::PartitionGraph;
use crate::sampling::algo_d;
use crate::sampling::request::{
    Direction, GatherRequest, GatherResponse, SampleConfig, ServerMsg,
};
use crate::util::rng::Rng;

/// Shared per-server workload counters (Fig. 10's measurement).
#[derive(Debug, Default)]
pub struct ServerStats {
    pub requests: AtomicU64,
    pub seeds: AtomicU64,
    pub edges_scanned: AtomicU64,
    pub neighbors_returned: AtomicU64,
    /// Per-thread CPU nanoseconds spent serving gathers (NOT wall clock:
    /// on a single-core testbed concurrent server threads timeshare the
    /// CPU and wall time would over-count contention ~P×). The simulated
    /// *distributed* makespan of a run is max_p(busy_ns): the paper's P
    /// servers run on parallel machines, so the busiest one gates
    /// completion (Fig. 9's simulated-throughput column).
    pub busy_ns: AtomicU64,
}

/// CPU time of the calling thread (CLOCK_THREAD_CPUTIME_ID).
pub fn thread_cpu_ns() -> u64 {
    let mut ts = libc::timespec {
        tv_sec: 0,
        tv_nsec: 0,
    };
    // Safety: ts is a valid out-pointer; the clock id is a constant.
    unsafe {
        libc::clock_gettime(libc::CLOCK_THREAD_CPUTIME_ID, &mut ts);
    }
    ts.tv_sec as u64 * 1_000_000_000 + ts.tv_nsec as u64
}

pub struct PartitionServer {
    pub graph: Arc<PartitionGraph>,
    pub stats: Arc<ServerStats>,
    /// Per-partition seed; each request's sampling stream is derived from
    /// (seed, request salt) so responses are independent of arrival order
    /// under concurrent clients (the pipelined producer's determinism
    /// contract, DESIGN.md §7).
    seed: u64,
}

impl PartitionServer {
    pub fn new(graph: Arc<PartitionGraph>, stats: Arc<ServerStats>, seed: u64) -> Self {
        let part = graph.part_id as u64;
        Self {
            graph,
            stats,
            seed: seed ^ part.wrapping_mul(0x9E3779B97F4A7C15),
        }
    }

    fn request_rng(&self, salt: u64) -> Rng {
        Rng::new(self.seed ^ salt.wrapping_mul(0xA076_1D64_78BD_642F))
    }

    /// Blocking server loop; returns on Shutdown or closed inbox.
    pub fn run(mut self, inbox: Receiver<ServerMsg>) {
        while let Ok(msg) = inbox.recv() {
            match msg {
                ServerMsg::Gather(req, reply) => {
                    let resp = self.gather(&req);
                    // Client may have given up; ignore send errors.
                    let _ = reply.send(resp);
                }
                ServerMsg::Shutdown => break,
            }
        }
    }

    /// One-hop gather over the local partition: UniformGatherOp /
    /// WeightedGatherOp depending on cfg.weighted.
    pub fn gather(&mut self, req: &GatherRequest) -> GatherResponse {
        let t_busy = thread_cpu_ns();
        let mut rng = self.request_rng(req.salt);
        let g = self.graph.clone();
        let mut resp = GatherResponse {
            part_id: g.part_id,
            offsets: Vec::with_capacity(req.seeds.len() + 1),
            neighbors: Vec::new(),
            scores: if req.cfg.weighted { Vec::new() } else { Vec::new() },
            work_edges: 0,
        };
        resp.offsets.push(0);
        for &seed in &req.seeds {
            if let Some(local) = g.local_id(seed) {
                if req.cfg.weighted {
                    self.gather_weighted(&mut rng, local, req.fanout, &req.cfg, &mut resp);
                } else {
                    self.gather_uniform(&mut rng, local, req.fanout, &req.cfg, &mut resp);
                }
            }
            resp.offsets.push(resp.neighbors.len() as u32);
        }
        self.stats.requests.fetch_add(1, Ordering::Relaxed);
        self.stats
            .seeds
            .fetch_add(req.seeds.len() as u64, Ordering::Relaxed);
        self.stats
            .edges_scanned
            .fetch_add(resp.work_edges, Ordering::Relaxed);
        self.stats
            .neighbors_returned
            .fetch_add(resp.neighbors.len() as u64, Ordering::Relaxed);
        self.stats
            .busy_ns
            .fetch_add(thread_cpu_ns().saturating_sub(t_busy), Ordering::Relaxed);
        resp
    }

    /// Candidate edge range honoring direction + optional edge type.
    /// Returns (global neighbor ids, first local edge index) as a slice.
    fn candidates<'g>(
        g: &'g PartitionGraph,
        local: u32,
        cfg: &SampleConfig,
    ) -> (&'g [VId], usize) {
        match cfg.direction {
            Direction::Out => match cfg.etype {
                None => {
                    let (a, _) = g.out_range(local);
                    (g.out_neighbors(local), a)
                }
                Some(t) => {
                    let sl = g.out_neighbors_of_type(local, t);
                    // The slice aliases out_dst; its element offset IS the
                    // absolute local edge index (for weight lookup).
                    let base = (sl.as_ptr() as usize - g.out_dst.as_ptr() as usize)
                        / std::mem::size_of::<VId>();
                    (sl, base)
                }
            },
            Direction::In => {
                let (a, _) = g.in_range(local);
                (g.in_neighbors(local), a)
            }
        }
    }

    /// UniformGatherOp (Algorithm 2): the server samples
    /// `r = fanout · local_deg / global_deg` of its local neighbors with
    /// Algorithm D. Stochastic rounding keeps E[Σ r over servers] = fanout.
    fn gather_uniform(
        &self,
        rng: &mut Rng,
        local: u32,
        fanout: usize,
        cfg: &SampleConfig,
        resp: &mut GatherResponse,
    ) {
        let g = &self.graph;
        let (cands, _) = Self::candidates(g, local, cfg);
        let local_deg = cands.len();
        if local_deg == 0 {
            return;
        }
        let global_deg = match cfg.direction {
            Direction::Out => g.out_deg_global[local as usize] as usize,
            Direction::In => g.in_deg_global[local as usize] as usize,
        }
        .max(local_deg);
        let exact = fanout as f64 * local_deg as f64 / global_deg as f64;
        let mut r = exact.floor() as usize;
        if rng.f64() < exact - r as f64 {
            r += 1;
        }
        let r = r.min(local_deg);
        if r == 0 {
            return;
        }
        resp.work_edges += r as u64;
        if r == local_deg {
            resp.neighbors.extend_from_slice(cands);
        } else {
            for i in algo_d::sample(rng, local_deg, r) {
                resp.neighbors.push(cands[i]);
            }
        }
    }

    /// WeightedGatherOp (Algorithm 3): A-ES scores for local neighbors,
    /// keep the local top-fanout, ship (neighbor, score) to the client.
    fn gather_weighted(
        &self,
        rng: &mut Rng,
        local: u32,
        fanout: usize,
        cfg: &SampleConfig,
        resp: &mut GatherResponse,
    ) {
        let g = &self.graph;
        let (cands, first_edge) = Self::candidates(g, local, cfg);
        if cands.is_empty() {
            return;
        }
        resp.work_edges += cands.len() as u64;
        let mut tk = crate::util::topk::TopK::new(fanout.min(cands.len()));
        for (i, &nbr) in cands.iter().enumerate() {
            // In-edges reference the owning out-edge for weight lookup (the
            // paper's (dst, edge_id) trick).
            let w = match cfg.direction {
                Direction::Out => g.edge_weight((first_edge + i) as u32),
                Direction::In => {
                    let (a, _) = g.in_range(local);
                    g.edge_weight(g.in_eid[a + i])
                }
            };
            let s = crate::sampling::aes::score(rng, w);
            if s > 0.0 {
                tk.push(s, rng.next_u64(), nbr);
            }
        }
        for (s, nbr) in tk.into_sorted() {
            resp.neighbors.push(nbr);
            resp.scores.push(s);
        }
    }
}

/// Spawn a server thread; returns its inbox sender.
pub fn spawn(
    graph: Arc<PartitionGraph>,
    stats: Arc<ServerStats>,
    seed: u64,
) -> (Sender<ServerMsg>, std::thread::JoinHandle<()>) {
    let (tx, rx) = std::sync::mpsc::channel();
    let server = PartitionServer::new(graph, stats, seed);
    let handle = std::thread::spawn(move || server.run(rx));
    (tx, handle)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generator;
    use crate::graph::hetero::build_partitions;
    use crate::partition::{AdaDNE, Partitioner};

    fn one_partition() -> Arc<PartitionGraph> {
        let mut rng = Rng::new(120);
        let g = generator::heterogeneous_graph(1000, 12_000, 2, 3, 2.2, &mut rng);
        let ea = AdaDNE::default().partition(&g, 1, 0);
        Arc::new(build_partitions(&g, &ea.part_of_edge, 1).remove(0))
    }

    #[test]
    fn uniform_single_server_full_degree() {
        // With one partition, local_deg == global_deg => exactly min(f, deg)
        // neighbors per seed.
        let pg = one_partition();
        let mut srv =
            PartitionServer::new(pg.clone(), Arc::new(ServerStats::default()), 1);
        let seeds: Vec<VId> = (0..50).map(|i| pg.global(i)).collect();
        let resp = srv.gather(&GatherRequest {
            seeds: seeds.clone(),
            fanout: 5,
            salt: 11,
            cfg: SampleConfig::default(),
        });
        for (i, &s) in seeds.iter().enumerate() {
            let l = pg.local_id(s).unwrap();
            let expect = pg.local_out_degree(l).min(5);
            assert_eq!(resp.neighbors_of(i).len(), expect, "seed {s}");
            // All sampled neighbors are real out-neighbors.
            for n in resp.neighbors_of(i) {
                assert!(pg.out_neighbors(l).contains(n));
            }
        }
    }

    #[test]
    fn uniform_no_duplicates_per_seed() {
        let pg = one_partition();
        let mut srv =
            PartitionServer::new(pg.clone(), Arc::new(ServerStats::default()), 2);
        // Pick a high-degree seed.
        let hub = (0..pg.nv() as u32)
            .max_by_key(|&l| pg.local_out_degree(l))
            .unwrap();
        let resp = srv.gather(&GatherRequest {
            seeds: vec![pg.global(hub)],
            fanout: 10,
            salt: 22,
            cfg: SampleConfig::default(),
        });
        // Multigraph can hold genuine duplicate edges; compare against the
        // multiset of candidates instead of requiring distinct values.
        assert_eq!(resp.neighbors_of(0).len(), 10.min(pg.local_out_degree(hub)));
    }

    #[test]
    fn weighted_returns_scores_sorted() {
        let pg = one_partition();
        let mut srv =
            PartitionServer::new(pg.clone(), Arc::new(ServerStats::default()), 3);
        let seeds: Vec<VId> = (0..20).map(|i| pg.global(i)).collect();
        let resp = srv.gather(&GatherRequest {
            seeds,
            fanout: 4,
            salt: 33,
            cfg: SampleConfig {
                weighted: true,
                ..Default::default()
            },
        });
        assert_eq!(resp.scores.len(), resp.neighbors.len());
        for i in 0..resp.offsets.len() - 1 {
            let sc = resp.scores_of(i);
            for w in sc.windows(2) {
                assert!(w[0] >= w[1], "scores not descending");
            }
        }
    }

    #[test]
    fn etype_filter_respected() {
        let pg = one_partition();
        let mut srv =
            PartitionServer::new(pg.clone(), Arc::new(ServerStats::default()), 4);
        let seeds: Vec<VId> = (0..100).map(|i| pg.global(i)).collect();
        let resp = srv.gather(&GatherRequest {
            seeds: seeds.clone(),
            fanout: 8,
            salt: 44,
            cfg: SampleConfig {
                etype: Some(1),
                ..Default::default()
            },
        });
        for (i, &s) in seeds.iter().enumerate() {
            let l = pg.local_id(s).unwrap();
            let allowed = pg.out_neighbors_of_type(l, 1);
            for n in resp.neighbors_of(i) {
                assert!(allowed.contains(n), "neighbor {n} not of etype 1");
            }
        }
    }

    #[test]
    fn in_direction_samples_in_neighbors() {
        let pg = one_partition();
        let mut srv =
            PartitionServer::new(pg.clone(), Arc::new(ServerStats::default()), 5);
        let seeds: Vec<VId> = (0..50).map(|i| pg.global(i)).collect();
        let resp = srv.gather(&GatherRequest {
            seeds: seeds.clone(),
            fanout: 5,
            salt: 55,
            cfg: SampleConfig {
                direction: Direction::In,
                ..Default::default()
            },
        });
        for (i, &s) in seeds.iter().enumerate() {
            let l = pg.local_id(s).unwrap();
            for n in resp.neighbors_of(i) {
                assert!(pg.in_neighbors(l).contains(n));
            }
        }
    }

    #[test]
    fn stats_accumulate() {
        let pg = one_partition();
        let stats = Arc::new(ServerStats::default());
        let mut srv = PartitionServer::new(pg.clone(), stats.clone(), 6);
        let seeds: Vec<VId> = (0..10).map(|i| pg.global(i)).collect();
        srv.gather(&GatherRequest {
            seeds,
            fanout: 3,
            salt: 66,
            cfg: SampleConfig::default(),
        });
        assert_eq!(stats.requests.load(Ordering::Relaxed), 1);
        assert_eq!(stats.seeds.load(Ordering::Relaxed), 10);
    }

    #[test]
    fn spawned_server_round_trip() {
        let pg = one_partition();
        let (tx, handle) = spawn(pg.clone(), Arc::new(ServerStats::default()), 7);
        let (rtx, rrx) = std::sync::mpsc::channel();
        tx.send(ServerMsg::Gather(
            GatherRequest {
                seeds: vec![pg.global(0)],
                fanout: 3,
                salt: 77,
                cfg: SampleConfig::default(),
            },
            rtx,
        ))
        .unwrap();
        let resp = rrx.recv().unwrap();
        assert_eq!(resp.offsets.len(), 2);
        tx.send(ServerMsg::Shutdown).unwrap();
        handle.join().unwrap();
    }
}
